import numpy as np
import pytest

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device.
# Multi-device tests spawn subprocesses that set the flag themselves.


@pytest.fixture(scope="session")
def small_dataset():
    from repro.data.vectors import make_sift_like, make_queries, brute_force_topk
    x = make_sift_like(4000, seed=3)
    q = make_queries(x, 40, seed=4)
    gt = brute_force_topk(x, q, 10)
    return x, q, gt


@pytest.fixture(scope="session")
def small_graph(small_dataset):
    from repro.configs.base import PHNSWConfig
    from repro.core.graph import build_hnsw
    x, _, _ = small_dataset
    cfg = PHNSWConfig(name="test4k", n_points=len(x), ef_construction=50)
    return build_hnsw(x, cfg, seed=0)


@pytest.fixture(scope="session")
def small_pca(small_dataset):
    from repro.core.pca import fit_pca
    x, _, _ = small_dataset
    return fit_pca(x, 15)


@pytest.fixture(scope="session")
def small_xlow(small_dataset, small_pca):
    x, _, _ = small_dataset
    return small_pca.transform(x).astype(np.float32)
