"""Wave-based bulk construction (core/build.py): wave-vs-sequential
recall parity on the 8k fixture across every filter kind, graph
structural invariants, fixed-seed determinism, the cache-key builder
separation, and the MutableIndex wave-insert zero-recompile
guarantee."""
import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp

from repro.configs.base import PHNSWConfig
from repro.core.build import (build_hnsw_wave, graph_invariants,
                              link_wave_layer, select_heuristic_batch)
from repro.core.graph import build_hnsw, build_hnsw_ref, cached_graph
from repro.core.search_jax import build_packed, search_batched
from repro.core.search_ref import recall_at
from repro.data.vectors import (brute_force_topk, make_queries,
                                make_sift_like)


@pytest.fixture(scope="module")
def build8k():
    """The 8k A/B fixture: the SAME (x, cfg, seed) built by both
    builders. ef_construction matches the churn scenario (32) to bound
    the sequential oracle's runtime."""
    cfg = PHNSWConfig(name="build8k", n_points=8000, ef_construction=32)
    x = make_sift_like(8000, seed=11)
    g_wave = build_hnsw(x, cfg, seed=5)          # cfg.builder == "wave"
    g_ref = build_hnsw_ref(x, cfg, seed=5)
    q = make_queries(x, 48, seed=12)
    gt = brute_force_topk(x, q, 10)
    return cfg, x, g_wave, g_ref, q, gt


@pytest.mark.parametrize("kind", ["pca", "pq", "none"])
def test_wave_vs_ref_recall_parity(build8k, kind):
    """Recall@10 of a wave-built graph never trails the sequential
    build by more than 0.01 — for every filter stage (the graph is
    filter-independent; the filter only changes the search). The bound
    is one-sided: the wave builder's richer candidate sets (full-beam
    probe + intra-wave block + symmetric peers) routinely come out
    AHEAD of the serial oracle at this ef_construction."""
    from repro.core.filters import make_filter
    cfg, x, g_wave, g_ref, q, gt = build8k
    filt = make_filter(dataclasses.replace(cfg, filter_kind=kind,
                                           pq_train_iters=4), x)
    rec = {}
    for name, g in (("wave", g_wave), ("ref", g_ref)):
        db = build_packed(g, filt=filt)
        _, fi = search_batched(db, jnp.asarray(q), filt=filt)
        fi = np.asarray(fi)
        rec[name] = float(np.mean([recall_at(fi[i], gt[i], 10)
                                   for i in range(len(q))]))
    assert rec["wave"] >= rec["ref"] - 0.01, rec


def test_wave_graph_invariants(build8k):
    """Degree bounds, -1 suffix padding, no self/dup links, links only
    to nodes at the layer, entry-reachability of every node per layer
    — and the builders share level assignment + entry for a seed."""
    cfg, x, g_wave, g_ref, q, gt = build8k
    for g in (g_wave, g_ref):
        inv = graph_invariants(g)
        assert inv["ok"], inv["violations"]
        assert all(f == 1.0 for f in inv["reachable_frac"]), \
            inv["reachable_frac"]
    np.testing.assert_array_equal(g_wave.levels, g_ref.levels)
    assert g_wave.entry == g_ref.entry
    for l, (aw, ar) in enumerate(zip(g_wave.layers, g_ref.layers)):
        assert aw.shape == ar.shape == (len(x), cfg.degree(l))


def test_wave_build_determinism():
    """Same (x, cfg, seed) -> bit-identical graph, run to run."""
    cfg = PHNSWConfig(name="det2k", n_points=2000, ef_construction=24,
                      wave_size=512)
    x = make_sift_like(2000, seed=7)
    g1 = build_hnsw_wave(x, cfg, seed=3)
    g2 = build_hnsw_wave(x, cfg, seed=3)
    assert g1.entry == g2.entry
    np.testing.assert_array_equal(g1.levels, g2.levels)
    for a1, a2 in zip(g1.layers, g2.layers):
        np.testing.assert_array_equal(a1, a2)


def test_single_wave_build_is_searchable():
    """n < wave_size: one wave against a 1-node snapshot — the
    intra-wave block alone must produce a connected, searchable
    graph."""
    cfg = PHNSWConfig(name="one_wave", n_points=600,
                      ef_construction=24, wave_size=2048)
    x = make_sift_like(600, seed=9)
    g = build_hnsw_wave(x, cfg, seed=1)
    inv = graph_invariants(g)
    assert inv["ok"], inv["violations"]
    assert all(f == 1.0 for f in inv["reachable_frac"])
    from repro.core.pca import fit_pca
    pca = fit_pca(x, cfg.d_low)
    q = make_queries(x, 16, seed=10)
    gt = brute_force_topk(x, q, 10)
    db = build_packed(g, pca.transform(x).astype(np.float32))
    _, fi = search_batched(db, jnp.asarray(q), pca=pca)
    fi = np.asarray(fi)
    rec = float(np.mean([recall_at(fi[i], gt[i], 10)
                         for i in range(len(q))]))
    assert rec > 0.9, rec


def test_select_heuristic_batch_matches_scalar():
    """The batched Algorithm 4 agrees with the scalar oracle
    (graph._select_heuristic) node by node."""
    from repro.core.graph import _select_heuristic
    rng = np.random.default_rng(0)
    x = rng.normal(size=(200, 16)).astype(np.float32)
    B, C, m = 32, 24, 8
    cand_i = np.stack([rng.choice(200, C, replace=False)
                       for _ in range(B)]).astype(np.int64)
    qs = rng.normal(size=(B, 16)).astype(np.float32)
    cand_d = ((x[cand_i] - qs[:, None]) ** 2).sum(-1).astype(np.float32)
    o = np.argsort(cand_d, axis=1, kind="stable")
    cand_d = np.take_along_axis(cand_d, o, 1)
    cand_i = np.take_along_axis(cand_i, o, 1)
    rows, total, _ = select_heuristic_batch(x, cand_d, cand_i, m)
    for b in range(B):
        ref = _select_heuristic(
            x, [(float(d), int(i)) for d, i in zip(cand_d[b], cand_i[b])],
            m)
        assert list(rows[b][:total[b]]) == ref, b


def test_link_wave_layer_degree_bound_and_dedup():
    """Reverse linking respects the degree bound, never duplicates an
    edge, and re-selects overfull rows instead of dropping links."""
    rng = np.random.default_rng(1)
    n, m = 120, 6
    x = rng.normal(size=(n + 8, 16)).astype(np.float32)
    adj = np.full((n + 8, m), -1, np.int32)
    # a dense hub: every wave node will select node 0 (closest)
    x[0] = 0.0
    node_ids = np.arange(n, n + 8)
    x[node_ids] = rng.normal(scale=0.01, size=(8, 16)).astype(np.float32)
    C = 10
    cand_i = np.broadcast_to(np.arange(C), (8, C)).astype(np.int64).copy()
    cand_d = ((x[cand_i] - x[node_ids][:, None]) ** 2).sum(-1)
    o = np.argsort(cand_d, axis=1, kind="stable")
    cand_d = np.take_along_axis(cand_d, o, 1).astype(np.float32)
    cand_i = np.take_along_axis(cand_i, o, 1)
    dirty = link_wave_layer(x, adj, node_ids, cand_d, cand_i)
    valid = adj >= 0
    assert (valid.sum(1) <= m).all()
    # -1 padding is a suffix everywhere
    assert not (valid[:, 1:] & ~valid[:, :-1]).any()
    # no duplicate neighbors within any row
    s = np.sort(adj, axis=1)
    assert not ((s[:, 1:] == s[:, :-1]) & (s[:, 1:] >= 0)).any()
    # no self links
    assert not (adj == np.arange(len(adj))[:, None]).any()
    assert len(dirty)


def test_cached_graph_keys_builders_apart(tmp_path):
    """The cache key embeds the builder + a full-config hash: wave and
    ref builds of the same (x, seed) never collide, and a config tweak
    beyond M/efc (e.g. wave_size) gets its own entry."""
    cfg = PHNSWConfig(name="ck", n_points=400, ef_construction=16)
    x = make_sift_like(400, seed=2)
    g_w = cached_graph(x, cfg, tmp_path, seed=0)
    g_r = cached_graph(x, cfg, tmp_path, seed=0, builder="ref")
    files = sorted(p.name for p in tmp_path.glob("*.npz"))
    assert len(files) == 2, files
    assert any("_wavev" in f for f in files)
    assert any("_refv" in f for f in files)
    cfg2 = dataclasses.replace(cfg, wave_size=128)
    cached_graph(x, cfg2, tmp_path, seed=0)
    assert len(list(tmp_path.glob("*.npz"))) == 3
    # cache round-trip: reloading returns the identical graph
    g_w2 = cached_graph(x, cfg, tmp_path, seed=0)
    for a, b in zip(g_w.layers, g_w2.layers):
        np.testing.assert_array_equal(a, b)
    assert g_w2.entry == g_w.entry
    # both builders' cached graphs pass the invariant check
    for g in (g_w, g_r):
        assert graph_invariants(g)["ok"]


def test_mutable_wave_insert_zero_recompile(small_graph, small_pca):
    """Steady-state wave inserts through MutableIndex never recompile:
    the probe program (shared with the wave builder) and the search
    program stay cache-stable across churn."""
    from repro.core import search_jax
    from repro.index import MutableIndex, mutable

    idx = MutableIndex.from_graph(small_graph, small_pca, seed=1)
    idx.reserve(idx.n + 1200)
    x_new = make_sift_like(1200, seed=33)
    # warmup: compile the probe (first batch) and the search program
    # (at the steady-state query width — raw search has no pad lanes)
    idx.upsert(x_new[:idx.cfg.insert_batch])
    idx.search(x_new[:32])
    counters = (search_jax._search_batched_jit._cache_size(),
                mutable._probe_jit._cache_size())
    ids = idx.upsert(x_new[idx.cfg.insert_batch:])
    _, fi = idx.search(x_new[-32:])
    assert (search_jax._search_batched_jit._cache_size(),
            mutable._probe_jit._cache_size()) == counters, \
        "steady-state wave inserts recompiled the engine"
    # the wave-linked inserts are immediately findable
    hits = (np.asarray(fi)[:, 0] == ids[-32:])
    assert hits.mean() > 0.9
