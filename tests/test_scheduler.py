"""Continuous-batching scheduler tests (DESIGN.md § Serving front-end):
delivery semantics under out-of-order retirement, SLO admission-control
accounting, adaptive-ef recall parity, and the zero-recompile
churn-under-load regression."""
import numpy as np
import pytest


@pytest.fixture(scope="module")
def stream_setup(small_dataset, small_graph, small_pca):
    from repro.core.filters import PCAFilter
    from repro.core.search_jax import build_packed
    from repro.data.vectors import brute_force_topk, make_queries
    from repro.serve.vector_service import VectorSearchService
    x, _, _ = small_dataset
    cfg = small_graph.cfg
    filt = PCAFilter(small_pca, low_dtype=cfg.low_dtype)
    db = build_packed(small_graph, filt.encode(x), filt=filt)
    q = make_queries(x, 200, seed=7)
    gt = brute_force_topk(x, q, 10)
    svc = VectorSearchService(db, small_pca)
    return svc, db, q, gt


def _recall10(idx, gt):
    return np.mean([len(set(idx[i, :10]) & set(gt[i])) / 10
                    for i in range(len(gt))])


def test_run_stream_matches_sync_bitwise(stream_setup):
    """The scheduler path returns the SAME ids as the synchronous
    batch path, in submission order — continuous batching changes
    when a query's work runs, never what it computes."""
    svc, _, q, _ = stream_setup
    idx_sync, st_sync = svc.run_stream_sync(q)
    idx_sched, st_sched = svc.run_stream(q, scheduler=True)
    assert st_sync["path"] == "sync"
    assert st_sched["path"] == "scheduler"
    assert np.array_equal(idx_sync.astype(np.int64), idx_sched)


def test_exactly_once_out_of_order(stream_setup):
    """Mixed-k traffic retires out of submission order (a k=24 query
    runs a deeper beam than a k=4 one); every rid is delivered exactly
    once, and each answer matches the synchronous program run at that
    request's effective ef."""
    from repro.core.search_jax import search_batched
    import jax.numpy as jnp
    svc, db, q, _ = stream_setup
    sched = svc.scheduler(ef=24)
    n = 96
    ks = [4 if i % 2 == 0 else 24 for i in range(n)]
    for i in range(n):
        assert sched.submit(q[i], k=ks[i], rid=i) == i
    got = {}
    order = []
    for c in sched.drain():
        assert c.rid not in got, "duplicate delivery"
        got[c.rid] = c
        order.append(c.rid)
    assert sorted(got) == list(range(n))
    assert order != sorted(order), "expected out-of-order retirement"
    # per-request parity: ef_eff = max(k, ef_policy=10)
    qj = jnp.asarray(q[:n])
    qp = svc.filt.prepare_jnp(qj)
    ref = {}
    for ef_eff in (10, 24):
        _, fi = search_batched(db, qj, qp, ef0=ef_eff)
        ref[ef_eff] = np.asarray(fi)
    for i in range(n):
        ef_eff = max(ks[i], 10)
        assert np.array_equal(got[i].ids, ref[ef_eff][i, :ks[i]])


def test_shed_accounting(stream_setup):
    """Bounded-queue overflow and expired deadlines shed with
    per-reason counters; shed + delivered == submitted."""
    svc, _, q, _ = stream_setup
    sched = svc.scheduler(n_slots=8, max_queue=4)
    fam = svc.stats.registry.get("phnsw_sched_shed_total")
    base_full = fam.labels(reason="queue_full").value
    base_dl = fam.labels(reason="deadline").value
    # overflow: 4 queue places, no ticks -> submissions 5.. shed
    rids = [sched.submit(q[i], k=10) for i in range(6)]
    assert rids[4] is None and rids[5] is None
    assert fam.labels(reason="queue_full").value == base_full + 2
    # expired deadline: scheduled far in the past
    import time
    late = sched.submit(q[6], k=10,
                        t_sched=time.monotonic() - 10.0,
                        deadline_ms=1.0)
    assert late is None
    assert fam.labels(reason="deadline").value == base_dl + 1
    delivered = sched.drain()
    assert len(delivered) == 4
    assert {c.rid for c in delivered} == {r for r in rids
                                          if r is not None}


def test_adaptive_ef_recall_parity(stream_setup):
    """Adaptive step budgets (p50 start + escalation) must not cost
    recall: >= the fixed-budget path's recall - 0.005. (They are in
    fact bit-equal — escalation re-runs the same monotone program.)"""
    svc, _, q, gt = stream_setup
    fixed = svc.scheduler(adaptive_budget=False)
    adaptive = svc.scheduler(adaptive_budget=True)
    esc = svc.stats.registry.get("phnsw_sched_escalations_total")

    def run(s):
        for i in range(len(q)):
            s.submit(q[i], k=10, rid=i)
        out = np.full((len(q), 10), -1, np.int64)
        for c in s.drain():
            out[c.rid] = c.ids
        return out

    idx_fixed = run(fixed)
    # two passes: the first fills the step histogram, the second runs
    # with p50 initial budgets (escalations must fire for deep queries)
    run(adaptive)
    before = esc.value
    idx_adaptive = run(adaptive)
    assert esc.value > before, "p50 budgets should force escalations"
    r_fixed = _recall10(idx_fixed, gt)
    r_adaptive = _recall10(idx_adaptive, gt)
    assert r_adaptive >= r_fixed - 0.005
    assert np.array_equal(idx_fixed, idx_adaptive)


def test_zero_recompile_under_churn(stream_setup):
    """Steady-state serving — admission churn, mixed k, adaptive
    escalation, repeated waves — reuses the warm compiled programs:
    the jit cache counters must not move."""
    from repro.core.search_jax import slot_cache_sizes
    svc, _, q, _ = stream_setup
    sched = svc.scheduler()          # cached default, already warm
    svc.run_stream(q[:64], scheduler=True)
    warm = slot_cache_sizes()
    for wave in range(3):
        for i in range(50):
            sched.submit(q[(wave * 50 + i) % len(q)],
                         k=(i % 10) + 1)
        sched.drain()
    svc.run_stream(q[64:128], scheduler=True)
    assert slot_cache_sizes() == warm


@pytest.mark.parametrize("kind", ["pca", "cascade"])
def test_scheduler_deferred_parity_bitwise(small_dataset, small_graph,
                                           small_pca, kind):
    """The ISSUE-9 acceptance bar: a deferred-rerank service (PCA and
    the cascade — whose retire path additionally runs the promote
    gather off the low2 side-car) serves through the continuous-batching
    scheduler bit-equal to the synchronous batch path, at healthy
    recall."""
    import dataclasses
    from repro.core.filters import PCAFilter, make_filter
    from repro.core.search_jax import build_packed
    from repro.data.vectors import brute_force_topk, make_queries
    from repro.serve.vector_service import VectorSearchService
    x, _, _ = small_dataset
    cfg = dataclasses.replace(small_graph.cfg, deferred_rerank=True,
                              filter_kind=kind, pq_train_iters=8)
    if kind == "pca":
        filt = PCAFilter(small_pca, low_dtype=cfg.low_dtype)
    else:
        filt = make_filter(cfg, x, seed=0, pca=small_pca,
                           levels=small_graph.levels)
    g = dataclasses.replace(small_graph, cfg=cfg)
    db = build_packed(g, filt.encode(x), filt=filt)
    assert db.cfg.deferred_rerank
    svc = VectorSearchService(db, filt=filt)
    assert svc.scheduler_supported
    q = make_queries(x, 120, seed=7)
    gt = brute_force_topk(x, q, 10)
    idx_sync, st_sync = svc.run_stream_sync(q)
    idx_sched, st_sched = svc.run_stream(q, scheduler=True)
    assert st_sched["path"] == "scheduler"
    assert np.array_equal(idx_sync.astype(np.int64), idx_sched)
    assert _recall10(idx_sched, gt) >= 0.9


def test_sharded_degraded_scheduler(small_dataset, small_pca):
    """The sharded slotted path serves GLOBAL ids; with a dead shard
    the done gate and the merge exclude it (answers never contain its
    ids) and completions carry degraded/coverage accounting."""
    from repro.core.distributed import build_sharded
    from repro.core.filters import PCAFilter
    from repro.data.vectors import make_queries
    from repro.serve.vector_service import VectorSearchService
    x, _, _ = small_dataset
    from repro.configs.base import PHNSWConfig
    cfg = PHNSWConfig(name="test4k", n_points=len(x),
                      ef_construction=50)
    filt = PCAFilter(small_pca, low_dtype=cfg.low_dtype)
    sdb = build_sharded(x, cfg, filt, 3, seed=0)
    svc = VectorSearchService(sdb, small_pca)
    q = make_queries(x, 60, seed=11)
    idx_all, _ = svc.run_stream(q, scheduler=True)
    idx_sync, _ = svc.run_stream_sync(q)
    assert np.array_equal(idx_sync.astype(np.int64), idx_all)
    sched = svc.scheduler()
    sched.set_live([True, False, True])
    for i in range(40):
        sched.submit(q[i], k=10, rid=i)
    comps = sched.drain()
    assert sorted(c.rid for c in comps) == list(range(40))
    offs = np.asarray(sdb.offsets)
    cnts = np.asarray(sdb.counts)
    lo, hi = offs[1], offs[1] + cnts[1]
    for c in comps:
        assert c.degraded and c.coverage < 1.0
        assert not ((c.ids >= lo) & (c.ids < hi)).any()
