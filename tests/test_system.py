"""End-to-end system tests: batched JAX search parity, training loop with
checkpoint/restart determinism, serving engine, vector service."""
import shutil
import tempfile
from pathlib import Path

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.core.search_jax import build_packed, search_batched
from repro.core.search_ref import recall_at, run_queries
from repro.launch.mesh import make_host_mesh
from repro.models import get_model
from repro.optim import AdamWConfig
from repro.serve.engine import GenerationEngine
from repro.serve.vector_service import VectorSearchService
from repro.train.loop import TrainLoop, TrainLoopConfig

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=4, kind="train")


def test_batched_jax_search_matches_reference(small_dataset, small_graph,
                                              small_pca, small_xlow):
    """The fixed-shape TPU traversal reaches the same recall as the
    host reference (same algorithm, different execution model)."""
    x, q, gt = small_dataset
    r_ref, _ = run_queries(small_graph, q, gt, algo="phnsw",
                           x_low=small_xlow, pca=small_pca)
    db = build_packed(small_graph, small_xlow)
    _, fi = search_batched(db, jnp.asarray(q), pca=small_pca)
    fi = np.asarray(fi)
    r_jax = float(np.mean([recall_at(fi[i], gt[i], 10)
                           for i in range(len(q))]))
    assert abs(r_jax - r_ref) < 0.08


def test_batched_search_step_telemetry(small_dataset, small_graph,
                                       small_pca, small_xlow):
    """return_stats exposes per-query expansion counts: every query took
    at least one layer-0 step and stayed within the per-layer budget."""
    x, q, gt = small_dataset
    cfg = small_graph.cfg
    db = build_packed(small_graph, small_xlow)
    _, _, stats = search_batched(db, jnp.asarray(q), pca=small_pca,
                                 return_stats=True)
    steps = np.asarray(stats["steps_per_layer"])   # [L, B], top first
    assert steps.shape == (len(db.layers), len(q))
    assert (steps >= 0).all()
    assert (steps[-1] >= 1).all()                  # layer 0 always expands
    for i, layer in enumerate(range(len(db.layers) - 1, -1, -1)):
        assert steps[i].max() <= cfg.max_steps_for_layer(layer)
    assert np.asarray(stats["steps_total"]).sum() == steps.sum()


def test_bf16_layout3_recall_parity(small_dataset, small_graph, small_pca,
                                    small_xlow):
    """Layout (3) stored in bf16: half the inline-vector bytes, recall
    within 0.02 of the f32 store."""
    x, q, gt = small_dataset
    db32 = build_packed(small_graph, small_xlow)
    db16 = build_packed(small_graph, small_xlow, low_dtype="bfloat16")
    assert db16.layers[0].packed_low.dtype == jnp.bfloat16
    assert db16.bytes_layout3 < 0.75 * db32.bytes_layout3
    rec = {}
    for name, db in (("f32", db32), ("bf16", db16)):
        _, fi = search_batched(db, jnp.asarray(q), pca=small_pca)
        fi = np.asarray(fi)
        rec[name] = float(np.mean([recall_at(fi[i], gt[i], 10)
                                   for i in range(len(q))]))
    assert abs(rec["bf16"] - rec["f32"]) <= 0.02


def test_layout_memory_accounting(small_graph, small_xlow):
    """Layout (3) costs extra memory (paper: ~2.9x the dataset)."""
    db = build_packed(small_graph, small_xlow)
    raw = small_graph.x.size * 4
    assert db.bytes_layout3 > 2.0 * raw
    assert db.bytes_layout4 < db.bytes_layout3


def test_train_restart_determinism(tmp_path):
    """12 straight steps == 6 steps + kill + resume for 6 more."""
    cfg = get_smoke_config("starcoder2-3b")
    mesh = make_host_mesh()
    opt = AdamWConfig(lr=1e-3, total_steps=12, warmup_steps=2)

    d1 = tmp_path / "run_straight"
    loop = TrainLoop(cfg, SMOKE_SHAPE, mesh,
                     TrainLoopConfig(steps=12, ckpt_every=6,
                                     ckpt_dir=str(d1), seed=5), opt)
    out1 = loop.run()

    d2 = tmp_path / "run_split"
    loop_a = TrainLoop(cfg, SMOKE_SHAPE, mesh,
                       TrainLoopConfig(steps=6, ckpt_every=6,
                                       ckpt_dir=str(d2), seed=5), opt)
    loop_a.run()
    loop_b = TrainLoop(cfg, SMOKE_SHAPE, mesh,
                       TrainLoopConfig(steps=12, ckpt_every=6,
                                       ckpt_dir=str(d2), seed=5), opt)
    out2 = loop_b.run()
    assert out2["last_metrics"]["loss"] == pytest.approx(
        out1["last_metrics"]["loss"], rel=1e-5)


@pytest.mark.parametrize("arch", ["starcoder2-3b", "mixtral-8x7b",
                                  "whisper-medium", "internvl2-76b",
                                  "recurrentgemma-9b", "rwkv6-1.6b"])
def test_generation_engine(arch):
    cfg = get_smoke_config(arch)
    api = get_model(cfg)
    params = api.init(jax.random.key(0))
    eng = GenerationEngine(cfg, params, max_new=4)
    B, S = 2, 16
    batch = {"tokens": jax.random.randint(jax.random.key(1), (B, S), 0,
                                          cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones((B, cfg.enc_frames, cfg.d_model),
                                   jnp.float32)
    if cfg.vis_tokens:
        batch["patches"] = jnp.ones((B, cfg.vis_tokens, cfg.d_model),
                                    jnp.float32)
    res = eng.generate(batch)
    assert res.tokens.shape == (B, 4)
    assert (res.tokens >= 0).all() and (res.tokens < cfg.vocab).all()


def test_vector_service(small_dataset, small_graph, small_pca, small_xlow):
    x, q, gt = small_dataset
    db = build_packed(small_graph, small_xlow)
    svc = VectorSearchService(db, small_pca, batch_size=16)
    idx, stats = svc.run_stream(q)
    r = float(np.mean([recall_at(idx[i], gt[i], 10) for i in range(len(q))]))
    assert r > 0.75
    assert stats["p50_ms"] > 0
    # the whole stream was served (underfull tail batch included) and
    # pad lanes never leak into results or stats
    assert idx.shape[0] == len(q)
    assert svc.stats.queries == len(q)
    assert svc.stats.latency_ms.count == len(q)


def test_mutable_index_churn_vs_rebuild_and_zero_recompile():
    """The ISSUE-2 acceptance scenario: starting from an 8k index,
    upserting +25% vectors and deleting 10% through the mutable index
    yields recall@10 within 0.02 of a from-scratch rebuild on the same
    final dataset; deleted ids never appear in results; and the whole
    steady-state phase (upserts, deletes, queries) triggers ZERO
    recompilations (asserted via the jit cache sizes of the compiled
    search and probe programs)."""
    from repro.configs.base import PHNSWConfig
    from repro.core import search_jax
    from repro.core.graph import build_hnsw
    from repro.core.pca import fit_pca
    from repro.data.vectors import (brute_force_topk, make_queries,
                                    make_sift_like)
    from repro.index import MutableIndex, mutable

    cfg = PHNSWConfig(name="churn8k", n_points=8000, ef_construction=32)
    x_all = make_sift_like(10_000, seed=21)
    x0, x_new = x_all[:8000], x_all[8000:]          # +25% upserts
    pca = fit_pca(x0, cfg.d_low)
    g = build_hnsw(x0, cfg, seed=0)
    idx = MutableIndex.from_graph(g, pca, seed=1)
    idx.reserve(10_000)      # pre-grow: the one capacity recompile,
    #                          paid before traffic (production pattern)
    svc = VectorSearchService(idx, batch_size=64)

    # ---- warmup: compile the query program (service ctor did) and the
    # insert probe (first upsert batch), then freeze the counters ----
    svc.upsert(x_new[:cfg.insert_batch])
    counters = (search_jax._search_batched_jit._cache_size(),
                mutable._probe_jit._cache_size())

    # ---- steady state: the rest of the churn, all through the service
    svc.upsert(x_new[cfg.insert_batch:])
    rng = np.random.default_rng(2)
    doomed = rng.choice(8000, size=800, replace=False)  # 10% deletes
    svc.delete(doomed)

    q = make_queries(x_all, 64, seed=22)
    fd, fi = svc.query(q)
    fi = np.asarray(fi)

    assert (search_jax._search_batched_jit._cache_size(),
            mutable._probe_jit._cache_size()) == counters, \
        "steady-state upserts/deletes/queries recompiled the engine"

    # ---- deleted ids never appear; results live in the live id space
    assert not np.isin(fi, doomed).any()
    assert (fi >= 0).all() and (fi < idx.n).all()
    assert not idx.deleted[fi.ravel()].any()

    # ---- recall parity vs a from-scratch rebuild on the final dataset
    live = idx.live_ids()
    x_final = idx.x[live]
    gt_live = brute_force_topk(x_final, q, 10)
    remap = np.full(idx.n, -1, np.int64)
    remap[live] = np.arange(len(live))
    fi_live = remap[fi]                      # mutable ids -> live space
    r_mut = float(np.mean([recall_at(fi_live[i], gt_live[i], 10)
                           for i in range(len(q))]))

    g2 = build_hnsw(x_final, cfg, seed=3)
    db2 = build_packed(g2, pca.transform(x_final).astype(np.float32))
    _, fi2 = search_batched(db2, jnp.asarray(q), pca=pca)
    fi2 = np.asarray(fi2)
    r_reb = float(np.mean([recall_at(fi2[i], gt_live[i], 10)
                           for i in range(len(q))]))
    assert abs(r_mut - r_reb) <= 0.02, (r_mut, r_reb)


def test_vector_service_underfull_batch_pads_with_entry(
        small_dataset, small_graph, small_pca, small_xlow):
    """An underfull batch returns the same answers as the same queries
    inside a full batch (pad = entry point, not a repeated query)."""
    x, q, gt = small_dataset
    db = build_packed(small_graph, small_xlow)
    svc = VectorSearchService(db, small_pca, batch_size=16)
    _, fi_full = svc.query(q[:16])
    _, fi_part = svc.query(q[:3])
    np.testing.assert_array_equal(fi_part, fi_full[:3])
    assert svc.stats.queries == 19
