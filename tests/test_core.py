"""pHNSW core: PCA properties, graph invariants, Algorithm 1 behaviour,
cost-model orderings — the paper's claims as assertions."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core.cost_model import DDR4, HBM, query_cost, table3, \
    hw_variant_stats
from repro.core.search_ref import run_queries, search_hnsw, search_phnsw


# ------------------------------- PCA ----------------------------------------

def test_pca_orthonormal(small_pca):
    c = small_pca.components
    np.testing.assert_allclose(c.T @ c, np.eye(c.shape[1]), atol=1e-4)


def test_pca_contraction(small_dataset, small_pca):
    """Low-dim distances never exceed high-dim distances (orthonormal
    projection) — the property the filter's correctness leans on."""
    x, q, _ = small_dataset
    xl = small_pca.transform(x[:500])
    ql = small_pca.transform(q[:10])
    d_hi = ((x[:500][None] - q[:10][:, None]) ** 2).sum(-1)
    d_lo = ((xl[None] - ql[:, None]) ** 2).sum(-1)
    assert np.all(d_lo <= d_hi * (1 + 1e-5))


def test_pca_explains_variance(small_dataset, small_pca):
    assert float(small_pca.explained.sum()) > 0.8


# ------------------------------ graph ---------------------------------------

def test_graph_degree_bounds(small_graph):
    cfg = small_graph.cfg
    for l, adj in enumerate(small_graph.layers):
        assert adj.shape[1] == cfg.degree(l)
        assert adj.max() < small_graph.n


def test_graph_layer_population(small_graph):
    """Geometric level assignment: layer l has ~N/M^l points."""
    sizes = [int((small_graph.levels >= l).sum()) for l in range(3)]
    assert sizes[0] == small_graph.n
    assert sizes[1] < sizes[0] // 8
    assert sizes[2] <= max(sizes[1] // 4, 8)


def test_graph_connectivity(small_graph):
    """Layer 0 must be (almost fully) reachable from the entry point."""
    adj = small_graph.layers[0]
    n = small_graph.n
    seen = np.zeros(n, bool)
    frontier = [small_graph.entry]
    seen[small_graph.entry] = True
    while frontier:
        nxt = adj[frontier]
        nxt = np.unique(nxt[nxt >= 0])
        frontier = [int(i) for i in nxt if not seen[i]]
        seen[[int(i) for i in nxt]] = True
    assert seen.mean() > 0.99


# --------------------------- Algorithm 1 ------------------------------------

def test_phnsw_recall_close_to_hnsw(small_dataset, small_graph, small_pca,
                                    small_xlow):
    x, q, gt = small_dataset
    r_h, _ = run_queries(small_graph, q, gt, algo="hnsw")
    r_p, _ = run_queries(small_graph, q, gt, algo="phnsw",
                         x_low=small_xlow, pca=small_pca)
    assert r_h > 0.75
    assert r_p >= r_h - 0.05      # paper: filter costs ~no recall


def test_phnsw_reduces_highdim_work(small_dataset, small_graph, small_pca,
                                    small_xlow):
    """The core claim: high-dim distance computations bounded by k per
    expansion -> far fewer than HNSW's per-neighbor count."""
    x, q, gt = small_dataset
    _, st_h = run_queries(small_graph, q, gt, algo="hnsw", hw_mode=True)
    _, st_p = run_queries(small_graph, q, gt, algo="phnsw",
                          x_low=small_xlow, pca=small_pca)
    assert st_p.dist_high < st_h.dist_high / 2
    assert st_p.rand_bytes < st_h.rand_bytes / 2


def test_layout_access_patterns(small_dataset, small_graph, small_pca,
                                small_xlow):
    """Layout (3) vs (4): same algorithm, same recall, wildly different
    irregular-access counts (paper IV-A)."""
    x, q, gt = small_dataset
    r_p, st_p = run_queries(small_graph, q, gt, algo="phnsw",
                            x_low=small_xlow, pca=small_pca, layout="packed")
    r_s, st_s = run_queries(small_graph, q, gt, algo="phnsw",
                            x_low=small_xlow, pca=small_pca,
                            layout="separate")
    assert r_p == r_s                      # identical traversal
    assert st_s.rand_accesses > 4 * st_p.rand_accesses
    assert st_p.seq_bytes > st_s.seq_bytes  # inline data moves to bursts


def test_recall_monotone_in_k(small_dataset, small_graph, small_pca,
                              small_xlow):
    """Fig 2: recall non-decreasing (within noise) as k grows; saturates."""
    x, q, gt = small_dataset
    recalls = []
    for k0 in (4, 8, 16, 32):
        r, _ = run_queries(small_graph, q, gt, algo="phnsw",
                           x_low=small_xlow, pca=small_pca,
                           k_schedule=(k0, 8, 3, 3, 3, 3))
        recalls.append(r)
    assert recalls[-1] >= recalls[0] - 1e-9
    # saturation: last doubling gains little
    assert recalls[-1] - recalls[-2] < 0.05


def test_recall_monotone_in_ef(small_dataset, small_graph, small_pca,
                               small_xlow):
    x, q, gt = small_dataset
    r10, _ = run_queries(small_graph, q, gt, algo="hnsw")
    cfgs = small_graph.cfg
    from repro.core.search_ref import search_hnsw, recall_at
    r_small = np.mean([recall_at(search_hnsw(small_graph, qi, ef0=5)[0],
                                 gt[i], 10) for i, qi in enumerate(q)])
    r_big = np.mean([recall_at(search_hnsw(small_graph, qi, ef0=40)[0],
                               gt[i], 10) for i, qi in enumerate(q)])
    assert r_big >= r_small


@pytest.mark.parametrize("impl", ["ref", "fused-pallas"])
def test_search_batched_recall_parity(small_dataset, small_graph,
                                      small_pca, small_xlow, impl,
                                      monkeypatch):
    """Batched engine vs host reference: recall@10 within 0.02, under
    both the jnp-oracle path (REPRO_KERNEL_IMPL=ref) and the fused
    Pallas expand/merge path (interpret mode on CPU)."""
    from repro.core.search_jax import build_packed, search_batched
    from repro.core.search_ref import recall_at
    if impl == "ref":
        monkeypatch.setenv("REPRO_KERNEL_IMPL", "ref")
        monkeypatch.delenv("REPRO_FORCE_PALLAS_INTERPRET", raising=False)
    else:
        monkeypatch.setenv("REPRO_FORCE_PALLAS_INTERPRET", "1")
    # the kernel dispatchers branch on env vars at trace time — drop any
    # compiled programs cached under the other setting
    jax.clear_caches()
    try:
        x, q, gt = small_dataset
        r_ref, _ = run_queries(small_graph, q, gt, algo="phnsw",
                               x_low=small_xlow, pca=small_pca)
        db = build_packed(small_graph, small_xlow)
        _, fi = search_batched(db, jnp.asarray(q), pca=small_pca)
        fi = np.asarray(fi)
        r_jax = float(np.mean([recall_at(fi[i], gt[i], 10)
                               for i in range(len(q))]))
        assert abs(r_jax - r_ref) <= 0.02
    finally:
        jax.clear_caches()


# ----------------------------- cost model -----------------------------------

def _stats(small_dataset, small_graph, small_pca, small_xlow):
    x, q, gt = small_dataset
    _, st_h = run_queries(small_graph, q, gt, algo="hnsw", hw_mode=True)
    _, st_p = run_queries(small_graph, q, gt, algo="phnsw",
                          x_low=small_xlow, pca=small_pca)
    _, st_s = run_queries(small_graph, q, gt, algo="phnsw",
                          x_low=small_xlow, pca=small_pca, layout="separate")
    return table3(hw_variant_stats(st_h, st_p, st_s), n_queries=len(q),
                  dim=x.shape[1], d_low=small_xlow.shape[1])


def test_table3_orderings(small_dataset, small_graph, small_pca, small_xlow):
    """Paper Table III orderings: QPS pHNSW > pHNSW-Sep > (Sep vs Std
    varies with scale) and pHNSW > HNSW-Std on both DRAMs; HBM >= DDR4
    for every variant."""
    t3 = _stats(small_dataset, small_graph, small_pca, small_xlow)
    for d in ("DDR4", "HBM"):
        assert t3["pHNSW"][d].qps > t3["pHNSW-Sep"][d].qps
        assert t3["pHNSW"][d].qps > t3["HNSW-Std"][d].qps
    for v in t3:
        assert t3[v]["HBM"].qps >= t3[v]["DDR4"].qps


def test_fig5_energy_orderings(small_dataset, small_graph, small_pca,
                               small_xlow):
    """Fig 5: pHNSW lowest energy; DRAM dominates energy on DDR4; HBM
    share lower than DDR4 share."""
    t3 = _stats(small_dataset, small_graph, small_pca, small_xlow)
    for d in ("DDR4", "HBM"):
        assert t3["pHNSW"][d].energy_uj < t3["HNSW-Std"][d].energy_uj
        assert t3["pHNSW"][d].energy_uj < t3["pHNSW-Sep"][d].energy_uj
    assert t3["pHNSW"]["DDR4"].dram_energy_share > 0.6
    assert t3["pHNSW"]["HBM"].dram_energy_share < \
        t3["pHNSW"]["DDR4"].dram_energy_share
