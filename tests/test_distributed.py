"""Distribution: sharding-rule coverage, fault-tolerance logic, gradient
compression, multi-device sharded search + cross-mesh checkpoint restore
(subprocess with forced host device count)."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config, SHAPES
from repro.distributed import sharding as shd
from repro.distributed.fault import (GradSkipPolicy, StepMonitor,
                                     healthy_mesh_shape, remesh)
from repro.models import get_model
from repro.optim.compression import compress_grads, decompress_grads


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_rules_cover_all_archs(arch):
    """Every parameter leaf of every arch must have a sharding rule, with
    correct rank, on the production mesh axis sizes."""
    cfg = get_config(arch)
    api = get_model(cfg)
    a_params = api.abstract_params()

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    specs = shd.param_specs(cfg, a_params, FakeMesh())
    flat = jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "_normalized_spec") or True)
    n = len(jax.tree.leaves(a_params))
    assert len(jax.tree.leaves(
        specs, is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))) == n


def test_param_specs_divisibility():
    """No spec may shard a non-divisible dim (whisper's vocab 51865)."""
    cfg = get_config("whisper-medium")
    api = get_model(cfg)
    a_params = api.abstract_params()

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    specs = shd.param_specs(cfg, a_params, FakeMesh())
    flat_p = jax.tree_util.tree_flatten_with_path(a_params)[0]
    flat_s = jax.tree.leaves(
        specs, is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
    for (path, leaf), spec in zip(flat_p, flat_s):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is not None:
                size = {"data": 16, "model": 16}[ax]
                assert dim % size == 0, (path, leaf.shape, spec)


def test_step_monitor_straggler_detection():
    mon = StepMonitor(straggler_factor=2.0)
    for i in range(10):
        ev = mon.heartbeat(i, 1.0)
        assert ev.kind == "ok"
    ev = mon.heartbeat(10, 5.0)
    assert ev.kind == "straggler"
    ev = mon.heartbeat(11, 1.1)
    assert ev.kind == "ok"


def test_grad_skip_policy():
    pol = GradSkipPolicy(planned=8)
    for _ in range(6):
        pol.complete()
    assert pol.should_skip_rest(elapsed_s=100.0, deadline_s=10.0)
    assert not GradSkipPolicy(planned=8, completed=2).should_skip_rest(100, 10)
    assert pol.renorm() == pytest.approx(8 / 6)


def test_healthy_mesh_shape():
    assert healthy_mesh_shape(256) == (16, 16)
    assert healthy_mesh_shape(240) == (15, 16)
    with pytest.raises(RuntimeError):
        healthy_mesh_shape(8, model_parallel=16)


def test_compression_roundtrip():
    tree = {"a": jnp.asarray(np.random.default_rng(0)
                             .standard_normal((300, 17)), jnp.float32),
            "b": jnp.ones((5,), jnp.float32)}
    comp = compress_grads(tree)
    back = decompress_grads(comp, tree)
    for k in tree:
        err = np.abs(np.asarray(back[k]) - np.asarray(tree[k])).max()
        scale = np.abs(np.asarray(tree[k])).max()
        assert err <= scale / 127 * 1.01
    nbytes = sum(np.asarray(c["q"]).nbytes + np.asarray(c["scale"]).nbytes
                 for c in jax.tree.leaves(
                     comp, is_leaf=lambda t: isinstance(t, dict) and "q" in t))
    orig = sum(np.asarray(v).nbytes for v in tree.values())
    assert nbytes < orig / 3   # ~4x compression minus scale overhead


def test_distributed_single_shard_parity_bit_equal(
        small_dataset, small_graph, small_pca, small_xlow):
    """A 1-shard mesh runs the IDENTICAL descent as search_batched (the
    shared _search_batched_impl, entry as data): global ids and dists
    must be bit-equal, offsets 0, all-gather/merge a no-op."""
    from repro.core.distributed import ShardedDB, distributed_search
    from repro.core.search_jax import build_packed, search_batched
    x, q, gt = small_dataset
    db = build_packed(small_graph, small_xlow, drop_empty_layers=False)
    sdb = ShardedDB(
        adj=[l.adj[None] for l in db.layers],
        packed_low=[l.packed_low[None] for l in db.layers],
        low=db.low[None], high=db.high[None],
        entries=jnp.asarray([db.entry], jnp.int32),
        offsets=jnp.asarray([0], jnp.int32),
        cfg=db.cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ql = jnp.asarray(small_pca.transform(q).astype(np.float32))
    fd_d, fi_d = distributed_search(mesh, sdb, jnp.asarray(q), ql)
    fd_b, fi_b = search_batched(db, jnp.asarray(q), ql)
    np.testing.assert_array_equal(np.asarray(fi_d), np.asarray(fi_b))
    np.testing.assert_array_equal(np.asarray(fd_d), np.asarray(fd_b))


def test_search_batched_explicit_entry(small_dataset, small_graph,
                                       small_xlow, small_pca):
    """The explicit entry override reaches the descent: seeding from the
    db's own entry reproduces the default result exactly."""
    from repro.core.search_jax import build_packed, search_batched
    x, q, gt = small_dataset
    db = build_packed(small_graph, small_xlow)
    ql = jnp.asarray(small_pca.transform(q).astype(np.float32))
    fd0, fi0 = search_batched(db, jnp.asarray(q), ql)
    fd1, fi1 = search_batched(db, jnp.asarray(q), ql, entry=db.entry)
    np.testing.assert_array_equal(np.asarray(fi0), np.asarray(fi1))
    # a different (valid) entry still reaches high recall — the descent
    # is entry-robust, which is what the per-shard entries rely on
    alt = int(np.nonzero(small_graph.levels == small_graph.levels.max())
              [0][-1])
    _, fi2 = search_batched(db, jnp.asarray(q), ql, entry=alt)
    fi2 = np.asarray(fi2)
    from repro.core.search_ref import recall_at
    r = float(np.mean([recall_at(fi2[i], gt[i], 10)
                       for i in range(len(q))]))
    assert r > 0.85


SUBPROCESS_SHARDED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs.base import PHNSWConfig
    from repro.data.vectors import make_sift_like, make_queries, brute_force_topk
    from repro.core.pca import fit_pca
    from repro.core.distributed import build_sharded, distributed_search
    from repro.core.search_ref import recall_at

    cfg = PHNSWConfig(name="t", n_points=4000, ef_construction=40)
    x = make_sift_like(4000); q = make_queries(x, 16)
    gt = brute_force_topk(x, q, 10)
    pca = fit_pca(x, cfg.d_low)
    sdb = build_sharded(x, cfg, pca, n_shards=4)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ql = pca.transform(q).astype(np.float32)
    fd, fi = distributed_search(mesh, sdb, jnp.asarray(q), jnp.asarray(ql))
    fi = np.asarray(fi)
    r = float(np.mean([recall_at(fi[i], gt[i], 10) for i in range(len(q))]))
    assert r > 0.8, r
    print("RECALL", r)
""")


@pytest.mark.slow
def test_sharded_search_multidevice():
    out = subprocess.run([sys.executable, "-c", SUBPROCESS_SHARDED],
                         capture_output=True, text=True,
                         env={**__import__("os").environ,
                              "PYTHONPATH": "src"},
                         cwd=Path(__file__).resolve().parents[1])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "RECALL" in out.stdout


SUBPROCESS_REMESH = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import save_checkpoint, restore_checkpoint
    from repro.distributed.fault import remesh

    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    mesh8 = jax.make_mesh((2, 4), ("data", "model"))
    sh8 = {"w": NamedSharding(mesh8, P("data", "model"))}
    t8 = jax.device_put(tree, sh8)
    d = tempfile.mkdtemp()
    save_checkpoint(d, 1, t8)
    # restore onto a SMALLER mesh (elastic downscale 8 -> 4 devices)
    mesh4 = jax.make_mesh((1, 4), ("data", "model"),
                          devices=jax.devices()[:4])
    sh4 = {"w": NamedSharding(mesh4, P("data", "model"))}
    t4 = restore_checkpoint(d, 1, tree, sh4)
    np.testing.assert_array_equal(np.asarray(t4["w"]), np.asarray(tree["w"]))
    # live remesh too
    t4b = remesh(t8, sh4)
    np.testing.assert_array_equal(np.asarray(t4b["w"]), np.asarray(tree["w"]))
    print("REMESH OK")
""")


@pytest.mark.slow
def test_checkpoint_remesh_multidevice():
    out = subprocess.run([sys.executable, "-c", SUBPROCESS_REMESH],
                         capture_output=True, text=True,
                         env={**__import__("os").environ,
                              "PYTHONPATH": "src"},
                         cwd=Path(__file__).resolve().parents[1])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "REMESH OK" in out.stdout


SUBPROCESS_MOE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models import moe as moe_mod
    from repro.distributed import sharding as shd

    cfg = get_smoke_config("qwen3-moe-235b-a22b")   # 4 experts
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    p = moe_mod.init_moe(cfg, jax.random.key(0), jnp.float32)
    x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model), jnp.float32)
    y0, _ = moe_mod._apply_moe_local(cfg, p, x, capacity_factor=100.0)
    with shd.activation_rules({}, mesh), mesh:
        y1, m = jax.jit(lambda p, x: moe_mod.apply_moe(
            cfg, p, x, capacity_factor=100.0))(p, x)
    err = float(jnp.max(jnp.abs(y1 - y0)))
    assert err < 1e-5, err
    # gradients flow through the shard_map dispatch
    def loss(p):
        with shd.activation_rules({}, mesh):
            y, _ = moe_mod.apply_moe(cfg, p, x, capacity_factor=100.0)
        return jnp.sum(jnp.square(y))
    with mesh:
        g = jax.jit(jax.grad(loss))(p)
    gn = sum(float(jnp.sum(jnp.square(v))) for v in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    print("MOE OK", err)
""")


@pytest.mark.slow
def test_moe_sharded_dispatch_multidevice():
    """The shard_map expert-parallel dispatch (the qwen3 perf fix) matches
    the local oracle and is differentiable."""
    out = subprocess.run([sys.executable, "-c", SUBPROCESS_MOE],
                         capture_output=True, text=True,
                         env={**__import__("os").environ,
                              "PYTHONPATH": "src"},
                         cwd=Path(__file__).resolve().parents[1])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MOE OK" in out.stdout


SUBPROCESS_PIPELINE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np, jax, jax.numpy as jnp
    from repro.distributed.pipeline import build_pipeline_forward

    mesh = jax.make_mesh((1, 4), ("data", "model"))
    L, M, B, S, D = 8, 6, 2, 4, 16
    params = {"w": jax.random.normal(jax.random.key(0), (L, D, D)) * 0.1}
    layer_fn = lambda lp, x: jnp.tanh(x @ lp["w"])
    xs = jax.random.normal(jax.random.key(1), (M, B, S, D))
    def seq(params, xs):
        h = xs
        for l in range(L):
            h = layer_fn({"w": params["w"][l]}, h)
        return h
    pf = build_pipeline_forward(mesh, layer_fn, L)
    with mesh:
        out = jax.jit(pf)(params, xs)
    err = float(jnp.max(jnp.abs(out - seq(params, xs))))
    assert err < 1e-5, err
    print("PIPELINE OK", err)
""")


@pytest.mark.slow
def test_pipeline_parallel_multidevice():
    """GPipe-style pipeline over the model axis == sequential forward."""
    out = subprocess.run([sys.executable, "-c", SUBPROCESS_PIPELINE],
                         capture_output=True, text=True,
                         env={**__import__("os").environ,
                              "PYTHONPATH": "src"},
                         cwd=Path(__file__).resolve().parents[1])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "PIPELINE OK" in out.stdout
