"""Distribution: sharding-rule coverage, fault-tolerance logic, gradient
compression, multi-device sharded search + cross-mesh checkpoint restore
(subprocess with forced host device count), and the sharded pHNSW
serving path at full feature parity (ISSUE-4): 1-shard bit-equality for
every filter kind x rerank mode, remainder-distribution regression,
property-based cross-shard merge invariants, a seeded stress sweep vs
the sharded host oracle, a sharded churn scenario (zero steady-state
recompiles, rebuild recall parity), and the golden 8k recall-floor
fixture."""
import dataclasses
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.configs import ARCH_IDS, get_config, get_smoke_config, SHAPES
from repro.distributed import sharding as shd
from repro.distributed.fault import (GradSkipPolicy, StepMonitor,
                                     healthy_mesh_shape, remesh)
from repro.models import get_model
from repro.optim.compression import compress_grads, decompress_grads

RERANK_MULT = 3


@pytest.fixture(scope="module")
def shard_filters(small_dataset, small_graph, small_pca):
    """One shared FilterSpec per kind, fitted on the FULL small dataset
    (the sharded contract: one filter, many shard graphs)."""
    from repro.core.filters import IdentityFilter, PCAFilter, make_filter
    x, _, _ = small_dataset
    cfg_pq = dataclasses.replace(small_graph.cfg, filter_kind="pq",
                                 pq_train_iters=3)
    cfg_c = dataclasses.replace(cfg_pq, filter_kind="cascade",
                                pq_train_iters=8)
    return {
        "pca": PCAFilter(small_pca),
        "pq": make_filter(cfg_pq, x, seed=0),
        "cascade": make_filter(cfg_c, x, seed=0, pca=small_pca,
                               levels=small_graph.levels),
        "none": IdentityFilter(dim=x.shape[1]),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_rules_cover_all_archs(arch):
    """Every parameter leaf of every arch must have a sharding rule, with
    correct rank, on the production mesh axis sizes."""
    cfg = get_config(arch)
    api = get_model(cfg)
    a_params = api.abstract_params()

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    specs = shd.param_specs(cfg, a_params, FakeMesh())
    flat = jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "_normalized_spec") or True)
    n = len(jax.tree.leaves(a_params))
    assert len(jax.tree.leaves(
        specs, is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))) == n


def test_param_specs_divisibility():
    """No spec may shard a non-divisible dim (whisper's vocab 51865)."""
    cfg = get_config("whisper-medium")
    api = get_model(cfg)
    a_params = api.abstract_params()

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    specs = shd.param_specs(cfg, a_params, FakeMesh())
    flat_p = jax.tree_util.tree_flatten_with_path(a_params)[0]
    flat_s = jax.tree.leaves(
        specs, is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
    for (path, leaf), spec in zip(flat_p, flat_s):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is not None:
                size = {"data": 16, "model": 16}[ax]
                assert dim % size == 0, (path, leaf.shape, spec)


def test_step_monitor_straggler_detection():
    mon = StepMonitor(straggler_factor=2.0)
    for i in range(10):
        ev = mon.heartbeat(i, 1.0)
        assert ev.kind == "ok"
    ev = mon.heartbeat(10, 5.0)
    assert ev.kind == "straggler"
    ev = mon.heartbeat(11, 1.1)
    assert ev.kind == "ok"


def test_grad_skip_policy():
    pol = GradSkipPolicy(planned=8)
    for _ in range(6):
        pol.complete()
    assert pol.should_skip_rest(elapsed_s=100.0, deadline_s=10.0)
    assert not GradSkipPolicy(planned=8, completed=2).should_skip_rest(100, 10)
    assert pol.renorm() == pytest.approx(8 / 6)


def test_healthy_mesh_shape():
    assert healthy_mesh_shape(256) == (16, 16)
    assert healthy_mesh_shape(240) == (15, 16)
    with pytest.raises(RuntimeError):
        healthy_mesh_shape(8, model_parallel=16)


def test_compression_roundtrip():
    tree = {"a": jnp.asarray(np.random.default_rng(0)
                             .standard_normal((300, 17)), jnp.float32),
            "b": jnp.ones((5,), jnp.float32)}
    comp = compress_grads(tree)
    back = decompress_grads(comp, tree)
    for k in tree:
        err = np.abs(np.asarray(back[k]) - np.asarray(tree[k])).max()
        scale = np.abs(np.asarray(tree[k])).max()
        assert err <= scale / 127 * 1.01
    nbytes = sum(np.asarray(c["q"]).nbytes + np.asarray(c["scale"]).nbytes
                 for c in jax.tree.leaves(
                     comp, is_leaf=lambda t: isinstance(t, dict) and "q" in t))
    orig = sum(np.asarray(v).nbytes for v in tree.values())
    assert nbytes < orig / 3   # ~4x compression minus scale overhead


@pytest.mark.parametrize("kind", ["pca", "pq", "cascade", "none"])
@pytest.mark.parametrize("deferred", [False, True])
def test_distributed_single_shard_parity_bit_equal(
        small_dataset, small_graph, shard_filters, kind, deferred):
    """The ISSUE-4 acceptance bar: a 1-shard mesh runs the IDENTICAL
    program as single-shard search_batched for EVERY filter kind and
    re-rank mode — global ids and dists bit-equal, offsets 0, the
    all-gather/merge a no-op, and the deferred global re-rank reduced
    to the single-shard one. Covers both the meshless host loop and
    (for the canonical pca mode) the shard_map collective path."""
    from repro.core.distributed import (build_sharded, distributed_search,
                                        shard_search_host)
    from repro.core.search_jax import build_packed, search_batched
    x, q, gt = small_dataset
    filt = shard_filters[kind]
    db = build_packed(small_graph, filt.encode(x), filt=filt,
                      drop_empty_layers=False)
    sdb = build_sharded(x, small_graph.cfg, filt, 1, graphs=[small_graph])
    qd = jnp.asarray(q)
    qp = filt.prepare_jnp(qd)
    fd_b, fi_b = search_batched(db, qd, qp, deferred=deferred,
                                rerank_mult=RERANK_MULT)
    fd_h, fi_h = shard_search_host(sdb, qd, qp, deferred=deferred,
                                   rerank_mult=RERANK_MULT)
    np.testing.assert_array_equal(np.asarray(fi_h), np.asarray(fi_b))
    np.testing.assert_array_equal(np.asarray(fd_h), np.asarray(fd_b))
    if kind == "pca" and not deferred:
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        fd_d, fi_d = distributed_search(mesh, sdb, qd, qp,
                                        deferred=deferred,
                                        rerank_mult=RERANK_MULT)
        np.testing.assert_array_equal(np.asarray(fi_d), np.asarray(fi_b))
        np.testing.assert_array_equal(np.asarray(fd_d), np.asarray(fd_b))


def test_build_sharded_remainder_no_tail_drop(small_dataset, small_pca,
                                              small_graph):
    """Regression for the seed bug (`per = n // n_shards` dropped the
    n % P tail): with 4000 vectors over 3 shards every vector is owned
    by exactly one shard, and the TAIL vectors — unindexed entirely
    under the old code — are found as their own nearest neighbor."""
    from repro.core.distributed import (build_sharded, shard_bounds,
                                        shard_search_host)
    x, _, _ = small_dataset
    cfg = small_graph.cfg
    n, P = len(x), 3
    assert n % P != 0, "fixture must exercise a non-divisible split"
    bounds = shard_bounds(n, P)
    assert bounds[-1][1] == n
    assert sum(e - s for s, e in bounds) == n
    assert max(e - s for s, e in bounds) - \
        min(e - s for s, e in bounds) <= 1           # balanced
    sdb = build_sharded(x, cfg, small_pca, P)
    assert int(sdb.counts.sum()) == n
    # query the exact tail vectors: d(x, x) = 0 must win slot 0
    tail = np.arange(n - 5, n)
    qd = jnp.asarray(x[tail])
    qp = jnp.asarray(small_pca.transform(x[tail]).astype(np.float32))
    _, fi = shard_search_host(sdb, qd, qp)
    np.testing.assert_array_equal(np.asarray(fi)[:, 0], tail)


# --------- property-based cross-shard merge invariants (ISSUE-4) -----------

@settings(max_examples=40, deadline=None, derandomize=True)
@given(st.integers(1, 5), st.integers(1, 12), st.data())
def test_cross_shard_merge_invariants(P, E, data):
    """_merge_lists over P per-shard sorted lists: output sorted, a
    multiset-subset of the inputs, global ids in each shard's range,
    stable under duplicate distances / all-INF rows / k=1 / P=1 (where
    it must be the identity on the already-sorted input)."""
    from collections import Counter
    from repro.constants import INF
    from repro.core.distributed import _merge_lists
    pool = [0.0, 1.0, 1.0, 2.0, 2.5, float(np.float32(INF))]
    per = 100                                     # ids per shard range
    fd, fi = [], []
    for s in range(P):
        d = np.sort(np.asarray(
            data.draw(st.lists(st.sampled_from(pool),
                               min_size=E, max_size=E)), np.float32))
        ids = np.where(d < np.float32(INF),
                       np.arange(E, dtype=np.int32) + s * per, -1)
        fd.append(d)
        fi.append(ids)
    k = data.draw(st.integers(1, P * E))
    md, mi = _merge_lists(jnp.asarray(np.stack(fd))[:, None],
                          jnp.asarray(np.stack(fi))[:, None], k)
    md, mi = np.asarray(md[0]), np.asarray(mi[0])
    assert md.shape == (k,) and np.all(np.diff(md) >= 0)     # sorted
    # ids live in their owning shard's global range (or the -1 pad)
    for v in mi:
        assert v == -1 or 0 <= v % per < E
        assert v == -1 or 0 <= v // per < P
    have = Counter(zip(md.tolist(), mi.tolist()))
    src = Counter()
    for s in range(P):
        src.update(zip(fd[s].tolist(), fi[s].tolist()))
    for pair, c in have.items():
        assert src[pair] >= c, (pair, c)                     # subset
    if P == 1 and k == E:
        np.testing.assert_array_equal(md, fd[0])             # identity
        np.testing.assert_array_equal(mi, fi[0])


@settings(deadline=None, max_examples=40)
@given(E=st.integers(2, 12), data=st.data())
def test_global_promote_invariants(E, data):
    """_global_promote (the cascade's cross-shard mid-stage trim) is a
    STABLE sort of the merged list by promote-stage distance with -1
    pads pushed to INF, trimmed to n_keep — bit-equal to the host
    oracle's np.argsort(kind="stable") spelling, including duplicate
    distances, all-pad rows, and n_keep shorter than the valid set."""
    from repro.constants import INF
    from repro.core.distributed import _global_promote
    pool = [0.0, 1.0, 1.0, 2.0, 3.5]
    dm = np.asarray(data.draw(st.lists(st.sampled_from(pool),
                                       min_size=E, max_size=E)),
                    np.float32)
    mask = np.asarray(data.draw(st.lists(st.booleans(),
                                         min_size=E, max_size=E)))
    ids = np.where(mask, np.arange(E, dtype=np.int32) + 100,
                   np.int32(-1))
    n_keep = data.draw(st.integers(1, E))
    pd, pi = _global_promote(jnp.asarray(ids)[None],
                             jnp.asarray(dm)[None], n_keep)
    pd, pi = np.asarray(pd[0]), np.asarray(pi[0])
    keyed = np.where(ids >= 0, dm, np.float32(INF))
    order = np.argsort(keyed, kind="stable")
    np.testing.assert_array_equal(pd, keyed[order][:n_keep])
    np.testing.assert_array_equal(
        pi, np.where(ids >= 0, ids, -1)[order][:n_keep])


# --------- seeded stress: engine vs sharded oracle (ISSUE-4) ---------------

def test_sharded_stress_vs_oracle(small_dataset, small_graph,
                                  shard_filters):
    """Randomized seeded stress sweep: the sharded batched engine vs
    the sharded ``search_ref`` oracle across ALL filter x deferred x
    tombstone combinations on a remainder-bearing 3-shard split. The
    two implement one algorithm, so beyond recall parity (<= 0.02) the
    returned id SETS must agree on nearly every query (disagreements
    are float-tie edge cases, amplified by PQ's quantized lattice).
    The engine always carries a bitmap here (empty == no tombstones),
    so one compiled program serves both tombstone arms."""
    from repro.core.distributed import (build_sharded, shard_bounds,
                                        shard_search_host)
    from repro.core.graph import build_hnsw
    from repro.core.search_ref import recall_at, search_sharded
    x, q, gt = small_dataset
    cfg = small_graph.cfg
    P = 3
    rng = np.random.default_rng(42)
    bounds = shard_bounds(len(x), P)
    graphs = [build_hnsw(x[a:b], cfg, seed=7 + s)
              for s, (a, b) in enumerate(bounds)]
    doomed = np.zeros(len(x), bool)
    doomed[rng.choice(len(x), 200, replace=False)] = True
    doomed[gt[:12, 0]] = True                 # kill true answers too
    nq = 12
    for kind, filt in shard_filters.items():
        payloads = [filt.encode(x[a:b]) for a, b in bounds]
        mids = ([filt.encode_mid(x[a:b]) for a, b in bounds]
                if hasattr(filt, "encode_mid") else None)
        for tombs in (False, True):
            deleted = doomed if tombs else np.zeros(len(x), bool)
            dels = [deleted[a:b] for a, b in bounds]
            sdb = build_sharded(x, cfg, filt, P, graphs=graphs,
                                deleted=deleted)
            qd = jnp.asarray(q[:nq])
            qp = filt.prepare_jnp(qd)
            for deferred in ([False, True] if kind != "none"
                             else [False]):
                pm = max(cfg.promote_mult, RERANK_MULT)
                _, fi = shard_search_host(sdb, qd, qp,
                                          deferred=deferred,
                                          rerank_mult=RERANK_MULT,
                                          promote_mult=pm)
                fi = np.asarray(fi)
                assert not deleted[fi.ravel()].any(), \
                    (kind, tombs, deferred)
                r_b, r_r, exact = [], [], 0
                for i in range(nq):
                    ids, _ = search_sharded(
                        graphs, filt, payloads, q[i], deleted=dels,
                        deferred=deferred, rerank_mult=RERANK_MULT,
                        promote_mult=pm, payload_mids=mids)
                    assert not deleted[ids].any()
                    r_r.append(recall_at(ids, gt[i], 10))
                    r_b.append(recall_at(fi[i], gt[i], 10))
                    if set(ids.tolist()) == \
                            set(fi[i][:len(ids)].tolist()):
                        exact += 1
                tag = (kind, tombs, deferred)
                assert abs(np.mean(r_b) - np.mean(r_r)) <= 0.02, \
                    (tag, np.mean(r_b), np.mean(r_r))
                floor = 0.7 if kind in ("pq", "cascade") else 0.85
                assert exact >= floor * nq, (tag, exact, nq)


def test_sharded_churn_zero_recompile_and_rebuild_parity():
    """The sharded twin of the ISSUE-2 churn acceptance: a 2-shard
    mutable index absorbing +20% upserts and ~7% deletes through the
    serving layer triggers ZERO steady-state recompiles (jit cache
    counters of the sharded search and the per-shard insert probe),
    never surfaces a tombstoned global id, and lands recall@10 within
    0.02 of a from-scratch sharded rebuild on the final live set."""
    from repro.configs.base import PHNSWConfig
    from repro.core import distributed
    from repro.core.search_ref import recall_at
    from repro.data.vectors import make_queries, make_sift_like
    from repro.index import ShardedMutableIndex, mutable
    from repro.serve.vector_service import VectorSearchService

    cfg = PHNSWConfig(name="shch", n_points=2000, ef_construction=32)
    x_all = make_sift_like(2400, seed=21)
    x0, x_new = x_all[:2000], x_all[2000:]
    idx = ShardedMutableIndex.build(x0, cfg, 2, seed=1)
    idx.reserve(2048)      # pre-grow: uniform stride, no growth later
    svc = VectorSearchService(idx, batch_size=32)

    # warmup: compile the query program (service ctor), the per-shard
    # insert probes (first upsert round), then freeze the counters
    svc.upsert(x_new[:cfg.insert_batch])
    counters = (distributed.search_cache_sizes(),
                mutable._probe_jit._cache_size())

    svc.upsert(x_new[cfg.insert_batch:])
    rng = np.random.default_rng(2)
    doomed = rng.choice(idx.live_global_ids(), size=160, replace=False)
    svc.delete(doomed)

    q = make_queries(x_all, 32, seed=22)
    _, fi = svc.query(q)
    fi = np.asarray(fi)

    assert (distributed.search_cache_sizes(),
            mutable._probe_jit._cache_size()) == counters, \
        "steady-state sharded churn recompiled the engine"

    # tombstoned ids never surface; every id is live in its owner shard
    assert not np.isin(fi, doomed).any()
    assert (fi >= 0).all()
    assert not idx.is_deleted(fi).any()

    # recall parity vs a from-scratch sharded rebuild on the live set
    x_final = np.concatenate([s.x[s.live_ids()] for s in idx.shards])
    gt_live = idx.live_ground_truth(q, 10)
    r_mut = float(np.mean([recall_at(fi[i], gt_live[i], 10)
                           for i in range(len(q))]))
    idx2 = ShardedMutableIndex.build(x_final, cfg, 2, seed=3,
                                     filt=idx.filt)
    _, fi2 = idx2.search(q)
    fi2 = np.asarray(fi2)
    gt2 = idx2.live_ground_truth(q, 10)
    r_reb = float(np.mean([recall_at(fi2[i], gt2[i], 10)
                           for i in range(len(q))]))
    assert abs(r_mut - r_reb) <= 0.02, (r_mut, r_reb)


def test_frozen_sharded_db_serves(small_dataset, small_pca, small_graph):
    """A read-only ShardedDB behind VectorSearchService: global ids out,
    pad lanes never leak, stats correct — the serving layer takes a
    sharded backend transparently."""
    from repro.core.distributed import build_sharded
    from repro.core.search_ref import recall_at
    from repro.serve.vector_service import VectorSearchService
    x, q, gt = small_dataset
    sdb = build_sharded(x, small_graph.cfg, small_pca, 3)
    svc = VectorSearchService(sdb, small_pca, batch_size=16)
    idx_out, stats = svc.run_stream(q)
    r = float(np.mean([recall_at(idx_out[i], gt[i], 10)
                       for i in range(len(q))]))
    assert r > 0.75
    assert idx_out.shape[0] == len(q)
    assert (idx_out >= 0).all() and (idx_out < len(x)).all()
    assert svc.stats.queries == len(q)
    assert stats["p50_ms"] > 0


# --------- golden recall regression fixture (ISSUE-4) ----------------------
# Fixed-seed 8k dataset; the floors pin every compiled branch's
# recall@10 (measured at PR time minus a 0.03 margin), so a recall
# regression in any filter x rerank x shard combination fails tier-1
# instead of only moving a benchmark number.

GOLDEN_FLOORS = {
    # (kind, deferred): recall@10 floor, asserted for P=1 AND P=4.
    # Measured at PR-4 time (48 queries, seeds 11/12, graph seeds
    # 0/1..4): pca .975/.996, pq .906/.910, none .977 at P=1; every
    # P=4 value was >= its P=1 twin (the merge sees 4x ef0 candidates)
    ("pca", False): 0.94,
    ("pca", True): 0.96,
    ("pq", False): 0.87,
    ("pq", True): 0.87,
    ("none", False): 0.94,
    # the ISSUE-9 acceptance bar: the deferred cascade hits PCA-class
    # recall on PQ-class inline bytes. The P1 floor is the gate value
    # itself (deterministic fixture, measured .9958 at
    # pq_train_iters=16); the P4 twin (measured .9917 — the 2k shard
    # graphs, not the cascade, are the limiter) gets the usual
    # measured-minus-margin floor via the (P1, P4) tuple form.
    ("cascade", True): (0.995, 0.985),
}


@pytest.fixture(scope="module")
def golden8k():
    """The golden datum: fixed seeds end to end (data, queries, graph
    builds, PQ training), one shared filter per kind, shard graphs
    reused across kinds."""
    import dataclasses as _dc
    from repro.configs.base import PHNSWConfig
    from repro.core.filters import IdentityFilter, PCAFilter, make_filter
    from repro.core.graph import build_hnsw
    from repro.core.pca import fit_pca
    from repro.core.distributed import shard_bounds
    from repro.data.vectors import (brute_force_topk, make_queries,
                                    make_sift_like)
    cfg = PHNSWConfig(name="golden8k", n_points=8000, ef_construction=32)
    x = make_sift_like(8000, seed=11)
    q = make_queries(x, 48, seed=12)
    gt = brute_force_topk(x, q, 10)
    pca = fit_pca(x, cfg.d_low)
    g1 = build_hnsw(x, cfg, seed=0)
    graphs4 = [build_hnsw(x[a:b], cfg, seed=1 + s)
               for s, (a, b) in enumerate(shard_bounds(8000, 4))]
    filters = {
        "pca": PCAFilter(pca),
        "pq": make_filter(_dc.replace(cfg, filter_kind="pq",
                                      pq_train_iters=4), x, seed=0),
        # the cascade traverses on its codes and only promotes at the
        # exit, so code quality IS its recall ceiling: full training
        "cascade": make_filter(_dc.replace(cfg, filter_kind="cascade",
                                           pq_train_iters=16),
                               x, seed=0, pca=pca, levels=g1.levels),
        "none": IdentityFilter(dim=x.shape[1]),
    }
    return dict(cfg=cfg, x=x, q=q, gt=gt, g1=g1, graphs4=graphs4,
                filters=filters)


@pytest.mark.parametrize("kind,deferred", sorted(GOLDEN_FLOORS))
def test_golden_recall_floors(golden8k, kind, deferred):
    """Every (filter x rerank x shards) combination clears its pinned
    recall@10 floor, and the 4-shard merge costs at most 0.01 recall vs
    single-shard at matched ef0 (the ISSUE-4 acceptance bar)."""
    from repro.core.distributed import build_sharded, shard_search_host
    from repro.core.search_jax import build_packed, search_batched
    from repro.core.search_ref import recall_at
    d = golden8k
    filt = d["filters"][kind]
    db1 = build_packed(d["g1"], filt.encode(d["x"]), filt=filt)
    sdb4 = build_sharded(d["x"], d["cfg"], filt, 4, graphs=d["graphs4"])
    qd = jnp.asarray(d["q"])
    qp = filt.prepare_jnp(qd)
    _, fi1 = search_batched(db1, qd, qp, deferred=deferred)
    _, fi4 = shard_search_host(sdb4, qd, qp, deferred=deferred)
    fi1, fi4 = np.asarray(fi1), np.asarray(fi4)
    nq = len(d["q"])
    r1 = float(np.mean([recall_at(fi1[i], d["gt"][i], 10)
                        for i in range(nq)]))
    r4 = float(np.mean([recall_at(fi4[i], d["gt"][i], 10)
                        for i in range(nq)]))
    floor = GOLDEN_FLOORS[(kind, deferred)]
    f1, f4 = floor if isinstance(floor, tuple) else (floor, floor)
    assert r1 >= f1, (kind, deferred, "P1", r1)
    assert r4 >= f4, (kind, deferred, "P4", r4)
    assert r4 >= r1 - 0.01, (kind, deferred, r1, r4)


def test_golden_recall_floors_tombstoned(golden8k):
    """The tombstoned arm of the golden fixture (pca, per-step and
    deferred): 5% deletions incl. every rank-1 answer — live-set
    recall clears the floor, the 4-shard path stays within 0.01 of
    single-shard, and no tombstoned id ever surfaces."""
    import dataclasses as _dc
    from repro.core.distributed import build_sharded, shard_search_host
    from repro.core.search_jax import (build_packed, pack_bitmap,
                                       search_batched)
    from repro.core.search_ref import recall_at
    from repro.data.vectors import brute_force_topk
    d = golden8k
    filt = d["filters"]["pca"]
    rng = np.random.default_rng(13)
    deleted = np.zeros(8000, bool)
    deleted[rng.choice(8000, 400, replace=False)] = True
    deleted[d["gt"][:, 0]] = True
    live = np.nonzero(~deleted)[0]
    gt_live = live[brute_force_topk(d["x"][live], d["q"], 10)]
    db1 = _dc.replace(
        build_packed(d["g1"], filt.encode(d["x"]), filt=filt),
        deleted=jnp.asarray(pack_bitmap(deleted)))
    sdb4 = build_sharded(d["x"], d["cfg"], filt, 4, graphs=d["graphs4"],
                         deleted=deleted)
    qd = jnp.asarray(d["q"])
    qp = filt.prepare_jnp(qd)
    nq = len(d["q"])
    for deferred in (False, True):
        _, fi1 = search_batched(db1, qd, qp, deferred=deferred)
        _, fi4 = shard_search_host(sdb4, qd, qp, deferred=deferred)
        fi1, fi4 = np.asarray(fi1), np.asarray(fi4)
        assert not deleted[fi1.ravel()].any()
        assert not deleted[fi4.ravel()].any()
        r1 = float(np.mean([recall_at(fi1[i], gt_live[i], 10)
                            for i in range(nq)]))
        r4 = float(np.mean([recall_at(fi4[i], gt_live[i], 10)
                            for i in range(nq)]))
        assert r1 >= GOLDEN_FLOORS[("pca", deferred)] - 0.02, \
            (deferred, r1)
        assert r4 >= r1 - 0.01, (deferred, r1, r4)


def test_golden_degraded_recall_floor(golden8k):
    """The ISSUE-6 acceptance bar on the golden 8k datum: killing k of
    4 shards serves DEGRADED with (a) exact coverage accounting, (b)
    full-ground-truth recall monotonically non-increasing in k (losing
    shards only ever costs the neighbors they owned), (c) recall
    against the SURVIVORS' ground truth >= 0.90 — degraded mode
    answers as well as a healthy index built on just the survivors —
    and (d) no dead shard's id ever surfacing."""
    from repro.core.distributed import (build_sharded, shard_bounds,
                                        shard_live_counts,
                                        shard_search_host)
    from repro.core.search_ref import recall_at
    from repro.data.vectors import brute_force_topk
    d = golden8k
    filt = d["filters"]["pca"]
    sdb4 = build_sharded(d["x"], d["cfg"], filt, 4, graphs=d["graphs4"])
    qd = jnp.asarray(d["q"])
    qp = filt.prepare_jnp(qd)
    bounds = shard_bounds(8000, 4)
    lc = shard_live_counts(sdb4)
    nq = len(d["q"])
    prev = None
    for k_dead in range(3):                     # nested dead sets
        mask = np.ones(4, bool)
        mask[:k_dead] = False
        fd, fi, st = shard_search_host(sdb4, qd, qp, live=mask,
                                       return_stats=True)
        fi = np.asarray(fi)
        assert st["coverage"] == pytest.approx(
            lc[mask].sum() / lc.sum())          # exact, not estimated
        assert st["degraded"] == (k_dead > 0)
        for s in range(4):                      # dead ids never surface
            if not mask[s]:
                a, b = bounds[s]
                assert not ((fi >= a) & (fi < b)).any()
        r_full = float(np.mean([recall_at(fi[i], d["gt"][i], 10)
                                for i in range(nq)]))
        if prev is not None:
            assert r_full <= prev + 0.02, (k_dead, prev, r_full)
        prev = r_full
        rows = np.concatenate([np.arange(a, b)
                               for s, (a, b) in enumerate(bounds)
                               if mask[s]])
        gt_s = rows[brute_force_topk(d["x"][rows], d["q"], 10)]
        r_surv = float(np.mean([recall_at(fi[i], gt_s[i], 10)
                                for i in range(nq)]))
        assert r_surv >= 0.90, (k_dead, r_surv)


def test_search_batched_explicit_entry(small_dataset, small_graph,
                                       small_xlow, small_pca):
    """The explicit entry override reaches the descent: seeding from the
    db's own entry reproduces the default result exactly."""
    from repro.core.search_jax import build_packed, search_batched
    x, q, gt = small_dataset
    db = build_packed(small_graph, small_xlow)
    ql = jnp.asarray(small_pca.transform(q).astype(np.float32))
    fd0, fi0 = search_batched(db, jnp.asarray(q), ql)
    fd1, fi1 = search_batched(db, jnp.asarray(q), ql, entry=db.entry)
    np.testing.assert_array_equal(np.asarray(fi0), np.asarray(fi1))
    # a different (valid) entry still reaches high recall — the descent
    # is entry-robust, which is what the per-shard entries rely on
    alt = int(np.nonzero(small_graph.levels == small_graph.levels.max())
              [0][-1])
    _, fi2 = search_batched(db, jnp.asarray(q), ql, entry=alt)
    fi2 = np.asarray(fi2)
    from repro.core.search_ref import recall_at
    r = float(np.mean([recall_at(fi2[i], gt[i], 10)
                       for i in range(len(q))]))
    assert r > 0.85


SUBPROCESS_SHARDED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs.base import PHNSWConfig
    from repro.data.vectors import make_sift_like, make_queries, brute_force_topk
    from repro.core.pca import fit_pca
    from repro.core.distributed import (build_sharded, distributed_search,
                                        shard_search_host)
    from repro.core.search_ref import recall_at

    cfg = PHNSWConfig(name="t", n_points=4000, ef_construction=40)
    x = make_sift_like(4000); q = make_queries(x, 16)
    gt = brute_force_topk(x, q, 10)
    pca = fit_pca(x, cfg.d_low)
    deleted = np.zeros(4000, bool)
    deleted[gt[:, 0]] = True                 # tombstone true answers
    sdb = build_sharded(x, cfg, pca, n_shards=4, deleted=deleted)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ql = jnp.asarray(pca.transform(q).astype(np.float32))
    qd = jnp.asarray(q)
    # the REAL collective path (all-gather + psum over 4 devices) must
    # be bit-equal to the single-device shard loop that tier-1 locks
    # down — per-step AND deferred, tombstones active
    for deferred in (False, True):
        fd_m, fi_m = distributed_search(mesh, sdb, qd, ql,
                                        deferred=deferred, rerank_mult=3)
        fd_h, fi_h = shard_search_host(sdb, qd, ql,
                                       deferred=deferred, rerank_mult=3)
        np.testing.assert_array_equal(np.asarray(fi_m), np.asarray(fi_h))
        np.testing.assert_array_equal(np.asarray(fd_m), np.asarray(fd_h))
        fi = np.asarray(fi_m)
        assert not deleted[fi.ravel()].any()
    r = float(np.mean([recall_at(np.asarray(fi_m)[i], gt[i], 10)
                       for i in range(len(q))]))
    print("MESH==HOST OK, recall", r)
""")


@pytest.mark.slow
def test_sharded_search_multidevice():
    """8 simulated devices, 4 shards: the shard_map collective path is
    bit-equal to the host shard loop under deferred re-ranking and
    tombstones (the host loop is what the rest of tier-1 verifies)."""
    out = subprocess.run([sys.executable, "-c", SUBPROCESS_SHARDED],
                         capture_output=True, text=True,
                         env={**__import__("os").environ,
                              "PYTHONPATH": "src"},
                         cwd=Path(__file__).resolve().parents[1])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MESH==HOST OK" in out.stdout


SUBPROCESS_REMESH = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import save_checkpoint, restore_checkpoint
    from repro.distributed.fault import remesh

    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    mesh8 = jax.make_mesh((2, 4), ("data", "model"))
    sh8 = {"w": NamedSharding(mesh8, P("data", "model"))}
    t8 = jax.device_put(tree, sh8)
    d = tempfile.mkdtemp()
    save_checkpoint(d, 1, t8)
    # restore onto a SMALLER mesh (elastic downscale 8 -> 4 devices)
    mesh4 = jax.make_mesh((1, 4), ("data", "model"),
                          devices=jax.devices()[:4])
    sh4 = {"w": NamedSharding(mesh4, P("data", "model"))}
    t4 = restore_checkpoint(d, 1, tree, sh4)
    np.testing.assert_array_equal(np.asarray(t4["w"]), np.asarray(tree["w"]))
    # live remesh too
    t4b = remesh(t8, sh4)
    np.testing.assert_array_equal(np.asarray(t4b["w"]), np.asarray(tree["w"]))
    print("REMESH OK")
""")


@pytest.mark.slow
def test_checkpoint_remesh_multidevice():
    out = subprocess.run([sys.executable, "-c", SUBPROCESS_REMESH],
                         capture_output=True, text=True,
                         env={**__import__("os").environ,
                              "PYTHONPATH": "src"},
                         cwd=Path(__file__).resolve().parents[1])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "REMESH OK" in out.stdout


SUBPROCESS_MOE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models import moe as moe_mod
    from repro.distributed import sharding as shd

    cfg = get_smoke_config("qwen3-moe-235b-a22b")   # 4 experts
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    p = moe_mod.init_moe(cfg, jax.random.key(0), jnp.float32)
    x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model), jnp.float32)
    y0, _ = moe_mod._apply_moe_local(cfg, p, x, capacity_factor=100.0)
    with shd.activation_rules({}, mesh), mesh:
        y1, m = jax.jit(lambda p, x: moe_mod.apply_moe(
            cfg, p, x, capacity_factor=100.0))(p, x)
    err = float(jnp.max(jnp.abs(y1 - y0)))
    assert err < 1e-5, err
    # gradients flow through the shard_map dispatch
    def loss(p):
        with shd.activation_rules({}, mesh):
            y, _ = moe_mod.apply_moe(cfg, p, x, capacity_factor=100.0)
        return jnp.sum(jnp.square(y))
    with mesh:
        g = jax.jit(jax.grad(loss))(p)
    gn = sum(float(jnp.sum(jnp.square(v))) for v in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    print("MOE OK", err)
""")


@pytest.mark.slow
def test_moe_sharded_dispatch_multidevice():
    """The shard_map expert-parallel dispatch (the qwen3 perf fix) matches
    the local oracle and is differentiable."""
    out = subprocess.run([sys.executable, "-c", SUBPROCESS_MOE],
                         capture_output=True, text=True,
                         env={**__import__("os").environ,
                              "PYTHONPATH": "src"},
                         cwd=Path(__file__).resolve().parents[1])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MOE OK" in out.stdout


SUBPROCESS_PIPELINE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np, jax, jax.numpy as jnp
    from repro.distributed.pipeline import build_pipeline_forward

    mesh = jax.make_mesh((1, 4), ("data", "model"))
    L, M, B, S, D = 8, 6, 2, 4, 16
    params = {"w": jax.random.normal(jax.random.key(0), (L, D, D)) * 0.1}
    layer_fn = lambda lp, x: jnp.tanh(x @ lp["w"])
    xs = jax.random.normal(jax.random.key(1), (M, B, S, D))
    def seq(params, xs):
        h = xs
        for l in range(L):
            h = layer_fn({"w": params["w"][l]}, h)
        return h
    pf = build_pipeline_forward(mesh, layer_fn, L)
    with mesh:
        out = jax.jit(pf)(params, xs)
    err = float(jnp.max(jnp.abs(out - seq(params, xs))))
    assert err < 1e-5, err
    print("PIPELINE OK", err)
""")


@pytest.mark.slow
def test_pipeline_parallel_multidevice():
    """GPipe-style pipeline over the model axis == sequential forward."""
    out = subprocess.run([sys.executable, "-c", SUBPROCESS_PIPELINE],
                         capture_output=True, text=True,
                         env={**__import__("os").environ,
                              "PYTHONPATH": "src"},
                         cwd=Path(__file__).resolve().parents[1])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "PIPELINE OK" in out.stdout
