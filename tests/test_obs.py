"""Unified observability plane (ISSUE-7): log-bucketed histograms with
exact-to-bucket percentiles and lossless merge, thread-safe counters,
per-request trace spans threaded through the serving path (query ->
per-shard probe -> merge -> epoch swap; failover / snapshot shipping),
Prometheus + JSON exporters, the device-telemetry cost bridge — and
the zero-cost-when-disabled contract (no span objects allocated on the
untraced hot path; warmup batches never pollute the histograms)."""
import json
import threading

import numpy as np
import pytest

from repro.obs import (NULL_SPAN, NULL_TRACER, Registry, Span, Tracer,
                       parse_prometheus, prometheus_families,
                       record_search_stats, snapshot_json,
                       to_prometheus)
from repro.obs.metrics import DEFAULT, Histogram


# --------------------------------------------------------------------------
# metrics core
# --------------------------------------------------------------------------

def test_histogram_percentiles_within_one_bucket_of_numpy():
    """Bucket quantiles track np.percentile within one log-bucket
    relative width (growth - 1), with EXACT extremes (min/max ride
    along), on a heavy-tailed latency-like distribution."""
    rng = np.random.default_rng(0)
    samples = np.exp(rng.normal(1.0, 1.2, 20_000))  # lognormal, ~ms
    h = Histogram()
    h.observe_many(samples)
    assert h.count == len(samples)
    assert h.percentile(0) == samples.min()
    assert h.percentile(100) == samples.max()
    for p in (1, 10, 25, 50, 75, 90, 99, 99.9):
        exact = float(np.percentile(samples, p))
        est = h.percentile(p)
        assert abs(est - exact) / exact <= h.growth - 1, (p, est, exact)
    assert h.mean == pytest.approx(float(samples.mean()))


def test_histogram_observe_many_matches_loop_and_merge_is_lossless():
    rng = np.random.default_rng(1)
    a, b = rng.exponential(5.0, 3_000), rng.exponential(0.5, 2_000)
    h_loop, h_vec, h_a, h_b = (Histogram() for _ in range(4))
    for v in a:
        h_loop.observe(v)
    h_vec.observe_many(a)
    np.testing.assert_array_equal(h_loop.counts, h_vec.counts)
    assert h_loop.count == h_vec.count
    h_a.observe_many(a)
    h_b.observe_many(b)
    h_a.merge(h_b)
    h_all = Histogram()
    h_all.observe_many(np.concatenate([a, b]))
    np.testing.assert_array_equal(h_a.counts, h_all.counts)
    assert h_a.min == h_all.min and h_a.max == h_all.max
    with pytest.raises(ValueError, match="bucket configs differ"):
        h_a.merge(Histogram(lo=1.0))


def test_histogram_out_of_range_and_empty():
    h = Histogram(lo=1.0, hi=100.0, growth=2.0)
    assert h.percentile(50) == 0.0                  # empty
    h.observe(0.001)                                # underflow -> bucket 0
    h.observe(1e9)                                  # overflow -> last
    assert h.counts[0] == 1 and h.counts[-1] == 1
    assert h.percentile(0) == 0.001                 # exact extremes kept
    assert h.percentile(100) == 1e9


def test_counter_gauge_histogram_thread_safety():
    reg = Registry()
    c = reg.counter("c_total")
    g = reg.gauge("g")
    h = reg.histogram("h")
    n_threads, per = 8, 5_000

    def work(k):
        for i in range(per):
            c.inc()
            g.inc()
            h.observe(float(i % 100 + 1))

    ts = [threading.Thread(target=work, args=(k,))
          for k in range(n_threads)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert c.value == n_threads * per               # no lost updates
    assert g.value == n_threads * per
    assert h.count == n_threads * per
    assert int(h.counts.sum()) == h.count
    with pytest.raises(ValueError, match="only go up"):
        c.inc(-1)


def test_family_labels_and_redeclare_conflict():
    reg = Registry()
    fam = reg.counter("reqs_total", "by status", labels=("status",))
    fam.labels(status="ok").inc(3)
    fam.labels(status="err").inc()
    assert fam.labels(status="ok").value == 3
    assert reg.counter("reqs_total", labels=("status",)) is fam
    with pytest.raises(ValueError, match="re-declared"):
        reg.gauge("reqs_total")
    with pytest.raises(ValueError, match="labels"):
        fam.labels(shard=1)
    unl = reg.counter("plain_total")
    unl.inc(2)
    assert unl.value == 2                           # proxy to solo child
    with pytest.raises(AttributeError):
        unl.no_such_attr


def test_registry_reset_keeps_references_valid():
    reg = Registry()
    h = reg.histogram("lat")
    c = reg.counter("n_total")
    h.observe(5.0)
    c.inc()
    reg.emit("x", source="t")
    reg.reset()
    assert h.count == 0 and c.value == 0 and not reg.events
    h.observe(1.0)                                  # same objects still live
    assert reg.histogram("lat").count == 1


# --------------------------------------------------------------------------
# trace spans
# --------------------------------------------------------------------------

def test_span_nesting_and_event_ordering():
    tr = Tracer()
    with tr.span("root", a=1) as root:
        root.event("start")
        with root.child("left") as left:
            left.event("fault", attempt=0)
            left.event("backoff", ms=5)
            left.event("fault", attempt=1)
        with root.child("right") as right:
            right.set(ok=True)
    assert tr.last("root") is root
    assert [s.name for s in root.iter_spans()] == ["root", "left",
                                                   "right"]
    assert root.find("left").event_kinds() == ["fault", "backoff",
                                               "fault"]
    ts = [t for t, _, _ in root.find("left").events]
    assert ts == sorted(ts)                         # monotone offsets
    assert root.children[0] is left and root.children[1] is right
    d = root.to_dict()
    assert d["attrs"] == {"a": 1}
    assert [c["name"] for c in d["children"]] == ["left", "right"]
    json.dumps(d)                                   # JSON-serializable


def test_span_exit_records_error_and_propagates():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("boom") as s:
            raise RuntimeError("x")
    assert s.attrs["ok"] is False
    assert s.event_kinds() == ["error"]
    assert s.t1 is not None and tr.last("boom") is s


def test_disabled_tracer_allocates_no_spans():
    """THE zero-overhead contract: a disabled tracer returns the
    NULL_SPAN singleton, whose children are itself — a fully
    instrumented code path creates zero Span objects."""
    before = Span.n_created
    sp = NULL_TRACER.span("serve.query", n=64)
    assert sp is NULL_SPAN and not sp.enabled
    with sp.child("shard.probe", shard=0) as ps:
        ps.event("fault", error="nope")
        assert ps is NULL_SPAN
    assert sp.find("shard.probe") is None
    assert Span.n_created == before


# --------------------------------------------------------------------------
# exporters
# --------------------------------------------------------------------------

def test_prometheus_roundtrip_and_snapshot_stability():
    reg = Registry()
    reg.counter("reqs_total", "requests", labels=("status",)) \
        .labels(status="ok").inc(7)
    reg.gauge("cov").set(0.75)
    h = reg.histogram("lat_ms", "latency")
    h.observe_many([0.5, 2.0, 2.1, 40.0])
    text = to_prometheus(reg)
    assert set(prometheus_families(text)) == {"reqs_total", "cov",
                                              "lat_ms"}
    parsed = parse_prometheus(text)
    assert parsed["reqs_total"] == [({"status": "ok"}, 7.0)]
    assert parsed["cov"] == [({}, 0.75)]
    assert parsed["lat_ms_count"][0][1] == 4.0
    assert parsed["lat_ms_sum"][0][1] == pytest.approx(44.6)
    # cumulative bucket series ends at the total, +Inf included
    buckets = parsed["lat_ms_bucket"]
    assert buckets[-1][0]["le"] == "+Inf" and buckets[-1][1] == 4.0
    cums = [v for _, v in buckets]
    assert cums == sorted(cums)
    with pytest.raises(ValueError):
        parse_prometheus("lat_ms{bad 1.0")
    # snapshot: byte-stable under re-serialization, carries quantiles
    s1, s2 = snapshot_json(reg), snapshot_json(reg)
    assert s1 == s2
    snap = json.loads(s1)
    lat = next(f for f in snap["families"] if f["name"] == "lat_ms")
    assert lat["children"][0]["count"] == 4
    assert lat["children"][0]["p50"] > 0


# --------------------------------------------------------------------------
# the device-telemetry cost bridge
# --------------------------------------------------------------------------

def test_bridge_folds_telemetry_and_prices_queries():
    from repro.configs.base import PHNSWConfig
    from repro.obs.bridge import predicted_query_ns
    cfg = PHNSWConfig()
    reg = Registry()
    stats = {"steps_total": np.full(32, 20.0),
             "dist_h_evals": np.full(32, 60.0), "coverage": 1.0}
    out = record_search_stats(stats, wall_s=0.004, registry=reg,
                              cfg=cfg)
    assert reg.histogram("phnsw_search_steps").count == 32
    assert reg.histogram("phnsw_search_dist_h_evals").count == 32
    assert reg.gauge("phnsw_search_coverage").value == 1.0
    assert out["steps_mean"] == 20.0 and out["dist_h_mean"] == 60.0
    assert out["measured_us"] == pytest.approx(125.0)
    assert out["predicted_us"] > 0
    assert out["cost_ratio"] == pytest.approx(
        out["measured_us"] / out["predicted_us"])
    assert reg.histogram("phnsw_cost_ratio").count == 1
    # the prediction is monotone in the telemetry it prices
    lo = predicted_query_ns(cfg, steps_mean=10, dist_h_mean=30)
    hi = predicted_query_ns(cfg, steps_mean=40, dist_h_mean=120)
    assert hi > lo > 0


# --------------------------------------------------------------------------
# unified event stream: train-loop StepMonitor + serving ShardHealth
# --------------------------------------------------------------------------

def test_step_monitor_and_shard_health_share_event_stream():
    from repro.distributed.fault import StepMonitor
    from repro.distributed.faults import FaultPolicy, ShardHealth
    DEFAULT.reset()
    mon = StepMonitor(straggler_factor=2.0, source="train")
    for i in range(8):
        mon.heartbeat(i, 0.10)
    mon.heartbeat(8, 10.0)                          # obvious straggler
    health = ShardHealth(2, FaultPolicy(dead_after_failures=2))
    health.failure(0, RuntimeError("boom"))
    health.failure(0, RuntimeError("boom"))         # -> dead
    health.recover(0)
    kinds = [(e.kind, e.source) for e in DEFAULT.events]
    assert ("straggler", "train") in kinds
    assert ("failure", "serve.shard0") in kinds
    assert ("dead", "serve.shard0") in kinds
    assert ("recovered", "serve.shard0") in kinds
    # one record type, queryable by kind and source prefix
    assert all(type(e).__name__ == "ObsEvent" for e in DEFAULT.events)
    assert len(DEFAULT.events_of(source_prefix="serve.shard")) == 4
    assert DEFAULT.events_of("straggler")[0].target == 8
    assert DEFAULT.counter(
        "phnsw_heartbeats_total",
        labels=("source",)).labels(source="train").value == 9
    # an unnamed monitor stays OFF the obs plane (train loops that
    # predate the obs plane emit nothing)
    DEFAULT.reset()
    StepMonitor().heartbeat(0, 0.1)
    assert not DEFAULT.events


# --------------------------------------------------------------------------
# the serving path, traced end to end
# --------------------------------------------------------------------------

N_OBS, P_OBS, B_OBS = 2000, 4, 16


@pytest.fixture(scope="module")
def traced_svc():
    from repro.configs.base import PHNSWConfig
    from repro.data.vectors import make_queries, make_sift_like
    from repro.index import ShardedMutableIndex
    from repro.serve.vector_service import VectorSearchService
    from repro.distributed.faults import FaultPolicy
    cfg = PHNSWConfig(name="obs2k", n_points=N_OBS, ef_construction=32)
    x = make_sift_like(N_OBS, seed=51)
    q = make_queries(x, B_OBS, seed=52)
    idx = ShardedMutableIndex.build(x, cfg, P_OBS, seed=1)
    tracer = Tracer()
    pol = FaultPolicy(deadline_ms=250.0, max_retries=2, backoff_ms=1.0,
                      dead_after_failures=2)
    svc = VectorSearchService(idx, batch_size=B_OBS, fault_policy=pol,
                              tracer=tracer)
    return svc, idx, q, tracer


def test_warmup_batches_excluded_from_histograms(traced_svc):
    """Regression: the ctor's compile-warming batch must never appear
    in the latency histogram or the query counter (stats are reset IN
    PLACE after warmup, so scraper references stay valid)."""
    svc, _, q, tracer = traced_svc
    hist = svc.stats.latency_ms                     # pre-reset reference
    assert svc.stats.queries == 0
    assert hist.count == 0
    n0 = svc.stats.queries
    svc.query(q)
    assert svc.stats.queries == n0 + len(q)
    assert hist.count == n0 + len(q)                # same object counts


def test_end_to_end_degraded_query_trace(traced_svc):
    """THE acceptance scenario: kill 1 of 4 shards via the fault plan;
    ONE degraded request's span tree must tell the whole story —
    dead-shard probe fault, retry/backoff, dead-mark, and a merge with
    coverage=0.75 and the degraded flag."""
    from repro.distributed import faults
    from repro.distributed.faults import FaultPlan
    svc, _, q, tracer = traced_svc
    tracer.clear()
    with faults.inject(FaultPlan()) as plan:
        plan.add("kill_shard", 1)
        fd, fi, st = svc.query(q, return_stats=True)
    root = tracer.last("serve.query")
    assert root is not None and root.t1 is not None
    assert root.attrs["n"] == len(q)
    assert root.attrs["degraded"] is True
    assert root.attrs["coverage"] == pytest.approx(0.75)
    # all four shards were probed (none pre-marked dead)
    probes = root.find_all("shard.probe")
    assert sorted(p.attrs["shard"] for p in probes) == [0, 1, 2, 3]
    dead_p = next(p for p in probes if p.attrs["shard"] == 1)
    live_p = [p for p in probes if p.attrs["shard"] != 1]
    # the killed shard: fault -> backoff -> fault -> dead_mark, in
    # exactly that order (dead_after_failures=2, so the second fault
    # crosses the threshold and retries stop)
    assert dead_p.event_kinds() == ["fault", "backoff", "fault",
                                    "dead_mark"]
    assert dead_p.attrs["answered"] is False
    ev_fields = [f for _, k, f in dead_p.events if k == "fault"]
    assert all("ShardKilledError" in f["error"] for f in ev_fields)
    # healthy shards answered cleanly with a recorded probe wall
    for p in live_p:
        assert p.attrs["answered"] is True
        assert p.attrs["wall_ms"] > 0
        assert "probe" in p.event_kinds()
    # the merge span carries the request's degraded accounting
    merge = root.find("merge")
    assert merge is not None
    assert merge.attrs["coverage"] == pytest.approx(0.75)
    assert merge.attrs["degraded"] is True
    assert merge.attrs["live_shards"] == 3
    assert st["coverage"] == pytest.approx(0.75) and st["degraded"]
    assert svc.stats.degraded_queries >= 1
    # NEXT request skips the dead-marked shard outright — visible as a
    # root-level event, with only 3 probes
    svc.query(q)
    root2 = tracer.last("serve.query")
    assert "skip_dead_shard" in root2.event_kinds()
    assert sorted(p.attrs["shard"]
                  for p in root2.find_all("shard.probe")) == [0, 2, 3]
    svc.recover_shard(1)                            # leave module clean


def test_mutation_and_swap_trace(traced_svc):
    svc, idx, _, tracer = traced_svc
    rng = np.random.default_rng(7)
    ids = svc.upsert(rng.standard_normal(
        (6, idx.cfg.dim)).astype(np.float32))
    up = tracer.last("serve.upsert")
    assert [s.name for s in up.iter_spans()] == \
        ["serve.upsert", "publish", "epoch.swap"]
    assert up.attrs["n"] == 6
    # round-robin routing visible as events; publish carries the epoch
    assert set(up.event_kinds()) == {"route_upsert"}
    assert sum(f["n"] for _, k, f in up.events) == 6
    sw = up.find("epoch.swap")
    assert sw.attrs["to_epoch"] == sw.attrs["from_epoch"] + 1
    assert sw.attrs["to_epoch"] == svc.epoch
    n = svc.delete(ids[:2])
    assert n == 2
    dl = tracer.last("serve.delete")
    assert dl.find("publish") is not None
    assert dl.attrs["n"] == 2


def test_untraced_service_query_allocates_no_spans(traced_svc):
    """The disabled path through the REAL serving stack: same service,
    tracer swapped for the null one — zero Span objects per request."""
    svc, _, q, tracer = traced_svc
    svc.query(q)                                    # steady state
    svc.tracer = NULL_TRACER
    try:
        before = Span.n_created
        svc.query(q)
        assert Span.n_created == before
    finally:
        svc.tracer = tracer


def test_replica_failover_and_recovery_trace(tmp_path):
    from repro.configs.base import PHNSWConfig
    from repro.core.graph import build_hnsw
    from repro.core.pca import fit_pca
    from repro.data.vectors import make_queries, make_sift_like
    from repro.index import MutableIndex
    from repro.serve.replica import ReplicaSet
    from repro.serve.vector_service import VectorSearchService
    cfg = PHNSWConfig(name="obs-rep", n_points=600, ef_construction=32)
    x = make_sift_like(600, seed=61)
    q = make_queries(x, 8, seed=62)
    pca = fit_pca(x, cfg.d_low)
    idx = MutableIndex.from_graph(build_hnsw(x, cfg, seed=0), pca,
                                  seed=1)
    svc = VectorSearchService(idx, batch_size=8)
    tracer = Tracer()
    rs = ReplicaSet.replicate(svc, 2, snapshot_dir=tmp_path)
    rs.tracer = tracer
    rs.query(q)
    rq = tracer.last("replica.query")
    # the serving replica's request span is PARENTED under the
    # failover loop's span (explicit context passing end to end)
    assert [s.name for s in rq.iter_spans()][:2] == ["replica.query",
                                                     "serve.query"]
    assert rq.attrs["served_by"] == 0
    rs.upsert(make_sift_like(4, seed=63))
    rs._mark_dead(0, "test kill")
    rs.query(q)
    rq2 = tracer.last("replica.query")
    assert rq2.attrs["served_by"] == 1
    rs.recover(0)
    rc = tracer.last("replica.recover")
    names = [s.name for s in rc.iter_spans()]
    assert names == ["replica.recover", "replica.checkpoint",
                     "snapshot.ship", "oplog.replay"]
    assert rc.attrs["replica"] == 0
    assert rc.find("oplog.replay").attrs["n_replayed"] >= 0
    rs.assert_converged()
