"""Import hypothesis if available; otherwise provide no-op stand-ins so
the rest of the suite still collects and runs (property tests skip).
The container image does not always ship hypothesis, and the tier-1
suite must not lose coverage of the non-property tests because of it.
"""
try:
    from hypothesis import given, settings, strategies as st   # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            def skipped():
                pytest.skip("hypothesis not installed")
            skipped.__name__ = fn.__name__
            return skipped
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()
