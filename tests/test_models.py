"""Per-architecture smoke tests (reduced configs, 1 device): one train
step + one decode step, shape/NaN assertions; exactness checks where the
math guarantees them."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_smoke_config
from repro.configs.base import RetrievalConfig
from repro.models import get_model

KEY = jax.random.key(0)


def make_batch(cfg, B=2, S=32, with_labels=True):
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    if with_labels:
        batch["labels"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            KEY, (B, cfg.enc_frames, cfg.d_model), jnp.float32)
    if cfg.vis_tokens:
        batch["patches"] = jax.random.normal(
            KEY, (B, cfg.vis_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_train_step(arch):
    cfg = get_smoke_config(arch)
    api = get_model(cfg)
    params = api.init(KEY)
    loss, metrics = jax.jit(api.loss)(params, make_batch(cfg))
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: api.loss(p, make_batch(cfg))[0])(params)
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_prefill_decode_shapes(arch):
    cfg = get_smoke_config(arch)
    api = get_model(cfg)
    params = api.init(KEY)
    B, S = 2, 16
    batch = make_batch(cfg, B, S, with_labels=False)
    logits, cache = jax.jit(api.prefill)(params, batch)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    cache2 = api.init_cache(B, S + 4)
    lg2, _ = jax.jit(api.decode_step)(params, cache2, tok, jnp.int32(S))
    assert lg2.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(lg2)).all()


@pytest.mark.parametrize("arch", ["llama3-405b", "starcoder2-3b",
                                  "qwen2-72b", "mistral-nemo-12b",
                                  "recurrentgemma-9b", "rwkv6-1.6b"])
def test_decode_matches_prefill_exact(arch):
    """Token-by-token decode == one-shot prefill logits (archs without
    capacity-dropping MoE or prefix inputs)."""
    cfg = get_smoke_config(arch)
    api = get_model(cfg)
    params = api.init(KEY)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.key(7), (B, S), 0, cfg.vocab)
    lg_pre, _ = jax.jit(api.prefill)(params, {"tokens": toks})
    cache = api.init_cache(B, S)
    step = jax.jit(api.decode_step)
    lg = None
    for t in range(S):
        lg, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_pre),
                               rtol=2e-3, atol=2e-3)


def test_moe_dispatch_matches_dense_oracle():
    from repro.models import moe as moe_mod
    cfg = get_smoke_config("mixtral-8x7b")
    p = moe_mod.init_moe(cfg, KEY, jnp.float32)
    x = jax.random.normal(jax.random.key(2), (2, 8, cfg.d_model))
    y, m = moe_mod.apply_moe(cfg, p, x, capacity_factor=100.0)
    E, K = cfg.moe.n_experts, cfg.moe.experts_per_tok
    xf = x.reshape(-1, cfg.d_model)
    gates = jax.nn.softmax(xf @ p["router"], -1)
    tw, te = jax.lax.top_k(gates, K)
    tw = tw / tw.sum(-1, keepdims=True)
    outs = jnp.stack([(jax.nn.silu(xf @ p["e_gate"][e]) *
                       (xf @ p["e_up"][e])) @ p["e_down"][e]
                      for e in range(E)], 1)
    want = sum(tw[:, kk:kk + 1] *
               jnp.take_along_axis(
                   outs, te[:, kk:kk + 1, None].repeat(cfg.d_model, -1),
                   1)[:, 0]
               for kk in range(K)).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    assert float(m["dropped_frac"]) == 0.0


def test_moe_capacity_drops_counted():
    from repro.models import moe as moe_mod
    cfg = get_smoke_config("qwen3-moe-235b-a22b")
    p = moe_mod.init_moe(cfg, KEY, jnp.float32)
    x = jax.random.normal(jax.random.key(3), (2, 16, cfg.d_model))
    _, m = moe_mod.apply_moe(cfg, p, x, capacity_factor=0.5)
    assert float(m["dropped_frac"]) > 0.0


def test_retrieval_attention_full_coverage_exact():
    """pHNSW retrieval attention == dense attention when the filter
    budget covers the whole cache and d_low == head_dim (lossless
    projection): the Step 2/3 plumbing is exact."""
    base = get_smoke_config("llama3-405b")
    T = 64
    full = base.replace(retrieval=RetrievalConfig(
        enabled=True, d_low=base.resolved_head_dim, topk=T, block=4))
    api_d, api_f = get_model(base), get_model(full)
    params_f = api_f.init(KEY)
    # dense model shares every leaf except rp_proj
    params_d = jax.tree.map(lambda x: x, params_f)
    del params_d["layers"]["attn"]["rp_proj"]
    toks = jax.random.randint(jax.random.key(1), (2, 24), 0, base.vocab)
    cd, cf = api_d.init_cache(2, T), api_f.init_cache(2, T)
    sd, sf = jax.jit(api_d.decode_step), jax.jit(api_f.decode_step)
    for t in range(24):
        lg_d, cd = sd(params_d, cd, toks[:, t:t + 1], jnp.int32(t))
        lg_f, cf = sf(params_f, cf, toks[:, t:t + 1], jnp.int32(t))
    np.testing.assert_allclose(np.asarray(lg_f), np.asarray(lg_d),
                               rtol=2e-4, atol=2e-4)


def test_rwkv_chunked_matches_stepwise():
    """Chunked-parallel RWKV6 forward == sequential decode recurrence."""
    cfg = get_smoke_config("rwkv6-1.6b")
    api = get_model(cfg)
    params = api.init(KEY)
    B, S = 1, 32
    toks = jax.random.randint(jax.random.key(5), (B, S), 0, cfg.vocab)
    lg_pre, _ = jax.jit(api.prefill)(params, {"tokens": toks})
    cache = api.init_cache(B, S)
    step = jax.jit(api.decode_step)
    for t in range(S):
        lg, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_pre),
                               rtol=2e-3, atol=2e-3)


def test_int8_kv_cache_decode():
    """int8 KV cache: near-exact decode (>=90% greedy agreement, <2%
    relative logit error) at half the cache bytes."""
    base = get_smoke_config("llama3-405b").replace(dtype="float32")
    quant = base.replace(kv_quant=True)
    api_b, api_q = get_model(base), get_model(quant)
    params = api_b.init(KEY)
    T = 24
    toks = jax.random.randint(jax.random.key(3), (2, T), 0, base.vocab)
    cb, cq = api_b.init_cache(2, T), api_q.init_cache(2, T)
    sb, sq = jax.jit(api_b.decode_step), jax.jit(api_q.decode_step)
    agree = 0
    for t in range(T):
        lb, cb = sb(params, cb, toks[:, t:t + 1], jnp.int32(t))
        lq, cq = sq(params, cq, toks[:, t:t + 1], jnp.int32(t))
        agree += int((jnp.argmax(lb, -1) == jnp.argmax(lq, -1)).all())
    assert agree >= int(0.9 * T)
    rel = float(jnp.max(jnp.abs(lb - lq)) / (jnp.max(jnp.abs(lb)) + 1e-9))
    assert rel < 0.02
    flat = jax.tree_util.tree_flatten_with_path(cq)[0]
    dtypes = {p[-1].key: str(l.dtype) for p, l in flat}
    assert dtypes["k"] == "int8" and dtypes["v"] == "int8"
