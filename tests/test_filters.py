"""Pluggable filter-stage pipeline: ref-vs-batched parity on every
filter x rerank combination, deferred-rerank telemetry and tombstone
semantics, payload accounting, and the generic cost-model pricing."""
import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.filters import (IdentityFilter, PCAFilter, PQFilter,
                                make_filter)
from repro.core.search_jax import build_packed, search_batched
from repro.core.search_ref import (recall_at, run_queries,
                                   search_filtered, search_hnsw)

RERANK_MULT = 3


@pytest.fixture(scope="module")
def filters(small_dataset, small_graph, small_pca):
    """One fitted FilterSpec per kind (PQ trained briefly — recall
    parity, not PQ quality, is under test here)."""
    x, _, _ = small_dataset
    cfg = dataclasses.replace(small_graph.cfg, filter_kind="pq",
                              pq_train_iters=3)
    return {
        "pca": PCAFilter(small_pca),
        "pq": make_filter(cfg, x, seed=0),
        "none": IdentityFilter(dim=x.shape[1]),
    }


@pytest.fixture(scope="module")
def payloads(small_dataset, filters):
    x, _, _ = small_dataset
    return {k: f.encode(x) for k, f in filters.items()}


@pytest.mark.parametrize("kind", ["pca", "pq", "none"])
@pytest.mark.parametrize("deferred", [False, True])
def test_ref_vs_batched_parity(small_dataset, small_graph, filters,
                               payloads, kind, deferred):
    """search_batched and search_filtered agree on every filter x
    rerank combination: same recall@10 (within 0.02) and bit-equal
    returned id sets on (nearly) every query — the two engines run the
    same algorithm, so disagreements are confined to float-tie /
    frontier-truncation edge cases."""
    x, q, gt = small_dataset
    filt, payload = filters[kind], payloads[kind]
    db = build_packed(small_graph, payload, filt=filt)
    _, fi = search_batched(db, jnp.asarray(q), filt=filt,
                           deferred=deferred, rerank_mult=RERANK_MULT)
    fi = np.asarray(fi)
    r_bat, r_ref, exact = [], [], 0
    for i in range(len(q)):
        ids, _ = search_filtered(small_graph, filt, payload, q[i],
                                 deferred=deferred,
                                 rerank_mult=RERANK_MULT)
        r_ref.append(recall_at(ids, gt[i], 10))
        r_bat.append(recall_at(fi[i], gt[i], 10))
        if set(ids.tolist()) == set(fi[i][:len(ids)].tolist()):
            exact += 1
    # PQ quantizes distances onto a small lattice, so EXACT filter-dist
    # ties between distinct nodes (identical code rows) are common —
    # the heap oracle breaks them by id, the fixed-shape engine by
    # slot, and per-step traversal amplifies the divergence; the dense
    # filters tie only at float-ulp granularity. The recall band and
    # the bit-equality floor are both wider for pq accordingly.
    tol = 0.03 if kind == "pq" else 0.02
    assert abs(np.mean(r_bat) - np.mean(r_ref)) <= tol, \
        (kind, deferred, np.mean(r_bat), np.mean(r_ref))
    floor = 0.8 if kind == "pq" else 0.9
    assert exact >= floor * len(q), \
        f"{kind}/deferred={deferred}: only {exact}/{len(q)} bit-equal"


def test_identity_filter_is_hnsw(small_dataset, small_graph, filters,
                                 payloads):
    """The filter bypass runs standard HNSW: the ref oracle routes to
    search_hnsw verbatim, and the batched engine reaches its recall."""
    x, q, gt = small_dataset
    filt = filters["none"]
    ids_f, _ = search_filtered(small_graph, filt, payloads["none"], q[0])
    ids_h, _ = search_hnsw(small_graph, q[0])
    np.testing.assert_array_equal(ids_f, ids_h)
    r_h, _ = run_queries(small_graph, q, gt, algo="hnsw")
    db = build_packed(small_graph, payloads["none"], filt=filt)
    _, fi = search_batched(db, jnp.asarray(q), filt=filt)
    fi = np.asarray(fi)
    r_b = float(np.mean([recall_at(fi[i], gt[i], 10)
                         for i in range(len(q))]))
    assert abs(r_b - r_h) <= 0.02


def test_deferred_rerank_cuts_dist_h(small_dataset, small_graph,
                                     filters, payloads):
    """The acceptance criterion: deferred PCA mode shows measurably
    fewer Dist.H evaluations per query in return_stats telemetry, at
    recall@10 within 0.01 of the per-step baseline."""
    x, q, gt = small_dataset
    filt = filters["pca"]
    db = build_packed(small_graph, payloads["pca"], filt=filt)
    rec, dhe = {}, {}
    for mode, deferred in (("per_step", False), ("deferred", True)):
        _, fi, st = search_batched(db, jnp.asarray(q), filt=filt,
                                   deferred=deferred,
                                   rerank_mult=RERANK_MULT,
                                   return_stats=True)
        fi = np.asarray(fi)
        rec[mode] = float(np.mean([recall_at(fi[i], gt[i], 10)
                                   for i in range(len(q))]))
        dhe[mode] = float(np.asarray(st["dist_h_evals"]).mean())
    assert abs(rec["deferred"] - rec["per_step"]) <= 0.01, rec
    assert dhe["deferred"] < 0.8 * dhe["per_step"], dhe
    # deferred Dist.H ~ rerank_mult * ef0 final candidates, not k/step
    assert dhe["deferred"] <= RERANK_MULT * small_graph.cfg.ef0 + 2


@pytest.mark.parametrize("kind", ["pca", "pq"])
def test_tombstones_under_deferred_rerank(small_dataset, small_graph,
                                          filters, payloads, kind):
    """Tombstoned rows never surface under deferred re-ranking (the
    final high-dim re-rank list is drawn from the live-only F), and the
    host oracle agrees."""
    x, q, gt = small_dataset
    filt, payload = filters[kind], payloads[kind]
    from repro.index import MutableIndex
    idx = MutableIndex.from_graph(small_graph, filt, seed=1)
    dels = np.unique(gt[:, :3].ravel())       # delete many true answers
    idx.delete(dels, auto_compact=False)
    _, fi = idx.search(q, deferred=True, rerank_mult=RERANK_MULT)
    fi = np.asarray(fi)
    assert not np.isin(fi, dels).any()
    assert (fi >= 0).all() and (fi < idx.n).all()
    assert not idx.deleted[fi.ravel()].any()
    # live-ground-truth recall holds (deleted nodes still route)
    gt_live = idx.live_ground_truth(q, 10)
    rec = float(np.mean([recall_at(fi[i], gt_live[i], 10)
                         for i in range(len(q))]))
    assert rec > 0.8
    # ref oracle: same semantics
    deleted = np.zeros(len(x), bool)
    deleted[dels] = True
    ids, _ = search_filtered(small_graph, filt, payload, q[0],
                             deleted=deleted, deferred=True,
                             rerank_mult=RERANK_MULT)
    assert not np.isin(ids, dels).any()


def test_payload_bytes_accounting(small_graph, filters, payloads,
                                  small_dataset):
    """Layout-(3) byte accounting follows the filter payload: PQ codes
    (n_sub B/vec) shrink the store vs PCA f32 rows; the identity bypass
    pays only the index lists."""
    x, _, _ = small_dataset
    dbs = {k: build_packed(small_graph, payloads[k], filt=filters[k])
           for k in filters}
    assert dbs["pq"].bytes_layout3 < dbs["pca"].bytes_layout3
    assert dbs["none"].bytes_layout3 < dbs["pq"].bytes_layout3
    # identity: index bytes + the high table, nothing else
    nnz = sum(int((l.adj >= 0).sum()) for l in dbs["none"].layers)
    assert dbs["none"].bytes_layout3 == nnz * 4 + x.size * 4
    assert dbs["pq"].low.dtype == jnp.uint8
    assert dbs["none"].low.shape[1] == 0
    # per-vector pricing surfaces through the FilterSpec contract
    assert filters["pq"].bytes_per_vec == filters["pq"].cb.n_sub
    assert filters["pca"].bytes_per_vec == 15 * 4
    assert filters["none"].bytes_per_vec == 0


def test_cost_model_prices_filter_generically(small_dataset, small_graph,
                                              filters, payloads):
    """query_cost accepts the active FilterSpec and prices the filter
    compute by its cost_dims: at identical traversal stats, the PQ
    filter (n_sub lookups) models cheaper Dist.L time than PCA (d_low
    dims) iff n_sub < d_low scaling says so; DRAM bytes always follow
    the instrumented stats."""
    from repro.core.cost_model import DDR4, query_cost
    x, q, _ = small_dataset
    st = {}
    for kind in ("pca", "pq"):
        _, st[kind] = search_filtered(small_graph, filters[kind],
                                      payloads[kind], q[0])
    c_pca = query_cost(st["pca"], n_queries=1, dim=x.shape[1],
                       filt=filters["pca"], dram=DDR4)
    c_pq = query_cost(st["pq"], n_queries=1, dim=x.shape[1],
                      filt=filters["pq"], dram=DDR4)
    # PQ stats priced with PCA depth must differ from PQ depth pricing
    c_pq_mispriced = query_cost(st["pq"], n_queries=1, dim=x.shape[1],
                                d_low=filters["pca"].cost_dims, dram=DDR4)
    assert c_pq.breakdown["dist_l"] != c_pq_mispriced.breakdown["dist_l"]
    # the PQ trace moved fewer payload bytes (16 vs 60 B/vec inline)
    assert st["pq"].seq_bytes < st["pca"].seq_bytes
    assert c_pq.total_ns > 0 and c_pca.total_ns > 0


def test_mutable_index_with_pq_filter(small_dataset, small_graph,
                                      filters):
    """The mutable index refreshes whichever payload the filter owns:
    PQ-coded dirty rows re-gather uint8 codes, upserts encode through
    the filter, and search stays live."""
    from repro.data.vectors import make_sift_like
    from repro.index import MutableIndex
    x, q, _ = small_dataset
    idx = MutableIndex.from_graph(small_graph, filters["pq"], seed=1)
    assert idx.x_low.dtype == np.uint8
    assert idx.db.filter_kind == "pq"
    x_new = make_sift_like(80, seed=33)
    ids = idx.upsert(x_new)
    _, fi = idx.search(x_new[:16])
    hits = (np.asarray(fi)[:, 0] == ids[:16])
    assert hits.mean() > 0.8          # PQ filter is lossy but close
    # drift check degrades gracefully for non-PCA filters
    rep = idx.pca_drift()
    assert not rep["refit_recommended"]


def test_vector_service_identity_filter(small_dataset, small_graph,
                                        filters, payloads):
    """A frozen identity-filter PackedDB serves without any PCA."""
    from repro.serve.vector_service import VectorSearchService
    x, q, gt = small_dataset
    db = build_packed(small_graph, payloads["none"],
                      filt=filters["none"])
    svc = VectorSearchService(db, batch_size=16)
    idx_out, stats = svc.run_stream(q)
    r = float(np.mean([recall_at(idx_out[i], gt[i], 10)
                       for i in range(len(q))]))
    assert r > 0.75
    assert stats["p50_ms"] > 0
