"""Pluggable filter-stage pipeline: ref-vs-batched parity on every
filter x rerank combination, deferred-rerank telemetry and tombstone
semantics, payload accounting, and the generic cost-model pricing."""
import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.filters import (IdentityFilter, PCAFilter, PQFilter,
                                make_filter)
from repro.core.search_jax import build_packed, search_batched
from repro.core.search_ref import (recall_at, run_queries,
                                   search_filtered, search_hnsw)

RERANK_MULT = 3


@pytest.fixture(scope="module")
def filters(small_dataset, small_graph, small_pca):
    """One fitted FilterSpec per kind (PQ trained briefly — recall
    parity, not PQ quality, is under test here). The cascade adopts
    the shared PCA and trains its codebooks density-aware off the
    graph's level assignment."""
    x, _, _ = small_dataset
    cfg = dataclasses.replace(small_graph.cfg, filter_kind="pq",
                              pq_train_iters=3)
    # the cascade rides its codes through the whole traversal, so it
    # gets the full Lloyd schedule (same policy as the benches)
    cfg_c = dataclasses.replace(cfg, filter_kind="cascade",
                                pq_train_iters=8)
    return {
        "pca": PCAFilter(small_pca),
        "pq": make_filter(cfg, x, seed=0),
        "cascade": make_filter(cfg_c, x, seed=0, pca=small_pca,
                               levels=small_graph.levels),
        "none": IdentityFilter(dim=x.shape[1]),
    }


@pytest.fixture(scope="module")
def payloads(small_dataset, filters):
    x, _, _ = small_dataset
    return {k: f.encode(x) for k, f in filters.items()}


@pytest.fixture(scope="module")
def payload_mids(small_dataset, filters):
    """Side-car payloads for the filters that carry one (cascade)."""
    x, _, _ = small_dataset
    return {k: f.encode_mid(x) for k, f in filters.items()
            if hasattr(f, "encode_mid")}


@pytest.mark.parametrize("kind", ["pca", "pq", "cascade", "none"])
@pytest.mark.parametrize("deferred", [False, True])
def test_ref_vs_batched_parity(small_dataset, small_graph, filters,
                               payloads, payload_mids, kind, deferred):
    """search_batched and search_filtered agree on every filter x
    rerank combination: same recall@10 (within 0.02) and bit-equal
    returned id sets on (nearly) every query — the two engines run the
    same algorithm, so disagreements are confined to float-tie /
    frontier-truncation edge cases. The deferred cascade additionally
    exercises the PCA promote stage (side-car gather + mid-score trim)
    on both engines."""
    x, q, gt = small_dataset
    filt, payload = filters[kind], payloads[kind]
    db = build_packed(small_graph, payload, filt=filt)
    # mirror the engine's normalization: the promote pool can never be
    # narrower than the rerank pool (no-op outside deferred cascade)
    pm = max(small_graph.cfg.promote_mult, RERANK_MULT)
    _, fi = search_batched(db, jnp.asarray(q), filt=filt,
                           deferred=deferred, rerank_mult=RERANK_MULT,
                           promote_mult=pm)
    fi = np.asarray(fi)
    r_bat, r_ref, exact = [], [], 0
    for i in range(len(q)):
        ids, _ = search_filtered(small_graph, filt, payload, q[i],
                                 deferred=deferred,
                                 rerank_mult=RERANK_MULT,
                                 promote_mult=pm,
                                 payload_mid=payload_mids.get(kind))
        r_ref.append(recall_at(ids, gt[i], 10))
        r_bat.append(recall_at(fi[i], gt[i], 10))
        if set(ids.tolist()) == set(fi[i][:len(ids)].tolist()):
            exact += 1
    # PQ quantizes distances onto a small lattice, so EXACT filter-dist
    # ties between distinct nodes (identical code rows) are common —
    # the heap oracle breaks them by id, the fixed-shape engine by
    # slot, and per-step traversal amplifies the divergence; the dense
    # filters tie only at float-ulp granularity. The recall band and
    # the bit-equality floor are both wider for pq (and the cascade,
    # which traverses on the same lattice) accordingly.
    tol = 0.04 if kind in ("pq", "cascade") else 0.02
    assert abs(np.mean(r_bat) - np.mean(r_ref)) <= tol, \
        (kind, deferred, np.mean(r_bat), np.mean(r_ref))
    floor = {"pq": 0.8, "cascade": 0.75}.get(kind, 0.9)
    assert exact >= floor * len(q), \
        f"{kind}/deferred={deferred}: only {exact}/{len(q)} bit-equal"


def test_identity_filter_is_hnsw(small_dataset, small_graph, filters,
                                 payloads):
    """The filter bypass runs standard HNSW: the ref oracle routes to
    search_hnsw verbatim, and the batched engine reaches its recall."""
    x, q, gt = small_dataset
    filt = filters["none"]
    ids_f, _ = search_filtered(small_graph, filt, payloads["none"], q[0])
    ids_h, _ = search_hnsw(small_graph, q[0])
    np.testing.assert_array_equal(ids_f, ids_h)
    r_h, _ = run_queries(small_graph, q, gt, algo="hnsw")
    db = build_packed(small_graph, payloads["none"], filt=filt)
    _, fi = search_batched(db, jnp.asarray(q), filt=filt)
    fi = np.asarray(fi)
    r_b = float(np.mean([recall_at(fi[i], gt[i], 10)
                         for i in range(len(q))]))
    assert abs(r_b - r_h) <= 0.02


def test_deferred_rerank_cuts_dist_h(small_dataset, small_graph,
                                     filters, payloads):
    """The acceptance criterion: deferred PCA mode shows measurably
    fewer Dist.H evaluations per query in return_stats telemetry, at
    recall@10 within 0.01 of the per-step baseline."""
    x, q, gt = small_dataset
    filt = filters["pca"]
    db = build_packed(small_graph, payloads["pca"], filt=filt)
    rec, dhe = {}, {}
    for mode, deferred in (("per_step", False), ("deferred", True)):
        _, fi, st = search_batched(db, jnp.asarray(q), filt=filt,
                                   deferred=deferred,
                                   rerank_mult=RERANK_MULT,
                                   return_stats=True)
        fi = np.asarray(fi)
        rec[mode] = float(np.mean([recall_at(fi[i], gt[i], 10)
                                   for i in range(len(q))]))
        dhe[mode] = float(np.asarray(st["dist_h_evals"]).mean())
    assert abs(rec["deferred"] - rec["per_step"]) <= 0.01, rec
    assert dhe["deferred"] < 0.8 * dhe["per_step"], dhe
    # deferred Dist.H ~ rerank_mult * ef0 final candidates, not k/step
    assert dhe["deferred"] <= RERANK_MULT * small_graph.cfg.ef0 + 2


@pytest.mark.parametrize("kind", ["pca", "pq", "cascade"])
def test_tombstones_under_deferred_rerank(small_dataset, small_graph,
                                          filters, payloads,
                                          payload_mids, kind):
    """Tombstoned rows never surface under deferred re-ranking (the
    final high-dim re-rank list is drawn from the live-only F), and the
    host oracle agrees."""
    x, q, gt = small_dataset
    filt, payload = filters[kind], payloads[kind]
    from repro.index import MutableIndex
    idx = MutableIndex.from_graph(small_graph, filt, seed=1)
    dels = np.unique(gt[:, :3].ravel())       # delete many true answers
    idx.delete(dels, auto_compact=False)
    _, fi = idx.search(q, deferred=True, rerank_mult=RERANK_MULT)
    fi = np.asarray(fi)
    assert not np.isin(fi, dels).any()
    assert (fi >= 0).all() and (fi < idx.n).all()
    assert not idx.deleted[fi.ravel()].any()
    # live-ground-truth recall holds (deleted nodes still route)
    gt_live = idx.live_ground_truth(q, 10)
    rec = float(np.mean([recall_at(fi[i], gt_live[i], 10)
                         for i in range(len(q))]))
    assert rec > 0.8
    # ref oracle: same semantics
    deleted = np.zeros(len(x), bool)
    deleted[dels] = True
    ids, _ = search_filtered(small_graph, filt, payload, q[0],
                             deleted=deleted, deferred=True,
                             rerank_mult=RERANK_MULT,
                             payload_mid=payload_mids.get(kind))
    assert not np.isin(ids, dels).any()


def test_payload_bytes_accounting(small_graph, filters, payloads,
                                  small_dataset):
    """Layout-(3) byte accounting follows the filter payload: PQ codes
    (n_sub B/vec) shrink the store vs PCA f32 rows; the identity bypass
    pays only the index lists."""
    x, _, _ = small_dataset
    dbs = {k: build_packed(small_graph, payloads[k], filt=filters[k])
           for k in filters}
    assert dbs["pq"].bytes_layout3 < dbs["pca"].bytes_layout3
    assert dbs["none"].bytes_layout3 < dbs["pq"].bytes_layout3
    # identity: index bytes + the high table, nothing else
    nnz = sum(int((l.adj >= 0).sum()) for l in dbs["none"].layers)
    assert dbs["none"].bytes_layout3 == nnz * 4 + x.size * 4
    assert dbs["pq"].low.dtype == jnp.uint8
    assert dbs["none"].low.shape[1] == 0
    # per-vector pricing surfaces through the FilterSpec contract
    assert filters["pq"].bytes_per_vec == filters["pq"].cb.n_sub
    assert filters["pca"].bytes_per_vec == 15 * 4
    assert filters["none"].bytes_per_vec == 0
    # cascade: PQ-class INLINE bytes (same hot-stream burst as pq),
    # with the PCA rows off-stream in the low2 side-car
    assert filters["cascade"].bytes_per_vec == \
        filters["pq"].bytes_per_vec
    assert filters["cascade"].mid_bytes_per_vec == 15 * 4
    assert dbs["cascade"].bytes_layout3 == dbs["pq"].bytes_layout3
    assert dbs["cascade"].low.dtype == jnp.uint8
    assert dbs["cascade"].low2 is not None
    assert dbs["cascade"].low2.shape == (len(x), 15)
    assert dbs["cascade"].bytes_sidecar == len(x) * 15 * 4
    for k in ("pca", "pq", "none"):
        assert dbs[k].low2 is None and dbs[k].bytes_sidecar == 0


def test_cost_model_prices_filter_generically(small_dataset, small_graph,
                                              filters, payloads):
    """query_cost accepts the active FilterSpec and prices the filter
    compute by its cost_dims: at identical traversal stats, the PQ
    filter (n_sub lookups) models cheaper Dist.L time than PCA (d_low
    dims) iff n_sub < d_low scaling says so; DRAM bytes always follow
    the instrumented stats."""
    from repro.core.cost_model import DDR4, query_cost
    x, q, _ = small_dataset
    st = {}
    for kind in ("pca", "pq"):
        _, st[kind] = search_filtered(small_graph, filters[kind],
                                      payloads[kind], q[0])
    c_pca = query_cost(st["pca"], n_queries=1, dim=x.shape[1],
                       filt=filters["pca"], dram=DDR4)
    c_pq = query_cost(st["pq"], n_queries=1, dim=x.shape[1],
                      filt=filters["pq"], dram=DDR4)
    # PQ stats priced with PCA depth must differ from PQ depth pricing
    c_pq_mispriced = query_cost(st["pq"], n_queries=1, dim=x.shape[1],
                                d_low=filters["pca"].cost_dims, dram=DDR4)
    assert c_pq.breakdown["dist_l"] != c_pq_mispriced.breakdown["dist_l"]
    # the PQ trace moved fewer payload bytes (16 vs 60 B/vec inline)
    assert st["pq"].seq_bytes < st["pca"].seq_bytes
    assert c_pq.total_ns > 0 and c_pca.total_ns > 0


def test_cost_model_prices_cascade_two_stage(small_dataset, small_graph,
                                             filters, payloads,
                                             payload_mids):
    """The cascade trace carries a third distance class — the promote
    stage's PCA scores — and the cost model prices it as its own
    breakdown entry at mid_cost_dims depth, separate from the in-loop
    ADC (cost_dims) and the deferred Dist.H pass."""
    from repro.core.cost_model import DDR4, query_cost
    x, q, _ = small_dataset
    filt = filters["cascade"]
    _, st = search_filtered(small_graph, filt, payloads["cascade"],
                            q[0], deferred=True, rerank_mult=2,
                            promote_mult=4,
                            payload_mid=payload_mids["cascade"])
    assert st.dist_mid > 0                 # promote stage ran
    c = query_cost(st, n_queries=1, dim=x.shape[1], filt=filt,
                   dram=DDR4)
    assert c.breakdown.get("dist_m", 0) > 0
    # in-loop stage priced at ADC depth, promote stage at d_low depth
    import math
    from repro.core.cost_model import PROCESSOR
    cycles = c.breakdown["dist_m"] * PROCESSOR.freq_ghz
    assert cycles == math.ceil(st.dist_mid / PROCESSOR.dist_lanes) \
        * filt.mid_cost_dims
    # single-stage traces never grow a dist_m entry
    _, st_p = search_filtered(small_graph, filters["pca"],
                              payloads["pca"], q[0])
    assert st_p.dist_mid == 0
    c_p = query_cost(st_p, n_queries=1, dim=x.shape[1],
                     filt=filters["pca"], dram=DDR4)
    assert "dist_m" not in c_p.breakdown


@pytest.mark.parametrize("kind", ["pq", "cascade"])
def test_prepare_jnp_matches_host(small_dataset, filters, kind):
    """prepare_jnp (device path, shared ADC-table helper) reproduces
    the host prepare() tables/projections to float tolerance — the two
    engines must score candidates off the same per-query prep."""
    _, q, _ = small_dataset
    filt = filters[kind]
    host = filt.prepare(q[:8].astype(np.float32))
    dev = np.asarray(filt.prepare_jnp(jnp.asarray(q[:8])))
    assert host.shape == dev.shape
    np.testing.assert_allclose(host, dev, atol=2e-3, rtol=1e-4)


def test_train_pq_small_n_and_reseed():
    """Regression: train_pq on fewer than 256 points must not crash
    (the sharded build path hits this), and empty clusters get reseeded
    — every centroid finite, codes stay decodable."""
    from repro.core.pq import adc_table, encode_pq, train_pq
    rng = np.random.default_rng(5)
    x = rng.normal(size=(60, 32)).astype(np.float32)
    cb = train_pq(x, 4, iters=3, seed=0)          # n=60 < 256 codes
    assert cb.centroids.shape == (4, 256, 8)
    assert np.isfinite(cb.centroids).all()
    codes = encode_pq(cb, x)
    assert codes.shape == (60, 4) and codes.dtype == np.uint8
    # ADC self-distance via own code is near-zero for tiny n (every
    # point effectively owns a centroid after reseed + jitter)
    tab = adc_table(cb, x[0])
    d0 = tab[np.arange(4), codes[0]].sum()
    assert d0 < 1e-2
    # weighted training: zero-weight support below 256 also survives
    w = np.zeros(60)
    w[:40] = 1.0
    cbw = train_pq(x, 4, iters=2, seed=1, weights=w)
    assert np.isfinite(cbw.centroids).all()
    # empty-cluster reseed: two tight blobs empty most of the 256
    # initial clusters every iteration — a stale centroid would
    # survive as a DUPLICATE dead code; after reseeding to the
    # farthest-assigned points every centroid row stays distinct
    blobs = np.concatenate([
        rng.normal(0.0, 0.05, (150, 16)),
        rng.normal(4.0, 0.05, (150, 16))]).astype(np.float32)
    cb2 = train_pq(blobs, 2, iters=4, seed=2)
    for m in range(2):
        assert len(np.unique(cb2.centroids[m], axis=0)) == 256


def test_mutable_index_with_pq_filter(small_dataset, small_graph,
                                      filters):
    """The mutable index refreshes whichever payload the filter owns:
    PQ-coded dirty rows re-gather uint8 codes, upserts encode through
    the filter, and search stays live."""
    from repro.data.vectors import make_sift_like
    from repro.index import MutableIndex
    x, q, _ = small_dataset
    idx = MutableIndex.from_graph(small_graph, filters["pq"], seed=1)
    assert idx.x_low.dtype == np.uint8
    assert idx.db.filter_kind == "pq"
    x_new = make_sift_like(80, seed=33)
    ids = idx.upsert(x_new)
    _, fi = idx.search(x_new[:16])
    hits = (np.asarray(fi)[:, 0] == ids[:16])
    assert hits.mean() > 0.8          # PQ filter is lossy but close
    # drift check degrades gracefully for non-PCA filters
    rep = idx.pca_drift()
    assert not rep["refit_recommended"]


def test_vector_service_identity_filter(small_dataset, small_graph,
                                        filters, payloads):
    """A frozen identity-filter PackedDB serves without any PCA."""
    from repro.serve.vector_service import VectorSearchService
    x, q, gt = small_dataset
    db = build_packed(small_graph, payloads["none"],
                      filt=filters["none"])
    svc = VectorSearchService(db, batch_size=16)
    idx_out, stats = svc.run_stream(q)
    r = float(np.mean([recall_at(idx_out[i], gt[i], 10)
                       for i in range(len(q))]))
    assert r > 0.75
    assert stats["p50_ms"] > 0
