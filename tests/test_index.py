"""Mutable-index subsystem: online upserts, tombstone deletes,
compaction, PCA drift, snapshot/restore, and the serving integration
(epoch-versioned atomic swap). The churn acceptance scenario (8k index,
+25% upserts, 10% deletes, recall parity with a from-scratch rebuild,
zero steady-state recompiles) lives in tests/test_system.py."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.search_jax import build_packed, search_batched
from repro.core.search_ref import recall_at, search_phnsw
from repro.data.vectors import make_queries, make_sift_like
from repro.index import MutableIndex
from repro.serve.vector_service import VectorSearchService


@pytest.fixture()
def mut_index(small_graph, small_pca):
    # fresh per test: every test mutates it
    return MutableIndex.from_graph(small_graph, small_pca, seed=1)


def _live_recall(idx, q, at=10):
    """Recall of idx.search against brute force over the live set."""
    gt = idx.live_ground_truth(q, at)
    _, fi = idx.search(q)
    fi = np.asarray(fi)
    return float(np.mean([recall_at(fi[i], gt[i], at)
                          for i in range(len(q))])), fi


def test_capacity_padding_invariants(mut_index):
    idx = mut_index
    assert idx.cap >= idx.n and idx.cap & (idx.cap - 1) == 0
    # pad slots: born deleted, unlinked, level -1
    assert idx.deleted[idx.n:].all()
    assert (idx.levels[idx.n:] == -1).all()
    for a in idx.adj:
        assert (a[idx.n:] == -1).all()
    # published device buffers are capacity-sized
    assert idx.db.high.shape[0] == idx.cap
    assert idx.db.deleted.shape[0] == idx.cap // 32


def test_insert_finds_new_vectors(mut_index, small_dataset):
    idx = mut_index
    x, _, _ = small_dataset
    rng = np.random.default_rng(9)
    x_new = make_sift_like(300, seed=77)
    n0, epoch0 = idx.n, idx.epoch
    ids = idx.upsert(x_new)
    assert idx.n == n0 + 300 and len(ids) == 300
    assert idx.epoch > epoch0
    # querying AT the inserted vectors must surface their ids
    _, fi = idx.search(x_new[:32])
    hits = (np.asarray(fi)[:, 0] == ids[:32])
    assert hits.mean() > 0.9
    # overall recall on the mixed live set stays high
    q = make_queries(np.concatenate([x, x_new]), 32, seed=10)
    rec, _ = _live_recall(idx, q)
    assert rec > 0.85


def test_delete_tombstone_semantics(mut_index, small_dataset, small_graph,
                                    small_pca, small_xlow):
    idx = mut_index
    x, q, gt = small_dataset
    dels = np.unique(gt[:, :3].ravel())      # delete many true neighbors
    idx.delete(dels, auto_compact=False)
    _, fi = idx.search(q)
    fi = np.asarray(fi)
    assert not np.isin(fi, dels).any()
    assert (fi < idx.n).all()                # pad slots never returned
    # deleted nodes are traversed: recall vs the LIVE ground truth holds
    rec, _ = _live_recall(idx, q)
    assert rec > 0.85
    # host reference implements the same semantics
    deleted = np.zeros(len(x), bool)
    deleted[dels] = True
    found, _ = search_phnsw(small_graph, small_xlow, small_pca, q[0],
                            deleted=deleted)
    assert not np.isin(found, dels).any()


def test_delete_entry_point_still_routes(mut_index, small_dataset):
    idx = mut_index
    _, q, _ = small_dataset
    entry = idx.entry
    idx.delete([entry], auto_compact=False)
    rec, fi = _live_recall(idx, q)
    assert not (fi == entry).any()
    assert rec > 0.85


def test_growth_is_power_of_two_and_reserve(mut_index):
    idx = mut_index
    cap0 = idx.cap
    x_new = make_sift_like(cap0 - idx.n + 1, seed=5)   # force one growth
    idx.upsert(x_new)
    assert idx.cap == 2 * cap0
    idx.reserve(idx.cap * 4 + 1)
    assert idx.cap == cap0 * 16
    assert idx.deleted[idx.n:].all()


def test_compact_trigger_and_remap(small_graph, small_pca, small_dataset):
    import dataclasses
    cfg = dataclasses.replace(small_graph.cfg, compact_tombstone_frac=0.2)
    g = dataclasses.replace(small_graph, cfg=cfg)
    idx = MutableIndex.from_graph(g, small_pca, seed=1)
    _, q, _ = small_dataset
    n0 = idx.n
    rng = np.random.default_rng(0)
    doomed = rng.choice(n0, size=int(0.25 * n0), replace=False)
    idx.delete(doomed)                       # crosses 0.2 -> auto-compact
    assert idx.n_deleted == 0 and idx.n == n0 - len(doomed)
    assert idx.cap & (idx.cap - 1) == 0
    rec, fi = _live_recall(idx, q)
    assert (fi[fi >= 0] < idx.n).all()       # dense remapped id space
    assert rec > 0.8                         # graph repair kept recall
    # compaction renumbers ids and surfaces the remap: dropped ids map
    # to -1, survivors to their dense slot
    remap = idx.last_remap
    assert remap is not None and len(remap) == n0
    assert (remap[doomed] == -1).all()
    assert (np.sort(remap[remap >= 0]) == np.arange(idx.n)).all()
    # stale ids (>= the shrunk n) are ignored, not a crash
    assert idx.delete(np.asarray([n0 - 1, n0, 10 ** 6])) == 0


def test_pca_drift_flags_distribution_shift(mut_index):
    idx = mut_index
    rep0 = idx.pca_drift()
    assert not rep0["refit_recommended"]
    # inserts far off the fitted manifold (full-rank uniform noise);
    # 1k of them against 4k on-manifold points drop the captured
    # variance well past the refit tolerance
    rng = np.random.default_rng(3)
    x_off = rng.uniform(0, 220, size=(1000, idx.x.shape[1])) \
        .astype(np.float32)
    idx.upsert(x_off)
    rep1 = idx.pca_drift()
    assert rep1["captured_live"] < rep0["captured_live"]
    assert rep1["refit_recommended"]


def test_snapshot_restore_roundtrip(mut_index, small_dataset, tmp_path):
    idx = mut_index
    _, q, _ = small_dataset
    idx.upsert(make_sift_like(100, seed=8))
    idx.delete(np.arange(50), auto_compact=False)
    idx.save(tmp_path / "snap.npz")
    idx2 = MutableIndex.load(tmp_path / "snap.npz", idx.cfg, seed=2)
    assert idx2.n == idx.n and idx2.entry == idx.entry
    assert idx2.n_deleted == idx.n_deleted
    _, fi = idx.search(q)
    _, fi2 = idx2.search(q)
    np.testing.assert_array_equal(np.asarray(fi), np.asarray(fi2))
    # the restored index keeps absorbing upserts
    ids = idx2.upsert(make_sift_like(20, seed=9))
    assert len(ids) == 20


def test_service_upsert_delete_epoch_swap(small_graph, small_pca,
                                          small_dataset):
    x, q, gt = small_dataset
    idx = MutableIndex.from_graph(small_graph, small_pca, seed=1)
    svc = VectorSearchService(idx, batch_size=16)
    e0 = svc.epoch
    _, fi_before = svc.query(q[:16])
    x_new = make_sift_like(60, seed=12)
    ids = svc.upsert(x_new)
    assert svc.epoch > e0
    # new vectors are immediately servable
    _, fi_new = svc.query(x_new[:16])
    assert (fi_new[:, 0] == ids[:16]).mean() > 0.9
    # deletes take effect on the next batch
    victim = np.asarray(fi_before[:, 0])
    svc.delete(victim)
    _, fi_after = svc.query(q[:16])
    assert not np.isin(fi_after, victim).any()
    assert svc.stats.upserts == 60 and svc.stats.deletes == len(
        np.unique(victim))
    # frozen PackedDB service refuses mutation
    db = build_packed(small_graph, small_pca.transform(x)
                      .astype(np.float32))
    frozen = VectorSearchService(db, small_pca, batch_size=16)
    with pytest.raises(RuntimeError):
        frozen.upsert(x_new)
    with pytest.raises(RuntimeError):
        frozen.delete([0])
