"""Per-kernel Pallas (interpret=True) vs pure-jnp oracle, swept over
shapes/dtypes, plus hypothesis property tests on the sort/filter
invariants."""
import os

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dist_l import dist_l_pallas
from repro.kernels.ksort_l import ksort_l_pallas
from repro.kernels.dist_h import dist_h_pallas
from repro.kernels.fused_filter import fused_expand_pallas, fused_filter_pallas
from repro.kernels.merge_sorted import merge_sorted_pallas
from repro.kernels.pq_adc import pq_adc_expand_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.decode_attention import decode_attention_pallas

RNG = np.random.default_rng(0)


def rnd(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale).astype(dtype)


# ------------------------- shape/dtype sweeps -------------------------------

@pytest.mark.parametrize("B,M,dl", [(8, 16, 15), (8, 32, 15), (16, 32, 16),
                                    (8, 64, 8), (24, 128, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dist_l_sweep(B, M, dl, dtype):
    x, q = rnd((B, M, dl), dtype), rnd((B, dl), dtype)
    out = dist_l_pallas(x, q, block_b=8, interpret=True)
    tol = 1e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(out, ref.dist_l_ref(x, q), rtol=tol, atol=tol)


@pytest.mark.parametrize("B,M,k", [(8, 16, 3), (8, 32, 16), (16, 32, 8),
                                   (8, 64, 16), (8, 128, 32)])
def test_ksort_sweep(B, M, k):
    d = rnd((B, M), scale=3.0)
    v1, i1 = ksort_l_pallas(d, k, block_b=8, interpret=True)
    v0, i0 = ref.ksort_l_ref(d, k)
    np.testing.assert_allclose(v1, v0, rtol=1e-6)
    np.testing.assert_array_equal(i1, i0)


@pytest.mark.parametrize("B,K,D", [(8, 16, 128), (8, 3, 128), (16, 32, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dist_h_sweep(B, K, D, dtype):
    x, q = rnd((B, K, D), dtype), rnd((B, D), dtype)
    out = dist_h_pallas(x, q, block_b=8, interpret=True)
    tol = 1e-5 if dtype == jnp.float32 else 0.3
    np.testing.assert_allclose(out, ref.dist_h_ref(x, q), rtol=tol, atol=tol)


@pytest.mark.parametrize("B,M,dl,k", [(8, 32, 15, 16), (8, 16, 15, 3),
                                      (16, 64, 16, 8)])
def test_fused_filter_sweep(B, M, dl, k):
    x, q = rnd((B, M, dl)), rnd((B, dl))
    v1, i1 = fused_filter_pallas(x, q, k, block_b=8, interpret=True)
    v0, i0 = ref.fused_filter_ref(x, q, k)
    np.testing.assert_allclose(v1, v0, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(i1, i0)


@pytest.mark.parametrize("B,M,dl,k", [(8, 32, 15, 16), (8, 16, 15, 3),
                                      (16, 64, 16, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_expand_sweep(B, M, dl, k, dtype):
    """Masked/thresholded expand kernel == ref oracle, incl. bf16
    layout-(3) storage (distances still f32)."""
    x, q = rnd((B, M, dl), dtype), rnd((B, dl))
    valid = jnp.asarray(RNG.integers(0, 2, (B, M)), jnp.int32)
    th = jnp.asarray(
        np.where(RNG.random(B) < 0.5, 2.0, ref.INF), jnp.float32)
    v1, i1 = fused_expand_pallas(x, q, valid, th[:, None], k,
                                 block_b=8, interpret=True)
    v0, i0 = ref.fused_expand_ref(x, q, valid.astype(bool), th, k)
    np.testing.assert_allclose(v1, v0, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(i1, i0)


def test_fused_expand_masks_and_threshold():
    """Survivors = valid & below-threshold only; non-survivors sort last
    with vals >= VALID_MAX."""
    x, q = rnd((8, 16, 4)), rnd((8, 4))
    valid = jnp.ones((8, 16), bool).at[:, 10:].set(False)
    th = jnp.full((8,), 1.5, jnp.float32)
    v, i = ref.fused_expand_ref(x, q, valid, th, 16)
    d = np.asarray(ref.dist_l_ref(x, q))
    surv = (d < 1.5) & np.asarray(valid)
    got_surv = np.asarray(v) < ref.VALID_MAX
    assert (got_surv.sum(1) == surv.sum(1)).all()
    for b in range(8):
        kept = np.asarray(i[b])[got_surv[b]]
        assert set(kept.tolist()) == set(np.where(surv[b])[0].tolist())
        assert np.all(np.diff(np.asarray(v[b])[got_surv[b]]) >= 0)


def test_pq_adc_bit_equality_vs_numpy():
    """The fused ADC kernel (one-hot gather-accumulate, interpret mode)
    is BIT-EQUAL to the plain numpy ADC (`core.pq.adc_distances`) on
    exactly-representable table values — the satellite acceptance for
    the on-device PQ path. Integer-valued f32 tables make every
    accumulation order exact, so any mismatch is a real indexing bug,
    not summation noise."""
    from repro.core.pq import adc_distances
    B, M, S = 8, 32, 16
    lut = jnp.asarray(RNG.integers(0, 1 << 16, (B, S, 256)), jnp.float32)
    codes = jnp.asarray(RNG.integers(0, 256, (B, M, S)), jnp.int32)
    valid = jnp.ones((B, M), jnp.int32)
    th = jnp.full((B, 1), ref.INF, jnp.float32)
    v, i = pq_adc_expand_pallas(codes, lut, valid, th, M, block_b=8,
                                interpret=True)
    # numpy oracle: per query, ADC every code row then sort (ties -> idx)
    for b in range(B):
        want = adc_distances(np.asarray(lut[b]), np.asarray(codes[b]))
        order = np.lexsort((np.arange(M), want))
        np.testing.assert_array_equal(np.asarray(i[b]), order)
        np.testing.assert_array_equal(np.asarray(v[b]), want[order])


@pytest.mark.parametrize("B,M,S,k", [(8, 32, 16, 16), (8, 16, 8, 3),
                                     (16, 64, 4, 8)])
def test_pq_adc_expand_sweep(B, M, S, k):
    """Fused PQ ADC expand kernel == jnp oracle across shapes, with
    masking and thresholds active."""
    lut = jnp.abs(rnd((B, S, 256), scale=2.0))
    codes = jnp.asarray(RNG.integers(0, 256, (B, M, S)), jnp.int32)
    valid = jnp.asarray(RNG.integers(0, 2, (B, M)), jnp.int32)
    th = jnp.asarray(
        np.where(RNG.random(B) < 0.5, float(S), ref.INF), jnp.float32)
    v1, i1 = pq_adc_expand_pallas(codes, lut, valid, th[:, None], k,
                                  block_b=8, interpret=True)
    v0, i0 = ref.pq_adc_expand_ref(codes, lut, valid.astype(bool), th, k)
    np.testing.assert_allclose(v1, v0, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(i1, i0)


def test_pq_adc_ref_matches_numpy():
    """The jnp ADC oracle (take_along_axis form) == core.pq's numpy
    ADC on random float tables."""
    from repro.core.pq import adc_distances
    B, M, S = 4, 12, 8
    lut = np.abs(RNG.standard_normal((B, S, 256))).astype(np.float32)
    codes = RNG.integers(0, 256, (B, M, S)).astype(np.int32)
    got = np.asarray(ref.pq_adc_ref(jnp.asarray(codes), jnp.asarray(lut)))
    want = np.stack([adc_distances(lut[b], codes[b]) for b in range(B)])
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("Na,Nb,k", [(36, 16, 36), (10, 16, 10),
                                     (16, 16, 16), (64, 3, 64),
                                     (32, 8, 20)])
def test_merge_sorted_sweep(Na, Nb, k):
    B = 8
    a = np.sort(RNG.choice(RNG.standard_normal(16), (B, Na)), axis=1)
    b = np.sort(RNG.choice(RNG.standard_normal(16), (B, Nb)), axis=1)
    ia = jnp.asarray(RNG.integers(0, 999, (B, Na)), jnp.int32)
    ib = jnp.asarray(RNG.integers(0, 999, (B, Nb)), jnp.int32)
    a, b = jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32)
    d1, i1 = merge_sorted_pallas(a, ia, b, ib, k, block_b=8,
                                 interpret=True)
    d0, i0 = ref.merge_topk_sorted_ref(a, ia, b, ib, k)
    np.testing.assert_allclose(d1, d0, rtol=1e-6)
    np.testing.assert_array_equal(i1, i0)


def test_merge_sorted_matches_full_sort():
    """The O(ef+k) sorted merge == concat + stable full sort (a side
    wins ties, then lower slot)."""
    B, Na, Nb, k = 4, 24, 8, 24
    a = np.sort(RNG.choice(RNG.standard_normal(8), (B, Na)), axis=1) \
        .astype(np.float32)
    b = np.sort(RNG.choice(RNG.standard_normal(8), (B, Nb)), axis=1) \
        .astype(np.float32)
    ia = RNG.integers(0, 999, (B, Na)).astype(np.int32)
    ib = RNG.integers(0, 999, (B, Nb)).astype(np.int32)
    d, i = ref.merge_topk_sorted_ref(jnp.asarray(a), jnp.asarray(ia),
                                     jnp.asarray(b), jnp.asarray(ib), k)
    for r in range(B):
        alld = np.concatenate([a[r], b[r]])
        alli = np.concatenate([ia[r], ib[r]])
        side = np.r_[np.zeros(Na), np.ones(Nb)]
        slot = np.r_[np.arange(Na), np.arange(Nb)]
        order = np.lexsort((slot, side, alld))
        np.testing.assert_allclose(np.asarray(d[r]), alld[order][:k])
        np.testing.assert_array_equal(np.asarray(i[r]), alli[order][:k])


def test_merge_sorted_edge_cases():
    """Duplicate distances (a side wins ties, then lower slot), an
    all-INF b list (output == a), and k=1 — the degenerate shapes the
    traversal hits on empty frontiers and the distributed merge hits
    with ef=1 upper layers."""
    from repro.constants import INF
    from repro.kernels import ops
    # duplicate distances across and within lists: deterministic order
    d_a = jnp.asarray([[1.0, 1.0, 2.0]], jnp.float32)
    i_a = jnp.asarray([[0, 1, 2]], jnp.int32)
    d_b = jnp.asarray([[1.0, 2.0]], jnp.float32)
    i_b = jnp.asarray([[10, 11]], jnp.int32)
    d, i = ops.merge_topk_sorted(d_a, i_a, d_b, i_b, 5)
    np.testing.assert_allclose(np.asarray(d[0]), [1.0, 1.0, 1.0, 2.0, 2.0])
    np.testing.assert_array_equal(np.asarray(i[0]), [0, 1, 10, 2, 11])
    # all-INF b list: output is exactly a (the no-new-candidates step)
    d_inf = jnp.full((1, 2), INF, jnp.float32)
    i_inf = jnp.full((1, 2), -1, jnp.int32)
    d, i = ops.merge_topk_sorted(d_a, i_a, d_inf, i_inf, 3)
    np.testing.assert_allclose(np.asarray(d), np.asarray(d_a))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_a))
    # both all-INF: k output slots stay (INF, -1)
    d, i = ops.merge_topk_sorted(d_inf, i_inf, d_inf, i_inf, 2)
    assert (np.asarray(d) >= ref.VALID_MAX).all()
    np.testing.assert_array_equal(np.asarray(i), [[-1, -1]])
    # k=1: the single smallest, a side on ties
    d, i = ops.merge_topk_sorted(d_a, i_a, d_b, i_b, 1)
    np.testing.assert_allclose(np.asarray(d), [[1.0]])
    np.testing.assert_array_equal(np.asarray(i), [[0]])
    # k=1 against the pallas kernel path too
    d8 = jnp.tile(d_a, (8, 1))
    i8 = jnp.tile(i_a, (8, 1))
    db8 = jnp.tile(d_b, (8, 1))
    ib8 = jnp.tile(i_b, (8, 1))
    dp, ip = merge_sorted_pallas(d8, i8, db8, ib8, 1, block_b=8,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(dp), np.ones((8, 1)))
    np.testing.assert_array_equal(np.asarray(ip), np.zeros((8, 1)))


def test_sentinels_single_source():
    """The INF/VALID_MAX sentinels have exactly one definition
    (repro.constants), re-exported bit-identically everywhere."""
    from repro import constants
    from repro.core import search_jax
    assert ref.INF is constants.INF
    assert ref.VALID_MAX is constants.VALID_MAX
    assert float(search_jax.INF) == float(np.float32(constants.INF))


@pytest.mark.parametrize("S,T,window", [(128, 128, 0), (128, 256, 0),
                                        (256, 256, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, T, window, dtype):
    B, H, d = 2, 2, 64
    q, k, v = rnd((B, H, S, d), dtype), rnd((B, H, T, d), dtype), \
        rnd((B, H, T, d), dtype)
    out = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 bq=64, bk=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-3 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_noncausal():
    B, H, S, d = 1, 2, 128, 64
    q, k, v = rnd((B, H, S, d)), rnd((B, H, S, d)), rnd((B, H, S, d))
    out = flash_attention_pallas(q, k, v, causal=False, bq=64, bk=64,
                                 interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, want, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("T,bk", [(256, 64), (512, 128)])
def test_decode_attention_sweep(T, bk):
    B, H, d = 3, 4, 64
    q, k, v = rnd((B, H, d)), rnd((B, H, T, d)), rnd((B, H, T, d))
    length = jnp.asarray([1, T // 2, T], jnp.int32)
    out = decode_attention_pallas(q, k, v, length, bk=bk, interpret=True)
    want = ref.decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(out, want, rtol=2e-3, atol=2e-3)


# ------------------------- hypothesis properties ----------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(2, 48), st.integers(1, 16), st.data())
def test_ksort_properties(m, k, data):
    """rank is a permutation; output = sorted smallest-k; ties -> index."""
    k = min(k, m)
    # XLA flushes subnormals to zero (numpy doesn't), which legitimately
    # changes tie-breaking — exclude denormal magnitudes
    vals = data.draw(st.lists(
        st.floats(-1e6, 1e6, allow_nan=False, width=32).filter(
            lambda v: v == 0.0 or abs(v) > 1e-30),
        min_size=m, max_size=m))
    d = jnp.asarray([vals], jnp.float32)
    v, i = ref.ksort_l_ref(d, k)
    order = np.lexsort((np.arange(m), np.asarray(d[0])))
    np.testing.assert_array_equal(np.asarray(i[0]), order[:k])
    assert np.all(np.diff(np.asarray(v[0])) >= 0)            # ascending
    assert len(set(np.asarray(i[0]).tolist())) == k           # distinct


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(4, 32), st.integers(1, 20))
def test_dist_l_nonneg_and_zero(b, m, dl):
    """distances are >= 0 and d(x, x) == 0."""
    x = jnp.asarray(RNG.standard_normal((b, m, dl)), jnp.float32)
    q = x[:, 0, :]
    d = ref.dist_l_ref(x, q)
    assert float(d.min()) >= 0.0
    np.testing.assert_allclose(np.asarray(d[:, 0]), 0.0, atol=1e-4)


# --------- invariant suite: ksort_l / merge_topk_sorted (ISSUE-4) ----------
# Deterministic under fixed seeds: derandomize=True replays the same
# example sequence every run (no flaky health checks, no shrink-database
# state in CI). Values are drawn from a SMALL tie-rich pool (duplicates
# and INF sentinels are exactly the cases the traversal and the
# cross-shard merge hit constantly).

_TIE_POOL = [0.0, 0.5, 1.0, 1.0, 2.0, 2.0, 3.5, float(np.float32(ref.INF))]


@settings(max_examples=40, deadline=None, derandomize=True)
@given(st.integers(2, 40), st.integers(1, 16), st.data())
def test_ksort_l_invariants(m, k, data):
    """ops.ksort_l: output ascending; indices in range, distinct; the
    (val, idx) pairs are a multiset-subset of the input pairs (val =
    d[idx] exactly); ties broken by index — all under duplicate values
    and all-INF rows."""
    from repro.kernels import ops
    k = min(k, m)
    vals = data.draw(st.lists(st.sampled_from(_TIE_POOL),
                              min_size=m, max_size=m))
    d = np.asarray([vals], np.float32)
    v, i = ops.ksort_l(jnp.asarray(d), k)
    v, i = np.asarray(v[0]), np.asarray(i[0])
    assert np.all(np.diff(v) >= 0)                       # sorted
    assert ((i >= 0) & (i < m)).all()                    # in range
    assert len(set(i.tolist())) == k                     # distinct
    np.testing.assert_array_equal(v, d[0][i])            # pairs exist
    order = np.lexsort((np.arange(m), d[0]))             # ties -> index
    np.testing.assert_array_equal(i, order[:k])


@settings(max_examples=40, deadline=None, derandomize=True)
@given(st.integers(1, 24), st.integers(1, 16), st.integers(1, 24),
       st.data())
def test_merge_topk_sorted_invariants(na, nb, k, data):
    """ops.merge_topk_sorted: output sorted; every output (dist, idx)
    pair is a multiset-subset of the two inputs; equals the concat +
    stable-lexsort oracle — deterministic under duplicate distances
    (a side, then lower slot), all-INF rows and k=1."""
    from collections import Counter
    from repro.kernels import ops
    k = min(k, na + nb)                       # the documented contract
    da = np.sort(np.asarray(
        data.draw(st.lists(st.sampled_from(_TIE_POOL),
                           min_size=na, max_size=na)), np.float32))
    db_ = np.sort(np.asarray(
        data.draw(st.lists(st.sampled_from(_TIE_POOL),
                           min_size=nb, max_size=nb)), np.float32))
    ia = np.arange(na, dtype=np.int32)
    ib = np.arange(100, 100 + nb, dtype=np.int32)
    d, i = ops.merge_topk_sorted(jnp.asarray(da[None]),
                                 jnp.asarray(ia[None]),
                                 jnp.asarray(db_[None]),
                                 jnp.asarray(ib[None]), k)
    d, i = np.asarray(d[0]), np.asarray(i[0])
    assert d.shape == (k,) and np.all(np.diff(d) >= 0)   # sorted, k wide
    have = Counter(zip(d.tolist(), i.tolist()))
    pool = Counter(zip(da.tolist(), ia.tolist()))
    pool.update(zip(db_.tolist(), ib.tolist()))
    for pair, c in have.items():
        assert pool[pair] >= c, (pair, c)                # multiset subset
    # oracle: concat + stable lexsort on (dist, side, slot). The b list
    # is trimmed to its first k entries before the merge (a sorted b
    # slot past k can never reach a k-wide output), which on EQUAL
    # dists is exactly the (side, slot) tie-break the lexsort applies
    alld = np.concatenate([da, db_[:k]])
    alli = np.concatenate([ia, ib[:k]])
    side = np.r_[np.zeros(na), np.ones(min(nb, k))]
    slot = np.r_[np.arange(na), np.arange(min(nb, k))]
    order = np.lexsort((slot, side, alld))[:k]
    np.testing.assert_array_equal(d, alld[order])
    np.testing.assert_array_equal(i, alli[order])
