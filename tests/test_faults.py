"""Fault-tolerant serving plane (ISSUE-6): deterministic fault
injection, degraded-mode search bit-equal to a survivor oracle with
exact coverage accounting, retry/backoff inside a deadline budget,
straggler detection over per-shard query walls, checksummed snapshot
envelopes, replica failover + snapshot-shipped recovery with an
idempotent op log — and zero steady-state recompiles across the whole
kill -> degraded -> recover cycle (every failure state is DATA)."""
import time

import numpy as np
import pytest
import jax.numpy as jnp

from repro.distributed import faults
from repro.distributed.fault import StepMonitor
from repro.distributed.faults import (AllReplicasDeadError, FaultPlan,
                                      FaultPolicy, ShardHealth,
                                      ShardKilledError,
                                      SnapshotCorruptError)


# --------------------------------------------------------------------------
# fault-plan unit behavior (no index needed)
# --------------------------------------------------------------------------

def test_fault_plan_windows_heal_and_determinism():
    plan = FaultPlan(seed=7)
    plan.add("kill_shard", 1, at=3, until=5)
    plan.add("stall_shard", 0, param=0.01)          # from now, open-ended
    assert not plan.is_active("kill_shard", 1)      # t=0 < at=3
    assert plan.is_active("stall_shard", 0)
    plan.tick(3)
    assert plan.is_active("kill_shard", 1)
    assert not plan.is_active("kill_shard", 0)      # targeted
    plan.tick(2)                                    # t=5 == until: over
    assert not plan.is_active("kill_shard", 1)
    assert plan.heal("stall_shard") == 1
    assert not plan.is_active("stall_shard", 0)
    # chaos scripts are reproducible: same seed, same events
    a = FaultPlan.chaos(4, seed=3, n_events=6)
    b = FaultPlan.chaos(4, seed=3, n_events=6)
    assert [(e.kind, e.target, e.at, e.until) for e in a.events] == \
           [(e.kind, e.target, e.at, e.until) for e in b.events]
    assert FaultPlan.chaos(4, seed=4, n_events=6).events != a.events
    with pytest.raises(AssertionError):
        plan.add("melt_shard", 0)


def test_fault_plan_hooks_raise_and_log():
    with faults.inject(FaultPlan()) as plan:
        assert faults.active() is plan
        plan.add("kill_shard", 2)
        with pytest.raises(ShardKilledError):
            plan.shard_query_hook(2)
        plan.shard_query_hook(1)                    # other shards fine
        with pytest.raises(ShardKilledError):
            plan.shard_mutation_hook(2)
        assert plan.log == [(0, "kill_shard", 2), (0, "kill_shard", 2)]
        # corrupt garbles a COPY (caller arrays untouched) into exactly
        # what check_shard_result must reject
        plan.add("corrupt_shard", 0)
        fd = np.zeros((2, 4), np.float32)
        gi = np.arange(8, dtype=np.int32).reshape(2, 4)
        cfd, cgi = plan.corrupt_hook(0, fd, gi)
        assert np.isnan(cfd[:, 0]).all() and not np.isnan(fd).any()
        assert (cgi < 0).all() and (gi >= 0).all()
        fd2, gi2 = plan.corrupt_hook(1, fd, gi)     # untargeted shard
        assert fd2 is fd and gi2 is gi
    assert faults.active() is None                  # inject() scope-cleans


def test_step_monitor_mad_factor():
    """The additive MAD term keeps sub-ms workloads from flagging jitter
    that is a large RATIO but a tiny absolute delay; a genuine stall
    still fires. mad_factor=None preserves the ratio-only seed rule."""
    walls = [0.0010, 0.0011, 0.0009, 0.0010, 0.0012, 0.0010, 0.0009,
             0.0011]
    ratio_only = StepMonitor(straggler_factor=2.0)
    robust = StepMonitor(straggler_factor=2.0, mad_factor=20.0)
    for i, w in enumerate(walls):
        assert ratio_only.heartbeat(i, w).kind == "ok"
        assert robust.heartbeat(i, w).kind == "ok"
    # 2.5x the median but only +1.5ms absolute: scheduler noise
    assert ratio_only.heartbeat(8, 0.0025).kind == "straggler"
    assert robust.heartbeat(8, 0.0025).kind == "ok"
    # a real stall clears both terms of the max()
    assert robust.heartbeat(9, 0.050).kind == "straggler"


def test_shard_health_dead_mark_and_recover():
    h = ShardHealth(3, FaultPolicy(dead_after_failures=2))
    assert not h.failure(1, RuntimeError("x"))      # streak 1: not dead
    assert h.failure(1, RuntimeError("x"))          # streak 2: dead
    assert h.dead[1] and h.n_live == 2
    np.testing.assert_array_equal(h.live_mask(), [True, False, True])
    h.heartbeat(0, 0.001)                           # success resets streak
    assert h.failures[0] == 0
    h.recover(1)
    assert not h.dead[1] and h.failures[1] == 0
    kinds = [k for k, _, _ in h.events]
    assert kinds == ["failure", "failure", "dead", "recovered"]


def test_check_shard_result_rejects_garbage():
    from repro.core.distributed import check_shard_result
    from repro.constants import INF
    good_d = np.array([[0.0, 1.0, INF, INF]], np.float32)
    good_i = np.array([[100, 105, -1, -1]], np.int32)
    assert check_shard_result(good_d, good_i, 100, 10)
    bad_nan = good_d.copy(); bad_nan[0, 0] = np.nan
    assert not check_shard_result(bad_nan, good_i, 100, 10)
    bad_neg = good_d.copy(); bad_neg[0, 0] = -1.0
    assert not check_shard_result(bad_neg, good_i, 100, 10)
    bad_ord = np.array([[1.0, 0.5, INF, INF]], np.float32)
    assert not check_shard_result(bad_ord, good_i, 100, 10)
    alien = good_i.copy(); alien[0, 0] = 99          # below offset
    assert not check_shard_result(good_d, alien, 100, 10)
    alien[0, 0] = 110                                # past the span
    assert not check_shard_result(good_d, alien, 100, 10)


# --------------------------------------------------------------------------
# degraded-mode search: bit-equality vs the survivor oracle
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def frozen_sdb3(small_dataset, small_pca, small_graph):
    from repro.core.distributed import build_sharded
    x, q, _ = small_dataset
    sdb = build_sharded(x, small_graph.cfg, small_pca, 3)
    qd = jnp.asarray(q[:16])
    qp = jnp.asarray(small_pca.transform(q[:16]).astype(np.float32))
    return sdb, qd, qp


@pytest.mark.parametrize("deferred", [False, True])
@pytest.mark.parametrize("dead", [(0,), (2,), (0, 2)])
def test_degraded_bit_equal_survivor_subset(frozen_sdb3, dead, deferred):
    """A live-mask search must be BIT-EQUAL to searching an index built
    from only the surviving shards (``sdb.select`` keeps the original
    offsets, so global ids line up) — degraded mode is a data mask, not
    a different algorithm. Coverage is exact."""
    from repro.core.distributed import shard_live_counts, shard_search_host
    sdb, qd, qp = frozen_sdb3
    mask = np.ones(3, bool)
    mask[list(dead)] = False
    fd, fi, st = shard_search_host(sdb, qd, qp, deferred=deferred,
                                   live=mask, return_stats=True)
    survivors = sdb.select(np.nonzero(mask)[0])
    fd_o, fi_o = shard_search_host(survivors, qd, qp, deferred=deferred)
    np.testing.assert_array_equal(np.asarray(fi), np.asarray(fi_o))
    np.testing.assert_array_equal(np.asarray(fd), np.asarray(fd_o))
    lc = shard_live_counts(sdb)
    assert st["coverage"] == pytest.approx(lc[mask].sum() / lc.sum())
    assert st["degraded"] and st["live_shards"] == int(mask.sum())
    # dead shards' ids never surface
    off = np.asarray(sdb.offsets); cnt = np.asarray(sdb.counts)
    fi = np.asarray(fi)
    for s in dead:
        assert not ((fi >= off[s]) & (fi < off[s] + cnt[s])).any()


@pytest.mark.parametrize("deferred", [False, True])
def test_probe_and_merge_bit_equal_masked_path(frozen_sdb3, deferred):
    """The resilient building blocks (per-shard ``probe_shard`` + an
    answered-mask ``merge_surviving``) reassemble to the exact same
    bits as the one-program live-mask search — for the full mask AND a
    degraded one. This is the equality the service's retry loop rides
    on: HOW the per-shard lists were obtained (one program, retries,
    order) can never change the merged answer."""
    from repro.core.distributed import (merge_surviving, probe_shard,
                                        shard_search_host)
    sdb, qd, qp = frozen_sdb3
    outs = [probe_shard(sdb, s, qd, qp, deferred=deferred)
            for s in range(3)]
    assert all(w > 0 for _, _, w in outs)
    fd_all = np.stack([o[0] for o in outs])
    gi_all = np.stack([o[1] for o in outs])
    for mask in (np.array([True] * 3), np.array([True, False, True])):
        fd_m, fi_m = merge_surviving(sdb, fd_all, gi_all, mask, qd,
                                     deferred=deferred)
        fd_r, fi_r = shard_search_host(sdb, qd, qp, deferred=deferred,
                                       live=mask)
        np.testing.assert_array_equal(np.asarray(fi_m), np.asarray(fi_r))
        np.testing.assert_array_equal(np.asarray(fd_m), np.asarray(fd_r))


def test_single_shard_coverage_stats_contract(small_dataset, small_pca,
                                              small_graph):
    """``return_stats`` carries the same coverage keys on the
    single-shard engine (always 1.0 / not degraded) — one stats
    contract across every serving path."""
    from repro.core.search_jax import build_packed, search_batched
    x, q, _ = small_dataset
    db = build_packed(small_graph,
                      small_pca.transform(x).astype(np.float32))
    qd = jnp.asarray(q[:8])
    qp = jnp.asarray(small_pca.transform(q[:8]).astype(np.float32))
    out = search_batched(db, qd, qp, return_stats=True)
    st = out[-1]
    assert st["coverage"] == 1.0 and st["degraded"] is False


# --------------------------------------------------------------------------
# the resilient service: kill / corrupt / stall / recover
# --------------------------------------------------------------------------

N_FAULT, P_FAULT, B_FAULT = 2000, 4, 16


@pytest.fixture(scope="module")
def fault_svc():
    from repro.configs.base import PHNSWConfig
    from repro.data.vectors import make_queries, make_sift_like
    from repro.index import ShardedMutableIndex
    from repro.serve.vector_service import VectorSearchService
    cfg = PHNSWConfig(name="faults2k", n_points=N_FAULT,
                      ef_construction=32)
    x = make_sift_like(N_FAULT, seed=31)
    q = make_queries(x, B_FAULT, seed=32)
    idx = ShardedMutableIndex.build(x, cfg, P_FAULT, seed=1)
    pol = FaultPolicy(deadline_ms=250.0, max_retries=2, backoff_ms=1.0,
                      dead_after_failures=2, straggler_factor=4.0,
                      mad_factor=6.0)
    svc = VectorSearchService(idx, batch_size=B_FAULT, fault_policy=pol)
    return svc, idx, q


@pytest.fixture(autouse=True)
def _clean_faults(request):
    """No test leaks an installed plan or dead marks into the next."""
    yield
    faults.clear()
    if "fault_svc" in request.fixturenames:
        svc = request.getfixturevalue("fault_svc")[0]
        for s in range(P_FAULT):
            svc.recover_shard(s)
        svc.health.failures[:] = 0


def test_service_kill_degrade_recover_zero_recompiles(fault_svc):
    """The acceptance cycle: kill one of four shards under a live
    service -> requests complete DEGRADED with exact coverage and
    results bit-equal to the live-mask oracle -> the shard is marked
    dead after the failure streak (later requests skip it: no retry
    tax, no further hook hits) -> heal + recover -> full coverage
    again. The compiled-program caches never grow."""
    from repro.core import distributed as dist
    svc, idx, q = fault_svc
    fd_h, fi_h, st = svc.query(q, return_stats=True)
    assert st["coverage"] == 1.0 and not st["degraded"]
    # warm the ORACLE program too (idx.search is the one-shot masked
    # path, not what the resilient service runs) so the frozen counters
    # measure only the service's kill/degrade/recover cycle
    idx.search(q)
    counters = (dist.search_cache_sizes(), dist.resilient_cache_sizes())

    with faults.inject(FaultPlan()) as plan:
        plan.add("kill_shard", 1)
        fd_d, fi_d, st = svc.query(q, return_stats=True)
        assert st["degraded"] and st["live_shards"] == P_FAULT - 1
        lc = svc._live_counts
        mask = np.ones(P_FAULT, bool); mask[1] = False
        assert st["coverage"] == pytest.approx(lc[mask].sum() / lc.sum())
        # bit-equal to the one-program degraded oracle
        fd_o, fi_o = idx.search(q, live=mask)
        np.testing.assert_array_equal(fi_d, np.asarray(fi_o))
        np.testing.assert_array_equal(fd_d, np.asarray(fd_o))
        # dead-marked after the streak: the next request never probes it
        assert svc.health.dead[1]
        hits = len(plan.log)
        svc.query(q)
        assert len(plan.log) == hits, "dead shard still being probed"
        assert svc.stats.degraded_queries >= 2

    svc.recover_shard(1)                    # plan healed by inject exit
    fd_r, fi_r, st = svc.query(q, return_stats=True)
    assert st["coverage"] == 1.0 and not st["degraded"]
    np.testing.assert_array_equal(fi_r, fi_h)
    np.testing.assert_array_equal(fd_r, fd_h)
    assert (dist.search_cache_sizes(),
            dist.resilient_cache_sizes()) == counters, \
        "the kill/degrade/recover cycle recompiled the engine"


def test_service_corrupt_shard_quarantined(fault_svc):
    """A corrupted shard answer (NaN distances, alien ids) is caught at
    the merge boundary, never reaches results, and the shard is
    dead-marked like any other failure."""
    svc, idx, q = fault_svc
    with faults.inject(FaultPlan()) as plan:
        plan.add("corrupt_shard", 2)
        fd, fi, st = svc.query(q, return_stats=True)
        assert st["degraded"] and not st["answered"][2]
        assert np.isfinite(fd).all() and (fi >= 0).all()
        mask = np.ones(P_FAULT, bool); mask[2] = False
        fd_o, fi_o = idx.search(q, live=mask)
        np.testing.assert_array_equal(fi, np.asarray(fi_o))
        assert svc.health.dead[2]
        assert any(k == "failure" and s == 2
                   for k, s, _ in svc.health.events)


def test_service_retry_backoff_respects_deadline(fault_svc):
    """With the dead mark disabled, a killed shard burns its full retry
    budget — bounded exponential backoff inside the request's deadline:
    the request still completes degraded, fast (every sleep is capped
    by the remaining deadline, so CI never waits on a long timer)."""
    svc, idx, q = fault_svc
    pol = FaultPolicy(deadline_ms=80.0, max_retries=4, backoff_ms=5.0,
                      dead_after_failures=10 ** 6)
    old = svc.fault_policy
    svc.fault_policy = svc.health.policy = pol
    try:
        with faults.inject(FaultPlan()) as plan:
            plan.add("kill_shard", 0)
            t0 = time.monotonic()
            _, _, st = svc.query(q, return_stats=True)
            elapsed = time.monotonic() - t0
            assert st["degraded"] and not st["answered"][0]
            assert not svc.health.dead[0]        # streak never crossed
            # 5+10+20+40ms backoff < deadline; generous CI slack
            assert elapsed < 1.0, f"retry loop ran {elapsed:.2f}s"
            assert len([e for e in plan.log if e[1] == "kill_shard"]) \
                == pol.max_retries + 1
    finally:
        svc.fault_policy = svc.health.policy = old


def test_service_all_shards_dead_raises(fault_svc):
    from repro.distributed.faults import AllShardsDeadError
    svc, idx, q = fault_svc
    with faults.inject(FaultPlan()) as plan:
        plan.add("kill_shard", -1)               # every shard
        with pytest.raises(AllShardsDeadError):
            svc.query(q)


def test_service_straggler_detection_on_query_walls(fault_svc):
    """A stalled (slow but correct) shard is flagged by the per-shard
    median+MAD monitor — and ONLY flagged: its answers still count,
    coverage stays full."""
    svc, idx, q = fault_svc
    for _ in range(8):                           # build the wall window
        svc.query(q)
    n_ev = len(svc.health.events)
    with faults.inject(FaultPlan()) as plan:
        plan.add("stall_shard", 3, param=0.05)
        _, _, st = svc.query(q, return_stats=True)
    assert st["coverage"] == 1.0 and not st["degraded"]
    stragglers = [(k, s) for k, s, _ in svc.health.events[n_ev:]
                  if k == "straggler"]
    assert ("straggler", 3) in stragglers


def test_sharded_mutation_fault_injection(fault_svc):
    """Mutations routed to a killed shard raise the typed error; after
    heal the same mutation lands and is immediately servable."""
    svc, idx, q = fault_svc
    rng = np.random.default_rng(5)
    xs = rng.standard_normal((P_FAULT, q.shape[1])).astype(np.float32)
    with faults.inject(FaultPlan()) as plan:
        plan.add("kill_shard", 2)
        with pytest.raises(ShardKilledError):
            svc.upsert(xs)                       # round-robin hits 2
    gids = svc.upsert(xs)                        # healed: lands
    assert len(gids) == P_FAULT
    with faults.inject(FaultPlan()) as plan:
        plan.add("kill_shard", int(gids[0] // idx.stride))
        with pytest.raises(ShardKilledError):
            svc.delete(gids[:1])
    assert svc.delete(gids[:1]) == 1


# --------------------------------------------------------------------------
# snapshot integrity envelope
# --------------------------------------------------------------------------

def test_snapshot_roundtrip_and_corruption(tmp_path, small_dataset):
    from repro.configs.base import PHNSWConfig
    from repro.index.mutable import (MutableIndex, read_snapshot,
                                     write_snapshot)
    x, q, _ = small_dataset
    cfg = PHNSWConfig(name="snap", n_points=1000, ef_construction=32)
    idx = MutableIndex.build(x[:1000], cfg, seed=0)
    p = tmp_path / "a.npz"
    idx.save(p)
    idx2 = MutableIndex.load(p, cfg)
    _, fi = idx.search(q[:8])
    _, fi2 = idx2.search(q[:8])
    np.testing.assert_array_equal(np.asarray(fi), np.asarray(fi2))

    # truncation -> typed error (not a zipfile traceback / garbage load)
    t = tmp_path / "trunc.npz"
    t.write_bytes(p.read_bytes()[: p.stat().st_size // 2])
    with pytest.raises(SnapshotCorruptError, match="unreadable|truncated"):
        read_snapshot(t)
    # a single flipped byte -> checksum mismatch
    raw = bytearray(p.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    f = tmp_path / "flip.npz"
    f.write_bytes(bytes(raw))
    with pytest.raises(SnapshotCorruptError):
        read_snapshot(f)
    # an envelope-less npz (foreign writer) is rejected, not guessed at
    e = tmp_path / "naked.npz"
    np.savez(e, x=np.zeros(3))
    with pytest.raises(SnapshotCorruptError, match="version"):
        read_snapshot(e)
    # the checksum covers array CONTENT, not just structure
    arrays = {"a": np.arange(5, dtype=np.int64)}
    write_snapshot(tmp_path / "c.npz", arrays)
    z = dict(np.load(tmp_path / "c.npz"))
    z["a"][0] = 99
    np.savez(tmp_path / "c2.npz", **z)
    with pytest.raises(SnapshotCorruptError, match="checksum"):
        read_snapshot(tmp_path / "c2.npz")


def test_sharded_snapshot_roundtrip_bit_equal(tmp_path, fault_svc):
    from repro.index import ShardedMutableIndex
    svc, idx, q = fault_svc
    p = tmp_path / "sharded.npz"
    idx.save(p)
    idx2 = ShardedMutableIndex.load(p, idx.cfg, seed=1)
    assert idx2.n_shards == idx.n_shards and idx2.stride == idx.stride
    _, fi = idx.search(q)
    _, fi2 = idx2.search(q)
    np.testing.assert_array_equal(np.asarray(fi), np.asarray(fi2))
    np.testing.assert_array_equal(idx2.live_global_ids(),
                                  idx.live_global_ids())


def test_truncate_snapshot_fault_caught_at_load(tmp_path, fault_svc):
    """The fault plan chops the npz DURING save; the envelope catches
    it at ship time instead of seeding a replica with garbage."""
    from repro.index import ShardedMutableIndex
    svc, idx, q = fault_svc
    p = tmp_path / "ship.npz"
    with faults.inject(FaultPlan()) as plan:
        plan.add("truncate_snapshot", param=0.6)
        idx.save(p)
        assert any(k == "truncate_snapshot" for _, k, _ in plan.log)
    with pytest.raises(SnapshotCorruptError):
        ShardedMutableIndex.load(p, idx.cfg)


# --------------------------------------------------------------------------
# service API boundary: validation + bounded stats
# --------------------------------------------------------------------------

def test_service_input_validation(fault_svc):
    svc, idx, q = fault_svc
    D = q.shape[1]
    with pytest.raises(ValueError, match=r"\[n, \d+\]"):
        svc.query(q[:, :-1])                     # wrong dim
    with pytest.raises(ValueError, match=r"\[n, \d+\]"):
        svc.query(q[0])                          # 1-D
    with pytest.raises(ValueError, match="empty"):
        svc.query(q[:0])
    with pytest.raises(ValueError, match="run_stream"):
        svc.query(np.zeros((B_FAULT + 1, D), np.float32))
    with pytest.raises(ValueError, match="numeric"):
        svc.query(np.array([["a"] * D], dtype=object))
    bad = q.copy(); bad[0, 0] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        svc.query(bad)
    with pytest.raises(ValueError, match="non-finite"):
        svc.upsert(np.full((1, D), np.inf, np.float32))
    with pytest.raises(ValueError, match="ids must be integers"):
        svc.upsert(q[:1], ids=np.array([1.5]))
    with pytest.raises(ValueError, match="2 ids for 1"):
        svc.upsert(q[:1], ids=np.array([1, 2]))


def test_service_nan_policy_sanitize(fault_svc):
    from repro.serve.vector_service import VectorSearchService
    svc, idx, q = fault_svc
    svc2 = VectorSearchService(idx, batch_size=B_FAULT,
                               nan_policy="sanitize",
                               fault_policy=svc.fault_policy)
    bad = q.copy(); bad[0, :] = np.nan
    zeroed = q.copy(); zeroed[0, :] = 0.0
    _, fi_bad = svc2.query(bad)
    _, fi_ref = svc2.query(zeroed)
    np.testing.assert_array_equal(fi_bad, fi_ref)
    with pytest.raises(ValueError, match="nan_policy"):
        VectorSearchService(idx, batch_size=B_FAULT, nan_policy="drop")


def test_service_ctor_guards(small_dataset, small_graph, small_pca):
    from repro.core.search_jax import build_packed
    from repro.serve.vector_service import VectorSearchService
    x, _, _ = small_dataset
    db = build_packed(small_graph,
                      small_pca.transform(x).astype(np.float32))
    with pytest.raises(ValueError, match="sharded backend"):
        VectorSearchService(db, small_pca, batch_size=8,
                            fault_policy=FaultPolicy())


def test_service_stats_bounded_memory():
    """The histogram-backed ServiceStats holds constant memory no
    matter how many requests it absorbs (the old LATENCY_WINDOW deque
    is gone): bucket storage never grows, percentiles stay exact at
    the extremes (min/max tracked exactly) and within one log-bucket
    width elsewhere."""
    from repro.serve.vector_service import ServiceStats
    st = ServiceStats()
    n_buckets = len(st.latency_ms.counts)
    for i in range(5_000):
        st.record_request(1, float(i + 1))
    assert len(st.latency_ms.counts) == n_buckets    # no growth, ever
    assert st.latency_ms.count == 5_000
    assert st.percentile(0) == 1.0                   # exact min
    assert st.percentile(100) == 5_000.0             # exact max
    g = st.latency_ms.growth
    assert abs(st.percentile(50) - 2_500) / 2_500 < g - 1
    assert st.queries == 5_000


# --------------------------------------------------------------------------
# replica failover + snapshot-shipped recovery
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def replica_set(tmp_path_factory):
    from repro.configs.base import PHNSWConfig
    from repro.data.vectors import make_queries, make_sift_like
    from repro.index import ShardedMutableIndex
    from repro.serve import ReplicaSet, VectorSearchService
    cfg = PHNSWConfig(name="repl", n_points=600, ef_construction=32)
    x = make_sift_like(600, seed=41)
    q = make_queries(x, 8, seed=42)
    idx = ShardedMutableIndex.build(x, cfg, 2, seed=1)
    svc = VectorSearchService(idx, batch_size=8)
    rs = ReplicaSet.replicate(
        svc, 3, snapshot_dir=tmp_path_factory.mktemp("replicas"))
    return rs, q, x


def test_replica_set_serves_and_replicates(replica_set):
    rs, q, x = replica_set
    fd0, fi0 = rs.query(q)
    for r in rs.replicas[1:]:                   # replicas agree, bit-equal
        _, fi = r.svc.query(q)
        np.testing.assert_array_equal(fi, fi0)
    # replicated upsert: identical ids everywhere, state converged
    gids = rs.upsert(x[:3] + 0.01)
    assert len(gids) == 3
    rep = rs.assert_converged()
    assert rep["n_healthy"] == 3 and rep["applied_seq"] == 1
    assert rs.delete(gids[:1]) == 1
    assert rs.assert_converged()["applied_seq"] == 2


def test_replica_failover_and_stale_checkpoint_recovery(replica_set):
    """Kill the primary mid-traffic: the same request fails over; ops
    applied while it was dead replay from a STALE checkpoint on
    recovery (idempotent — the second republish applies nothing), and
    the set converges back to 3 healthy replicas."""
    rs, q, x = replica_set
    ckpt, ckpt_seq = rs.checkpoint()            # stale: before the ops
    with faults.inject(FaultPlan()) as plan:
        plan.add("kill_replica", 0)
        fd, fi = rs.query(q)                    # request survives
        assert not rs.replicas[0].alive
        assert ("failover", 1, "primary -> 1") in rs.events
        gids = rs.upsert(x[3:6] + 0.02)         # replica 0 misses this
        assert rs.assert_converged()["n_healthy"] == 2
    behind = rs.seq - ckpt_seq
    assert behind >= 1
    replayed = rs.recover(0, snapshot=ckpt, snapshot_seq=ckpt_seq)
    assert replayed == behind                   # the whole gap replayed
    assert rs.republish(0) == 0                 # idempotent: all skipped
    rep = rs.assert_converged()
    assert rep["n_healthy"] == 3
    assert rs.replicas[0].reseeds == 1
    # the recovered replica serves the post-recovery state: every id it
    # returns is live on every replica (graphs may differ microscopically
    # after a replayed insert — rng histories diverge — but the live id
    # set is the convergence invariant)
    _, fi0 = rs.replicas[0].svc.query(q)
    live = rs.replicas[1].svc._mut.live_ids()
    assert np.isin(np.asarray(fi0), live).all()


def test_replica_all_dead_raises(replica_set):
    rs, q, x = replica_set
    with faults.inject(FaultPlan()) as plan:
        plan.add("kill_replica", -1)            # everyone
        with pytest.raises(AllReplicasDeadError):
            rs.query(q)
        with pytest.raises(AllReplicasDeadError):
            rs.upsert(x[:1])
        with pytest.raises(AllReplicasDeadError):
            rs.checkpoint()
    # plan healed: replicas were only MARKED dead; recover re-seeds
    rs.replicas[1].alive = True                 # operator override
    rs.recover(0)
    rs.recover(2)
    assert rs.assert_converged()["n_healthy"] == 3
