"""Checkpointing: atomic, sharded, async — the restart half of fault
tolerance.

Format: one directory per step, one .npy per pytree leaf (path-encoded
file names) + a manifest.json with step/config/tree structure. Writes go
to ``<dir>.tmp`` and are renamed only after fsync — a killed job can
never leave a half-written "latest" checkpoint. ``CheckpointManager``
saves on a background thread (training continues while the previous
step's arrays stream to disk) and keeps the last ``keep`` checkpoints.

On restore, leaves are ``device_put`` against the CURRENT mesh's
shardings — restoring onto a different mesh shape (elastic downscale
after a failure, or scale-up) is the same code path; see
``distributed/fault.py::remesh``.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import numpy as np
import jax


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save_checkpoint(ckpt_dir: Path, step: int, tree: Any,
                    extra: Optional[Dict] = None) -> Path:
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat, _ = _flatten(tree)
    names = {}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(leaf)
        np.save(tmp / f"leaf_{i:05d}.npy", arr)
        names[key] = {"file": f"leaf_{i:05d}.npy", "dtype": str(arr.dtype),
                      "shape": list(arr.shape)}
    manifest = {"step": step, "leaves": names, "extra": extra or {}}
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def latest_step(ckpt_dir: Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
             if (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: Path, step: int, like: Any,
                       shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; if ``shardings`` given,
    leaves are device_put with them (any mesh shape)."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    flat_like, treedef = _flatten(like)
    sh_flat = None
    if shardings is not None:
        sh_flat, _ = _flatten(shardings)
    leaves = {}
    for key, meta in manifest["leaves"].items():
        arr = np.load(d / meta["file"])
        if sh_flat is not None and key in sh_flat:
            leaves[key] = jax.device_put(arr, sh_flat[key])
        else:
            leaves[key] = jax.numpy.asarray(arr)
    ordered = [leaves[k] for k in flat_like.keys()]
    # tree_unflatten needs the ORIGINAL leaf order, not sorted:
    flat_paths = [k for k in flat_like.keys()]
    return jax.tree_util.tree_unflatten(
        treedef, [leaves[k] for k in flat_paths])


class CheckpointManager:
    """Async checkpointing with retention."""

    def __init__(self, ckpt_dir: Path, keep: int = 3):
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save_async(self, step: int, tree: Any, extra=None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot off-device

        def work():
            save_checkpoint(self.dir, step, host_tree, extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.dir.glob("step_*"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
