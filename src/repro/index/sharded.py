"""Sharded mutable pHNSW index: P shard-local ``MutableIndex`` replicas
of the single-shard machinery behind one mutable, globally-addressed
front (DESIGN.md § Sharded serving).

* **Global id space.** ``gid = shard * stride + local`` with ``stride``
  = the uniform per-shard buffer capacity (a power of two). Owner
  lookup is a divide — no routing table to keep consistent.
* **Routing.** Deletes and replace-upserts go to the owner shard
  (owner-offset routing: ``gid // stride``); fresh inserts round-robin
  across shards (deterministic, keeps shards balanced so the
  fixed-shape per-shard search programs stay load-matched).
* **Publication.** Every mutation republishes a stacked ``ShardedDB``
  snapshot (leaves = per-shard device buffers stacked along a leading P
  dim) under a bumped ``epoch``. In steady state no leaf changes shape
  — same zero-recompile guarantee as the single-shard index; the
  non-steady-state events are the same two (capacity growth, a shard's
  top layer rising) plus their sharded twist: growth on ANY shard grows
  ALL shards (the stride must stay uniform) and RENUMBERS global ids.
  ``reserve()`` up front, exactly like ``MutableIndex``.
* **Compaction** is deliberately NOT auto-triggered (it would renumber
  one shard's local ids and corrupt the global id space mid-traffic);
  ``delete`` always runs shard-local ``auto_compact=False``.

Search runs through ``core/distributed.py``: ``shard_search_host`` on a
single device (simulated shards), ``distributed_search`` when a mesh is
provided — the two are bit-equal.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np
import jax.numpy as jnp

from repro.configs.base import PHNSWConfig
from repro.core.distributed import (ShardedDB, distributed_search,
                                    shard_bounds, shard_search_host)
from repro.core.filters import FilterSpec, make_filter
from repro.core.graph import build_hnsw
from repro.distributed import faults as _faults
from repro.index.mutable import (MutableIndex, read_snapshot,
                                 write_snapshot)
from repro.obs.trace import NULL_SPAN


class ShardedMutableIndex:
    """P shard-local mutable indexes + one stacked device snapshot."""

    def __init__(self, shards: Sequence[MutableIndex], filt: FilterSpec,
                 cfg: PHNSWConfig):
        assert len(shards) >= 1
        self.shards: List[MutableIndex] = list(shards)
        self.filt = filt
        self.cfg = cfg
        self.epoch = 0
        self._rr = 0                      # round-robin insert cursor
        self._align_capacity()
        self._publish()

    @classmethod
    def build(cls, x: np.ndarray, cfg: PHNSWConfig, n_shards: int, *,
              seed: int = 0, filt: Optional[FilterSpec] = None,
              builder: Optional[str] = None) -> "ShardedMutableIndex":
        """Fit ONE shared filter on the full dataset, partition
        (remainder distributed), and build each shard's graph + mutable
        index independently — through the one construction pipeline
        (``builder`` defaults to ``cfg.builder``, the wave pipeline;
        equal-sized shards reuse its compiled probe program, and the
        shard indexes' subsequent wave inserts share it too)."""
        filt = filt or make_filter(cfg, x, seed=seed)
        shards = []
        for s, (a, b) in enumerate(shard_bounds(len(x), n_shards)):
            g = build_hnsw(x[a:b], cfg, seed=seed + s, builder=builder)
            shards.append(MutableIndex.from_graph(g, filt,
                                                  seed=seed + 101 * s + 1))
        return cls(shards, filt, cfg)

    # ------------------------------------------------------------------
    # id space / aggregates
    # ------------------------------------------------------------------

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def stride(self) -> int:
        """Global-id stride = the uniform per-shard capacity. Changes
        only on capacity growth (which renumbers global ids)."""
        return self.shards[0].cap

    @property
    def n_live(self) -> int:
        return sum(s.n_live for s in self.shards)

    @property
    def tombstone_frac(self) -> float:
        n = sum(s.n for s in self.shards)
        return sum(s.n_deleted for s in self.shards) / max(n, 1)

    @property
    def sdb(self) -> ShardedDB:
        """The current epoch's stacked device snapshot."""
        return self._sdb

    def owner(self, gids: np.ndarray) -> np.ndarray:
        return np.asarray(gids, np.int64) // self.stride

    def live_global_ids(self) -> np.ndarray:
        """Global ids of live nodes across all shards, ascending."""
        return np.concatenate([s.live_ids() + i * self.stride
                               for i, s in enumerate(self.shards)])

    # uniform mutable-index surface (benchmarks/serving treat the two
    # index kinds interchangeably; ids are GLOBAL here)
    live_ids = live_global_ids

    def pca_drift(self) -> dict:
        """The WORST per-shard drift report (every shard shares one
        frozen filter, so any shard crossing the refit threshold means
        the global projection needs a refit), with the per-shard
        reports attached."""
        reps = [s.pca_drift() for s in self.shards]
        worst = max(reps, key=lambda r: r["drift"] or 0.0)
        return {**worst, "per_shard": reps}

    def live_ground_truth(self, q: np.ndarray, at: int) -> np.ndarray:
        """Exact top-``at`` over the global LIVE set, as GLOBAL ids."""
        from repro.data.vectors import brute_force_topk
        gids = self.live_global_ids()
        x = np.concatenate([s.x[s.live_ids()] for s in self.shards])
        return gids[brute_force_topk(x, q, at)]

    def is_deleted(self, gids: np.ndarray) -> np.ndarray:
        """Tombstone flags for global ids (pad slots count as deleted)."""
        gids = np.asarray(gids, np.int64)
        sh, loc = gids // self.stride, gids % self.stride
        return np.array([self.shards[int(s)].deleted[int(l)]
                         for s, l in zip(sh.ravel(), loc.ravel())],
                        bool).reshape(gids.shape)

    # ------------------------------------------------------------------
    # capacity / publication
    # ------------------------------------------------------------------

    def _align_capacity(self) -> None:
        cap = max(s.cap for s in self.shards)
        for s in self.shards:
            if s.cap < cap:
                s.reserve(cap)

    def reserve(self, per_shard_capacity: int) -> None:
        """Pre-grow EVERY shard (the stride must stay uniform): pay the
        one growth recompile + global-id renumbering now, before
        traffic."""
        for s in self.shards:
            s.reserve(per_shard_capacity)
        self._align_capacity()
        self._publish()

    def _publish(self, span=NULL_SPAN) -> None:
        """Stack the per-shard device snapshots into a new epoch's
        ShardedDB. Pure data movement — in steady state every leaf
        keeps its shape, so compiled search programs are reused. An
        installed ``FaultPlan``'s ``delay_swap`` event stretches the
        window between mutation and publication (readers keep the
        previous epoch — the swap stays atomic, just late; a trace span
        records the injected delay as a ``delay_swap`` event)."""
        pub = span.child("publish", epoch=self.epoch + 1)
        plan = _faults.active()
        if plan is not None:
            slept = plan.swap_delay_hook()
            if slept > 0.0:
                pub.event("delay_swap", seconds=slept)
        n_pub = max(s.top for s in self.shards) + 1
        per = [s.device_layers(n_pub) for s in self.shards]
        stride = self.stride
        Pn = self.n_shards
        self.epoch += 1
        self._sdb = ShardedDB(
            adj=[jnp.stack([adj[l] for adj, _ in per])
                 for l in range(n_pub)],
            packed_low=[jnp.stack([pck[l] for _, pck in per])
                        for l in range(n_pub)],
            low=jnp.stack([s._dev_low for s in self.shards]),
            high=jnp.stack([s._dev_high for s in self.shards]),
            entries=jnp.asarray([s.entry for s in self.shards],
                                jnp.int32),
            offsets=jnp.asarray([i * stride for i in range(Pn)],
                                jnp.int32),
            counts=jnp.asarray([stride] * Pn, jnp.int32),
            cfg=self.cfg,
            deleted=jnp.stack([s._dev_deleted for s in self.shards]),
            low2=None if self.shards[0]._dev_low2 is None else
            jnp.stack([s._dev_low2 for s in self.shards]),
            filter_kind=self.filt.kind,
        )
        pub.set(n_layers=n_pub)
        pub.end()

    # ------------------------------------------------------------------
    # mutation
    # ------------------------------------------------------------------

    def upsert(self, xs: np.ndarray,
               ids: Optional[np.ndarray] = None, *,
               span=NULL_SPAN) -> np.ndarray:
        """Insert vectors (with ``ids``: tombstone those global ids
        first — replace semantics). Fresh inserts round-robin across
        shards. Returns the new GLOBAL ids, aligned with ``xs``. If any
        shard had to grow, ALL shards grow and previously handed-out
        global ids are renumbered (reserve() up front to avoid).
        ``span`` records per-shard routing events and the publish."""
        if ids is not None:
            # publish once at the end — the intermediate post-delete
            # snapshot would never be served
            self._delete(ids, span=span)
        xs = np.asarray(xs, np.float32)
        Pn = self.n_shards
        assign = (self._rr + np.arange(len(xs))) % Pn
        self._rr = (self._rr + len(xs)) % Pn
        plan = _faults.active()
        locs = {}
        for s in range(Pn):
            m = assign == s
            if m.any():
                # a killed shard rejects its slice BEFORE any shard
                # state changes for it (typed ShardKilledError; slices
                # already applied to healthy shards stay applied — the
                # caller retries the batch or reroutes)
                if plan is not None:
                    plan.shard_mutation_hook(s)
                span.event("route_upsert", shard=s, n=int(m.sum()))
                locs[s] = (m, self.shards[s].upsert(xs[m]))
        # gids are computed AFTER the post-insert capacity alignment so
        # a mid-batch growth can't hand out ids under a stale stride
        self._align_capacity()
        stride = self.stride
        gids = np.empty(len(xs), np.int64)
        for s, (m, loc) in locs.items():
            gids[m] = s * stride + loc
        self._publish(span=span)
        return gids

    def delete(self, gids: np.ndarray, *, span=NULL_SPAN) -> int:
        """Tombstone global ids on their owner shards (owner-offset
        routing; idempotent, out-of-range ids ignored). Returns the
        number newly deleted. Never auto-compacts (compaction would
        renumber the global id space)."""
        n = self._delete(gids, span=span)
        if n:
            self._publish(span=span)
        return n

    def _delete(self, gids: np.ndarray, *, span=NULL_SPAN) -> int:
        """Shard-local tombstoning without the snapshot publish."""
        gids = np.atleast_1d(np.asarray(gids, np.int64))
        stride = self.stride
        plan = _faults.active()
        n = 0
        for s in range(self.n_shards):
            m = (gids >= 0) & (gids // stride == s)
            if m.any():
                if plan is not None:
                    plan.shard_mutation_hook(s)
                span.event("route_delete", shard=s, n=int(m.sum()))
                n += self.shards[s].delete(gids[m] % stride,
                                           auto_compact=False)
        return n

    # ------------------------------------------------------------------
    # snapshot (one npz for all shards — the replica-shipping unit)
    # ------------------------------------------------------------------

    def save(self, path) -> None:
        """Snapshot EVERY shard plus the global-id bookkeeping into one
        npz (per-shard arrays live under an ``s{i}_`` prefix), sealed
        by the same integrity envelope as ``MutableIndex.save`` — one
        file is the unit replica re-seeding ships."""
        arrays = {"n_shards": np.int64(self.n_shards),
                  "rr": np.int64(self._rr),
                  "sharded_epoch": np.int64(self.epoch)}
        for i, s in enumerate(self.shards):
            for k, v in s._snapshot_arrays().items():
                arrays[f"s{i}_{k}"] = v
        write_snapshot(path, arrays)

    @classmethod
    def load(cls, path, cfg: PHNSWConfig, *, seed: int = 0
             ) -> "ShardedMutableIndex":
        """Restore a ``save``d sharded index (typed
        ``SnapshotCorruptError`` on integrity failure). Per-shard rng
        seeds are re-derived exactly as ``build`` derives them, so a
        restored replica draws the same insert levels as one that
        lived through the same history from the same seed."""
        z = read_snapshot(path)
        Pn = int(z["n_shards"])
        shards = []
        for i in range(Pn):
            pre = f"s{i}_"
            zi = {k[len(pre):]: v for k, v in z.items()
                  if k.startswith(pre)}
            shards.append(MutableIndex._from_arrays(
                zi, cfg, seed=seed + 101 * i + 1))
        idx = cls(shards, shards[0].filt, cfg)
        idx._rr = int(z["rr"])
        idx.epoch = int(z["sharded_epoch"])
        return idx

    # ------------------------------------------------------------------
    # search
    # ------------------------------------------------------------------

    def search(self, queries: np.ndarray, *, mesh=None, **kw):
        """Batched sharded search over the current epoch: the mesh
        collective path when ``mesh`` is given, the bit-equal
        single-device loop otherwise. Returns ([B, ef0] dists, [B, ef0]
        GLOBAL ids)."""
        q = jnp.asarray(queries)
        if mesh is not None:
            return distributed_search(mesh, self._sdb, q, filt=self.filt,
                                      **kw)
        return shard_search_host(self._sdb, q, filt=self.filt, **kw)
