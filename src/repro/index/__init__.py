from repro.index.mutable import MutableIndex

__all__ = ["MutableIndex"]
