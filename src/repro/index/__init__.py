from repro.index.mutable import MutableIndex
from repro.index.sharded import ShardedMutableIndex

__all__ = ["MutableIndex", "ShardedMutableIndex"]
