"""Mutable pHNSW index: online upserts, tombstone deletes, compaction,
snapshot/restore — a living index on top of the packed layout-(3)
representation (DESIGN.md § Mutable index).

The paper builds its database once (C phase) and only accelerates
search; HNSW itself, though, is natively incremental (Malkov & Yashunin
Alg. 1 *is* the insert procedure). This module makes the device-resident
``PackedDB`` mutable without ever giving up the fixed-shape compiled
search program:

* **Capacity padding.** All buffers are allocated at a power-of-two
  capacity (``>= cfg.min_capacity``). Inserts fill pre-allocated slots;
  only when capacity is exhausted do the buffers double (one recompile
  per doubling, O(log N) ever). Pad slots have no adjacency (never
  traversed) and are additionally marked in the tombstone bitmap (never
  returned).
* **Batched insert.** Inserts run through the WAVE pipeline shared
  with the bulk builder (DESIGN.md § Construction pipeline): a new
  vector's ef_construction neighborhood is found ON DEVICE by the same
  fused S-phase kernels the serving path uses
  (``search_jax.probe_neighborhoods``), one probe per insert
  sub-batch, always padded to a fixed probe width; the host then links
  the whole batch at once with the vectorized diversity heuristic
  (``core/build.link_wave`` — an intra-wave distance block supplies
  batch peers the pre-batch snapshot cannot see), followed by an
  incremental layout-(3) refresh of exactly the adjacency rows that
  changed.
* **Tombstone deletes.** Deletes flip a bit in a word-packed bitmap that
  ships with the ``PackedDB``; deleted nodes keep routing traffic
  (traversed) but are excluded from results (never returned). Same
  shapes, same compiled program.
* **Compaction.** When tombstone density crosses
  ``cfg.compact_tombstone_frac``, the graph is repaired (each live
  node's dead neighbors are replaced by live 2-hop candidates under the
  diversity heuristic), ids are remapped dense, buffers reallocated at
  the shrunk capacity, and a PCA-drift report says whether the frozen
  projection still captures the live distribution.
* **Snapshot/restore.** The whole index (vectors, adjacency, levels,
  tombstones, PCA) round-trips through one ``.npz``.

Every mutation publishes a NEW ``PackedDB`` value under a bumped
``epoch`` — readers holding the previous epoch keep a consistent frozen
view (functional arrays), and serving swaps atomically.
"""
from __future__ import annotations

import zlib
from pathlib import Path
from typing import Dict, List, Optional, Sequence

import numpy as np
import jax.numpy as jnp

from repro.configs.base import PHNSWConfig
from repro.distributed.faults import SnapshotCorruptError
from repro.constants import INF
from repro.core.build import link_wave, pad_rows_pow2, pairwise_sq
from repro.core.filters import (CascadeFilter, FilterSpec,
                                IdentityFilter, PCAFilter, PQFilter,
                                make_filter)
from repro.core.graph import (HNSWGraph, _select_heuristic, build_hnsw,
                              sample_levels)
from repro.core.pca import PCA, fit_pca
from repro.core.pq import PQCodebook
from repro.core.search_jax import (PackedDB, PackedLayer, pack_bitmap,
                                   probe_neighborhoods, search_batched)


def _as_filter(f, cfg: PHNSWConfig) -> FilterSpec:
    """Adopt a bare ``PCA`` (the seed API) as a ``PCAFilter``."""
    if isinstance(f, PCA):
        return PCAFilter(f, low_dtype=cfg.low_dtype)
    return f


def _next_pow2(n: int, floor: int) -> int:
    """Smallest power of two >= max(n, floor, 32). The floor itself is
    rounded up to a power of two — a non-pow2 ``cfg.min_capacity`` must
    not break the capacity invariant (doubling preserves any stray
    factor, and the bitmap packing needs 32 | cap)."""
    cap = 32
    while cap < max(int(floor), n):
        cap *= 2
    return cap


# --------------------------------------------------------------------------
# snapshot integrity envelope (shared by MutableIndex and the sharded
# stacked snapshot; the safety rail under replica snapshot shipping)
# --------------------------------------------------------------------------

# bump on any change to the snapshot array schema; loads of a different
# version raise SnapshotCorruptError instead of mis-deserializing
SNAPSHOT_VERSION = 1


def snapshot_checksum(arrays: Dict[str, np.ndarray]) -> int:
    """Order-independent crc32 over every array's name, dtype, shape,
    and bytes (the ``checksum`` entry itself excluded)."""
    crc = 0
    for k in sorted(arrays):
        if k == "checksum":
            continue
        v = np.asarray(arrays[k])
        meta = f"{k}|{v.dtype.str}|{v.shape}".encode()
        crc = zlib.crc32(v.tobytes(), zlib.crc32(meta, crc))
    return crc & 0xFFFFFFFF


def write_snapshot(path, arrays: Dict[str, np.ndarray]) -> None:
    """One compressed npz with the integrity envelope
    (``format_version`` + content ``checksum``) stamped in. Honors an
    installed ``FaultPlan``'s truncate-snapshot event (post-write) so
    corruption-detection tests exercise the REAL file path."""
    from repro.distributed import faults as _faults
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    arrays = dict(arrays)
    arrays["format_version"] = np.int64(SNAPSHOT_VERSION)
    arrays["checksum"] = np.uint32(snapshot_checksum(arrays))
    np.savez_compressed(path, **arrays)
    plan = _faults.active()
    if plan is not None:
        plan.snapshot_hook(path)


def read_snapshot(path) -> Dict[str, np.ndarray]:
    """Load + verify an npz written by ``write_snapshot``. Raises the
    typed ``SnapshotCorruptError`` on an unreadable/truncated file, a
    missing envelope, a format-version mismatch, or a content checksum
    mismatch — never garbage-deserializes."""
    try:
        with np.load(path, allow_pickle=False) as z:
            arrays = {k: np.asarray(z[k]) for k in z.files}
    except OSError as e:
        raise SnapshotCorruptError(
            f"snapshot {path} is unreadable/truncated: {e}") from None
    except Exception as e:   # zlib/zip errors on partial members, etc.
        raise SnapshotCorruptError(
            f"snapshot {path} is unreadable/truncated "
            f"(failed to deserialize): {e}") from None
    if "format_version" not in arrays or "checksum" not in arrays:
        raise SnapshotCorruptError(
            f"snapshot {path} has no integrity envelope (pre-versioned "
            f"or foreign npz)")
    ver = int(arrays.pop("format_version"))
    if ver != SNAPSHOT_VERSION:
        raise SnapshotCorruptError(
            f"snapshot {path}: format version {ver} != supported "
            f"{SNAPSHOT_VERSION}")
    want = int(arrays.pop("checksum"))
    got = snapshot_checksum(
        {**arrays, "format_version": np.int64(ver)})
    if got != want:
        raise SnapshotCorruptError(
            f"snapshot {path}: checksum mismatch "
            f"(stored {want:#010x}, computed {got:#010x})")
    return arrays


# the engine's _tombstone_bit word layout has exactly one packer
# (core/search_jax.pack_bitmap); keep the historical local name
_pack_bitmap = pack_bitmap


# O(log N)-distinct-shape dirty-row padding, shared with the wave
# builder's incremental snapshot refresh (historical local name)
_pad_rows_pow2 = pad_rows_pow2


# The on-device neighborhood probe is the wave pipeline's device half,
# hoisted to core/search_jax.py (PR-5) — the wave builder and this
# module share ONE compiled program family (and one jit cache counter,
# which the zero-recompile tests read under the historical name).
_probe_jit = probe_neighborhoods


class MutableIndex:
    """Mutable pHNSW index over capacity-padded device buffers.

    Host-side numpy mirrors hold the authoritative graph; the device
    holds the packed layout-(3) snapshot published as ``self.db`` (a
    ``PackedDB``) under a monotonically increasing ``self.epoch``.
    """

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    def __init__(self, cfg: PHNSWConfig, pca: PCA, x: np.ndarray,
                 x_low: np.ndarray, levels: np.ndarray,
                 adj: Sequence[np.ndarray], entry: int,
                 deleted: Optional[np.ndarray] = None, *, seed: int = 0,
                 epoch: int = 0):
        """Build from UNPADDED arrays ([n] rows); pads to capacity and
        publishes. ``pca`` may be a bare ``PCA`` (the seed API) or any
        ``FilterSpec``; ``x_low`` is that filter's payload rows.
        Prefer the ``from_graph`` / ``build`` / ``load`` classmethods."""
        n = len(x)
        cap = _next_pow2(n, cfg.min_capacity)
        self.cfg = cfg
        self.filt = _as_filter(pca, cfg)
        # PCA convenience handle (drift checks, seed callers): the
        # PCAFilter's projection, or the cascade's mid-stage projection;
        # None for the other filter kinds
        self.pca = getattr(self.filt, "pca", None)
        self.n, self.cap = n, cap
        self.entry = int(entry)
        self.epoch = epoch
        self.rng = np.random.default_rng(seed)
        D, dl = x.shape[1], x_low.shape[1]
        self.x = np.zeros((cap, D), np.float32)
        self.x[:n] = x
        # host mirror of the filter payload (dtype is the filter's:
        # f32 low-dim rows for PCA, uint8 codes for PQ, width 0 for
        # identity); the name survives from the PCA-only engine
        self.x_low = np.zeros((cap, dl), self.filt.payload_dtype)
        self.x_low[:n] = x_low
        # the cascade's mid-stage side-car (PCA rows scored by the
        # promote pass) — recomputed from x, so compaction/restore need
        # no extra plumbing; None for single-stage filters
        self.x_mid: Optional[np.ndarray] = None
        if hasattr(self.filt, "encode_mid"):
            xm = self.filt.encode_mid(x)
            self.x_mid = np.zeros((cap, xm.shape[1]), np.float32)
            self.x_mid[:n] = xm
        self.levels = np.full(cap, -1, np.int64)
        self.levels[:n] = levels
        # tombstones: real deletions in [:n]; pad slots are born deleted
        self.deleted = np.ones(cap, bool)
        self.deleted[:n] = deleted[:n] if deleted is not None else False
        self.n_deleted = int(self.deleted[:n].sum())
        self.adj: List[np.ndarray] = []
        for l in range(cfg.n_layers):
            a = np.full((cap, cfg.degree(l)), -1, np.int32)
            if l < len(adj):
                a[:n] = adj[l][:n]
            self.adj.append(a)
        self.top = max(int(self.levels[:n].max()), 0)
        # old-id -> new-id map of the most recent compaction (None until
        # one happens); compaction renumbers the public id space
        self.last_remap: Optional[np.ndarray] = None
        # (layer, cap) -> empty device layer, for device_layers()
        self._empty_layers: Dict = {}
        self._publish_full()

    @classmethod
    def from_graph(cls, g: HNSWGraph, pca, *, seed: int = 0
                   ) -> "MutableIndex":
        """Adopt a one-shot ``build_hnsw`` graph as the mutable seed.
        ``pca``: a fitted ``PCA`` or any ``FilterSpec``."""
        filt = _as_filter(pca, g.cfg)
        x_low = filt.encode(g.x)
        return cls(g.cfg, filt, g.x, x_low, g.levels, g.layers, g.entry,
                   seed=seed)

    @classmethod
    def build(cls, x: np.ndarray, cfg: PHNSWConfig, *, seed: int = 0
              ) -> "MutableIndex":
        """Fit the configured filter + host-build the seed graph +
        adopt it."""
        filt = make_filter(cfg, x, seed=seed)
        g = build_hnsw(x, cfg, seed=seed)
        return cls.from_graph(g, filt, seed=seed + 1)

    # ------------------------------------------------------------------
    # device publication (epoch-versioned, functional)
    # ------------------------------------------------------------------

    def _packed_rows(self, layer: int, rows: np.ndarray) -> np.ndarray:
        """Layout-(3) inline-vector refresh for a set of adjacency rows:
        re-gather each row's neighbor low-dim vectors."""
        a = self.adj[layer][rows]                      # [R, M]
        safe = np.where(a >= 0, a, 0)
        packed = self.x_low[safe]                      # [R, M, dl]
        packed[a < 0] = 0.0
        return packed

    @property
    def _dev_payload_dtype(self):
        """Device storage dtype of the filter payload: cfg.low_dtype
        for PCA (the bf16 layout-(3) option), the payload's own dtype
        (uint8 codes / zero-width f32) otherwise."""
        if self.filt.kind == "pca":
            return jnp.dtype(self.cfg.low_dtype)
        return jnp.dtype(self.x_low.dtype)

    def device_layers(self, n_pub: int):
        """The published device layers padded with cached EMPTY layers
        (all -1 adjacency, zero payload) up to ``n_pub`` >= top+1 —
        shard stacking (index/sharded.py) needs uniform layer counts
        across shards whose top layers differ. An empty layer is inert:
        the entry has no neighbors there, so its while_loop exits after
        one popped-and-dropped iteration. Returns (adj list, packed
        list)."""
        adj, packed = list(self._dev_adj), list(self._dev_packed)
        for l in range(len(adj), n_pub):
            key = (l, self.cap)
            if key not in self._empty_layers:
                M = self.cfg.degree(l)
                pl = self._dev_low.shape[1]
                self._empty_layers[key] = (
                    jnp.full((self.cap, M), -1, jnp.int32),
                    jnp.zeros((self.cap, M, pl), self._dev_payload_dtype))
            a, p = self._empty_layers[key]
            adj.append(a)
            packed.append(p)
        return adj, packed

    def _publish_full(self) -> None:
        """Rebuild every device buffer (init / growth / compaction /
        top-layer change — anything that changes shapes or layer count)."""
        dt = self._dev_payload_dtype
        n_pub = self.top + 1
        all_rows = np.arange(self.cap)
        self._dev_adj = [jnp.asarray(self.adj[l]) for l in range(n_pub)]
        self._dev_packed = [jnp.asarray(self._packed_rows(l, all_rows), dt)
                            for l in range(n_pub)]
        self._dev_low = jnp.asarray(self.x_low, dt)
        self._dev_high = jnp.asarray(self.x)
        self._dev_deleted = jnp.asarray(_pack_bitmap(self.deleted))
        self._dev_low2 = None if self.x_mid is None \
            else jnp.asarray(self.x_mid)
        self._swap()

    def _publish_incremental(self, dirty: List[set], new_ids: np.ndarray,
                             deleted_ids: Optional[np.ndarray] = None
                             ) -> None:
        """Refresh only what changed: new vector rows, dirty adjacency
        rows (+ their inline packed payload), and exactly the tombstone
        words whose bits flipped (``new_ids`` clear their pad-slot bits;
        ``deleted_ids`` set theirs). Payload refresh is filter-generic:
        whatever rows the active filter owns (low-dim vectors, PQ
        codes) are re-gathered for the dirty adjacency rows."""
        dt = self._dev_payload_dtype
        if len(new_ids):
            rows = _pad_rows_pow2(np.asarray(new_ids))
            self._dev_high = self._dev_high.at[rows].set(
                jnp.asarray(self.x[rows]))
            self._dev_low = self._dev_low.at[rows].set(
                jnp.asarray(self.x_low[rows], dt))
            if self._dev_low2 is not None:
                self._dev_low2 = self._dev_low2.at[rows].set(
                    jnp.asarray(self.x_mid[rows]))
        for l in range(self.top + 1):
            if not dirty[l]:
                continue
            rows = _pad_rows_pow2(np.fromiter(sorted(dirty[l]), np.int64,
                                              len(dirty[l])))
            self._dev_adj[l] = self._dev_adj[l].at[rows].set(
                jnp.asarray(self.adj[l][rows]))
            self._dev_packed[l] = self._dev_packed[l].at[rows].set(
                jnp.asarray(self._packed_rows(l, rows), dt))
        changed = np.concatenate(
            [np.asarray(new_ids, np.int64),
             np.asarray(deleted_ids, np.int64)
             if deleted_ids is not None else np.empty(0, np.int64)])
        if len(changed):
            words = _pad_rows_pow2(np.unique(changed // 32))
            w_host = np.stack([
                _pack_bitmap(self.deleted[w * 32:(w + 1) * 32])[0]
                for w in words])
            self._dev_deleted = self._dev_deleted.at[words].set(
                jnp.asarray(w_host))
        self._swap()

    def _swap(self) -> None:
        """Atomically publish a new epoch's PackedDB (plain attribute
        assignment; previous epochs stay valid frozen views)."""
        layers = [PackedLayer(adj=a, packed_low=p)
                  for a, p in zip(self._dev_adj, self._dev_packed)]
        self.epoch += 1
        self._db = PackedDB(layers=layers, low=self._dev_low,
                            high=self._dev_high, entry=self.entry,
                            cfg=self.cfg, deleted=self._dev_deleted,
                            low2=self._dev_low2,
                            filter_kind=self.filt.kind)

    @property
    def db(self) -> PackedDB:
        """The current epoch's device snapshot."""
        return self._db

    @property
    def n_live(self) -> int:
        return self.n - self.n_deleted

    @property
    def tombstone_frac(self) -> float:
        return self.n_deleted / max(self.n, 1)

    def live_ids(self) -> np.ndarray:
        """Ids of live (allocated, non-tombstoned) nodes, ascending —
        the id space results are drawn from."""
        return np.nonzero(~self.deleted[:self.n])[0]

    def live_ground_truth(self, q: np.ndarray, at: int) -> np.ndarray:
        """Exact top-``at`` neighbors of each query over the LIVE set,
        as mutable-index ids ([len(q), at]) — the yardstick every
        recall-under-churn measurement shares."""
        from repro.data.vectors import brute_force_topk
        live = self.live_ids()
        return live[brute_force_topk(self.x[live], q, at)]

    # ------------------------------------------------------------------
    # upsert
    # ------------------------------------------------------------------

    def upsert(self, xs: np.ndarray,
               ids: Optional[np.ndarray] = None) -> np.ndarray:
        """Insert vectors; with ``ids`` given, tombstone those ids first
        (replace semantics). Returns the new internal ids."""
        if ids is not None:
            self.delete(ids, auto_compact=False)
        xs = np.asarray(xs, np.float32)
        out = []
        bb = self.cfg.insert_batch
        for i in range(0, len(xs), bb):
            out.append(self._insert_batch(xs[i:i + bb]))
        return np.concatenate(out) if out else np.empty(0, np.int64)

    def reserve(self, capacity: int) -> None:
        """Pre-grow buffers to ``capacity`` (rounded up to a power of
        two): pay the one growth recompile now, before traffic, instead
        of mid-upsert."""
        if capacity > self.cap:
            self._grow(capacity)
            self._publish_full()

    def _grow(self, need: int) -> None:
        new_cap = _next_pow2(need, self.cap * 2)
        pad = new_cap - self.cap
        self.x = np.concatenate(
            [self.x, np.zeros((pad, self.x.shape[1]), np.float32)])
        self.x_low = np.concatenate(
            [self.x_low, np.zeros((pad, self.x_low.shape[1]),
                                  self.x_low.dtype)])
        if self.x_mid is not None:
            self.x_mid = np.concatenate(
                [self.x_mid, np.zeros((pad, self.x_mid.shape[1]),
                                      np.float32)])
        self.levels = np.concatenate(
            [self.levels, np.full(pad, -1, np.int64)])
        self.deleted = np.concatenate([self.deleted, np.ones(pad, bool)])
        self.adj = [np.concatenate(
            [a, np.full((pad, a.shape[1]), -1, np.int32)])
            for a in self.adj]
        self.cap = new_cap

    def _insert_batch(self, xb: np.ndarray) -> np.ndarray:
        b = len(xb)
        grew = False
        if self.n + b > self.cap:
            self._grow(self.n + b)
            grew = True
        ids = np.arange(self.n, self.n + b)
        lvls = sample_levels(b, self.cfg, self.rng)
        xl = self.filt.encode(xb)

        # --- on-device neighborhood probe (pre-batch snapshot; padded
        # to the fixed probe width so the compiled program is reused) ---
        bb = self.cfg.insert_batch
        qx = xb
        if b < bb:
            qx = np.concatenate(
                [qx, np.broadcast_to(self.x[self.entry], (bb - b,
                                                          qx.shape[1]))])
        qprep = self.filt.prepare(qx)
        fd, fi = _probe_jit(self._db, jnp.asarray(qx),
                            jnp.asarray(qprep),
                            self.cfg.ef_construction,
                            self.cfg.ef_construction_k)
        # [Lpub, bb, efc] -> drop the pad lanes of an underfull batch
        fd = np.asarray(fd)[:, :b]
        fi = np.asarray(fi)[:, :b]

        # --- host state for the batch (before linking, so intra-wave
        # peers are visible as candidates) ---
        self.x[ids] = xb
        self.x_low[ids] = xl
        if self.x_mid is not None:
            self.x_mid[ids] = self.filt.encode_mid(xb)
        self.levels[ids] = lvls
        self.deleted[ids] = False
        self.n += b

        # --- vectorized wave linking (core/build.py): batched
        # diversity selection + bidirectional linking over the whole
        # batch; the intra-wave distance block supplies batch peers the
        # pre-batch probe snapshot cannot see ---
        block = pairwise_sq(xb, xb)
        np.fill_diagonal(block, INF)
        changed = link_wave(self.x, self.adj, ids, self.levels,
                            fd, fi, block, self.cfg)
        dirty: List[set] = [set(map(int, d)) for d in changed]
        wmax = int(lvls.max())
        top_changed = wmax > self.top
        if top_changed:
            self.top = wmax
            self.entry = int(ids[int(np.argmax(lvls == wmax))])

        if grew or top_changed:
            self._publish_full()
        else:
            self._publish_incremental(dirty, ids)
        return ids

    # ------------------------------------------------------------------
    # delete / compaction
    # ------------------------------------------------------------------

    def delete(self, ids: np.ndarray, *, auto_compact: bool = True) -> int:
        """Tombstone ids (idempotent; out-of-range ids — e.g. stale
        after a compaction shrank the id space — are ignored). The nodes
        keep routing traffic but never appear in results. Returns the
        number newly deleted; triggers compaction past
        ``cfg.compact_tombstone_frac``."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        ids = ids[(ids >= 0) & (ids < self.n)]
        ids = np.unique(ids[~self.deleted[ids]])
        if len(ids) == 0:
            return 0
        self.deleted[ids] = True
        self.n_deleted += len(ids)
        self._publish_incremental([set() for _ in self.adj],
                                  np.empty(0, np.int64),
                                  deleted_ids=ids)
        if auto_compact and \
                self.tombstone_frac >= self.cfg.compact_tombstone_frac:
            self.compact()
        return len(ids)

    def compact(self) -> dict:
        """Physically drop tombstoned nodes: splice live 2-hop candidates
        over dead neighbors (diversity heuristic), remap ids dense,
        reallocate at the shrunk power-of-two capacity, and re-publish.

        COMPACTION RENUMBERS THE ID SPACE: ids handed out before it are
        stale afterward. The report's ``"remap"`` array (old id -> new
        id, -1 for dropped) — also kept as ``self.last_remap`` — lets
        callers re-resolve any ids they hold; `delete()` ignores stale
        out-of-range ids rather than crashing.

        Returns a report including the remap and the PCA-drift check."""
        n_before, frac_before = self.n, self.tombstone_frac
        live = ~self.deleted[:self.n]
        n_live = int(live.sum())
        if n_live == 0:
            raise ValueError("compact() on a fully-deleted index")
        drift = self.pca_drift()

        # --- graph repair: replace dead neighbors with live 2-hop ---
        for l in range(self.top + 1):
            A = self.adj[l]
            deg = A.shape[1]
            has_dead = np.zeros(self.n, bool)
            valid = A[:self.n] >= 0
            safe = np.where(valid, A[:self.n], 0)
            has_dead[live] = (valid & self.deleted[safe])[live].any(axis=1)
            for i in np.nonzero(has_dead)[0]:
                nb = A[i][A[i] >= 0]
                keep = [int(e) for e in nb if not self.deleted[e]]
                cand = set(keep)
                for e in nb:
                    if self.deleted[e]:
                        for f in A[e][A[e] >= 0]:
                            f = int(f)
                            if f != i and not self.deleted[f]:
                                cand.add(f)
                if not cand:
                    A[i, :] = -1
                    continue
                cl = np.fromiter(cand, np.int64, len(cand))
                ds = np.sum((self.x[cl] - self.x[i]) ** 2, axis=1)
                ordered = sorted(zip(ds.tolist(), cl.tolist()))
                sel = _select_heuristic(self.x, ordered, deg)
                A[i, :] = -1
                A[i, :len(sel)] = sel

        # --- dense remap + reallocation ---
        remap = np.full(self.n, -1, np.int64)
        remap[live] = np.arange(n_live)
        x = self.x[:self.n][live]
        x_low = self.x_low[:self.n][live]
        levels = self.levels[:self.n][live]
        adj = []
        for l in range(self.cfg.n_layers):
            A = self.adj[l][:self.n][live]
            A = np.where(A >= 0, remap[np.where(A >= 0, A, 0)], -1)
            adj.append(A.astype(np.int32))
        lv_top = int(levels.max())
        entry_cands = np.nonzero(levels == lv_top)[0]
        self.__init__(self.cfg, self.filt, x, x_low, levels, adj,
                      int(entry_cands[0]), seed=int(
                          self.rng.integers(0, 2**31 - 1)),
                      epoch=self.epoch)
        self.last_remap = remap
        return {"n_before": n_before, "n_after": self.n,
                "tombstone_frac_before": frac_before,
                "capacity": self.cap, "remap": remap,
                "pca_drift": drift}

    def pca_drift(self) -> dict:
        """How much variance of the LIVE distribution the frozen
        projection still captures, vs. what it captured at fit time.
        A large drop means inserts moved the data manifold and the
        low-dim filter is losing selectivity — refit offline.
        Only meaningful for the PCA filter; other kinds report no
        drift (their refit criteria live elsewhere)."""
        if self.pca is None:
            return {"captured_live": None, "captured_fit": None,
                    "drift": 0.0, "refit_recommended": False,
                    "note": f"drift check n/a for filter "
                            f"{self.filt.kind!r}"}
        live = ~self.deleted[:self.n]
        xc = self.x[:self.n][live] - self.pca.mean
        tot = float((xc * xc).sum())
        proj = xc @ self.pca.components
        captured = float((proj * proj).sum()) / max(tot, 1e-12)
        fit = float(self.pca.explained.sum())
        return {"captured_live": captured, "captured_fit": fit,
                "drift": fit - captured,
                "refit_recommended": bool(
                    fit - captured > self.cfg.pca_drift_tol)}

    # ------------------------------------------------------------------
    # search / snapshot
    # ------------------------------------------------------------------

    def search(self, queries: np.ndarray, **kw):
        """Convenience: batched search over the current epoch."""
        return search_batched(self._db, jnp.asarray(queries),
                              filt=self.filt, **kw)

    def _snapshot_arrays(self) -> Dict[str, np.ndarray]:
        """The unpadded array schema of one index snapshot (shared by
        ``save`` and the sharded stacked snapshot, which stores one of
        these per shard under a prefix)."""
        fk = self.filt.kind
        filt_arrays = {}
        if fk == "pca":
            filt_arrays = dict(pca_mean=self.pca.mean,
                               pca_components=self.pca.components,
                               pca_explained=self.pca.explained)
        elif fk == "pq":
            filt_arrays = dict(pq_centroids=self.filt.cb.centroids)
        elif fk == "cascade":
            # both stages' parameters: the PQ traversal codebook AND
            # the PCA promote projection (x_mid is recomputed on load)
            filt_arrays = dict(pq_centroids=self.filt.cb.centroids,
                               pca_mean=self.pca.mean,
                               pca_components=self.pca.components,
                               pca_explained=self.pca.explained)
        return dict(
            n=np.int64(self.n), entry=np.int64(self.entry),
            epoch=np.int64(self.epoch),
            n_layers=np.int64(self.cfg.n_layers), filter_kind=fk,
            x=self.x[:self.n], x_low=self.x_low[:self.n],
            levels=self.levels[:self.n], deleted=self.deleted[:self.n],
            **filt_arrays,
            **{f"adj{l}": self.adj[l][:self.n]
               for l in range(self.cfg.n_layers)})

    def save(self, path) -> None:
        """Snapshot the whole index (graph + vectors + tombstones +
        filter payload + filter parameters) to one npz, under the
        integrity envelope (format version + content checksum) that
        ``load`` verifies."""
        write_snapshot(path, self._snapshot_arrays())

    @classmethod
    def _from_arrays(cls, z: Dict[str, np.ndarray], cfg: PHNSWConfig,
                     *, seed: int = 0) -> "MutableIndex":
        fk = str(z["filter_kind"]) if "filter_kind" in z else "pca"
        if fk == "pca":
            filt = PCAFilter(
                PCA(mean=z["pca_mean"], components=z["pca_components"],
                    explained=z["pca_explained"]),
                low_dtype=cfg.low_dtype)
        elif fk == "pq":
            filt = PQFilter(PQCodebook(centroids=z["pq_centroids"]))
        elif fk == "cascade":
            filt = CascadeFilter(
                PQCodebook(centroids=z["pq_centroids"]),
                PCA(mean=z["pca_mean"], components=z["pca_components"],
                    explained=z["pca_explained"]))
        else:
            filt = IdentityFilter(dim=z["x"].shape[1])
        n_layers = int(z["n_layers"])
        return cls(cfg, filt, z["x"], z["x_low"], z["levels"],
                   [z[f"adj{l}"] for l in range(n_layers)],
                   int(z["entry"]), deleted=z["deleted"], seed=seed,
                   epoch=int(z["epoch"]))

    @classmethod
    def load(cls, path, cfg: PHNSWConfig, *, seed: int = 0
             ) -> "MutableIndex":
        """Restore from ``save``'s npz. Raises ``SnapshotCorruptError``
        (typed, from ``repro.distributed.faults``) on a truncated,
        bit-flipped, envelope-less, or version-mismatched file."""
        return cls._from_arrays(read_snapshot(path), cfg, seed=seed)
