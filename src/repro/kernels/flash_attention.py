"""Flash attention (tiled, online-softmax) for train/prefill on TPU.

Grid: (B*H, Sq/bq, T/bk) with the KV axis innermost ("arbitrary"
semantics). Running max / sum / accumulator live in VMEM scratch and are
rescaled per KV block; the output block is written once, on the last KV
step. Causal + sliding-window masking is applied in-block; fully-masked
KV blocks are skipped with ``pl.when`` (their DMA still runs — a noted
TPU trade vs. a ragged grid).

Block sizes default to (128, 128) q x kv tiles with the head dim loaded
whole — MXU-aligned for head_dim in {64, 128, 256}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window: int, bq: int, bk: int, t_total: int,
                  s_total: int):
    kv_i = pl.program_id(2)
    q_i = pl.program_id(1)
    nk = pl.num_programs(2)

    @pl.when(kv_i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # absolute positions (q aligned to the END of the kv axis)
    q_pos = q_i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + (t_total - s_total)
    k_pos = kv_i * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    run = True
    if causal:   # static skip is impossible; runtime-skip fully-masked blocks
        run = (kv_i * bk) <= (q_i * bq + bq - 1 + (t_total - s_total))

    @pl.when(run if causal else True)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # [bq, d]
        k = k_ref[0].astype(jnp.float32)                 # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        lg = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * (q.shape[-1] ** -0.5)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= q_pos >= k_pos
        if window:
            mask &= (q_pos - k_pos) < window
        lg = jnp.where(mask, lg, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(lg, axis=-1, keepdims=True))
        p = jnp.exp(lg - m_new)                          # [bq, bk]
        scale = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * scale + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * scale + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kv_i == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = False):
    """q: [B, H, S, d]; k, v: [B, H, T, d] -> [B, H, S, d].
    S % bq == 0, T % bk == 0 (ops.py pads)."""
    B, H, S, d = q.shape
    T = k.shape[2]
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0
    qf = q.reshape(B * H, S, d)
    kf = k.reshape(B * H, T, d)
    vf = v.reshape(B * H, T, d)
    grid = (B * H, S // bq, T // bk)
    kernel = functools.partial(_flash_kernel, causal=causal, window=window,
                               bq=bq, bk=bk, t_total=T, s_total=S)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max  m
            pltpu.VMEM((bq, 1), jnp.float32),    # running sum  l
            pltpu.VMEM((bq, d), jnp.float32),    # accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, d)
