"""kSort.L — fully parallel comparison-matrix top-k (paper IV-B3,
Fig 3(c)).

This is the rare ASIC algorithm that transfers to TPU *verbatim*: the
hardware compares all pairs simultaneously and derives each element's
rank by counting '>' entries in its comparison-matrix row (7 cycles vs
120 for bubble sort). On TPU the [M, M] comparison matrix is one
broadcast compare on the VPU and the rank is a row-sum — no
data-dependent control flow, no sorting network. Ties break by index so
ranks form a permutation; the top-k extraction is a one-hot contraction
(rank == 0..k-1), which is MXU/VPU-friendly and avoids gathers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ksort_kernel(d_ref, val_ref, idx_ref, *, k: int):
    d = d_ref[...].astype(jnp.float32)                   # [bb, M]
    bb, M = d.shape
    ii = jax.lax.broadcasted_iota(jnp.int32, (M, M), 0)  # row index i
    jj = jax.lax.broadcasted_iota(jnp.int32, (M, M), 1)  # col index j
    gt = d[:, :, None] > d[:, None, :]
    eq = d[:, :, None] == d[:, None, :]
    cmp = gt | (eq & (ii > jj)[None])
    rank = jnp.sum(cmp.astype(jnp.int32), axis=-1)       # [bb, M]
    kk = jax.lax.broadcasted_iota(jnp.int32, (1, M, k), 2)
    onehot = rank[:, :, None] == kk                      # [bb, M, k]
    im = jax.lax.broadcasted_iota(jnp.int32, (1, M, k), 1)
    val_ref[...] = jnp.sum(jnp.where(onehot, d[:, :, None], 0.0), axis=1)
    idx_ref[...] = jnp.sum(jnp.where(onehot, im, 0), axis=1).astype(jnp.int32)


def ksort_l_pallas(d, k: int, *, block_b: int = 8, interpret: bool = False):
    """d: [B, M] -> (vals [B, k] asc, idx [B, k]). B % block_b == 0."""
    B, M = d.shape
    assert B % block_b == 0, (B, block_b)
    grid = (B // block_b,)
    kernel = lambda dr, vr, ir: _ksort_kernel(dr, vr, ir, k=k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, M), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
        ),
        interpret=interpret,
    )(d)
