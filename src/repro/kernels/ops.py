"""Public jit'd wrappers for the Pallas kernels.

Padding, block-size selection, and backend dispatch live here: on TPU the
kernels run compiled; anywhere else they run under ``interpret=True``
(the kernel body executes in Python on CPU — bit-faithful semantics, no
performance claim). ``REPRO_FORCE_PALLAS_INTERPRET=1`` forces interpret
mode for testing.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.constants import VALID_MAX  # noqa: F401  (re-export: callers
# of fused_expand test returned vals against this sentinel)
from repro.kernels import ref
from repro.kernels.dist_l import dist_l_pallas
from repro.kernels.ksort_l import ksort_l_pallas
from repro.kernels.dist_h import dist_h_pallas
from repro.kernels.fused_filter import fused_expand_pallas, fused_filter_pallas
from repro.kernels.merge_sorted import merge_sorted_pallas
from repro.kernels.pq_adc import pq_adc_expand_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.decode_attention import decode_attention_pallas


def _interpret() -> bool:
    if os.environ.get("REPRO_FORCE_PALLAS_INTERPRET"):
        return True
    return jax.default_backend() != "tpu"


def _use_ref() -> bool:
    """On non-TPU backends, route to the jnp oracles by default: interpret
    mode executes the kernel body in Python per grid step (correct but
    ~100x slower), which would dominate CPU tests/benchmarks. Set
    REPRO_FORCE_PALLAS_INTERPRET=1 to exercise the Pallas path on CPU
    (the kernel test suite does)."""
    if os.environ.get("REPRO_FORCE_PALLAS_INTERPRET"):
        return False
    impl = os.environ.get("REPRO_KERNEL_IMPL", "auto")
    if impl == "ref":
        return True
    if impl == "pallas":
        return False
    return jax.default_backend() != "tpu"


def _pad_batch(x, mult: int):
    B = x.shape[0]
    pad = (-B) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    return x, B


def _pick_block_b(B: int, row_elems: int, cap_elems: int = 1 << 20) -> int:
    """Every traversal kernel holds O(row_elems) VMEM per batch row
    (comparison matrices, neighbor blocks, ...); shrink the batch block
    until the per-block footprint fits under ``cap_elems`` elements."""
    bb = 8
    while bb > 1 and bb * row_elems > cap_elems:
        bb //= 2
    return bb


@jax.jit
def dist_l(x, q):
    """x: [B, M, dl]; q: [B, dl] -> [B, M] f32 squared distances."""
    if _use_ref():
        return ref.dist_l_ref(x, q)
    bb = _pick_block_b(x.shape[0], x.shape[1] * x.shape[2])
    xp, B = _pad_batch(x, bb)
    qp, _ = _pad_batch(q, bb)
    return dist_l_pallas(xp, qp, block_b=bb, interpret=_interpret())[:B]


@functools.partial(jax.jit, static_argnames=("k",))
def ksort_l(d, k: int):
    """d: [B, M] -> (vals [B, k] ascending, idx [B, k])."""
    if _use_ref():
        return ref.ksort_l_ref(d, k)
    bb = _pick_block_b(d.shape[0], d.shape[1] * d.shape[1])
    dp, B = _pad_batch(d, bb)
    v, i = ksort_l_pallas(dp, k, block_b=bb, interpret=_interpret())
    return v[:B], i[:B]


@jax.jit
def dist_h(x, q):
    """x: [B, K, D]; q: [B, D] -> [B, K] f32 squared distances."""
    if _use_ref():
        return ref.dist_h_ref(x, q)
    bb = _pick_block_b(x.shape[0], x.shape[1] * x.shape[2])
    xp, B = _pad_batch(x, bb)
    qp, _ = _pad_batch(q, bb)
    return dist_h_pallas(xp, qp, block_b=bb, interpret=_interpret())[:B]


@functools.partial(jax.jit, static_argnames=("k",))
def fused_filter(x, q, k: int):
    """pHNSW step 2: x [B, M, dl], q [B, dl] -> top-k (vals, idx)."""
    if _use_ref():
        return ref.fused_filter_ref(x, q, k)
    bb = _pick_block_b(x.shape[0],
                       x.shape[1] * (x.shape[1] + x.shape[2]))
    xp, B = _pad_batch(x, bb)
    qp, _ = _pad_batch(q, bb)
    v, i = fused_filter_pallas(xp, qp, k, block_b=bb,
                               interpret=_interpret())
    return v[:B], i[:B]


@functools.partial(jax.jit, static_argnames=("k",))
def fused_expand(x, q, valid, th, k: int):
    """One traversal expansion's full filter stage (Dist.L + validity
    mask + C_pca threshold + kSort.L) in a single kernel.
    x: [B, M, dl]; q: [B, dl]; valid: [B, M] bool; th: [B] f32.
    Returns (vals [B, k] ascending, idx [B, k]); filtered-out slots get
    vals >= constants.VALID_MAX."""
    if _use_ref():
        return ref.fused_expand_ref(x, q, valid, th, k)
    bb = _pick_block_b(x.shape[0],
                       x.shape[1] * (x.shape[1] + x.shape[2]))
    xp, B = _pad_batch(x, bb)
    qp, _ = _pad_batch(q, bb)
    vp, _ = _pad_batch(valid.astype(jnp.int32), bb)
    tp, _ = _pad_batch(th[:, None].astype(jnp.float32), bb)
    v, i = fused_expand_pallas(xp, qp, vp, tp, k, block_b=bb,
                               interpret=_interpret())
    return v[:B], i[:B]


@functools.partial(jax.jit, static_argnames=("k",))
def pq_adc_expand(codes, lut, valid, th, k: int):
    """One traversal expansion's PQ filter stage (ADC gather-accumulate
    + validity mask + C_pca threshold + kSort.L) in a single kernel —
    the PQ analogue of ``fused_expand``.
    codes: [B, M, S] integer PQ codes; lut: [B, S, 256] f32; valid:
    [B, M] bool; th: [B] f32. Returns (vals [B, k] ascending, idx
    [B, k]); filtered-out slots get vals >= constants.VALID_MAX."""
    if _use_ref():
        return ref.pq_adc_expand_ref(codes, lut, valid, th, k)
    B, M, S = codes.shape
    # the one-hot ADC contraction holds [bb, M, S, 256] in VMEM
    bb = _pick_block_b(B, M * S * 256 + M * M)
    cp, _ = _pad_batch(codes.astype(jnp.int32), bb)
    lp, _ = _pad_batch(lut.astype(jnp.float32), bb)
    vp, _ = _pad_batch(valid.astype(jnp.int32), bb)
    tp, _ = _pad_batch(th[:, None].astype(jnp.float32), bb)
    v, i = pq_adc_expand_pallas(cp, lp, vp, tp, k, block_b=bb,
                                interpret=_interpret())
    return v[:B], i[:B]


@jax.jit
def pq_adc(codes, lut):
    """Plain batched ADC distances (no mask/sort): codes [B, K, S],
    lut [B, S, 256] -> [B, K] f32. Used for entry-point scoring in
    deferred-rerank traversal; tiny, so it always runs the jnp oracle."""
    return ref.pq_adc_ref(codes, lut)


@functools.partial(jax.jit, static_argnames=("k",))
def merge_topk_sorted(d_a, i_a, d_b, i_b, k: int):
    """Merge two ascending-sorted (dist, idx) lists, keep the k smallest
    (ties -> a side, then lower slot). d_a: [B, Na]; d_b: [B, Nb]."""
    if d_b.shape[1] > k:
        # only the first k of a sorted b can reach a k-wide output
        d_b, i_b = d_b[:, :k], i_b[:, :k]
    if _use_ref():
        return ref.merge_topk_sorted_ref(d_a, i_a, d_b, i_b, k)
    Na, Nb = d_a.shape[1], d_b.shape[1]
    bb = _pick_block_b(d_a.shape[0], Na * Nb + k * (Na + Nb))
    dap, B = _pad_batch(d_a, bb)
    iap, _ = _pad_batch(i_a, bb)
    dbp, _ = _pad_batch(d_b, bb)
    ibp, _ = _pad_batch(i_b, bb)
    v, i = merge_sorted_pallas(dap, iap, dbp, ibp, k, block_b=bb,
                               interpret=_interpret())
    return v[:B], i[:B]


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128):
    """q: [B, H, S, d]; k, v: [B, H, T, d] -> [B, H, S, d]."""
    if _use_ref():
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  bq=bq, bk=bk, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("bk",))
def decode_attention(q, k, v, length, *, bk: int = 512):
    """q: [B, H, d]; k, v: [B, H, T, d]; length [B] -> [B, H, d]."""
    if _use_ref():
        return ref.decode_attention_ref(q, k, v, length)
    return decode_attention_pallas(q, k, v, length, bk=bk,
                                   interpret=_interpret())


# re-export the oracles for tests/benchmarks
refs = ref
