"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` is the definitional semantics; kernels must match it to
float tolerance under ``interpret=True`` (CPU) and on TPU. Property
tests in tests/test_kernels.py sweep shapes/dtypes against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# pHNSW kernels
# ---------------------------------------------------------------------------

def dist_l_ref(x, q):
    """Low-dim squared distances (paper Dist.L).
    x: [B, M, dl]; q: [B, dl] -> [B, M] float32."""
    d = x.astype(jnp.float32) - q.astype(jnp.float32)[:, None, :]
    return jnp.sum(d * d, axis=-1)


def ksort_l_ref(d, k: int, valid=None):
    """Comparison-matrix top-k (paper kSort.L): rank[i] = #{j : (d_j, j) <
    (d_i, i)}; the k smallest (dist, index) pairs, ascending.
    d: [B, M] -> (vals [B, k] f32, idx [B, k] i32). ``valid``: optional
    [B, M] bool mask; invalid entries sort last."""
    d = d.astype(jnp.float32)
    if valid is not None:
        d = jnp.where(valid, d, jnp.inf)
    B, M = d.shape
    lt = d[:, :, None] > d[:, None, :]                        # d_i > d_j
    eq = d[:, :, None] == d[:, None, :]
    idx_gt = jnp.arange(M)[:, None] > jnp.arange(M)[None, :]
    cmp = lt | (eq & idx_gt[None])
    rank = jnp.sum(cmp, axis=-1).astype(jnp.int32)            # [B, M]
    onehot = rank[:, :, None] == jnp.arange(k)[None, None, :]  # [B, M, k]
    vals = jnp.sum(jnp.where(onehot, d[:, :, None], 0.0), axis=1)
    idx = jnp.sum(jnp.where(onehot, jnp.arange(M)[None, :, None], 0),
                  axis=1).astype(jnp.int32)
    return vals, idx


def dist_h_ref(x, q):
    """High-dim re-rank distances (paper Dist.H).
    x: [B, K, D]; q: [B, D] -> [B, K] float32."""
    d = x.astype(jnp.float32) - q.astype(jnp.float32)[:, None, :]
    return jnp.sum(d * d, axis=-1)


def fused_filter_ref(x, q, k: int):
    """Fused Dist.L + kSort.L (one VMEM residency; pHNSW steps 2+filter).
    x: [B, M, dl]; q: [B, dl] -> (vals [B,k], idx [B,k])."""
    return ksort_l_ref(dist_l_ref(x, q), k)


# ---------------------------------------------------------------------------
# attention kernels
# ---------------------------------------------------------------------------

def flash_attention_ref(q, k, v, *, causal=True, window: int = 0):
    """q: [B, H, S, d]; k, v: [B, H, T, d] -> [B, H, S, d].
    Plain softmax attention; H == KV heads (GQA expansion by caller)."""
    S, T = q.shape[2], k.shape[2]
    scale = q.shape[-1] ** -0.5
    lg = jnp.einsum("bhsd,bhtd->bhst", q, k,
                    preferred_element_type=jnp.float32) * scale
    qpos = jnp.arange(S)[:, None] + (T - S)   # aligned at the end
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= (qpos - kpos) < window
    lg = jnp.where(mask[None, None], lg, NEG_INF)
    w = jax.nn.softmax(lg, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", w.astype(v.dtype), v)


def decode_attention_ref(q, k, v, length):
    """One-token decode. q: [B, H, d]; k, v: [B, H, T, d];
    length: [B] int32 (valid prefix) -> [B, H, d]."""
    scale = q.shape[-1] ** -0.5
    lg = jnp.einsum("bhd,bhtd->bht", q, k,
                    preferred_element_type=jnp.float32) * scale
    T = k.shape[2]
    mask = jnp.arange(T)[None, :] < length[:, None]           # [B, T]
    lg = jnp.where(mask[:, None, :], lg, NEG_INF)
    w = jax.nn.softmax(lg, axis=-1)
    return jnp.einsum("bht,bhtd->bhd", w.astype(v.dtype), v)
