"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` is the definitional semantics; kernels must match it to
float tolerance under ``interpret=True`` (CPU) and on TPU. Property
tests in tests/test_kernels.py sweep shapes/dtypes against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Sentinels live in repro.constants (shared with the engine); the
# re-export keeps ``ref.INF`` / ``ref.VALID_MAX`` spelling working for
# kernels and tests.
from repro.constants import INF, NEG_INF, VALID_MAX


# ---------------------------------------------------------------------------
# pHNSW kernels
# ---------------------------------------------------------------------------

def dist_l_ref(x, q):
    """Low-dim squared distances (paper Dist.L).
    x: [B, M, dl]; q: [B, dl] -> [B, M] float32."""
    d = x.astype(jnp.float32) - q.astype(jnp.float32)[:, None, :]
    return jnp.sum(d * d, axis=-1)


def ksort_l_ref(d, k: int, valid=None):
    """Comparison-matrix top-k (paper kSort.L): rank[i] = #{j : (d_j, j) <
    (d_i, i)}; the k smallest (dist, index) pairs, ascending.
    d: [B, M] -> (vals [B, k] f32, idx [B, k] i32). ``valid``: optional
    [B, M] bool mask; invalid entries sort last.

    Deliberately NOT a lax.sort: XLA lowers variadic sorts (and gathers)
    to scalar loops on CPU, while the O(M^2) compare + one-hot contract
    is pure vector code — measurably faster at every M this repo uses,
    on CPU and TPU both."""
    d = d.astype(jnp.float32)
    if valid is not None:
        d = jnp.where(valid, d, jnp.inf)
    B, M = d.shape
    lt = d[:, :, None] > d[:, None, :]                        # d_i > d_j
    eq = d[:, :, None] == d[:, None, :]
    idx_gt = jnp.arange(M)[:, None] > jnp.arange(M)[None, :]
    cmp = lt | (eq & idx_gt[None])
    rank = jnp.sum(cmp, axis=-1).astype(jnp.int32)            # [B, M]
    onehot = rank[:, :, None] == jnp.arange(k)[None, None, :]  # [B, M, k]
    vals = jnp.sum(jnp.where(onehot, d[:, :, None], 0.0), axis=1)
    idx = jnp.sum(jnp.where(onehot, jnp.arange(M)[None, :, None], 0),
                  axis=1).astype(jnp.int32)
    return vals, idx


def dist_h_ref(x, q):
    """High-dim re-rank distances (paper Dist.H).
    x: [B, K, D]; q: [B, D] -> [B, K] float32."""
    d = x.astype(jnp.float32) - q.astype(jnp.float32)[:, None, :]
    return jnp.sum(d * d, axis=-1)


def fused_filter_ref(x, q, k: int):
    """Fused Dist.L + kSort.L (one VMEM residency; pHNSW steps 2+filter).
    x: [B, M, dl]; q: [B, dl] -> (vals [B,k], idx [B,k])."""
    return ksort_l_ref(dist_l_ref(x, q), k)


def fused_expand_ref(x, q, valid, th, k: int):
    """The whole pHNSW expansion filter (step 2) in one op: Dist.L +
    adjacency/active masking + C_pca threshold + kSort.L.

    x: [B, M, dl] neighbor low-dim block; q: [B, dl]; valid: [B, M] bool
    (adjacency padding & per-query active mask); th: [B] f32 C_pca
    threshold. Returns (vals [B, k], idx [B, k]): the k nearest surviving
    neighbors ascending; non-survivors carry vals >= VALID_MAX."""
    d = dist_l_ref(x, q)
    d = jnp.where(valid & (d < th[:, None]), d, INF)
    return ksort_l_ref(d, k)


def pq_adc_ref(codes, lut):
    """Asymmetric-distance computation (the PQ filter's Dist.L):
    d[b, m] = sum_s lut[b, s, codes[b, m, s]].

    codes: [B, M, S] integer PQ codes; lut: [B, S, 256] f32 per-query
    ADC tables -> [B, M] f32 approximate squared distances. The oracle
    gathers (definitional); the Pallas kernel uses the gather-free
    one-hot contraction (masked 0.0 lanes never change the sum; only
    f32 association order can differ)."""
    B, M, S = codes.shape
    ct = jnp.transpose(codes.astype(jnp.int32), (0, 2, 1))     # [B, S, M]
    picked = jnp.take_along_axis(lut.astype(jnp.float32), ct, axis=2)
    return jnp.sum(jnp.transpose(picked, (0, 2, 1)), axis=-1)  # [B, M]


def pq_adc_expand_ref(codes, lut, valid, th, k: int):
    """The PQ filter's whole expansion step (ADC + adjacency/active
    masking + C_pca threshold + kSort.L) — the PQ analogue of
    ``fused_expand_ref``. codes: [B, M, S]; lut: [B, S, 256]; valid:
    [B, M] bool; th: [B] f32. Returns (vals [B, k] ascending, idx
    [B, k]); non-survivors carry vals >= VALID_MAX."""
    d = pq_adc_ref(codes, lut)
    d = jnp.where(valid & (d < th[:, None]), d, INF)
    return ksort_l_ref(d, k)


def merge_topk_sorted_ref(d_a, i_a, d_b, i_b, k: int):
    """Merge two ASCENDING-sorted (dist, idx) lists, keep the k smallest
    — the O((Na+k)·Nb) frontier merge (Nb = k small), vs concat +
    O((Na+Nb)^2) rank sort. Ties between lists resolve to the a side;
    within a list the lower slot wins, so the merge is a permutation and
    fully deterministic.

    d_a: [B, Na], d_b: [B, Nb] (each row ascending); k <= Na + Nb.
    Returns (d [B, k], i [B, k]) ascending."""
    d_a = d_a.astype(jnp.float32)
    d_b = d_b.astype(jnp.float32)
    B, Nb = d_b.shape
    Na = d_a.shape[1]
    # merged positions: pos_a[i] = i + #{j : b[j] < a[i]},
    #                   pos_b[j] = j + #{i : a[i] <= b[j]}
    pos_a = jnp.arange(Na, dtype=jnp.int32)[None, :] + jnp.sum(
        d_b[:, None, :] < d_a[:, :, None], axis=-1, dtype=jnp.int32)
    pos_b = jnp.arange(Nb, dtype=jnp.int32)[None, :] + jnp.sum(
        d_a[:, None, :] <= d_b[:, :, None], axis=-1, dtype=jnp.int32)
    # one-hot scatter into the k output slots (positions are unique;
    # gather-free on purpose — XLA CPU lowers gathers to scalar loops)
    out = jnp.arange(k, dtype=jnp.int32)[None, :, None]       # [1, k, 1]
    hot_a = pos_a[:, None, :] == out                          # [B, k, Na]
    hot_b = pos_b[:, None, :] == out                          # [B, k, Nb]
    d = jnp.sum(jnp.where(hot_a, d_a[:, None, :], 0.0), axis=-1) \
        + jnp.sum(jnp.where(hot_b, d_b[:, None, :], 0.0), axis=-1)
    i = jnp.sum(jnp.where(hot_a, i_a[:, None, :], 0), axis=-1) \
        + jnp.sum(jnp.where(hot_b, i_b[:, None, :], 0), axis=-1)
    return d, i.astype(jnp.int32)


# ---------------------------------------------------------------------------
# attention kernels
# ---------------------------------------------------------------------------

def flash_attention_ref(q, k, v, *, causal=True, window: int = 0):
    """q: [B, H, S, d]; k, v: [B, H, T, d] -> [B, H, S, d].
    Plain softmax attention; H == KV heads (GQA expansion by caller)."""
    S, T = q.shape[2], k.shape[2]
    scale = q.shape[-1] ** -0.5
    lg = jnp.einsum("bhsd,bhtd->bhst", q, k,
                    preferred_element_type=jnp.float32) * scale
    qpos = jnp.arange(S)[:, None] + (T - S)   # aligned at the end
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= (qpos - kpos) < window
    lg = jnp.where(mask[None, None], lg, NEG_INF)
    w = jax.nn.softmax(lg, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", w.astype(v.dtype), v)


def decode_attention_ref(q, k, v, length):
    """One-token decode. q: [B, H, d]; k, v: [B, H, T, d];
    length: [B] int32 (valid prefix) -> [B, H, d]."""
    scale = q.shape[-1] ** -0.5
    lg = jnp.einsum("bhd,bhtd->bht", q, k,
                    preferred_element_type=jnp.float32) * scale
    T = k.shape[2]
    mask = jnp.arange(T)[None, :] < length[:, None]           # [B, T]
    lg = jnp.where(mask[:, None, :], lg, NEG_INF)
    w = jax.nn.softmax(lg, axis=-1)
    return jnp.einsum("bht,bhtd->bhd", w.astype(v.dtype), v)
