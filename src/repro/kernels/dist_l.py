"""Dist.L — batched low-dimensional squared distances (paper IV-B3).

The ASIC computes 16 neighbor distances in parallel; on TPU the whole
neighbor block [block_b, M, dl] sits in VMEM and the VPU evaluates
|x - q|^2 with a vectorized reduction over dl. One grid step per
query-block; the packed layout (3) guarantees x rows are contiguous, so
each block arrives in a single HBM->VMEM DMA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dist_l_kernel(x_ref, q_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # [bb, M, dl]
    q = q_ref[...].astype(jnp.float32)          # [bb, dl]
    d = x - q[:, None, :]
    o_ref[...] = jnp.sum(d * d, axis=-1)


def dist_l_pallas(x, q, *, block_b: int = 8, interpret: bool = False):
    """x: [B, M, dl]; q: [B, dl] -> [B, M] float32. B % block_b == 0."""
    B, M, dl = x.shape
    assert B % block_b == 0, (B, block_b)
    grid = (B // block_b,)
    return pl.pallas_call(
        _dist_l_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, M, dl), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, dl), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, M), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, M), jnp.float32),
        interpret=interpret,
    )(x, q)
