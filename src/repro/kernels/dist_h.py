"""Dist.H — high-dimensional re-rank distances for the k filtered
candidates (paper step 3). The gather of the k candidates happens on the
host/XLA side (irregular HBM access is exactly what the algorithm
bounds to k); the kernel computes the [block_b, K, D] block's distances
in one VMEM residency.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dist_h_kernel(x_ref, q_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # [bb, K, D]
    q = q_ref[...].astype(jnp.float32)          # [bb, D]
    d = x - q[:, None, :]
    o_ref[...] = jnp.sum(d * d, axis=-1)


def dist_h_pallas(x, q, *, block_b: int = 8, interpret: bool = False):
    """x: [B, K, D]; q: [B, D] -> [B, K] float32."""
    B, K, D = x.shape
    assert B % block_b == 0, (B, block_b)
    return pl.pallas_call(
        _dist_h_kernel,
        grid=(B // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, K, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, D), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, K), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K), jnp.float32),
        interpret=interpret,
    )(x, q)
