"""Fused PQ ADC expand: asymmetric-distance gather-accumulate + mask +
C_pca threshold + kSort.L in a single VMEM residency.

The PQ filter's expansion step mirrors ``fused_filter.fused_expand``
with the dense low-dim Dist.L replaced by ADC: each neighbor carries
S uint8 codes, the query carries a per-subspace lookup table
``lut[S, 256]`` built once per query, and the filter distance is
``sum_s lut[s, codes[s]]``. TPUs have no VMEM gather, so the kernel
scores codes with a one-hot contraction against the 256 centroid slots
(`codes == iota(256)`), which is pure VPU element-wise work — the same
formulation trick as the comparison-matrix kSort.L (DESIGN.md). The
0.0-masked lanes never perturb an f32 sum, so the kernel matches the
gathering oracle (``ref.pq_adc_ref``) up to f32 summation order —
bit-equal on exactly-representable table values (asserted in
tests/test_kernels.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.constants import INF
from repro.kernels.fused_filter import ksort_block


def _pq_adc_expand_kernel(codes_ref, lut_ref, valid_ref, th_ref,
                          val_ref, idx_ref, *, k: int):
    codes = codes_ref[...].astype(jnp.int32)             # [bb, M, S]
    lut = lut_ref[...].astype(jnp.float32)               # [bb, S, 256]
    valid = valid_ref[...] != 0                          # [bb, M]
    th = th_ref[...].astype(jnp.float32)                 # [bb, 1]
    # -- ADC: one-hot gather-accumulate over the 256 centroid slots --
    cc = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, 256), 3)
    onehot = codes[:, :, :, None] == cc                  # [bb, M, S, 256]
    d = jnp.sum(jnp.where(onehot, lut[:, None, :, :], 0.0), axis=(2, 3))
    d = jnp.where(valid & (d < th), d, INF)              # filter
    val_ref[...], idx_ref[...] = ksort_block(d, k)       # kSort.L


def pq_adc_expand_pallas(codes, lut, valid, th, k: int, *,
                         block_b: int = 8, interpret: bool = False):
    """codes: [B, M, S] int32; lut: [B, S, 256] f32; valid: [B, M] int32
    (0/1); th: [B, 1] f32 -> (vals [B, k] ascending, idx [B, k]).
    Non-survivors get vals = INF."""
    B, M, S = codes.shape
    assert B % block_b == 0, (B, block_b)
    assert lut.shape == (B, S, 256), (lut.shape, codes.shape)
    kernel = lambda cr, lr, vr, tr, or_, ir: \
        _pq_adc_expand_kernel(cr, lr, vr, tr, or_, ir, k=k)
    return pl.pallas_call(
        kernel,
        grid=(B // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, M, S), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, S, 256), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, M), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
        ),
        interpret=interpret,
    )(codes, lut, valid, th)
