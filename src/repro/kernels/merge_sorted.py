"""Sorted-merge top-k — the O(ef+k) frontier merge.

The traversal keeps C (candidates), F (finals) and C_pca (filter
thresholds) as ASCENDING-sorted invariants, so folding one expansion
step's k new candidates into them is a two-list sorted merge, not a
re-sort. The previous implementation concatenated and ran the full
comparison-matrix rank sort over every slot — O((CAP+k)^2) compares per
merge, three merges per step. Here each element's merged position is its
own slot index plus its rank in the OTHER list (Na·Nb compares, Nb = k
small), and the k output slots are filled by a one-hot contraction —
no data-dependent gathers, so the same formulation compiles on the TPU
VPU and under interpret mode.

Tie-breaking matches the oracle: equal keys resolve to the a side, and
within a list to the lower slot, so merged positions form a permutation
and the output is bit-deterministic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _merge_kernel(da_ref, ia_ref, db_ref, ib_ref, vd_ref, vi_ref, *, k: int):
    d_a = da_ref[...].astype(jnp.float32)                # [bb, Na]
    d_b = db_ref[...].astype(jnp.float32)                # [bb, Nb]
    i_a = ia_ref[...]
    i_b = ib_ref[...]
    bb, Na = d_a.shape
    Nb = d_b.shape[1]
    # merged positions: pos_a[i] = i + #{j : b[j] < a[i]},
    #                   pos_b[j] = j + #{i : a[i] <= b[j]}
    ja = jax.lax.broadcasted_iota(jnp.int32, (1, Na), 1)
    jb = jax.lax.broadcasted_iota(jnp.int32, (1, Nb), 1)
    pos_a = ja + jnp.sum((d_b[:, None, :] < d_a[:, :, None])
                         .astype(jnp.int32), axis=-1)    # [bb, Na]
    pos_b = jb + jnp.sum((d_a[:, None, :] <= d_b[:, :, None])
                         .astype(jnp.int32), axis=-1)    # [bb, Nb]
    # one-hot scatter into the k output slots (positions are unique)
    ka = jax.lax.broadcasted_iota(jnp.int32, (1, k, Na), 1)
    kb = jax.lax.broadcasted_iota(jnp.int32, (1, k, Nb), 1)
    hot_a = pos_a[:, None, :] == ka                      # [bb, k, Na]
    hot_b = pos_b[:, None, :] == kb                      # [bb, k, Nb]
    vd_ref[...] = jnp.sum(jnp.where(hot_a, d_a[:, None, :], 0.0), axis=-1) \
        + jnp.sum(jnp.where(hot_b, d_b[:, None, :], 0.0), axis=-1)
    vi_ref[...] = (jnp.sum(jnp.where(hot_a, i_a[:, None, :], 0), axis=-1)
                   + jnp.sum(jnp.where(hot_b, i_b[:, None, :], 0), axis=-1)
                   ).astype(jnp.int32)


def merge_sorted_pallas(d_a, i_a, d_b, i_b, k: int, *, block_b: int = 8,
                        interpret: bool = False):
    """d_a: [B, Na], d_b: [B, Nb] ascending per row; k <= Na + Nb.
    Returns (d [B, k], i [B, k]) ascending. B % block_b == 0."""
    B, Na = d_a.shape
    Nb = d_b.shape[1]
    assert B % block_b == 0, (B, block_b)
    kernel = lambda dar, iar, dbr, ibr, vdr, vir: \
        _merge_kernel(dar, iar, dbr, ibr, vdr, vir, k=k)
    return pl.pallas_call(
        kernel,
        grid=(B // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, Na), lambda i: (i, 0)),
            pl.BlockSpec((block_b, Na), lambda i: (i, 0)),
            pl.BlockSpec((block_b, Nb), lambda i: (i, 0)),
            pl.BlockSpec((block_b, Nb), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
        ),
        interpret=interpret,
    )(d_a, i_a, d_b, i_b)
