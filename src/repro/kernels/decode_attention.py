"""Decode attention: one query token against a long KV cache, tiled over
the cache (flash-decoding style single-chip kernel; the cross-chip
sequence-parallel merge is GSPMD's job, see distributed/sharding.py).

Grid: (B*H, T/bk). The query row loads once per (b, h); KV blocks
stream through VMEM with online-softmax accumulation in scratch.
``length`` masks the valid cache prefix (SMEM scalar prefetch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, bk: int, n_batch_heads: int,
                   heads: int):
    bh = pl.program_id(0)
    kv_i = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(kv_i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    b = bh // heads
    length = len_ref[b]
    k_pos = kv_i * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)

    @pl.when(kv_i * bk < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # [1, d]
        k = k_ref[0].astype(jnp.float32)                  # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        lg = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * (q.shape[-1] ** -0.5)
        lg = jnp.where(k_pos < length, lg, NEG_INF)       # [1, bk]
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(lg, axis=-1, keepdims=True))
        p = jnp.exp(lg - m_new)
        scale = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * scale + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * scale + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kv_i == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_pallas(q, k, v, length, *, bk: int = 512,
                            interpret: bool = False):
    """q: [B, H, d]; k, v: [B, H, T, d]; length: [B] int32 -> [B, H, d]."""
    B, H, d = q.shape
    T = k.shape[2]
    bk = min(bk, T)
    assert T % bk == 0
    qf = q.reshape(B * H, 1, d)
    kf = k.reshape(B * H, T, d)
    vf = v.reshape(B * H, T, d)
    grid = (B * H, T // bk)
    kernel = functools.partial(_decode_kernel, bk=bk,
                               n_batch_heads=B * H, heads=H)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, d), lambda b, j, *_: (b, 0, 0)),
                pl.BlockSpec((1, bk, d), lambda b, j, *_: (b, j, 0)),
                pl.BlockSpec((1, bk, d), lambda b, j, *_: (b, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, d), lambda b, j, *_: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B * H, 1, d), q.dtype),
        interpret=interpret,
    )(length, qf, kf, vf)
    return out.reshape(B, H, d)
