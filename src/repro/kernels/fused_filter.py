"""Fused Dist.L + kSort.L (pHNSW step 2 in a single VMEM residency).

Beyond-paper optimization: the ASIC writes Dist.L results to registers
and feeds kSort.L; the XLA equivalent of running the two kernels
separately would round-trip the [B, M] distance matrix through HBM.
Fusing them keeps distances in VMEM — for the traversal loop this
removes 2 x B x M x 4 bytes of HBM traffic per expansion step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.constants import INF


def ksort_block(d, k: int):
    """The comparison-matrix kSort.L as a kernel-body building block:
    d [bb, M] -> (vals [bb, k] ascending, idx [bb, k]), ties -> lower
    index. One definition shared by every fused kernel (here and
    ``pq_adc.py``) — ``merge_topk_sorted``'s determinism depends on
    this exact (dist, index) lexicographic order, so there must be a
    single site to keep correct."""
    bb, M = d.shape
    ii = jax.lax.broadcasted_iota(jnp.int32, (M, M), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (M, M), 1)
    cmp = (d[:, :, None] > d[:, None, :]) \
        | ((d[:, :, None] == d[:, None, :]) & (ii > jj)[None])
    rank = jnp.sum(cmp.astype(jnp.int32), axis=-1)       # (kSort.L)
    kk = jax.lax.broadcasted_iota(jnp.int32, (1, M, k), 2)
    onehot = rank[:, :, None] == kk
    im = jax.lax.broadcasted_iota(jnp.int32, (1, M, k), 1)
    vals = jnp.sum(jnp.where(onehot, d[:, :, None], 0.0), axis=1)
    idx = jnp.sum(jnp.where(onehot, im, 0), axis=1).astype(jnp.int32)
    return vals, idx


def _fused_kernel(x_ref, q_ref, val_ref, idx_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)                   # [bb, M, dl]
    q = q_ref[...].astype(jnp.float32)                   # [bb, dl]
    diff = x - q[:, None, :]
    d = jnp.sum(diff * diff, axis=-1)                    # [bb, M] (Dist.L)
    val_ref[...], idx_ref[...] = ksort_block(d, k)


def fused_filter_pallas(x, q, k: int, *, block_b: int = 8,
                        interpret: bool = False):
    """x: [B, M, dl]; q: [B, dl] -> (vals [B, k], idx [B, k])."""
    B, M, dl = x.shape
    assert B % block_b == 0, (B, block_b)
    kernel = lambda xr, qr, vr, ir: _fused_kernel(xr, qr, vr, ir, k=k)
    return pl.pallas_call(
        kernel,
        grid=(B // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, M, dl), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, dl), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
        ),
        interpret=interpret,
    )(x, q)


# ---------------------------------------------------------------------------
# fused expand: the masked/thresholded variant used by the traversal loop
# ---------------------------------------------------------------------------

def _fused_expand_kernel(x_ref, q_ref, valid_ref, th_ref, val_ref, idx_ref,
                         *, k: int):
    """One expansion step's whole filter stage in a single VMEM
    residency: Dist.L, adjacency/active masking, the C_pca threshold
    compare, and the comparison-matrix kSort.L."""
    x = x_ref[...].astype(jnp.float32)                   # [bb, M, dl]
    q = q_ref[...].astype(jnp.float32)                   # [bb, dl]
    valid = valid_ref[...] != 0                          # [bb, M]
    th = th_ref[...].astype(jnp.float32)                 # [bb, 1]
    diff = x - q[:, None, :]
    d = jnp.sum(diff * diff, axis=-1)                    # Dist.L
    d = jnp.where(valid & (d < th), d, INF)              # filter
    val_ref[...], idx_ref[...] = ksort_block(d, k)       # kSort.L


def fused_expand_pallas(x, q, valid, th, k: int, *, block_b: int = 8,
                        interpret: bool = False):
    """x: [B, M, dl]; q: [B, dl]; valid: [B, M] int32 (0/1); th: [B, 1]
    f32 -> (vals [B, k], idx [B, k]). Non-survivors get vals = INF."""
    B, M, dl = x.shape
    assert B % block_b == 0, (B, block_b)
    kernel = lambda xr, qr, vr, tr, or_, ir: \
        _fused_expand_kernel(xr, qr, vr, tr, or_, ir, k=k)
    return pl.pallas_call(
        kernel,
        grid=(B // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, M, dl), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, dl), lambda i: (i, 0)),
            pl.BlockSpec((block_b, M), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
        ),
        interpret=interpret,
    )(x, q, valid, th)
