"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, smoke_config

_ARCH_MODULES = {
    "whisper-medium": "repro.configs.whisper_medium",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "llama3-405b": "repro.configs.llama3_405b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "qwen2-72b": "repro.configs.qwen2_72b",
    "internvl2-76b": "repro.configs.internvl2_76b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "rwkv6-1.6b": "repro.configs.rwkv6_1_6b",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(_ARCH_MODULES[arch])
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return smoke_config(get_config(arch))


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def all_cells():
    """Yield every (arch, shape) cell of the assignment (40 total)."""
    for arch in ARCH_IDS:
        for shape in SHAPES.values():
            yield arch, shape


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> str:
    """Classify a cell: 'native', 'retrieval' (runs via the paper's pHNSW
    retrieval attention), or 'skip:<reason>'."""
    if shape.name == "long_500k" and shape.kind == "decode":
        if cfg.sub_quadratic:
            return "native"
        return "retrieval"   # full-attention arch: paper technique makes it runnable
    return "native"
