"""Config system: model configs for the 10 assigned architectures + the
paper's own SIFT1M pHNSW config, and the input-shape suite.

Every architecture is selectable via ``--arch <id>`` in the launchers; the
registry in ``configs/registry.py`` maps ids to ``ModelConfig`` instances.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    experts_per_tok: int
    # router options
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class RetrievalConfig:
    """pHNSW retrieval-attention config (the paper's technique applied to
    long-context decode): PCA-project keys to ``d_low``, filter ``topk``
    candidates in low-dim space, exact attention over re-ranked set."""
    enabled: bool = False
    d_low: int = 16            # PCA dim (paper: 128 -> 15 for SIFT1M)
    topk: int = 128            # candidates kept after low-dim filter
    block: int = 128           # KV positions grouped per index entry
    # cache partitions: the filter is partition-LOCAL (top-k within each
    # partition, softmax-merged across) so a sequence-sharded cache never
    # gathers globally. Set to the number of cache shards on the
    # production mesh (data x model = 256 for batch-1 long-context).
    partitions: int = 256


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | encdec | vlm | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int                # 0 for attention-free families
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    mlp: str = "swiglu"         # swiglu | gelu | geglu | rwkv
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: int = 0             # sliding-window attention size; 0 = full
    moe: Optional[MoEConfig] = None
    # --- enc-dec (whisper) ---
    enc_layers: int = 0
    enc_frames: int = 1500      # stubbed audio frontend output length
    # --- vlm ---
    vis_tokens: int = 0         # stubbed patch-embedding count
    # --- hybrid (recurrentgemma): repeating block pattern ---
    pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    local_window: int = 2048
    lru_width: int = 0          # 0 -> d_model
    # --- retrieval attention (paper technique integration) ---
    retrieval: RetrievalConfig = field(default_factory=RetrievalConfig)
    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # attention implementation: "xla" (jnp, used for dry-run/CPU) or
    # "flash" (Pallas kernel, TPU target; interpret=True on CPU)
    attn_impl: str = "xla"
    remat: str = "full"         # full | none | dots
    # int8 KV cache (per-token-per-head absmax scales): halves decode
    # cache reads; dequant fuses into the decode kernel on TPU
    kv_quant: bool = False
    # parameter-sharding profile: "tp" = FSDP(data) x tensor-parallel
    # (model); "fsdp" = pure FSDP over (data x model) jointly, no TP —
    # wins when per-layer activation all-reduces exceed param gathers
    # (small d_model; see EXPERIMENTS.md §Perf)
    shard_profile: str = "tp"

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports long_500k natively (bounded state or
        bounded attention window)."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.window > 0:
            return True
        return self.retrieval.enabled

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND
        MODEL_FLOPS accounting."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":                      # rwkv6
            # time-mix: r,k,v,g,o ~ 5 d*d + decay loras; channel-mix ~ 2 d*f + d*d
            per_layer = 5 * d * d + 2 * d * f + d * d
        else:
            attn = d * self.n_heads * hd + 2 * d * self.kv_heads * hd \
                + self.n_heads * hd * d
            if self.moe is not None:
                mlp = self.moe.n_experts * 3 * d * f + d * self.moe.n_experts
            elif self.mlp in ("swiglu", "geglu"):
                mlp = 3 * d * f
            else:
                mlp = 2 * d * f
            per_layer = attn + mlp
            if self.family == "hybrid" and self.pattern:
                # mix of recurrent + attn blocks; recurrent block ~ 2*d*lru + lru*d + gates
                lru = self.lru_width or d
                rec = 2 * d * lru + lru * d + 2 * lru
                n_rec = sum(1 for p in self.pattern for _ in [p] if p == "rec")
                frac_rec = self.pattern.count("rec") / len(self.pattern)
                per_layer = frac_rec * (rec + mlp) + (1 - frac_rec) * (attn + mlp)
        total = emb + self.n_layers * per_layer
        if self.enc_layers:
            total += self.enc_layers * per_layer
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE uses experts_per_tok of n_experts)."""
        if self.moe is None:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        dense = self.n_params() - self.n_layers * self.moe.n_experts * 3 * d * f
        active = self.n_layers * self.moe.experts_per_tok * 3 * d * f
        return int(dense + active)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


# The assigned LM shape suite (applies to every architecture).
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: tiny depth/width/
    experts/vocab, same structural features (GQA ratio, MoE, pattern...)."""
    kw = dict(
        n_layers=2,
        d_model=64,
        d_ff=128,
        vocab=256,
        head_dim=16,
        enc_frames=8 if cfg.enc_layers else 1500,
        vis_tokens=4 if cfg.vis_tokens else 0,
        lru_width=64 if cfg.family == "hybrid" else 0,
        local_window=8,
        window=8 if cfg.window else 0,
        dtype="float32",
        remat="none",
    )
    if cfg.n_heads:
        kw["n_heads"] = 4
        kw["kv_heads"] = max(1, round(4 * cfg.kv_heads / cfg.n_heads))
    if cfg.enc_layers:
        kw["enc_layers"] = 2
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(n_experts=4,
                              experts_per_tok=min(2, cfg.moe.experts_per_tok))
    if cfg.pattern:
        kw["pattern"] = cfg.pattern
        kw["n_layers"] = 3   # one full pattern group
    if cfg.retrieval.enabled:
        kw["retrieval"] = RetrievalConfig(enabled=True, d_low=4, topk=8,
                                          block=8, partitions=2)
    return cfg.replace(**kw)


# ---------------------------------------------------------------------------
# pHNSW (the paper's own experiment) configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PHNSWConfig:
    """Configuration of the paper's SIFT1M experiment (Section V)."""
    name: str = "sift1m"
    n_points: int = 1_000_000
    dim: int = 128              # SIFT descriptor dim
    d_low: int = 15             # PCA dim (paper Step 1: 128 -> 15)
    n_layers: int = 6           # six-layer search graph
    M: int = 16                 # graph degree, layers 1..5
    M0: int = 32                # graph degree at layer 0 (2M)
    ef_upper: int = 1           # ef for layers 1..5
    ef0: int = 10               # ef for layer 0
    # per-layer top-k filter sizes (paper Section III-B):
    #   layers 2..5 -> 3 (3x ef per pKNN recommendation), layer1 -> 8,
    #   layer0 -> 16
    k_schedule: Tuple[int, ...] = (16, 8, 3, 3, 3, 3)
    ef_construction: int = 100
    recall_at: int = 10
    dtype: str = "float32"
    # ---- construction pipeline (core/build.py) ----
    # "wave": batched device-accelerated builder — insert in waves of
    # ``wave_size``, one fused-kernel beam search per wave against the
    # current snapshot, vectorized diversity selection + bidirectional
    # linking over the whole wave. "ref": the sequential host builder
    # (build_hnsw_ref), kept as the recall/structure oracle.
    builder: str = "wave"
    # vectors per construction wave. Larger waves amortize the per-wave
    # snapshot + probe overhead; smaller waves reduce snapshot staleness
    # (wave members probe a graph that predates the wave — the
    # intra-wave distance block covers wave-internal neighbors).
    wave_size: int = 2048
    # upper-layer beam width of the wave builder's device probe (layers
    # >= 1 mostly supply descent seeds; the sequential oracle descends
    # with ef=1, and M upper-layer links only need ~M candidates — the
    # intra-wave block supplements them). None = full ef_construction
    # at every layer. Does NOT apply to MutableIndex inserts (their
    # probe keeps the full beam).
    wave_ef_upper: Optional[int] = 16
    # ---- filter stage (core/filters.py) ----
    # which low-cost filter ranks candidates before (or instead of)
    # high-dim re-ranking: "pca" (the paper's dense low-dim projection),
    # "pq" (Flash-style product quantization, scored via an on-device
    # ADC gather-accumulate kernel), "cascade" (PQ-traverse →
    # PCA-promote → one deferred Dist.H pass: PQ-class inline bytes at
    # PCA-class recall; requires deferred_rerank), or "none" (filter
    # bypass: every neighbor goes straight to Dist.H — the HNSW-Std
    # behavior, kept as a first-class measured baseline)
    filter_kind: str = "pca"
    # PQ filter shape: n_sub subspaces x 256 centroids = n_sub bytes/vec
    pq_n_sub: int = 16
    pq_train_iters: int = 8
    # cascade promote stage: the layer-0 traversal keeps
    # promote_mult * ef0 PQ-space candidates, the PCA mid-stage score
    # (batched, once per layer-0 exit) trims them to rerank_mult * ef0
    # for the single final Dist.H pass. The PQ-recall recovery knob:
    # widen it until the promote pool covers what PQ ranking misses.
    promote_mult: int = 6
    # ---- re-ranking mode ----
    # "deferred" traverses purely on filter distances and re-ranks only
    # the final list in high dim: ONE batched Dist.H call per query
    # instead of k per expansion step. rerank_mult widens the layer-0
    # result list to rerank_mult * ef0 filter-space candidates before
    # that single re-rank — the recall-vs-Dist.H-traffic knob.
    deferred_rerank: bool = False
    rerank_mult: int = 3
    # storage dtype of the inline low-dim vectors in layout (3)
    # ("bfloat16" halves the dominant HBM stream and the paper's ~2.9x
    # memory blow-up; distances still accumulate in f32)
    low_dtype: str = "float32"
    # per-layer expansion-step budgets for the batched engine (layer 0
    # first). None -> the default linear-in-ef budget. Tune from the
    # steps_mean/steps_p99 telemetry in BENCH_table3.json: the batch
    # convoys on its slowest query, so capping tail steps trades a
    # bounded recall loss for wall-clock.
    step_budget: Optional[Tuple[int, ...]] = None
    # batched engine: expand the W nearest frontier candidates per loop
    # iteration (DESIGN.md). Exact w.r.t. the per-candidate expansion
    # rule (a popped candidate beyond F.max can never re-qualify) and
    # cuts while_loop trips ~W-fold, but widens every per-iteration
    # matrix ~W-fold — a win only where fixed per-iteration overhead
    # dominates element throughput (measured: not on CPU; revisit per
    # backend via BENCH_table3.json).
    expand_width: int = 1
    # ---- mutable index (src/repro/index/) ----
    # top-k width of the on-device ef_construction probe that finds a
    # new vector's neighborhood (wider than the serving k_schedule: the
    # construction beam needs breadth, not latency)
    ef_construction_k: int = 16
    # upserts are chunked into device probes of this many vectors
    insert_batch: int = 128
    # compact() auto-triggers when deleted/live crosses this fraction
    compact_tombstone_frac: float = 0.25
    # PCA-drift report flags a refit when the frozen projection captures
    # this much less variance on the live distribution than at fit time
    pca_drift_tol: float = 0.10
    # capacity floor for the power-of-two buffer growth schedule
    min_capacity: int = 1024

    def k_for_layer(self, layer: int) -> int:
        return self.k_schedule[min(layer, len(self.k_schedule) - 1)]

    def k_schedule_for(self, filter_kind: str,
                       deferred: bool) -> Tuple[int, ...]:
        """Effective default per-layer expansion k for a filter kind.
        The deferred CASCADE keeps ALL M0 neighbors at layer 0 (no
        kSort.L pruning): its in-loop distances are ~free ADC lookups,
        but 256-way sub-codebooks rank too coarsely for a tight
        per-step top-k — pruned edges are exactly how true neighbors
        become unreachable, and no promote/re-rank width can recover a
        node the traversal never visited. In per-step mode k also
        bounds the per-expansion Dist.H count, so the configured
        schedule stands there. An explicit ``k_schedule=`` argument to
        any search entry point overrides this default verbatim."""
        if deferred and filter_kind == "cascade":
            return (max(self.k_schedule[0], self.M0),) \
                + tuple(self.k_schedule[1:])
        return tuple(self.k_schedule)

    def ef_for_layer(self, layer: int) -> int:
        return self.ef0 if layer == 0 else self.ef_upper

    def degree(self, layer: int) -> int:
        return self.M0 if layer == 0 else self.M

    def max_steps_for_layer(self, layer: int) -> int:
        if self.step_budget is not None:
            return self.step_budget[min(layer, len(self.step_budget) - 1)]
        return 4 * self.ef_for_layer(layer) + 16
