"""mistral-nemo-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072, 128k context. [hf:mistralai/Mistral-Nemo-Base-2407]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    kv_heads=8,
    head_dim=128,         # Nemo uses head_dim=128 (not d_model/n_heads=160)
    d_ff=14336,
    vocab=131072,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=1e6,
)
