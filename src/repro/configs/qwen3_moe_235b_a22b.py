"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) d_ff=1536
(per expert) vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-*]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    kv_heads=4,
    head_dim=128,         # Qwen3 decouples head_dim from d_model/n_heads
    d_ff=1536,            # per-expert FFN width
    vocab=151936,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=1e6,
    moe=MoEConfig(n_experts=128, experts_per_tok=8),
)
