"""internvl2-76b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — InternViT frontend STUBBED (input_specs provides patch
embeddings); the LLM backbone (llama3-70b-like) is modeled in full.
[arXiv:2404.16821]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=5e5,
    vis_tokens=256,       # stubbed patch embeddings prepended to the text
)
