from repro.configs.base import (
    ModelConfig,
    MoEConfig,
    PHNSWConfig,
    RetrievalConfig,
    ShapeConfig,
    SHAPES,
    smoke_config,
)
from repro.configs.registry import (
    ARCH_IDS,
    all_cells,
    cell_supported,
    get_config,
    get_shape,
    get_smoke_config,
)

__all__ = [
    "ModelConfig", "MoEConfig", "PHNSWConfig", "RetrievalConfig",
    "ShapeConfig", "SHAPES", "smoke_config", "ARCH_IDS", "all_cells",
    "cell_supported", "get_config", "get_shape", "get_smoke_config",
]
