"""whisper-medium [audio]: 24L d_model=1024 16H (GQA kv=16) d_ff=4096
vocab=51865 — encoder-decoder, conv audio frontend stubbed (input_specs
provides precomputed frame embeddings). [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,          # decoder layers
    enc_layers=24,        # encoder layers
    d_model=1024,
    n_heads=16,
    kv_heads=16,          # MHA
    head_dim=64,
    d_ff=4096,
    vocab=51865,
    mlp="gelu",
    norm="layernorm",
    qkv_bias=True,
    enc_frames=1500,      # 30 s audio -> 1500 frames after the conv stub
    rope_theta=0.0,       # whisper uses learned/sinusoidal positions
    norm_eps=1e-5,
)
