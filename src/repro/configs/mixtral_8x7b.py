"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=32000,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=1e6,
    window=4096,          # SWA -> bounded KV cache -> native long_500k
    moe=MoEConfig(n_experts=8, experts_per_tok=2),
)
