"""rwkv6-1.6b [ssm] "Finch": 24L d_model=2048 (attention-free)
d_ff=7168 vocab=65536 — data-dependent decay linear attention.
O(1) recurrent state -> native long_500k. The paper's PCA-filtering
technique is inapplicable to the sequence mixer (no neighbor candidate
set to filter) — see DESIGN.md §Arch-applicability. [arXiv:2404.05892]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,           # time-mix heads (head_dim 64)
    kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab=65536,
    mlp="rwkv",           # channel-mix (relu^2 gated)
    norm="layernorm",
    norm_eps=1e-5,
)
