"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000 — RG-LRU recurrent blocks + local attention, 2:1 pattern
(rec, rec, attn). Sub-quadratic: bounded local window + O(1) recurrent
state -> native long_500k. [arXiv:2402.19427]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,          # 12 full (rec,rec,attn) groups + 2 trailing rec
    d_model=4096,
    n_heads=16,
    kv_heads=1,           # MQA in the local-attention blocks
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    mlp="geglu",
    norm="rmsnorm",
    pattern=("rec", "rec", "attn"),
    local_window=2048,
    lru_width=4096,
    rope_theta=10000.0,
)
