"""The paper's own experiment config: SIFT1M, six-layer HNSW graph,
PCA 128 -> 15, per-layer k schedule (16, 8, 3, 3, 3, 3), recall@10 target
0.92 (Section III-B / V-A)."""
from repro.configs.base import PHNSWConfig

CONFIG = PHNSWConfig(
    name="sift1m",
    n_points=1_000_000,
    dim=128,
    d_low=15,
    n_layers=6,
    M=16,
    M0=32,
    ef_upper=1,
    ef0=10,
    k_schedule=(16, 8, 3, 3, 3, 3),
    ef_construction=100,
    recall_at=10,
)

# Scaled-down variant used by CPU tests and benchmarks in this container
# (construction of the full 1M graph is minutes of numpy time; the scaled
# config preserves dims/degrees/k-schedule so algorithmic ratios hold).
SMALL = CONFIG_SMALL = PHNSWConfig(
    name="sift50k",
    n_points=50_000,
    dim=128,
    d_low=15,
    n_layers=6,
    M=16,
    M0=32,
    ef_upper=1,
    ef0=10,
    k_schedule=(16, 8, 3, 3, 3, 3),
    ef_construction=60,
    recall_at=10,
)
