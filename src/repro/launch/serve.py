"""Serving launcher: LM generation or pHNSW vector search.

  PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b --smoke
  PYTHONPATH=src python -m repro.launch.serve --vector --n-points 8000
"""
from __future__ import annotations

import argparse

import numpy as np
import jax
import jax.numpy as jnp


def serve_lm(args):
    from repro.configs import get_config, get_smoke_config
    from repro.data.tokens import synthetic_batch, batch_extras_for
    from repro.models import get_model
    from repro.serve.engine import GenerationEngine

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    api = get_model(cfg)
    params = api.init(jax.random.key(args.seed))
    eng = GenerationEngine(cfg, params, max_new=args.max_new,
                           temperature=args.temperature)
    batch = synthetic_batch(args.seed, 0, args.batch, args.prompt_len,
                            cfg.vocab, extras=batch_extras_for(cfg))
    batch.pop("labels")
    if "frames" in batch or "patches" in batch:
        for k in ("frames", "patches"):
            if k in batch:
                batch[k] = batch[k].astype(cfg.dtype)
    res = eng.generate({k: jnp.asarray(v) for k, v in batch.items()})
    print(f"[serve] batch={args.batch} prompt={args.prompt_len} "
          f"new={res.steps}: prefill {res.prefill_s:.2f}s, "
          f"decode {res.decode_s:.2f}s ({res.tokens_per_s:.1f} tok/s)")
    print(f"[serve] sample tokens: {res.tokens[0][:16].tolist()}")


def serve_vectors(args):
    from repro.configs.base import PHNSWConfig
    from repro.core.graph import cached_graph
    from repro.core.pca import fit_pca
    from repro.core.search_jax import build_packed
    from repro.data.vectors import make_sift_like, make_queries
    from repro.serve.vector_service import VectorSearchService

    cfg = PHNSWConfig(name=f"serve{args.n_points}", n_points=args.n_points,
                      ef_construction=60)
    x = make_sift_like(args.n_points)
    g = cached_graph(x, cfg, "experiments/data")
    pca = fit_pca(x, cfg.d_low)
    db = build_packed(g, pca.transform(x).astype(np.float32))
    svc = VectorSearchService(db, pca, batch_size=args.batch)
    queries = make_queries(x, args.n_queries)
    idx, stats = svc.run_stream(queries)
    print(f"[serve] {args.n_queries} queries: {stats['qps']:.0f} QPS, "
          f"p50 {stats['p50_ms']:.1f}ms, p99 {stats['p99_ms']:.1f}ms")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vector", action="store_true")
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--n-points", type=int, default=8000)
    ap.add_argument("--n-queries", type=int, default=256)
    args = ap.parse_args()
    if args.vector:
        serve_vectors(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
