"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import so these meshes can be built on the CPU container.

Production topology (TPU v5e pods):
  single pod:  (data=16, model=16)          = 256 chips
  multi pod:   (pod=2, data=16, model=16)   = 512 chips
``pod`` is the DCN axis (pure data parallel; optionally int8-compressed
gradient all-reduce), ``data`` is within-pod FSDP/batch, ``model`` is
tensor/expert parallel. Scaling to 1000+ nodes grows ``pod`` (the mesh
construction takes the pod count as a parameter).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False, n_pods: int = 2):
    if multi_pod:
        shape = (n_pods, 16, 16)
        axes = ("pod", "data", "model")
    else:
        shape = (16, 16)
        axes = ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)}; "
            "the dry-run entrypoint must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh():
    """1-device mesh for smoke tests / CPU examples."""
    return jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])
