import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax (device count is now locked at 512) ---
import argparse
import gzip
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.launch.hlo_cost import hlo_cost

from repro.configs import (ARCH_IDS, SHAPES, cell_supported, get_config,
                           get_shape)
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import lower_step

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


# --------------------------------------------------------------------------
# HLO collective accounting
# --------------------------------------------------------------------------

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|s32|s16|s8|u64|u32|u16|u8|pred)"
                       r"\[([0-9,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4,
          "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _BYTES[dtype]


def _split_computations(hlo: str):
    """Map computation name -> text block."""
    comps = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        # entry: `%name (args...) -> ret {`  or  `ENTRY %name ...{`
        m = re.match(r"\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->.*{", line)
        if m:
            if cur_name is not None:
                comps[cur_name] = cur_lines
            cur_name, cur_lines = m.group(1), []
        elif cur_name is not None:
            cur_lines.append(line)
            if line.strip() == "}":
                comps[cur_name] = cur_lines
                cur_name = None
    if cur_name is not None:
        comps[cur_name] = cur_lines
    return comps


def _direct_collective_bytes(lines):
    per_cat = {c: 0 for c in _COLLECTIVES}
    for line in lines:
        s = line.strip()
        for cat in _COLLECTIVES:
            # match the op use, e.g. `= bf16[...]{...} all-gather(` and
            # `all-gather-start(`; skip -done ops (no new data movement)
            if re.search(rf"\b{cat}(-start)?\(", s):
                # operand shapes: inside the call parens
                call = s.split(f"{cat}", 1)[1]
                shapes = _SHAPE_RE.findall(call)
                if not shapes:  # fall back to result shape
                    shapes = _SHAPE_RE.findall(s.split("=")[1])
                per_cat[cat] += sum(_shape_bytes(d, n) for d, n in shapes)
                break
    return per_cat


def _trip_count(cond_lines) -> int:
    """Heuristic scan trip count: the largest s32 constant compared in the
    while condition."""
    best = 1
    for line in cond_lines:
        for m in re.finditer(r"s32\[\]\s+constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


def collective_bytes(hlo: str):
    """Per-category collective bytes for one executed step, multiplying
    collectives inside while (scan) bodies by their trip count."""
    comps = _split_computations(hlo)
    # find while ops: body=%X, condition=%Y
    while_re = re.compile(r"body=%?([\w\.\-]+).*?condition=%?([\w\.\-]+)")
    memo = {}

    def total(comp_name):
        if comp_name in memo:
            return memo[comp_name]
        lines = comps.get(comp_name, [])
        per_cat = _direct_collective_bytes(lines)
        for line in lines:
            m = while_re.search(line)
            if m:
                body, cond = m.group(1), m.group(2)
                trips = _trip_count(comps.get(cond, []))
                sub = total(body)
                for c in _COLLECTIVES:
                    per_cat[c] += trips * sub[c]
        memo[comp_name] = per_cat
        return per_cat

    entry = None
    for line in hlo.splitlines():
        m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
        if m:
            entry = m.group(1)
            break
    if entry is None:
        # fall back: sum everything once
        return _direct_collective_bytes(hlo.splitlines())
    # also count calls (fusions/calls execute once; nested whiles handled)
    per_cat = total(entry)
    call_re = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
    seen = {entry}

    def add_calls(comp_name):
        for line in comps.get(comp_name, []):
            m = call_re.search(line)
            if m and m.group(1) not in seen:
                seen.add(m.group(1))
                sub = total(m.group(1))
                for c in _COLLECTIVES:
                    per_cat[c] += sub[c]
                add_calls(m.group(1))
    add_calls(entry)
    return per_cat


# --------------------------------------------------------------------------
# dry-run driver
# --------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             variant: str = "auto", out_dir: Path = RESULTS_DIR,
             profile: str = "tp") -> dict:
    shape = get_shape(shape_name)
    cfg = get_config(arch)
    support = cell_supported(cfg, shape)
    if variant == "auto":
        variant = support
    if variant == "retrieval":
        from repro.configs.base import RetrievalConfig
        # partitions = actual cache shards: (data x model) for batch=1
        # sequence sharding, model-only otherwise
        parts = 256 if shape.global_batch == 1 else 16
        cfg = cfg.replace(retrieval=RetrievalConfig(
            enabled=True, d_low=16, topk=2048, block=128, partitions=parts))
    if profile != "tp":
        cfg = cfg.replace(shard_profile=profile)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    if profile != "tp":
        mesh_name = f"{mesh_name}-{profile}"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "variant": variant, "profile": profile, "ok": False}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        lowered = lower_step(cfg, mesh, shape)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        mem = compiled.memory_analysis()
        if mem is not None:
            for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                         "output_size_in_bytes", "alias_size_in_bytes",
                         "generated_code_size_in_bytes"):
                v = getattr(mem, attr, None)
                if v is not None:
                    rec[attr] = int(v)
        cost = compiled.cost_analysis()
        if cost:
            rec["flops"] = float(cost.get("flops", -1))
            rec["bytes_accessed"] = float(cost.get("bytes accessed", -1))
            rec["transcendentals"] = float(cost.get("transcendentals", -1))
        hlo = compiled.as_text()
        # trip-count-aware per-chip costs (launch/hlo_cost.py): XLA's own
        # cost_analysis counts scan bodies once, so these are the numbers
        # the roofline uses.
        wc = hlo_cost(hlo)
        rec["walker_flops"] = wc.flops
        rec["walker_dot_bytes"] = wc.dot_bytes
        rec["walker_collectives"] = wc.collective
        rec["collectives"] = collective_bytes(hlo)   # legacy parser
        rec["collective_bytes_total"] = int(wc.collective_bytes)
        rec["hlo_bytes"] = len(hlo)
        out_dir.mkdir(parents=True, exist_ok=True)
        gz = out_dir / f"{arch}__{shape_name}__{mesh_name}.hlo.txt.gz"
        gz.write_bytes(gzip.compress(hlo.encode()))
        rec["ok"] = True
        print(compiled.memory_analysis())
    except Exception as e:  # record the failure; the sweep continues
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 1)
    out_dir.mkdir(parents=True, exist_ok=True)
    out = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
    out.write_text(json.dumps(rec, indent=1))
    status = "OK" if rec["ok"] else f"FAIL ({rec.get('error', '?')[:120]})"
    print(f"[dryrun] {arch} x {shape_name} x {mesh_name} [{variant}]: "
          f"{status} ({rec['total_s']}s)", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--variant", default="auto",
                    choices=["auto", "native", "retrieval"])
    ap.add_argument("--profile", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells whose JSON already reports ok")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}
    n_ok = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes[args.mesh]:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                if args.profile != "tp":
                    mesh_name = f"{mesh_name}-{args.profile}"
                f = RESULTS_DIR / f"{arch}__{shape}__{mesh_name}.json"
                if args.skip_done and f.exists():
                    try:
                        if json.loads(f.read_text()).get("ok"):
                            print(f"[dryrun] skip done: {f.name}", flush=True)
                            continue
                    except Exception:
                        pass
                rec = run_cell(arch, shape, mp, args.variant,
                               profile=args.profile)
                n_ok += rec["ok"]
                n_fail += not rec["ok"]
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed", flush=True)


if __name__ == "__main__":
    main()
