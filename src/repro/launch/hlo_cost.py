"""Trip-count-aware cost extraction from compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE — a
``lax.scan`` over 126 layers contributes a single layer's FLOPs, so
roofline terms derived from it understate per-step work by the loop trip
count. This walker recomputes costs with loop multiplication:

  cost(comp) = direct ops in comp
             + trips(while) * cost(body)     for each while op
               (trips from the while op's backend_config
                known_trip_count, falling back to the largest s32
                constant in the condition computation)
             + cost(callee)                  for each call site
               (fusion calls=, reduce/map/scatter to_apply=, etc.)

Direct ops counted (operand shapes resolved through a per-computation
symbol table, since scheduled HLO references operands by name):
  * ``dot``: FLOPs = 2 x prod(result) x prod(lhs contracting dims);
    bytes = operand + result sizes. Dots dominate both compute and HBM
    traffic for every assigned architecture (attention einsums read the
    KV cache; matmuls read the weights); elementwise traffic is NOT
    counted — the memory term is a documented lower bound.
  * collectives (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute): operand bytes, per category.

All shapes in the per-device HLO are per-shard, so every number is
per-chip per-step.
"""
from __future__ import annotations

import gzip
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Tuple

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4,
          "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
          "pred": 1, "c64": 8, "c128": 16}
_DTYPES = "|".join(_BYTES)
_DEF_RE = re.compile(
    rf"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*({_DTYPES})\[([0-9,]*)\]")
_OPND_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_TRIP_RE = re.compile(r'known_trip_count["\\]*:\s*\{["\\]*n["\\]*:\s*(\d+)')
_WHILE_RE = re.compile(r"\bwhile\(")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply|branch_computations)="
                      r"\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")


def _dims(dimstr: str) -> List[int]:
    return [int(d) for d in dimstr.split(",") if d]


def _numel(dimstr: str) -> int:
    n = 1
    for d in _dims(dimstr):
        n *= d
    return n


@dataclass
class Cost:
    flops: float = 0.0
    dot_bytes: float = 0.0
    collective: Dict[str, float] = field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.dot_bytes += mult * other.dot_bytes
        for c in _COLLECTIVES:
            self.collective[c] += mult * other.collective[c]

    @property
    def collective_bytes(self) -> float:
        return sum(self.collective.values())

    def as_dict(self) -> Dict:
        return {"flops": self.flops, "dot_bytes": self.dot_bytes,
                "collective_bytes": self.collective_bytes,
                "collective": dict(self.collective)}


def split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur, lines = None, []
    for line in hlo.splitlines():
        m = re.match(r"\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$",
                     line)
        if m:
            if cur is not None:
                comps[cur] = lines
            cur, lines = m.group(1), []
        elif cur is not None:
            lines.append(line)
            if line.strip() == "}":
                comps[cur] = lines
                cur = None
    if cur is not None:
        comps[cur] = lines
    return comps


def _entry_name(hlo: str) -> Optional[str]:
    for line in hlo.splitlines():
        m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
        if m:
            return m.group(1)
    return None


_OPKIND_RE = re.compile(
    rf"^\s*(?:ROOT\s+)?%[\w\.\-]+\s*=\s*(?:{_DTYPES})\[[0-9,]*\]\S*\s+"
    r"([\w\-]+)\(")
_GTE_IDX_RE = re.compile(r"index=(\d+)")


def _symbols(lines: List[str]) -> Dict[str, Tuple[str, str, str,
                                                  Optional[str],
                                                  Optional[int]]]:
    """name -> (dtype, dims, opkind, first_operand, gte_index)."""
    table = {}
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        mk = _OPKIND_RE.match(line)
        kind = mk.group(1) if mk else ""
        first = None
        if mk:
            rest = line.split(f"{kind}(", 1)[1]
            mo = _OPND_RE.search(rest.split(")")[0])
            if mo:
                first = mo.group(1)
        gte = None
        if kind == "get-tuple-element":
            mi = _GTE_IDX_RE.search(line)
            if mi:
                gte = int(mi.group(1))
        table[m.group(1)] = (m.group(2), m.group(3), kind, first, gte)
    return table


_PASSTHRU = {"convert", "copy", "bitcast", "all-gather", "transpose",
             "reshape", "fusion", "dynamic-slice"}


class Resolver:
    """Origin-dtype resolution across computation boundaries: XLA:CPU
    widens bf16 dot operands to f32 (no native bf16 GEMM) and hoists the
    convert out of scan loops, so the f32 origin may be a while-carry
    element; on the TPU target those values stay bf16. We chase
    convert/copy/gather chains, and hop get-tuple-element(body param, i)
    to operand i of the parent's while-init tuple."""

    def __init__(self, comps: Dict[str, List[str]]):
        self.comps = comps
        self.syms = {n: _symbols(l) for n, l in comps.items()}
        # body comp -> (parent comp, while tuple-operand names)
        self.body_parent: Dict[str, Tuple[str, List[str]]] = {}
        for parent, lines in comps.items():
            for line in lines:
                if not _WHILE_RE.search(line):
                    continue
                mb = _BODY_RE.search(line)
                if not mb:
                    continue
                # while operand: a tuple var (tuple-shaped defs aren't in
                # the symbol table; parse the def line textually)
                args = _op_args(line.strip(), "while")
                elems: List[str] = []
                if len(args) == 1:
                    for l2 in comps[parent]:
                        s2 = l2.strip()
                        if f"%{args[0]} = " in s2 and " tuple(" in s2:
                            elems = _op_args(s2, "tuple")
                            break
                self.body_parent[mb.group(1)] = (parent, elems)

    def consumed_as_bf16(self, comp: str, name: str) -> bool:
        """True if %name's only array-typed uses flow into bf16-producing
        defs (convert/fusion) — i.e. the f32 is a CPU-backend artifact."""
        lines = self.comps.get(comp, [])
        uses = 0
        bf16_uses = 0
        pat = f"%{name}"
        for line in lines:
            s = line.strip()
            m = _DEF_RE.match(s)
            if m is None or m.group(1) == name:
                continue
            # operand appears in this def?
            if pat + ")" in s or pat + "," in s or pat + " " in s:
                uses += 1
                if m.group(2) == "bf16":
                    bf16_uses += 1
        return uses > 0 and uses == bf16_uses

    def origin_is_bf16(self, comp: str, name: str, hops: int = 0) -> bool:
        if hops > 10:
            return False
        syms = self.syms.get(comp, {})
        e = syms.get(name)
        if e is None:
            return False
        dt, _, kind, first, gte = e
        if dt == "bf16":
            return True
        if kind == "get-tuple-element" and gte is not None \
                and first not in syms:
            # tuple is the computation's parameter: hop to the parent's
            # while-init tuple element
            pb = self.body_parent.get(comp)
            if pb and gte < len(pb[1]):
                return self.origin_is_bf16(pb[0], pb[1][gte], hops + 1)
            return False
        if kind in _PASSTHRU | {"get-tuple-element"} and first is not None:
            return self.origin_is_bf16(comp, first, hops + 1)
        return False


def _effective_bytes(name: str, syms, resolver: Optional["Resolver"] = None,
                     comp: str = "") -> float:
    if name not in syms:
        return 0.0
    dt, dims = syms[name][0], syms[name][1]
    elems = _numel(dims)
    if dt == "f32" and resolver is not None \
            and resolver.origin_is_bf16(comp, name):
        return elems * 2
    return elems * _BYTES[dt]


def _op_args(s: str, opname: str) -> List[str]:
    """Operand names inside 'opname(...)' (first level)."""
    try:
        inner = s.split(f" {opname}(", 1)[1]
    except IndexError:
        return []
    depth, out, cur = 1, [], []
    for ch in inner:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                out.append("".join(cur))
                break
        elif ch == "," and depth == 1:
            out.append("".join(cur))
            cur = []
            continue
        cur.append(ch)
    names = []
    for frag in out:
        m = _OPND_RE.search(frag)
        if m:
            names.append(m.group(1))
    return names


def _direct_cost(lines: List[str], syms, resolver: Optional[Resolver] = None,
                 comp: str = "") -> Cost:
    c = Cost()
    for line in lines:
        s = line.strip()
        mdef = _DEF_RE.match(s)
        if " dot(" in s and mdef:
            out_dt, out_dims = mdef.group(2), mdef.group(3)
            out_elems = _numel(out_dims)
            ops = _op_args(s, "dot")
            contract = 1
            mcon = _CONTRACT_RE.search(s)
            if mcon and ops and ops[0] in syms:
                lhs_dims = _dims(syms[ops[0]][1])
                for d in _dims(mcon.group(1)):
                    if d < len(lhs_dims):
                        contract *= lhs_dims[d]
            out_bytes = out_elems * _BYTES[out_dt]
            if out_dt == "f32" and resolver is not None and all(
                    resolver.origin_is_bf16(comp, n) for n in ops[:2]
                    if n in syms):
                # f32 dot fed by bf16-origin operands -> bf16 on TPU
                out_bytes = out_elems * 2
            c.flops += 2.0 * out_elems * contract
            c.dot_bytes += out_bytes
            for name in ops[:2]:
                c.dot_bytes += _effective_bytes(name, syms, resolver, comp)
            continue
        for cat in _COLLECTIVES:
            if re.search(rf"\b{cat}(-start)?\(", s):
                op_label = cat + ("-start" if f"{cat}-start(" in s else "")
                ops = _op_args(s, op_label)
                total = sum(_effective_bytes(n, syms, resolver, comp)
                            for n in ops)
                if total == 0 and mdef:   # fall back to result shape
                    total = _numel(mdef.group(3)) * _BYTES[mdef.group(2)]
                # JAX-level dtype correction: XLA:CPU reduces raw f32 dot
                # outputs; if this op's result is immediately narrowed to
                # bf16, the TPU target reduces bf16 -> halve the bytes.
                if mdef and mdef.group(2) == "f32" and resolver is not None \
                        and resolver.consumed_as_bf16(comp, mdef.group(1)):
                    total *= 0.5
                c.collective[cat] += total
                break
    return c


def _trips(s: str, comps, fallback_cond: Optional[str]) -> int:
    m = _TRIP_RE.search(s)
    if m:
        return int(m.group(1))
    best = 1
    if fallback_cond and fallback_cond in comps:
        for line in comps[fallback_cond]:
            for mm in re.finditer(r"s32\[\]\s+constant\((\d+)\)", line):
                best = max(best, int(mm.group(1)))
    return best


def hlo_cost(hlo: str) -> Cost:
    comps = split_computations(hlo)
    resolver = Resolver(comps)
    memo: Dict[str, Cost] = {}

    def walk(name: str, stack=()) -> Cost:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return Cost()
        lines = comps[name]
        syms = resolver.syms[name]
        total = Cost()
        total.add(_direct_cost(lines, syms, resolver, name))
        for line in lines:
            s = line.strip()
            if _WHILE_RE.search(s):
                mb = _BODY_RE.search(s)
                if mb:
                    mc = _COND_RE.search(s)
                    trips = _trips(s, comps, mc.group(1) if mc else None)
                    total.add(walk(mb.group(1), stack + (name,)), trips)
                continue
            mcall = _CALL_RE.search(s)
            if mcall:
                for callee in re.split(r",\s*%?", mcall.group(1)):
                    total.add(walk(callee, stack + (name,)))
        memo[name] = total
        return total

    entry = _entry_name(hlo)
    if entry is None:
        lines = hlo.splitlines()
        return _direct_cost(lines, _symbols(lines))
    total = walk(entry)
    return total


def load_hlo(path: Path) -> str:
    if str(path).endswith(".gz"):
        with gzip.open(path, "rt") as f:
            return f.read()
    return Path(path).read_text()
