"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \
      --shape train_4k --smoke --steps 20

``--smoke`` runs the reduced config on the host device; on a real TPU
pod, omit it and the production mesh is built from the job's device set.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, get_shape, get_smoke_config
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.optim import AdamWConfig
from repro.train.loop import TrainLoop, TrainLoopConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny shape on the host device")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()

    if args.smoke:
        cfg = get_smoke_config(args.arch)
        shape = ShapeConfig("smoke", seq_len=64, global_batch=8, kind="train")
        mesh = make_host_mesh()
    else:
        cfg = get_config(args.arch)
        shape = get_shape(args.shape)
        mesh = make_production_mesh()

    loop = TrainLoop(
        cfg, shape, mesh,
        TrainLoopConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                        ckpt_dir=args.ckpt_dir, seed=args.seed,
                        microbatches=args.microbatches,
                        resume=not args.no_resume),
        AdamWConfig(lr=args.lr, total_steps=max(args.steps, 10)))
    out = loop.run()
    print(f"[train] done: {out}", flush=True)


if __name__ == "__main__":
    main()
