"""Roofline analysis from the compiled dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell, from experiments/dryrun/*.json:

  compute term    = HLO_FLOPs_per_chip / peak_FLOP/s        (197 TF bf16)
  memory term     = HLO_bytes_per_chip / HBM_bw             (819 GB/s)
  collective term = collective_bytes_per_chip / link_bw     (50 GB/s ICI)

``cost_analysis()`` numbers are per-device after SPMD partitioning
(verified against a hand-checked sharded matmul); collective bytes are
parsed from the per-device HLO with while-loop trip-count multiplication
(launch/dryrun.py), so all three terms are per-chip step times.

MODEL_FLOPS (the useful-work floor) is 6·N_active·tokens for training and
2·N_active·tokens for inference; the ratio MODEL_FLOPS/HLO_FLOPs exposes
remat recompute and padding waste. The bottleneck column names the term
the §Perf loop should attack.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

from repro.configs import SHAPES, get_config

PEAK_FLOPS = 197e12          # bf16 per chip (TPU v5e class)
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def model_flops_per_chip(arch: str, shape_name: str, n_chips: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / n_chips


def analyze(rec: Dict) -> Optional[Dict]:
    if not rec.get("ok"):
        return None
    n_chips = 512 if rec["mesh"] == "pod2x16x16" else 256
    flops = rec.get("walker_flops") or rec["flops"]
    mem_bytes = rec.get("walker_dot_bytes") or rec["bytes_accessed"]
    t_comp = flops / PEAK_FLOPS
    t_mem = mem_bytes / HBM_BW
    t_coll = rec["collective_bytes_total"] / ICI_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get).split("_")[0]
    mf = model_flops_per_chip(rec["arch"], rec["shape"], n_chips)
    step_s = max(terms.values())            # perfectly-overlapped bound
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "variant": rec.get("variant", "native"),
        **{k: round(v, 6) for k, v in terms.items()},
        "bottleneck": bottleneck,
        "model_flops_per_chip": mf,
        "useful_flops_ratio": round(
            mf / max(rec.get("walker_flops") or rec["flops"], 1.0), 3),
        "roofline_fraction": round(
            (mf / PEAK_FLOPS) / max(step_s, 1e-12), 3),
        "hw_mfu_bound": round(t_comp / max(step_s, 1e-12), 3),
        "temp_gb": round(rec.get("temp_size_in_bytes", 0) / 1e9, 2),
        "args_gb": round(rec.get("argument_size_in_bytes", 0) / 1e9, 2),
    }


def load_all(mesh: str = "pod16x16") -> List[Dict]:
    rows = []
    for f in sorted(RESULTS_DIR.glob(f"*__{mesh}.json")):
        rec = json.loads(f.read_text())
        row = analyze(rec)
        if row:
            rows.append(row)
    return rows


def what_would_help(row: Dict) -> str:
    b = row["bottleneck"]
    if b == "compute":
        if row["useful_flops_ratio"] < 0.4:
            return ("compute-bound but mostly recompute/padding: relax "
                    "remat policy or cut padded-expert waste")
        return "compute-bound near useful-flops: raise MXU utilization"
    if b == "memory":
        return ("HBM-bound: shrink cache/param traffic (quantize KV, "
                "fuse ops, low-dim filter first)")
    return ("collective-bound: reshard to cut gathered bytes or overlap "
            "collectives with compute")


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--csv", default=None)
    args = ap.parse_args()
    rows = load_all(args.mesh)
    cols = ["arch", "shape", "variant", "compute_s", "memory_s",
            "collective_s", "bottleneck", "useful_flops_ratio",
            "roofline_fraction", "temp_gb"]
    print(",".join(cols))
    lines = [",".join(cols)]
    for r in rows:
        line = ",".join(str(r[c]) for c in cols)
        print(line)
        lines.append(line)
    if args.csv:
        Path(args.csv).write_text("\n".join(lines) + "\n")


if __name__ == "__main__":
    main()
