"""Step builders: jitted train / prefill / serve steps with full
in/out shardings for a given (arch config, shape, mesh).

These are shared by the real launchers (train.py / serve.py) and the
multi-pod dry-run (dryrun.py) — the dry-run lowers exactly what the
launchers run.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.models import get_model
from repro.optim import AdamWConfig, adamw_init, adamw_update


def _ns(mesh, *spec):
    return NamedSharding(mesh, P(*spec))


def _logits_sharding(cfg, mesh):
    """Vocab-sharded logits unless the vocab doesn't divide the model
    axis (e.g. whisper's 51865)."""
    if cfg.vocab % shd.axis_size(mesh, "model") == 0:
        return _ns(mesh, None, "model")
    return _ns(mesh, None, None)


def _replicated(mesh, tree):
    return jax.tree.map(lambda _: _ns(mesh), tree)


# --------------------------------------------------------------------------
# cache shardings (name-dispatched, mirrors distributed/sharding.py rules)
# --------------------------------------------------------------------------

def cache_shardings(cfg, mesh: Mesh, abstract_cache, batch: int):
    b_ax = shd.batch_axes(mesh)
    b_size = 1
    for a in b_ax:
        b_size *= shd.axis_size(mesh, a)
    bspec = b_ax if batch % b_size == 0 and batch >= b_size else None
    # sequence axis of KV caches: model (+data when batch can't use it)
    seq_ax = "model" if bspec is not None else tuple(list(b_ax) + ["model"])

    def rule(path, leaf):
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = entry.key
                break
        if name in ("k", "v", "k_low", "k_sc", "v_sc"):  # [L,B,T,KV,*]
            t = leaf.shape[2]
            n_seq = 1
            for a in (seq_ax if isinstance(seq_ax, tuple) else (seq_ax,)):
                n_seq *= shd.axis_size(mesh, a)
            sax = seq_ax if t % n_seq == 0 else None
            return _ns(mesh, None, bspec, sax, None, None)
        if name == "S":                  # rwkv state [L, B, H, hd, hd]
            h = leaf.shape[2]
            m = "model" if h % shd.axis_size(mesh, "model") == 0 else None
            return _ns(mesh, None, bspec, m, None, None)
        if name == "x_prev":             # [L, B, 1, D]
            d = leaf.shape[3]
            m = "model" if d % shd.axis_size(mesh, "model") == 0 else None
            return _ns(mesh, None, bspec, None, m)
        if name == "h":                  # rg-lru state [R, B, W]
            w = leaf.shape[2]
            m = "model" if w % shd.axis_size(mesh, "model") == 0 else None
            return _ns(mesh, None, bspec, m)
        if name == "conv":               # [R, B, 3, W]
            w = leaf.shape[3]
            m = "model" if w % shd.axis_size(mesh, "model") == 0 else None
            return _ns(mesh, None, bspec, None, m)
        raise KeyError(f"no cache sharding rule for {path}")

    return jax.tree_util.tree_map_with_path(rule, abstract_cache)


# --------------------------------------------------------------------------
# step builders
# --------------------------------------------------------------------------

def default_microbatches(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> int:
    """Gradient-accumulation depth: keep per-device live activations
    roughly constant across model widths (bigger d_model -> more
    microbatches), bounded by the per-device batch."""
    if getattr(cfg, "shard_profile", "tp") == "fsdp":
        return 1   # the batch spreads over the whole mesh instead
    b_ax = shd.batch_axes(mesh, cfg)
    b_size = 1
    for a in b_ax:
        b_size *= shd.axis_size(mesh, a)
    want = max(4, cfg.d_model // 2048)
    mb = 1
    while mb < want and shape.global_batch % (mb * 2) == 0 \
            and (shape.global_batch // (mb * 2)) % b_size == 0:
        mb *= 2
    return mb


def build_train_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                     opt_cfg: AdamWConfig = AdamWConfig(),
                     microbatches: int = 0):
    """Returns (jitted_step, specs) where specs holds all abstract
    values + shardings needed to lower or to initialize real state.
    microbatches=0 -> auto (default_microbatches)."""
    api = get_model(cfg)
    a_params = api.abstract_params()
    p_sh = shd.param_shardings(cfg, a_params, mesh)
    a_opt = jax.eval_shape(adamw_init, a_params)
    o_sh = {"m": p_sh, "v": p_sh, "step": _ns(mesh)}
    b_sh = shd.batch_sharding(cfg, mesh, shape, "train")
    mb = microbatches or default_microbatches(cfg, shape, mesh)
    arules = shd.act_rules(cfg, mesh, shape.global_batch // mb)

    def train_step(params, opt_state, batch):
        if mb == 1:
            with shd.activation_rules(arules, mesh):
                (loss, metrics), grads = jax.value_and_grad(
                    api.loss, has_aux=True)(params, batch)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]),
                batch)

            def mb_body(acc, mbatch):
                with shd.activation_rules(arules, mesh):
                    (l, m), g = jax.value_and_grad(
                        api.loss, has_aux=True)(params, mbatch)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), acc, g)
                return acc, (l, m)

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            gsum, (ls, ms) = jax.lax.scan(mb_body, zero, mbs)
            grads = jax.tree.map(lambda g: g / mb, gsum)
            loss = jnp.mean(ls)
            metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), ms)
        new_p, new_o, om = adamw_update(opt_cfg, params, grads, opt_state)
        return new_p, new_o, {**metrics, **om}

    a_metrics = jax.eval_shape(
        lambda p, o, b: train_step(p, o, b)[2], a_params, a_opt,
        _abstract_batch(api, shape))
    step = jax.jit(
        train_step,
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, _replicated(mesh, a_metrics)),
        donate_argnums=(0, 1),
    )
    specs = dict(api=api, a_params=a_params, p_sh=p_sh, a_opt=a_opt,
                 o_sh=o_sh, b_sh=b_sh)
    return step, specs


def build_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig):
    api = get_model(cfg)
    a_params = api.abstract_params()
    p_sh = shd.param_shardings(cfg, a_params, mesh)
    b_sh = shd.batch_sharding(cfg, mesh, shape, "prefill")
    arules = shd.act_rules(cfg, mesh, shape.global_batch)
    a_cache = api.abstract_cache(shape.global_batch, shape.seq_len)
    c_sh = cache_shardings(cfg, mesh, a_cache, shape.global_batch)
    lg_sh = _logits_sharding(cfg, mesh)

    def prefill_step(params, batch):
        with shd.activation_rules(arules, mesh):
            return api.prefill(params, batch)

    step = jax.jit(prefill_step, in_shardings=(p_sh, b_sh),
                   out_shardings=(lg_sh, c_sh))
    return step, dict(api=api, a_params=a_params, p_sh=p_sh, b_sh=b_sh,
                      a_cache=a_cache, c_sh=c_sh)


def build_serve_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig):
    """One-token decode against a seq_len cache."""
    api = get_model(cfg)
    a_params = api.abstract_params()
    p_sh = shd.param_shardings(cfg, a_params, mesh)
    b_sh = shd.batch_sharding(cfg, mesh, shape, "decode")
    a_cache = api.abstract_cache(shape.global_batch, shape.seq_len)
    c_sh = cache_shardings(cfg, mesh, a_cache, shape.global_batch)
    lg_sh = _logits_sharding(cfg, mesh)

    def serve_step(params, cache, token, pos):
        return api.decode_step(params, cache, token, pos)

    step = jax.jit(
        serve_step,
        in_shardings=(p_sh, c_sh, b_sh["token"], b_sh["pos"]),
        out_shardings=(lg_sh, c_sh),
        donate_argnums=(1,),
    )
    return step, dict(api=api, a_params=a_params, p_sh=p_sh, b_sh=b_sh,
                      a_cache=a_cache, c_sh=c_sh)


def _abstract_batch(api, shape: ShapeConfig):
    return api.input_specs(shape)


def lower_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig):
    """Lower the appropriate step for a (cfg, shape) cell. Returns the
    jax ``Lowered`` object."""
    api = get_model(cfg)
    specs_in = api.input_specs(shape)
    with mesh:
        if shape.kind == "train":
            step, s = build_train_step(cfg, mesh, shape)
            return step.lower(s["a_params"], s["a_opt"], specs_in)
        if shape.kind == "prefill":
            step, s = build_prefill_step(cfg, mesh, shape)
            return step.lower(s["a_params"], specs_in)
        step, s = build_serve_step(cfg, mesh, shape)
        return step.lower(s["a_params"], s["a_cache"], specs_in["token"],
                          specs_in["pos"])
