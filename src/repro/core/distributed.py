"""Distributed pHNSW: database sharded across the mesh (the paper's
Section VI future work — "partitioning the billion-scale database into
smaller parts while preserving efficient coordination" — built here as a
first-class feature, at full feature parity with the single-shard
engine: any filter kind, deferred re-ranking, tombstones).

Scheme (scale-out ANN as deployed in practice):
  * the dataset is partitioned into P shards along the ``model`` axis
    (remainder vectors spread over the first ``n % P`` shards — no tail
    is ever dropped); each shard gets its own independently-built HNSW
    graph (host-side, embarrassingly parallel at build time) over ONE
    shared filter (PCA projection / PQ codebook fitted on the full
    dataset, so filter distances are comparable across shards);
  * queries are sharded along the ``data`` (+``pod``) axes and
    REPLICATED along ``model``;
  * every device runs the fixed-shape batched pHNSW search
    (search_jax) over its local shard — identical compiled program, no
    cross-device traffic during traversal; tombstones ride along as the
    per-shard word-packed ``deleted`` bitmap (traversed, never
    returned);
  * per-shard top-ef results are all-gathered over ``model`` and merged
    with one kSort.L pass (global index = shard offset + local index);
  * under DEFERRED re-ranking the per-shard traversal stays purely in
    filter space and hands back the WIDE ``rerank_mult * ef0`` list;
    the cross-shard merge happens on filter distances, and ONE global
    batched Dist.H re-ranks the merged list — each shard scores only
    the merged candidates it owns and a psum assembles the row
    (total Dist.H evals per query = rerank_mult * ef0 across the whole
    mesh, same as single-shard deferred).

Collective cost per query batch: one all-gather of [P, B_local, E]
(dist, idx) pairs (E = ef0, or rerank_mult*ef0 when deferred) plus,
when deferred, one [B_local, E] psum — a few KB; the traversal itself
is communication-free.

``shard_search_host`` runs the IDENTICAL program without a mesh (a
python loop over shards + the same merge/re-rank) — bit-equal to
``distributed_search`` on any mesh, so single-device CI can lock down
multi-shard semantics and the multi-device job only has to assert
mesh == host.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import PHNSWConfig
from repro.constants import INF as _INF
from repro.core.graph import build_hnsw
from repro.core.pca import PCA
from repro.core.search_jax import (PackedDB, PackedLayer, build_packed,
                                   pack_bitmap, _rank_sort_with_payload,
                                   _search_batched_impl)
from repro.kernels import ops

INF = jnp.float32(_INF)


def shard_bounds(n: int, n_shards: int) -> List[Tuple[int, int]]:
    """[start, end) per shard: ``n // P`` each, the ``n % P`` remainder
    spread one-per-shard from the front — every vector is owned by
    exactly one shard (the seed implementation silently dropped the
    tail)."""
    per, rem = divmod(n, n_shards)
    out, start = [], 0
    for s in range(n_shards):
        size = per + (1 if s < rem else 0)
        out.append((start, start + size))
        start += size
    assert start == n
    return out


@dataclass
class ShardedDB:
    """Stacked per-shard databases: every leaf has leading dim P.
    Shards may hold unequal counts (remainder distribution, per-shard
    mutation); rows are padded to a uniform per-shard height — pad rows
    have no adjacency and are never linked, so they are unreachable.
    ``counts[s]`` is the live row span of shard s (ownership test for
    the global deferred re-rank); ``offsets[s]`` maps local ids to the
    global id space. ``deleted`` (optional) stacks the per-shard
    word-packed tombstone bitmaps. ``filter_kind`` is METADATA, same
    contract as ``PackedDB``."""
    adj: List[jax.Array]          # per layer: [P, N, M_l]
    packed_low: List[jax.Array]   # per layer: [P, N, M_l, pl]
    low: jax.Array                # [P, N, pl]
    high: jax.Array               # [P, N, D]
    entries: jax.Array            # [P] int32
    offsets: jax.Array            # [P] int32 global-id offset per shard
    counts: jax.Array             # [P] int32 rows owned per shard
    cfg: PHNSWConfig
    deleted: Optional[jax.Array] = None   # [P, ceil(N/32)] int32
    filter_kind: str = "pca"

    @property
    def n_shards(self) -> int:
        return int(self.high.shape[0])

    def shard_db(self, s) -> PackedDB:
        """The PackedDB view of one shard (``s`` may be a traced index
        inside jit; with integer 0 after shard_map it is the local
        shard)."""
        layers = [PackedLayer(adj=a[s], packed_low=p[s])
                  for a, p in zip(self.adj, self.packed_low)]
        return PackedDB(layers=layers, low=self.low[s], high=self.high[s],
                        entry=self.entries[s], cfg=self.cfg,
                        deleted=None if self.deleted is None
                        else self.deleted[s],
                        filter_kind=self.filter_kind)


jax.tree_util.register_dataclass(
    ShardedDB,
    data_fields=["adj", "packed_low", "low", "high", "entries",
                 "offsets", "counts", "deleted"],
    meta_fields=["cfg", "filter_kind"])


def _pad_rows(a: np.ndarray, n: int, fill) -> np.ndarray:
    """Pad axis 0 of ``a`` to ``n`` rows with ``fill``."""
    if a.shape[0] == n:
        return a
    pad = np.full((n - a.shape[0],) + a.shape[1:], fill, a.dtype)
    return np.concatenate([a, pad])


def build_sharded(x: np.ndarray, cfg: PHNSWConfig, filt, n_shards: int,
                  *, deleted: Optional[np.ndarray] = None,
                  graphs=None, seed: int = 0,
                  builder: Optional[str] = None) -> ShardedDB:
    """Partition ``x`` into ``n_shards`` (remainder distributed, no tail
    dropped), build one HNSW graph per shard, and stack the packed
    databases. ``filt`` is the SHARED filter — any
    ``core.filters.FilterSpec`` fitted on the full dataset, or a bare
    ``PCA`` (the seed API, adopted as a ``PCAFilter``). ``deleted``
    ([n] bool, optional) seeds the per-shard tombstone bitmaps.
    ``graphs`` (per-shard ``HNSWGraph`` over exactly the shard_bounds
    partition) skips the builds — graphs are filter-independent, so
    callers comparing filter kinds build once. Shard builds route
    through the one construction pipeline (``builder`` defaults to
    ``cfg.builder`` — the wave pipeline; equal-sized shards share its
    compiled probe program, so P shards pay ONE compile)."""
    from repro.core.filters import PCAFilter
    if isinstance(filt, PCA):
        filt = PCAFilter(filt, low_dtype=cfg.low_dtype)
    n = len(x)
    bounds = shard_bounds(n, n_shards)
    n_max = max(e - s for s, e in bounds)
    dbs, offs, cnts, dels = [], [], [], []
    for s, (a, b) in enumerate(bounds):
        xs = x[a:b]
        if graphs is not None:
            g = graphs[s]
            assert len(g.x) == b - a, "graphs must match shard_bounds"
        else:
            g = build_hnsw(xs, cfg, seed=seed + s, builder=builder)
        # keep layer counts uniform across shards for stacking
        dbs.append(build_packed(g, filt.encode(xs), filt=filt,
                                drop_empty_layers=False))
        offs.append(a)
        cnts.append(b - a)
        if deleted is not None:
            # pad slots marked deleted too (unreachable, but the bitmap
            # shape must stack)
            d = _pad_rows(deleted[a:b].astype(bool), n_max, True)
            dels.append(pack_bitmap(d))
    stack = lambda xs: jnp.stack(xs)
    n_layers = len(dbs[0].layers)
    return ShardedDB(
        adj=[stack([_pad_rows(np.asarray(db.layers[l].adj), n_max, -1)
                    for db in dbs]) for l in range(n_layers)],
        packed_low=[stack([_pad_rows(np.asarray(db.layers[l].packed_low),
                                     n_max, 0) for db in dbs])
                    for l in range(n_layers)],
        low=stack([_pad_rows(np.asarray(db.low), n_max, 0)
                   for db in dbs]),
        high=stack([_pad_rows(np.asarray(db.high), n_max, 0)
                    for db in dbs]),
        entries=jnp.asarray([db.entry for db in dbs], jnp.int32),
        offsets=jnp.asarray(offs, jnp.int32),
        counts=jnp.asarray(cnts, jnp.int32),
        cfg=cfg,
        deleted=None if deleted is None else stack(dels),
        filter_kind=filt.kind,
    )


# ---------------------------------------------------------------------------
# the shared per-shard + merge program (mesh and host paths run THE SAME
# traced code — bit-equal by construction)
# ---------------------------------------------------------------------------

def _shard_lists(db: PackedDB, offset, queries, qprep, *, ef0, ks,
                 deferred, rerank_mult):
    """One shard's pre-merge candidate lists: ([B, E] dists ascending,
    [B, E] GLOBAL ids). High-dim dists normally; the WIDE
    (rerank_mult * ef0) filter-space list when deferred (the global
    re-rank happens after the cross-shard merge)."""
    fd, fi, _, _ = _search_batched_impl(
        db, queries, qprep, ef0=ef0, k_schedule=ks, deferred=deferred,
        rerank_mult=rerank_mult, final_rerank=False)
    return fd, jnp.where(fi >= 0, fi + offset, -1)


def _merge_lists(fd_all, fi_all, k: int):
    """Cross-shard merge: [P, B, E] stacked per-shard ascending lists ->
    the global top-k ([B, k] dists, [B, k] ids) with one kSort.L pass
    (deterministic ties: lower shard, then lower slot)."""
    Pn, B, E = fd_all.shape
    fd_c = jnp.moveaxis(fd_all, 0, 1).reshape(B, Pn * E)
    fi_c = jnp.moveaxis(fi_all, 0, 1).reshape(B, Pn * E)
    vals, sel = ops.ksort_l(fd_c, k)
    return vals, jnp.take_along_axis(fi_c, sel, axis=1)


def _owned_dist_h(high, offset, count, gids, queries):
    """One shard's contribution to the global deferred re-rank: Dist.H
    for the merged candidates THIS shard owns, zeros elsewhere — the
    cross-shard sum (psum / host loop) assembles the full row, so the
    whole mesh pays exactly ONE batched Dist.H per query."""
    own = (gids >= offset) & (gids < offset + count)
    loc = jnp.where(own, gids - offset, 0)
    xh = jnp.take(high, loc, axis=0)                     # [B, E, D]
    return jnp.where(own, ops.dist_h(xh, queries), 0.0)


def _global_rerank(md, mi, dh, ef0: int):
    """Sort the merged list by the assembled high-dim dists (stable on
    ties — same ``_rank_sort_with_payload`` as the single-shard deferred
    re-rank) and trim to ef0."""
    dh = jnp.where(mi >= 0, dh, INF)
    rd, ri = _rank_sort_with_payload(dh, jnp.where(mi >= 0, mi, -1))
    return rd[:, :ef0], ri[:, :ef0]


def _normalize(sdb: ShardedDB, ef0, k_schedule, deferred, rerank_mult):
    """Default + no-op normalization, mirroring ``search_batched`` so a
    caller varying a dead knob never recompiles a bit-identical
    program."""
    cfg = sdb.cfg
    ef0 = int(ef0 or cfg.ef0)
    ks = tuple(k_schedule or cfg.k_schedule)
    if deferred is None:
        deferred = cfg.deferred_rerank
    if rerank_mult is None:
        rerank_mult = cfg.rerank_mult
    if sdb.filter_kind == "none":
        deferred = False
    if not deferred:
        rerank_mult = 1
    return ef0, ks, bool(deferred), int(rerank_mult)


@functools.partial(jax.jit, static_argnames=("mesh", "ef0", "k_schedule",
                                             "deferred", "rerank_mult"))
def _mesh_search_jit(mesh, sdb, queries, qprep, ef0, k_schedule,
                     deferred, rerank_mult):
    b_ax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    m_ax = "model"
    has_del = sdb.deleted is not None

    def local_search(adj, packed_low, low, high, entry, offset, count,
                     dele, q, qp):
        # leaves arrive with the leading shard dim = 1: squeeze it
        db = PackedDB(
            layers=[PackedLayer(adj=a[0], packed_low=p[0])
                    for a, p in zip(adj, packed_low)],
            low=low[0], high=high[0], entry=entry[0], cfg=sdb.cfg,
            deleted=dele[0] if has_del else None,
            filter_kind=sdb.filter_kind)
        fd, gi = _shard_lists(db, offset[0], q, qp, ef0=ef0,
                              ks=k_schedule, deferred=deferred,
                              rerank_mult=rerank_mult)
        fd_all = jax.lax.all_gather(fd, m_ax, axis=0)      # [P, B, E]
        gi_all = jax.lax.all_gather(gi, m_ax, axis=0)
        E = fd.shape[1]
        md, mi = _merge_lists(fd_all, gi_all, E)
        if deferred:
            dh = jax.lax.psum(
                _owned_dist_h(high[0], offset[0], count[0], mi, q), m_ax)
            return _global_rerank(md, mi, dh, ef0)
        return md, mi

    n_l = len(sdb.adj)
    q_spec = P(b_ax, None)
    qp_spec = P(b_ax, *([None] * (qprep.ndim - 1)))
    in_specs = (
        [P(m_ax, None, None)] * n_l,          # adj
        [P(m_ax, None, None, None)] * n_l,    # packed_low
        P(m_ax, None, None), P(m_ax, None, None),
        P(m_ax), P(m_ax), P(m_ax),
        P(m_ax, None) if has_del else P(),
        q_spec, qp_spec,
    )
    out_specs = (P(b_ax, None), P(b_ax, None))
    fn = shard_map(local_search, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    dele = sdb.deleted if has_del else jnp.zeros((), jnp.int32)
    return fn(sdb.adj, sdb.packed_low, sdb.low, sdb.high, sdb.entries,
              sdb.offsets, sdb.counts, dele, queries, qprep)


@functools.partial(jax.jit, static_argnames=("ef0", "k_schedule",
                                             "deferred", "rerank_mult"))
def _host_search_jit(sdb, queries, qprep, ef0, k_schedule, deferred,
                     rerank_mult):
    """The meshless twin of ``_mesh_search_jit``: an unrolled loop over
    shards + the same merge and global re-rank. all_gather == stack,
    psum == sum of the per-shard owned contributions (exactly one
    non-zero term per slot, so the float result is bit-equal)."""
    Pn = sdb.n_shards
    fds, gis = [], []
    for s in range(Pn):
        fd, gi = _shard_lists(sdb.shard_db(s), sdb.offsets[s], queries,
                              qprep, ef0=ef0, ks=k_schedule,
                              deferred=deferred, rerank_mult=rerank_mult)
        fds.append(fd)
        gis.append(gi)
    E = fds[0].shape[1]
    md, mi = _merge_lists(jnp.stack(fds), jnp.stack(gis), E)
    if deferred:
        dh = jnp.zeros_like(md)
        for s in range(Pn):
            dh = dh + _owned_dist_h(sdb.high[s], sdb.offsets[s],
                                    sdb.counts[s], mi, queries)
        return _global_rerank(md, mi, dh, ef0)
    return md, mi


def _prepare_qprep(sdb: ShardedDB, queries, q_low, filt):
    if q_low is not None:
        return q_low
    if filt is not None:
        if filt.kind != sdb.filter_kind:
            raise ValueError(f"filter mismatch: sharded db carries a "
                             f"{sdb.filter_kind!r} payload, filt is "
                             f"{filt.kind!r}")
        return filt.prepare_jnp(queries)
    if sdb.filter_kind == "none":
        return queries[:, :0].astype(jnp.float32)
    raise ValueError("q_low or filt required for the "
                     f"{sdb.filter_kind!r} filter")


def distributed_search(mesh: Mesh, sdb: ShardedDB, queries, q_low=None,
                       *, filt=None, ef0: int = 0, k_schedule=None,
                       deferred: Optional[bool] = None,
                       rerank_mult: Optional[int] = None):
    """Sharded batched search over ``mesh``. queries: [B, D] global;
    ``q_low`` is the active filter's per-query prep (or pass ``filt``
    to compute it here; the identity filter needs neither). Returns
    (dists [B, ef0], GLOBAL idx [B, ef0]). On a 1-shard mesh this is
    bit-equal to single-shard ``search_batched`` for every filter kind
    and re-rank mode."""
    qprep = _prepare_qprep(sdb, queries, q_low, filt)
    ef0, ks, deferred, rm = _normalize(sdb, ef0, k_schedule, deferred,
                                       rerank_mult)
    return _mesh_search_jit(mesh, sdb, queries, qprep, ef0, ks,
                            deferred, rm)


def shard_search_host(sdb: ShardedDB, queries, q_low=None, *, filt=None,
                      ef0: int = 0, k_schedule=None,
                      deferred: Optional[bool] = None,
                      rerank_mult: Optional[int] = None):
    """``distributed_search`` without a mesh: the same per-shard
    programs and the same merge, on however many devices exist (one is
    fine) — bit-equal to the mesh path. This is the simulated-shards
    entry point for single-device tests/benchmarks and the serving
    default when no mesh is configured."""
    qprep = _prepare_qprep(sdb, queries, q_low, filt)
    ef0, ks, deferred, rm = _normalize(sdb, ef0, k_schedule, deferred,
                                       rerank_mult)
    return _host_search_jit(sdb, queries, qprep, ef0, ks, deferred, rm)


def search_cache_sizes() -> Tuple[int, int]:
    """(mesh, host) compiled-program cache sizes — the sharded
    zero-recompile assertions read these."""
    return (_mesh_search_jit._cache_size(),
            _host_search_jit._cache_size())
