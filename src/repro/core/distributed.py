"""Distributed pHNSW: database sharded across the mesh (the paper's
Section VI future work — "partitioning the billion-scale database into
smaller parts while preserving efficient coordination" — built here as a
first-class feature, at full feature parity with the single-shard
engine: any filter kind, deferred re-ranking, tombstones).

Scheme (scale-out ANN as deployed in practice):
  * the dataset is partitioned into P shards along the ``model`` axis
    (remainder vectors spread over the first ``n % P`` shards — no tail
    is ever dropped); each shard gets its own independently-built HNSW
    graph (host-side, embarrassingly parallel at build time) over ONE
    shared filter (PCA projection / PQ codebook fitted on the full
    dataset, so filter distances are comparable across shards);
  * queries are sharded along the ``data`` (+``pod``) axes and
    REPLICATED along ``model``;
  * every device runs the fixed-shape batched pHNSW search
    (search_jax) over its local shard — identical compiled program, no
    cross-device traffic during traversal; tombstones ride along as the
    per-shard word-packed ``deleted`` bitmap (traversed, never
    returned);
  * per-shard top-ef results are all-gathered over ``model`` and merged
    with one kSort.L pass (global index = shard offset + local index);
  * under DEFERRED re-ranking the per-shard traversal stays purely in
    filter space and hands back the WIDE ``rerank_mult * ef0`` list;
    the cross-shard merge happens on filter distances, and ONE global
    batched Dist.H re-ranks the merged list — each shard scores only
    the merged candidates it owns and a psum assembles the row
    (total Dist.H evals per query = rerank_mult * ef0 across the whole
    mesh, same as single-shard deferred);
  * the deferred CASCADE widens the per-shard lists further to
    ``promote_mult * ef0`` PQ-space candidates, merges on PQ
    distances, and inserts a GLOBAL promote stage before the Dist.H
    pass: each shard scores the merged candidates it owns against its
    PCA side-car (``low2``) rows, a psum assembles the mid-stage row,
    and the list is trimmed to ``rerank_mult * ef0`` — so the whole
    mesh still pays exactly one batched Dist.H of the single-shard
    deferred width.

Collective cost per query batch: one all-gather of [P, B_local, E]
(dist, idx) pairs (E = ef0, or rerank_mult*ef0 when deferred,
promote_mult*ef0 for the cascade) plus, when deferred, one
[B_local, E] psum (two for the cascade) — a few KB; the traversal
itself is communication-free.

``shard_search_host`` runs the IDENTICAL program without a mesh (a
python loop over shards + the same merge/re-rank) — bit-equal to
``distributed_search`` on any mesh, so single-device CI can lock down
multi-shard semantics and the multi-device job only has to assert
mesh == host.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import PHNSWConfig
from repro.constants import INF as _INF
from repro.core.graph import build_hnsw
from repro.core.pca import PCA
from repro.core.search_jax import (PackedDB, PackedLayer, build_packed,
                                   pack_bitmap, _rank_sort_with_payload,
                                   _search_batched_impl)
from repro.kernels import ops

INF = jnp.float32(_INF)


def shard_bounds(n: int, n_shards: int) -> List[Tuple[int, int]]:
    """[start, end) per shard: ``n // P`` each, the ``n % P`` remainder
    spread one-per-shard from the front — every vector is owned by
    exactly one shard (the seed implementation silently dropped the
    tail)."""
    per, rem = divmod(n, n_shards)
    out, start = [], 0
    for s in range(n_shards):
        size = per + (1 if s < rem else 0)
        out.append((start, start + size))
        start += size
    assert start == n
    return out


@dataclass
class ShardedDB:
    """Stacked per-shard databases: every leaf has leading dim P.
    Shards may hold unequal counts (remainder distribution, per-shard
    mutation); rows are padded to a uniform per-shard height — pad rows
    have no adjacency and are never linked, so they are unreachable.
    ``counts[s]`` is the live row span of shard s (ownership test for
    the global deferred re-rank); ``offsets[s]`` maps local ids to the
    global id space. ``deleted`` (optional) stacks the per-shard
    word-packed tombstone bitmaps. ``filter_kind`` is METADATA, same
    contract as ``PackedDB``."""
    adj: List[jax.Array]          # per layer: [P, N, M_l]
    packed_low: List[jax.Array]   # per layer: [P, N, M_l, pl]
    low: jax.Array                # [P, N, pl]
    high: jax.Array               # [P, N, D]
    entries: jax.Array            # [P] int32
    offsets: jax.Array            # [P] int32 global-id offset per shard
    counts: jax.Array             # [P] int32 rows owned per shard
    cfg: PHNSWConfig
    deleted: Optional[jax.Array] = None   # [P, ceil(N/32)] int32
    low2: Optional[jax.Array] = None      # [P, N, d_low] f32 side-car
    filter_kind: str = "pca"

    @property
    def n_shards(self) -> int:
        return int(self.high.shape[0])

    def shard_db(self, s) -> PackedDB:
        """The PackedDB view of one shard (``s`` may be a traced index
        inside jit; with integer 0 after shard_map it is the local
        shard)."""
        layers = [PackedLayer(adj=a[s], packed_low=p[s])
                  for a, p in zip(self.adj, self.packed_low)]
        return PackedDB(layers=layers, low=self.low[s], high=self.high[s],
                        entry=self.entries[s], cfg=self.cfg,
                        deleted=None if self.deleted is None
                        else self.deleted[s],
                        low2=None if self.low2 is None else self.low2[s],
                        filter_kind=self.filter_kind)

    def select(self, keep) -> "ShardedDB":
        """The survivor-only twin of a degraded db: slice every stacked
        leaf down to the ``keep`` shards while KEEPING each survivor's
        original global offset — global ids and the merge tie-break
        order (lower shard first) are preserved, so searching this db
        is the host oracle that degraded-mode (live-masked) results are
        asserted bit-equal against."""
        k = jnp.asarray(np.atleast_1d(np.asarray(keep, np.int64)))
        return dataclasses.replace(
            self,
            adj=[a[k] for a in self.adj],
            packed_low=[p[k] for p in self.packed_low],
            low=self.low[k], high=self.high[k],
            entries=self.entries[k], offsets=self.offsets[k],
            counts=self.counts[k],
            deleted=None if self.deleted is None else self.deleted[k],
            low2=None if self.low2 is None else self.low2[k])


jax.tree_util.register_dataclass(
    ShardedDB,
    data_fields=["adj", "packed_low", "low", "high", "entries",
                 "offsets", "counts", "deleted", "low2"],
    meta_fields=["cfg", "filter_kind"])


def stacked_db_view(sdb: ShardedDB) -> PackedDB:
    """The STACKED PackedDB view of a ShardedDB: every leaf keeps its
    leading shard dim P (``shard_db`` strips it for one shard; this
    keeps all of them). Not searchable directly — it is the vmap
    operand of the slotted sharded programs
    (``search_jax._slot_step_sharded_jit`` / ``_slot_admit_sharded_jit``),
    which map the per-shard program over axis 0 of every leaf
    (``entries`` [P] becomes each lane's scalar ``entry``)."""
    return PackedDB(
        layers=[PackedLayer(adj=a, packed_low=p)
                for a, p in zip(sdb.adj, sdb.packed_low)],
        low=sdb.low, high=sdb.high, entry=sdb.entries, cfg=sdb.cfg,
        deleted=sdb.deleted, low2=sdb.low2, filter_kind=sdb.filter_kind)


def _pad_rows(a: np.ndarray, n: int, fill) -> np.ndarray:
    """Pad axis 0 of ``a`` to ``n`` rows with ``fill``."""
    if a.shape[0] == n:
        return a
    pad = np.full((n - a.shape[0],) + a.shape[1:], fill, a.dtype)
    return np.concatenate([a, pad])


def build_sharded(x: np.ndarray, cfg: PHNSWConfig, filt, n_shards: int,
                  *, deleted: Optional[np.ndarray] = None,
                  graphs=None, seed: int = 0,
                  builder: Optional[str] = None) -> ShardedDB:
    """Partition ``x`` into ``n_shards`` (remainder distributed, no tail
    dropped), build one HNSW graph per shard, and stack the packed
    databases. ``filt`` is the SHARED filter — any
    ``core.filters.FilterSpec`` fitted on the full dataset, or a bare
    ``PCA`` (the seed API, adopted as a ``PCAFilter``). ``deleted``
    ([n] bool, optional) seeds the per-shard tombstone bitmaps.
    ``graphs`` (per-shard ``HNSWGraph`` over exactly the shard_bounds
    partition) skips the builds — graphs are filter-independent, so
    callers comparing filter kinds build once. Shard builds route
    through the one construction pipeline (``builder`` defaults to
    ``cfg.builder`` — the wave pipeline; equal-sized shards share its
    compiled probe program, so P shards pay ONE compile)."""
    from repro.core.filters import PCAFilter
    if isinstance(filt, PCA):
        filt = PCAFilter(filt, low_dtype=cfg.low_dtype)
    n = len(x)
    bounds = shard_bounds(n, n_shards)
    n_max = max(e - s for s, e in bounds)
    dbs, offs, cnts, dels = [], [], [], []
    for s, (a, b) in enumerate(bounds):
        xs = x[a:b]
        if graphs is not None:
            g = graphs[s]
            assert len(g.x) == b - a, "graphs must match shard_bounds"
        else:
            g = build_hnsw(xs, cfg, seed=seed + s, builder=builder)
        # keep layer counts uniform across shards for stacking
        dbs.append(build_packed(g, filt.encode(xs), filt=filt,
                                drop_empty_layers=False))
        offs.append(a)
        cnts.append(b - a)
        if deleted is not None:
            # pad slots marked deleted too (unreachable, but the bitmap
            # shape must stack)
            d = _pad_rows(deleted[a:b].astype(bool), n_max, True)
            dels.append(pack_bitmap(d))
    stack = lambda xs: jnp.stack(xs)
    n_layers = len(dbs[0].layers)
    return ShardedDB(
        adj=[stack([_pad_rows(np.asarray(db.layers[l].adj), n_max, -1)
                    for db in dbs]) for l in range(n_layers)],
        packed_low=[stack([_pad_rows(np.asarray(db.layers[l].packed_low),
                                     n_max, 0) for db in dbs])
                    for l in range(n_layers)],
        low=stack([_pad_rows(np.asarray(db.low), n_max, 0)
                   for db in dbs]),
        high=stack([_pad_rows(np.asarray(db.high), n_max, 0)
                    for db in dbs]),
        entries=jnp.asarray([db.entry for db in dbs], jnp.int32),
        offsets=jnp.asarray(offs, jnp.int32),
        counts=jnp.asarray(cnts, jnp.int32),
        cfg=cfg,
        deleted=None if deleted is None else stack(dels),
        low2=None if dbs[0].low2 is None else
        stack([_pad_rows(np.asarray(db.low2), n_max, 0) for db in dbs]),
        filter_kind=filt.kind,
    )


# ---------------------------------------------------------------------------
# the shared per-shard + merge program (mesh and host paths run THE SAME
# traced code — bit-equal by construction)
# ---------------------------------------------------------------------------

def _shard_lists(db: PackedDB, offset, queries, qprep, *, ef0, ks,
                 deferred, rerank_mult, promote_mult=1):
    """One shard's pre-merge candidate lists: ([B, E] dists ascending,
    [B, E] GLOBAL ids). High-dim dists normally; the WIDE
    (rerank_mult * ef0 — promote_mult * ef0 for the cascade)
    filter-space list when deferred (the global promote/re-rank happens
    after the cross-shard merge)."""
    fd, fi, _, _ = _search_batched_impl(
        db, queries, qprep, ef0=ef0, k_schedule=ks, deferred=deferred,
        rerank_mult=rerank_mult, promote_mult=promote_mult,
        final_rerank=False)
    return fd, jnp.where(fi >= 0, fi + offset, -1)


def _merge_lists(fd_all, fi_all, k: int):
    """Cross-shard merge: [P, B, E] stacked per-shard ascending lists ->
    the global top-k ([B, k] dists, [B, k] ids) with one kSort.L pass
    (deterministic ties: lower shard, then lower slot)."""
    Pn, B, E = fd_all.shape
    fd_c = jnp.moveaxis(fd_all, 0, 1).reshape(B, Pn * E)
    fi_c = jnp.moveaxis(fi_all, 0, 1).reshape(B, Pn * E)
    vals, sel = ops.ksort_l(fd_c, k)
    return vals, jnp.take_along_axis(fi_c, sel, axis=1)


def _owned_dist_h(high, offset, count, gids, queries):
    """One shard's contribution to the global deferred re-rank: Dist.H
    for the merged candidates THIS shard owns, zeros elsewhere — the
    cross-shard sum (psum / host loop) assembles the full row, so the
    whole mesh pays exactly ONE batched Dist.H per query."""
    own = (gids >= offset) & (gids < offset + count)
    loc = jnp.where(own, gids - offset, 0)
    xh = jnp.take(high, loc, axis=0)                     # [B, E, D]
    return jnp.where(own, ops.dist_h(xh, queries), 0.0)


def _owned_dist_mid(low2, offset, count, gids, qpca):
    """One shard's contribution to the global cascade promote: PCA
    mid-stage dists (against the ``low2`` side-car) for the merged
    candidates THIS shard owns, zeros elsewhere — assembled by the same
    cross-shard sum as ``_owned_dist_h``."""
    own = (gids >= offset) & (gids < offset + count)
    loc = jnp.where(own, gids - offset, 0)
    mid = jnp.take(low2, loc, axis=0)                    # [B, E, d_low]
    return jnp.where(own, ops.dist_l(mid, qpca), 0.0)


def _global_promote(mi, dm, n_keep: int):
    """Sort the merged PQ-space list by the assembled mid-stage dists
    (stable — merge-order ties preserved, matching the host oracle's
    stable argsort) and trim to ``n_keep = rerank_mult * ef0``, the
    width the global Dist.H pass then pays."""
    dm = jnp.where(mi >= 0, dm, INF)
    pd, pi = _rank_sort_with_payload(dm, jnp.where(mi >= 0, mi, -1))
    return pd[:, :n_keep], pi[:, :n_keep]


def _global_rerank(md, mi, dh, ef0: int):
    """Sort the merged list by the assembled high-dim dists (stable on
    ties — same ``_rank_sort_with_payload`` as the single-shard deferred
    re-rank) and trim to ef0."""
    dh = jnp.where(mi >= 0, dh, INF)
    rd, ri = _rank_sort_with_payload(dh, jnp.where(mi >= 0, mi, -1))
    return rd[:, :ef0], ri[:, :ef0]


def _normalize(sdb: ShardedDB, ef0, k_schedule, deferred, rerank_mult,
               promote_mult=None):
    """Default + no-op normalization, mirroring ``search_batched`` so a
    caller varying a dead knob never recompiles a bit-identical
    program."""
    cfg = sdb.cfg
    ef0 = int(ef0 or cfg.ef0)
    if deferred is None:
        deferred = cfg.deferred_rerank
    ks = tuple(k_schedule
               or cfg.k_schedule_for(sdb.filter_kind, bool(deferred)))
    if rerank_mult is None:
        rerank_mult = cfg.rerank_mult
    if promote_mult is None:
        promote_mult = cfg.promote_mult
    if sdb.filter_kind == "none":
        deferred = False
    if not deferred:
        rerank_mult = 1
    if not (deferred and sdb.filter_kind == "cascade"):
        promote_mult = 1          # dead knob outside the cascade
    else:
        # the promote pool is never narrower than the re-rank pool
        promote_mult = max(int(promote_mult), int(rerank_mult))
    return ef0, ks, bool(deferred), int(rerank_mult), int(promote_mult)


@functools.partial(jax.jit, static_argnames=("mesh", "ef0", "k_schedule",
                                             "deferred", "rerank_mult",
                                             "promote_mult"))
def _mesh_search_jit(mesh, sdb, queries, qprep, live, ef0, k_schedule,
                     deferred, rerank_mult, promote_mult):
    b_ax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    m_ax = "model"
    has_del = sdb.deleted is not None
    cascade = deferred and sdb.filter_kind == "cascade"

    def local_search(adj, packed_low, low, high, entry, offset, count,
                     dele, lo2, lv, q, qp):
        # leaves arrive with the leading shard dim = 1: squeeze it
        db = PackedDB(
            layers=[PackedLayer(adj=a[0], packed_low=p[0])
                    for a, p in zip(adj, packed_low)],
            low=low[0], high=high[0], entry=entry[0], cfg=sdb.cfg,
            deleted=dele[0] if has_del else None,
            low2=lo2[0] if cascade else None,
            filter_kind=sdb.filter_kind)
        fd, gi = _shard_lists(db, offset[0], q, qp, ef0=ef0,
                              ks=k_schedule, deferred=deferred,
                              rerank_mult=rerank_mult,
                              promote_mult=promote_mult)
        # degraded mode: a dead shard's lists are masked to (INF, -1)
        # — pure DATA, shapes unchanged, so kill/recover cycles reuse
        # the compiled program (zero recompiles)
        fd = jnp.where(lv[0], fd, INF)
        gi = jnp.where(lv[0], gi, -1)
        fd_all = jax.lax.all_gather(fd, m_ax, axis=0)      # [P, B, E]
        gi_all = jax.lax.all_gather(gi, m_ax, axis=0)
        E = fd.shape[1]
        md, mi = _merge_lists(fd_all, gi_all, E)
        if cascade:
            # the GLOBAL promote trim: psum-assembled PCA mid-stage
            # scores over the merged PQ-space list
            qpca = qp[:, low.shape[-1] * 256:]
            dm = jax.lax.psum(
                jnp.where(lv[0],
                          _owned_dist_mid(lo2[0], offset[0], count[0],
                                          mi, qpca), 0.0), m_ax)
            md, mi = _global_promote(mi, dm, ef0 * rerank_mult)
        if deferred:
            dh = jax.lax.psum(
                jnp.where(lv[0],
                          _owned_dist_h(high[0], offset[0], count[0],
                                        mi, q), 0.0), m_ax)
            return _global_rerank(md, mi, dh, ef0)
        return md, mi

    n_l = len(sdb.adj)
    q_spec = P(b_ax, None)
    qp_spec = P(b_ax, *([None] * (qprep.ndim - 1)))
    in_specs = (
        [P(m_ax, None, None)] * n_l,          # adj
        [P(m_ax, None, None, None)] * n_l,    # packed_low
        P(m_ax, None, None), P(m_ax, None, None),
        P(m_ax), P(m_ax), P(m_ax),
        P(m_ax, None) if has_del else P(),
        P(m_ax, None, None) if cascade else P(),
        P(m_ax),                              # live
        q_spec, qp_spec,
    )
    out_specs = (P(b_ax, None), P(b_ax, None))
    fn = shard_map(local_search, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    dele = sdb.deleted if has_del else jnp.zeros((), jnp.int32)
    lo2 = sdb.low2 if cascade else jnp.zeros((), jnp.float32)
    return fn(sdb.adj, sdb.packed_low, sdb.low, sdb.high, sdb.entries,
              sdb.offsets, sdb.counts, dele, lo2, live, queries, qprep)


@functools.partial(jax.jit, static_argnames=("ef0", "k_schedule",
                                             "deferred", "rerank_mult",
                                             "promote_mult"))
def _host_search_jit(sdb, queries, qprep, live, ef0, k_schedule,
                     deferred, rerank_mult, promote_mult):
    """The meshless twin of ``_mesh_search_jit``: an unrolled loop over
    shards + the same merge, global promote (cascade), and global
    re-rank. all_gather == stack, psum == sum of the per-shard owned
    contributions (exactly one non-zero term per slot, so the float
    result is bit-equal). ``live`` [P] bool masks dead shards to
    (INF, -1) — data, not shape, so degraded mode never recompiles."""
    Pn = sdb.n_shards
    cascade = deferred and sdb.filter_kind == "cascade"
    fds, gis = [], []
    for s in range(Pn):
        fd, gi = _shard_lists(sdb.shard_db(s), sdb.offsets[s], queries,
                              qprep, ef0=ef0, ks=k_schedule,
                              deferred=deferred, rerank_mult=rerank_mult,
                              promote_mult=promote_mult)
        fds.append(jnp.where(live[s], fd, INF))
        gis.append(jnp.where(live[s], gi, -1))
    E = fds[0].shape[1]
    md, mi = _merge_lists(jnp.stack(fds), jnp.stack(gis), E)
    if cascade:
        qpca = qprep[:, sdb.low.shape[-1] * 256:]
        dm = jnp.zeros_like(md)
        for s in range(Pn):
            dm = dm + jnp.where(live[s],
                                _owned_dist_mid(sdb.low2[s],
                                                sdb.offsets[s],
                                                sdb.counts[s], mi, qpca),
                                0.0)
        md, mi = _global_promote(mi, dm, ef0 * rerank_mult)
    if deferred:
        dh = jnp.zeros_like(md)
        for s in range(Pn):
            dh = dh + jnp.where(live[s],
                                _owned_dist_h(sdb.high[s], sdb.offsets[s],
                                              sdb.counts[s], mi, queries),
                                0.0)
        return _global_rerank(md, mi, dh, ef0)
    return md, mi


def _prepare_qprep(sdb: ShardedDB, queries, q_low, filt):
    if q_low is not None:
        return q_low
    if filt is not None:
        if filt.kind != sdb.filter_kind:
            raise ValueError(f"filter mismatch: sharded db carries a "
                             f"{sdb.filter_kind!r} payload, filt is "
                             f"{filt.kind!r}")
        return filt.prepare_jnp(queries)
    if sdb.filter_kind == "none":
        return queries[:, :0].astype(jnp.float32)
    raise ValueError("q_low or filt required for the "
                     f"{sdb.filter_kind!r} filter")


def _norm_live(sdb: ShardedDB, live) -> jax.Array:
    """[P] bool live mask (default: everyone lives). Always a DATA
    argument of the compiled programs — all-live and degraded requests
    share one program."""
    if live is None:
        return jnp.ones((sdb.n_shards,), bool)
    return jnp.asarray(live).astype(bool)


def shard_live_counts(sdb: ShardedDB) -> np.ndarray:
    """[P] live (owned, non-tombstoned) row counts per shard — the
    denominator basis of the degraded-mode ``coverage`` stat. Counts
    each shard's ownership span minus the tombstone bits inside it
    (pad slots sit outside the span or are born tombstoned, so both
    frozen unequal shards and mutable capacity-padded shards report
    their true live population)."""
    counts = np.asarray(sdb.counts, np.int64)
    if sdb.deleted is None:
        return counts
    words = np.asarray(sdb.deleted).astype(np.uint32)       # [P, nw]
    bits = np.unpackbits(words.view(np.uint8), axis=1,
                         bitorder="little")                 # [P, nw*32]
    dead_in_span = np.array([int(bits[s, :counts[s]].sum())
                             for s in range(len(counts))], np.int64)
    return counts - dead_in_span


def coverage_stats(sdb: ShardedDB, live) -> dict:
    """The degraded-mode accounting attached to ``return_stats``
    results: ``coverage`` = fraction of the index's live vectors
    reachable through the surviving shards (exact, tombstone-aware),
    plus the raw masks/counts."""
    lc = shard_live_counts(sdb)
    lv = np.ones(sdb.n_shards, bool) if live is None \
        else np.asarray(live, bool)
    total = int(lc.sum())
    reach = int(lc[lv].sum())
    return {"coverage": reach / max(total, 1),
            "degraded": bool(~lv.all()),
            "live_shards": int(lv.sum()),
            "n_shards": sdb.n_shards,
            "live_mask": lv,
            "reachable": reach, "total_live": total}


def distributed_search(mesh: Mesh, sdb: ShardedDB, queries, q_low=None,
                       *, filt=None, ef0: int = 0, k_schedule=None,
                       deferred: Optional[bool] = None,
                       rerank_mult: Optional[int] = None,
                       promote_mult: Optional[int] = None,
                       live=None, return_stats: bool = False):
    """Sharded batched search over ``mesh``. queries: [B, D] global;
    ``q_low`` is the active filter's per-query prep (or pass ``filt``
    to compute it here; the identity filter needs neither). Returns
    (dists [B, ef0], GLOBAL idx [B, ef0]). On a 1-shard mesh this is
    bit-equal to single-shard ``search_batched`` for every filter kind
    and re-rank mode. ``live`` ([P] bool, optional) serves DEGRADED
    from the surviving shards only; with ``return_stats`` a third
    element carries the ``coverage`` accounting."""
    qprep = _prepare_qprep(sdb, queries, q_low, filt)
    ef0, ks, deferred, rm, pm = _normalize(sdb, ef0, k_schedule,
                                           deferred, rerank_mult,
                                           promote_mult)
    fd, fi = _mesh_search_jit(mesh, sdb, queries, qprep,
                              _norm_live(sdb, live), ef0, ks,
                              deferred, rm, pm)
    if return_stats:
        return fd, fi, coverage_stats(sdb, live)
    return fd, fi


def shard_search_host(sdb: ShardedDB, queries, q_low=None, *, filt=None,
                      ef0: int = 0, k_schedule=None,
                      deferred: Optional[bool] = None,
                      rerank_mult: Optional[int] = None,
                      promote_mult: Optional[int] = None,
                      live=None, return_stats: bool = False):
    """``distributed_search`` without a mesh: the same per-shard
    programs and the same merge, on however many devices exist (one is
    fine) — bit-equal to the mesh path. This is the simulated-shards
    entry point for single-device tests/benchmarks and the serving
    default when no mesh is configured. ``live`` / ``return_stats``:
    see ``distributed_search``."""
    qprep = _prepare_qprep(sdb, queries, q_low, filt)
    ef0, ks, deferred, rm, pm = _normalize(sdb, ef0, k_schedule,
                                           deferred, rerank_mult,
                                           promote_mult)
    fd, fi = _host_search_jit(sdb, queries, qprep,
                              _norm_live(sdb, live), ef0, ks,
                              deferred, rm, pm)
    if return_stats:
        return fd, fi, coverage_stats(sdb, live)
    return fd, fi


def search_cache_sizes() -> Tuple[int, int]:
    """(mesh, host) compiled-program cache sizes — the sharded
    zero-recompile assertions read these."""
    return (_mesh_search_jit._cache_size(),
            _host_search_jit._cache_size())


# ---------------------------------------------------------------------------
# the resilient per-shard path (serving plane, DESIGN.md § Fault
# tolerance): probe shards ONE AT A TIME so a failure costs exactly that
# shard's attempt, then merge whatever answered. One compiled probe
# program serves every shard (uniform stacked shapes, shard id is data),
# and the merge takes the answered mask as data — a kill/recover cycle
# never recompiles anything.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("ef0", "k_schedule",
                                             "deferred", "rerank_mult",
                                             "promote_mult"))
def _shard_probe_jit(sdb, s, queries, qprep, ef0, k_schedule, deferred,
                     rerank_mult, promote_mult):
    return _shard_lists(sdb.shard_db(s), sdb.offsets[s], queries, qprep,
                        ef0=ef0, ks=k_schedule, deferred=deferred,
                        rerank_mult=rerank_mult,
                        promote_mult=promote_mult)


def probe_shard(sdb: ShardedDB, s: int, queries, qprep, *, ef0: int = 0,
                k_schedule=None, deferred: Optional[bool] = None,
                rerank_mult: Optional[int] = None,
                promote_mult: Optional[int] = None, span=None
                ) -> Tuple[np.ndarray, np.ndarray, float]:
    """ONE shard's pre-merge candidate lists, timed and
    fault-injectable: the per-shard half of the resilient serving path
    (and the injection point of ``distributed.faults`` — kill raises,
    stall sleeps, corrupt garbles the return). Returns
    (fd [B, E], gi [B, E] GLOBAL ids, wall seconds); the wall time
    feeds the per-shard straggler monitor. ``span`` (a ``repro.obs``
    trace span, optional) receives a ``probe`` event with the measured
    wall time."""
    from repro.distributed import faults as _faults
    ef0, ks, deferred, rm, pm = _normalize(sdb, ef0, k_schedule,
                                           deferred, rerank_mult,
                                           promote_mult)
    plan = _faults.active()
    # the wall clock starts BEFORE the fault hook: an injected stall is
    # latency the coordinator actually observed, so it must feed the
    # straggler monitor like any real slow shard
    t0 = time.monotonic()
    if plan is not None:
        plan.shard_query_hook(s)
    fd, gi = _shard_probe_jit(sdb, jnp.int32(s), queries, qprep, ef0,
                              ks, deferred, rm, pm)
    gi.block_until_ready()
    wall = time.monotonic() - t0
    fd, gi = np.asarray(fd), np.asarray(gi)
    if plan is not None:
        fd, gi = plan.corrupt_hook(s, fd, gi)
    if span is not None:
        span.event("probe", shard=s, wall_ms=wall * 1e3)
    return fd, gi, wall


def check_shard_result(fd: np.ndarray, gi: np.ndarray, offset: int,
                       count: int) -> bool:
    """Merge-boundary integrity check of one shard's candidate lists:
    distances finite-or-sentinel, non-negative, ascending; ids either
    -1 (empty slot) or inside the shard's global ownership range. A
    shard failing this is treated as a ``ShardCorruptError`` — its
    answer never reaches the merge."""
    fd = np.asarray(fd)
    gi = np.asarray(gi)
    if np.isnan(fd).any() or (fd < 0).any():
        return False
    if (np.diff(fd, axis=1) < 0).any():
        return False
    ok = (gi == -1) | ((gi >= offset) & (gi < offset + count))
    return bool(ok.all())


@functools.partial(jax.jit, static_argnames=("ef0", "deferred",
                                             "cascade", "rerank_mult"))
def _merge_surviving_jit(fd_all, gi_all, live, high, offsets, counts,
                         low2, queries, qpca, ef0, deferred, cascade,
                         rerank_mult):
    """Merge the [P, B, E] per-shard stacks from ``probe_shard`` under
    an answered-mask: the same masking, merge, global promote
    (cascade), and deferred global re-rank as ``_host_search_jit`` —
    bit-equal to searching the survivor subset."""
    Pn = fd_all.shape[0]
    fd_all = jnp.where(live[:, None, None], fd_all, INF)
    gi_all = jnp.where(live[:, None, None], gi_all, -1)
    E = fd_all.shape[2]
    md, mi = _merge_lists(fd_all, gi_all, E)
    if cascade:
        dm = jnp.zeros_like(md)
        for s in range(Pn):
            dm = dm + jnp.where(live[s],
                                _owned_dist_mid(low2[s], offsets[s],
                                                counts[s], mi, qpca),
                                0.0)
        md, mi = _global_promote(mi, dm, ef0 * rerank_mult)
    if deferred:
        dh = jnp.zeros_like(md)
        for s in range(Pn):
            dh = dh + jnp.where(live[s],
                                _owned_dist_h(high[s], offsets[s],
                                              counts[s], mi, queries),
                                0.0)
        return _global_rerank(md, mi, dh, ef0)
    return md, mi


def merge_surviving(sdb: ShardedDB, fd_all, gi_all, live, queries, *,
                    qprep=None, ef0: int = 0, k_schedule=None,
                    deferred: Optional[bool] = None,
                    rerank_mult: Optional[int] = None,
                    promote_mult: Optional[int] = None):
    """Complete a request from the shards that answered: merge the
    stacked per-shard lists (dead/unanswered rows may hold anything —
    they are masked to (INF, -1) first) and run the global promote
    (cascade; needs ``qprep``, the same per-query prep handed to
    ``probe_shard``) plus the deferred global re-rank over the
    survivors. Returns ([B, ef0] dists, [B, ef0] GLOBAL ids)."""
    ef0, ks, deferred, rm, pm = _normalize(sdb, ef0, k_schedule,
                                           deferred, rerank_mult,
                                           promote_mult)
    cascade = deferred and sdb.filter_kind == "cascade"
    if cascade and qprep is None:
        raise ValueError("the deferred cascade merge needs qprep")
    low2 = sdb.low2 if cascade else jnp.zeros((), jnp.float32)
    qpca = (jnp.asarray(qprep)[:, sdb.low.shape[-1] * 256:] if cascade
            else jnp.zeros((queries.shape[0], 0), jnp.float32))
    return _merge_surviving_jit(jnp.asarray(np.asarray(fd_all)),
                                jnp.asarray(np.asarray(gi_all)),
                                _norm_live(sdb, live), sdb.high,
                                sdb.offsets, sdb.counts, low2, queries,
                                qpca, ef0, deferred, cascade, rm)


def resilient_cache_sizes() -> Tuple[int, int]:
    """(probe, merge) compiled-program cache sizes of the resilient
    path — the fault-cycle zero-recompile assertions read these."""
    return (_shard_probe_jit._cache_size(),
            _merge_surviving_jit._cache_size())
