"""Distributed pHNSW: database sharded across the mesh (the paper's
Section VI future work — "partitioning the billion-scale database into
smaller parts while preserving efficient coordination" — built here as a
first-class feature).

Scheme (scale-out ANN as deployed in practice):
  * the dataset is partitioned into P shards along the ``model`` axis;
    each shard gets its own independently-built HNSW graph (host-side,
    embarrassingly parallel at build time);
  * queries are sharded along the ``data`` (+``pod``) axes and
    REPLICATED along ``model``;
  * every device runs the fixed-shape batched pHNSW search
    (search_jax) over its local shard — identical compiled program, no
    cross-device traffic during traversal;
  * per-shard top-ef results are all-gathered over ``model`` and merged
    with one kSort.L pass (global index = shard offset + local index).

Collective cost per query batch: one all-gather of [P, B_local, ef]
(dist, idx) pairs — a few KB; the traversal itself is communication-free.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import List, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import PHNSWConfig
from repro.core.graph import build_hnsw
from repro.core.pca import PCA, fit_pca
from repro.core.search_jax import (PackedDB, PackedLayer, build_packed,
                                   _search_batched_impl)
from repro.kernels import ops


@dataclass
class ShardedDB:
    """Stacked per-shard databases: every leaf has leading dim P."""
    adj: List[jax.Array]          # per layer: [P, N, M_l]
    packed_low: List[jax.Array]   # per layer: [P, N, M_l, dl]
    low: jax.Array                # [P, N, dl]
    high: jax.Array               # [P, N, D]
    entries: jax.Array            # [P] int32
    offsets: jax.Array            # [P] int32 global-id offset per shard
    cfg: PHNSWConfig


def build_sharded(x: np.ndarray, cfg: PHNSWConfig, pca: PCA,
                  n_shards: int, *, seed: int = 0) -> ShardedDB:
    n = len(x)
    per = n // n_shards
    dbs = []
    offsets = []
    for s in range(n_shards):
        xs = x[s * per:(s + 1) * per]
        g = build_hnsw(xs, cfg, seed=seed + s)
        xl = pca.transform(xs).astype(np.float32)
        # keep layer counts uniform across shards for stacking
        dbs.append(build_packed(g, xl, drop_empty_layers=False))
        offsets.append(s * per)
    stack = lambda xs: jnp.stack(xs)
    n_layers = len(dbs[0].layers)
    return ShardedDB(
        adj=[stack([db.layers[l].adj for db in dbs])
             for l in range(n_layers)],
        packed_low=[stack([db.layers[l].packed_low for db in dbs])
                    for l in range(n_layers)],
        low=stack([db.low for db in dbs]),
        high=stack([db.high for db in dbs]),
        entries=jnp.asarray([db.entry for db in dbs], jnp.int32),
        offsets=jnp.asarray(offsets, jnp.int32),
        cfg=cfg,
    )


def distributed_search(mesh: Mesh, sdb: ShardedDB, queries, q_low,
                       *, ef0: int = 0, k_schedule=None):
    """queries: [B, D] global. Returns (dists [B, ef0], GLOBAL idx)."""
    cfg = sdb.cfg
    ef0 = ef0 or cfg.ef0
    ks = k_schedule or cfg.k_schedule
    b_ax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    m_ax = "model"

    def local_search(adj, packed_low, low, high, entry, offset, q, ql):
        # leaves arrive with the leading shard dim = 1: squeeze it
        layers = [PackedLayer(adj=a[0], packed_low=p[0])
                  for a, p in zip(adj, packed_low)]
        # the per-shard entry id is data (a traced scalar), which is
        # exactly what PackedDB.entry now is — the shared descent in
        # _search_batched_impl handles it directly
        db = PackedDB(layers=layers, low=low[0], high=high[0],
                      entry=entry[0], cfg=cfg)
        fd, fi, _, _ = _search_batched_impl(db, q, ql, ef0=ef0,
                                            k_schedule=ks)
        fi = jnp.where(fi >= 0, fi + offset[0], -1)
        # merge across shards: all-gather the per-shard top-ef
        fd_all = jax.lax.all_gather(fd, m_ax, axis=0)      # [P, B, ef]
        fi_all = jax.lax.all_gather(fi, m_ax, axis=0)
        Pn, B, E = fd_all.shape
        fd_c = jnp.moveaxis(fd_all, 0, 1).reshape(B, Pn * E)
        fi_c = jnp.moveaxis(fi_all, 0, 1).reshape(B, Pn * E)
        vals, sel = ops.ksort_l(fd_c, ef0)
        return vals, jnp.take_along_axis(fi_c, sel, axis=1)

    n_l = len(sdb.adj)
    in_specs = (
        [P(m_ax, None, None)] * n_l,          # adj
        [P(m_ax, None, None, None)] * n_l,    # packed_low
        P(m_ax, None, None), P(m_ax, None, None),
        P(m_ax), P(m_ax),
        P(b_ax, None), P(b_ax, None),
    )
    out_specs = (P(b_ax, None), P(b_ax, None))
    fn = shard_map(local_search, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    return fn(sdb.adj, sdb.packed_low, sdb.low, sdb.high, sdb.entries,
              sdb.offsets, queries, q_low)
