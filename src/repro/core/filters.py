"""Pluggable filter stage for the pHNSW traversal pipeline.

The paper's core idea is a *filter stage*: a cheap per-neighbor score
(PCA-projected distance) prunes candidates before expensive high-dim
re-ranking. This module makes that stage a first-class component with
three interchangeable implementations behind one contract:

  * ``PCAFilter``  — the paper's dense low-dim projection (Dist.L).
  * ``PQFilter``   — Flash [15]-style product quantization: uint8 codes
    scored by an on-device ADC gather-accumulate kernel.
  * ``IdentityFilter`` — filter bypass: every neighbor goes straight to
    Dist.H (the HNSW-Std behavior, kept as a measured baseline).

A ``FilterSpec`` owns (DESIGN.md § Filter-stage contract):

  * its **build-time payload** (``encode``): the per-vector rows stored
    in ``PackedDB.low`` and inlined per-neighbor in layout (3)
    (``PackedLayer.packed_low``) — dense f32/bf16 low-dim rows for PCA,
    uint8 codes for PQ, a zero-width array for identity;
  * its **per-query preparation** (``prepare`` / ``prepare_jnp``): PCA
    projection of the query vs. construction of the [S, 256] ADC
    lookup table (identity needs none);
  * its **device expand kernel** (``expand``): the fused
    Dist.L+mask+threshold+kSort.L kernel for PCA, the fused ADC kernel
    for PQ (the engine bypasses the kernel entirely for identity);
  * its **cost-model pricing**: ``bytes_per_vec`` (layout-(3) inline
    payload bytes, the dominant sequential-burst stream) and
    ``cost_dims`` (per-point filter-distance pipeline depth).

``search_ref`` uses ``dists`` (the host numpy oracle) so the reference
and batched engines share one filter definition per kind.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np
import jax.numpy as jnp

from repro.configs.base import PHNSWConfig
from repro.core.pca import PCA, fit_pca
from repro.core.pq import (PQCodebook, adc_table_batch,
                           adc_tables_from_centroids, encode_pq,
                           train_pq)
from repro.kernels import ops


class FilterSpec:
    """Contract shared by the three filter kinds. ``kind`` is the
    static string that keys the compiled search program (a structural
    property: each kind compiles a different expand pipeline)."""

    kind: str = "?"

    # --- build-time payload -------------------------------------------------
    def encode(self, x: np.ndarray) -> np.ndarray:
        """x [N, D] -> payload rows [N, P] (host array; P may be 0)."""
        raise NotImplementedError

    @property
    def payload_dtype(self) -> np.dtype:
        raise NotImplementedError

    @property
    def bytes_per_vec(self) -> int:
        """Layout-(3) inline payload bytes per vector (DRAM pricing)."""
        raise NotImplementedError

    @property
    def cost_dims(self) -> int:
        """Per-point filter-distance pipeline depth for the processor
        cost model (d_low for PCA, n_sub table lookups for PQ)."""
        raise NotImplementedError

    # --- per-query preparation ----------------------------------------------
    def prepare(self, q: np.ndarray) -> np.ndarray:
        """q [B, D] -> host per-query filter data (f32)."""
        raise NotImplementedError

    def prepare_jnp(self, q):
        """Device-side ``prepare`` (jnp in, jnp out)."""
        raise NotImplementedError

    # --- host distance oracle (search_ref) ----------------------------------
    def dists(self, qprep_row: np.ndarray, payload: np.ndarray
              ) -> np.ndarray:
        """One query's filter distances: qprep_row = prepare(q)[i],
        payload [M, P] -> [M] f32."""
        raise NotImplementedError

    # --- device expand kernel (search_jax) ----------------------------------
    def expand(self, nb_payload, qprep, valid, th, k: int):
        """The fused expansion filter stage for this kind (see
        ``ops.fused_expand`` / ``ops.pq_adc_expand``)."""
        raise NotImplementedError


@dataclass
class PCAFilter(FilterSpec):
    """The paper's filter: dense projection to d_low dims."""
    pca: PCA
    low_dtype: str = "float32"   # device storage dtype of the payload

    kind = "pca"

    def encode(self, x):
        return self.pca.transform(x).astype(np.float32)

    @property
    def payload_dtype(self):
        return np.dtype(np.float32)

    @property
    def bytes_per_vec(self):
        return self.pca.d_low * jnp.dtype(self.low_dtype).itemsize

    @property
    def cost_dims(self):
        return self.pca.d_low

    def prepare(self, q):
        return self.pca.transform(q).astype(np.float32)

    def prepare_jnp(self, q):
        return self.pca.transform_jnp(q).astype(jnp.float32)

    def dists(self, qprep_row, payload):
        d = payload.astype(np.float32) - qprep_row
        return np.einsum("ij,ij->i", d, d)

    def expand(self, nb_payload, qprep, valid, th, k):
        return ops.fused_expand(nb_payload, qprep, valid, th, k)


@dataclass
class PQFilter(FilterSpec):
    """Flash-style PQ filter: n_sub uint8 codes per vector, scored with
    per-query ADC lookup tables."""
    cb: PQCodebook
    _cents_jnp: Optional[jnp.ndarray] = field(
        default=None, init=False, repr=False, compare=False)

    kind = "pq"

    def encode(self, x):
        return encode_pq(self.cb, x)

    @property
    def payload_dtype(self):
        return np.dtype(np.uint8)

    @property
    def bytes_per_vec(self):
        return self.cb.bytes_per_vec

    @property
    def cost_dims(self):
        return self.cb.n_sub

    def prepare(self, q):
        return adc_table_batch(self.cb, q)

    def prepare_jnp(self, q):
        # codebook uploaded once (same caching story as PCA.transform_jnp)
        if self._cents_jnp is None:
            self._cents_jnp = jnp.asarray(self.cb.centroids)
        return adc_tables_from_centroids(self._cents_jnp, q, jnp)

    def dists(self, qprep_row, payload):
        S = qprep_row.shape[0]
        return qprep_row[np.arange(S)[None, :],
                         payload.astype(np.int64)].sum(1)

    def expand(self, nb_payload, qprep, valid, th, k):
        return ops.pq_adc_expand(nb_payload, qprep, valid, th, k)


@dataclass
class CascadeFilter(FilterSpec):
    """Multi-stage cascade (AQR-HNSW-style, see PAPERS.md): traverse on
    cheap PQ codes, promote the surviving ``promote_mult * ef``
    candidates through a PCA mid-stage score once per layer-0 exit (not
    per step), and defer Dist.H to ONE final batched pass of
    ``rerank_mult * k`` survivors — PQ-class bytes/vec on the hot
    stream at PCA-class recall.

    Two build-time payloads:

      * **inline** (``encode``): uint8 PQ codes — the layout-(3)
        per-neighbor stream the traversal touches every step;
      * **side-car** (``encode_mid``): f32 PCA rows, stored OFF the hot
        stream (``PackedDB.low2``) and gathered once per query at the
        promote stage.

    Per-query prep is ONE flat f32 row ``[n_sub*256 + d_low]`` — the
    ADC tables flattened, then the PCA-projected query. The engine
    slices it statically on ``n_sub`` (= the inline payload width); a
    single array keeps the slot-state scatter and the shard_map specs
    rank-generic.
    """
    cb: PQCodebook
    pca: PCA
    _cents_jnp: Optional[jnp.ndarray] = field(
        default=None, init=False, repr=False, compare=False)

    kind = "cascade"

    # --- inline payload: PQ codes (what the traversal streams) --------------
    def encode(self, x):
        return encode_pq(self.cb, x)

    @property
    def payload_dtype(self):
        return np.dtype(np.uint8)

    @property
    def bytes_per_vec(self):
        return self.cb.bytes_per_vec       # inline codes only

    @property
    def cost_dims(self):
        return self.cb.n_sub               # in-loop ADC depth

    # --- side-car payload: PCA rows (the promote stage) ---------------------
    def encode_mid(self, x):
        return self.pca.transform(x).astype(np.float32)

    @property
    def mid_bytes_per_vec(self):
        return self.pca.d_low * 4          # f32 side-car rows

    @property
    def mid_cost_dims(self):
        return self.pca.d_low

    # --- per-query preparation: flat concat (luts | projected query) --------
    def prepare(self, q):
        luts = adc_table_batch(self.cb, q)
        qp = self.pca.transform(q).astype(np.float32)
        return np.concatenate([luts.reshape(len(q), -1), qp], axis=1)

    def prepare_jnp(self, q):
        if self._cents_jnp is None:
            self._cents_jnp = jnp.asarray(self.cb.centroids)
        luts = adc_tables_from_centroids(self._cents_jnp, q, jnp)
        qp = self.pca.transform_jnp(q).astype(jnp.float32)
        return jnp.concatenate([luts.reshape(q.shape[0], -1), qp],
                               axis=1)

    # --- host oracles --------------------------------------------------------
    def dists(self, qprep_row, payload):
        S = self.cb.n_sub
        lut = qprep_row[:S * 256].reshape(S, 256)
        return lut[np.arange(S)[None, :],
                   payload.astype(np.int64)].sum(1)

    def mid_dists(self, qprep_row, payload_mid):
        """Promote-stage distances: PCA rows vs the projected query."""
        qp = qprep_row[self.cb.n_sub * 256:]
        d = payload_mid.astype(np.float32) - qp
        return np.einsum("ij,ij->i", d, d)

    def expand(self, nb_payload, qprep, valid, th, k):
        S = self.cb.n_sub
        lut = qprep[:, :S * 256].reshape(qprep.shape[0], S, 256)
        return ops.pq_adc_expand(nb_payload, lut, valid, th, k)


@dataclass
class IdentityFilter(FilterSpec):
    """Filter bypass: no payload, no per-query prep, no expand kernel.
    The engine skips the C_pca stage entirely and ranks every valid
    neighbor in high dim — HNSW-Std as a pluggable baseline. Its
    'filter distance' IS the high-dim distance, so deferred re-ranking
    degenerates to per-step behavior (with a wider final list)."""
    dim: int = 0                 # high dim, for cost_dims

    kind = "none"

    def encode(self, x):
        return np.zeros((len(x), 0), np.float32)

    @property
    def payload_dtype(self):
        return np.dtype(np.float32)

    @property
    def bytes_per_vec(self):
        return 0

    @property
    def cost_dims(self):
        return self.dim

    def prepare(self, q):
        return q.astype(np.float32)[:, :0]     # [B, 0] — unused

    def prepare_jnp(self, q):
        return q.astype(jnp.float32)[:, :0]

    def dists(self, qprep_row, payload):
        raise RuntimeError("identity filter has no filter distances; "
                           "the engine ranks in high dim directly")

    def expand(self, nb_payload, qprep, valid, th, k):
        raise RuntimeError("identity filter bypasses the expand kernel")


def make_filter(cfg: PHNSWConfig, x: np.ndarray, *,
                pca: Optional[PCA] = None, seed: int = 0,
                levels: Optional[np.ndarray] = None) -> FilterSpec:
    """Fit the filter selected by ``cfg.filter_kind`` on the dataset.
    A pre-fit ``pca`` is adopted (avoids double fits when callers
    already hold one). ``levels`` (optional, [n] per-point HNSW level
    assignment) trains PQ codebooks density-aware: points are weighted
    by graph-layer occupancy (``level + 1`` — the number of layers the
    node appears on, hence how often the traversal streams its codes)."""

    def _train_cb():
        # seeded RANDOM subsample, not a prefix: the sharded build
        # shares one codebook across shards partitioned contiguously
        # from x, so a prefix sample would train on the first shard(s)
        # only and skew cross-shard ADC comparability
        weights = None if levels is None else \
            np.asarray(levels, np.float64) + 1.0
        n_train = min(len(x), 20_000)
        if n_train == len(x):
            xt, wt = x, weights
        else:
            perm = np.random.default_rng(seed).permutation(
                len(x))[:n_train]
            xt = x[perm]
            wt = None if weights is None else weights[perm]
        return train_pq(xt, cfg.pq_n_sub,
                        iters=cfg.pq_train_iters, seed=seed, weights=wt)

    if cfg.filter_kind == "pca":
        return PCAFilter(pca or fit_pca(x, cfg.d_low),
                         low_dtype=cfg.low_dtype)
    if cfg.filter_kind == "pq":
        return PQFilter(_train_cb())
    if cfg.filter_kind == "cascade":
        return CascadeFilter(_train_cb(), pca or fit_pca(x, cfg.d_low))
    if cfg.filter_kind == "none":
        return IdentityFilter(dim=x.shape[1])
    raise ValueError(f"unknown filter kind {cfg.filter_kind!r}")
