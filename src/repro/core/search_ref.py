"""Reference (host, numpy) search implementations with instrumentation.

Three searchers over the same HNSW graph:
  * ``search_hnsw``   — standard HNSW (paper baseline [2]): all neighbor
    distances in HIGH-dim space; per expansion the neighbor index list is
    one sequential burst, then M irregular high-dim vector fetches.
  * ``search_phnsw``  — Algorithm 1: neighbor distances in LOW-dim space,
    top-k filter (kSort.L), only k candidates re-ranked in high-dim.
    ``layout="packed"`` = paper layout (3): indices + low-dim vectors
    inline -> ONE sequential burst per expansion. ``layout="separate"`` =
    pKNN layout (4): index burst + M irregular low-dim fetches.

Every searcher fills a ``SearchStats`` with algorithmic counts and DRAM
access events; ``core/cost_model.py`` turns those into QPS / energy for
the pHNSW processor configurations of Table III / Fig 5.

Interpretation note on Algorithm 1 (documented deviation): the paper
carries ``C_pca`` across iterations as the filter threshold heap (lines
5, 20, 24) but does not pin its capacity; we bound it at k (matching the
fixed-size kSort.L register file) and use its max as ``f_pca``. Ties in
the filter are broken by index, making the top-k deterministic.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field, asdict
from typing import List, Literal, Optional, Tuple

import numpy as np

from repro.configs.base import PHNSWConfig
from repro.core.graph import HNSWGraph
from repro.core.pca import PCA

IDX_BYTES = 4
F32 = 4


@dataclass
class SearchStats:
    """Algorithmic + memory-event counters for ONE query."""
    expansions: int = 0          # node expansions (step-2 loops)
    dist_high: int = 0           # high-dim distance computations
    dist_low: int = 0            # low-dim (in-loop filter) distances
    dist_mid: int = 0            # cascade promote-stage distances
    ksort_calls: int = 0         # kSort.L invocations
    minh_calls: int = 0          # Min.H invocations
    visit_checks: int = 0        # Visit&Raw SPM reads
    f_updates: int = 0           # F-list inserts (RMF on eviction)
    evictions: int = 0
    seq_bursts: int = 0          # sequential DRAM bursts
    seq_bytes: int = 0
    rand_accesses: int = 0       # irregular DRAM accesses
    rand_bytes: int = 0

    def add(self, other: "SearchStats"):
        for k, v in asdict(other).items():
            setattr(self, k, getattr(self, k) + v)

    def as_dict(self):
        return asdict(self)


def _d2(a, b):
    d = a - b
    return float(np.dot(d, d))


def _d2_rows(x, q):
    d = x - q
    return np.einsum("ij,ij->i", d, d)


# ---------------------------------------------------------------------------
# standard HNSW layer search (baseline [2] / HNSW-Std hardware variant)
# ---------------------------------------------------------------------------

def _hnsw_layer(g: HNSWGraph, q: np.ndarray, eps: List[int], ef: int,
                layer: int, st: SearchStats,
                hw_mode: bool = False,
                deleted: Optional[np.ndarray] = None) -> List[Tuple[float, int]]:
    """hw_mode=True models the HNSW-Std accelerator baseline ([5],[6] as
    characterized in Section IV-B2): the DMA fetches high-dim data for
    ALL M neighbors of the expanded node before the visited check (the
    V-list lives with the raw data in SPM), so fetch/distance counts are
    per-neighbor, not per-unvisited-neighbor. The traversal itself is
    identical.

    ``deleted`` ([N] bool, optional): tombstone semantics — deleted
    nodes are traversed (pushed to the candidate heap, expanded) but
    never enter the result heap."""
    adj = g.layers[layer]
    dim = g.x.shape[1]
    live = (lambda e: True) if deleted is None \
        else (lambda e: not deleted[e])
    visited = set(eps)
    cand = []
    best = []
    for e in eps:
        d = _d2(g.x[e], q)
        st.dist_high += 1
        st.rand_accesses += 1
        st.rand_bytes += dim * F32
        heapq.heappush(cand, (d, e))
        if live(e):
            heapq.heappush(best, (-d, e))
    while cand:
        d_c, c = heapq.heappop(cand)
        d_f = -best[0][0] if best else np.inf
        if d_c > d_f and len(best) >= ef:
            break
        st.expansions += 1
        neigh = adj[c]
        neigh = neigh[neigh >= 0]
        # one sequential burst for the index list
        st.seq_bursts += 1
        st.seq_bytes += adj.shape[1] * IDX_BYTES
        new = [int(e) for e in neigh if e not in visited]
        st.visit_checks += len(neigh)
        visited.update(new)
        # irregular fetches + high-dim distances: all M neighbors in
        # hw_mode, unvisited only in software mode
        n_fetch = len(neigh) if hw_mode else len(new)
        st.rand_accesses += n_fetch
        st.rand_bytes += n_fetch * dim * F32
        st.dist_high += n_fetch
        if not new:
            continue
        ds = _d2_rows(g.x[new], q)
        for d_e, e in zip(ds, new):
            d_f = -best[0][0] if best else np.inf
            if d_e < d_f or len(best) < ef:
                heapq.heappush(cand, (float(d_e), e))
                if live(e):
                    heapq.heappush(best, (-float(d_e), e))
                    st.f_updates += 1
                    if len(best) > ef:
                        heapq.heappop(best)
                        st.evictions += 1
    return sorted([(-d, e) for d, e in best])


def search_hnsw(g: HNSWGraph, q: np.ndarray, *, ef0: Optional[int] = None,
                hw_mode: bool = False,
                deleted: Optional[np.ndarray] = None
                ) -> Tuple[np.ndarray, SearchStats]:
    cfg = g.cfg
    st = SearchStats()
    ep = [g.entry]
    top = int(g.levels.max())
    for layer in range(top, 0, -1):
        res = _hnsw_layer(g, q, ep, cfg.ef_for_layer(layer), layer, st,
                          hw_mode)
        ep = [res[0][1]]
    # only the output layer filters tombstones; upper layers just route
    res = _hnsw_layer(g, q, ep, ef0 or cfg.ef0, 0, st, hw_mode,
                      deleted=deleted)
    return np.array([e for _, e in res], np.int64), st


# ---------------------------------------------------------------------------
# pHNSW Algorithm 1, generalized over the pluggable filter stage
# ---------------------------------------------------------------------------

def _filter_layer(g: HNSWGraph, filt, payload: np.ndarray, q: np.ndarray,
                  qprep: np.ndarray, eps: List[int], ef: int, k: int,
                  layer: int, st: SearchStats,
                  layout: Literal["packed", "separate"],
                  deleted: Optional[np.ndarray] = None,
                  deferred: bool = False) -> List[Tuple[float, int]]:
    """One Algorithm-1 layer for any ``core.filters.FilterSpec`` with a
    real filter stage ("pca"/"pq"; the identity bypass routes through
    ``_hnsw_layer`` instead).

    Per-step mode (the paper): C/F are keyed on HIGH-dim distances,
    the filter only prunes which neighbors get re-ranked. ``deferred``
    keys the whole traversal (C, F, the acceptance bound) on FILTER
    distances and computes no high-dim distance at all — the caller
    re-ranks the final list once."""
    adj = g.layers[layer]
    M = adj.shape[1]
    dim = g.x.shape[1]
    pb = filt.bytes_per_vec         # layout-(3) inline payload bytes
    live = (lambda e: True) if deleted is None \
        else (lambda e: not deleted[e])
    visited = set(eps)
    C: List[Tuple[float, int]] = []      # candidate min-heap
    F: List[Tuple[float, int]] = []      # final max-heap (neg dist)
    C_pca: List[Tuple[float, int]] = []  # filter-threshold max-heap
    for e in eps:
        dl = float(filt.dists(qprep, payload[[e]])[0])
        st.dist_low += 1
        if deferred:
            key = dl
        else:
            key = _d2(g.x[e], q)
            st.dist_high += 1
            st.rand_accesses += 1
            st.rand_bytes += dim * F32
        heapq.heappush(C, (key, e))
        if live(e):
            heapq.heappush(F, (-key, e))
        heapq.heappush(C_pca, (-dl, e))
    while C:
        d_c, c = heapq.heappop(C)
        d_f = -F[0][0] if F else np.inf
        if d_c > d_f and len(F) >= ef:
            break                                     # lines 7-8
        st.expansions += 1
        neigh = adj[c]
        neigh = neigh[neigh >= 0]
        if layout == "packed":
            # layout (3): indices + inline payload in ONE burst
            st.seq_bursts += 1
            st.seq_bytes += M * (IDX_BYTES + pb)
        else:
            # layout (4): index burst + M irregular payload fetches
            st.seq_bursts += 1
            st.seq_bytes += M * IDX_BYTES
            st.rand_accesses += len(neigh)
            st.rand_bytes += len(neigh) * pb
        if len(neigh) == 0:
            continue
        # ---- step 2: filter distances + top-k filter (lines 10-13) ----
        nl = [int(e) for e in neigh]
        dls = filt.dists(qprep, payload[nl])
        st.dist_low += len(nl)
        # threshold is only meaningful once the k-bounded heap is full
        f_pca = -C_pca[0][0] if len(C_pca) >= k else np.inf
        keep = [(float(d), e) for d, e in zip(dls, nl) if d < f_pca]
        st.ksort_calls += 1                           # kSort.L, 7 cycles
        keep.sort()                                   # deterministic top-k
        topk = keep[:k]
        # ---- step 3: the k survivors — high-dim re-rank per step, or
        # filter-space acceptance when deferred (lines 15-23) ----
        for dl_m, m in topk:
            st.visit_checks += 1
            if m in visited:
                continue
            visited.add(m)
            if deferred:
                key_m = dl_m
            else:
                st.rand_accesses += 1                 # high-dim fetch
                st.rand_bytes += dim * F32
                key_m = _d2(g.x[m], q)
                st.dist_high += 1
                st.minh_calls += 1
            d_f = -F[0][0] if F else np.inf
            if key_m < d_f or len(F) < ef:
                heapq.heappush(C, (key_m, m))
                if live(m):
                    heapq.heappush(F, (-key_m, m))
                    st.f_updates += 1
                    if len(F) > ef:
                        heapq.heappop(F)
                        st.evictions += 1
                # C_pca_tmp: bounded-k filter threshold heap (line 20/24)
                heapq.heappush(C_pca, (-dl_m, m))
                if len(C_pca) > k:
                    heapq.heappop(C_pca)
    return sorted([(-d, e) for d, e in F])


def _promote_trim(filt, qprep, payload_mid, ids, n_keep: int,
                  st: SearchStats) -> np.ndarray:
    """The cascade's promote stage, host oracle: score the candidates'
    side-car PCA rows against the projected query and keep the best
    ``n_keep`` (stable sort — exact mid-score ties keep the incoming
    PQ-space order, mirroring the batched engine's slot-order
    tie-break). Accounts one irregular side-car fetch + one low-dim
    distance per candidate."""
    mids = payload_mid[ids]
    dm = filt.mid_dists(qprep, mids)
    st.dist_mid += len(ids)
    st.rand_accesses += len(ids)
    st.rand_bytes += len(ids) * filt.mid_bytes_per_vec
    return ids[np.argsort(dm, kind="stable")][:n_keep]


def search_filtered(g: HNSWGraph, filt, payload: Optional[np.ndarray],
                    q: np.ndarray, *,
                    layout: Literal["packed", "separate"] = "packed",
                    k_schedule: Optional[Tuple[int, ...]] = None,
                    ef0: Optional[int] = None,
                    deleted: Optional[np.ndarray] = None,
                    deferred: bool = False, rerank_mult: int = 1,
                    promote_mult: int = 1,
                    payload_mid: Optional[np.ndarray] = None,
                    final_rerank: bool = True
                    ) -> Tuple[np.ndarray, SearchStats]:
    """Reference search under any filter x rerank combination — the
    host oracle the batched engine is tested against.

    ``payload = filt.encode(x)`` is passed in (encoded once per
    database, like the graph). The identity filter routes to the plain
    HNSW traversal (its 'filter distance' IS the high-dim distance, so
    deferred mode is a no-op). Deferred mode widens the layer-0 result
    list to ``rerank_mult * ef0`` filter-space candidates, then
    re-ranks them with high-dim distances in one batch;
    ``final_rerank=False`` skips that re-rank and returns the WIDE
    filter-space list (ascending filter distance) — the sharded oracle
    merges per-shard lists first and re-ranks once globally.

    The deferred CASCADE (``filt.kind == "cascade"``; needs
    ``payload_mid = filt.encode_mid(x)``) widens layer 0 further to
    ``promote_mult * ef0`` PQ-space candidates and trims them back to
    ``rerank_mult * ef0`` with the PCA mid-stage score (ONE batch per
    query) before the single Dist.H pass."""
    cfg = g.cfg
    if filt.kind == "none":
        return search_hnsw(g, q, ef0=ef0, deleted=deleted)
    cascade = deferred and filt.kind == "cascade"
    if cascade:
        assert payload_mid is not None, \
            "the deferred cascade oracle needs payload_mid"
        promote_mult = max(int(promote_mult), int(rerank_mult))
    st = SearchStats()
    qprep = filt.prepare(q[None])[0]
    ks = k_schedule or cfg.k_schedule_for(filt.kind, deferred)
    k_of = lambda l: ks[min(l, len(ks) - 1)]
    ep = [g.entry]
    top = int(g.levels.max())
    for layer in range(top, 0, -1):
        res = _filter_layer(g, filt, payload, q, qprep, ep,
                            cfg.ef_for_layer(layer), k_of(layer), layer,
                            st, layout, deferred=deferred)
        ep = [res[0][1]]
    # tombstones filter only at the output layer (upper layers route)
    ef_out = ef0 or cfg.ef0
    wide_mult = promote_mult if cascade else rerank_mult
    ef_run = ef_out * wide_mult if deferred else ef_out
    res = _filter_layer(g, filt, payload, q, qprep, ep, ef_run, k_of(0),
                        0, st, layout, deleted=deleted, deferred=deferred)
    ids = np.array([e for _, e in res], np.int64)
    if deferred and not final_rerank:
        return ids, st
    if cascade and len(ids):
        ids = _promote_trim(filt, qprep, payload_mid, ids,
                            ef_out * rerank_mult, st)
    if deferred and len(ids):
        # the deferred high-dim re-rank: ONE batch of Dist.H over the
        # final filter-space list (stable sort keeps the filter order
        # on exact ties, mirroring the batched engine's slot order)
        dim = g.x.shape[1]
        dh = _d2_rows(g.x[ids], q)
        st.dist_high += len(ids)
        st.rand_accesses += len(ids)
        st.rand_bytes += len(ids) * dim * F32
        ids = ids[np.argsort(dh, kind="stable")][:ef_out]
    return ids, st


def search_phnsw(g: HNSWGraph, x_low: np.ndarray, pca: PCA, q: np.ndarray,
                 *, layout: Literal["packed", "separate"] = "packed",
                 k_schedule: Optional[Tuple[int, ...]] = None,
                 ef0: Optional[int] = None,
                 deleted: Optional[np.ndarray] = None,
                 deferred: bool = False, rerank_mult: int = 1
                 ) -> Tuple[np.ndarray, SearchStats]:
    """The seed API: pHNSW with the paper's PCA filter (a thin wrapper
    over ``search_filtered``)."""
    from repro.core.filters import PCAFilter
    filt = PCAFilter(pca, low_dtype=g.cfg.low_dtype)
    return search_filtered(g, filt, x_low, q, layout=layout,
                           k_schedule=k_schedule, ef0=ef0,
                           deleted=deleted, deferred=deferred,
                           rerank_mult=rerank_mult)


# ---------------------------------------------------------------------------
# sharded oracle (host mirror of core/distributed.py)
# ---------------------------------------------------------------------------

def search_sharded(graphs, filt, payloads, q: np.ndarray, *,
                   k_schedule: Optional[Tuple[int, ...]] = None,
                   ef0: Optional[int] = None,
                   deleted=None,
                   deferred: bool = False, rerank_mult: int = 1,
                   promote_mult: int = 1, payload_mids=None
                   ) -> Tuple[np.ndarray, SearchStats]:
    """The sharded reference: ``search_filtered`` per shard + the
    host-side cross-shard merge, mirroring ``distributed_search``
    exactly — per-shard lists (high-dim keyed normally, WIDE
    filter-space keyed when deferred), a global merge with ties broken
    by (lower shard, lower slot), and when deferred ONE global high-dim
    re-rank over the merged list. The deferred cascade (needs
    ``payload_mids``, per-shard ``filt.encode_mid`` rows) merges the
    per-shard ``promote_mult * ef0`` lists on PQ distances, runs the
    PCA promote trim ONCE globally over the merged list, then the
    single global Dist.H pass — promote and re-rank both happen after
    the cross-shard merge, exactly like the device path's psum stages.

    ``graphs``: per-shard ``HNSWGraph`` (independent builds over ONE
    shared ``filt``); ``payloads``: per-shard ``filt.encode`` rows;
    ``deleted``: per-shard [n_s] bool masks or None. Returned ids are
    GLOBAL (shard offset = cumulative shard sizes)."""
    cfg = graphs[0].cfg
    ef_out = ef0 or cfg.ef0
    deferred = deferred and filt.kind != "none"
    cascade = deferred and filt.kind == "cascade"
    if cascade:
        promote_mult = max(int(promote_mult), int(rerank_mult))
    wide_mult = promote_mult if cascade else rerank_mult
    E = ef_out * wide_mult if deferred else ef_out
    qprep = filt.prepare(q[None])[0] if filt.kind != "none" else None
    tot = SearchStats()
    keys, shards, slots, gids, locs = [], [], [], [], []
    offset = 0
    for s, g in enumerate(graphs):
        dele = deleted[s] if deleted is not None else None
        ids, st = search_filtered(g, filt, payloads[s], q,
                                  k_schedule=k_schedule, ef0=ef0,
                                  deleted=dele, deferred=deferred,
                                  rerank_mult=rerank_mult,
                                  promote_mult=promote_mult,
                                  payload_mid=None if payload_mids is
                                  None else payload_mids[s],
                                  final_rerank=False)
        tot.add(st)
        if len(ids):
            if deferred:
                k = filt.dists(qprep, payloads[s][ids])
            else:
                k = _d2_rows(g.x[ids], q)
            keys.append(k.astype(np.float64))
            shards.append(np.full(len(ids), s))
            slots.append(np.arange(len(ids)))
            gids.append(ids + offset)
            locs.append(ids)
        offset += len(g.x)
    if not keys:
        # every shard came back empty (e.g. a fully tombstoned index);
        # the batched engine returns pad ids for the same input
        return np.empty(0, np.int64), tot
    key = np.concatenate(keys)
    shard = np.concatenate(shards)
    slot = np.concatenate(slots)
    gid = np.concatenate(gids)
    loc = np.concatenate(locs)
    order = np.lexsort((slot, shard, key))[:E]
    if cascade:
        # the GLOBAL promote trim: PCA mid-stage scores over the merged
        # PQ-space list (stable — merge-order ties preserved)
        mids = np.stack([payload_mids[shard[i]][loc[i]] for i in order])
        dm = filt.mid_dists(qprep, mids)
        tot.dist_mid += len(order)
        tot.rand_accesses += len(order)
        tot.rand_bytes += len(order) * filt.mid_bytes_per_vec
        order = order[np.argsort(dm, kind="stable")][
            :ef_out * rerank_mult]
    if deferred:
        # ONE global batched Dist.H over the merged filter-space list
        xh = np.stack([graphs[shard[i]].x[loc[i]] for i in order])
        dh = _d2_rows(xh, q)
        tot.dist_high += len(order)
        tot.rand_accesses += len(order)
        tot.rand_bytes += len(order) * q.shape[0] * F32
        order = order[np.argsort(dh, kind="stable")][:ef_out]
    return gid[order], tot


# ---------------------------------------------------------------------------
# batch helpers
# ---------------------------------------------------------------------------

def recall_at(found: np.ndarray, truth: np.ndarray, at: int) -> float:
    """found: [k_found] indices; truth: [at] ground-truth indices."""
    return len(set(found[:at].tolist()) & set(truth[:at].tolist())) / at


def run_queries(g: HNSWGraph, queries: np.ndarray, truth: np.ndarray,
                *, algo: str = "phnsw", x_low=None, pca=None,
                layout="packed", k_schedule=None, hw_mode: bool = False,
                deleted: Optional[np.ndarray] = None,
                filt=None, payload=None, deferred: bool = False,
                rerank_mult: int = 1, promote_mult: int = 1,
                payload_mid=None):
    """Run all queries; returns (mean recall@cfg.recall_at, total
    stats). ``algo="filtered"`` (with ``filt``/``payload``) runs the
    generalized filter x rerank oracle; "phnsw"/"hnsw" keep the seed
    behavior."""
    cfg = g.cfg
    tot = SearchStats()
    recs = []
    for i, q in enumerate(queries):
        if algo == "hnsw":
            found, st = search_hnsw(g, q, hw_mode=hw_mode,
                                    deleted=deleted)
        elif algo == "filtered":
            found, st = search_filtered(g, filt, payload, q,
                                        layout=layout,
                                        k_schedule=k_schedule,
                                        deleted=deleted,
                                        deferred=deferred,
                                        rerank_mult=rerank_mult,
                                        promote_mult=promote_mult,
                                        payload_mid=payload_mid)
        else:
            found, st = search_phnsw(g, x_low, pca, q, layout=layout,
                                     k_schedule=k_schedule,
                                     deleted=deleted, deferred=deferred,
                                     rerank_mult=rerank_mult)
        tot.add(st)
        recs.append(recall_at(found, truth[i], cfg.recall_at))
    return float(np.mean(recs)), tot
