"""Reference (host, numpy) search implementations with instrumentation.

Three searchers over the same HNSW graph:
  * ``search_hnsw``   — standard HNSW (paper baseline [2]): all neighbor
    distances in HIGH-dim space; per expansion the neighbor index list is
    one sequential burst, then M irregular high-dim vector fetches.
  * ``search_phnsw``  — Algorithm 1: neighbor distances in LOW-dim space,
    top-k filter (kSort.L), only k candidates re-ranked in high-dim.
    ``layout="packed"`` = paper layout (3): indices + low-dim vectors
    inline -> ONE sequential burst per expansion. ``layout="separate"`` =
    pKNN layout (4): index burst + M irregular low-dim fetches.

Every searcher fills a ``SearchStats`` with algorithmic counts and DRAM
access events; ``core/cost_model.py`` turns those into QPS / energy for
the pHNSW processor configurations of Table III / Fig 5.

Interpretation note on Algorithm 1 (documented deviation): the paper
carries ``C_pca`` across iterations as the filter threshold heap (lines
5, 20, 24) but does not pin its capacity; we bound it at k (matching the
fixed-size kSort.L register file) and use its max as ``f_pca``. Ties in
the filter are broken by index, making the top-k deterministic.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field, asdict
from typing import List, Literal, Optional, Tuple

import numpy as np

from repro.configs.base import PHNSWConfig
from repro.core.graph import HNSWGraph
from repro.core.pca import PCA

IDX_BYTES = 4
F32 = 4


@dataclass
class SearchStats:
    """Algorithmic + memory-event counters for ONE query."""
    expansions: int = 0          # node expansions (step-2 loops)
    dist_high: int = 0           # high-dim distance computations
    dist_low: int = 0            # low-dim distance computations
    ksort_calls: int = 0         # kSort.L invocations
    minh_calls: int = 0          # Min.H invocations
    visit_checks: int = 0        # Visit&Raw SPM reads
    f_updates: int = 0           # F-list inserts (RMF on eviction)
    evictions: int = 0
    seq_bursts: int = 0          # sequential DRAM bursts
    seq_bytes: int = 0
    rand_accesses: int = 0       # irregular DRAM accesses
    rand_bytes: int = 0

    def add(self, other: "SearchStats"):
        for k, v in asdict(other).items():
            setattr(self, k, getattr(self, k) + v)

    def as_dict(self):
        return asdict(self)


def _d2(a, b):
    d = a - b
    return float(np.dot(d, d))


def _d2_rows(x, q):
    d = x - q
    return np.einsum("ij,ij->i", d, d)


# ---------------------------------------------------------------------------
# standard HNSW layer search (baseline [2] / HNSW-Std hardware variant)
# ---------------------------------------------------------------------------

def _hnsw_layer(g: HNSWGraph, q: np.ndarray, eps: List[int], ef: int,
                layer: int, st: SearchStats,
                hw_mode: bool = False,
                deleted: Optional[np.ndarray] = None) -> List[Tuple[float, int]]:
    """hw_mode=True models the HNSW-Std accelerator baseline ([5],[6] as
    characterized in Section IV-B2): the DMA fetches high-dim data for
    ALL M neighbors of the expanded node before the visited check (the
    V-list lives with the raw data in SPM), so fetch/distance counts are
    per-neighbor, not per-unvisited-neighbor. The traversal itself is
    identical.

    ``deleted`` ([N] bool, optional): tombstone semantics — deleted
    nodes are traversed (pushed to the candidate heap, expanded) but
    never enter the result heap."""
    adj = g.layers[layer]
    dim = g.x.shape[1]
    live = (lambda e: True) if deleted is None \
        else (lambda e: not deleted[e])
    visited = set(eps)
    cand = []
    best = []
    for e in eps:
        d = _d2(g.x[e], q)
        st.dist_high += 1
        st.rand_accesses += 1
        st.rand_bytes += dim * F32
        heapq.heappush(cand, (d, e))
        if live(e):
            heapq.heappush(best, (-d, e))
    while cand:
        d_c, c = heapq.heappop(cand)
        d_f = -best[0][0] if best else np.inf
        if d_c > d_f and len(best) >= ef:
            break
        st.expansions += 1
        neigh = adj[c]
        neigh = neigh[neigh >= 0]
        # one sequential burst for the index list
        st.seq_bursts += 1
        st.seq_bytes += adj.shape[1] * IDX_BYTES
        new = [int(e) for e in neigh if e not in visited]
        st.visit_checks += len(neigh)
        visited.update(new)
        # irregular fetches + high-dim distances: all M neighbors in
        # hw_mode, unvisited only in software mode
        n_fetch = len(neigh) if hw_mode else len(new)
        st.rand_accesses += n_fetch
        st.rand_bytes += n_fetch * dim * F32
        st.dist_high += n_fetch
        if not new:
            continue
        ds = _d2_rows(g.x[new], q)
        for d_e, e in zip(ds, new):
            d_f = -best[0][0] if best else np.inf
            if d_e < d_f or len(best) < ef:
                heapq.heappush(cand, (float(d_e), e))
                if live(e):
                    heapq.heappush(best, (-float(d_e), e))
                    st.f_updates += 1
                    if len(best) > ef:
                        heapq.heappop(best)
                        st.evictions += 1
    return sorted([(-d, e) for d, e in best])


def search_hnsw(g: HNSWGraph, q: np.ndarray, *, ef0: Optional[int] = None,
                hw_mode: bool = False,
                deleted: Optional[np.ndarray] = None
                ) -> Tuple[np.ndarray, SearchStats]:
    cfg = g.cfg
    st = SearchStats()
    ep = [g.entry]
    top = int(g.levels.max())
    for layer in range(top, 0, -1):
        res = _hnsw_layer(g, q, ep, cfg.ef_for_layer(layer), layer, st,
                          hw_mode)
        ep = [res[0][1]]
    # only the output layer filters tombstones; upper layers just route
    res = _hnsw_layer(g, q, ep, ef0 or cfg.ef0, 0, st, hw_mode,
                      deleted=deleted)
    return np.array([e for _, e in res], np.int64), st


# ---------------------------------------------------------------------------
# pHNSW Algorithm 1
# ---------------------------------------------------------------------------

def _phnsw_layer(g: HNSWGraph, x_low: np.ndarray, q: np.ndarray,
                 q_pca: np.ndarray, eps: List[int], ef: int, k: int,
                 layer: int, st: SearchStats,
                 layout: Literal["packed", "separate"],
                 deleted: Optional[np.ndarray] = None) -> List[Tuple[float, int]]:
    adj = g.layers[layer]
    M = adj.shape[1]
    dim = g.x.shape[1]
    d_low = x_low.shape[1]
    live = (lambda e: True) if deleted is None \
        else (lambda e: not deleted[e])
    visited = set(eps)
    C: List[Tuple[float, int]] = []      # candidate min-heap (high-dim dist)
    F: List[Tuple[float, int]] = []      # final max-heap (neg high-dim dist)
    C_pca: List[Tuple[float, int]] = []  # filter-threshold max-heap (neg low-dim)
    for e in eps:
        d = _d2(g.x[e], q)
        st.dist_high += 1
        st.rand_accesses += 1
        st.rand_bytes += dim * F32
        dl = _d2(x_low[e], q_pca)
        st.dist_low += 1
        heapq.heappush(C, (d, e))
        if live(e):
            heapq.heappush(F, (-d, e))
        heapq.heappush(C_pca, (-dl, e))
    while C:
        d_c, c = heapq.heappop(C)
        d_f = -F[0][0] if F else np.inf
        if d_c > d_f and len(F) >= ef:
            break                                     # lines 7-8
        st.expansions += 1
        neigh = adj[c]
        neigh = neigh[neigh >= 0]
        if layout == "packed":
            # layout (3): indices + low-dim raw data in ONE burst
            st.seq_bursts += 1
            st.seq_bytes += M * (IDX_BYTES + d_low * F32)
        else:
            # layout (4): index burst + M irregular low-dim fetches
            st.seq_bursts += 1
            st.seq_bytes += M * IDX_BYTES
            st.rand_accesses += len(neigh)
            st.rand_bytes += len(neigh) * d_low * F32
        if len(neigh) == 0:
            continue
        # ---- step 2: low-dim distances + top-k filter (lines 10-13) ----
        nl = [int(e) for e in neigh]
        dls = _d2_rows(x_low[nl], q_pca)
        st.dist_low += len(nl)
        # threshold is only meaningful once the k-bounded heap is full
        f_pca = -C_pca[0][0] if len(C_pca) >= k else np.inf
        keep = [(float(d), e) for d, e in zip(dls, nl) if d < f_pca]
        st.ksort_calls += 1                           # kSort.L, 7 cycles
        keep.sort()                                   # deterministic top-k
        topk = keep[:k]
        # ---- step 3: high-dim re-rank of the k survivors (lines 15-23) --
        for dl_m, m in topk:
            st.visit_checks += 1
            if m in visited:
                continue
            visited.add(m)
            st.rand_accesses += 1                     # high-dim fetch
            st.rand_bytes += dim * F32
            d_m = _d2(g.x[m], q)
            st.dist_high += 1
            st.minh_calls += 1
            d_f = -F[0][0] if F else np.inf
            if d_m < d_f or len(F) < ef:
                heapq.heappush(C, (d_m, m))
                if live(m):
                    heapq.heappush(F, (-d_m, m))
                    st.f_updates += 1
                    if len(F) > ef:
                        heapq.heappop(F)
                        st.evictions += 1
                # C_pca_tmp: bounded-k low-dim threshold heap (line 20/24)
                heapq.heappush(C_pca, (-dl_m, m))
                if len(C_pca) > k:
                    heapq.heappop(C_pca)
    return sorted([(-d, e) for d, e in F])


def search_phnsw(g: HNSWGraph, x_low: np.ndarray, pca: PCA, q: np.ndarray,
                 *, layout: Literal["packed", "separate"] = "packed",
                 k_schedule: Optional[Tuple[int, ...]] = None,
                 ef0: Optional[int] = None,
                 deleted: Optional[np.ndarray] = None
                 ) -> Tuple[np.ndarray, SearchStats]:
    cfg = g.cfg
    st = SearchStats()
    q_pca = pca.transform(q[None])[0].astype(np.float32)
    ks = k_schedule or cfg.k_schedule
    k_of = lambda l: ks[min(l, len(ks) - 1)]
    ep = [g.entry]
    top = int(g.levels.max())
    for layer in range(top, 0, -1):
        res = _phnsw_layer(g, x_low, q, q_pca, ep, cfg.ef_for_layer(layer),
                           k_of(layer), layer, st, layout)
        ep = [res[0][1]]
    # tombstones filter only at the output layer (upper layers route)
    res = _phnsw_layer(g, x_low, q, q_pca, ep, ef0 or cfg.ef0, k_of(0), 0,
                       st, layout, deleted=deleted)
    return np.array([e for _, e in res], np.int64), st


# ---------------------------------------------------------------------------
# batch helpers
# ---------------------------------------------------------------------------

def recall_at(found: np.ndarray, truth: np.ndarray, at: int) -> float:
    """found: [k_found] indices; truth: [at] ground-truth indices."""
    return len(set(found[:at].tolist()) & set(truth[:at].tolist())) / at


def run_queries(g: HNSWGraph, queries: np.ndarray, truth: np.ndarray,
                *, algo: str = "phnsw", x_low=None, pca=None,
                layout="packed", k_schedule=None, hw_mode: bool = False,
                deleted: Optional[np.ndarray] = None):
    """Run all queries; returns (mean recall@cfg.recall_at, total stats)."""
    cfg = g.cfg
    tot = SearchStats()
    recs = []
    for i, q in enumerate(queries):
        if algo == "hnsw":
            found, st = search_hnsw(g, q, hw_mode=hw_mode,
                                    deleted=deleted)
        else:
            found, st = search_phnsw(g, x_low, pca, q, layout=layout,
                                     k_schedule=k_schedule,
                                     deleted=deleted)
        tot.add(st)
        recs.append(recall_at(found, truth[i], cfg.recall_at))
    return float(np.mean(recs)), tot
