"""Batched fixed-shape pHNSW search in JAX — the TPU-native adaptation.

The ASIC processes one query with data-dependent control flow; a TPU
wants a BATCH of queries with fixed shapes. This module runs B queries
simultaneously through Algorithm 1 with:

  * packed layout (3) as a device array ``packed_low[N, M, dl]`` — one
    row gather per expansion fetches indices + all neighbor low-dim
    vectors (the regular-access insight, HBM edition), storable in
    bfloat16 (``PHNSWConfig.low_dtype``) to halve the dominant stream;
  * the FUSED expand kernel (``ops.fused_expand``): Dist.L, the
    adjacency/active mask, the C_pca threshold compare and kSort.L in a
    single VMEM residency — one kernel per expansion step instead of a
    Dist.L -> HBM -> kSort.L round-trip;
  * sorted frontiers: C (candidates), F (finals) and C_pca are kept
    ascending-sorted loop invariants, so the pop is slot 0 and every
    per-step merge is an O(ef+k) sorted merge (``ops.merge_topk_sorted``)
    instead of a concat + O((CAP+k)^2) comparison-matrix re-sort;
  * fixed-capacity candidate/final buffers with masked updates inside
    ``lax.while_loop`` (no data-dependent shapes anywhere), and the
    ASIC's per-query visited BITMAP (one bit per node, packed into
    int32 words — membership is a single word gather per candidate);
  * per-query ``done`` masks carried as loop state (termination is
    monotone, so freezing is latched), per-query step telemetry, and a
    global early exit once every query in the batch has frozen — the
    convoy-mitigation story (DESIGN.md).

Formulation note (DESIGN.md): every small sort/merge here is a
comparison-matrix + one-hot contraction, NOT lax.sort/gather — XLA
lowers variadic sorts and gathers to scalar loops on CPU and the widths
involved (M, k, CAP) are tiny, so the O(n^2) vector form wins on every
backend this repo targets.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import PHNSWConfig
from repro.constants import INF as _INF, VALID_MAX
from repro.core.graph import HNSWGraph
from repro.kernels import ops

INF = jnp.float32(_INF)


@dataclass
class PackedLayer:
    adj: jax.Array          # [N, M] int32, -1 padded
    packed_low: jax.Array   # [N, M, dl] neighbor low-dim data, inline


@dataclass
class PackedDB:
    """Device-resident database in the paper's layout (3).

    ``entry`` is a pytree DATA field (a scalar, traced under jit), not
    metadata: the mutable-index subsystem re-points the entry when a new
    top-level node is inserted, and a metadata entry would key the jit
    cache — every entry change would recompile the search program.

    ``deleted`` is an optional word-packed tombstone bitmap,
    ``[ceil(N/32)] int32`` (bit i of word i>>5 = node i is deleted).
    ``None`` (the default, a structurally static distinction) means "no
    tombstones ever": the engine then compiles the plain accept path.
    When present, deleted nodes are TRAVERSED (they stay in the
    candidate frontier, their neighbors are expanded) but never RETURNED
    (they are excluded from the result list F on the output layer).

    ``filter_kind`` is METADATA (static): which filter stage the
    payload in ``low`` / ``packed_low`` belongs to — "pca" (dense
    low-dim rows), "pq" (uint8 ADC codes), "cascade" (uint8 ADC codes
    inline + a PCA side-car) or "none" (zero-width bypass payload).
    Each kind compiles a different expand pipeline, so it is structural
    by design (core/filters.py owns the payload contract).

    ``low2`` is the cascade's SIDE-CAR payload: f32 PCA rows
    ``[N, d_low]``, stored OFF the layout-(3) hot stream (never inlined
    per neighbor) and gathered once per query at the promote stage.
    ``None`` (every non-cascade kind) is structurally static, like
    ``deleted``."""
    layers: List[PackedLayer]
    low: jax.Array          # [N, P] filter payload rows (P may be 0)
    high: jax.Array         # [N, D]
    entry: int
    cfg: PHNSWConfig
    deleted: Optional[jax.Array] = None   # [ceil(N/32)] int32 or None
    low2: Optional[jax.Array] = None      # [N, dl] promote side-car
    filter_kind: str = "pca"

    @property
    def bytes_layout3(self) -> int:
        """Stored bytes under the paper's layout (3): per RESIDENT node
        per layer, the neighbor list with inline low-dim vectors
        (non-padded entries), plus the high-dim table. (The device arrays
        keep full-N rows for gather regularity; the accounting reflects
        what a packed store would hold.)"""
        dl = self.low.shape[1]
        low_bytes = jnp.dtype(self.low.dtype).itemsize
        extra = 0
        for l in self.layers:
            nnz = int((l.adj >= 0).sum())
            extra += nnz * (4 + dl * low_bytes)
        return extra + int(self.high.size) * 4

    @property
    def bytes_sidecar(self) -> int:
        """Stored bytes of the cascade's promote side-car (0 without
        one) — NOT part of the layout-(3) inline stream the traversal
        bursts; reported separately by the byte accounting."""
        if self.low2 is None:
            return 0
        return int(self.low2.size) * jnp.dtype(self.low2.dtype).itemsize

    @property
    def bytes_layout4(self) -> int:
        idx = sum(int((l.adj >= 0).sum()) * 4 for l in self.layers)
        low_bytes = jnp.dtype(self.low.dtype).itemsize
        return idx + int(self.low.size) * low_bytes \
            + int(self.high.size) * 4


# pytree registration so whole searches can be jit'd / shard_map'd
jax.tree_util.register_dataclass(
    PackedLayer, data_fields=["adj", "packed_low"], meta_fields=[])
jax.tree_util.register_dataclass(
    PackedDB, data_fields=["layers", "low", "high", "entry", "deleted",
                           "low2"],
    meta_fields=["cfg", "filter_kind"])


def _tombstone_bit(deleted, ids):
    """Gather the tombstone bit for an int32 id array (any shape).
    Negative ids (padding) read word 0 harmlessly; callers mask them."""
    safe = jnp.maximum(ids, 0)
    return (jnp.take(deleted, safe // 32) >> (safe % 32)) & 1 != 0


def pack_bitmap(flags: np.ndarray) -> np.ndarray:
    """bool [n] -> int32 words [ceil(n/32)] in the ``_tombstone_bit``
    layout (bit i of word i >> 5 = flags[i]); the tail word is
    zero-padded. The ONE definition of the on-device tombstone word
    layout — the mutable index and the sharded builder both pack
    through here."""
    nw = -(-len(flags) // 32)
    words = np.zeros(nw, np.uint32)
    ids = np.nonzero(flags)[0].astype(np.uint32)
    np.bitwise_or.at(words, ids // 32, np.uint32(1) << (ids % 32))
    return words.view(np.int32)


def build_packed(g: HNSWGraph, x_low: Optional[np.ndarray] = None,
                 *, filt=None, low_dtype: Optional[str] = None,
                 drop_empty_layers: bool = True) -> PackedDB:
    """``x_low`` is the filter payload ([N, P] rows — dense low-dim
    vectors for the default PCA filter); passing ``filt`` (a
    ``core.filters.FilterSpec``) instead encodes the payload from the
    filter and stamps its kind onto the db ("pca" assumed otherwise).
    ``low_dtype`` overrides ``g.cfg.low_dtype`` (layout-(3) storage
    dtype of the inline PCA vectors; distances still run in f32; PQ
    codes always store uint8). ``drop_empty_layers`` skips all-padding
    top layers (the level assignment rarely reaches cfg.n_layers at
    small N) so the search never runs a while_loop over an empty graph
    layer; pass False when layer counts must stay uniform (e.g.
    stacking shards)."""
    fkind = filt.kind if filt is not None else "pca"
    if x_low is None:
        if filt is None:
            raise ValueError("build_packed needs x_low or filt")
        x_low = filt.encode(g.x)
    dt = jnp.dtype(low_dtype or g.cfg.low_dtype) if fkind == "pca" \
        else jnp.dtype(x_low.dtype)
    adjs = list(g.layers)
    if drop_empty_layers:
        while len(adjs) > 1 and not (adjs[-1] >= 0).any():
            adjs.pop()
    layers = []
    for adj in adjs:
        safe = np.where(adj >= 0, adj, 0)
        packed = x_low[safe]                       # [N, M, P]
        packed[adj < 0] = 0
        layers.append(PackedLayer(adj=jnp.asarray(adj),
                                  packed_low=jnp.asarray(packed, dt)))
    low2 = None
    if filt is not None and hasattr(filt, "encode_mid"):
        # the cascade's promote side-car: PCA rows off the hot stream
        low2 = jnp.asarray(filt.encode_mid(g.x))
    return PackedDB(layers=layers, low=jnp.asarray(x_low, dt),
                    high=jnp.asarray(g.x), entry=g.entry, cfg=g.cfg,
                    low2=low2, filter_kind=fkind)


def _rank_sort_with_payload(d, p):
    """Stable ascending sort of each row of d (ties -> lower slot), the
    int payload p carried along. Same (dist, slot) order as
    ref.ksort_l_ref — merge_topk_sorted's determinism depends on the
    tie-break matching — but applies the payload through the rank
    one-hot instead of ksort_l + take_along_axis: n is small (W*k) and
    XLA CPU lowers lax.sort/gather to scalar loops."""
    B, n = d.shape
    ii = jnp.arange(n)
    idx_gt = (ii[:, None] > ii[None, :])[None]
    cmp = (d[:, :, None] > d[:, None, :]) \
        | ((d[:, :, None] == d[:, None, :]) & idx_gt)
    rank = cmp.sum(-1).astype(jnp.int32)
    hot = rank[:, :, None] == ii[None, None, :]          # [B, n, n]
    sd = jnp.sum(jnp.where(hot, d[:, :, None], 0.0), axis=1)
    sp = jnp.sum(jnp.where(hot, p[:, :, None], 0), axis=1).astype(p.dtype)
    return sd, sp


def _cascade_lut(qprep, S: int):
    """ADC tables out of the cascade's flat per-query prep:
    [B, S*256 + d_low] -> [B, S, 256]. ``S`` is static — the inline
    payload width (``db.low.shape[-1]``), so the slice never depends on
    traced values."""
    return qprep[:, :S * 256].reshape(qprep.shape[0], S, 256)


def _cascade_qpca(qprep, S: int):
    """The PCA-projected query out of the cascade's flat prep:
    [B, S*256 + d_low] -> [B, d_low] (the promote-stage operand)."""
    return qprep[:, S * 256:]


def _layer_init(db: PackedDB, start_d, start_i, *, ef: int, k: int,
                CAP: int, filter_deleted: bool):
    """The fixed-capacity SORTED layer state seeded from a start set:
    (C_d, C_i, F_d, F_i, V, Cp). Shared by ``search_layer_batched``
    (fresh per layer) and the slotted admission path (fresh per
    admitted query, scattered into a live ``SlotState``)."""
    B = start_d.shape[0]
    N = db.high.shape[0]
    pad = CAP - start_d.shape[1]
    C_d = jnp.pad(start_d, ((0, 0), (0, pad)), constant_values=INF)
    C_i = jnp.pad(start_i, ((0, 0), (0, pad)), constant_values=-1)
    if filter_deleted:
        # seed F with the LIVE subset of the start set (the routing
        # layers above may hand us tombstoned entry points: legal to
        # traverse from, illegal to return)
        tomb0 = _tombstone_bit(db.deleted, start_i) | (start_i < 0)
        s_d, s_i = _rank_sort_with_payload(
            jnp.where(tomb0, INF, start_d),
            jnp.where(tomb0, -1, start_i))
        epad = max(ef - s_d.shape[1], 0)
        F_d = jnp.pad(s_d, ((0, 0), (0, epad)),
                      constant_values=INF)[:, :ef]
        F_i = jnp.pad(s_i, ((0, 0), (0, epad)),
                      constant_values=-1)[:, :ef]
    else:
        F_d, F_i = C_d[:, :ef], C_i[:, :ef]    # best ef of the start set
    # visited bitmap, the ASIC's SPM bitmap verbatim: one bit per node,
    # packed into int32 words; membership = one word gather per
    # candidate, insert = scatter-add of (disjoint) bit masks
    nw = -(-N // 32)
    V = jnp.zeros((B, nw), jnp.int32)
    sw, sb = start_i // 32, start_i % 32
    V = jax.vmap(lambda v, w, m: v.at[w].add(m))(
        V, sw, jnp.where(start_i >= 0, (1 << sb).astype(jnp.int32), 0))
    # C_pca threshold heap (k-bounded filter dists of accepted
    # candidates, ascending; Cp[-1] is the filter threshold f_pca).
    # The identity filter has no threshold stage — Cp stays a constant
    # INF row and its merge is elided from the compiled program.
    Cp = jnp.full((B, k), INF)
    return C_d, C_i, F_d, F_i, V, Cp


def _layer_body(db: PackedDB, layer: int, q_high, qprep, *, ef: int,
                k: int, W: int, steps, filter_deleted: bool,
                deferred: bool, ef_eff=None, budget=None):
    """Build the ONE-expansion-iteration body over the layer state
    tuple ``(t, C_d, C_i, F_d, F_i, V, Cp, done, nsteps, dhe)``.

    ``search_layer_batched`` drives it inside a ``lax.while_loop`` with
    a static per-layer ``steps`` budget; the slotted stepper
    (``_slot_step_jit``) drives the SAME body with two per-slot DATA
    generalizations, both exactly the static program when absent:

    * ``ef_eff`` [B] int32 — the per-slot effective ef: the acceptance
      /termination bound reads ``F_d[i, ef_eff[i]-1]`` instead of
      ``F_d[i, -1]``, so a slot converges once its top-``ef_eff``
      results are stable even though the compiled buffers are ``ef``
      wide (the adaptive-ef and mixed-k hook);
    * ``budget`` [B] int32 — the per-slot expansion-step budget
      replacing the static ``steps`` limit (the adaptive step-budget
      hook: a stalled slot freezes without latching ``done`` and
      resumes when the scheduler raises its budget)."""
    B = q_high.shape[0]
    lay = db.layers[layer]
    M = lay.adj.shape[1]
    fkind = db.filter_kind
    if fkind == "none":
        kk = W * M          # filter bypass: every neighbor is a candidate
        deferred = False    # filter space == high-dim space
    else:
        kk = W * k                               # survivors per iteration

    def body(state):
        t, C_d, C_i, F_d, F_i, V, Cp, done, nsteps, dhe = state
        # the acceptance/termination bound: F.max over the slot's
        # effective result width (the full compiled width when no
        # per-slot ef is active — bit-identical to the original)
        if ef_eff is None:
            bnd = F_d[:, -1:]
        else:
            bnd = jnp.take_along_axis(
                F_d, jnp.maximum(ef_eff, 1)[:, None] - 1, axis=1)
        lim = steps if budget is None else budget[:, None]
        # -- pop the W nearest candidates: slots 0..W-1 of sorted C --
        d_w, c_w = C_d[:, :W], C_i[:, :W]
        # termination is monotone (F.max only shrinks, the popped min
        # only grows), so the freeze is latched per query; frozen
        # queries keep popping into masked work, which is harmless.
        # An exhausted frontier (slot 0 is the -1/INF pad) also
        # latches: nothing left to expand can ever improve F — this is
        # what the host reference's "while C" does, and without it a
        # query on a sparse/empty layer spins through the whole step
        # budget doing masked work (the construction probe publishes
        # not-yet-populated top layers, where that spin dominates)
        done = done | (C_d[:, 0] > bnd[:, 0]) \
            | (C_i[:, 0] < 0)                           # lines 7-8
        # per-slot expansion gate: a popped candidate past F.max is
        # dead forever, so dropping it unexpanded is exact; the budget
        # term keeps total expansions <= steps even when W ∤ steps
        exp = (d_w <= bnd) & ~done[:, None] \
            & (nsteps[:, None] + jnp.arange(W)[None, :] < lim)
        sh_d = jnp.concatenate([C_d[:, W:], jnp.full((B, W), INF)], 1)
        sh_i = jnp.concatenate([C_i[:, W:],
                                jnp.full((B, W), -1, jnp.int32)], 1)
        if budget is None:
            # static budget == the loop's iteration bound: every body
            # application is a real pop (the original program, verbatim)
            C_d, C_i = sh_d, sh_i
        else:
            # slotted: a budget-frozen (or done) slot must NOT pop — it
            # keeps its frontier intact and resumes exactly where it
            # froze when the scheduler raises its budget
            alive = (~done & (nsteps < budget))[:, None]
            C_d = jnp.where(alive, sh_d, C_d)
            C_i = jnp.where(alive, sh_i, C_i)
        # gated-off slots gather row 0 (cheap, discarded via the mask)
        c_safe = jnp.where(exp, jnp.maximum(c_w, 0), 0)
        # -- step 2: W row gathers = paper layout (3) bursts --
        nb_i = jnp.take(lay.adj, c_safe.reshape(-1), axis=0) \
            .reshape(B, -1)                             # [B, W*M]
        nb_mask = (nb_i >= 0) & jnp.repeat(exp, M, axis=1)
        if fkind == "none":
            # filter bypass: every valid neighbor is a candidate (slot
            # order = adjacency order); no payload gather, no kernel
            cand, kv = nb_i, None
            valid = nb_mask
        else:
            nb_pay = jnp.take(lay.packed_low, c_safe.reshape(-1),
                              axis=0).reshape(B, nb_i.shape[1], -1)
            # -- fused expand: filter dist (Dist.L or PQ ADC) + mask +
            #    f_pca threshold + kSort.L in one kernel --
            th = Cp[:, -1]
            if fkind == "pca":
                kv, ki = ops.fused_expand(nb_pay, qprep, nb_mask, th, kk)
            else:
                # pq and cascade both traverse on ADC codes; the
                # cascade's luts are sliced out of its flat prep row
                lut = _cascade_lut(qprep, nb_pay.shape[-1]) \
                    if fkind == "cascade" else qprep
                kv, ki = ops.pq_adc_expand(nb_pay, lut, nb_mask, th, kk)
            cand = jnp.take_along_axis(nb_i, ki, axis=1)    # [B, W*k]
            valid = (kv < VALID_MAX) & (cand >= 0)
        # -- visited check: one bit gather per candidate --
        cw, cb = jnp.maximum(cand, 0) // 32, jnp.maximum(cand, 0) % 32
        seen = (jnp.take_along_axis(V, cw, axis=1) >> cb) & 1 != 0
        if W > 1:
            # intra-iteration dedup (the W neighbor lists may overlap;
            # keep the first occurrence); a single list holds distinct
            # ids on every path, including the bypass
            jj = jnp.arange(kk, dtype=jnp.int32)
            dup = ((cand[:, :, None] == cand[:, None, :])
                   & (jj[None, :, None] > jj[None, None, :])
                   & valid[:, None, :]).any(-1)
            seen |= dup
        valid &= ~seen
        if deferred and fkind != "none":
            # -- deferred re-rank: traverse on FILTER distances; no
            #    high-dim gather, no Dist.H inside the loop --
            dh = jnp.where(valid, kv, INF)
        else:
            # -- step 3: kk irregular high-dim fetches + Dist.H --
            xh = jnp.take(db.high, jnp.maximum(cand, 0), axis=0)
            dh = jnp.where(valid, ops.dist_h(xh, q_high), INF)  # Dist.H
            dhe = dhe + valid.sum(axis=1, dtype=jnp.int32)
        # -- mark visited: disjoint bit masks (valid slots are distinct
        #    ids, so mod-2^32 add == bitwise or) --
        V = jax.vmap(lambda v, w, m: v.at[w].add(m))(
            V, cw, jnp.where(valid, (1 << cb).astype(jnp.int32), 0))
        # -- accept: d < F.max or F not full (F starts padded with INF) --
        accept = dh < bnd
        # one stacked stable sort orders the acceptees for every
        # frontier feed; which rows exist depends on the static mode:
        #   * okF row (tombstoned masked out) only under filter_deleted
        #   * a separate kv row for the C_pca heap only when the
        #     traversal orders by Dist.H (per-step pca/pq) — in
        #     deferred mode dh IS kv, and the bypass has no C_pca
        rows_d = [jnp.where(accept, dh, INF)]
        rows_i = [jnp.where(accept, cand, -1)]
        if filter_deleted:
            # tombstoned candidates are accepted into C (traversed) but
            # masked out of the F feed (never returned)
            tomb = _tombstone_bit(db.deleted, cand)
            okF = accept & ~tomb
            rows_d.insert(0, jnp.where(okF, dh, INF))
            rows_i.insert(0, jnp.where(okF, cand, -1))
        need_kv_row = fkind != "none" and not deferred
        if need_kv_row:
            rows_d.append(jnp.where(accept, kv, INF))
            rows_i.append(jnp.zeros((B, kk), jnp.int32))
        s_d, s_i = _rank_sort_with_payload(jnp.concatenate(rows_d, 0),
                                           jnp.concatenate(rows_i, 0))
        r = B if filter_deleted else 0
        sd, si = s_d[r:r + B], s_i[r:r + B]          # C feed (dh order)
        fd_n, fi_n = (s_d[:B], s_i[:B]) if filter_deleted else (sd, si)
        # -- fold into the sorted frontiers: O(ef+k) sorted merges,
        #    each right-sized (element work, not op count, is what the
        #    CPU/TPU vector units pay for) --
        F_d, F_i = ops.merge_topk_sorted(F_d, F_i, fd_n, fi_n, ef)
        C_d, C_i = ops.merge_topk_sorted(C_d, C_i, sd, si,
                                         C_d.shape[1])
        if fkind != "none":
            # C_pca feed: the accepted candidates' filter dists — their
            # own sort row per-step, the dh row itself when deferred
            pv = s_d[r + B:] if need_kv_row else sd
            Cp, _ = ops.merge_topk_sorted(
                Cp, jnp.zeros((B, k), jnp.int32), pv,
                jnp.zeros((B, pv.shape[1]), jnp.int32), k)
        nsteps = nsteps + exp.sum(axis=1, dtype=jnp.int32)
        return (t + 1, C_d, C_i, F_d, F_i, V, Cp, done, nsteps, dhe)

    return body


def search_layer_batched(db: PackedDB, layer: int, q_high, qprep,
                         start_d, start_i, *, ef: int, k: int,
                         max_steps: Optional[int] = None,
                         expand_width: Optional[int] = None,
                         filter_deleted: bool = False,
                         deferred: bool = False):
    """One layer of Algorithm 1 for a batch of queries.

    ``qprep`` is the active filter's per-query data (PCA-projected
    query [B, dl] for "pca", ADC lookup tables [B, S, 256] for "pq",
    a zero-width dummy for "none" — see core/filters.py); the filter
    kind itself is static on ``db.filter_kind`` and selects the expand
    pipeline: the fused Dist.L kernel, the fused PQ ADC kernel, or the
    filter bypass (every valid neighbor goes straight to Dist.H and the
    C_pca threshold stage disappears from the compiled program).

    start_d/start_i: [B, E] entry candidates ASCENDING (high-dim dists
    normally; FILTER-space dists when ``deferred``) — the previous
    layer's output already is.

    Each loop iteration pops the W = expand_width nearest frontier
    candidates (slots 0..W-1 of the sorted C) and expands them jointly —
    exact w.r.t. the per-candidate rule, since a popped candidate with
    d > F.max can never re-qualify (F.max only shrinks). W-fold fewer
    while_loop trips; each trip's gathers/kernels widen instead.

    ``filter_deleted`` (static; requires ``db.deleted``) applies the
    tombstone semantics: deleted nodes enter the candidate frontier C
    (and the C_pca threshold heap) and are expanded like any node, but
    are excluded from the result list F — so F.max, the acceptance
    bound, is computed over LIVE nodes only and the traversal keeps
    digging until ef live results converge.

    ``deferred`` (static) traverses purely on filter distances: no
    high-dim gathers or Dist.H inside the loop — C, F and the
    acceptance bound all live in filter space, and the caller re-ranks
    the final F list in high dim once. A no-op for the identity filter
    (its filter distance IS the high-dim distance).

    Returns (F_dist [B, ef], F_idx [B, ef] ascending, steps [B] int32 =
    per-query expansion count before that query froze, dist_h [B]
    int32 = per-query Dist.H evaluations inside this layer)."""
    B = q_high.shape[0]
    M = db.layers[layer].adj.shape[1]
    W = expand_width or db.cfg.expand_width
    kk = W * M if db.filter_kind == "none" else W * k
    CAP = max(ef + kk, 8)
    steps = max_steps or db.cfg.max_steps_for_layer(layer)
    iters = -(-steps // W)                       # expansion budget / W
    if filter_deleted:
        assert db.deleted is not None, "filter_deleted needs db.deleted"

    # --- fixed-capacity SORTED state ---
    C_d, C_i, F_d, F_i, V, Cp = _layer_init(
        db, start_d, start_i, ef=ef, k=k, CAP=CAP,
        filter_deleted=filter_deleted)
    done = jnp.zeros((B,), bool)
    nsteps = jnp.zeros((B,), jnp.int32)
    dhe = jnp.zeros((B,), jnp.int32)
    state = (jnp.int32(0), C_d, C_i, F_d, F_i, V, Cp, done, nsteps, dhe)

    def cond(state):
        t, *_, done, _ns, _de = state
        return (t < iters) & ~done.all()

    body = _layer_body(db, layer, q_high, qprep, ef=ef, k=k, W=W,
                       steps=steps, filter_deleted=filter_deleted,
                       deferred=deferred)
    out = jax.lax.while_loop(cond, body, state)
    _, _, _, F_d, F_i, _, _, _, nsteps, dhe = out
    return F_d, F_i, nsteps, dhe


@functools.partial(jax.jit,
                   static_argnames=("ef", "k", "filter_deleted",
                                    "ef_upper"))
def probe_neighborhoods(db, queries, qprep, ef, k,
                        filter_deleted=True, ef_upper=None):
    """On-device neighborhood probe for a batch of to-be-inserted
    vectors: the serving traversal run at every layer with the
    construction beam (ef = ef_construction), each layer's full top-ef
    seeding the next (richer than the serial ef=1 descent). The C-phase
    device half shared by the wave builder (``core/build.py``) and the
    mutable index (``index/mutable.py``): the host keeps only the cheap
    vectorized linking.

    ``filter_deleted`` (static; requires ``db.deleted``) excludes
    tombstoned nodes at EVERY layer — new nodes must never link to the
    dead. The one-shot wave builder passes False (a fresh build has no
    tombstone bitmap; not-yet-inserted rows are unreachable, nothing
    links to them).

    ``ef_upper`` (static) narrows the beam at layers above 0: the
    upper-layer beam mostly supplies DESCENT seeds (only the ~1/M
    fraction of nodes with level >= 1 link there), and the sequential
    oracle descends with ef=1 — a beam between those extremes trades a
    little upper-layer candidate richness for the probe wall-clock the
    beam's ~ef expansion steps cost at every layer. None keeps the full
    ``ef`` everywhere. Returns ([L, B, ef] dists, [L, B, ef] ids),
    bottom layer FIRST (out[l] = layer l); upper-layer rows are padded
    to ef width with INF/-1 when ``ef_upper`` trims them."""
    B = queries.shape[0]
    ep = jnp.broadcast_to(
        jnp.asarray(db.entry, jnp.int32).reshape(()), (B, 1))
    ep_d = ops.dist_h(jnp.take(db.high, ep, axis=0), queries)
    out_d, out_i = [], []
    for layer in range(len(db.layers) - 1, -1, -1):
        ef_l = ef if layer == 0 else min(ef_upper or ef, ef)
        fd, fi, _, _ = search_layer_batched(
            db, layer, queries, qprep, ep_d, ep, ef=ef_l, k=k,
            max_steps=2 * ef_l + 16, filter_deleted=filter_deleted)
        ep_d, ep = fd, fi
        if ef_l < ef:
            fd = jnp.pad(fd, ((0, 0), (0, ef - ef_l)),
                         constant_values=INF)
            fi = jnp.pad(fi, ((0, 0), (0, ef - ef_l)),
                         constant_values=-1)
        out_d.append(fd)
        out_i.append(fi)
    return jnp.stack(out_d[::-1]), jnp.stack(out_i[::-1])


@functools.partial(jax.jit, static_argnames=("ef0", "k_schedule",
                                             "deferred", "rerank_mult",
                                             "promote_mult"))
def _search_batched_jit(db, queries, qprep, ef0, k_schedule, deferred,
                        rerank_mult, promote_mult):
    return _search_batched_impl(db, queries, qprep, ef0=ef0,
                                k_schedule=k_schedule, deferred=deferred,
                                rerank_mult=rerank_mult,
                                promote_mult=promote_mult)


def search_batched(db: PackedDB, queries, qprep=None, *, pca=None,
                   filt=None,
                   ef0: Optional[int] = None,
                   k_schedule: Optional[Tuple[int, ...]] = None,
                   entry: Optional[int] = None,
                   return_stats: bool = False,
                   deferred: Optional[bool] = None,
                   rerank_mult: Optional[int] = None,
                   promote_mult: Optional[int] = None):
    """Full multi-layer pHNSW search for a batch (jit'd).
    queries: [B, D] (device). Returns (dists [B, ef0], idx [B, ef0]);
    with ``return_stats=True`` also a dict with per-query telemetry:
    ``steps_per_layer`` [n_layers, B] (top layer first), ``steps_total``
    [B] and ``dist_h_evals`` [B] (high-dim distance evaluations — the
    quantity deferred re-ranking trades recall against), plus the
    serving-plane accounting pair ``coverage``/``degraded`` (trivially
    1.0/False here; the sharded path reports real values).

    ``qprep`` is the active filter's per-query data; leave it None and
    pass ``filt`` (a ``core.filters.FilterSpec``) or ``pca`` (the
    PCA-filter convenience, the seed API) to compute it here. The
    identity filter needs neither.

    ``deferred`` / ``rerank_mult`` select the re-ranking mode (defaults
    from ``db.cfg.deferred_rerank`` / ``db.cfg.rerank_mult``): deferred
    traverses on filter distances only and re-ranks the final
    ``rerank_mult * ef0`` candidates in high dim with ONE batched
    Dist.H call per query. ``promote_mult`` (cascade + deferred only;
    default ``db.cfg.promote_mult``) widens the layer-0 traversal to
    ``promote_mult * ef0`` PQ-space candidates that the PCA promote
    stage trims back to ``rerank_mult * ef0`` before that single
    Dist.H pass.

    ``entry`` overrides the descent entry point (``db.entry`` by
    default). Both the entry and the tombstone bitmap ``db.deleted`` are
    DATA to the compiled program — changing either between calls never
    recompiles."""
    if filt is not None and filt.kind != db.filter_kind:
        raise ValueError(f"filter mismatch: db carries a "
                         f"{db.filter_kind!r} payload, filt is "
                         f"{filt.kind!r}")
    if qprep is None:
        if filt is not None:
            qprep = filt.prepare_jnp(queries)
        elif pca is not None:
            qprep = pca.transform_jnp(queries).astype(jnp.float32)
        elif db.filter_kind == "none":
            qprep = queries[:, :0].astype(jnp.float32)
        else:
            raise ValueError("qprep, filt or pca required for the "
                             f"{db.filter_kind!r} filter")
    if entry is not None:
        db = dataclasses.replace(db, entry=entry)
    if deferred is None:
        deferred = db.cfg.deferred_rerank
    if rerank_mult is None:
        rerank_mult = db.cfg.rerank_mult
    if promote_mult is None:
        promote_mult = db.cfg.promote_mult
    # normalize the no-op combinations BEFORE they key the jit cache:
    # deferred is defined as a no-op for the identity filter,
    # rerank_mult only exists inside deferred mode, and promote_mult
    # only exists for the deferred cascade — without this a caller
    # varying any knob recompiles a bit-identical program
    if db.filter_kind == "none":
        deferred = False
    if not deferred:
        rerank_mult = 1
    if not (deferred and db.filter_kind == "cascade"):
        promote_mult = 1
    else:
        # the promote pool can never be narrower than the rerank pool
        promote_mult = max(int(promote_mult), int(rerank_mult))
    fd, fi, steps, dhe = _search_batched_jit(
        db, queries, qprep, ef0 or db.cfg.ef0,
        k_schedule or db.cfg.k_schedule_for(db.filter_kind,
                                            bool(deferred)),
        bool(deferred), int(rerank_mult), int(promote_mult))
    if return_stats:
        # coverage/degraded ride along so the stats contract is uniform
        # with the sharded degraded-mode path (core/distributed.py):
        # a single-shard snapshot always reaches its whole live set
        return fd, fi, {"steps_per_layer": steps,
                        "steps_total": steps.sum(axis=0),
                        "dist_h_evals": dhe,
                        "coverage": 1.0, "degraded": False}
    return fd, fi


def _search_batched_impl(db: PackedDB, queries, qprep, *,
                         ef0: Optional[int] = None,
                         k_schedule: Optional[Tuple[int, ...]] = None,
                         deferred: bool = False, rerank_mult: int = 1,
                         promote_mult: int = 1,
                         final_rerank: bool = True):
    """The traced body (also called directly inside shard_map by
    ``core/distributed.py``). The upper routing layers never filter
    tombstones — a deleted node is a fine descent waypoint — the output
    layer (0) does, iff the db carries a bitmap.

    Deferred mode runs the whole descent in filter space (the entry is
    scored against the payload, every layer traverses on filter
    distances, layer 0 keeps ``rerank_mult * ef0`` candidates) and
    finishes with a single batched Dist.H over the final list. The
    deferred CASCADE widens layer 0 further to ``promote_mult * ef0``
    PQ-space candidates and inserts the PCA promote stage (one batched
    ``dist_l`` over side-car rows, once per query — never per step)
    that trims them back to ``rerank_mult * ef0`` before the Dist.H
    pass. ``final_rerank=False`` (deferred only) skips promote AND
    re-rank and returns the WIDE filter-space list instead — the
    sharded path merges per-shard lists on filter distances first and
    runs promote + re-rank ONCE globally after the cross-shard merge."""
    cfg = db.cfg
    B = queries.shape[0]
    ks = k_schedule or cfg.k_schedule_for(db.filter_kind,
                                          bool(deferred))
    k_of = lambda l: ks[min(l, len(ks) - 1)]
    ep = jnp.broadcast_to(
        jnp.asarray(db.entry, jnp.int32).reshape(()), (B, 1))
    deferred = deferred and db.filter_kind != "none"
    cascade = deferred and db.filter_kind == "cascade"
    if deferred:
        pay = jnp.take(db.low, ep, axis=0)              # [B, 1, P]
        if db.filter_kind == "pca":
            ep_d = ops.dist_l(pay, qprep)
        elif db.filter_kind == "cascade":
            ep_d = ops.pq_adc(pay, _cascade_lut(qprep, pay.shape[-1]))
        else:
            ep_d = ops.pq_adc(pay, qprep)
        dhe = jnp.zeros((B,), jnp.int32)
    else:
        ep_d = ops.dist_h(jnp.take(db.high, ep, axis=0), queries)
        dhe = jnp.ones((B,), jnp.int32)
    n_layers = len(db.layers)
    steps = []
    for layer in range(n_layers - 1, 0, -1):
        ep_d, ep, st, de = search_layer_batched(
            db, layer, queries, qprep, ep_d, ep,
            ef=cfg.ef_for_layer(layer), k=k_of(layer), deferred=deferred)
        steps.append(st)
        dhe = dhe + de
    ef_out = ef0 or cfg.ef0
    wide_mult = promote_mult if cascade else rerank_mult
    ef_run = ef_out * wide_mult if deferred else ef_out
    fd, fi, st, de = search_layer_batched(
        db, 0, queries, qprep, ep_d, ep, ef=ef_run, k=k_of(0),
        filter_deleted=db.deleted is not None, deferred=deferred)
    steps.append(st)
    dhe = dhe + de
    if deferred and final_rerank:
        if cascade:
            # promote stage: ONE batched PCA score over side-car rows
            # trims the PQ-space pool to the Dist.H rerank pool
            ok = fi >= 0
            mid = jnp.take(db.low2, jnp.maximum(fi, 0), axis=0)
            qpca = _cascade_qpca(qprep, db.low.shape[-1])
            dm = jnp.where(ok, ops.dist_l(mid, qpca), INF)
            pd, pi = _rank_sort_with_payload(dm, jnp.where(ok, fi, -1))
            fd, fi = pd[:, :ef_out * rerank_mult], \
                pi[:, :ef_out * rerank_mult]
        # the deferred high-dim re-rank: ONE batched Dist.H over the
        # final filter-space list, then a single sort back to ef0
        ok = fi >= 0
        xh = jnp.take(db.high, jnp.maximum(fi, 0), axis=0)
        dh = jnp.where(ok, ops.dist_h(xh, queries), INF)
        dhe = dhe + ok.sum(axis=1, dtype=jnp.int32)
        rd, ri = _rank_sort_with_payload(dh, jnp.where(ok, fi, -1))
        fd, fi = rd[:, :ef_out], ri[:, :ef_out]
    return fd, fi, jnp.stack(steps), dhe


# ---------------------------------------------------------------------------
# slotted resumable search state — the continuous-batching substrate
# (serve/scheduler.py; DESIGN.md § Serving front-end).
#
# The synchronous path runs descent + layer 0 to completion for one
# batch and returns; a slot whose ``done`` mask latched early then idles
# until the SLOWEST query in the batch converges (the convoy). Here the
# layer-0 traversal state is instead a long-lived pytree of S slots:
#
#   * ``_slot_step_jit`` advances EVERY live slot by up to ``quantum``
#     expansion iterations of the SAME ``_layer_body`` program the
#     synchronous search compiles, and returns — the host can now
#     retire slots whose ``done`` latched and refill them;
#   * ``_slot_admit_jit`` swaps freshly-descended queries into chosen
#     slots as PURE DATA (a fixed-width scatter; unused admission rows
#     carry an out-of-range slot id and are dropped) — the same
#     zero-recompile discipline as entry/tombstone swaps;
#   * per-slot ``ef_eff`` (mixed-k / adaptive-ef) and ``budget``
#     (adaptive step budgets) ride in the state as data — see
#     ``_layer_body``.
#
# Sharded twins vmap the identical per-shard program over the stacked
# ShardedDB leaves; the host merges per-shard lists at retirement
# (shards are disjoint, so the merge is a host-side sorted concat).
# ---------------------------------------------------------------------------

@dataclass
class SlotState:
    """The resumable layer-0 traversal state of S slots — every field
    is pytree DATA (leading dim S; the sharded twin prepends the shard
    dim P), so admission, budget escalation, and epoch swaps never
    recompile. Geometry (CAP/ef/k widths) is fixed at
    ``make_slot_state`` time and keys the compiled programs via shapes.

    An EMPTY slot is ``done=True`` with ``budget=0`` and a ``-1``/INF
    frontier: it latches immediately, gates no loop iteration, and its
    masked lanes cost only vector width."""
    C_d: jax.Array      # [S, CAP] sorted candidate frontier dists
    C_i: jax.Array      # [S, CAP] candidate ids (-1 pad)
    F_d: jax.Array      # [S, EF] sorted result dists
    F_i: jax.Array      # [S, EF] result ids (-1 pad)
    V: jax.Array        # [S, ceil(N/32)] visited bitmap words
    Cp: jax.Array       # [S, k] C_pca threshold heap
    done: jax.Array     # [S] bool, latched per slot
    nsteps: jax.Array   # [S] int32 expansion steps so far
    dhe: jax.Array      # [S] int32 Dist.H evaluations so far
    q_high: jax.Array   # [S, D] the resident queries
    qprep: jax.Array    # [S, ...] per-query filter prep (payload space)
    ef_eff: jax.Array   # [S] int32 per-slot effective ef (<= EF)
    budget: jax.Array   # [S] int32 per-slot expansion-step budget


jax.tree_util.register_dataclass(
    SlotState,
    data_fields=["C_d", "C_i", "F_d", "F_i", "V", "Cp", "done", "nsteps",
                 "dhe", "q_high", "qprep", "ef_eff", "budget"],
    meta_fields=[])


def _slot_geometry(db: PackedDB, ef: int,
                   deferred: bool = False) -> Tuple[int, int, int]:
    """(k, W, CAP) of the slotted layer-0 program — derived exactly the
    way ``search_layer_batched`` derives them, so the slotted body is
    the same compiled shape family as the synchronous one.
    ``deferred`` selects the same effective layer-0 k the synchronous
    default does (the deferred cascade runs unpruned at M0)."""
    cfg = db.cfg
    k = cfg.k_schedule_for(db.filter_kind, deferred)[0]
    W = cfg.expand_width
    M = db.layers[0].adj.shape[-1]
    kk = W * M if db.filter_kind == "none" else W * k
    return k, W, max(ef + kk, 8)


def make_slot_state(db: PackedDB, n_slots: int, qprep_example, *,
                    ef: int, n_shards: Optional[int] = None,
                    deferred: bool = False) -> SlotState:
    """An all-empty slot bank. ``ef`` is the COMPILED result width (the
    per-slot ``ef_eff`` can only narrow it — size it to the largest k /
    ef any request may ask for). ``qprep_example`` is any [b, ...]
    filter-prep array, used only for its trailing shape/dtype.
    ``n_shards`` (sharded serving) prepends the shard dim to every
    leaf — the stacked per-shard states the vmapped twins advance.
    ``deferred`` must match the mode the slots will step in — it sizes
    the Cp register (the per-expansion keep width) to the same
    effective k the synchronous program uses."""
    k, _, CAP = _slot_geometry(db, ef, deferred)
    N = db.high.shape[-2]
    D = db.high.shape[-1]
    nw = -(-N // 32)
    lead = () if n_shards is None else (n_shards,)
    shp = lambda *s: lead + (n_slots,) + s
    qp_trail = tuple(np.asarray(qprep_example).shape[1:])
    return SlotState(
        C_d=jnp.full(shp(CAP), INF),
        C_i=jnp.full(shp(CAP), -1, jnp.int32),
        F_d=jnp.full(shp(ef), INF),
        F_i=jnp.full(shp(ef), -1, jnp.int32),
        V=jnp.zeros(shp(nw), jnp.int32),
        Cp=jnp.full(shp(k), INF),
        done=jnp.ones(shp(), bool),
        nsteps=jnp.zeros(shp(), jnp.int32),
        dhe=jnp.zeros(shp(), jnp.int32),
        q_high=jnp.zeros(shp(D), jnp.float32),
        qprep=jnp.zeros(shp(*qp_trail), jnp.float32),
        ef_eff=jnp.full(shp(), ef, jnp.int32),
        budget=jnp.zeros(shp(), jnp.int32),
    )


def _slot_admit_impl(db: PackedDB, state: SlotState, q_new, qprep_new,
                     slot_ids, ef_eff_new, budget_new, *,
                     deferred: bool = False) -> SlotState:
    """Descend the admission batch through the routing layers (the same
    per-layer programs as ``_search_batched_impl``) and scatter the
    fresh layer-0 state into the chosen slots. The admission width is
    FIXED (pad rows carry slot id >= S and are dropped by the scatter),
    so every admission reuses one compiled program regardless of how
    many slots actually refill.

    ``deferred`` (static) admits in FILTER space exactly the way the
    synchronous deferred path does: the entry is scored against the
    payload and the routing descent traverses on filter distances, so
    the scattered layer-0 state is bit-identical to the synchronous
    program's."""
    cfg = db.cfg
    ef = state.F_d.shape[-1]
    k, _, CAP = _slot_geometry(db, ef, deferred)
    ks = cfg.k_schedule_for(db.filter_kind, deferred)
    k_of = lambda l: ks[min(l, len(ks) - 1)]
    A = q_new.shape[0]
    ep = jnp.broadcast_to(
        jnp.asarray(db.entry, jnp.int32).reshape(()), (A, 1))
    deferred = deferred and db.filter_kind != "none"
    if deferred:
        pay = jnp.take(db.low, ep, axis=0)
        if db.filter_kind == "pca":
            ep_d = ops.dist_l(pay, qprep_new)
        elif db.filter_kind == "cascade":
            ep_d = ops.pq_adc(pay, _cascade_lut(qprep_new,
                                                pay.shape[-1]))
        else:
            ep_d = ops.pq_adc(pay, qprep_new)
        dhe = jnp.zeros((A,), jnp.int32)
    else:
        ep_d = ops.dist_h(jnp.take(db.high, ep, axis=0), q_new)
        dhe = jnp.ones((A,), jnp.int32)
    for layer in range(len(db.layers) - 1, 0, -1):
        ep_d, ep, _, de = search_layer_batched(
            db, layer, q_new, qprep_new, ep_d, ep,
            ef=cfg.ef_for_layer(layer), k=k_of(layer),
            deferred=deferred)
        dhe = dhe + de
    C_d, C_i, F_d, F_i, V, Cp = _layer_init(
        db, ep_d, ep, ef=ef, k=k, CAP=CAP,
        filter_deleted=db.deleted is not None)
    ids = slot_ids
    sc = lambda dst, rows: dst.at[ids].set(rows, mode="drop")
    return dataclasses.replace(
        state,
        C_d=sc(state.C_d, C_d), C_i=sc(state.C_i, C_i),
        F_d=sc(state.F_d, F_d), F_i=sc(state.F_i, F_i),
        V=sc(state.V, V), Cp=sc(state.Cp, Cp),
        done=sc(state.done, jnp.zeros((A,), bool)),
        nsteps=sc(state.nsteps, jnp.zeros((A,), jnp.int32)),
        dhe=sc(state.dhe, dhe),
        q_high=sc(state.q_high, q_new),
        qprep=sc(state.qprep, qprep_new),
        ef_eff=sc(state.ef_eff, ef_eff_new),
        budget=sc(state.budget, budget_new))


def _slot_step_impl(db: PackedDB, state: SlotState, *, quantum: int,
                    expand_width: int,
                    deferred: bool = False) -> SlotState:
    """Advance every live slot by up to ``quantum`` iterations of the
    layer-0 body — the SAME ``_layer_body`` the synchronous search
    compiles, with the per-slot ``ef_eff``/``budget`` data
    generalizations active. The loop exits early once no slot can make
    progress (all done or budget-frozen), so a sparse bank costs what
    its live slots cost. ``deferred`` (static) traverses on filter
    distances — the slot's F list then holds FILTER-space candidates
    and the scheduler runs the single batched Dist.H pass at
    retirement."""
    ef = state.F_d.shape[-1]
    k = state.Cp.shape[-1]
    body = _layer_body(db, 0, state.q_high, state.qprep, ef=ef, k=k,
                       W=expand_width, steps=0,
                       filter_deleted=db.deleted is not None,
                       deferred=deferred and db.filter_kind != "none",
                       ef_eff=state.ef_eff,
                       budget=state.budget)
    st = (jnp.int32(0), state.C_d, state.C_i, state.F_d, state.F_i,
          state.V, state.Cp, state.done, state.nsteps, state.dhe)

    def cond(s):
        t, *_, done, ns, _de = s
        return (t < quantum) & (~done & (ns < state.budget)).any()

    out = jax.lax.while_loop(cond, body, st)
    _, C_d, C_i, F_d, F_i, V, Cp, done, nsteps, dhe = out
    return dataclasses.replace(
        state, C_d=C_d, C_i=C_i, F_d=F_d, F_i=F_i, V=V, Cp=Cp,
        done=done, nsteps=nsteps, dhe=dhe)


_slot_admit_jit = jax.jit(_slot_admit_impl,
                          static_argnames=("deferred",))


@functools.partial(jax.jit, static_argnames=("quantum", "expand_width",
                                             "deferred"))
def _slot_step_jit(db, state, quantum, expand_width, deferred=False):
    return _slot_step_impl(db, state, quantum=quantum,
                           expand_width=expand_width, deferred=deferred)


@functools.partial(jax.jit, static_argnames=("deferred",))
def _slot_admit_sharded_jit(db_stack, state, q_new, qprep_new, slot_ids,
                            ef_eff_new, budget_new, deferred=False):
    """Admission over a stacked-leaf PackedDB view of a ShardedDB
    ([P, ...] leaves; ``core.distributed.stacked_db_view``): each shard
    descends its own graph for the SAME queries into the SAME slots."""
    return jax.vmap(
        lambda d, s: _slot_admit_impl(d, s, q_new, qprep_new, slot_ids,
                                      ef_eff_new, budget_new,
                                      deferred=deferred)
    )(db_stack, state)


@functools.partial(jax.jit, static_argnames=("quantum", "expand_width",
                                             "deferred"))
def _slot_step_sharded_jit(db_stack, state, quantum, expand_width,
                           deferred=False):
    return jax.vmap(
        lambda d, s: _slot_step_impl(d, s, quantum=quantum,
                                     expand_width=expand_width,
                                     deferred=deferred)
    )(db_stack, state)


def _slot_step_prefix_impl(db, state, *, width, quantum, expand_width,
                           deferred=False):
    """Step only the first ``width`` slots of the bank — the WIDTH
    LADDER. Slots are allocated low-first, so at partial occupancy the
    scheduler steps the smallest compiled prefix covering the highest
    live slot instead of paying full-bank prices (each ladder rung is
    one compile, warmed at construction — steady state stays
    zero-recompile)."""
    part = jax.tree_util.tree_map(lambda a: a[:width], state)
    part = _slot_step_impl(db, part, quantum=quantum,
                           expand_width=expand_width, deferred=deferred)
    return jax.tree_util.tree_map(lambda f, p: f.at[:width].set(p),
                                  state, part)


@functools.partial(jax.jit,
                   static_argnames=("width", "quantum", "expand_width",
                                    "deferred"))
def _slot_step_prefix_jit(db, state, width, quantum, expand_width,
                          deferred=False):
    return _slot_step_prefix_impl(db, state, width=width,
                                  quantum=quantum,
                                  expand_width=expand_width,
                                  deferred=deferred)


@functools.partial(jax.jit,
                   static_argnames=("width", "quantum", "expand_width",
                                    "deferred"))
def _slot_step_prefix_sharded_jit(db_stack, state, width, quantum,
                                  expand_width, deferred=False):
    return jax.vmap(
        lambda d, s: _slot_step_prefix_impl(d, s, width=width,
                                            quantum=quantum,
                                            expand_width=expand_width,
                                            deferred=deferred)
    )(db_stack, state)


def _slot_admit_step_impl(db, state, q_new, qprep_new, slot_ids,
                          ef_eff_new, budget_new, *, width, quantum,
                          expand_width, deferred=False):
    """One FUSED tick program: admission scatter + prefix step in a
    single compiled call — the same content as the synchronous search
    (upper-layer descent, then the layer-0 loop), so a tick with
    arrivals costs one dispatch and never materializes the
    intermediate post-admission state."""
    state = _slot_admit_impl(db, state, q_new, qprep_new, slot_ids,
                             ef_eff_new, budget_new, deferred=deferred)
    return _slot_step_prefix_impl(db, state, width=width,
                                  quantum=quantum,
                                  expand_width=expand_width,
                                  deferred=deferred)


@functools.partial(jax.jit,
                   static_argnames=("width", "quantum", "expand_width",
                                    "deferred"))
def _slot_admit_step_jit(db, state, q_new, qprep_new, slot_ids,
                         ef_eff_new, budget_new, width, quantum,
                         expand_width, deferred=False):
    return _slot_admit_step_impl(db, state, q_new, qprep_new, slot_ids,
                                 ef_eff_new, budget_new, width=width,
                                 quantum=quantum,
                                 expand_width=expand_width,
                                 deferred=deferred)


@functools.partial(jax.jit,
                   static_argnames=("width", "quantum", "expand_width",
                                    "deferred"))
def _slot_admit_step_sharded_jit(db_stack, state, q_new, qprep_new,
                                 slot_ids, ef_eff_new, budget_new,
                                 width, quantum, expand_width,
                                 deferred=False):
    return jax.vmap(
        lambda d, s: _slot_admit_step_impl(
            d, s, q_new, qprep_new, slot_ids, ef_eff_new, budget_new,
            width=width, quantum=quantum, expand_width=expand_width,
            deferred=deferred)
    )(db_stack, state)


@jax.jit
def _retire_rerank_jit(db, queries, fi):
    """The scheduler's deferred Dist.H retirement pass: the EXACT final
    block of the synchronous deferred program (one batched Dist.H over
    the filter-space list, then the same stable rank sort) applied to a
    fixed-width batch of retiring slots — non-retiring pad rows carry
    ``fi = -1`` everywhere and cost only masked lanes. Bit-parity with
    ``run_stream_sync`` depends on this being the same op sequence."""
    ok = fi >= 0
    xh = jnp.take(db.high, jnp.maximum(fi, 0), axis=0)
    dh = jnp.where(ok, ops.dist_h(xh, queries), INF)
    rd, ri = _rank_sort_with_payload(dh, jnp.where(ok, fi, -1))
    return rd, ri, ok.sum(axis=1, dtype=jnp.int32)


@jax.jit
def _retire_promote_jit(db, qprep, fi, n_keep):
    """The scheduler's cascade promote pass at retirement: PCA-score
    the side-car rows of the retiring slots' PQ-space lists and keep
    each slot's best ``n_keep`` (data, per-slot) — the slotted twin of
    the promote stage in ``_search_batched_impl``."""
    ok = fi >= 0
    mid = jnp.take(db.low2, jnp.maximum(fi, 0), axis=0)
    qpca = _cascade_qpca(qprep, db.low.shape[-1])
    dm = jnp.where(ok, ops.dist_l(mid, qpca), INF)
    pd, pi = _rank_sort_with_payload(dm, jnp.where(ok, fi, -1))
    keep = jnp.arange(pd.shape[1])[None, :] < n_keep[:, None]
    return jnp.where(keep, pd, INF), jnp.where(keep, pi, -1)


def slot_cache_sizes() -> Tuple[int, ...]:
    """(step, admit, step_sharded, admit_sharded, step_prefix,
    step_prefix_sharded, admit_step, admit_step_sharded,
    retire_rerank, retire_promote) compiled-program cache sizes — the
    scheduler's zero-recompile-under-churn assertions read these (same
    pattern as ``core.distributed.search_cache_sizes``)."""
    return (_slot_step_jit._cache_size(),
            _slot_admit_jit._cache_size(),
            _slot_step_sharded_jit._cache_size(),
            _slot_admit_sharded_jit._cache_size(),
            _slot_step_prefix_jit._cache_size(),
            _slot_step_prefix_sharded_jit._cache_size(),
            _slot_admit_step_jit._cache_size(),
            _slot_admit_step_sharded_jit._cache_size(),
            _retire_rerank_jit._cache_size(),
            _retire_promote_jit._cache_size())
