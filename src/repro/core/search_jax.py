"""Batched fixed-shape pHNSW search in JAX — the TPU-native adaptation.

The ASIC processes one query with data-dependent control flow; a TPU
wants a BATCH of queries with fixed shapes. This module runs B queries
simultaneously through Algorithm 1 with:

  * packed layout (3) as a device array ``packed_low[N, M, dl]`` — one
    row gather per expansion fetches indices + all neighbor low-dim
    vectors (the regular-access insight, HBM edition), storable in
    bfloat16 (``PHNSWConfig.low_dtype``) to halve the dominant stream;
  * the FUSED expand kernel (``ops.fused_expand``): Dist.L, the
    adjacency/active mask, the C_pca threshold compare and kSort.L in a
    single VMEM residency — one kernel per expansion step instead of a
    Dist.L -> HBM -> kSort.L round-trip;
  * sorted frontiers: C (candidates), F (finals) and C_pca are kept
    ascending-sorted loop invariants, so the pop is slot 0 and every
    per-step merge is an O(ef+k) sorted merge (``ops.merge_topk_sorted``)
    instead of a concat + O((CAP+k)^2) comparison-matrix re-sort;
  * fixed-capacity candidate/final buffers with masked updates inside
    ``lax.while_loop`` (no data-dependent shapes anywhere), and the
    ASIC's per-query visited BITMAP (one bit per node, packed into
    int32 words — membership is a single word gather per candidate);
  * per-query ``done`` masks carried as loop state (termination is
    monotone, so freezing is latched), per-query step telemetry, and a
    global early exit once every query in the batch has frozen — the
    convoy-mitigation story (DESIGN.md).

Formulation note (DESIGN.md): every small sort/merge here is a
comparison-matrix + one-hot contraction, NOT lax.sort/gather — XLA
lowers variadic sorts and gathers to scalar loops on CPU and the widths
involved (M, k, CAP) are tiny, so the O(n^2) vector form wins on every
backend this repo targets.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import PHNSWConfig
from repro.constants import INF as _INF, VALID_MAX
from repro.core.graph import HNSWGraph
from repro.kernels import ops

INF = jnp.float32(_INF)


@dataclass
class PackedLayer:
    adj: jax.Array          # [N, M] int32, -1 padded
    packed_low: jax.Array   # [N, M, dl] neighbor low-dim data, inline


@dataclass
class PackedDB:
    """Device-resident database in the paper's layout (3).

    ``entry`` is a pytree DATA field (a scalar, traced under jit), not
    metadata: the mutable-index subsystem re-points the entry when a new
    top-level node is inserted, and a metadata entry would key the jit
    cache — every entry change would recompile the search program.

    ``deleted`` is an optional word-packed tombstone bitmap,
    ``[ceil(N/32)] int32`` (bit i of word i>>5 = node i is deleted).
    ``None`` (the default, a structurally static distinction) means "no
    tombstones ever": the engine then compiles the plain accept path.
    When present, deleted nodes are TRAVERSED (they stay in the
    candidate frontier, their neighbors are expanded) but never RETURNED
    (they are excluded from the result list F on the output layer)."""
    layers: List[PackedLayer]
    low: jax.Array          # [N, dl]
    high: jax.Array         # [N, D]
    entry: int
    cfg: PHNSWConfig
    deleted: Optional[jax.Array] = None   # [ceil(N/32)] int32 or None

    @property
    def bytes_layout3(self) -> int:
        """Stored bytes under the paper's layout (3): per RESIDENT node
        per layer, the neighbor list with inline low-dim vectors
        (non-padded entries), plus the high-dim table. (The device arrays
        keep full-N rows for gather regularity; the accounting reflects
        what a packed store would hold.)"""
        dl = self.low.shape[1]
        low_bytes = jnp.dtype(self.low.dtype).itemsize
        extra = 0
        for l in self.layers:
            nnz = int((l.adj >= 0).sum())
            extra += nnz * (4 + dl * low_bytes)
        return extra + int(self.high.size) * 4

    @property
    def bytes_layout4(self) -> int:
        idx = sum(int((l.adj >= 0).sum()) * 4 for l in self.layers)
        low_bytes = jnp.dtype(self.low.dtype).itemsize
        return idx + int(self.low.size) * low_bytes \
            + int(self.high.size) * 4


# pytree registration so whole searches can be jit'd / shard_map'd
jax.tree_util.register_dataclass(
    PackedLayer, data_fields=["adj", "packed_low"], meta_fields=[])
jax.tree_util.register_dataclass(
    PackedDB, data_fields=["layers", "low", "high", "entry", "deleted"],
    meta_fields=["cfg"])


def _tombstone_bit(deleted, ids):
    """Gather the tombstone bit for an int32 id array (any shape).
    Negative ids (padding) read word 0 harmlessly; callers mask them."""
    safe = jnp.maximum(ids, 0)
    return (jnp.take(deleted, safe // 32) >> (safe % 32)) & 1 != 0


def build_packed(g: HNSWGraph, x_low: np.ndarray,
                 *, low_dtype: Optional[str] = None,
                 drop_empty_layers: bool = True) -> PackedDB:
    """``low_dtype`` overrides ``g.cfg.low_dtype`` (layout-(3) storage
    dtype of the inline low-dim vectors; distances still run in f32).
    ``drop_empty_layers`` skips all-padding top layers (the level
    assignment rarely reaches cfg.n_layers at small N) so the search
    never runs a while_loop over an empty graph layer; pass False when
    layer counts must stay uniform (e.g. stacking shards)."""
    dt = jnp.dtype(low_dtype or g.cfg.low_dtype)
    adjs = list(g.layers)
    if drop_empty_layers:
        while len(adjs) > 1 and not (adjs[-1] >= 0).any():
            adjs.pop()
    layers = []
    for adj in adjs:
        safe = np.where(adj >= 0, adj, 0)
        packed = x_low[safe]                       # [N, M, dl]
        packed[adj < 0] = 0.0
        layers.append(PackedLayer(adj=jnp.asarray(adj),
                                  packed_low=jnp.asarray(packed, dt)))
    return PackedDB(layers=layers, low=jnp.asarray(x_low, dt),
                    high=jnp.asarray(g.x), entry=g.entry, cfg=g.cfg)


def _rank_sort_with_payload(d, p):
    """Stable ascending sort of each row of d (ties -> lower slot), the
    int payload p carried along. Same (dist, slot) order as
    ref.ksort_l_ref — merge_topk_sorted's determinism depends on the
    tie-break matching — but applies the payload through the rank
    one-hot instead of ksort_l + take_along_axis: n is small (W*k) and
    XLA CPU lowers lax.sort/gather to scalar loops."""
    B, n = d.shape
    ii = jnp.arange(n)
    idx_gt = (ii[:, None] > ii[None, :])[None]
    cmp = (d[:, :, None] > d[:, None, :]) \
        | ((d[:, :, None] == d[:, None, :]) & idx_gt)
    rank = cmp.sum(-1).astype(jnp.int32)
    hot = rank[:, :, None] == ii[None, None, :]          # [B, n, n]
    sd = jnp.sum(jnp.where(hot, d[:, :, None], 0.0), axis=1)
    sp = jnp.sum(jnp.where(hot, p[:, :, None], 0), axis=1).astype(p.dtype)
    return sd, sp


def search_layer_batched(db: PackedDB, layer: int, q_high, q_low,
                         start_d, start_i, *, ef: int, k: int,
                         max_steps: Optional[int] = None,
                         expand_width: Optional[int] = None,
                         filter_deleted: bool = False):
    """One layer of Algorithm 1 for a batch of queries.

    start_d/start_i: [B, E] entry candidates (high-dim dists, idx),
    ASCENDING — the previous layer's output already is.

    Each loop iteration pops the W = expand_width nearest frontier
    candidates (slots 0..W-1 of the sorted C) and expands them jointly —
    exact w.r.t. the per-candidate rule, since a popped candidate with
    d > F.max can never re-qualify (F.max only shrinks). W-fold fewer
    while_loop trips; each trip's gathers/kernels widen instead.

    ``filter_deleted`` (static; requires ``db.deleted``) applies the
    tombstone semantics: deleted nodes enter the candidate frontier C
    (and the C_pca threshold heap) and are expanded like any node, but
    are excluded from the result list F — so F.max, the acceptance
    bound, is computed over LIVE nodes only and the traversal keeps
    digging until ef live results converge.

    Returns (F_dist [B, ef], F_idx [B, ef] ascending, steps [B] int32 =
    per-query expansion count before that query froze)."""
    B = q_high.shape[0]
    lay = db.layers[layer]
    N = db.high.shape[0]
    W = expand_width or db.cfg.expand_width
    kk = W * k                                   # survivors per iteration
    CAP = max(ef + kk, 8)
    steps = max_steps or db.cfg.max_steps_for_layer(layer)
    iters = -(-steps // W)                       # expansion budget / W
    if filter_deleted:
        assert db.deleted is not None, "filter_deleted needs db.deleted"

    # --- fixed-capacity SORTED state ---
    pad = CAP - start_d.shape[1]
    C_d = jnp.pad(start_d, ((0, 0), (0, pad)), constant_values=INF)
    C_i = jnp.pad(start_i, ((0, 0), (0, pad)), constant_values=-1)
    if filter_deleted:
        # seed F with the LIVE subset of the start set (the routing
        # layers above may hand us tombstoned entry points: legal to
        # traverse from, illegal to return)
        tomb0 = _tombstone_bit(db.deleted, start_i) | (start_i < 0)
        s_d, s_i = _rank_sort_with_payload(
            jnp.where(tomb0, INF, start_d),
            jnp.where(tomb0, -1, start_i))
        epad = max(ef - s_d.shape[1], 0)
        F_d = jnp.pad(s_d, ((0, 0), (0, epad)),
                      constant_values=INF)[:, :ef]
        F_i = jnp.pad(s_i, ((0, 0), (0, epad)),
                      constant_values=-1)[:, :ef]
    else:
        F_d, F_i = C_d[:, :ef], C_i[:, :ef]    # best ef of the start set
    # visited bitmap, the ASIC's SPM bitmap verbatim: one bit per node,
    # packed into int32 words; membership = one word gather per
    # candidate, insert = scatter-add of (disjoint) bit masks
    nw = -(-N // 32)
    V = jnp.zeros((B, nw), jnp.int32)
    sw, sb = start_i // 32, start_i % 32
    V = jax.vmap(lambda v, w, m: v.at[w].add(m))(
        V, sw, jnp.where(start_i >= 0, (1 << sb).astype(jnp.int32), 0))
    # C_pca threshold heap (k-bounded low-dim dists of accepted
    # candidates, ascending; Cp[-1] is the filter threshold f_pca)
    Cp = jnp.full((B, k), INF)
    done = jnp.zeros((B,), bool)
    nsteps = jnp.zeros((B,), jnp.int32)
    state = (jnp.int32(0), C_d, C_i, F_d, F_i, V, Cp, done, nsteps)

    def cond(state):
        t, *_, done, _ns = state
        return (t < iters) & ~done.all()

    def body(state):
        t, C_d, C_i, F_d, F_i, V, Cp, done, nsteps = state
        # -- pop the W nearest candidates: slots 0..W-1 of sorted C --
        d_w, c_w = C_d[:, :W], C_i[:, :W]
        # termination is monotone (F.max only shrinks, the popped min
        # only grows), so the freeze is latched per query; frozen
        # queries keep popping into masked work, which is harmless
        done = done | (C_d[:, 0] > F_d[:, -1])          # lines 7-8
        # per-slot expansion gate: a popped candidate past F.max is
        # dead forever, so dropping it unexpanded is exact; the budget
        # term keeps total expansions <= steps even when W ∤ steps
        exp = (d_w <= F_d[:, -1:]) & ~done[:, None] \
            & (nsteps[:, None] + jnp.arange(W)[None, :] < steps)
        C_d = jnp.concatenate([C_d[:, W:], jnp.full((B, W), INF)], 1)
        C_i = jnp.concatenate([C_i[:, W:],
                               jnp.full((B, W), -1, jnp.int32)], 1)
        # gated-off slots gather row 0 (cheap, discarded via the mask)
        c_safe = jnp.where(exp, jnp.maximum(c_w, 0), 0)
        # -- step 2: W row gathers = paper layout (3) bursts --
        nb_i = jnp.take(lay.adj, c_safe.reshape(-1), axis=0) \
            .reshape(B, -1)                             # [B, W*M]
        nb_low = jnp.take(lay.packed_low, c_safe.reshape(-1), axis=0) \
            .reshape(B, nb_i.shape[1], -1)              # [B, W*M, dl]
        # -- fused expand: Dist.L + mask + f_pca threshold + kSort.L --
        th = Cp[:, -1]
        M = lay.adj.shape[1]
        kv, ki = ops.fused_expand(
            nb_low, q_low,
            (nb_i >= 0) & jnp.repeat(exp, M, axis=1), th, kk)
        cand = jnp.take_along_axis(nb_i, ki, axis=1)    # [B, W*k]
        valid = (kv < VALID_MAX) & (cand >= 0)
        # -- visited check: one bit gather per candidate --
        cw, cb = jnp.maximum(cand, 0) // 32, jnp.maximum(cand, 0) % 32
        seen = (jnp.take_along_axis(V, cw, axis=1) >> cb) & 1 != 0
        if W > 1:
            # intra-iteration dedup (the W neighbor lists may overlap;
            # keep the first occurrence)
            jj = jnp.arange(kk, dtype=jnp.int32)
            dup = ((cand[:, :, None] == cand[:, None, :])
                   & (jj[None, :, None] > jj[None, None, :])
                   & valid[:, None, :]).any(-1)
            seen |= dup
        valid &= ~seen
        # -- step 3: W*k irregular high-dim fetches + Dist.H --
        xh = jnp.take(db.high, jnp.maximum(cand, 0), axis=0)
        dh = jnp.where(valid, ops.dist_h(xh, q_high), INF)    # Dist.H
        # -- mark visited: disjoint bit masks (valid slots are distinct
        #    ids, so mod-2^32 add == bitwise or) --
        V = jax.vmap(lambda v, w, m: v.at[w].add(m))(
            V, cw, jnp.where(valid, (1 << cb).astype(jnp.int32), 0))
        # -- accept: d < F.max or F not full (F starts padded with INF) --
        accept = dh < F_d[:, -1:]
        if filter_deleted:
            # tombstoned candidates are accepted into C (traversed) but
            # masked out of the F feed (never returned); one extra
            # stacked row keeps it a single sort
            tomb = _tombstone_bit(db.deleted, cand)
            okF = accept & ~tomb
            s3d, s3i = _rank_sort_with_payload(
                jnp.concatenate([jnp.where(okF, dh, INF),
                                 jnp.where(accept, dh, INF),
                                 jnp.where(accept, kv, INF)], 0),
                jnp.concatenate([jnp.where(okF, cand, -1),
                                 jnp.where(accept, cand, -1),
                                 jnp.zeros((B, kk), jnp.int32)], 0))
            fd_n, fi_n = s3d[:B], s3i[:B]
            sd, si = s3d[B:2 * B], s3i[B:2 * B]
            pv, zk = s3d[2 * B:], s3i[2 * B:]
        else:
            # one stacked stable sort orders the acceptees by high-dim
            # dist (rows 0..B-1, feeding F/C) and by low-dim dist (rows
            # B..2B-1, feeding the C_pca threshold heap)
            s2d, s2i = _rank_sort_with_payload(
                jnp.concatenate([jnp.where(accept, dh, INF),
                                 jnp.where(accept, kv, INF)], 0),
                jnp.concatenate([jnp.where(accept, cand, -1),
                                 jnp.zeros((B, kk), jnp.int32)], 0))
            sd, si = s2d[:B], s2i[:B]
            fd_n, fi_n = sd, si
            pv, zk = s2d[B:], s2i[B:]
        # -- fold into the three sorted frontiers: O(ef+k) sorted
        #    merges, each right-sized (element work, not op count, is
        #    what the CPU/TPU vector units pay for) --
        F_d, F_i = ops.merge_topk_sorted(F_d, F_i, fd_n, fi_n, ef)
        C_d, C_i = ops.merge_topk_sorted(C_d, C_i, sd, si, CAP)
        Cp, _ = ops.merge_topk_sorted(Cp, jnp.zeros((B, k), jnp.int32),
                                      pv, zk, k)
        nsteps = nsteps + exp.sum(axis=1, dtype=jnp.int32)
        return (t + 1, C_d, C_i, F_d, F_i, V, Cp, done, nsteps)

    out = jax.lax.while_loop(cond, body, state)
    _, _, _, F_d, F_i, _, _, _, nsteps = out
    return F_d, F_i, nsteps


@functools.partial(jax.jit, static_argnames=("ef0", "k_schedule"))
def _search_batched_jit(db, queries, q_low, ef0, k_schedule):
    return _search_batched_impl(db, queries, q_low, ef0=ef0,
                                k_schedule=k_schedule)


def search_batched(db: PackedDB, queries, q_low=None, *, pca=None,
                   ef0: Optional[int] = None,
                   k_schedule: Optional[Tuple[int, ...]] = None,
                   entry: Optional[int] = None,
                   return_stats: bool = False):
    """Full multi-layer pHNSW search for a batch (jit'd).
    queries: [B, D] (device). Returns (dists [B, ef0], idx [B, ef0]);
    with ``return_stats=True`` also a dict with per-query expansion-step
    telemetry: ``steps_per_layer`` [n_layers, B] (top layer first) and
    ``steps_total`` [B].

    ``entry`` overrides the descent entry point (``db.entry`` by
    default). Both the entry and the tombstone bitmap ``db.deleted`` are
    DATA to the compiled program — changing either between calls never
    recompiles."""
    if q_low is None:
        q_low = pca.transform_jnp(queries).astype(jnp.float32)
    if entry is not None:
        db = dataclasses.replace(db, entry=entry)
    fd, fi, steps = _search_batched_jit(db, queries, q_low,
                                        ef0 or db.cfg.ef0,
                                        k_schedule or db.cfg.k_schedule)
    if return_stats:
        return fd, fi, {"steps_per_layer": steps,
                        "steps_total": steps.sum(axis=0)}
    return fd, fi


def _search_batched_impl(db: PackedDB, queries, q_low, *,
                         ef0: Optional[int] = None,
                         k_schedule: Optional[Tuple[int, ...]] = None):
    """The traced body (also called directly inside shard_map by
    ``core/distributed.py``). The upper routing layers never filter
    tombstones — a deleted node is a fine descent waypoint — the output
    layer (0) does, iff the db carries a bitmap."""
    cfg = db.cfg
    B = queries.shape[0]
    ks = k_schedule or cfg.k_schedule
    k_of = lambda l: ks[min(l, len(ks) - 1)]
    ep = jnp.broadcast_to(
        jnp.asarray(db.entry, jnp.int32).reshape(()), (B, 1))
    ep_d = ops.dist_h(jnp.take(db.high, ep, axis=0), queries)
    n_layers = len(db.layers)
    steps = []
    for layer in range(n_layers - 1, 0, -1):
        ep_d, ep, st = search_layer_batched(
            db, layer, queries, q_low, ep_d, ep,
            ef=cfg.ef_for_layer(layer), k=k_of(layer))
        steps.append(st)
    fd, fi, st = search_layer_batched(db, 0, queries, q_low, ep_d, ep,
                                      ef=ef0 or cfg.ef0, k=k_of(0),
                                      filter_deleted=db.deleted is not None)
    steps.append(st)
    return fd, fi, jnp.stack(steps)
