"""Batched fixed-shape pHNSW search in JAX — the TPU-native adaptation.

The ASIC processes one query with data-dependent control flow; a TPU
wants a BATCH of queries with fixed shapes. This module runs B queries
simultaneously through Algorithm 1 with:

  * packed layout (3) as a device array ``packed_low[N, M, dl]`` — one
    row gather per expansion fetches indices + all neighbor low-dim
    vectors (the regular-access insight, HBM edition);
  * the Dist.L / kSort.L / Dist.H kernels (repro.kernels.ops) for the
    filter pipeline;
  * fixed-capacity candidate/final/visited buffers with masked updates
    inside ``lax.while_loop`` (no data-dependent shapes anywhere);
  * per-query freeze masks instead of early exit.

The visited set is a bounded ring buffer (VCAP entries) — a documented
deviation from the ASIC's 1M-bit SPM bitmap (DESIGN.md): membership
tests are vectorized compares, and VCAP is sized so overflow is
statistically negligible at the paper's operating point.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import PHNSWConfig
from repro.core.graph import HNSWGraph
from repro.kernels import ops

INF = jnp.float32(3.4e38)


@dataclass
class PackedLayer:
    adj: jax.Array          # [N, M] int32, -1 padded
    packed_low: jax.Array   # [N, M, dl] neighbor low-dim data, inline


@dataclass
class PackedDB:
    """Device-resident database in the paper's layout (3)."""
    layers: List[PackedLayer]
    low: jax.Array          # [N, dl]
    high: jax.Array         # [N, D]
    entry: int
    cfg: PHNSWConfig

    @property
    def bytes_layout3(self) -> int:
        """Stored bytes under the paper's layout (3): per RESIDENT node
        per layer, the neighbor list with inline low-dim vectors
        (non-padded entries), plus the high-dim table. (The device arrays
        keep full-N rows for gather regularity; the accounting reflects
        what a packed store would hold.)"""
        dl = self.low.shape[1]
        extra = 0
        for l in self.layers:
            nnz = int((l.adj >= 0).sum())
            extra += nnz * (4 + dl * 4)
        return extra + int(self.high.size) * 4

    @property
    def bytes_layout4(self) -> int:
        idx = sum(int((l.adj >= 0).sum()) * 4 for l in self.layers)
        return idx + int(self.low.size) * 4 + int(self.high.size) * 4


# pytree registration so whole searches can be jit'd / shard_map'd
jax.tree_util.register_dataclass(
    PackedLayer, data_fields=["adj", "packed_low"], meta_fields=[])
jax.tree_util.register_dataclass(
    PackedDB, data_fields=["layers", "low", "high"],
    meta_fields=["entry", "cfg"])


def build_packed(g: HNSWGraph, x_low: np.ndarray) -> PackedDB:
    layers = []
    for adj in g.layers:
        safe = np.where(adj >= 0, adj, 0)
        packed = x_low[safe]                       # [N, M, dl]
        packed[adj < 0] = 0.0
        layers.append(PackedLayer(adj=jnp.asarray(adj),
                                  packed_low=jnp.asarray(packed)))
    return PackedDB(layers=layers, low=jnp.asarray(x_low),
                    high=jnp.asarray(g.x), entry=g.entry, cfg=g.cfg)


def _merge_topk(d_a, i_a, d_b, i_b, k: int):
    """Merge two (dist, idx) sets, keep k smallest (kSort.L merge)."""
    d = jnp.concatenate([d_a, d_b], axis=1)
    i = jnp.concatenate([i_a, i_b], axis=1)
    vals, sel = ops.ksort_l(d, k)
    return vals, jnp.take_along_axis(i, sel, axis=1)


def search_layer_batched(db: PackedDB, layer: int, q_high, q_low,
                         start_d, start_i, *, ef: int, k: int,
                         max_steps: Optional[int] = None,
                         vcap: int = 256):
    """One layer of Algorithm 1 for a batch of queries.

    start_d/start_i: [B, E] entry candidates (high-dim dists, idx).
    Returns (F_dist [B, ef], F_idx [B, ef]) ascending."""
    B = q_high.shape[0]
    lay = db.layers[layer]
    M = lay.adj.shape[1]
    CAP = max(2 * ef + k, 32)
    steps = max_steps or (4 * ef + 16)

    # --- fixed-capacity state ---
    pad = CAP - start_d.shape[1]
    C_d = jnp.pad(start_d, ((0, 0), (0, pad)), constant_values=INF)
    C_i = jnp.pad(start_i, ((0, 0), (0, pad)), constant_values=-1)
    F_d, F_i = _merge_topk(C_d, C_i, jnp.full((B, 1), INF),
                           jnp.full((B, 1), -1, jnp.int32), ef)
    V = jnp.full((B, vcap), -1, jnp.int32)
    V = V.at[:, :start_i.shape[1]].set(start_i)
    vptr = jnp.full((B,), start_i.shape[1], jnp.int32)
    # C_pca threshold heap (k-bounded low-dim dists of accepted candidates)
    Cp = jnp.full((B, k), INF)
    state = (jnp.int32(0), C_d, C_i, F_d, F_i, V, vptr, Cp)

    def cond(state):
        t, C_d, C_i, F_d, F_i, *_ = state
        active = C_d.min(axis=1) <= F_d.max(axis=1)
        return (t < steps) & active.any()

    def body(state):
        t, C_d, C_i, F_d, F_i, V, vptr, Cp = state
        # -- pop nearest candidate --
        j = jnp.argmin(C_d, axis=1)                         # [B]
        d_c = jnp.take_along_axis(C_d, j[:, None], 1)[:, 0]
        c = jnp.take_along_axis(C_i, j[:, None], 1)[:, 0]
        active = d_c <= F_d.max(axis=1)                     # lines 7-8
        C_d = C_d.at[jnp.arange(B), j].set(INF)
        c_safe = jnp.maximum(c, 0)
        # -- step 2: ONE row gather = paper layout (3) burst --
        nb_i = jnp.take(lay.adj, c_safe, axis=0)            # [B, M]
        nb_low = jnp.take(lay.packed_low, c_safe, axis=0)   # [B, M, dl]
        dl = ops.dist_l(nb_low, q_low)                      # Dist.L
        th = jnp.where(jnp.sum(jnp.isfinite(Cp), 1) >= k,
                       Cp.max(axis=1), INF)
        dl = jnp.where((nb_i >= 0) & (dl < th[:, None]) & active[:, None],
                       dl, INF)
        kv, ki = ops.ksort_l(dl, k)                         # kSort.L
        cand = jnp.take_along_axis(nb_i, ki, axis=1)        # [B, k]
        valid = jnp.isfinite(kv) & (cand >= 0)
        # -- visited check (V-list) --
        seen = (V[:, None, :] == cand[:, :, None]).any(-1)
        valid &= ~seen
        # -- step 3: k irregular high-dim fetches + Dist.H --
        xh = jnp.take(db.high, jnp.maximum(cand, 0), axis=0)  # [B, k, D]
        dh = jnp.where(valid, ops.dist_h(xh, q_high), INF)    # Dist.H
        # -- V append (ring) --
        slot = (vptr[:, None] + jnp.arange(k)[None, :]) % vcap
        V = jax.vmap(lambda v, s, cnd, vl:
                     v.at[s].set(jnp.where(vl, cnd, v[s])))(
                         V, slot, cand, valid)
        vptr = vptr + valid.sum(axis=1)
        # -- accept: d < F.max or F not full (F starts padded with INF) --
        accept = dh < F_d.max(axis=1)[:, None]
        dh_acc = jnp.where(accept, dh, INF)
        cand_acc = jnp.where(accept, cand, -1)
        F_d, F_i = _merge_topk(F_d, F_i, dh_acc, cand_acc, ef)
        # push to C: replace worst slots
        C_d2 = jnp.concatenate([C_d, dh_acc], axis=1)
        C_i2 = jnp.concatenate([C_i, cand_acc], axis=1)
        C_d, C_i = _merge_topk(C_d2, C_i2, jnp.full((B, 1), INF),
                               jnp.full((B, 1), -1, jnp.int32), CAP)
        # C_pca threshold heap update (low-dim dists of accepted)
        kv_acc = jnp.where(accept, kv, INF)
        Cp, _ = _merge_topk(Cp, cand_acc, kv_acc, cand_acc, k)
        return (t + 1, C_d, C_i, F_d, F_i, V, vptr, Cp)

    _, _, _, F_d, F_i, _, _, _ = jax.lax.while_loop(cond, body, state)
    return F_d, F_i


import functools


@functools.partial(jax.jit, static_argnames=("ef0", "k_schedule"))
def _search_batched_jit(db, queries, q_low, ef0, k_schedule):
    return _search_batched_impl(db, queries, q_low, ef0=ef0,
                                k_schedule=k_schedule)


def search_batched(db: PackedDB, queries, q_low=None, *, pca=None,
                   ef0: Optional[int] = None,
                   k_schedule: Optional[Tuple[int, ...]] = None):
    """Full multi-layer pHNSW search for a batch (jit'd).
    queries: [B, D] (device). Returns (dists [B, ef0], idx [B, ef0])."""
    if q_low is None:
        q_low = pca.transform_jnp(queries).astype(jnp.float32)
    return _search_batched_jit(db, queries, q_low,
                               ef0 or db.cfg.ef0,
                               k_schedule or db.cfg.k_schedule)


def _search_batched_impl(db: PackedDB, queries, q_low, *,
                         ef0: Optional[int] = None,
                         k_schedule: Optional[Tuple[int, ...]] = None):
    cfg = db.cfg
    B = queries.shape[0]
    ks = k_schedule or cfg.k_schedule
    k_of = lambda l: ks[min(l, len(ks) - 1)]
    ep = jnp.full((B, 1), db.entry, jnp.int32)
    ep_d = ops.dist_h(jnp.take(db.high, ep, axis=0), queries)
    n_layers = len(db.layers)
    for layer in range(n_layers - 1, 0, -1):
        ep_d, ep = search_layer_batched(
            db, layer, queries, q_low, ep_d, ep,
            ef=cfg.ef_for_layer(layer), k=k_of(layer))
    return search_layer_batched(db, 0, queries, q_low, ep_d, ep,
                                ef=ef0 or cfg.ef0, k=k_of(0))
