"""Per-layer k selection (paper Section III-B, Fig 2).

The paper's method:
  * upper layers (2..5): k = 3 x ef = 3 (pKNN's recommendation of three
    times the search-candidate count, ef=1);
  * denser layers get larger k: sweep k(layer1) at fixed k(layer0), pick
    the recall knee; then sweep k(layer0) at the chosen k(layer1);
  * stop increasing k when recall saturates — beyond the knee QPS drops
    (paper: up to 21.4% at k0=18) with no recall gain.

``sweep`` reproduces the Fig 2 curves (recall@10 + modeled QPS per k);
``select_schedule`` automates the paper's manual procedure.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cost_model import DDR4, HBM, query_cost
from repro.core.search_ref import run_queries


@dataclass
class SweepPoint:
    k0: int
    k1: int
    recall: float
    qps_ddr4: float
    qps_hbm: float


def _eval_schedule(g, x_low, pca, queries, gt, k0: int, k1: int,
                   upper: int = 3) -> SweepPoint:
    ks = (k0, k1, upper, upper, upper, upper)
    recall, st = run_queries(g, queries, gt, algo="phnsw", x_low=x_low,
                             pca=pca, k_schedule=ks)
    dim, d_low = g.x.shape[1], x_low.shape[1]
    n = len(queries)
    c4 = query_cost(st, n_queries=n, dim=dim, d_low=d_low, dram=DDR4)
    ch = query_cost(st, n_queries=n, dim=dim, d_low=d_low, dram=HBM)
    return SweepPoint(k0=k0, k1=k1, recall=recall, qps_ddr4=c4.qps,
                      qps_hbm=ch.qps)


def sweep_k1(g, x_low, pca, queries, gt, *, k0: int = 16,
             k1_values=(2, 4, 6, 8, 10, 12)) -> List[SweepPoint]:
    """Fig 2(a): vary k(layer1) at fixed k(layer0)."""
    return [_eval_schedule(g, x_low, pca, queries, gt, k0, k1)
            for k1 in k1_values]


def sweep_k0(g, x_low, pca, queries, gt, *, k1: int = 8,
             k0_values=(8, 10, 12, 14, 16, 18, 20)) -> List[SweepPoint]:
    """Fig 2(b): vary k(layer0) at fixed k(layer1)."""
    return [_eval_schedule(g, x_low, pca, queries, gt, k0, k1)
            for k0 in k0_values]


def select_schedule(g, x_low, pca, queries, gt, *,
                    recall_tolerance: float = 0.005
                    ) -> Tuple[Tuple[int, ...], Dict]:
    """Automated version of the paper's manual knee-finding: choose the
    smallest k at which recall is within ``recall_tolerance`` of the
    saturated (max) recall — first for layer1, then layer0."""
    s1 = sweep_k1(g, x_low, pca, queries, gt)
    best_r1 = max(p.recall for p in s1)
    k1 = next(p.k1 for p in s1 if p.recall >= best_r1 - recall_tolerance)
    s0 = sweep_k0(g, x_low, pca, queries, gt, k1=k1)
    best_r0 = max(p.recall for p in s0)
    k0 = next(p.k0 for p in s0 if p.recall >= best_r0 - recall_tolerance)
    schedule = (k0, k1, 3, 3, 3, 3)
    return schedule, {"sweep_k1": s1, "sweep_k0": s0}
