"""Product quantization — the alternative filter from Flash [15]
(related work): instead of PCA's dense low-dim projection, split the
vector into M subspaces and code each with an 8-bit codebook.

Used by the filter ablation (benchmarks/bench_pq_ablation.py): at a
matched byte budget per vector, does the paper's PCA filter or a PQ
filter rank candidates better? PQ codes are 4 bits/dim-equivalent
smaller but quantize distances; PCA keeps exact arithmetic in a smaller
space. The paper chose PCA and back-projection; Flash chose PQ + SIMD —
this benchmark quantifies the recall trade at equal memory.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass
class PQCodebook:
    centroids: np.ndarray      # [M, 256, dsub]

    @property
    def n_sub(self) -> int:
        return self.centroids.shape[0]

    @property
    def dsub(self) -> int:
        return self.centroids.shape[2]

    @property
    def bytes_per_vec(self) -> int:
        return self.n_sub            # one uint8 code per subspace


def _init_centroids(xs: np.ndarray, rng: np.random.Generator,
                    p: np.ndarray = None) -> np.ndarray:
    """256 initial centroids from ``xs`` (optionally ``p``-weighted).
    When the training set (or the weighted support) is smaller than the
    code count, sample WITH replacement and jitter the duplicates apart
    — ``replace=False`` raises for n < 256, which the small sharded
    build path hits."""
    n = len(xs)
    support = n if p is None else int(np.count_nonzero(p))
    if support >= 256:
        return xs[rng.choice(n, 256, replace=False, p=p)].copy()
    idx = rng.choice(n, 256, replace=True, p=p)
    c = xs[idx].copy()
    scale = float(xs.std(0).mean()) if n > 1 else 1.0
    c += rng.normal(0.0, max(scale, 1e-6) * 1e-3,
                    c.shape).astype(np.float32)
    return c


def train_pq(x: np.ndarray, n_sub: int, *, iters: int = 8,
             seed: int = 0, weights: np.ndarray = None) -> PQCodebook:
    """Lloyd k-means (k=256) per subspace.

    ``weights`` (optional, [n] non-negative): per-point training
    weights — density-aware codebooks weight points by graph-layer
    occupancy so regions the traversal actually visits get more code
    resolution. Weighted init sampling + weighted cluster means;
    assignment stays nearest-centroid.
    """
    n, d = x.shape
    assert d % n_sub == 0, (d, n_sub)
    dsub = d // n_sub
    rng = np.random.default_rng(seed)
    p = None
    w = None
    if weights is not None:
        w = np.asarray(weights, np.float64)
        assert w.shape == (n,) and (w >= 0).all() and w.sum() > 0, \
            "weights must be [n] non-negative with positive sum"
        p = w / w.sum()
    cents = np.empty((n_sub, 256, dsub), np.float32)
    for m in range(n_sub):
        xs = x[:, m * dsub:(m + 1) * dsub].astype(np.float32)
        c = _init_centroids(xs, rng, p)
        for _ in range(iters):
            d2 = ((xs[:, None, :] - c[None]) ** 2).sum(-1) \
                if n <= 20000 else None
            if d2 is None:
                # blockwise assignment for larger n
                assign = np.empty(n, np.int64)
                for i in range(0, n, 8192):
                    blk = xs[i:i + 8192]
                    d2b = ((blk[:, None, :] - c[None]) ** 2).sum(-1)
                    assign[i:i + 8192] = d2b.argmin(1)
            else:
                assign = d2.argmin(1)
            empty = []
            for k in range(256):
                sel = assign == k
                if not sel.any():
                    empty.append(k)
                elif w is None:
                    c[k] = xs[sel].mean(0)
                else:
                    ws = w[sel]
                    tot = ws.sum()
                    c[k] = ((ws[:, None] * xs[sel]).sum(0) / tot
                            if tot > 0 else xs[sel].mean(0))
            if empty:
                # reseed empty clusters to the farthest-assigned points
                # — a stale initial centroid would otherwise survive as
                # a duplicate dead code (recall loss at scale)
                d_assigned = ((xs - c[assign]) ** 2).sum(-1)
                far = np.argsort(-d_assigned)
                for k, i in zip(empty, far):
                    c[k] = xs[i]
        cents[m] = c
    return PQCodebook(centroids=cents)


def encode_pq(cb: PQCodebook, x: np.ndarray) -> np.ndarray:
    """x: [N, D] -> codes [N, M] uint8."""
    n, d = x.shape
    dsub = cb.dsub
    codes = np.empty((n, cb.n_sub), np.uint8)
    for m in range(cb.n_sub):
        xs = x[:, m * dsub:(m + 1) * dsub].astype(np.float32)
        for i in range(0, n, 8192):
            blk = xs[i:i + 8192]
            d2 = ((blk[:, None, :] - cb.centroids[m][None]) ** 2).sum(-1)
            codes[i:i + 8192, m] = d2.argmin(1).astype(np.uint8)
    return codes


def adc_table(cb: PQCodebook, q: np.ndarray) -> np.ndarray:
    """Asymmetric distance tables for one query: [M, 256]."""
    dsub = cb.dsub
    tabs = np.empty((cb.n_sub, 256), np.float32)
    for m in range(cb.n_sub):
        qs = q[m * dsub:(m + 1) * dsub].astype(np.float32)
        tabs[m] = ((cb.centroids[m] - qs[None]) ** 2).sum(-1)
    return tabs


def adc_tables_from_centroids(centroids, q, xp):
    """Backend-generic batched ADC tables: centroids [M, 256, dsub],
    q [B, D] -> [B, M, 256] f32. ONE implementation shared by the host
    oracle (``adc_table_batch``, xp=numpy) and the device prep
    (``PQFilter.prepare_jnp``, xp=jax.numpy) so the two cannot drift."""
    B = q.shape[0]
    M, _, dsub = centroids.shape
    qs = q.astype(xp.float32).reshape(B, M, 1, dsub)
    return ((qs - centroids[None]) ** 2).sum(-1)


def adc_table_batch(cb: PQCodebook, q: np.ndarray) -> np.ndarray:
    """Batched ADC tables: q [B, D] -> [B, n_sub, 256] f32 — the
    per-query preparation of the PQ filter (the PQ analogue of the PCA
    projection)."""
    return adc_tables_from_centroids(cb.centroids, q, np)


def adc_distances(tabs: np.ndarray, codes: np.ndarray) -> np.ndarray:
    """codes: [N, M] -> approximate squared distances [N]."""
    return tabs[np.arange(tabs.shape[0])[None, :], codes].sum(1)
