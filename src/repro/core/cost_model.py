"""pHNSW processor cost model (paper Section V: Synopsys/CACTI/Ramulator
evaluation, re-derived analytically from instrumented search traces).

Constants and their provenance:
  * 1 GHz clock, Table II cycle counts (kSort.L=7, Min.H=1, RMF=8,
    Visit&Raw=2, Move=1, JMP=1).
  * Dist.L: 16 distance lanes (Section IV-B3 "processing 16 data points
    simultaneously"), pipelined over d_low dims -> d_low cycles per
    16-point group.
  * Dist.H: sequential high-dim unit; 4 MACs/cycle (4B register lanes)
    -> dim/4 cycles per point.
  * Move overhead: the paper reports Move at up to 72.8% of executed
    instructions, i.e. 2.68 Moves per compute instruction, executed on
    TWO Move/BUS units -> 1.34 cycles of Move per compute cycle.
  * DDR4: 19.2 GB/s, 18.75 pJ/bit; HBM1.0: 128 GB/s, 7 pJ/bit
    (Section V-A). Random-access latency 45/40 ns (Ramulator DDR4-2400 /
    HBM tRC-class timings), 10 ns burst-setup overhead.
  * Core power 150 mW dynamic + 50 mW leakage (65 nm, 0.739 mm^2 class
    design) — energy = P * t; DRAM energy = bytes * pJ/bit. These two
    constants were chosen once so the DRAM energy share lands in the
    paper's reported bands (82-87% DDR4, 63-72% HBM) and then frozen;
    all RATIOS reported in benchmarks derive from measured traces, not
    from tuning.

Compute and DRAM time are modeled as non-overlapped (conservative): the
single-query processor blocks on DMA (Section IV-C dataflow), which is
also the paper's explanation for pHNSW-Sep's energy waste ("energy
consumed by other components waiting for data").
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional

from repro.core.search_ref import SearchStats


@dataclass(frozen=True)
class DramConfig:
    name: str
    bandwidth_gbps: float      # GB/s
    pj_per_bit: float
    rand_latency_ns: float     # exposed per irregular access
    burst_overhead_ns: float   # per sequential burst

    def time_ns(self, st: SearchStats) -> float:
        seq = st.seq_bursts * self.burst_overhead_ns \
            + st.seq_bytes / self.bandwidth_gbps
        rand = st.rand_accesses * self.rand_latency_ns \
            + st.rand_bytes / self.bandwidth_gbps
        return seq + rand

    def energy_pj(self, st: SearchStats) -> float:
        return (st.seq_bytes + st.rand_bytes) * 8.0 * self.pj_per_bit


DDR4 = DramConfig("DDR4", 19.2, 18.75, 45.0, 10.0)
HBM = DramConfig("HBM", 128.0, 7.0, 40.0, 10.0)


@dataclass(frozen=True)
class ProcessorConfig:
    name: str = "phnsw"
    freq_ghz: float = 1.0
    dist_lanes: int = 16        # Dist.L parallel lanes
    ksort_cycles: int = 7       # Table II
    disth_macs_per_cycle: int = 4
    minh_cycles: int = 1
    visit_cycles: int = 2
    rmf_cycles: int = 8
    heap_cycles: int = 4        # C/F list update (register ops)
    move_per_compute: float = 2.68   # -> 72.8% Move share
    move_units: int = 2
    dyn_power_w: float = 0.150
    static_power_w: float = 0.050

    def compute_cycles(self, st: SearchStats, dim: int, d_low: int,
                       d_mid: int = 0) -> Dict:
        """``d_low`` is the per-point filter pipeline depth: d_low dims
        for the PCA filter, n_sub table lookups for PQ, the full dim
        for the identity bypass — pass ``FilterSpec.cost_dims`` (or use
        ``query_cost(..., filt=...)``) so the modeled compute stays
        honest across filters. ``d_mid`` prices the cascade's promote
        stage (``SearchStats.dist_mid`` evals, PCA-row depth =
        ``CascadeFilter.mid_cost_dims``) on the same 16-lane Dist.L
        unit — a separate term because the two stages run at different
        pipeline depths (ADC table lookups vs f32 dims)."""
        c = {}
        c["dist_l"] = math.ceil(st.dist_low / self.dist_lanes) * d_low
        if st.dist_mid:
            c["dist_m"] = math.ceil(st.dist_mid / self.dist_lanes) * d_mid
        c["ksort_l"] = st.ksort_calls * self.ksort_cycles
        c["dist_h"] = st.dist_high * math.ceil(dim / self.disth_macs_per_cycle)
        c["min_h"] = st.minh_calls * self.minh_cycles
        c["visit"] = st.visit_checks * self.visit_cycles
        c["rmf"] = st.evictions * self.rmf_cycles
        c["heap"] = st.f_updates * self.heap_cycles
        c["jmp"] = st.expansions
        compute = sum(c.values())
        c["move"] = compute * self.move_per_compute / self.move_units
        return c


PROCESSOR = ProcessorConfig()


@dataclass
class QueryCost:
    compute_ns: float
    dram_ns: float
    core_pj: float
    dram_pj: float
    breakdown: Dict[str, float]

    @property
    def total_ns(self) -> float:
        return self.compute_ns + self.dram_ns

    @property
    def total_pj(self) -> float:
        return self.core_pj + self.dram_pj

    @property
    def qps(self) -> float:
        return 1e9 / self.total_ns

    @property
    def energy_uj(self) -> float:
        return self.total_pj / 1e6

    @property
    def dram_energy_share(self) -> float:
        return self.dram_pj / max(self.total_pj, 1e-12)


def query_cost(st: SearchStats, *, n_queries: int, dim: int,
               d_low: Optional[int] = None, dram: DramConfig,
               proc: ProcessorConfig = PROCESSOR, filt=None,
               d_mid: Optional[int] = None) -> QueryCost:
    """Cost of ONE query given aggregate stats over ``n_queries``.

    The filter payload is priced generically: DRAM traffic arrives in
    the stats already weighted by the active filter's bytes/vector
    (``FilterSpec.bytes_per_vec`` — e.g. ``PQCodebook.bytes_per_vec``
    for PQ codes), and the filter-distance compute depth comes from
    ``filt.cost_dims`` when ``filt`` is given (``d_low`` is the
    PCA-era spelling, kept for the seed callers). Cascade stats carry
    a second stage (``dist_mid``, the PCA promote pass) priced at
    ``d_mid`` — taken from ``filt.mid_cost_dims`` when available,
    falling back to ``d_low`` so two-stage stats are never silently
    priced at depth zero."""
    if filt is not None:
        d_low = filt.cost_dims
        d_mid = getattr(filt, "mid_cost_dims", d_mid)
    if d_low is None:
        raise ValueError("query_cost needs d_low or filt")
    if d_mid is None:
        d_mid = d_low
    per = SearchStats(**{k: v / n_queries for k, v in st.as_dict().items()})
    cyc = proc.compute_cycles(per, dim, d_low, d_mid)
    compute_ns = sum(cyc.values()) / proc.freq_ghz
    dram_ns = dram.time_ns(per)
    total_s = (compute_ns + dram_ns) * 1e-9
    core_pj = (proc.dyn_power_w + proc.static_power_w) * total_s * 1e12
    dram_pj = dram.energy_pj(per)
    return QueryCost(compute_ns=compute_ns, dram_ns=dram_ns,
                     core_pj=core_pj, dram_pj=dram_pj,
                     breakdown={k: v / proc.freq_ghz for k, v in cyc.items()})


def hw_variant_stats(stats_hnsw: SearchStats, stats_packed: SearchStats,
                     stats_separate: SearchStats) -> Dict[str, SearchStats]:
    """The three processor variants of Table III."""
    return {"HNSW-Std": stats_hnsw, "pHNSW-Sep": stats_separate,
            "pHNSW": stats_packed}


def table3(stats: Dict[str, SearchStats], *, n_queries: int, dim: int,
           d_low: int) -> Dict[str, Dict[str, QueryCost]]:
    """{variant: {dram: QueryCost}} for the Table III grid."""
    out: Dict[str, Dict[str, QueryCost]] = {}
    for name, st in stats.items():
        out[name] = {}
        for dram in (DDR4, HBM):
            out[name][dram.name] = query_cost(
                st, n_queries=n_queries, dim=dim, d_low=d_low, dram=dram)
    return out
