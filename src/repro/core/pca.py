"""PCA transform for pHNSW Step 1 (paper Fig. 1(c)): project the database
from dim -> d_low, preserving maximum variance.

Fit is exact (eigendecomposition of the covariance; numpy, done once at
index-build time on the host). Transform is a jnp matmul so it can run
sharded on the mesh. The transform keeps distances approximately:
||P(x) - P(q)||^2 <= ||x - q||^2 (orthonormal rows), so low-dim distances
underestimate true distances — the property the filter relies on."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np
import jax.numpy as jnp


@dataclass
class PCA:
    mean: np.ndarray        # [D]
    components: np.ndarray  # [D, d_low]  (orthonormal columns)
    explained: np.ndarray   # [d_low] fraction of variance per component
    # device-array cache for transform_jnp: wrapping mean/components with
    # jnp.asarray on every call re-pays a host->device transfer per
    # query batch; the projection matrices are frozen after fit, so they
    # are uploaded once and reused (excluded from ==/repr)
    _mean_jnp: Optional[jnp.ndarray] = field(
        default=None, init=False, repr=False, compare=False)
    _components_jnp: Optional[jnp.ndarray] = field(
        default=None, init=False, repr=False, compare=False)

    @property
    def d_low(self) -> int:
        return self.components.shape[1]

    def transform(self, x):
        return (x - self.mean) @ self.components

    def transform_jnp(self, x):
        if self._mean_jnp is None:
            self._mean_jnp = jnp.asarray(self.mean)
            self._components_jnp = jnp.asarray(self.components)
        return (x - self._mean_jnp) @ self._components_jnp

    def inverse(self, z):
        return z @ self.components.T + self.mean


def fit_pca(x: np.ndarray, d_low: int) -> PCA:
    """x: [N, D] float; exact PCA via covariance eigendecomposition."""
    x = np.asarray(x, np.float64)
    mean = x.mean(axis=0)
    xc = x - mean
    cov = xc.T @ xc / max(len(x) - 1, 1)
    w, v = np.linalg.eigh(cov)            # ascending
    order = np.argsort(w)[::-1][:d_low]
    comps = v[:, order]
    explained = w[order] / max(w.sum(), 1e-12)
    return PCA(mean.astype(np.float32), comps.astype(np.float32),
               explained.astype(np.float32))
