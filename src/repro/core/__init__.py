from repro.core.pca import PCA, fit_pca
from repro.core.graph import (HNSWGraph, build_hnsw, build_hnsw_ref,
                              cached_graph)
from repro.core.build import build_hnsw_wave, graph_invariants
from repro.core.filters import (FilterSpec, IdentityFilter, PCAFilter,
                                PQFilter, make_filter)
from repro.core.search_ref import (SearchStats, search_hnsw, search_phnsw,
                                   search_filtered, search_sharded,
                                   run_queries, recall_at)
from repro.core.search_jax import PackedDB, build_packed, search_batched
from repro.core.cost_model import (DDR4, HBM, PROCESSOR, QueryCost,
                                   query_cost, table3, hw_variant_stats)
from repro.core.kselect import select_schedule, sweep_k0, sweep_k1

__all__ = [
    "PCA", "fit_pca", "HNSWGraph", "build_hnsw", "build_hnsw_ref",
    "build_hnsw_wave", "graph_invariants", "cached_graph",
    "FilterSpec", "IdentityFilter", "PCAFilter", "PQFilter",
    "make_filter", "SearchStats", "search_hnsw", "search_phnsw",
    "search_filtered", "search_sharded", "run_queries",
    "recall_at", "PackedDB", "build_packed", "search_batched",
    "DDR4", "HBM", "PROCESSOR", "QueryCost", "query_cost", "table3",
    "hw_variant_stats", "select_schedule", "sweep_k0", "sweep_k1",
]
