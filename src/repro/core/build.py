"""Wave-based device-accelerated bulk construction (DESIGN.md
§ Construction pipeline).

The paper accelerates the S phase and leaves the C phase host-side; the
sequential builder (``graph.build_hnsw_ref``) caps every build at numpy
speed — one python beam search per insert. Malkov-Yashunin construction
is insertion-order-robust enough to batch: probing a WAVE of inserts
against a fixed snapshot and linking the whole wave vectorized
reproduces sequential-build recall. The pipeline:

  1. **Levels up front.** ``sample_levels`` draws every node's level
     before any insert (identical to the sequential builder for a given
     seed — same levels, same final entry point).
  2. **Batched device probe.** Each wave of ``cfg.wave_size`` vectors
     runs ONE fused-kernel beam search (``search_jax.probe_
     neighborhoods`` — the PR-1 S-phase kernels at ``ef =
     ef_construction``, every layer's top-ef seeding the next) against
     the snapshot published from the previous waves. The one-shot
     builder probes through an identity-filter snapshot (zero-width
     payload: construction is pure high-dim, exactly like the
     sequential oracle); ``MutableIndex`` probes through its live
     filtered snapshot.
  3. **Intra-wave block.** The probe's snapshot predates the wave, so
     wave-internal neighbors are invisible to it; one brute-force
     [B, B] distance block supplies them as candidates.
  4. **Vectorized linking.** Diversity-heuristic selection (Alg. 4) and
     degree-bounded bidirectional linking run over the WHOLE wave as
     masked numpy array ops (``select_heuristic_batch`` /
     ``link_wave``) — the greedy dependency is per-candidate-slot, so
     the loop is C iterations of [B, C] vector work, not B * C python
     iterations.

``build_hnsw`` (core/graph.py) dispatches here by default;
``MutableIndex._insert_batch`` and the sharded builders
(``core/distributed.build_sharded``, ``index/sharded.py``) route
through the same probe + ``link_wave`` pipeline.
"""
from __future__ import annotations

import time
from typing import List, Optional

import numpy as np
import jax.numpy as jnp

from repro.configs.base import PHNSWConfig
from repro.constants import INF, VALID_MAX
from repro.core.graph import HNSWGraph, sample_levels


def pad_rows_pow2(rows: np.ndarray) -> np.ndarray:
    """Pad a dirty-row id list to a power-of-two length (repeating the
    last id — an idempotent re-set) so eager ``.at[rows].set`` scatters
    only ever see O(log N) distinct shapes. Shared by the wave
    builder's incremental snapshot refresh and the mutable index's
    incremental publish."""
    n = max(len(rows), 1)
    b = 1
    while b < n:
        b *= 2
    return np.pad(rows, (0, b - len(rows)), mode="edge") if len(rows) \
        else np.zeros(1, np.int64)


def pairwise_sq(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """[n, D] x [m, D] -> [n, m] squared L2 distances (f32)."""
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    sa = np.einsum("id,id->i", a, a)
    sb = np.einsum("id,id->i", b, b)
    d = sa[:, None] + sb[None, :] - 2.0 * (a @ b.T)
    return np.maximum(d, 0.0, out=d)


def select_heuristic_batch(x: np.ndarray, cand_d: np.ndarray,
                           cand_i: np.ndarray, m: int):
    """Malkov-Yashunin Algorithm 4 over a BATCH of nodes at once.

    ``cand_d``/``cand_i``: [B, C] per-node candidate dists/ids sorted
    ascending (INF / -1 padding). Keep a candidate only if it is closer
    to its node than to every already-selected neighbor; backfill with
    the nearest rejected when underfull — identical acceptance rule to
    the scalar ``graph._select_heuristic``, restated as C rounds of
    [B, C] masked vector ops (the greedy dependency is along C, so the
    batch dimension vectorizes cleanly).

    Float caveat: inter-candidate distances use the clamped expansion
    formula (one batched matmul), which can land an ulp below the
    oracle's direct-difference sum — EXACT ties (duplicate points)
    may therefore resolve differently than the scalar oracle (the
    strict ``<`` flips and the tied candidate is backfilled instead of
    heuristic-accepted: closest-M behavior around duplicates, a
    quality-neutral degeneracy). Duplicate-free data matches the
    oracle bit-for-bit (tests/test_build.py).

    Returns (rows [B, m] int32 — selected ids, accepted-then-backfilled
    order, -1 padded; total [B]; sel_final [B, C] bool mask over the
    candidate grid)."""
    B, C = cand_d.shape
    valid = (cand_i >= 0) & (cand_d < VALID_MAX)
    safe = np.where(cand_i >= 0, cand_i, 0)
    xc = x[safe]                                        # [B, C, D]
    sq = np.einsum("bcd,bcd->bc", xc, xc)
    d_cc = sq[:, :, None] + sq[:, None, :] \
        - 2.0 * np.matmul(xc, xc.transpose(0, 2, 1))    # [B, C, C]
    np.maximum(d_cc, 0.0, out=d_cc)     # expansion can go ulp-negative
    sel = np.zeros((B, C), bool)
    count = np.zeros(B, np.int64)
    for c in range(C):
        viol = (sel & (d_cc[:, c, :] < cand_d[:, c, None])).any(1)
        ok = valid[:, c] & ~viol & (count < m)
        sel[:, c] = ok
        count += ok
    # backfill with the nearest rejected (candidates are ascending)
    rej = valid & ~sel
    fill = rej & (np.cumsum(rej, axis=1) <= (m - count)[:, None])
    total = count + fill.sum(1)
    # row order: heuristic-accepted ascending, then backfilled ascending
    cols = np.arange(C)[None, :]
    key = np.where(sel, cols, np.where(fill, C + cols, 2 * C + cols))
    w = min(m, C)                   # C < m: fewer candidates than slots
    order = np.argsort(key, axis=1, kind="stable")[:, :w]
    picked = np.take_along_axis(cand_i, order, axis=1)
    rows = np.full((B, m), -1, np.int32)
    rows[:, :w] = np.where(np.arange(w)[None, :] < total[:, None],
                           picked, -1)
    return rows, total, sel | fill


def link_wave_layer(x: np.ndarray, adj_l: np.ndarray,
                    node_ids: np.ndarray, cand_d: np.ndarray,
                    cand_i: np.ndarray) -> np.ndarray:
    """Link one wave at one layer, fully vectorized: batched forward
    diversity selection, then batched degree-bounded bidirectional
    (reverse) linking — free-slot appends scattered in one shot,
    overfull rows re-selected with the SAME batched heuristic (the
    hnswlib re-selection ``graph.add_link`` does one edge at a time).
    Mutates ``adj_l`` in place; returns the ids of every row that
    changed."""
    m = adj_l.shape[1]
    node_ids = np.asarray(node_ids, np.int64)
    if len(node_ids) == 0 or cand_d.shape[1] == 0:
        return np.empty(0, np.int64)

    # --- forward: each wave node's own neighbor row ---
    rows, total, sel = select_heuristic_batch(x, cand_d, cand_i, m)
    has = total > 0
    adj_l[node_ids[has]] = rows[has]

    # --- reverse: add each wave node to its selected neighbors ---
    bb, cc = np.nonzero(sel)
    tgt = cand_i[bb, cc].astype(np.int64)
    src = node_ids[bb]
    d_ts = cand_d[bb, cc].astype(np.float32)
    # intra-wave symmetry dedup: if tgt is itself a wave node whose
    # forward row already selected src, don't add src twice
    dup = (adj_l[tgt] == src[:, None]).any(1)
    if dup.any():
        tgt, src, d_ts = tgt[~dup], src[~dup], d_ts[~dup]
    if len(tgt) == 0:
        return np.unique(node_ids[has])

    order = np.argsort(tgt, kind="stable")       # group by target;
    t_s, s_s, d_s = tgt[order], src[order], d_ts[order]  # stable keeps
    ut, start, cnt = np.unique(t_s, return_index=True,   # wave order
                               return_counts=True)
    within = np.arange(len(t_s)) - np.repeat(start, cnt)
    inv = np.repeat(np.arange(len(ut)), cnt)
    first_free = (adj_l[ut] >= 0).sum(1)         # -1 pad is a suffix
    overfull = first_free + cnt > m

    # free-slot appends (no re-selection needed): one scatter
    app = ~overfull[inv]
    if app.any():
        adj_l[t_s[app], (first_free[inv] + within)[app]] = s_s[app]

    # overfull targets: re-select {existing row + all incoming} with the
    # batched diversity heuristic
    if overfull.any():
        uo = ut[overfull]                        # [U]
        o_of = np.cumsum(overfull) - 1           # ut idx -> uo idx
        pm = overfull[inv]                       # pairs on overfull tgts
        R = int(cnt[overfull].max())
        U = len(uo)
        inc_i = np.full((U, R), -1, np.int64)
        inc_d = np.full((U, R), INF, np.float32)
        inc_i[o_of[inv[pm]], within[pm]] = s_s[pm]
        inc_d[o_of[inv[pm]], within[pm]] = d_s[pm]
        ex_i = adj_l[uo].astype(np.int64)        # [U, m]
        ex_ok = ex_i >= 0
        diff = x[np.where(ex_ok, ex_i, 0)] - x[uo][:, None, :]
        ex_d = np.einsum("umd,umd->um", diff, diff).astype(np.float32)
        ex_d = np.where(ex_ok, ex_d, INF)
        c2_d = np.concatenate([ex_d, inc_d], 1)
        c2_i = np.concatenate([ex_i, inc_i], 1)
        o2 = np.argsort(c2_d, axis=1, kind="stable")
        c2_d = np.take_along_axis(c2_d, o2, 1)
        c2_i = np.take_along_axis(c2_i, o2, 1)
        rows2, _, _ = select_heuristic_batch(x, c2_d, c2_i, m)
        adj_l[uo] = rows2

    return np.unique(np.concatenate([node_ids[has], ut]))


def link_wave(x: np.ndarray, adj: List[np.ndarray],
              node_ids: np.ndarray, levels: np.ndarray,
              probe_d: Optional[np.ndarray],
              probe_i: Optional[np.ndarray], block_d: np.ndarray,
              cfg: PHNSWConfig, *, max_cand: Optional[int] = None
              ) -> List[np.ndarray]:
    """Link a wave of new nodes into the graph at every layer they
    occupy. Per layer, each node's candidate set is the union of its
    device-probe results (level-masked: a link at layer l may only
    target nodes with level >= l — the probe can hand back lower-level
    seeds at layers above the snapshot's top) and its intra-wave peers
    from ``block_d``, merged ascending and truncated to ``max_cand``
    (default ef_construction, the sequential beam width).

    ``probe_d``/``probe_i``: [Lp, B, E] bottom-layer-first (fewer
    layers than the wave's max level is fine). ``block_d``: [B, B]
    squared dists among the wave, diagonal = INF. Mutates ``adj`` in
    place; returns the changed row ids per layer (len(adj) entries) —
    the mutable index feeds these to its incremental publish."""
    node_ids = np.asarray(node_ids, np.int64)
    lvls = np.asarray(levels)[node_ids]
    Lp = 0 if probe_d is None else probe_d.shape[0]
    C_cap = int(max_cand or cfg.ef_construction)
    dirty = [np.empty(0, np.int64) for _ in range(len(adj))]
    for l in range(min(int(lvls.max()) + 1, len(adj)) - 1, -1, -1):
        rows = np.nonzero(lvls >= l)[0]
        if len(rows) == 0:
            continue
        parts_d, parts_i = [], []
        if l < Lp:
            pd = np.asarray(probe_d[l][rows], np.float32)
            pi = np.asarray(probe_i[l][rows], np.int64)
            ok = (pi >= 0) & (pd < VALID_MAX)
            ok &= np.asarray(levels)[np.where(pi >= 0, pi, 0)] >= l
            parts_d.append(np.where(ok, pd, INF))
            parts_i.append(np.where(ok, pi, -1))
        if len(rows) > 1:
            bd = np.asarray(block_d[np.ix_(rows, rows)], np.float32)
            parts_d.append(bd)            # diag already INF (self)
            parts_i.append(np.broadcast_to(node_ids[rows][None, :],
                                           bd.shape).copy())
        if not parts_d:
            continue
        cd = np.concatenate(parts_d, 1)
        ci = np.concatenate(parts_i, 1)
        if cd.shape[1] > C_cap:
            # cheap top-C preselection before the full sort: the block
            # contributes a wave-width column span, most of it far
            part = np.argpartition(cd, C_cap - 1, axis=1)[:, :C_cap]
            cd = np.take_along_axis(cd, part, 1)
            ci = np.take_along_axis(ci, part, 1)
        o = np.argsort(cd, axis=1, kind="stable")
        cd = np.take_along_axis(cd, o, 1)
        ci = np.take_along_axis(ci, o, 1)
        dirty[l] = link_wave_layer(x, adj[l], node_ids[rows], cd, ci)
    return dirty


def build_hnsw_wave(x: np.ndarray, cfg: PHNSWConfig, *, seed: int = 0,
                    wave_size: Optional[int] = None,
                    verbose: bool = False) -> HNSWGraph:
    """The wave pipeline, one-shot form: levels up front, then waves of
    ``wave_size`` — device probe against the running snapshot +
    vectorized wave linking. The snapshot republishes once per wave
    with FIXED shapes (full-N buffers, all final layers from the start
    — empty top layers are inert, the probe's frontier exhausts in one
    pop), so the probe program compiles exactly once per build shape.
    Construction runs in pure high-dim space (identity-filter snapshot,
    zero-width payload) — the same metric as the sequential oracle."""
    from repro.core.search_jax import (PackedDB, PackedLayer,
                                       probe_neighborhoods)
    n, dim = x.shape
    rng = np.random.default_rng(seed)
    levels = sample_levels(n, cfg, rng)
    n_layers = int(levels.max()) + 1
    adj = [np.full((n, cfg.degree(l)), -1, np.int32)
           for l in range(n_layers)]
    entry, top = 0, int(levels[0])
    if n > 1:
        B = int(wave_size or cfg.wave_size)
        high = jnp.asarray(np.asarray(x, np.float32))
        low = jnp.zeros((n, 0), jnp.float32)
        pl0 = [jnp.zeros((n, cfg.degree(l), 0), jnp.float32)
               for l in range(n_layers)]
        qprep = jnp.zeros((B, 0), jnp.float32)
        # device-resident adjacency, refreshed INCREMENTALLY: only the
        # rows link_wave changed are scattered back each wave (pow2-
        # padded so scatters see O(log n) shapes) — re-uploading full
        # [n, M_l] layers per wave would be quadratic over the build
        dev_adj = [jnp.asarray(a) for a in adj]
        t0 = time.perf_counter()
        done = 1                               # node 0 seeds the graph
        while done < n:
            ids = np.arange(done, min(done + B, n))
            b = len(ids)
            xb = np.asarray(x[ids], np.float32)
            db = PackedDB(
                layers=[PackedLayer(adj=dev_adj[l], packed_low=pl0[l])
                        for l in range(n_layers)],
                low=low, high=high, entry=entry, cfg=cfg,
                deleted=None, filter_kind="none")
            qx = xb if b == B else np.concatenate(
                [xb, np.broadcast_to(x[entry].astype(np.float32),
                                     (B - b, dim))])
            fd, fi = probe_neighborhoods(
                db, jnp.asarray(qx), qprep, cfg.ef_construction,
                cfg.ef_construction_k, filter_deleted=False,
                ef_upper=cfg.wave_ef_upper)
            fd = np.asarray(fd)[:, :b]
            fi = np.asarray(fi)[:, :b]
            block = pairwise_sq(xb, xb)
            np.fill_diagonal(block, INF)
            dirty = link_wave(x, adj, ids, levels, fd, fi, block, cfg)
            for l, d in enumerate(dirty):
                if len(d):
                    rows = pad_rows_pow2(d)
                    dev_adj[l] = dev_adj[l].at[rows].set(
                        jnp.asarray(adj[l][rows]))
            wmax = int(levels[ids].max())
            if wmax > top:
                entry = int(ids[int(np.argmax(levels[ids] == wmax))])
                top = wmax
            done = int(ids[-1]) + 1
            if verbose:
                vps = done / max(time.perf_counter() - t0, 1e-9)
                print(f"  wave {done}/{n} ({vps:.0f} vec/s)",
                      flush=True)
    # pad adjacency list count up to cfg.n_layers for uniform access
    while len(adj) < cfg.n_layers:
        adj.append(np.full((n, cfg.M), -1, np.int32))
    return HNSWGraph(cfg=cfg, x=x, levels=levels, layers=adj,
                     entry=entry)


# --------------------- structural invariant checker -----------------------

def graph_invariants(g: HNSWGraph) -> dict:
    """Check the structural invariants every builder must uphold.
    Returns {"ok", "violations": [...], "reachable_frac": [per layer],
    "mean_degree": [per layer]} — the test suite asserts ok, the CI
    build-smoke gate cross-checks wave output against the sequential
    oracle with it."""
    n = g.n
    violations = []
    reach_frac, mean_deg = [], []
    for l, a in enumerate(g.layers):
        present = np.nonzero(g.levels >= l)[0]
        valid = a >= 0
        if (a >= n).any():
            violations.append(f"layer {l}: id out of range")
        # -1 padding must be a strict suffix of each row
        if (valid[:, 1:] & ~valid[:, :-1]).any():
            violations.append(f"layer {l}: -1 pad not a suffix")
        rows_absent = np.ones(n, bool)
        rows_absent[present] = False
        if valid[rows_absent].any():
            violations.append(f"layer {l}: links on absent node rows")
        sub = a[present]
        if (sub == present[:, None]).any():
            violations.append(f"layer {l}: self link")
        s = np.sort(sub, axis=1)
        if ((s[:, 1:] == s[:, :-1]) & (s[:, 1:] >= 0)).any():
            violations.append(f"layer {l}: duplicate link")
        safe = np.where(sub >= 0, sub, 0)
        if ((g.levels[safe] < l) & (sub >= 0)).any():
            violations.append(f"layer {l}: link to node below layer")
        mean_deg.append(float((sub >= 0).sum(1).mean())
                        if len(present) else 0.0)
        # entry-reachability of every present node within the layer
        if len(present) == 0:
            reach_frac.append(1.0)
            continue
        reach = np.zeros(n, bool)
        if g.levels[g.entry] >= l:
            frontier = np.asarray([g.entry])
            reach[g.entry] = True
            while len(frontier):
                nb = a[frontier]
                nb = np.unique(nb[nb >= 0])
                nb = nb[~reach[nb]]
                reach[nb] = True
                frontier = nb
        reach_frac.append(float(reach[present].mean()))
    return {"ok": not violations, "violations": violations,
            "reachable_frac": reach_frac, "mean_degree": mean_deg}
