"""HNSW graph construction (paper's C phase).

Standard Malkov-Yashunin insertion: geometric level assignment
(mL = 1/ln(M)), greedy descent through upper layers, ef_construction beam
search + closest-M neighbor selection with degree-bounded bidirectional
linking. Two builders share those semantics (DESIGN.md § Construction
pipeline):

  * ``build_hnsw_ref`` — the sequential host insertion loop (numpy +
    heapq), kept as the recall/structure oracle;
  * the WAVE builder (``core/build.py``) — inserts in batches of
    ``cfg.wave_size``, probing each wave on device with the fused
    S-phase kernels and linking the whole wave with vectorized
    diversity selection. ``build_hnsw`` dispatches on ``cfg.builder``
    ("wave" by default).

Adjacency is stored as fixed-degree arrays ([N, M_l] int32, -1 padded) —
the regular layout both the cost model (layout (3)) and the fixed-shape
JAX search build on.
"""
from __future__ import annotations

import dataclasses
import hashlib
import heapq
import math
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import List, Optional

import numpy as np

from repro.configs.base import PHNSWConfig


@dataclass
class HNSWGraph:
    cfg: PHNSWConfig
    x: np.ndarray                  # [N, D] high-dim data
    levels: np.ndarray             # [N] max layer of each point
    layers: List[np.ndarray]       # adjacency per layer [N, M_l], -1 pad
    entry: int

    @property
    def n(self) -> int:
        return len(self.x)

    def degree(self, layer: int) -> int:
        return self.layers[layer].shape[1]

    def stats(self) -> dict:
        return {
            "n": self.n,
            "levels_max": int(self.levels.max()),
            "layer_sizes": [int((self.levels >= l).sum())
                            for l in range(len(self.layers))],
            "mean_degree0": float((self.layers[0] >= 0).sum(1).mean()),
        }


def _search_layer_build(x, adj, q, eps, ef):
    """Beam search in one layer during construction. Returns list of
    (dist, idx), ascending, len <= ef."""
    visited = set(eps)
    cand = [(float(np.sum((x[e] - q) ** 2)), e) for e in eps]
    heapq.heapify(cand)                          # min-heap on dist
    best = [(-d, e) for d, e in cand]            # max-heap (neg dist)
    heapq.heapify(best)
    while cand:
        d_c, c = heapq.heappop(cand)
        d_f = -best[0][0]
        if d_c > d_f and len(best) >= ef:
            break
        neigh = adj[c]
        neigh = neigh[neigh >= 0]
        new = [int(e) for e in neigh if e not in visited]
        if not new:
            continue
        visited.update(new)
        ds = np.sum((x[new] - q) ** 2, axis=1)
        for d_e, e in zip(ds, new):
            d_f = -best[0][0]
            if d_e < d_f or len(best) < ef:
                heapq.heappush(cand, (float(d_e), e))
                heapq.heappush(best, (-float(d_e), e))
                if len(best) > ef:
                    heapq.heappop(best)
    out = sorted([(-d, e) for d, e in best])
    return out


def _select_heuristic(x, cand, m):
    """Malkov-Yashunin Algorithm 4: keep a candidate only if it is closer
    to the query point than to every already-selected neighbor (diversity
    pruning). cand: ascending [(dist_to_new, idx)]."""
    selected: list = []
    for d_e, e in cand:
        ok = True
        for s in selected:
            if float(np.sum((x[e] - x[s]) ** 2)) < d_e:
                ok = False
                break
        if ok:
            selected.append(e)
            if len(selected) >= m:
                break
    # backfill with nearest rejected if underfull
    if len(selected) < m:
        chosen = set(selected)
        for _, e in cand:
            if e not in chosen:
                selected.append(e)
                chosen.add(e)
                if len(selected) >= m:
                    break
    return selected


def sample_levels(n: int, cfg: PHNSWConfig,
                  rng: np.random.Generator) -> np.ndarray:
    """Geometric level assignment (mL = 1/ln(M)), capped at the config's
    layer count — shared by the one-shot builder and online inserts."""
    mL = 1.0 / math.log(cfg.M)
    return np.minimum(
        (-np.log(rng.uniform(1e-12, 1.0, size=n)) * mL).astype(np.int64),
        cfg.n_layers - 1)


def add_link(x: np.ndarray, adj_layer: np.ndarray, i: int, j: int) -> bool:
    """Add j to i's neighbor list in ``adj_layer`` ([N, M_l], -1 pad);
    when overfull, re-select the list with the diversity heuristic
    (hnswlib behavior — plain furthest-eviction strands nodes and breaks
    graph connectivity). Returns True iff i's row changed."""
    row = adj_layer[i]
    free = np.where(row < 0)[0]
    if len(free):
        row[free[0]] = j
        return True
    cand_ids = np.append(row, j)
    ds = np.sum((x[cand_ids] - x[i]) ** 2, axis=1)
    order = np.argsort(ds)
    cand = [(float(ds[o]), int(cand_ids[o])) for o in order]
    sel = _select_heuristic(x, cand, len(row))
    if len(sel) == len(row) and (row == sel).all():
        return False
    row[:] = -1
    row[:len(sel)] = sel
    return True


def build_hnsw_ref(x: np.ndarray, cfg: PHNSWConfig, *, seed: int = 0,
                   verbose: bool = False) -> HNSWGraph:
    """Sequential Malkov-Yashunin insertion — the recall/structure
    oracle for the wave builder (``core/build.py``), and the fallback
    selected by ``cfg.builder == "ref"``."""
    n, dim = x.shape
    rng = np.random.default_rng(seed)
    levels = sample_levels(n, cfg, rng)
    n_layers = int(levels.max()) + 1
    adj = [np.full((n, cfg.degree(l)), -1, np.int32)
           for l in range(n_layers)]

    entry = 0
    top = int(levels[0])
    t0 = time.perf_counter()
    for i in range(1, n):
        if verbose and i % 10000 == 0:
            vps = i / max(time.perf_counter() - t0, 1e-9)
            print(f"  insert {i}/{n} ({vps:.0f} vec/s)", flush=True)
        l_i = int(levels[i])
        q = x[i]
        eps = [entry]
        # greedy descent through layers above l_i
        for l in range(top, l_i, -1):
            if l >= n_layers:
                continue
            res = _search_layer_build(x, adj[l], q, eps, ef=1)
            eps = [res[0][1]]
        # insert at layers min(top, l_i)..0
        for l in range(min(top, l_i), -1, -1):
            res = _search_layer_build(x, adj[l], q, eps,
                                      ef=cfg.ef_construction)
            m_l = cfg.degree(l)
            neigh = _select_heuristic(x, res, m_l)
            adj[l][i, :len(neigh)] = neigh
            for e in neigh:
                add_link(x, adj[l], int(e), i)
            eps = [e for _, e in res]
        if l_i > top:
            entry = int(i)
            top = l_i
    # pad adjacency list count up to cfg.n_layers for uniform access
    while len(adj) < cfg.n_layers:
        adj.append(np.full((n, cfg.M), -1, np.int32))
    return HNSWGraph(cfg=cfg, x=x, levels=levels, layers=adj, entry=entry)


def build_hnsw(x: np.ndarray, cfg: PHNSWConfig, *, seed: int = 0,
               verbose: bool = False, builder: Optional[str] = None,
               wave_size: Optional[int] = None) -> HNSWGraph:
    """Build the C-phase graph with the builder selected by ``builder``
    (default ``cfg.builder``): "wave" — the batched device-accelerated
    wave pipeline (``core/build.py``), "ref" — the sequential host
    oracle. Both share ``sample_levels``, so a given seed yields the
    SAME level assignment (and therefore the same entry point) under
    either builder."""
    builder = builder or getattr(cfg, "builder", "wave")
    if builder == "ref":
        return build_hnsw_ref(x, cfg, seed=seed, verbose=verbose)
    if builder != "wave":
        raise ValueError(f"unknown builder {builder!r} "
                         "(expected 'wave' or 'ref')")
    from repro.core.build import build_hnsw_wave   # graph <-> build cycle
    return build_hnsw_wave(x, cfg, seed=seed, verbose=verbose,
                           wave_size=wave_size)


# --------------------------- disk cache -------------------------------------

# Bump whenever ANY builder's output changes for a fixed (cfg, seed) —
# stale cache entries from an older construction pipeline must never be
# served as if freshly built.
GRAPH_BUILD_VERSION = 2


def _cfg_fingerprint(cfg: PHNSWConfig) -> str:
    """Short stable hash over the FULL config (not just M/efc): any
    field can steer construction (wave_size, n_layers, degrees, ...),
    so two configs that differ anywhere must never share a cache
    entry."""
    items = sorted(dataclasses.asdict(cfg).items())
    return hashlib.sha1(repr(items).encode()).hexdigest()[:10]


def cached_graph(x: np.ndarray, cfg: PHNSWConfig, cache_dir: Path,
                 *, seed: int = 0, verbose: bool = False,
                 builder: Optional[str] = None) -> HNSWGraph:
    cache_dir = Path(cache_dir)
    builder = builder or getattr(cfg, "builder", "wave")
    key = f"hnsw_{cfg.name}_{len(x)}_{x.shape[1]}_M{cfg.M}" \
          f"_efc{cfg.ef_construction}_s{seed}" \
          f"_{builder}v{GRAPH_BUILD_VERSION}_{_cfg_fingerprint(cfg)}"
    f = cache_dir / f"{key}.npz"
    if f.exists():
        z = np.load(f)
        n_layers = int(z["n_layers"])
        return HNSWGraph(cfg=cfg, x=x, levels=z["levels"],
                         layers=[z[f"adj{l}"] for l in range(n_layers)],
                         entry=int(z["entry"]))
    g = build_hnsw(x, cfg, seed=seed, verbose=verbose, builder=builder)
    cache_dir.mkdir(parents=True, exist_ok=True)
    np.savez_compressed(
        f, levels=g.levels, entry=g.entry, n_layers=len(g.layers),
        **{f"adj{l}": a for l, a in enumerate(g.layers)})
    return g
