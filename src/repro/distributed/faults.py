"""Deterministic fault injection for the SERVING plane (DESIGN.md
§ Fault tolerance).

NOT to be confused with the similarly-named
``repro.distributed.fault`` (singular), the TRAINING plane's
fault-tolerance module (StepMonitor / GradSkipPolicy / remesh). This
module *injects* failures into the serving path on a seeded logical
clock so the resilient machinery can be tested; ``fault.py`` provides
*recovery* mechanisms for the train loop (its ``StepMonitor`` is
reused here by ``ShardHealth`` for per-shard straggler detection).

A ``FaultPlan`` is a seedable script of failure events — kill/stall/
corrupt a shard, kill a replica, delay a snapshot swap, truncate an npz
snapshot — consumed through small hooks at the three places real
failures would surface:

* ``core/distributed.probe_shard`` (the per-shard query wrapper): kill
  raises ``ShardKilledError`` before the probe runs, stall sleeps,
  corrupt garbles the returned candidate lists (caught downstream by
  ``check_shard_result``);
* ``index/sharded.py`` mutation path: kill makes upsert/delete routed
  to the dead shard raise; ``delay_swap`` stretches the snapshot
  publish window;
* ``index/mutable.py`` snapshot save: ``truncate_snapshot`` chops the
  written npz (caught at load time by the checksum envelope as
  ``SnapshotCorruptError``).

Time is LOGICAL: ``plan.tick()`` advances one step per service request
(or wherever the driver calls it), and events are active on
``at <= t < until`` — so every failure scenario is reproducible in
tier-1 without real hardware, wall clocks, or races. The module-level
``install``/``inject`` registry is what the hooks consult; no plan
installed means zero overhead on the hot path (one ``is None`` check).
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Tuple

import numpy as np


# --------------------------------------------------------------------------
# typed failure errors — the exception surface callers program against
# --------------------------------------------------------------------------

class FaultError(RuntimeError):
    """Base of every injected / detected serving-plane failure."""


class ShardFaultError(FaultError):
    """A single shard failed to answer (killed, or returned corrupt
    results). The resilient query path catches THIS type — anything
    else is a real bug and propagates."""


class ShardKilledError(ShardFaultError):
    """The shard is down: its probe raises before running."""


class ShardCorruptError(ShardFaultError):
    """The shard answered, but its candidate lists failed the merge
    boundary integrity check (``check_shard_result``)."""


class AllShardsDeadError(FaultError):
    """No shard answered within the request's deadline budget — the
    request cannot be served even in degraded mode."""


class ReplicaDeadError(FaultError):
    """A whole replica (one ``VectorSearchService``) is down."""


class AllReplicasDeadError(FaultError):
    """Every replica in the ``ReplicaSet`` is dead; nothing can serve."""


class SnapshotCorruptError(FaultError):
    """An npz snapshot failed its integrity envelope (unreadable zip,
    checksum mismatch, missing or mismatched format version). Raised by
    ``index.mutable.read_snapshot`` instead of garbage-deserializing —
    the safety rail under replica snapshot shipping."""


# --------------------------------------------------------------------------
# the fault plan
# --------------------------------------------------------------------------

# event kinds (``FaultEvent.kind``)
KILL_SHARD = "kill_shard"            # target = shard id
STALL_SHARD = "stall_shard"          # target = shard id, param = seconds
CORRUPT_SHARD = "corrupt_shard"      # target = shard id
KILL_REPLICA = "kill_replica"        # target = replica id
DELAY_SWAP = "delay_swap"            # param = seconds
TRUNCATE_SNAPSHOT = "truncate_snapshot"  # param = byte fraction kept

KINDS = (KILL_SHARD, STALL_SHARD, CORRUPT_SHARD, KILL_REPLICA,
         DELAY_SWAP, TRUNCATE_SNAPSHOT)


@dataclass
class FaultEvent:
    """One scripted failure: active while ``at <= plan.t < until``
    (``until=None`` = until healed). ``target`` is a shard or replica
    id (-1 = any); ``param`` is the kind-specific knob (stall seconds,
    swap delay seconds, truncation keep-fraction)."""
    kind: str
    target: int = -1
    param: float = 0.0
    at: int = 0
    until: Optional[int] = None


class FaultPlan:
    """A deterministic script of ``FaultEvent``s over logical time.

    ``log`` records every hook firing as ``(t, kind, target)`` — tests
    assert on it to prove an injection actually happened (and that a
    dead-marked shard stops being probed)."""

    def __init__(self, events: Tuple[FaultEvent, ...] = (), *,
                 seed: int = 0):
        self.events: List[FaultEvent] = list(events)
        self.rng = np.random.default_rng(seed)
        self.t = 0
        self.log: List[Tuple[int, str, int]] = []

    # -- scripting ---------------------------------------------------------

    def add(self, kind: str, target: int = -1, *, param: float = 0.0,
            at: Optional[int] = None, until: Optional[int] = None
            ) -> FaultEvent:
        """Schedule an event (default: active from now, until healed)."""
        assert kind in KINDS, f"unknown fault kind {kind!r}"
        ev = FaultEvent(kind, target, param,
                        self.t if at is None else at, until)
        self.events.append(ev)
        return ev

    def heal(self, kind: Optional[str] = None,
             target: Optional[int] = None) -> int:
        """Retire matching events (both None = everything). Returns the
        number healed. The underlying data was never touched — a healed
        shard serves correct results immediately; only the health
        tracker's dead mark (service-side) needs a ``recover``."""
        keep, healed = [], 0
        for ev in self.events:
            if (kind is None or ev.kind == kind) and \
                    (target is None or ev.target == target):
                healed += 1
            else:
                keep.append(ev)
        self.events = keep
        return healed

    def tick(self, n: int = 1) -> None:
        """Advance logical time (the service calls this once per
        request)."""
        self.t += n

    @classmethod
    def chaos(cls, n_shards: int, *, seed: int = 0, horizon: int = 64,
              n_events: int = 4, stall_s: float = 0.01) -> "FaultPlan":
        """A reproducible random plan: ``n_events`` kill/stall/corrupt
        events over ``horizon`` logical steps — same seed, same script."""
        plan = cls(seed=seed)
        kinds = (KILL_SHARD, STALL_SHARD, CORRUPT_SHARD)
        for _ in range(n_events):
            kind = kinds[int(plan.rng.integers(len(kinds)))]
            s = int(plan.rng.integers(n_shards))
            at = int(plan.rng.integers(horizon))
            until = at + int(plan.rng.integers(1, horizon // 2 + 1))
            plan.add(kind, s, param=stall_s, at=at, until=until)
        return plan

    # -- queries -----------------------------------------------------------

    def _active(self, kind: str, target: Optional[int] = None
                ) -> Iterator[FaultEvent]:
        for ev in self.events:
            if ev.kind != kind:
                continue
            if target is not None and ev.target not in (-1, target):
                continue
            if ev.at <= self.t and (ev.until is None or self.t < ev.until):
                yield ev

    def is_active(self, kind: str, target: Optional[int] = None) -> bool:
        return next(self._active(kind, target), None) is not None

    def replica_dead(self, r: int) -> bool:
        return self.is_active(KILL_REPLICA, r)

    # -- hooks (called from the instrumented code paths) -------------------

    def shard_query_hook(self, s: int) -> None:
        """Pre-probe: raise/stall if shard ``s`` is scripted down."""
        if self.is_active(KILL_SHARD, s):
            self.log.append((self.t, KILL_SHARD, s))
            raise ShardKilledError(f"shard {s} killed by fault plan "
                                   f"at t={self.t}")
        for ev in self._active(STALL_SHARD, s):
            self.log.append((self.t, STALL_SHARD, s))
            time.sleep(ev.param)

    def shard_mutation_hook(self, s: int) -> None:
        """Mutations routed to a killed shard fail (the index stays
        unchanged for that shard — callers see the typed error)."""
        if self.is_active(KILL_SHARD, s):
            self.log.append((self.t, KILL_SHARD, s))
            raise ShardKilledError(f"shard {s} down: mutation rejected "
                                   f"at t={self.t}")

    def corrupt_hook(self, s: int, fd: np.ndarray, gi: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Post-probe: deterministically garble shard ``s``'s candidate
        lists (NaN distances + out-of-owner-range ids) so the merge
        boundary check has something real to catch."""
        if not self.is_active(CORRUPT_SHARD, s):
            return fd, gi
        self.log.append((self.t, CORRUPT_SHARD, s))
        fd = np.array(fd, copy=True)
        gi = np.array(gi, copy=True)
        fd[:, 0] = np.nan                       # non-finite distance
        gi[:, :] = np.where(gi >= 0, -gi - 2_000_000_000, gi)  # alien ids
        return fd, gi

    def swap_delay_hook(self) -> float:
        """Pre-publish: sleep out any scripted swap delay; returns the
        seconds slept (0.0 when none active)."""
        total = sum(ev.param for ev in self._active(DELAY_SWAP))
        if total > 0.0:
            self.log.append((self.t, DELAY_SWAP, -1))
            time.sleep(total)
        return total

    def snapshot_hook(self, path) -> None:
        """Post-save: truncate the written snapshot to ``param`` of its
        bytes — load must detect this via the checksum envelope."""
        from pathlib import Path
        for ev in self._active(TRUNCATE_SNAPSHOT):
            p = Path(path)
            size = p.stat().st_size
            keep = max(1, int(size * ev.param))
            with open(p, "r+b") as f:
                f.truncate(keep)
            self.log.append((self.t, TRUNCATE_SNAPSHOT, -1))


# --------------------------------------------------------------------------
# module registry — what the hooks consult
# --------------------------------------------------------------------------

_ACTIVE: Optional[FaultPlan] = None


def install(plan: FaultPlan) -> FaultPlan:
    """Make ``plan`` the process-wide active plan (hooks fire from now
    on). Returns the plan for chaining."""
    global _ACTIVE
    _ACTIVE = plan
    return plan


def active() -> Optional[FaultPlan]:
    """The installed plan, or None (the common, zero-overhead case)."""
    return _ACTIVE


def clear() -> None:
    global _ACTIVE
    _ACTIVE = None


@contextmanager
def inject(plan: FaultPlan):
    """``with inject(FaultPlan(...)) as plan: ...`` — scoped install,
    always cleared on exit (tests never leak a plan into the next)."""
    install(plan)
    try:
        yield plan
    finally:
        clear()


# --------------------------------------------------------------------------
# detection side: per-shard health (StepMonitor per shard + liveness)
# --------------------------------------------------------------------------

@dataclass
class FaultPolicy:
    """Knobs of the service's resilient sharded query path.

    ``deadline_ms`` bounds ONE request's total retry budget; after it,
    the request completes from whichever shards answered (degraded).
    ``backoff_ms`` is the exponential-backoff base between retries to
    the same shard. ``dead_after_failures`` consecutive failures mark a
    shard dead — subsequent requests skip it outright (no retry tax)
    until ``ShardHealth.recover`` un-marks it. ``straggler_factor`` /
    ``mad_factor`` feed the per-shard ``StepMonitor`` (median + MAD
    over query wall times)."""
    deadline_ms: float = 250.0
    max_retries: int = 2
    backoff_ms: float = 5.0
    dead_after_failures: int = 2
    straggler_factor: float = 4.0
    mad_factor: Optional[float] = 6.0
    window: int = 64


class ShardHealth:
    """Per-shard liveness + straggler tracking for the serving path:
    one ``StepMonitor`` per shard fed with query wall times, a
    consecutive-failure counter driving the dead mark, and an event log
    (``(kind, shard, detail)``) for tests' structural assertions.

    Every verdict ALSO lands in the unified obs event stream
    (``repro.obs``) tagged ``source="serve.shard<N>"`` — the same
    ``ObsEvent`` record type the train loop's ``StepMonitor`` emits,
    so one ``events_of(...)`` query reads stragglers and dead marks
    across both planes."""

    def __init__(self, n_shards: int, policy: FaultPolicy):
        from repro.distributed.fault import StepMonitor
        from repro.obs.metrics import default_registry
        self.policy = policy
        self.monitors = [StepMonitor(straggler_factor=policy.straggler_factor,
                                     mad_factor=policy.mad_factor,
                                     window=policy.window,
                                     source=f"serve.shard{s}")
                         for s in range(n_shards)]
        self.failures = np.zeros(n_shards, np.int64)
        self.dead = np.zeros(n_shards, bool)
        self.events: List[Tuple[str, int, str]] = []
        self._obs = default_registry()
        self._step = 0

    def heartbeat(self, s: int, wall_s: float):
        """A successful shard answer: reset the failure streak, feed the
        monitor; records (and returns) a straggler event if flagged."""
        self._step += 1
        self.failures[s] = 0
        ev = self.monitors[s].heartbeat(self._step, wall_s)
        if ev.kind == "straggler":
            self.events.append(("straggler", s, ev.detail))
        return ev

    def failure(self, s: int, err: Exception) -> bool:
        """A failed shard attempt. Returns True if the streak just
        crossed ``dead_after_failures`` (shard now marked dead)."""
        self.failures[s] += 1
        self.events.append(("failure", s, repr(err)))
        self._obs.emit("failure", source=f"serve.shard{s}", target=s,
                       detail=repr(err))
        if not self.dead[s] and \
                self.failures[s] >= self.policy.dead_after_failures:
            self.mark_dead(s, f"{int(self.failures[s])} consecutive "
                              f"failures")
            return True
        return False

    def mark_dead(self, s: int, reason: str) -> None:
        self.dead[s] = True
        self.events.append(("dead", s, reason))
        self._obs.emit("dead", source=f"serve.shard{s}", target=s,
                       detail=reason)

    def recover(self, s: int) -> None:
        """Un-mark a shard (after the operator / fault plan healed it):
        next request probes it again."""
        self.dead[s] = False
        self.failures[s] = 0
        self.events.append(("recovered", s, ""))
        self._obs.emit("recovered", source=f"serve.shard{s}", target=s)

    def live_mask(self) -> np.ndarray:
        return ~self.dead

    @property
    def n_live(self) -> int:
        return int((~self.dead).sum())
