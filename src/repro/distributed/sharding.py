"""Sharding rules: parameter partition specs (FSDP over ``data`` x tensor
parallel over ``model``), activation constraints, KV-cache layouts.

The contract with the model zoo is the *leaf name*: ``wq``, ``e_up``,
``emb``... Each name maps to a base PartitionSpec; stacked-layer leading
axes (from ``stack_layers``) are detected by rank and get a leading None.

Mesh axes:
  pod    — DCN axis, pure data parallel across pods (multi-pod mesh only)
  data   — within-pod FSDP / batch axis
  model  — tensor / expert parallel axis

Key choices (see EXPERIMENTS.md §Perf for measured effects):
  * KV projections replicate over ``model`` when kv_heads doesn't divide
    the axis (GQA head replication) — avoids GSPMD resharding inside
    attention.
  * Experts shard over ``model`` (EP) when n_experts divides it, else the
    per-expert FFN dim is tensor-parallel.
  * Decode KV caches shard the *sequence* axis over ``model`` (and over
    ``data`` too when batch < data axis, e.g. long_500k's batch=1):
    GSPMD turns softmax + PV into the flash-decoding partial-softmax
    merge automatically.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# --------------------------------------------------------------------------
# activation-constraint context (models call constrain(x, name))
# --------------------------------------------------------------------------

_ACT_RULES: contextvars.ContextVar[Optional[Dict[str, Any]]] = \
    contextvars.ContextVar("act_rules", default=None)
_MESH_CTX: contextvars.ContextVar[Optional[Any]] = \
    contextvars.ContextVar("mesh_ctx", default=None)


@contextlib.contextmanager
def activation_rules(rules: Dict[str, Any], mesh=None):
    tok = _ACT_RULES.set(rules)
    tok2 = _MESH_CTX.set(mesh)
    try:
        yield
    finally:
        _ACT_RULES.reset(tok)
        _MESH_CTX.reset(tok2)


def current_mesh():
    """Mesh made visible to model code during tracing (for explicit
    shard_map regions, e.g. the MoE dispatch)."""
    return _MESH_CTX.get()


def constrain(x, name: str):
    rules = _ACT_RULES.get()
    if rules is None or name not in rules:
        return x
    return jax.lax.with_sharding_constraint(x, rules[name])


# --------------------------------------------------------------------------
# mesh helpers
# --------------------------------------------------------------------------

def batch_axes(mesh: Mesh, cfg=None) -> Tuple[str, ...]:
    ax = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if cfg is not None and getattr(cfg, "shard_profile", "tp") == "fsdp":
        ax = ax + ("model",)    # pure data parallelism across the full mesh
    return ax


def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


# --------------------------------------------------------------------------
# parameter rules
# --------------------------------------------------------------------------

def _param_rule_table(cfg, model_size: int) -> Dict[str, P]:
    hd = cfg.resolved_head_dim
    kv_tp = (cfg.kv_heads % model_size == 0) if cfg.n_heads else False
    kv_m = "model" if kv_tp else None
    moe_ep = cfg.moe is not None and cfg.moe.n_experts % model_size == 0
    heads_tp = cfg.n_heads % model_size == 0 if cfg.n_heads else False
    h_m = "model" if heads_tp else None
    return {
        # embeddings / head
        "emb": P("model", None),
        "lm_head": P(None, "model"),
        "vis_proj": P(None, "model"),
        # norms
        "scale": P(None), "bias": P(None),
        # attention
        "wq": P("data", "model"),
        "wk": P("data", kv_m), "wv": P("data", kv_m),
        "wo": P("model", "data"),
        "bq": P("model"), "bk": P(kv_m), "bv": P(kv_m),
        # dense mlp
        "w_gate": P("data", "model"), "w_up": P("data", "model"),
        "w_down": P("model", "data"),
        "b_up": P("model"), "b_down": P(None),
        # moe
        "router": P(None, None),
        "e_gate": P("model", "data", None) if moe_ep else P(None, "data", "model"),
        "e_up": P("model", "data", None) if moe_ep else P(None, "data", "model"),
        "e_down": P("model", None, "data") if moe_ep else P(None, "model", "data"),
        # rg-lru
        "rg_in_gate": P("data", "model"), "rg_in_x": P("data", "model"),
        "rg_conv": P(None, "model"),
        "rg_wa": P(h_m, None, None), "rg_wx": P(h_m, None, None),
        "rg_lam": P("model"),
        "rg_out": P("model", "data"),
        # rwkv6
        "w_r": P("data", "model"), "w_k": P("data", "model"),
        "w_v": P("data", "model"), "w_g": P("data", "model"),
        "w_o": P("model", "data"),
        "w0": P("model"), "lw_a": P("data", None), "lw_b": P(None, "model"),
        "u": P(h_m, None), "mu": P(None, None), "gn_scale": P(None),
        "c_wk": P("data", "model"), "c_wv": P("model", "data"),
        "c_wr": P("data", "model"), "c_mu": P(None, None),
        # retrieval attention (pHNSW): PCA-projection matrix, replicated
        "rp_proj": P(None, None),
        # whisper positional tables
        "pos_enc": P(None, None), "pos_dec": P(None, None),
    }


def param_specs(cfg, abstract_params, mesh: Mesh):
    """PartitionSpec pytree matching ``abstract_params`` (a ShapeDtypeStruct
    tree from eval_shape or a real param tree).

    Profiles (cfg.shard_profile):
      "tp"   — FSDP over ``data`` x tensor parallel over ``model``
               (the rule table below).
      "fsdp" — pure FSDP: the dim the tp-table marks as FSDP (or the
               largest dim if none) shards over ("data", "model")
               jointly; no tensor parallelism. Per-layer collective
               traffic becomes the param all-gather instead of the
               activation all-reduce — the right trade for small
               d_model (see EXPERIMENTS.md §Perf).
    """
    model_size = axis_size(mesh, "model")
    data_size = axis_size(mesh, "data")
    table = _param_rule_table(cfg, model_size)
    profile = getattr(cfg, "shard_profile", "tp")

    def _axis_prod(ax):
        if ax is None:
            return 1
        if isinstance(ax, tuple):
            n = 1
            for a in ax:
                n *= {"data": data_size, "model": model_size}.get(a, 1)
            return n
        return {"data": data_size, "model": model_size}.get(ax, 1)

    def rule(path, leaf):
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = entry.key
                break
        if name not in table:
            raise KeyError(f"no sharding rule for param leaf {path}")
        spec = table[name]
        ndim = leaf.ndim
        base = len(spec)
        lead = ndim - base            # stacked layer/group axes
        if lead < 0 or lead > 2:
            raise ValueError(f"rank mismatch for {name}: {ndim} vs {base}")
        if profile == "fsdp":
            # pick the dim to shard over (data, model): prefer the
            # tp-table's FSDP ("data") dim, else the largest dim
            body_shape = leaf.shape[lead:]
            cand = [i for i, ax in enumerate(spec) if ax == "data"]
            if not cand:
                cand = [int(max(range(len(body_shape)),
                                key=lambda i: body_shape[i]))]
            newspec = [None] * base
            i = cand[0]
            if body_shape[i] % (data_size * model_size) == 0:
                newspec[i] = ("data", "model")
            elif body_shape[i] % data_size == 0:
                newspec[i] = "data"
            spec = P(*newspec)
        spec = P(*((None,) * lead + tuple(spec)))
        # drop sharding for dims not divisible by the axis product (GSPMD
        # would pad; for weights we prefer exact layouts -> replicate)
        fixed = []
        for dim, ax in zip(leaf.shape, spec):   # len(spec) == ndim here
            if ax is None:
                fixed.append(None)
                continue
            fixed.append(ax if dim % _axis_prod(ax) == 0 else None)
        return P(*fixed)

    return jax.tree_util.tree_map_with_path(rule, abstract_params)


def param_shardings(cfg, abstract_params, mesh: Mesh):
    specs = param_specs(cfg, abstract_params, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------------
# activations / batch / cache
# --------------------------------------------------------------------------

def act_rules(cfg, mesh: Mesh, global_batch: int) -> Dict[str, NamedSharding]:
    b_ax = batch_axes(mesh, cfg)
    b_size = 1
    for a in b_ax:
        b_size *= axis_size(mesh, a)
    while len(b_ax) > 1 and (global_batch % b_size or global_batch < b_size):
        b_size //= axis_size(mesh, b_ax[-1])
        b_ax = b_ax[:-1]
    if global_batch % b_size == 0 and global_batch >= b_size:
        spec = P(b_ax, None, None)
    elif global_batch == 1:
        # batch=1 (long_500k): shard the sequence axis over data instead
        spec = P(None, b_ax, None)
    else:
        spec = P(b_ax[:1], None, None)
    return {"act_btd": NamedSharding(mesh, spec)}


def batch_sharding(cfg, mesh: Mesh, shape, kind: str) -> Dict[str, NamedSharding]:
    """Shardings for the input batch pytree, keyed like the batch dict."""
    b_ax = batch_axes(mesh, cfg if kind == "train" else None)
    ns = lambda *spec: NamedSharding(mesh, P(*spec))
    b_size = 1
    for a in b_ax:
        b_size *= axis_size(mesh, a)
    while len(b_ax) > 1 and shape.global_batch % b_size:
        b_size //= axis_size(mesh, b_ax[-1])
        b_ax = b_ax[:-1]
    bspec = b_ax if shape.global_batch % b_size == 0 else None
    out: Dict[str, NamedSharding] = {}
    if kind == "train":
        out = {"tokens": ns(bspec, None), "labels": ns(bspec, None)}
    elif kind == "prefill":
        out = {"tokens": ns(bspec, None)}
    else:  # decode
        out = {"token": ns(bspec, None), "pos": NamedSharding(mesh, P())}
    if cfg.vis_tokens:
        out["patches"] = ns(bspec, None, None)
    if cfg.enc_layers:
        out["frames"] = ns(bspec, None, None)
    return out


def cache_spec(cfg, mesh: Mesh, batch: int, seq_len: int):
    """PartitionSpec for KV caches [L, B, T, KV, Hd] (dense/moe/vlm/encdec),
    flash-decoding style: sequence axis over ``model`` (and ``data`` too
    when the batch can't use it)."""
    b_ax = batch_axes(mesh)
    b_size = 1
    for a in b_ax:
        b_size *= axis_size(mesh, a)
    if batch % b_size == 0:
        return P(None, b_ax, "model", None, None)
    # batch=1: sequence over (data, model) jointly
    seq_ax = tuple(a for a in (*b_ax, "model"))
    return P(None, None, seq_ax, None, None)


def state_spec(cfg, mesh: Mesh, batch: int):
    """Recurrent-state sharding (rwkv/hybrid): width over ``model``."""
    b_ax = batch_axes(mesh)
    b_size = 1
    for a in b_ax:
        b_size *= axis_size(mesh, a)
    bspec = b_ax if batch % b_size == 0 else None
    return bspec
