"""Pipeline parallelism (GPipe-style) over the ``model`` axis.

Completes the parallelism suite (DP / TP / EP / SP / **PP**): for very
deep models, an alternative to tensor parallelism is to place
contiguous layer blocks on pipeline stages and stream microbatches
through them. On a TPU mesh the stages map onto the ``model`` axis and
the stage boundary hop is a ``collective_permute`` (neighbor ICI link) —
cheap, point-to-point, and overlappable, in contrast to TP's per-layer
all-reduces.

Schedule: the classic GPipe loop with S stages and M microbatches runs
S + M - 1 ticks; each tick every stage processes one resident microbatch
and passes activations rightward. We implement it as a ``shard_map``
over ``model`` with a ``lax.scan`` over ticks (the "circular pipeline"
formulation: one [B_mb, S, D] buffer per stage, rotated with
collective_permute each tick; invalid ticks are masked). Bubble overhead
is the usual (S - 1) / (S + M - 1).

Wire cost per step per chip: 2 x (M + S) x B_mb x S_seq x D bytes
(fwd + bwd boundary activations) — for llama3-405b train_4k at S=16,
M=32: ~0.6 GB/chip vs the 6+ GB/chip of TP+FSDP collectives; the trade
is the bubble (31%) and per-stage weight residency (params/S per chip,
which for 405B at S=16 is 25 GB in bf16 — why PP at this scale pairs
with intra-stage FSDP in practice; both knobs exist here).

This module provides the generic machinery plus a reference pipelined
forward for the dense decoder family; it is exercised by tests and
offered as ``build_pipeline_forward`` for experimentation rather than
wired into every arch config (DESIGN.md section 8).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def stage_layers(n_layers: int, n_stages: int) -> Tuple[int, ...]:
    """Contiguous layer counts per stage (front-loaded remainder)."""
    base = n_layers // n_stages
    rem = n_layers % n_stages
    return tuple(base + (1 if s < rem else 0) for s in range(n_stages))


def build_pipeline_forward(mesh: Mesh, layer_fn: Callable,
                           n_layers: int, *, axis: str = "model"):
    """Returns pipelined_forward(stacked_params, x_microbatches).

    layer_fn(layer_params, x) -> x          (one layer, pure)
    stacked_params: pytree with leading layer axis [L, ...]
    x_microbatches: [M, B_mb, S, D] microbatched inputs.

    Stages = mesh.shape[axis]; layers are split contiguously; each stage
    runs its layer block per tick; boundary activations hop via
    collective_permute. Output: [M, B_mb, S, D] after all layers.
    """
    n_stages = mesh.shape[axis]
    assert n_layers % n_stages == 0, (n_layers, n_stages)
    per_stage = n_layers // n_stages

    def stage_block(params_local, x):
        def body(h, lp):
            return layer_fn(lp, h), None
        out, _ = jax.lax.scan(body, x, params_local)
        return out

    def local_fn(params_local, xs):
        # params_local: [per_stage, ...] this stage's layers
        # xs: [M, B_mb, S, D] (replicated copy of the microbatch queue)
        stage = jax.lax.axis_index(axis)
        M = xs.shape[0]
        n_ticks = M + n_stages - 1
        buf = jnp.zeros_like(xs[0])

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any)
            feed_idx = jnp.clip(t, 0, M - 1)
            feed = xs[feed_idx]
            buf = jnp.where(stage == 0,
                            jnp.where(t < M, feed, buf), buf)
            # every stage processes its resident microbatch
            buf = stage_block(params_local, buf)
            # last stage emits microbatch t - (S - 1)
            out_idx = t - (n_stages - 1)
            emit = (stage == n_stages - 1) & (out_idx >= 0)
            outs = jax.lax.cond(
                emit,
                lambda o: o.at[jnp.clip(out_idx, 0, M - 1)].set(buf),
                lambda o: o, outs)
            # rotate boundary activations rightward
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(buf, axis, perm)
            return (buf, outs), None

        outs0 = jnp.zeros_like(xs)
        (_, outs), _ = jax.lax.scan(tick, (buf, outs0),
                                    jnp.arange(n_ticks))
        # only the last stage holds real outputs; masked psum broadcasts
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, 0.0), axis)
        return outs

    # stacked params split by stage along the layer axis
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(axis), P(*((None,) * 4))),
        out_specs=P(*((None,) * 4)),
        check_rep=False)

    def pipelined_forward(stacked_params, x_microbatches):
        return fn(stacked_params, x_microbatches)

    return pipelined_forward


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_stages + n_microbatches - 1)
