"""Fault tolerance & elasticity for the TRAINING plane.

NOT to be confused with the similarly-named ``repro.distributed.faults``
(plural), which is the SERVING plane's deterministic fault-INJECTION
harness (FaultPlan scripts, ShardHealth, typed ShardFaultError surface).
The split, so the right module is imported on purpose:

* ``fault.py`` (this module) — mechanisms that keep a TRAINING job
  healthy: step-time straggler detection (``StepMonitor``, which the
  serving plane also reuses for per-shard wall-time monitoring),
  deadline-skipped microbatches (``GradSkipPolicy``), and elastic
  re-meshing after permanent device loss (``remesh``).
* ``faults.py`` — tools that BREAK the serving plane on purpose:
  seeded, logically-timed failure scripts consumed by hooks in the
  sharded query/mutation/snapshot paths, plus the shard-health state
  machine the resilient query loop drives.

Three mechanisms, each testable without real hardware failures:

1. **Heartbeat / straggler detection** (``StepMonitor``): per-step wall
   times feed a robust (median + MAD) estimator; steps slower than
   ``straggler_factor`` x median raise a straggler event, and a missing
   heartbeat past ``dead_after_s`` marks the worker dead. At scale this
   runs per-host against the coordinator; here the same logic is driven
   by the training loop and unit-tested with synthetic timings.

2. **Deadline-skipped microbatches** (``GradSkipPolicy``): when a
   straggler event fires mid-accumulation, the remaining microbatches
   are dropped and the gradient is renormalized by the completed count
   (unbiased up to batch-size noise) — latency bounded by the deadline
   instead of the slowest worker.

3. **Elastic re-meshing** (``remesh``): on permanent failure the job
   restarts from the last checkpoint onto a SMALLER healthy mesh (or a
   larger one after repair). Checkpoints are mesh-agnostic
   (host-side .npy per leaf); ``remesh`` re-derives shardings for the
   new mesh from the same rule table and device_puts every leaf. The
   batch schedule is preserved by keeping global_batch constant and
   raising gradient-accumulation depth.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import numpy as np
import jax


# --------------------------------------------------------------------------
# 1. heartbeat / straggler detection
# --------------------------------------------------------------------------

@dataclass
class StepEvent:
    kind: str          # "ok" | "straggler" | "dead"
    step: int
    wall_s: float
    detail: str = ""


class StepMonitor:
    def __init__(self, *, straggler_factor: float = 2.5,
                 dead_after_s: float = 300.0, window: int = 64,
                 mad_factor: Optional[float] = None,
                 source: str = ""):
        """``mad_factor`` (optional) adds a robust absolute-deviation
        term to the threshold: a step is a straggler when its wall time
        exceeds ``max(factor * median, median + mad_factor * MAD)``.
        The additive MAD term keeps near-zero-latency workloads (e.g.
        sub-ms shard queries, where any scheduler hiccup is a large
        RATIO but a tiny absolute delay) from flagging noise, while the
        multiplicative term still catches slow-but-steady drift. None
        preserves the original ratio-only rule.

        ``source`` (optional) names this monitor in the unified obs
        event stream (``repro.obs``): with a source set, heartbeats
        bump a per-source counter and straggler/liveness verdicts land
        as ``ObsEvent``s in the process registry — the SAME record
        type the serving plane's ``ShardHealth`` emits, so train-loop
        and serving-plane monitoring are one queryable stream. An
        unnamed monitor (the default) stays off the obs plane."""
        self.factor = straggler_factor
        self.mad_factor = mad_factor
        self.dead_after_s = dead_after_s
        self.times: Deque[float] = deque(maxlen=window)
        self.last_beat = time.monotonic()
        self.events: List[StepEvent] = []
        self.source = source

    def _obs(self):
        from repro.obs.metrics import default_registry
        return default_registry()

    def heartbeat(self, step: int, wall_s: float) -> StepEvent:
        self.last_beat = time.monotonic()
        if self.times:
            hist = np.asarray(self.times)
            med = float(np.median(hist))
            mad = float(np.median(np.abs(hist - med)))
        else:
            med, mad = wall_s, 0.0
        self.times.append(wall_s)
        thresh = self.factor * med
        if self.mad_factor is not None:
            thresh = max(thresh, med + self.mad_factor * mad)
        if len(self.times) >= 8 and wall_s > thresh:
            ev = StepEvent("straggler", step, wall_s,
                           f"{wall_s:.2f}s vs median {med:.2f}s "
                           f"(mad {mad:.3f}s)")
        else:
            ev = StepEvent("ok", step, wall_s)
        self.events.append(ev)
        if self.source:
            reg = self._obs()
            reg.counter("phnsw_heartbeats_total",
                        "monitor heartbeats by source",
                        labels=("source",)).labels(
                            source=self.source).inc()
            if ev.kind == "straggler":
                reg.emit("straggler", source=self.source, target=step,
                         detail=ev.detail)
        return ev

    def check_liveness(self) -> Optional[StepEvent]:
        gap = time.monotonic() - self.last_beat
        if gap > self.dead_after_s:
            ev = StepEvent("dead", -1, gap, f"no heartbeat for {gap:.0f}s")
            self.events.append(ev)
            if self.source:
                self._obs().emit("dead", source=self.source,
                                 detail=ev.detail)
            return ev
        return None


# --------------------------------------------------------------------------
# 2. straggler mitigation: deadline-skipped microbatches
# --------------------------------------------------------------------------

@dataclass
class GradSkipPolicy:
    """Tracks how many microbatches completed before the deadline; the
    train loop divides the accumulated gradient by ``completed`` instead
    of the planned count. Skipping is bounded so the batch never shrinks
    below ``min_fraction`` of plan."""
    planned: int
    min_fraction: float = 0.5
    completed: int = 0
    skipped_total: int = 0

    def complete(self, n: int = 1):
        self.completed += n

    def should_skip_rest(self, elapsed_s: float, deadline_s: float) -> bool:
        if elapsed_s < deadline_s:
            return False
        return self.completed >= max(1, int(self.planned * self.min_fraction))

    def renorm(self) -> float:
        """Gradient renormalization factor (planned/completed)."""
        self.skipped_total += self.planned - self.completed
        return self.planned / max(self.completed, 1)


# --------------------------------------------------------------------------
# 3. elastic re-meshing
# --------------------------------------------------------------------------

def remesh(tree, shardings_new):
    """Re-shard a (restored or live) pytree onto a new mesh's shardings.
    Works across mesh shapes because leaves are globally-shaped."""
    host = jax.tree.map(np.asarray, tree)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), host,
                        shardings_new)


def healthy_mesh_shape(n_healthy: int, model_parallel: int = 16):
    """Largest (data, model) mesh that fits the healthy-device count,
    keeping the model axis fixed (weights layout unchanged) and shrinking
    the data axis — grad-accum rises to keep global batch constant."""
    data = n_healthy // model_parallel
    if data < 1:
        raise RuntimeError("not enough healthy devices for model parallelism")
    return (data, model_parallel)
