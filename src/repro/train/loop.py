"""Training loop: sharded init, prefetched data, async checkpointing,
restart, straggler monitoring.

Restart contract: the data stream is (seed, step)-deterministic and the
optimizer state carries the step counter, so resume = restore latest
checkpoint + fast-forward the pipeline. Kill the process at any point
and relaunch with the same CLI: training continues bit-exactly (modulo
async-ckpt lag, bounded by ckpt_every).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, Optional

import numpy as np
import jax

from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.tokens import TokenPipeline
from repro.distributed.fault import StepMonitor
from repro.launch.steps import build_train_step
from repro.models import get_model
from repro.optim import AdamWConfig, adamw_init


@dataclass
class TrainLoopConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    keep: int = 3
    seed: int = 0
    log_every: int = 10
    microbatches: int = 0          # 0 = auto
    resume: bool = True


class TrainLoop:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, mesh,
                 loop_cfg: TrainLoopConfig = TrainLoopConfig(),
                 opt_cfg: AdamWConfig = AdamWConfig()):
        self.cfg, self.shape, self.mesh = cfg, shape, mesh
        self.loop_cfg, self.opt_cfg = loop_cfg, opt_cfg
        self.step_fn, self.specs = build_train_step(
            cfg, mesh, shape, opt_cfg, microbatches=loop_cfg.microbatches)
        self.monitor = StepMonitor()
        self.ckpt = CheckpointManager(Path(loop_cfg.ckpt_dir),
                                      keep=loop_cfg.keep)
        self.metrics_log: list = []

    # ---- state ----
    def init_state(self):
        api = self.specs["api"]
        p_sh, o_sh = self.specs["p_sh"], self.specs["o_sh"]
        with self.mesh:
            params = jax.jit(api.init, out_shardings=p_sh)(
                jax.random.key(self.loop_cfg.seed))
            opt = jax.jit(adamw_init, out_shardings=o_sh)(params)
        return params, opt

    def try_restore(self):
        step = latest_step(Path(self.loop_cfg.ckpt_dir))
        if step is None:
            return None
        a_params, a_opt = self.specs["a_params"], self.specs["a_opt"]
        state = restore_checkpoint(
            Path(self.loop_cfg.ckpt_dir), step,
            {"params": a_params, "opt": a_opt},
            {"params": self.specs["p_sh"], "opt": self.specs["o_sh"]})
        return step, state["params"], state["opt"]

    # ---- main ----
    def run(self) -> Dict[str, Any]:
        lc = self.loop_cfg
        start = 0
        restored = self.try_restore() if lc.resume else None
        if restored is not None:
            start, params, opt = restored
            print(f"[train] resumed from step {start}", flush=True)
        else:
            params, opt = self.init_state()
        pipe = TokenPipeline(self.cfg, self.shape, seed=lc.seed,
                             start_step=start,
                             shardings=self.specs["b_sh"])
        last_metrics = {}
        try:
            for step, batch in pipe:
                if step >= lc.steps:
                    break
                t0 = time.monotonic()
                params, opt, metrics = self.step_fn(params, opt, batch)
                jax.block_until_ready(metrics["loss"])
                wall = time.monotonic() - t0
                ev = self.monitor.heartbeat(step, wall)
                if ev.kind == "straggler":
                    print(f"[train] straggler step {step}: {ev.detail}",
                          flush=True)
                last_metrics = {k: float(v) for k, v in metrics.items()}
                self.metrics_log.append({"step": step, "wall_s": wall,
                                         **last_metrics})
                if step % lc.log_every == 0:
                    print(f"[train] step {step} loss={last_metrics['loss']:.4f} "
                          f"gnorm={last_metrics.get('grad_norm', 0):.3f} "
                          f"{wall:.2f}s", flush=True)
                if (step + 1) % lc.ckpt_every == 0 or step + 1 == lc.steps:
                    self.ckpt.save_async(step + 1,
                                         {"params": params, "opt": opt},
                                         extra={"arch": self.cfg.name})
        finally:
            pipe.close()
            self.ckpt.wait()
        return {"final_step": min(lc.steps, pipe.step),
                "last_metrics": last_metrics,
                "straggler_events": sum(
                    1 for e in self.monitor.events if e.kind == "straggler")}
