from repro.data.vectors import make_sift_like, brute_force_topk
from repro.data.tokens import TokenPipeline, synthetic_batch

__all__ = ["make_sift_like", "brute_force_topk", "TokenPipeline",
           "synthetic_batch"]
