"""Synthetic SIFT1M-like vector datasets.

Real SIFT descriptors are 128-dim, non-negative, and strongly correlated
(PCA to 15 dims preserves enough structure for recall 0.92 at the paper's
operating point — Section III-B). An isotropic Gaussian would NOT have
that property, so we generate a clustered low-intrinsic-dimension mixture
with added full-rank noise, scaled to SIFT's value range.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def make_sift_like(n: int, dim: int = 128, *, n_clusters: int = 64,
                   intrinsic: int = 16, noise: float = 0.04,
                   seed: int = 0) -> np.ndarray:
    """[n, dim] float32, SIFT-like: clustered, low intrinsic dimension,
    non-negative, magnitudes in SIFT's typical 0..220 range."""
    rng = np.random.default_rng(seed)
    basis = rng.standard_normal((intrinsic, dim)) / np.sqrt(intrinsic)
    centers = rng.standard_normal((n_clusters, intrinsic)) * 2.2
    assign = rng.integers(0, n_clusters, size=n)
    z = centers[assign] + rng.standard_normal((n, intrinsic))
    x = z @ basis + noise * rng.standard_normal((n, dim))
    # non-negativity via offset + clip (NOT folding: |x| would destroy the
    # low-rank structure PCA-15 relies on; real SIFT keeps ~80% variance
    # in 15 PCs)
    x = np.clip(x * 20.0 + 80.0, 0.0, None)
    return x.astype(np.float32)


def make_queries(x: np.ndarray, n_queries: int, *, seed: int = 1,
                 jitter: float = 0.05) -> np.ndarray:
    """Queries near the data manifold: perturbed database points."""
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(x), size=n_queries)
    q = x[idx] + jitter * x.std() * rng.standard_normal((n_queries,
                                                         x.shape[1]))
    return np.abs(q).astype(np.float32)


def brute_force_topk(x: np.ndarray, q: np.ndarray, k: int,
                     block: int = 4096) -> np.ndarray:
    """Exact top-k (squared L2) ground truth: [n_queries, k] indices."""
    n2 = (x * x).sum(axis=1)
    out = np.empty((len(q), k), np.int64)
    for i in range(0, len(q), block):
        qb = q[i:i + block]
        d = n2[None, :] - 2.0 * (qb @ x.T)    # + ||q||^2 (rank-invariant)
        part = np.argpartition(d, k, axis=1)[:, :k]
        rows = np.arange(len(qb))[:, None]
        order = np.argsort(d[rows, part], axis=1)
        out[i:i + block] = part[rows, order]
    return out
