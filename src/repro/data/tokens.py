"""Synthetic LM token pipeline: seeded, shardable, restart-deterministic.

Generates Zipf-distributed token streams (vocabulary statistics matter
for embedding-gather load balance) with next-token labels. Each step's
batch is derived from (seed, step) only, so a restarted job regenerates
the exact stream — the checkpoint/restart contract needs no data-state
snapshot beyond the step counter. Double-buffered host prefetch overlaps
generation with device compute.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np
import jax


def synthetic_batch(seed: int, step: int, batch: int, seq: int, vocab: int,
                    *, extras: Optional[Dict] = None) -> Dict[str, np.ndarray]:
    """One global batch for ``step``. tokens/labels: [batch, seq] int32."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    # Zipf-ish marginal over the vocab, cheap to sample:
    u = rng.random((batch, seq + 1))
    toks = np.minimum((vocab * u ** 2.2).astype(np.int32), vocab - 1)
    out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if extras:
        for name, (shape, dtype) in extras.items():
            out[name] = rng.standard_normal((batch,) + shape).astype(dtype)
    return out


def batch_extras_for(cfg) -> Dict:
    """Frontend-stub inputs per family (see input_specs)."""
    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = ((cfg.enc_frames, cfg.d_model), np.float32)
    if cfg.vis_tokens:
        extras["patches"] = ((cfg.vis_tokens, cfg.d_model), np.float32)
    return extras


class TokenPipeline:
    """Prefetching iterator of device-ready global batches."""

    def __init__(self, cfg, shape, *, seed: int = 0, start_step: int = 0,
                 shardings=None, prefetch: int = 2):
        self.cfg, self.shape = cfg, shape
        self.seed = seed
        self.step = start_step
        self.shardings = shardings
        self.extras = batch_extras_for(cfg)
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _make(self, step: int):
        b = synthetic_batch(self.seed, step, self.shape.global_batch,
                            self.shape.seq_len, self.cfg.vocab,
                            extras=self.extras)
        if self.extras and self.cfg.dtype != "float32":
            for name in self.extras:
                b[name] = b[name].astype(self.cfg.dtype)
        if self.shardings is not None:
            b = {k: jax.device_put(v, self.shardings[k])
                 for k, v in b.items()}
        return b

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._make(step)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        self.step = step
        return step, batch

    def close(self):
        self._stop.set()
