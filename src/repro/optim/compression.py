"""Gradient compression for the cross-pod (DCN) all-reduce.

int8 block-quantized compression: each gradient leaf is quantized to int8
with a per-block fp32 scale before the ``pod``-axis all-reduce, and
dequantized after. At 1000+ node scale the DCN all-reduce is the slowest
collective; 4x fewer bytes at <1% relative error on gradient noise is the
standard trade (the within-pod ICI reductions stay full precision).

Used by ``train/loop.py`` when ``compress_dcn=True``: gradients are
psum'd over ("data",) in full precision, then the quantized tree is
psum'd over ("pod",) inside shard_map.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

_BLOCK = 256


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compress_grads(grads) -> Any:
    """tree of arrays -> tree of (q_int8, scale, shape, dtype)."""
    def one(x):
        q, s = _quantize(x)
        return {"q": q, "scale": s}
    return jax.tree.map(one, grads)


def decompress_grads(comp, like) -> Any:
    return jax.tree.map(
        lambda c, x: _dequantize(c["q"], c["scale"], x.shape, x.dtype),
        comp, like, is_leaf=lambda t: isinstance(t, dict) and "q" in t)
