"""AdamW with global-norm clipping and cosine schedule, pure JAX.
Optimizer state shards exactly like the parameters (tree-structural)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def cosine_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
