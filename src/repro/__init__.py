"""repro: pHNSW (PCA-filtered HNSW ANN search) algorithm--hardware
co-design, reproduced and extended as a multi-pod JAX framework."""

__version__ = "0.1.0"
