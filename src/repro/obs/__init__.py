"""Unified observability plane (DESIGN.md § Observability): metrics
registry + per-request trace spans + exporters + the device-telemetry
cost bridge. Dependency-free (numpy only) — importable from kernels,
serving, and benchmarks alike."""
from repro.obs.metrics import (Counter, Family, Gauge, Histogram,
                               ObsEvent, Registry, counter,
                               default_registry, emit_event, gauge,
                               histogram)
from repro.obs.trace import NULL_SPAN, NULL_TRACER, Span, Tracer
from repro.obs.export import (parse_prometheus, prometheus_families,
                              snapshot, snapshot_json, to_prometheus)
from repro.obs.bridge import predicted_query_ns, record_search_stats

__all__ = [
    "Counter", "Family", "Gauge", "Histogram", "ObsEvent", "Registry",
    "counter", "default_registry", "emit_event", "gauge", "histogram",
    "NULL_SPAN", "NULL_TRACER", "Span", "Tracer",
    "parse_prometheus", "prometheus_families", "snapshot",
    "snapshot_json", "to_prometheus",
    "predicted_query_ns", "record_search_stats",
]
