"""Exporters for the observability plane: Prometheus text exposition +
a sort-stable JSON snapshot (DESIGN.md § Observability).

``to_prometheus`` renders a ``Registry`` in the text exposition format
(``# HELP`` / ``# TYPE`` headers, ``name{label="v"} value`` samples;
histograms as cumulative ``_bucket{le=...}`` series plus ``_sum`` /
``_count`` — the log-bucket upper edges become the ``le`` bounds, so
any Prometheus-compatible scraper computes the same exact-to-bucket
quantiles the in-process ``percentile()`` does). ``parse_prometheus``
is the matching minimal parser — the round-trip is what the obs-smoke
CI gate asserts.

``snapshot`` emits the same data as one JSON-serializable dict with
every collection sorted (family name, label values, bucket index), so
two snapshots of identical registries are byte-identical after
``json.dumps`` — diffable in tests and stable under re-serialization.
"""
from __future__ import annotations

import json
import math
from typing import Dict, List, Optional, Tuple

from repro.obs.metrics import (Counter, Family, Gauge, Histogram,
                               Registry, default_registry)


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    return repr(float(v))


def _label_str(labels: Tuple[Tuple[str, str], ...],
               extra: Tuple[Tuple[str, str], ...] = ()) -> str:
    items = tuple(labels) + tuple(extra)
    if not items:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in items)
    return "{" + body + "}"


def to_prometheus(registry: Optional[Registry] = None) -> str:
    """Render every family of ``registry`` (default: the process
    registry) in the Prometheus text exposition format."""
    registry = registry or default_registry()
    out: List[str] = []
    for fam in registry.families():
        out.append(f"# HELP {fam.name} {fam.help}")
        out.append(f"# TYPE {fam.name} {fam.kind}")
        for m in fam.children():
            if isinstance(m, (Counter, Gauge)):
                out.append(f"{fam.name}{_label_str(m.labels)} "
                           f"{_fmt(m.value)}")
            elif isinstance(m, Histogram):
                cum = 0
                for i, c in enumerate(m.counts):
                    cum += int(c)
                    out.append(
                        f"{fam.name}_bucket"
                        f"{_label_str(m.labels, (('le', _fmt(m.upper_edge(i))),))}"
                        f" {cum}")
                    # emit up to the first bucket that reaches the
                    # total (plus +Inf below) — full fidelity without
                    # the empty tail
                    if cum == m.count:
                        break
                out.append(f"{fam.name}_bucket"
                           f"{_label_str(m.labels, (('le', '+Inf'),))}"
                           f" {m.count}")
                out.append(f"{fam.name}_sum{_label_str(m.labels)} "
                           f"{_fmt(m.sum)}")
                out.append(f"{fam.name}_count{_label_str(m.labels)} "
                           f"{m.count}")
    return "\n".join(out) + "\n"


def parse_prometheus(text: str) -> Dict[str, List[Tuple[Dict[str, str],
                                                        float]]]:
    """Minimal exposition-format parser: ``{metric_name: [(labels,
    value), ...]}``. Raises ``ValueError`` on a malformed line — the
    CI gate's "the text output parses" assertion."""
    out: Dict[str, List[Tuple[Dict[str, str], float]]] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        try:
            series, value = line.rsplit(" ", 1)
            labels: Dict[str, str] = {}
            if "{" in series:
                name, rest = series.split("{", 1)
                if not rest.endswith("}"):
                    raise ValueError(line)
                body = rest[:-1]
                if body:
                    for item in body.split(","):
                        k, v = item.split("=", 1)
                        if not (v.startswith('"') and v.endswith('"')):
                            raise ValueError(line)
                        labels[k] = v[1:-1]
            else:
                name = series
            out.setdefault(name, []).append((labels, float(value)))
        except ValueError:
            raise
        except Exception as e:
            raise ValueError(f"malformed exposition line: {line!r}") from e
    return out


def prometheus_families(text: str) -> List[str]:
    """The family names declared by ``# TYPE`` headers, in order."""
    return [line.split()[2] for line in text.splitlines()
            if line.startswith("# TYPE ")]


def snapshot(registry: Optional[Registry] = None) -> dict:
    """JSON-serializable snapshot of every metric + the event stream,
    fully sorted — stable under re-serialization."""
    registry = registry or default_registry()
    fams = []
    for fam in registry.families():
        children = []
        for m in fam.children():
            entry: dict = {"labels": dict(m.labels)}
            if isinstance(m, (Counter, Gauge)):
                entry["value"] = m.value
            else:
                nz = {int(i): int(c) for i, c in enumerate(m.counts)
                      if c}
                entry.update({
                    "count": m.count, "sum": m.sum,
                    "min": None if m.count == 0 else m.min,
                    "max": None if m.count == 0 else m.max,
                    "buckets": {str(k): nz[k] for k in sorted(nz)},
                    "p50": m.percentile(50), "p99": m.percentile(99),
                    "p999": m.percentile(99.9),
                })
            children.append(entry)
        fams.append({"name": fam.name, "kind": fam.kind,
                     "help": fam.help,
                     "label_names": list(fam.label_names),
                     "children": children})
    return {
        "families": fams,
        "events": [{"kind": e.kind, "source": e.source,
                    "target": e.target, "detail": e.detail,
                    "t_wall": e.t_wall} for e in registry.events],
    }


def snapshot_json(registry: Optional[Registry] = None, **dumps_kw) -> str:
    return json.dumps(snapshot(registry), sort_keys=True, **dumps_kw)
