"""Per-request trace spans for the serving path (DESIGN.md
§ Observability).

A ``Span`` is one timed unit of serving work (a request, one shard
probe, the cross-shard merge, an epoch swap); spans nest into a tree
and carry ordered **events** (retry/backoff decisions, fault-injection
hits, straggler marks, dead-shard marks) so a degraded query is
explainable after the fact from its trace alone.

Context is passed EXPLICITLY: a function that should appear in the
trace takes a ``span`` argument and opens children with
``span.child(...)`` — no thread-locals, no contextvars, so the trace
tree is exactly the call tree the serving code actually took (and the
machinery works unchanged if requests ever fan out across threads).

**Off by default, one is-enabled check.** The cost gate is the same
pattern ``distributed.faults`` uses for its hook registry: the single
check lives in ``Tracer.span`` — a disabled tracer returns the
module-singleton ``NULL_SPAN``, whose every method is a no-op and
whose ``child()`` returns itself, so instrumented code is written
unconditionally (``span.event(...)``, ``span.child(...)``) and the
disabled hot path allocates NO span objects at all (asserted by
``tests/test_obs.py`` via the allocation counter) and costs one no-op
method call per instrumentation point. The traced path is CI-gated to
<= 10% QPS overhead on the perf-smoke workload (obs-smoke job).

Finished ROOT spans land in ``tracer.finished`` (bounded deque);
``Span.to_dict()`` / ``find()`` / ``iter_spans()`` are the assertion
surface for tests and the JSON export shape.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Deque, Dict, Iterator, List, Optional, Tuple


class Span:
    """One timed, attributed, evented node of a trace tree."""

    __slots__ = ("name", "attrs", "events", "children", "t0", "t1",
                 "_tracer")

    # allocation counter — the zero-overhead-when-disabled test reads
    # this across a disabled-path run to prove no Span was created
    n_created = 0

    def __init__(self, name: str, tracer: Optional["Tracer"] = None,
                 **attrs):
        Span.n_created += 1
        self.name = name
        self.attrs: Dict[str, object] = dict(attrs)
        self.events: List[Tuple[float, str, Dict[str, object]]] = []
        self.children: List["Span"] = []
        self.t0 = time.perf_counter()
        self.t1: Optional[float] = None
        self._tracer = tracer

    # -- building the tree -------------------------------------------------

    def child(self, name: str, **attrs) -> "Span":
        s = Span(name, **attrs)
        self.children.append(s)
        return s

    def event(self, kind: str, **fields) -> None:
        """Record an ordered event at the current offset into the
        span (milliseconds since span start)."""
        self.events.append(((time.perf_counter() - self.t0) * 1e3,
                            kind, fields))

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    def end(self) -> "Span":
        if self.t1 is None:
            self.t1 = time.perf_counter()
            if self._tracer is not None:
                self._tracer._finish(self)
        return self

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc is not None:
            self.event("error", error=repr(exc))
            self.set(ok=False)
        self.end()
        return False                       # never swallow

    # -- reading -----------------------------------------------------------

    @property
    def duration_ms(self) -> float:
        t1 = self.t1 if self.t1 is not None else time.perf_counter()
        return (t1 - self.t0) * 1e3

    @property
    def enabled(self) -> bool:
        return True

    def iter_spans(self) -> Iterator["Span"]:
        """self + all descendants, depth-first in creation order."""
        yield self
        for c in self.children:
            yield from c.iter_spans()

    def find(self, name: str) -> Optional["Span"]:
        return next((s for s in self.iter_spans() if s.name == name),
                    None)

    def find_all(self, name: str) -> List["Span"]:
        return [s for s in self.iter_spans() if s.name == name]

    def event_kinds(self) -> List[str]:
        return [k for _, k, _ in self.events]

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "duration_ms": self.duration_ms,
            "attrs": dict(self.attrs),
            "events": [{"t_ms": t, "kind": k, **f}
                       for t, k, f in self.events],
            "children": [c.to_dict() for c in self.children],
        }

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, {self.duration_ms:.2f}ms, "
                f"{len(self.children)} children, "
                f"{len(self.events)} events)")


class _NullSpan:
    """The disabled path: a singleton whose whole API is no-ops and
    whose ``child()`` is itself — instrumented code never branches."""

    __slots__ = ()

    enabled = False
    name = ""
    attrs: Dict[str, object] = {}
    events: List[Tuple[float, str, Dict[str, object]]] = []
    children: List[Span] = []
    duration_ms = 0.0

    def child(self, name: str, **attrs) -> "_NullSpan":
        return self

    def event(self, kind: str, **fields) -> None:
        pass

    def set(self, **attrs) -> None:
        pass

    def end(self) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False

    def iter_spans(self) -> Iterator[Span]:
        return iter(())

    def find(self, name: str) -> None:
        return None

    def find_all(self, name: str) -> List[Span]:
        return []

    def event_kinds(self) -> List[str]:
        return []

    def to_dict(self) -> dict:
        return {}

    def __repr__(self) -> str:
        return "NULL_SPAN"

    def __bool__(self) -> bool:
        # truthiness mirrors ``enabled`` so rare non-hot-path code can
        # gate expensive attr computation with ``if span: ...``
        return False


NULL_SPAN = _NullSpan()


class Tracer:
    """Span factory + finished-trace sink. ``Tracer(enabled=False)``
    (or the module's ``NULL_TRACER``) is the zero-cost default: its
    ``span()`` returns ``NULL_SPAN`` after ONE boolean check."""

    def __init__(self, *, enabled: bool = True, capacity: int = 256):
        self.enabled = enabled
        self.finished: Deque[Span] = deque(maxlen=capacity)

    def span(self, name: str, **attrs):
        """Open a ROOT span (it lands in ``finished`` when ended).
        This is THE is-enabled check of the tracing plane."""
        if not self.enabled:
            return NULL_SPAN
        return Span(name, tracer=self, **attrs)

    def _finish(self, span: Span) -> None:
        self.finished.append(span)

    def last(self, name: Optional[str] = None) -> Optional[Span]:
        """Most recent finished root span (optionally by name)."""
        for s in reversed(self.finished):
            if name is None or s.name == name:
                return s
        return None

    def clear(self) -> None:
        self.finished.clear()


NULL_TRACER = Tracer(enabled=False, capacity=1)
