"""Device-telemetry bridge: fold per-batch search stats into the
metrics plane, and record predicted-vs-measured query cost (DESIGN.md
§ Observability).

``search_batched(..., return_stats=True)`` (and the sharded paths)
return per-query device telemetry — ``steps_total`` [B],
``dist_h_evals`` [B], ``coverage``/``degraded``. ``record_search_stats``
folds one such batch into log-bucketed histograms (one vectorized
``observe_many`` per array — O(B), no samples retained), so the
steps/Dist.H distributions that bound QPS are scrapeable alongside the
service latency percentiles instead of riding in ad-hoc dicts.

The **cost accounting** half is the raw feed ROADMAP item 5's
autotuner needs before it can close the loop: ``predicted_query_ns``
prices a query from the SAME device telemetry through the paper-priced
cost model (``core/cost_model.query_cost``) by synthesizing the
per-query ``SearchStats`` the model expects from batched counters —
per expansion step: one fused Dist.L over the layer's M neighbors, one
kSort.L, one Min.H, M visited checks, and one random DRAM fetch of the
layout-(3) packed row; per Dist.H eval: dim floats of random traffic.
This is an analytic *approximation* of the trace-instrumented host
path (upper-layer step mix and eviction counts are folded into the
dominant layer-0 terms), documented here so the recorded
``phnsw_cost_ratio`` histogram (measured wall / predicted) is read as
what it is: a calibration residual to be LEARNED by the autotuner, not
an identity.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.obs.metrics import Registry, default_registry

# metric family names (the obs-smoke CI gate asserts these exist)
STEPS = "phnsw_search_steps"
DIST_H = "phnsw_search_dist_h_evals"
COVERAGE = "phnsw_search_coverage"
MEASURED_US = "phnsw_query_measured_us"
PREDICTED_US = "phnsw_query_predicted_us"
COST_RATIO = "phnsw_cost_ratio"
BATCHES = "phnsw_search_batches_total"


def predicted_query_ns(cfg, *, steps_mean: float, dist_h_mean: float,
                       filt=None, dram=None) -> float:
    """Cost-model prediction (ns/query) from batched device telemetry.

    ``cfg`` is a ``PHNSWConfig``; ``filt`` (a ``FilterSpec``) supplies
    the filter-distance pipeline depth and payload bytes (defaults to
    the PCA spelling: ``cfg.d_low`` / 4-byte floats). ``dram`` is a
    ``core.cost_model.DramConfig`` (default HBM)."""
    from repro.core.cost_model import HBM, query_cost
    from repro.core.search_ref import SearchStats
    dram = dram or HBM
    d_low = filt.cost_dims if filt is not None else cfg.d_low
    payload_bytes = filt.bytes_per_vec if filt is not None \
        else 4 * cfg.d_low
    M = cfg.M0                     # layer 0 dominates the step mix
    ew = max(cfg.expand_width, 1)
    steps = float(steps_mean)
    dist_h = float(dist_h_mean)
    # cascade promote stage: one batched PCA pass over the wide
    # PQ-space exit list (ef0 * promote_mult side-car gathers + mid
    # distances), once per query
    mid_evals = 0.0
    mid_bytes = 0
    if filt is not None and hasattr(filt, "mid_cost_dims"):
        mid_evals = float(cfg.ef0 * max(cfg.promote_mult,
                                        cfg.rerank_mult))
        mid_bytes = filt.mid_bytes_per_vec
    # layout-(3) packed row: M neighbor ids + M inline payloads
    row_bytes = M * (4 + payload_bytes)
    st = SearchStats(
        expansions=steps * ew,
        dist_low=steps * ew * M,
        dist_mid=mid_evals,
        dist_high=dist_h,
        ksort_calls=steps,
        minh_calls=steps,
        visit_checks=steps * ew * M,
        f_updates=steps * ew,
        evictions=steps,
        rand_accesses=steps * ew + dist_h + mid_evals,
        rand_bytes=steps * ew * row_bytes + dist_h * cfg.dim * 4
        + mid_evals * mid_bytes,
        seq_bursts=0, seq_bytes=0,
    )
    return query_cost(st, n_queries=1, dim=cfg.dim, d_low=d_low,
                      dram=dram, filt=filt).total_ns


def record_search_stats(stats: dict, *, wall_s: Optional[float] = None,
                        n_queries: Optional[int] = None,
                        registry: Optional[Registry] = None,
                        cfg=None, filt=None, dram=None) -> dict:
    """Fold one batch's ``return_stats`` telemetry into the metrics
    plane. With ``wall_s`` (the batch's measured wall time) the
    measured us/query lands in ``phnsw_query_measured_us``; with
    ``cfg`` additionally the cost-model prediction and the
    measured/predicted ratio are recorded — the autotuner's
    calibration feed. Returns a small summary dict."""
    reg = registry or default_registry()
    steps = np.asarray(stats["steps_total"], np.float64).ravel()
    dhe = np.asarray(stats["dist_h_evals"], np.float64).ravel()
    B = n_queries or len(steps)
    reg.histogram(STEPS, "expansion steps per query",
                  lo=1.0, hi=1e5, growth=2 ** 0.25).observe_many(steps[:B])
    reg.histogram(DIST_H, "high-dim distance evals per query",
                  lo=1.0, hi=1e6, growth=2 ** 0.25).observe_many(dhe[:B])
    reg.gauge(COVERAGE, "live-vector coverage of the last batch") \
        .set(float(stats.get("coverage", 1.0)))
    reg.counter(BATCHES, "telemetry batches folded").inc()
    out = {"steps_mean": float(steps[:B].mean()),
           "dist_h_mean": float(dhe[:B].mean()),
           "coverage": float(stats.get("coverage", 1.0))}
    if wall_s is not None:
        measured_us = wall_s / max(B, 1) * 1e6
        reg.histogram(MEASURED_US, "measured query wall time (us)") \
            .observe(measured_us)
        out["measured_us"] = measured_us
        if cfg is not None:
            pred_us = predicted_query_ns(
                cfg, steps_mean=out["steps_mean"],
                dist_h_mean=out["dist_h_mean"], filt=filt,
                dram=dram) / 1e3
            reg.histogram(PREDICTED_US,
                          "cost-model predicted query time (us)") \
                .observe(pred_us)
            reg.histogram(COST_RATIO,
                          "measured / predicted query time",
                          lo=1e-3, hi=1e4, growth=2 ** 0.125) \
                .observe(measured_us / max(pred_us, 1e-9))
            out["predicted_us"] = pred_us
            out["cost_ratio"] = measured_us / max(pred_us, 1e-9)
    return out
