"""Metrics core of the observability plane (DESIGN.md § Observability).

A dependency-free (numpy-only) registry of three metric kinds behind
one naming/labeling scheme:

* ``Counter`` — monotone float total (requests served, events emitted);
* ``Gauge``   — last-set value (coverage, live shards);
* ``Histogram`` — **log-bucketed** distribution: bucket ``i >= 1``
  covers ``(lo * growth^(i-1), lo * growth^i]``, bucket 0 holds
  everything ``<= lo``. Recording is O(1) (one log + one slot
  increment; ``observe_many`` amortizes a whole device batch into one
  vectorized bincount), quantiles are exact-to-bucket WITHOUT storing
  samples (the reason ``ServiceStats`` dropped its percentile deque:
  a service serving forever holds a fixed ~150-slot array per
  histogram, and ``percentile()`` is an O(buckets) cumulative walk
  instead of an O(n log n) ``np.percentile`` per read), and two
  histograms with the same bucket config **merge** by adding counts —
  per-shard / per-replica distributions aggregate losslessly.

Metrics are grouped into labeled **families**: ``registry.counter(
"phnsw_requests_total", labels=("status",))`` returns a ``Family``
whose ``.labels(status="ok")`` child is the actual counter; a family
declared without labels IS its single child. Families are idempotent —
re-declaring a name returns the existing family (so modules can
declare what they record without coordinating).

``DEFAULT`` is the process-global registry (the same pattern as
``distributed.faults``' module registry): library code records into it
unless handed an explicit registry, and ``Registry.reset()`` zeroes
every metric in place WITHOUT invalidating references held by scrapers
or bound recorders (warmup exclusion relies on this).

The registry also carries the unified **event stream**: one bounded
deque of ``ObsEvent`` records shared by the serving plane's shard
health tracker and the train loop's ``StepMonitor`` — straggler marks,
dead marks, failures, recoveries all land in one record type, tagged
by ``source``.
"""
from __future__ import annotations

import math
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np


# --------------------------------------------------------------------------
# the unified event record (serving-plane + train-loop monitoring share it)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ObsEvent:
    """One monitoring event in the unified stream: ``kind`` is the
    event type (``straggler`` / ``dead`` / ``failure`` / ``recovered``
    / ...), ``source`` names the emitter (``train``,
    ``serve.shard3``, ``replica1``), ``target`` is the affected
    shard/replica/worker id (-1 = n/a)."""
    kind: str
    source: str = ""
    target: int = -1
    detail: str = ""
    t_wall: float = 0.0


# --------------------------------------------------------------------------
# metric kinds
# --------------------------------------------------------------------------

class Counter:
    """Monotone total. ``inc`` is thread-safe (lock per metric — the
    hot serving path records once per REQUEST, not per vector, so a
    lock is noise next to a device dispatch)."""
    __slots__ = ("labels", "_v", "_lock")

    def __init__(self, labels: Tuple[Tuple[str, str], ...] = ()):
        self.labels = labels
        self._v = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError("counters only go up; use a Gauge")
        with self._lock:
            self._v += n

    @property
    def value(self) -> float:
        return self._v

    def reset(self) -> None:
        with self._lock:
            self._v = 0.0


class Gauge:
    """Last-set value (plus inc/dec for level-style gauges)."""
    __slots__ = ("labels", "_v", "_lock")

    def __init__(self, labels: Tuple[Tuple[str, str], ...] = ()):
        self.labels = labels
        self._v = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._v = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._v += n

    def dec(self, n: float = 1.0) -> None:
        self.inc(-n)

    @property
    def value(self) -> float:
        return self._v

    def reset(self) -> None:
        with self._lock:
            self._v = 0.0


class Histogram:
    """Log-bucketed histogram; see the module docstring for the bucket
    scheme. Defaults (``lo=1e-3, hi=1e7, growth=2**0.25``) resolve
    microsecond-to-hour latencies in milliseconds at <= ~9% relative
    half-width (sqrt(growth)) in ~134 buckets. Exact count/sum/min/max
    ride along, so means are exact and ``percentile(0)/percentile(100)``
    return the true extremes."""
    __slots__ = ("labels", "lo", "hi", "growth", "_log_g", "counts",
                 "count", "sum", "min", "max", "_lock")

    def __init__(self, labels: Tuple[Tuple[str, str], ...] = (), *,
                 lo: float = 1e-3, hi: float = 1e7,
                 growth: float = 2 ** 0.25):
        assert 0 < lo < hi and growth > 1
        self.labels = labels
        self.lo, self.hi, self.growth = float(lo), float(hi), float(growth)
        self._log_g = math.log(growth)
        n = 2 + int(math.ceil(math.log(hi / lo) / self._log_g))
        self.counts = np.zeros(n, np.int64)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._lock = threading.Lock()

    # -- recording ---------------------------------------------------------

    def _index(self, v: float) -> int:
        if v <= self.lo:
            return 0
        return min(len(self.counts) - 1,
                   1 + int(math.log(v / self.lo) / self._log_g))

    def observe(self, v: float) -> None:
        v = float(v)
        i = self._index(v)
        with self._lock:
            self.counts[i] += 1
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    def observe_many(self, values) -> None:
        """Fold a whole array (e.g. a device batch's per-query
        telemetry) in one vectorized pass."""
        v = np.asarray(values, np.float64).ravel()
        if v.size == 0:
            return
        idx = np.ones(v.shape, np.int64)
        pos = v > self.lo
        idx[~pos] = 0
        idx[pos] += np.minimum(
            len(self.counts) - 2,
            (np.log(v[pos] / self.lo) / self._log_g).astype(np.int64))
        binned = np.bincount(idx, minlength=len(self.counts))
        with self._lock:
            self.counts += binned
            self.count += int(v.size)
            self.sum += float(v.sum())
            self.min = min(self.min, float(v.min()))
            self.max = max(self.max, float(v.max()))

    # -- reading -----------------------------------------------------------

    def upper_edge(self, i: int) -> float:
        """Inclusive upper bound of bucket ``i``."""
        if i == 0:
            return self.lo
        return self.lo * self.growth ** i

    def lower_edge(self, i: int) -> float:
        return 0.0 if i == 0 else self.lo * self.growth ** (i - 1)

    def _representative(self, i: int) -> float:
        """A bucket's point estimate: the geometric midpoint of its
        edges (relative error <= sqrt(growth) - 1), clamped into the
        observed [min, max]."""
        if i == 0:
            r = self.lo
        else:
            r = math.sqrt(self.lower_edge(i) * self.upper_edge(i))
        return min(max(r, self.min), self.max)

    def percentile(self, p: float) -> float:
        """Bucket quantile: the representative value of the bucket
        holding the rank-``p`` sample — within one bucket width of the
        exact sample quantile, O(buckets), no samples stored."""
        if self.count == 0:
            return 0.0
        if p <= 0:
            return self.min
        if p >= 100:
            return self.max
        rank = p / 100.0 * (self.count - 1)
        cum = 0
        for i, c in enumerate(self.counts):
            cum += int(c)
            if cum > rank:
                return self._representative(i)
        return self.max

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def merge(self, other: "Histogram") -> "Histogram":
        """Add ``other``'s buckets into self (same bucket config
        required) — lossless cross-shard / cross-replica aggregation."""
        if (self.lo, self.hi, self.growth) != (other.lo, other.hi,
                                               other.growth):
            raise ValueError("histogram bucket configs differ; merge "
                             "needs identical (lo, hi, growth)")
        with self._lock:
            self.counts += other.counts
            self.count += other.count
            self.sum += other.sum
            self.min = min(self.min, other.min)
            self.max = max(self.max, other.max)
        return self

    def reset(self) -> None:
        with self._lock:
            self.counts[:] = 0
            self.count = 0
            self.sum = 0.0
            self.min = math.inf
            self.max = -math.inf


# --------------------------------------------------------------------------
# labeled families + the registry
# --------------------------------------------------------------------------

_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Family:
    """One named metric family: children keyed by their label values.
    A family declared with ``labels=()`` has exactly one anonymous
    child and proxies the metric API directly (``fam.inc()`` /
    ``fam.observe()`` / ... just work)."""

    def __init__(self, name: str, kind: str, help: str = "",
                 label_names: Tuple[str, ...] = (), **metric_kw):
        self.name = name
        self.kind = kind
        self.help = help
        self.label_names = tuple(label_names)
        self._metric_kw = metric_kw
        self._children: Dict[Tuple[str, ...], object] = {}
        self._lock = threading.Lock()
        if not self.label_names:
            self.labels()          # materialize the anonymous child

    def labels(self, **kv) -> object:
        if set(kv) != set(self.label_names):
            raise ValueError(f"{self.name} has labels "
                             f"{self.label_names}, got {tuple(kv)}")
        key = tuple(str(kv[n]) for n in self.label_names)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.get(key)
                if child is None:
                    child = _KINDS[self.kind](
                        tuple(zip(self.label_names, key)),
                        **self._metric_kw)
                    self._children[key] = child
        return child

    def children(self) -> List[object]:
        return [self._children[k] for k in sorted(self._children)]

    def reset(self) -> None:
        for c in self.children():
            c.reset()

    # -- unlabeled-family convenience proxy --------------------------------

    def _solo(self):
        if self.label_names:
            raise ValueError(f"{self.name} is labeled "
                             f"{self.label_names}; call .labels(...)")
        return self._children[()]

    def __getattr__(self, attr):
        # only metric API attributes fall through; anything else is a
        # genuine AttributeError
        if attr in ("inc", "dec", "set", "observe", "observe_many",
                    "percentile", "merge", "value", "count", "sum",
                    "min", "max", "mean", "counts", "upper_edge",
                    "lower_edge", "lo", "hi", "growth"):
            return getattr(self._solo(), attr)
        raise AttributeError(attr)


class Registry:
    """A named set of metric families + the unified event stream."""

    def __init__(self, *, event_capacity: int = 4096):
        self._families: Dict[str, Family] = {}
        self._lock = threading.Lock()
        from collections import deque
        self.events = deque(maxlen=event_capacity)

    # -- declaration (idempotent) ------------------------------------------

    def _family(self, name: str, kind: str, help: str,
                labels: Tuple[str, ...], **kw) -> Family:
        fam = self._families.get(name)
        if fam is not None:
            if fam.kind != kind or fam.label_names != tuple(labels):
                raise ValueError(
                    f"metric {name!r} re-declared as {kind}"
                    f"{tuple(labels)} but exists as {fam.kind}"
                    f"{fam.label_names}")
            return fam
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = Family(name, kind, help, tuple(labels), **kw)
                self._families[name] = fam
        return fam

    def counter(self, name: str, help: str = "",
                labels: Tuple[str, ...] = ()) -> Family:
        return self._family(name, "counter", help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Tuple[str, ...] = ()) -> Family:
        return self._family(name, "gauge", help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Tuple[str, ...] = (), *, lo: float = 1e-3,
                  hi: float = 1e7, growth: float = 2 ** 0.25) -> Family:
        return self._family(name, "histogram", help, labels,
                            lo=lo, hi=hi, growth=growth)

    # -- reading / lifecycle ----------------------------------------------

    def families(self) -> List[Family]:
        return [self._families[n] for n in sorted(self._families)]

    def get(self, name: str) -> Optional[Family]:
        return self._families.get(name)

    def reset(self) -> None:
        """Zero every metric IN PLACE (references stay valid) and drop
        buffered events — the warmup-exclusion / test-isolation hook."""
        for fam in self.families():
            fam.reset()
        self.events.clear()

    # -- the event stream --------------------------------------------------

    def emit(self, kind: str, *, source: str = "", target: int = -1,
             detail: str = "") -> ObsEvent:
        """Append one event to the unified stream (bounded) and bump
        the per-kind event counter."""
        ev = ObsEvent(kind, source, target, detail, time.time())
        self.events.append(ev)
        self.counter("obs_events_total",
                     "monitoring events by kind",
                     labels=("kind",)).labels(kind=kind).inc()
        return ev

    def events_of(self, kind: Optional[str] = None,
                  source_prefix: str = "") -> List[ObsEvent]:
        return [e for e in self.events
                if (kind is None or e.kind == kind)
                and e.source.startswith(source_prefix)]


# --------------------------------------------------------------------------
# process-global default registry
# --------------------------------------------------------------------------

DEFAULT = Registry()


def default_registry() -> Registry:
    return DEFAULT


def counter(name: str, help: str = "",
            labels: Tuple[str, ...] = ()) -> Family:
    return DEFAULT.counter(name, help, labels)


def gauge(name: str, help: str = "",
          labels: Tuple[str, ...] = ()) -> Family:
    return DEFAULT.gauge(name, help, labels)


def histogram(name: str, help: str = "",
              labels: Tuple[str, ...] = (), **kw) -> Family:
    return DEFAULT.histogram(name, help, labels, **kw)


def emit_event(kind: str, *, source: str = "", target: int = -1,
               detail: str = "") -> ObsEvent:
    return DEFAULT.emit(kind, source=source, target=target, detail=detail)
