"""Batched pHNSW vector-search service — the serving half of the paper's
system (single-query ASIC -> batched TPU service).

Requests accumulate into fixed-size batches (the compiled search program
has a static batch dim); underfull batches are padded with the entry
point and results trimmed. QPS and latency percentiles ride on the
observability plane (``repro.obs``): latency lands in a log-bucketed
histogram — O(1) per record, constant memory forever — and percentiles
are bucket quantiles, so a long-running service never rescans samples.

Backed by any of four snapshots behind one API:

  * a frozen ``PackedDB`` (read-only single-shard serving, the seed
    behavior) or a ``MutableIndex`` (live single-shard serving);
  * a frozen ``ShardedDB`` (read-only SHARDED serving) or a
    ``ShardedMutableIndex`` (live sharded serving) — results carry
    GLOBAL ids; pass ``mesh=`` to run the collective path on real
    devices, else the bit-equal single-device shard loop serves.

``upsert`` / ``delete`` (mutable backends) mutate the index and
atomically swap the published epoch's device snapshot under the running
service. The swap is a plain attribute assignment of an immutable
snapshot value — in-flight batches finish on the epoch they started on,
the next batch sees the new one, and in steady state no shape changes,
so the compiled program is reused across the swap (zero recompiles).
The NON-steady-state events that do recompile — capacity doubling
(pre-pay with ``reserve``) and an insert drawing a level above the
current top layer — are each O(log N) over an index's lifetime; the
sharded index additionally renumbers global ids on growth; see
DESIGN.md § Mutable index / § Sharded serving.

**Fault tolerance** (DESIGN.md § Fault tolerance): pass a
``FaultPolicy`` to serve a sharded backend resiliently — each shard is
probed individually (``core.distributed.probe_shard``), failures get
bounded exponential-backoff retries inside a per-request deadline
budget, per-shard wall times feed a median+MAD straggler monitor,
repeated failures mark a shard dead (skipped until ``recover_shard``),
and the request completes DEGRADED from whichever shards answered —
results then carry exact ``coverage`` accounting via
``query(..., return_stats=True)``. All of it is data-masked over the
same compiled programs: a kill/recover cycle never recompiles.

**Tracing** (DESIGN.md § Observability): pass ``tracer=Tracer()`` and
every request builds a span tree — ``serve.query`` -> per-shard
``shard.probe`` children (fault-injection hits, retry/backoff,
straggler and dead-shard marks as ordered events) -> ``merge`` (with
coverage/degraded attrs) — and mutations trace ``serve.upsert`` /
``serve.delete`` -> ``epoch.swap``. Off by default: the single
is-enabled check lives in ``Tracer.span`` and the disabled path
allocates no span objects (same hot-path discipline as
``distributed.faults``' hook registry).
"""
from __future__ import annotations

import time
from typing import Optional, Tuple, Union

import numpy as np
import jax.numpy as jnp

from repro.core.distributed import (ShardedDB, _normalize,
                                    check_shard_result, distributed_search,
                                    merge_surviving, probe_shard,
                                    shard_live_counts, shard_search_host)
from repro.core.filters import FilterSpec, IdentityFilter, PCAFilter
from repro.core.pca import PCA
from repro.core.search_jax import PackedDB, search_batched
from repro.distributed import faults as faults_mod
from repro.distributed.faults import (AllShardsDeadError, FaultPolicy,
                                      ShardCorruptError, ShardFaultError,
                                      ShardHealth)
from repro.index import MutableIndex, ShardedMutableIndex
from repro.obs.metrics import Registry
from repro.obs.trace import NULL_SPAN, NULL_TRACER, Tracer


class ServiceStats:
    """Rolling serving statistics on the obs metrics plane.

    Latency lives in a log-bucketed ``Histogram`` (``repro.obs``):
    recording is O(1) and ``percentile()`` is an O(buckets) cumulative
    walk over mergeable buckets — no per-sample window, so the old
    ``LATENCY_WINDOW`` deque (and its O(n log n) ``np.percentile`` per
    read) is gone while the read surface (``queries`` / ``upserts`` /
    ``deletes`` / ``degraded_queries`` / ``qps`` / ``percentile``)
    stays what it was.

    Each ``ServiceStats`` owns a private ``Registry`` by default (two
    services never share counts); pass one in to scrape several
    services — or a service plus the device-telemetry bridge — from a
    single exporter endpoint.
    """

    def __init__(self, registry: Optional[Registry] = None):
        self.registry = registry if registry is not None else Registry()
        r = self.registry
        self.latency_ms = r.histogram(
            "phnsw_request_latency_ms",
            "per-query serving latency (ms)")
        self._queries = r.counter("phnsw_queries_total", "queries served")
        self._upserts = r.counter("phnsw_upserts_total",
                                  "vectors upserted")
        self._deletes = r.counter("phnsw_deletes_total", "ids tombstoned")
        self._degraded = r.counter("phnsw_degraded_requests_total",
                                   "requests completed degraded")
        self._coverage = r.gauge("phnsw_request_coverage",
                                 "live-vector coverage of the last "
                                 "request")
        self._coverage.set(1.0)
        self.started = time.monotonic()

    # -- recording (the service's write surface) ---------------------------

    def record_request(self, n: int, latency_ms: float) -> None:
        """One served batch of ``n`` real queries: each counts toward
        QPS and each experienced the batch's latency."""
        self._queries.inc(n)
        for _ in range(n):
            self.latency_ms.observe(latency_ms)

    def record_degraded(self, coverage: float) -> None:
        self._degraded.inc()
        self._coverage.set(coverage)

    def record_upserts(self, n: int) -> None:
        self._upserts.inc(n)

    def record_deletes(self, n: int) -> None:
        self._deletes.inc(n)

    def reset(self) -> None:
        """Zero every metric in place (scraper references stay valid)
        and restart the QPS clock — the warmup-exclusion hook."""
        self.registry.reset()
        self._coverage.set(1.0)
        self.started = time.monotonic()

    # -- reading (backward-compatible with the pre-obs dataclass) ----------

    @property
    def queries(self) -> int:
        return int(self._queries.value)

    @property
    def upserts(self) -> int:
        return int(self._upserts.value)

    @property
    def deletes(self) -> int:
        return int(self._deletes.value)

    @property
    def degraded_queries(self) -> int:
        return int(self._degraded.value)

    @property
    def qps(self) -> float:
        return self.queries / max(time.monotonic() - self.started, 1e-9)

    def percentile(self, p: float) -> float:
        if self.latency_ms.count == 0:
            return 0.0
        return self.latency_ms.percentile(p)


class VectorSearchService:
    def __init__(self, db: Union[PackedDB, MutableIndex, ShardedDB,
                                 ShardedMutableIndex],
                 pca: Optional[PCA] = None, *, batch_size: int = 64,
                 ef0: Optional[int] = None,
                 filt: Optional[FilterSpec] = None, mesh=None,
                 nan_policy: str = "raise",
                 fault_policy: Optional[FaultPolicy] = None,
                 tracer: Optional[Tracer] = None,
                 registry: Optional[Registry] = None):
        """``filt`` (any ``core.filters.FilterSpec``) generalizes the
        seed's ``pca`` argument; mutable indexes bring their own filter.
        A frozen identity-filter db needs neither. Sharded backends
        (``ShardedDB`` / ``ShardedMutableIndex``) serve GLOBAL ids;
        ``mesh`` selects the collective path (single-device shard loop
        otherwise — bit-equal).

        ``nan_policy``: what to do with NaN/Inf entries in queries and
        upserts — ``"raise"`` (default, a clear ValueError at the API
        boundary instead of silent mis-serving) or ``"sanitize"``
        (zero them).

        ``fault_policy`` (sharded backends, host path) turns on the
        resilient per-shard query loop: retry/deadline/straggler
        handling plus degraded-mode completion — see the module
        docstring.

        ``tracer``: a ``repro.obs.Tracer`` to build per-request span
        trees (default: disabled — zero allocations on the hot path).
        ``registry``: the metrics registry ``ServiceStats`` records
        into (default: a private one per service)."""
        self.index: Optional[MutableIndex] = None
        self.sindex: Optional[ShardedMutableIndex] = None
        self.sdb: Optional[ShardedDB] = None
        self.db: Optional[PackedDB] = None
        self.mesh = mesh
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if nan_policy not in ("raise", "sanitize"):
            raise ValueError(f"nan_policy must be 'raise' or 'sanitize', "
                             f"got {nan_policy!r}")
        self.nan_policy = nan_policy
        if isinstance(db, ShardedMutableIndex):
            self.sindex = db
            self.sdb = db.sdb
            filt = filt or db.filt
        elif isinstance(db, ShardedDB):
            self.sdb = db
        elif isinstance(db, MutableIndex):
            self.index = db
            self.db = db.db
            filt = filt or db.filt
        else:
            self.db = db
        snap = self.sdb if self.sdb is not None else self.db
        if filt is None:
            if pca is not None:
                filt = PCAFilter(pca, low_dtype=snap.cfg.low_dtype)
            elif snap.filter_kind == "none":
                filt = IdentityFilter(dim=snap.high.shape[-1])
            else:
                raise ValueError("filt (or pca) is required when "
                                 "serving a frozen db with the "
                                 f"{snap.filter_kind!r} filter")
        self.filt = filt
        self.pca = filt.pca if isinstance(filt, PCAFilter) else pca
        self.batch = batch_size
        self.ef0 = ef0 or snap.cfg.ef0
        self._dim = int(snap.high.shape[-1])
        mut = self.index or self.sindex
        self.epoch = mut.epoch if mut else 0
        self.fault_policy = fault_policy
        self.health: Optional[ShardHealth] = None
        if fault_policy is not None:
            if self.sdb is None:
                raise ValueError("fault_policy needs a sharded backend "
                                 "(ShardedDB / ShardedMutableIndex) — "
                                 "single-shard redundancy is the "
                                 "ReplicaSet's job")
            if mesh is not None:
                raise ValueError("fault_policy drives the per-shard "
                                 "host path; it cannot be combined "
                                 "with mesh=")
            self.health = ShardHealth(self.sdb.n_shards, fault_policy)
        self.last_stats = {"coverage": 1.0, "degraded": False}
        self._refresh_pad_row()
        self._refresh_live_counts()
        # warm the compiled program, then reset stats IN PLACE so
        # compile time and the warmup batch never pollute QPS/latency
        # percentiles (tests/test_obs.py pins this); in-place reset
        # keeps scrapers' references to the histogram valid
        self.stats = ServiceStats(registry)
        dummy = np.zeros((batch_size, snap.high.shape[-1]), np.float32)
        self._run(dummy)
        self.stats.reset()

    def _refresh_pad_row(self):
        # pad row for underfull batches: the entry point's vector — its
        # search terminates in O(1) steps, so pad lanes never drag the
        # batch (padding with a caller query would re-run it); sharded:
        # shard 0's entry
        if self.sdb is not None:
            row = self.sdb.high[0, int(self.sdb.entries[0])]
        else:
            row = self.db.high[int(self.db.entry)]
        self._pad_row = np.asarray(row)[None].astype(np.float32)

    def _refresh_live_counts(self):
        """Host cache of per-shard live populations (the ``coverage``
        denominators) + ownership spans — refreshed on every epoch
        swap, read per degraded request."""
        if self.sdb is not None:
            self._live_counts = shard_live_counts(self.sdb)
            self._offsets_np = np.asarray(self.sdb.offsets, np.int64)
            self._counts_np = np.asarray(self.sdb.counts, np.int64)

    # ------------------------------------------------------------------
    # input validation (the API boundary: clear errors here instead of
    # shape/dtype explosions deep inside jit, or NaN mis-serving)
    # ------------------------------------------------------------------

    def _validate_vectors(self, a, what: str, *, dim: Optional[int] = None
                          ) -> np.ndarray:
        a = np.asarray(a)
        if a.dtype == object or not (np.issubdtype(a.dtype, np.floating)
                                     or np.issubdtype(a.dtype, np.integer)):
            raise ValueError(f"{what} must be numeric, got dtype "
                             f"{a.dtype}")
        dim = self._dim if dim is None else dim
        if a.ndim != 2 or a.shape[1] != dim:
            raise ValueError(f"{what} must be [n, {dim}], got shape "
                             f"{a.shape}")
        if len(a) == 0:
            raise ValueError(f"empty {what} batch")
        a = a.astype(np.float32, copy=False)
        finite = np.isfinite(a)
        if not finite.all():
            if self.nan_policy == "sanitize":
                a = np.where(finite, a, np.float32(0.0))
            else:
                raise ValueError(
                    f"{what} contain {int((~finite).sum())} non-finite "
                    f"(NaN/Inf) values; construct the service with "
                    f"nan_policy='sanitize' to zero them instead")
        return a

    def _validate_queries(self, q) -> np.ndarray:
        q = self._validate_vectors(q, "queries")
        if len(q) > self.batch:
            raise ValueError(
                f"{len(q)} queries exceed batch_size={self.batch}; "
                f"use run_stream() to serve in batches")
        return q

    # ------------------------------------------------------------------
    # mutation (MutableIndex-backed services only)
    # ------------------------------------------------------------------

    def _swap(self, span=NULL_SPAN):
        """Atomically publish the index's current epoch to the serving
        path (attribute assignment of an immutable snapshot)."""
        with span.child("epoch.swap", from_epoch=self.epoch) as sw:
            if self.sindex is not None:
                self.sdb = self.sindex.sdb
                self.epoch = self.sindex.epoch
            else:
                self.db = self.index.db
                self.epoch = self.index.epoch
            self._refresh_pad_row()
            self._refresh_live_counts()
            sw.set(to_epoch=self.epoch)

    @property
    def _mut(self):
        return self.index if self.index is not None else self.sindex

    def upsert(self, vectors: np.ndarray,
               ids: Optional[np.ndarray] = None,
               *, span=None) -> np.ndarray:
        """Insert (or, with ``ids``, replace) vectors; swaps the serving
        snapshot to the new epoch. Returns the new internal ids (GLOBAL
        ids on a sharded backend)."""
        if self._mut is None:
            raise RuntimeError("upsert() needs a mutable-index-backed "
                               "service (got a frozen snapshot)")
        vectors = self._validate_vectors(vectors, "upsert vectors")
        if ids is not None:
            ids = np.atleast_1d(np.asarray(ids))
            if not np.issubdtype(ids.dtype, np.integer):
                raise ValueError(f"ids must be integers, got dtype "
                                 f"{ids.dtype}")
            if len(ids) != len(vectors):
                raise ValueError(f"{len(ids)} ids for {len(vectors)} "
                                 f"vectors")
        root = (span.child("serve.upsert") if span is not None and
                span.enabled else self.tracer.span("serve.upsert"))
        root.set(n=len(vectors))
        with root:
            if self.sindex is not None:
                new_ids = self.sindex.upsert(vectors, ids=ids, span=root)
            else:
                new_ids = self.index.upsert(vectors, ids=ids)
            self.stats.record_upserts(len(new_ids))
            self._swap(span=root)
        return new_ids

    def delete(self, ids: np.ndarray, *, span=None) -> int:
        """Tombstone ids; deleted ids never appear in results from the
        swapped epoch onward. Returns the number newly deleted."""
        if self._mut is None:
            raise RuntimeError("delete() needs a mutable-index-backed "
                               "service (got a frozen snapshot)")
        root = (span.child("serve.delete") if span is not None and
                span.enabled else self.tracer.span("serve.delete"))
        with root:
            if self.sindex is not None:
                n = self.sindex.delete(ids, span=root)
            else:
                n = self.index.delete(ids)
            root.set(n=n)
            self.stats.record_deletes(n)
            self._swap(span=root)
        return n

    # ------------------------------------------------------------------
    # query path
    # ------------------------------------------------------------------

    def _run(self, q: np.ndarray, span=NULL_SPAN):
        if self.health is not None:
            return self._run_resilient(q, span=span)
        qprep = self.filt.prepare(q)
        if self.sdb is not None:
            if self.mesh is not None:
                with span.child("search", path="mesh"):
                    fd, fi = distributed_search(self.mesh, self.sdb,
                                                jnp.asarray(q),
                                                jnp.asarray(qprep),
                                                ef0=self.ef0)
            else:
                with span.child("search", path="host-sharded"):
                    fd, fi = shard_search_host(self.sdb, jnp.asarray(q),
                                               jnp.asarray(qprep),
                                               ef0=self.ef0)
        else:
            with span.child("search", path="single"):
                fd, fi = search_batched(self.db, jnp.asarray(q),
                                        jnp.asarray(qprep), ef0=self.ef0)
        return np.asarray(fd), np.asarray(fi)

    def _coverage(self, answered: np.ndarray) -> float:
        lc = self._live_counts
        return int(lc[answered].sum()) / max(int(lc.sum()), 1)

    def _run_resilient(self, q: np.ndarray, span=NULL_SPAN):
        """The fault-tolerant sharded query loop: probe every non-dead
        shard individually (bounded retry + exponential backoff inside
        the per-request deadline budget), validate each answer at the
        merge boundary, feed wall times to the per-shard straggler
        monitor, then complete the request from whichever shards
        answered (degraded when any didn't).

        Every decision the loop takes lands in the trace: a
        ``shard.probe`` child per probed shard carries fault /
        quarantine / backoff / straggler / dead_mark events in the
        order they happened; skipped-dead shards and the final merge
        (with exact coverage) are recorded on the request span."""
        pol = self.fault_policy
        sdb = self.sdb
        Pn = sdb.n_shards
        plan = faults_mod.active()
        if plan is not None:
            plan.tick()
        qd = jnp.asarray(q)
        qp = jnp.asarray(self.filt.prepare(q))
        ef0, _, deferred, rm, pm = _normalize(sdb, self.ef0, None, None,
                                              None)
        # per-shard list width: the cascade's promote pool when active
        # (pm normalizes to 1 for every other config)
        E = ef0 * max(rm, pm) if deferred else ef0
        fd_all = np.zeros((Pn, len(q), E), np.float32)
        gi_all = np.full((Pn, len(q), E), -1, np.int32)
        answered = np.zeros(Pn, bool)
        deadline = time.monotonic() + pol.deadline_ms / 1e3
        for s in range(Pn):
            if self.health.dead[s]:
                span.event("skip_dead_shard", shard=s)
                continue
            ps = span.child("shard.probe", shard=s)
            with ps:
                for attempt in range(pol.max_retries + 1):
                    if attempt and time.monotonic() >= deadline:
                        # retry budget spent: serve degraded
                        ps.event("deadline_exhausted", attempt=attempt)
                        break
                    try:
                        fd, gi, wall = probe_shard(sdb, s, qd, qp,
                                                   ef0=self.ef0,
                                                   span=ps)
                        if not check_shard_result(
                                fd, gi, int(self._offsets_np[s]),
                                int(self._counts_np[s])):
                            raise ShardCorruptError(
                                f"shard {s} failed the merge-boundary "
                                f"integrity check")
                        ev = self.health.heartbeat(s, wall)
                        if ev.kind == "straggler":
                            ps.event("straggler", shard=s,
                                     detail=ev.detail)
                        fd_all[s], gi_all[s] = fd, gi
                        answered[s] = True
                        ps.set(answered=True, attempts=attempt + 1,
                               wall_ms=wall * 1e3)
                        break
                    except ShardFaultError as e:
                        kind = ("quarantine"
                                if isinstance(e, ShardCorruptError)
                                else "fault")
                        ps.event(kind, shard=s, attempt=attempt,
                                 error=repr(e))
                        if self.health.failure(s, e):
                            ps.event("dead_mark", shard=s,
                                     failures=int(
                                         self.health.failures[s]))
                            break   # marked dead: stop retrying it
                        pause = min(pol.backoff_ms * (2 ** attempt) / 1e3,
                                    max(deadline - time.monotonic(), 0.0))
                        if pause > 0:
                            ps.event("backoff", ms=pause * 1e3,
                                     attempt=attempt)
                            time.sleep(pause)
                if not answered[s]:
                    ps.set(answered=False)
        if not answered.any():
            span.event("all_shards_dead")
            raise AllShardsDeadError(
                f"no shard of {Pn} answered within the "
                f"{pol.deadline_ms:.0f}ms budget")
        with span.child("merge", live_shards=int(answered.sum()),
                        n_shards=Pn) as ms:
            fd, fi = merge_surviving(sdb, fd_all, gi_all, answered, qd,
                                     qprep=qp, ef0=self.ef0)
            degraded = bool(~answered.all())
            cov = self._coverage(answered)
            ms.set(coverage=cov, degraded=degraded, deferred=deferred)
        self.last_stats = {
            "coverage": cov,
            "degraded": degraded,
            "live_shards": int(answered.sum()),
            "n_shards": Pn,
            "answered": answered,
        }
        if degraded:
            self.stats.record_degraded(cov)
        return np.asarray(fd), np.asarray(fi)

    def recover_shard(self, s: int) -> None:
        """Clear a shard's dead mark after the underlying fault healed
        (operator action / fault-plan heal): the next request probes it
        again — on the SAME compiled programs (recovery is data)."""
        if self.health is None:
            raise RuntimeError("recover_shard() needs a fault_policy-"
                               "enabled service")
        self.health.recover(s)

    def query(self, q: np.ndarray, *, return_stats: bool = False,
              span=None) -> Tuple[np.ndarray, ...]:
        """q: [n, D] with n <= batch_size; underfull batches are padded
        with the entry point. Returns (dists, indices) for the n real
        queries; only those count toward stats. With ``return_stats``
        a third element reports this request's serving health:
        ``coverage`` (fraction of live vectors reachable — exact),
        ``degraded``, and ``latency_ms``. ``span`` (optional) parents
        this request's trace under a caller span (e.g. a ReplicaSet
        failover loop) instead of opening a new root."""
        q = self._validate_queries(q)
        n = len(q)
        t0 = time.monotonic()
        root = (span.child("serve.query") if span is not None and
                span.enabled else self.tracer.span("serve.query"))
        root.set(n=n, batch=self.batch, epoch=self.epoch)
        with root:
            if n < self.batch:
                pad = np.broadcast_to(self._pad_row,
                                      (self.batch - n, q.shape[1]))
                q = np.concatenate([q, pad], axis=0)
            fd, fi = self._run(q, span=root)
            dt = (time.monotonic() - t0) * 1000.0
            self.stats.record_request(n, dt)
            root.set(latency_ms=dt,
                     coverage=self.last_stats.get("coverage", 1.0),
                     degraded=self.last_stats.get("degraded", False))
        if return_stats:
            return fd[:n], fi[:n], {**self.last_stats,
                                    "latency_ms": dt}
        return fd[:n], fi[:n]

    @property
    def scheduler_supported(self) -> bool:
        """Whether the continuous-batching scheduler can serve this
        configuration: host paths, including single-shard deferred
        re-ranking (the promote/re-rank passes run batched at
        retirement); the sharded deferred merge-then-rerank is not
        slotted."""
        snap = self.sdb if self.sdb is not None else self.db
        deferred = snap.cfg.deferred_rerank and snap.filter_kind != "none"
        return self.mesh is None and not (deferred
                                          and self.sdb is not None)

    def scheduler(self, **kw):
        """The service's continuous-batching front-end
        (``serve.scheduler.StreamScheduler``). With no arguments the
        one default instance is cached and reused (its slot state and
        step telemetry persist across ``run_stream`` calls); keyword
        arguments build a fresh scheduler (e.g. ``ef=128`` for
        mixed-k traffic, ``slo_ms=`` for deadline shedding)."""
        from repro.serve.scheduler import StreamScheduler
        if kw:
            return StreamScheduler(self, **kw)
        if getattr(self, "_sched", None) is None:
            self._sched = StreamScheduler(self)
        return self._sched

    def _stream_stats(self, extra: Optional[dict] = None) -> dict:
        st = {
            "qps": self.stats.qps,
            "p50_ms": self.stats.percentile(50),
            "p99_ms": self.stats.percentile(99),
            "p999_ms": self.stats.percentile(99.9),
        }
        if extra:
            st.update(extra)
        return st

    def run_stream_sync(self, queries: np.ndarray
                        ) -> Tuple[np.ndarray, dict]:
        """The synchronous batch-at-a-time stream path (the seed
        behavior, kept as the scheduler's A/B baseline): serve in
        service batches, every query waiting for its batch's slowest
        traverser."""
        outs = []
        for i in range(0, len(queries), self.batch):
            _, fi = self.query(queries[i:i + self.batch])
            outs.append(fi)
        return np.concatenate(outs, axis=0), \
            self._stream_stats({"path": "sync"})

    def run_stream(self, queries: np.ndarray, *,
                   scheduler: Optional[bool] = None
                   ) -> Tuple[np.ndarray, dict]:
        """Serve a stream of queries; returns (all indices [n, ef0],
        stats). By default the continuous-batching scheduler serves any
        supported configuration (queries retire individually as they
        converge — no convoy, no pad lanes) and the synchronous batch
        path serves the rest; force either with ``scheduler=``.
        Results come back in SUBMISSION order regardless of retirement
        order, exactly once per query."""
        if scheduler is None:
            scheduler = self.scheduler_supported
        if not scheduler:
            return self.run_stream_sync(queries)
        q = self._validate_vectors(queries, "queries")
        sched = self.scheduler()
        k = min(self.ef0, sched.EF)
        n = len(q)
        out = np.full((n, k), -1, np.int64)
        i = got = 0
        while got < n:
            while i < n and sched.has_capacity():
                sched.submit(q[i], k=k, rid=i)
                i += 1
            ticked = sched.tick()
            for c in ticked:
                out[c.rid] = c.ids
                got += 1
        return out, self._stream_stats({"path": "scheduler"})
