"""Batched pHNSW vector-search service — the serving half of the paper's
system (single-query ASIC -> batched TPU service).

Requests accumulate into fixed-size batches (the compiled search program
has a static batch dim); underfull batches are padded with the entry
point and results trimmed. Tracks QPS and latency percentiles.

Backed by either a frozen ``PackedDB`` (read-only serving, the seed
behavior) or a ``MutableIndex`` (live serving): ``upsert`` / ``delete``
mutate the index and atomically swap the published epoch's device
snapshot under the running service. The swap is a plain attribute
assignment of an immutable ``PackedDB`` value — in-flight batches finish
on the epoch they started on, the next batch sees the new one, and in
steady state no shape changes, so the compiled program is reused across
the swap (zero recompiles). The two NON-steady-state events that do
recompile — capacity doubling (pre-pay with ``MutableIndex.reserve``)
and an insert drawing a level above the current top layer (adds a
device layer; probability ~M^-(top+1) per insert) — are each O(log N)
over an index's lifetime; see DESIGN.md § Mutable index.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple, Union

import numpy as np
import jax.numpy as jnp

from repro.core.filters import FilterSpec, IdentityFilter, PCAFilter
from repro.core.pca import PCA
from repro.core.search_jax import PackedDB, search_batched
from repro.index import MutableIndex


@dataclass
class ServiceStats:
    latencies_ms: List[float] = field(default_factory=list)
    queries: int = 0
    upserts: int = 0
    deletes: int = 0
    started: float = field(default_factory=time.monotonic)

    @property
    def qps(self) -> float:
        return self.queries / max(time.monotonic() - self.started, 1e-9)

    def percentile(self, p: float) -> float:
        if not self.latencies_ms:
            return 0.0
        return float(np.percentile(self.latencies_ms, p))


class VectorSearchService:
    def __init__(self, db: Union[PackedDB, MutableIndex],
                 pca: Optional[PCA] = None, *, batch_size: int = 64,
                 ef0: Optional[int] = None,
                 filt: Optional[FilterSpec] = None):
        """``filt`` (any ``core.filters.FilterSpec``) generalizes the
        seed's ``pca`` argument; a MutableIndex brings its own filter.
        A frozen identity-filter PackedDB needs neither."""
        if isinstance(db, MutableIndex):
            self.index: Optional[MutableIndex] = db
            self.db = db.db
            filt = filt or db.filt
        else:
            self.index = None
            self.db = db
        if filt is None:
            if pca is not None:
                filt = PCAFilter(pca, low_dtype=self.db.cfg.low_dtype)
            elif self.db.filter_kind == "none":
                filt = IdentityFilter(dim=self.db.high.shape[1])
            else:
                raise ValueError("filt (or pca) is required when "
                                 "serving a PackedDB with the "
                                 f"{self.db.filter_kind!r} filter")
        self.filt = filt
        self.pca = filt.pca if isinstance(filt, PCAFilter) else pca
        self.batch = batch_size
        self.ef0 = ef0 or self.db.cfg.ef0
        self.epoch = self.index.epoch if self.index else 0
        self._refresh_pad_row()
        # warm the compiled program, then reset stats so compile time
        # and the warmup batch never pollute QPS/latency percentiles
        self.stats = ServiceStats()
        dummy = np.zeros((batch_size, self.db.high.shape[1]), np.float32)
        self._run(dummy)
        self.stats = ServiceStats()

    def _refresh_pad_row(self):
        # pad row for underfull batches: the entry point's vector — its
        # search terminates in O(1) steps, so pad lanes never drag the
        # batch (padding with a caller query would re-run it)
        self._pad_row = np.asarray(
            self.db.high[int(self.db.entry)])[None].astype(np.float32)

    # ------------------------------------------------------------------
    # mutation (MutableIndex-backed services only)
    # ------------------------------------------------------------------

    def _swap(self):
        """Atomically publish the index's current epoch to the serving
        path (attribute assignment of an immutable snapshot)."""
        self.db = self.index.db
        self.epoch = self.index.epoch
        self._refresh_pad_row()

    def upsert(self, vectors: np.ndarray,
               ids: Optional[np.ndarray] = None) -> np.ndarray:
        """Insert (or, with ``ids``, replace) vectors; swaps the serving
        snapshot to the new epoch. Returns the new internal ids."""
        if self.index is None:
            raise RuntimeError("upsert() needs a MutableIndex-backed "
                               "service (got a frozen PackedDB)")
        new_ids = self.index.upsert(np.asarray(vectors, np.float32),
                                    ids=ids)
        self.stats.upserts += len(new_ids)
        self._swap()
        return new_ids

    def delete(self, ids: np.ndarray) -> int:
        """Tombstone ids; deleted ids never appear in results from the
        swapped epoch onward. Returns the number newly deleted."""
        if self.index is None:
            raise RuntimeError("delete() needs a MutableIndex-backed "
                               "service (got a frozen PackedDB)")
        n = self.index.delete(ids)
        self.stats.deletes += n
        self._swap()
        return n

    # ------------------------------------------------------------------
    # query path
    # ------------------------------------------------------------------

    def _run(self, q: np.ndarray):
        qprep = self.filt.prepare(q)
        fd, fi = search_batched(self.db, jnp.asarray(q),
                                jnp.asarray(qprep), ef0=self.ef0)
        return np.asarray(fd), np.asarray(fi)

    def query(self, q: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """q: [n, D] with n <= batch_size; underfull batches are padded
        with the entry point. Returns (dists, indices) for the n real
        queries; only those count toward stats."""
        n = len(q)
        t0 = time.monotonic()
        if n < self.batch:
            pad = np.broadcast_to(self._pad_row,
                                  (self.batch - n, q.shape[1]))
            q = np.concatenate([q, pad], axis=0)
        fd, fi = self._run(q)
        dt = (time.monotonic() - t0) * 1000.0
        self.stats.queries += n
        self.stats.latencies_ms.extend([dt] * n)
        return fd[:n], fi[:n]

    def run_stream(self, queries: np.ndarray) -> Tuple[np.ndarray, dict]:
        """Serve a stream in service batches; returns (all indices, stats)."""
        outs = []
        for i in range(0, len(queries), self.batch):
            _, fi = self.query(queries[i:i + self.batch])
            outs.append(fi)
        return np.concatenate(outs, axis=0), {
            "qps": self.stats.qps,
            "p50_ms": self.stats.percentile(50),
            "p99_ms": self.stats.percentile(99),
        }
