"""Batched pHNSW vector-search service — the serving half of the paper's
system (single-query ASIC -> batched TPU service).

Requests accumulate into fixed-size batches (the compiled search program
has a static batch dim); underfull batches are padded with the entry
point and results trimmed. Tracks QPS and latency percentiles.

Backed by any of four snapshots behind one API:

  * a frozen ``PackedDB`` (read-only single-shard serving, the seed
    behavior) or a ``MutableIndex`` (live single-shard serving);
  * a frozen ``ShardedDB`` (read-only SHARDED serving) or a
    ``ShardedMutableIndex`` (live sharded serving) — results carry
    GLOBAL ids; pass ``mesh=`` to run the collective path on real
    devices, else the bit-equal single-device shard loop serves.

``upsert`` / ``delete`` (mutable backends) mutate the index and
atomically swap the published epoch's device snapshot under the running
service. The swap is a plain attribute assignment of an immutable
snapshot value — in-flight batches finish on the epoch they started on,
the next batch sees the new one, and in steady state no shape changes,
so the compiled program is reused across the swap (zero recompiles).
The NON-steady-state events that do recompile — capacity doubling
(pre-pay with ``reserve``) and an insert drawing a level above the
current top layer — are each O(log N) over an index's lifetime; the
sharded index additionally renumbers global ids on growth; see
DESIGN.md § Mutable index / § Sharded serving.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple, Union

import numpy as np
import jax.numpy as jnp

from repro.core.distributed import (ShardedDB, distributed_search,
                                    shard_search_host)
from repro.core.filters import FilterSpec, IdentityFilter, PCAFilter
from repro.core.pca import PCA
from repro.core.search_jax import PackedDB, search_batched
from repro.index import MutableIndex, ShardedMutableIndex


@dataclass
class ServiceStats:
    latencies_ms: List[float] = field(default_factory=list)
    queries: int = 0
    upserts: int = 0
    deletes: int = 0
    started: float = field(default_factory=time.monotonic)

    @property
    def qps(self) -> float:
        return self.queries / max(time.monotonic() - self.started, 1e-9)

    def percentile(self, p: float) -> float:
        if not self.latencies_ms:
            return 0.0
        return float(np.percentile(self.latencies_ms, p))


class VectorSearchService:
    def __init__(self, db: Union[PackedDB, MutableIndex, ShardedDB,
                                 ShardedMutableIndex],
                 pca: Optional[PCA] = None, *, batch_size: int = 64,
                 ef0: Optional[int] = None,
                 filt: Optional[FilterSpec] = None, mesh=None):
        """``filt`` (any ``core.filters.FilterSpec``) generalizes the
        seed's ``pca`` argument; mutable indexes bring their own filter.
        A frozen identity-filter db needs neither. Sharded backends
        (``ShardedDB`` / ``ShardedMutableIndex``) serve GLOBAL ids;
        ``mesh`` selects the collective path (single-device shard loop
        otherwise — bit-equal)."""
        self.index: Optional[MutableIndex] = None
        self.sindex: Optional[ShardedMutableIndex] = None
        self.sdb: Optional[ShardedDB] = None
        self.db: Optional[PackedDB] = None
        self.mesh = mesh
        if isinstance(db, ShardedMutableIndex):
            self.sindex = db
            self.sdb = db.sdb
            filt = filt or db.filt
        elif isinstance(db, ShardedDB):
            self.sdb = db
        elif isinstance(db, MutableIndex):
            self.index = db
            self.db = db.db
            filt = filt or db.filt
        else:
            self.db = db
        snap = self.sdb if self.sdb is not None else self.db
        if filt is None:
            if pca is not None:
                filt = PCAFilter(pca, low_dtype=snap.cfg.low_dtype)
            elif snap.filter_kind == "none":
                filt = IdentityFilter(dim=snap.high.shape[-1])
            else:
                raise ValueError("filt (or pca) is required when "
                                 "serving a frozen db with the "
                                 f"{snap.filter_kind!r} filter")
        self.filt = filt
        self.pca = filt.pca if isinstance(filt, PCAFilter) else pca
        self.batch = batch_size
        self.ef0 = ef0 or snap.cfg.ef0
        mut = self.index or self.sindex
        self.epoch = mut.epoch if mut else 0
        self._refresh_pad_row()
        # warm the compiled program, then reset stats so compile time
        # and the warmup batch never pollute QPS/latency percentiles
        self.stats = ServiceStats()
        dummy = np.zeros((batch_size, snap.high.shape[-1]), np.float32)
        self._run(dummy)
        self.stats = ServiceStats()

    def _refresh_pad_row(self):
        # pad row for underfull batches: the entry point's vector — its
        # search terminates in O(1) steps, so pad lanes never drag the
        # batch (padding with a caller query would re-run it); sharded:
        # shard 0's entry
        if self.sdb is not None:
            row = self.sdb.high[0, int(self.sdb.entries[0])]
        else:
            row = self.db.high[int(self.db.entry)]
        self._pad_row = np.asarray(row)[None].astype(np.float32)

    # ------------------------------------------------------------------
    # mutation (MutableIndex-backed services only)
    # ------------------------------------------------------------------

    def _swap(self):
        """Atomically publish the index's current epoch to the serving
        path (attribute assignment of an immutable snapshot)."""
        if self.sindex is not None:
            self.sdb = self.sindex.sdb
            self.epoch = self.sindex.epoch
        else:
            self.db = self.index.db
            self.epoch = self.index.epoch
        self._refresh_pad_row()

    @property
    def _mut(self):
        return self.index if self.index is not None else self.sindex

    def upsert(self, vectors: np.ndarray,
               ids: Optional[np.ndarray] = None) -> np.ndarray:
        """Insert (or, with ``ids``, replace) vectors; swaps the serving
        snapshot to the new epoch. Returns the new internal ids (GLOBAL
        ids on a sharded backend)."""
        if self._mut is None:
            raise RuntimeError("upsert() needs a mutable-index-backed "
                               "service (got a frozen snapshot)")
        new_ids = self._mut.upsert(np.asarray(vectors, np.float32),
                                   ids=ids)
        self.stats.upserts += len(new_ids)
        self._swap()
        return new_ids

    def delete(self, ids: np.ndarray) -> int:
        """Tombstone ids; deleted ids never appear in results from the
        swapped epoch onward. Returns the number newly deleted."""
        if self._mut is None:
            raise RuntimeError("delete() needs a mutable-index-backed "
                               "service (got a frozen snapshot)")
        n = self._mut.delete(ids)
        self.stats.deletes += n
        self._swap()
        return n

    # ------------------------------------------------------------------
    # query path
    # ------------------------------------------------------------------

    def _run(self, q: np.ndarray):
        qprep = self.filt.prepare(q)
        if self.sdb is not None:
            if self.mesh is not None:
                fd, fi = distributed_search(self.mesh, self.sdb,
                                            jnp.asarray(q),
                                            jnp.asarray(qprep),
                                            ef0=self.ef0)
            else:
                fd, fi = shard_search_host(self.sdb, jnp.asarray(q),
                                           jnp.asarray(qprep),
                                           ef0=self.ef0)
        else:
            fd, fi = search_batched(self.db, jnp.asarray(q),
                                    jnp.asarray(qprep), ef0=self.ef0)
        return np.asarray(fd), np.asarray(fi)

    def query(self, q: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """q: [n, D] with n <= batch_size; underfull batches are padded
        with the entry point. Returns (dists, indices) for the n real
        queries; only those count toward stats."""
        n = len(q)
        t0 = time.monotonic()
        if n < self.batch:
            pad = np.broadcast_to(self._pad_row,
                                  (self.batch - n, q.shape[1]))
            q = np.concatenate([q, pad], axis=0)
        fd, fi = self._run(q)
        dt = (time.monotonic() - t0) * 1000.0
        self.stats.queries += n
        self.stats.latencies_ms.extend([dt] * n)
        return fd[:n], fi[:n]

    def run_stream(self, queries: np.ndarray) -> Tuple[np.ndarray, dict]:
        """Serve a stream in service batches; returns (all indices, stats)."""
        outs = []
        for i in range(0, len(queries), self.batch):
            _, fi = self.query(queries[i:i + self.batch])
            outs.append(fi)
        return np.concatenate(outs, axis=0), {
            "qps": self.stats.qps,
            "p50_ms": self.stats.percentile(50),
            "p99_ms": self.stats.percentile(99),
        }
