"""Batched pHNSW vector-search service — the serving half of the paper's
system (single-query ASIC -> batched TPU service).

Requests accumulate into fixed-size batches (the compiled search program
has a static batch dim); underfull batches are padded with the entry
point and results trimmed. Tracks QPS and latency percentiles (over a
fixed-size window — a long-running service holds constant memory).

Backed by any of four snapshots behind one API:

  * a frozen ``PackedDB`` (read-only single-shard serving, the seed
    behavior) or a ``MutableIndex`` (live single-shard serving);
  * a frozen ``ShardedDB`` (read-only SHARDED serving) or a
    ``ShardedMutableIndex`` (live sharded serving) — results carry
    GLOBAL ids; pass ``mesh=`` to run the collective path on real
    devices, else the bit-equal single-device shard loop serves.

``upsert`` / ``delete`` (mutable backends) mutate the index and
atomically swap the published epoch's device snapshot under the running
service. The swap is a plain attribute assignment of an immutable
snapshot value — in-flight batches finish on the epoch they started on,
the next batch sees the new one, and in steady state no shape changes,
so the compiled program is reused across the swap (zero recompiles).
The NON-steady-state events that do recompile — capacity doubling
(pre-pay with ``reserve``) and an insert drawing a level above the
current top layer — are each O(log N) over an index's lifetime; the
sharded index additionally renumbers global ids on growth; see
DESIGN.md § Mutable index / § Sharded serving.

**Fault tolerance** (DESIGN.md § Fault tolerance): pass a
``FaultPolicy`` to serve a sharded backend resiliently — each shard is
probed individually (``core.distributed.probe_shard``), failures get
bounded exponential-backoff retries inside a per-request deadline
budget, per-shard wall times feed a median+MAD straggler monitor,
repeated failures mark a shard dead (skipped until ``recover_shard``),
and the request completes DEGRADED from whichever shards answered —
results then carry exact ``coverage`` accounting via
``query(..., return_stats=True)``. All of it is data-masked over the
same compiled programs: a kill/recover cycle never recompiles.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Optional, Tuple, Union

import numpy as np
import jax.numpy as jnp

from repro.core.distributed import (ShardedDB, _normalize,
                                    check_shard_result, distributed_search,
                                    merge_surviving, probe_shard,
                                    shard_live_counts, shard_search_host)
from repro.core.filters import FilterSpec, IdentityFilter, PCAFilter
from repro.core.pca import PCA
from repro.core.search_jax import PackedDB, search_batched
from repro.distributed import faults as faults_mod
from repro.distributed.faults import (AllShardsDeadError, FaultPolicy,
                                      ShardCorruptError, ShardFaultError,
                                      ShardHealth)
from repro.index import MutableIndex, ShardedMutableIndex

# latency reservoir size: big enough for stable p99 estimates, small
# enough that a service serving forever holds constant memory
LATENCY_WINDOW = 4096


@dataclass
class ServiceStats:
    """Rolling serving statistics. ``latencies_ms`` is a bounded deque
    (maxlen ``LATENCY_WINDOW``) — ``percentile()`` reads the most
    recent window, counters are exact totals."""
    latencies_ms: Deque[float] = field(
        default_factory=lambda: deque(maxlen=LATENCY_WINDOW))
    queries: int = 0
    upserts: int = 0
    deletes: int = 0
    degraded_queries: int = 0
    started: float = field(default_factory=time.monotonic)

    @property
    def qps(self) -> float:
        return self.queries / max(time.monotonic() - self.started, 1e-9)

    def percentile(self, p: float) -> float:
        if not self.latencies_ms:
            return 0.0
        return float(np.percentile(np.asarray(self.latencies_ms), p))


class VectorSearchService:
    def __init__(self, db: Union[PackedDB, MutableIndex, ShardedDB,
                                 ShardedMutableIndex],
                 pca: Optional[PCA] = None, *, batch_size: int = 64,
                 ef0: Optional[int] = None,
                 filt: Optional[FilterSpec] = None, mesh=None,
                 nan_policy: str = "raise",
                 fault_policy: Optional[FaultPolicy] = None):
        """``filt`` (any ``core.filters.FilterSpec``) generalizes the
        seed's ``pca`` argument; mutable indexes bring their own filter.
        A frozen identity-filter db needs neither. Sharded backends
        (``ShardedDB`` / ``ShardedMutableIndex``) serve GLOBAL ids;
        ``mesh`` selects the collective path (single-device shard loop
        otherwise — bit-equal).

        ``nan_policy``: what to do with NaN/Inf entries in queries and
        upserts — ``"raise"`` (default, a clear ValueError at the API
        boundary instead of silent mis-serving) or ``"sanitize"``
        (zero them).

        ``fault_policy`` (sharded backends, host path) turns on the
        resilient per-shard query loop: retry/deadline/straggler
        handling plus degraded-mode completion — see the module
        docstring."""
        self.index: Optional[MutableIndex] = None
        self.sindex: Optional[ShardedMutableIndex] = None
        self.sdb: Optional[ShardedDB] = None
        self.db: Optional[PackedDB] = None
        self.mesh = mesh
        if nan_policy not in ("raise", "sanitize"):
            raise ValueError(f"nan_policy must be 'raise' or 'sanitize', "
                             f"got {nan_policy!r}")
        self.nan_policy = nan_policy
        if isinstance(db, ShardedMutableIndex):
            self.sindex = db
            self.sdb = db.sdb
            filt = filt or db.filt
        elif isinstance(db, ShardedDB):
            self.sdb = db
        elif isinstance(db, MutableIndex):
            self.index = db
            self.db = db.db
            filt = filt or db.filt
        else:
            self.db = db
        snap = self.sdb if self.sdb is not None else self.db
        if filt is None:
            if pca is not None:
                filt = PCAFilter(pca, low_dtype=snap.cfg.low_dtype)
            elif snap.filter_kind == "none":
                filt = IdentityFilter(dim=snap.high.shape[-1])
            else:
                raise ValueError("filt (or pca) is required when "
                                 "serving a frozen db with the "
                                 f"{snap.filter_kind!r} filter")
        self.filt = filt
        self.pca = filt.pca if isinstance(filt, PCAFilter) else pca
        self.batch = batch_size
        self.ef0 = ef0 or snap.cfg.ef0
        self._dim = int(snap.high.shape[-1])
        mut = self.index or self.sindex
        self.epoch = mut.epoch if mut else 0
        self.fault_policy = fault_policy
        self.health: Optional[ShardHealth] = None
        if fault_policy is not None:
            if self.sdb is None:
                raise ValueError("fault_policy needs a sharded backend "
                                 "(ShardedDB / ShardedMutableIndex) — "
                                 "single-shard redundancy is the "
                                 "ReplicaSet's job")
            if mesh is not None:
                raise ValueError("fault_policy drives the per-shard "
                                 "host path; it cannot be combined "
                                 "with mesh=")
            self.health = ShardHealth(self.sdb.n_shards, fault_policy)
        self.last_stats = {"coverage": 1.0, "degraded": False}
        self._refresh_pad_row()
        self._refresh_live_counts()
        # warm the compiled program, then reset stats so compile time
        # and the warmup batch never pollute QPS/latency percentiles
        self.stats = ServiceStats()
        dummy = np.zeros((batch_size, snap.high.shape[-1]), np.float32)
        self._run(dummy)
        self.stats = ServiceStats()

    def _refresh_pad_row(self):
        # pad row for underfull batches: the entry point's vector — its
        # search terminates in O(1) steps, so pad lanes never drag the
        # batch (padding with a caller query would re-run it); sharded:
        # shard 0's entry
        if self.sdb is not None:
            row = self.sdb.high[0, int(self.sdb.entries[0])]
        else:
            row = self.db.high[int(self.db.entry)]
        self._pad_row = np.asarray(row)[None].astype(np.float32)

    def _refresh_live_counts(self):
        """Host cache of per-shard live populations (the ``coverage``
        denominators) + ownership spans — refreshed on every epoch
        swap, read per degraded request."""
        if self.sdb is not None:
            self._live_counts = shard_live_counts(self.sdb)
            self._offsets_np = np.asarray(self.sdb.offsets, np.int64)
            self._counts_np = np.asarray(self.sdb.counts, np.int64)

    # ------------------------------------------------------------------
    # input validation (the API boundary: clear errors here instead of
    # shape/dtype explosions deep inside jit, or NaN mis-serving)
    # ------------------------------------------------------------------

    def _validate_vectors(self, a, what: str, *, dim: Optional[int] = None
                          ) -> np.ndarray:
        a = np.asarray(a)
        if a.dtype == object or not (np.issubdtype(a.dtype, np.floating)
                                     or np.issubdtype(a.dtype, np.integer)):
            raise ValueError(f"{what} must be numeric, got dtype "
                             f"{a.dtype}")
        dim = self._dim if dim is None else dim
        if a.ndim != 2 or a.shape[1] != dim:
            raise ValueError(f"{what} must be [n, {dim}], got shape "
                             f"{a.shape}")
        if len(a) == 0:
            raise ValueError(f"empty {what} batch")
        a = a.astype(np.float32, copy=False)
        finite = np.isfinite(a)
        if not finite.all():
            if self.nan_policy == "sanitize":
                a = np.where(finite, a, np.float32(0.0))
            else:
                raise ValueError(
                    f"{what} contain {int((~finite).sum())} non-finite "
                    f"(NaN/Inf) values; construct the service with "
                    f"nan_policy='sanitize' to zero them instead")
        return a

    def _validate_queries(self, q) -> np.ndarray:
        q = self._validate_vectors(q, "queries")
        if len(q) > self.batch:
            raise ValueError(
                f"{len(q)} queries exceed batch_size={self.batch}; "
                f"use run_stream() to serve in batches")
        return q

    # ------------------------------------------------------------------
    # mutation (MutableIndex-backed services only)
    # ------------------------------------------------------------------

    def _swap(self):
        """Atomically publish the index's current epoch to the serving
        path (attribute assignment of an immutable snapshot)."""
        if self.sindex is not None:
            self.sdb = self.sindex.sdb
            self.epoch = self.sindex.epoch
        else:
            self.db = self.index.db
            self.epoch = self.index.epoch
        self._refresh_pad_row()
        self._refresh_live_counts()

    @property
    def _mut(self):
        return self.index if self.index is not None else self.sindex

    def upsert(self, vectors: np.ndarray,
               ids: Optional[np.ndarray] = None) -> np.ndarray:
        """Insert (or, with ``ids``, replace) vectors; swaps the serving
        snapshot to the new epoch. Returns the new internal ids (GLOBAL
        ids on a sharded backend)."""
        if self._mut is None:
            raise RuntimeError("upsert() needs a mutable-index-backed "
                               "service (got a frozen snapshot)")
        vectors = self._validate_vectors(vectors, "upsert vectors")
        if ids is not None:
            ids = np.atleast_1d(np.asarray(ids))
            if not np.issubdtype(ids.dtype, np.integer):
                raise ValueError(f"ids must be integers, got dtype "
                                 f"{ids.dtype}")
            if len(ids) != len(vectors):
                raise ValueError(f"{len(ids)} ids for {len(vectors)} "
                                 f"vectors")
        new_ids = self._mut.upsert(vectors, ids=ids)
        self.stats.upserts += len(new_ids)
        self._swap()
        return new_ids

    def delete(self, ids: np.ndarray) -> int:
        """Tombstone ids; deleted ids never appear in results from the
        swapped epoch onward. Returns the number newly deleted."""
        if self._mut is None:
            raise RuntimeError("delete() needs a mutable-index-backed "
                               "service (got a frozen snapshot)")
        n = self._mut.delete(ids)
        self.stats.deletes += n
        self._swap()
        return n

    # ------------------------------------------------------------------
    # query path
    # ------------------------------------------------------------------

    def _run(self, q: np.ndarray):
        if self.health is not None:
            return self._run_resilient(q)
        qprep = self.filt.prepare(q)
        if self.sdb is not None:
            if self.mesh is not None:
                fd, fi = distributed_search(self.mesh, self.sdb,
                                            jnp.asarray(q),
                                            jnp.asarray(qprep),
                                            ef0=self.ef0)
            else:
                fd, fi = shard_search_host(self.sdb, jnp.asarray(q),
                                           jnp.asarray(qprep),
                                           ef0=self.ef0)
        else:
            fd, fi = search_batched(self.db, jnp.asarray(q),
                                    jnp.asarray(qprep), ef0=self.ef0)
        return np.asarray(fd), np.asarray(fi)

    def _coverage(self, answered: np.ndarray) -> float:
        lc = self._live_counts
        return int(lc[answered].sum()) / max(int(lc.sum()), 1)

    def _run_resilient(self, q: np.ndarray):
        """The fault-tolerant sharded query loop: probe every non-dead
        shard individually (bounded retry + exponential backoff inside
        the per-request deadline budget), validate each answer at the
        merge boundary, feed wall times to the per-shard straggler
        monitor, then complete the request from whichever shards
        answered (degraded when any didn't)."""
        pol = self.fault_policy
        sdb = self.sdb
        Pn = sdb.n_shards
        plan = faults_mod.active()
        if plan is not None:
            plan.tick()
        qd = jnp.asarray(q)
        qp = jnp.asarray(self.filt.prepare(q))
        ef0, _, deferred, rm = _normalize(sdb, self.ef0, None, None, None)
        E = ef0 * rm if deferred else ef0
        fd_all = np.zeros((Pn, len(q), E), np.float32)
        gi_all = np.full((Pn, len(q), E), -1, np.int32)
        answered = np.zeros(Pn, bool)
        deadline = time.monotonic() + pol.deadline_ms / 1e3
        for s in range(Pn):
            if self.health.dead[s]:
                continue
            for attempt in range(pol.max_retries + 1):
                if attempt and time.monotonic() >= deadline:
                    break     # retry budget spent: serve degraded
                try:
                    fd, gi, wall = probe_shard(sdb, s, qd, qp,
                                               ef0=self.ef0)
                    if not check_shard_result(
                            fd, gi, int(self._offsets_np[s]),
                            int(self._counts_np[s])):
                        raise ShardCorruptError(
                            f"shard {s} failed the merge-boundary "
                            f"integrity check")
                    self.health.heartbeat(s, wall)
                    fd_all[s], gi_all[s] = fd, gi
                    answered[s] = True
                    break
                except ShardFaultError as e:
                    if self.health.failure(s, e):
                        break   # marked dead: stop retrying it
                    pause = min(pol.backoff_ms * (2 ** attempt) / 1e3,
                                max(deadline - time.monotonic(), 0.0))
                    if pause > 0:
                        time.sleep(pause)
        if not answered.any():
            raise AllShardsDeadError(
                f"no shard of {Pn} answered within the "
                f"{pol.deadline_ms:.0f}ms budget")
        fd, fi = merge_surviving(sdb, fd_all, gi_all, answered, qd,
                                 ef0=self.ef0)
        degraded = bool(~answered.all())
        self.last_stats = {
            "coverage": self._coverage(answered),
            "degraded": degraded,
            "live_shards": int(answered.sum()),
            "n_shards": Pn,
            "answered": answered,
        }
        if degraded:
            self.stats.degraded_queries += 1
        return np.asarray(fd), np.asarray(fi)

    def recover_shard(self, s: int) -> None:
        """Clear a shard's dead mark after the underlying fault healed
        (operator action / fault-plan heal): the next request probes it
        again — on the SAME compiled programs (recovery is data)."""
        if self.health is None:
            raise RuntimeError("recover_shard() needs a fault_policy-"
                               "enabled service")
        self.health.recover(s)

    def query(self, q: np.ndarray, *, return_stats: bool = False
              ) -> Tuple[np.ndarray, ...]:
        """q: [n, D] with n <= batch_size; underfull batches are padded
        with the entry point. Returns (dists, indices) for the n real
        queries; only those count toward stats. With ``return_stats``
        a third element reports this request's serving health:
        ``coverage`` (fraction of live vectors reachable — exact),
        ``degraded``, and ``latency_ms``."""
        q = self._validate_queries(q)
        n = len(q)
        t0 = time.monotonic()
        if n < self.batch:
            pad = np.broadcast_to(self._pad_row,
                                  (self.batch - n, q.shape[1]))
            q = np.concatenate([q, pad], axis=0)
        fd, fi = self._run(q)
        dt = (time.monotonic() - t0) * 1000.0
        self.stats.queries += n
        self.stats.latencies_ms.extend([dt] * n)
        if return_stats:
            return fd[:n], fi[:n], {**self.last_stats,
                                    "latency_ms": dt}
        return fd[:n], fi[:n]

    def run_stream(self, queries: np.ndarray) -> Tuple[np.ndarray, dict]:
        """Serve a stream in service batches; returns (all indices, stats)."""
        outs = []
        for i in range(0, len(queries), self.batch):
            _, fi = self.query(queries[i:i + self.batch])
            outs.append(fi)
        return np.concatenate(outs, axis=0), {
            "qps": self.stats.qps,
            "p50_ms": self.stats.percentile(50),
            "p99_ms": self.stats.percentile(99),
        }
