"""Batched pHNSW vector-search service — the serving half of the paper's
system (single-query ASIC -> batched TPU service).

Requests accumulate into fixed-size batches (the compiled search program
has a static batch dim); underfull batches are padded with the entry
point and results trimmed. Tracks QPS and latency percentiles.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core.pca import PCA
from repro.core.search_jax import PackedDB, search_batched


@dataclass
class ServiceStats:
    latencies_ms: List[float] = field(default_factory=list)
    queries: int = 0
    started: float = field(default_factory=time.monotonic)

    @property
    def qps(self) -> float:
        return self.queries / max(time.monotonic() - self.started, 1e-9)

    def percentile(self, p: float) -> float:
        if not self.latencies_ms:
            return 0.0
        return float(np.percentile(self.latencies_ms, p))


class VectorSearchService:
    def __init__(self, db: PackedDB, pca: PCA, *, batch_size: int = 64,
                 ef0: Optional[int] = None):
        self.db, self.pca = db, pca
        self.batch = batch_size
        self.ef0 = ef0 or db.cfg.ef0
        # pad row for underfull batches: the entry point's vector — its
        # search terminates in O(1) steps, so pad lanes never drag the
        # batch (padding with a caller query would re-run it)
        self._pad_row = np.asarray(db.high[db.entry])[None].astype(
            np.float32)
        # warm the compiled program, then reset stats so compile time
        # and the warmup batch never pollute QPS/latency percentiles
        self.stats = ServiceStats()
        dummy = np.zeros((batch_size, db.high.shape[1]), np.float32)
        self._run(dummy)
        self.stats = ServiceStats()

    def _run(self, q: np.ndarray):
        ql = self.pca.transform(q).astype(np.float32)
        fd, fi = search_batched(self.db, jnp.asarray(q), jnp.asarray(ql),
                                ef0=self.ef0)
        return np.asarray(fd), np.asarray(fi)

    def query(self, q: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """q: [n, D] with n <= batch_size; underfull batches are padded
        with the entry point. Returns (dists, indices) for the n real
        queries; only those count toward stats."""
        n = len(q)
        t0 = time.monotonic()
        if n < self.batch:
            pad = np.broadcast_to(self._pad_row,
                                  (self.batch - n, q.shape[1]))
            q = np.concatenate([q, pad], axis=0)
        fd, fi = self._run(q)
        dt = (time.monotonic() - t0) * 1000.0
        self.stats.queries += n
        self.stats.latencies_ms.extend([dt] * n)
        return fd[:n], fi[:n]

    def run_stream(self, queries: np.ndarray) -> Tuple[np.ndarray, dict]:
        """Serve a stream in service batches; returns (all indices, stats)."""
        outs = []
        for i in range(0, len(queries), self.batch):
            _, fi = self.query(queries[i:i + self.batch])
            outs.append(fi)
        return np.concatenate(outs, axis=0), {
            "qps": self.stats.qps,
            "p50_ms": self.stats.percentile(50),
            "p99_ms": self.stats.percentile(99),
        }
