"""Replica groups with failover and snapshot-shipping recovery
(DESIGN.md § Fault tolerance; ROADMAP item 3's "replica groups with
snapshot shipping + failover on top of the epoch-versioned atomic
swap").

A ``ReplicaSet`` holds N ``VectorSearchService`` replicas of the SAME
logical index behind one query/upsert/delete API:

* **Health-checked routing.** Queries go to the preferred (primary)
  replica; a replica that raises a serving-plane ``FaultError`` (or is
  killed by the installed ``FaultPlan``) is marked dead and the SAME
  request fails over to the next healthy replica — callers never see a
  replica die, only (at worst) degraded coverage.
* **Replicated mutation with an op log.** Every upsert/delete gets a
  monotonically increasing sequence number, is appended to a bounded
  op log, and applied to every healthy replica. Ids converge because
  inserts are deterministic (round-robin shard assignment + arange
  local slots) and every replica sees the same op order.
* **Snapshot shipping + idempotent re-publish.** Recovery re-seeds a
  dead replica from a healthy donor's checksummed npz snapshot
  (``MutableIndex.save`` / ``ShardedMutableIndex.save`` — a corrupt
  ship raises the typed ``SnapshotCorruptError`` instead of serving
  garbage), then replays the op-log tail the snapshot predates. Replay
  is idempotent: each replica tracks ``applied_seq`` and skips any op
  it already absorbed, so re-delivering the whole log is always safe
  (the re-publish protocol needs no careful cut point).

The replicas' graphs may differ microscopically after a recovery (each
replica's insert rng walks its own path once histories diverge — HNSW
is stochastic by construction); what converges is the STATE that
defines correct serving: the live id -> vector map, tombstones, and
``applied_seq``. ``assert_converged`` checks exactly that.
"""
from __future__ import annotations

import tempfile
from collections import deque
from dataclasses import dataclass
from pathlib import Path
from typing import Deque, List, Optional, Tuple

import numpy as np

from repro.distributed import faults as faults_mod
from repro.distributed.faults import AllReplicasDeadError, FaultError
from repro.obs.trace import NULL_TRACER, Tracer
from repro.serve.vector_service import VectorSearchService


@dataclass
class ReplicaState:
    svc: VectorSearchService
    alive: bool = True
    applied_seq: int = 0
    reseeds: int = 0


@dataclass
class _Op:
    kind: str                 # "upsert" | "delete"
    seq: int
    vectors: Optional[np.ndarray] = None
    ids: Optional[np.ndarray] = None


class ReplicaSet:
    """N replicas of one logical vector-search service: failover
    queries, replicated mutations, snapshot-shipped recovery."""

    def __init__(self, services: List[VectorSearchService], *,
                 snapshot_dir=None, oplog_capacity: int = 4096,
                 tracer: Optional[Tracer] = None):
        assert len(services) >= 1
        self.replicas = [ReplicaState(svc=s) for s in services]
        self.seq = 0
        self.oplog: Deque[_Op] = deque(maxlen=oplog_capacity)
        self.snapshot_dir = Path(snapshot_dir) if snapshot_dir \
            else Path(tempfile.mkdtemp(prefix="phnsw_replicas_"))
        self._primary = 0
        # (event, replica, detail) — failover/recovery observability
        self.events: List[Tuple[str, int, str]] = []
        # per-request span trees (failover decisions, snapshot shipping,
        # oplog replay) — disabled by default, zero hot-path cost
        self.tracer = tracer if tracer is not None else NULL_TRACER

    @classmethod
    def replicate(cls, svc: VectorSearchService, n: int, *,
                  snapshot_dir=None, seed: int = 0,
                  oplog_capacity: int = 4096) -> "ReplicaSet":
        """Clone one mutable-backed service into an N-replica set via
        the snapshot path — each replica gets its OWN index value (no
        shared mutable state), loaded with the same rng seed so
        replicas that live through the same op history stay
        convergent."""
        if svc._mut is None:
            raise ValueError("replicate() needs a mutable-index-backed "
                             "service (frozen snapshots cannot absorb "
                             "replicated mutations)")
        rs = cls([svc], snapshot_dir=snapshot_dir,
                 oplog_capacity=oplog_capacity)
        path = rs.snapshot_dir / "seed.npz"
        svc._mut.save(path)
        for _ in range(n - 1):
            rs.replicas.append(ReplicaState(
                svc=rs._service_from_snapshot(path, like=svc, seed=seed)))
        return rs

    def _service_from_snapshot(self, path, *, like: VectorSearchService,
                               seed: int = 0) -> VectorSearchService:
        """Load a snapshot and wrap it in a service with the SAME
        serving knobs as ``like`` (batch shape parity keeps the
        compiled programs shared — a re-seed never recompiles)."""
        from repro.index import MutableIndex, ShardedMutableIndex
        cfg = like._mut.cfg
        idx_cls = ShardedMutableIndex if like.sindex is not None \
            else MutableIndex
        idx = idx_cls.load(path, cfg, seed=seed)
        return VectorSearchService(
            idx, batch_size=like.batch, ef0=like.ef0,
            nan_policy=like.nan_policy,
            fault_policy=like.fault_policy, mesh=like.mesh)

    # ------------------------------------------------------------------
    # health / routing
    # ------------------------------------------------------------------

    @property
    def n_alive(self) -> int:
        return sum(r.alive for r in self.replicas)

    def _mark_dead(self, i: int, reason: str) -> None:
        if self.replicas[i].alive:
            self.replicas[i].alive = False
            self.events.append(("dead", i, reason))

    def _healthy_order(self):
        """Replica indices starting at the primary, wrapping — the
        failover probe order."""
        n = len(self.replicas)
        for d in range(n):
            i = (self._primary + d) % n
            if self.replicas[i].alive:
                yield i

    def _check_injected_death(self, i: int) -> bool:
        plan = faults_mod.active()
        if plan is not None and plan.replica_dead(i):
            self._mark_dead(i, f"killed by fault plan at t={plan.t}")
            return True
        return False

    # ------------------------------------------------------------------
    # query (failover)
    # ------------------------------------------------------------------

    def query(self, q: np.ndarray, *, return_stats: bool = False):
        """Serve from the primary, failing over through the healthy
        replicas on any serving-plane ``FaultError`` — the caller's
        request survives every failure short of total loss
        (``AllReplicasDeadError``). With a tracer, the request's span
        tree records each failover hop and parents the serving
        replica's ``serve.query`` span."""
        root = self.tracer.span("replica.query",
                                primary=self._primary)
        with root:
            last: Optional[Exception] = None
            for i in self._healthy_order():
                if self._check_injected_death(i):
                    root.event("replica_dead", replica=i,
                               detail="killed by fault plan")
                    continue
                r = self.replicas[i]
                try:
                    out = r.svc.query(
                        q, return_stats=return_stats,
                        span=root if root.enabled else None)
                except FaultError as e:
                    self._mark_dead(i, repr(e))
                    root.event("replica_dead", replica=i,
                               detail=repr(e))
                    last = e
                    continue
                if i != self._primary:
                    self.events.append(("failover", i,
                                        f"primary -> {i}"))
                    root.event("failover", from_replica=self._primary,
                               to_replica=i)
                    self._primary = i
                root.set(served_by=i)
                return out
            raise AllReplicasDeadError(
                f"all {len(self.replicas)} replicas dead"
                + (f" (last: {last!r})" if last else ""))

    # ------------------------------------------------------------------
    # replicated mutation (op log, seq-numbered, idempotent delivery)
    # ------------------------------------------------------------------

    _SKIPPED = object()        # _apply sentinel: op already absorbed

    def _apply(self, r: ReplicaState, op: _Op):
        """Deliver one op to one replica; skips ops the replica already
        absorbed (``seq <= applied_seq`` — THE idempotence that makes
        blanket re-publish safe). Returns the op's result, or
        ``_SKIPPED``."""
        if op.seq <= r.applied_seq:
            return self._SKIPPED
        if op.kind == "upsert":
            out = r.svc.upsert(op.vectors, ids=op.ids)
        else:
            out = r.svc.delete(op.ids)
        r.applied_seq = op.seq
        return out

    def _mutate(self, op: _Op):
        """Append to the op log and deliver to every healthy replica;
        a replica that cannot absorb the op is marked dead (it would
        fall behind silently otherwise) until a snapshot re-seed
        brings it back. Returns the first healthy replica's result
        (identical everywhere — deterministic op application)."""
        self.oplog.append(op)
        result, got = None, False
        for i, r in enumerate(self.replicas):
            if not r.alive or self._check_injected_death(i):
                continue
            try:
                out = self._apply(r, op)
                if not got and out is not self._SKIPPED:
                    result, got = out, True
            except FaultError as e:
                self._mark_dead(i, f"mutation failed: {e!r}")
        if not got:
            # total failure: NO replica absorbed the op, and the caller
            # sees an exception — the op never happened. Un-log it so a
            # later recovery cannot replay a mutation the client was
            # told failed (which would diverge the recovered replica
            # from the survivors).
            self.oplog.pop()
            self.seq = op.seq - 1
            raise AllReplicasDeadError(
                f"no healthy replica to apply {op.kind} seq={op.seq}")
        return result

    def upsert(self, vectors: np.ndarray,
               ids: Optional[np.ndarray] = None) -> np.ndarray:
        """Replicated upsert. Returns the new ids — identical on every
        healthy replica (round-robin shard assignment + arange local
        slots are deterministic in op order)."""
        self.seq += 1
        return self._mutate(_Op(
            "upsert", self.seq, vectors=np.asarray(vectors, np.float32),
            ids=None if ids is None else np.asarray(ids)))

    def delete(self, ids: np.ndarray) -> int:
        """Replicated delete. Returns the newly-deleted count."""
        self.seq += 1
        return self._mutate(_Op("delete", self.seq,
                                ids=np.asarray(ids)))

    # ------------------------------------------------------------------
    # snapshot shipping + recovery
    # ------------------------------------------------------------------

    def checkpoint(self, *, span=None) -> Tuple[Path, int]:
        """Ship a snapshot from the healthiest donor: returns
        (path, applied_seq at save time). Recovery from a STALE
        checkpoint is exactly as correct as from a fresh one — the
        op-log replay covers the gap (idempotently)."""
        cs = (span.child("replica.checkpoint") if span is not None and
              span.enabled else self.tracer.span("replica.checkpoint"))
        with cs:
            for i in self._healthy_order():
                donor = self.replicas[i]
                path = self.snapshot_dir / \
                    f"ckpt_seq{donor.applied_seq}_r{i}.npz"
                donor.svc._mut.save(path)
                self.events.append(("checkpoint", i,
                                    f"seq={donor.applied_seq}"))
                cs.set(donor=i, seq=donor.applied_seq)
                return path, donor.applied_seq
            raise AllReplicasDeadError(
                "no healthy donor to checkpoint from")

    def recover(self, i: int, *, snapshot: Optional[Path] = None,
                snapshot_seq: Optional[int] = None) -> int:
        """Re-seed replica ``i``: load a donor snapshot (fresh one
        shipped now unless a ``snapshot``/``snapshot_seq`` checkpoint
        is given), then re-publish the op log — ops the snapshot
        already contains are skipped by seq (idempotent), ops after it
        replay. Returns the number of ops replayed. The replica serves
        again immediately after. With a tracer the recovery's span
        tree times the snapshot ship and the oplog replay separately."""
        root = self.tracer.span("replica.recover", replica=i)
        with root:
            if snapshot is None:
                snapshot, snapshot_seq = self.checkpoint(span=root)
            assert snapshot_seq is not None
            r = self.replicas[i]
            donor_like = None
            for j in self._healthy_order():
                donor_like = self.replicas[j].svc
                break
            if donor_like is None:
                raise AllReplicasDeadError(
                    "no healthy replica to model the recovered "
                    "service on")
            with root.child("snapshot.ship",
                            seq=int(snapshot_seq)) as ship:
                r.svc = self._service_from_snapshot(snapshot,
                                                    like=donor_like)
                ship.set(path=str(snapshot))
            r.applied_seq = snapshot_seq
            r.alive = True
            r.reseeds += 1
            with root.child("oplog.replay") as rep:
                replayed = self.republish(i)
                rep.set(n_replayed=replayed,
                        log_len=len(self.oplog))
            self.events.append(("recovered", i,
                                f"seq={snapshot_seq}+{replayed} replayed"))
            root.set(replayed=replayed)
        return replayed

    def republish(self, i: int) -> int:
        """Deliver the WHOLE op log to replica ``i``; already-applied
        ops are skipped by seq. Safe to call any number of times —
        this idempotence is what lets a recovering replica converge
        without coordinating a precise log cut."""
        r = self.replicas[i]
        n = 0
        for op in list(self.oplog):
            if self._apply(r, op) is not self._SKIPPED:
                n += 1
        return n

    # ------------------------------------------------------------------
    # convergence accounting
    # ------------------------------------------------------------------

    def assert_converged(self) -> dict:
        """Verify every healthy replica agrees on the serving STATE:
        applied_seq, live id set, and the id -> vector map. Returns a
        small report; raises AssertionError on divergence."""
        healthy = [r for r in self.replicas if r.alive]
        assert healthy, "no healthy replicas to compare"
        ref = healthy[0]
        ref_ids = ref.svc._mut.live_ids()
        for r in healthy[1:]:
            assert r.applied_seq == ref.applied_seq, \
                (r.applied_seq, ref.applied_seq)
            ids = r.svc._mut.live_ids()
            np.testing.assert_array_equal(ids, ref_ids)
            np.testing.assert_array_equal(_live_vectors(r.svc),
                                          _live_vectors(ref.svc))
        return {"n_healthy": len(healthy),
                "applied_seq": ref.applied_seq,
                "n_live": int(len(ref_ids))}


def _live_vectors(svc: VectorSearchService) -> np.ndarray:
    """The live id -> vector map of a service's mutable index, in live
    id order (the convergence invariant replicas must agree on)."""
    mut = svc._mut
    if svc.sindex is not None:
        stride = mut.stride
        gids = mut.live_global_ids()
        return np.stack([mut.shards[g // stride].x[g % stride]
                         for g in gids])
    return mut.x[mut.live_ids()]
