"""Continuous-batching serving front-end (DESIGN.md § Serving
front-end).

The synchronous ``VectorSearchService.query`` convoy: every query in a
B=64 batch waits for the SLOWEST traverser (steps_max ~31 vs steps_mean
~17.5 in the tracked bench), and an underfull request pads dead lanes
on top. This scheduler replaces batch-at-a-time execution with a
fixed-slot continuously-batched loop over the resumable slotted search
state (``core.search_jax.SlotState``):

  * a bounded request QUEUE admits single queries (ragged, mixed-k
    traffic — each request carries its own k and deadline);
  * each ``tick`` (1) swaps admitted queries into free slots as PURE
    DATA (``_slot_admit_jit`` — a fixed-width scatter, so admission
    never recompiles), (2) advances every live slot by up to
    ``quantum`` expansion iterations (``_slot_step_jit`` — the same
    layer-0 body the synchronous search compiles; slots are allocated
    low-first and the tick runs the smallest compiled WIDTH-LADDER
    prefix covering the highest live slot, so a lightly-loaded bank
    costs a small batch, not a full one), and (3) RETIRES
    slots whose per-query ``done`` mask latched, delivering answers
    out-of-order as queries individually converge — slot occupancy
    stays high instead of draining to the convoy;
  * per-query ADAPTIVE STEP BUDGETS: a fresh query starts at the p50
    of the observed per-query step distribution (the obs plane's
    ``phnsw_sched_slot_steps`` histogram) and unconverged queries
    escalate (budget doubling, counted on the obs plane) up to the
    static bound — the common fast query retires early, the rare deep
    one still converges exactly (bit-equal to the fixed-budget
    program: a budget-frozen slot keeps its frontier intact and
    resumes where it froze);
  * per-slot EFFECTIVE ef (``ef_eff = clamp(max(k, ef_policy)) <=
    compiled EF``) serves mixed-k traffic from one compiled program;
  * SLO-aware ADMISSION CONTROL: the queue is bounded (overflow sheds
    at submit) and deadline-expired requests shed at admission instead
    of burning slots — shed counters by reason, queue-depth and
    occupancy gauges, and escalation counters all land on the service
    registry.

Sharded backends run the vmapped per-shard twins over the stacked
ShardedDB view; retirement requires the done latch on every LIVE shard
and merges the disjoint per-shard lists host-side (stable sort: lower
shard then lower slot — the ``_merge_lists`` tie-break). Degraded mode
is the same live-mask data discipline as the resilient path: dead
shards (``ShardHealth`` when the service carries a fault policy) are
excluded from both the done gate and the merge, and completions carry
exact coverage accounting.

Zero steady-state recompiles by construction: admission, retirement,
budget escalation, epoch swaps, and kill/recover cycles are all data;
``cache_sizes()`` (= ``search_jax.slot_cache_sizes``) backs the
regression tests.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional

import dataclasses as _dc

import numpy as np
import jax.numpy as jnp

from repro.core import search_jax as sj
from repro.core.distributed import stacked_db_view


class SchedulerUnsupported(RuntimeError):
    """The service's configuration has no slotted program (mesh
    collectives, sharded deferred re-ranking): callers fall back to
    the synchronous ``run_stream_sync``."""


@dataclass
class _Pending:
    rid: int
    k: int
    ef_eff: int
    t_submit: float
    t_sched: float                 # scheduled arrival (open-loop start)
    deadline: Optional[float]      # monotonic seconds, None = none
    q: Optional[np.ndarray]        # [D]; dropped once admitted


@dataclass
class Completion:
    """One retired query. ``ids``/``dists`` are the top-``k`` answer
    (GLOBAL ids on sharded backends). ``forced`` marks a query retired
    at the static step bound without latching ``done`` (exactly what
    the synchronous program would have returned for it)."""
    rid: int
    ids: np.ndarray
    dists: np.ndarray
    latency_ms: float
    steps: int
    forced: bool = False
    degraded: bool = False
    coverage: float = 1.0


class StreamScheduler:
    """The continuous-batching front-end over one
    ``VectorSearchService``. Construct via ``svc.scheduler()``.

    ``ef`` is the COMPILED result width (defaults to the service's
    ef0): the largest k / effective ef any request may ask for.
    ``ef_policy`` is the per-request effective-ef floor (default
    ``min(svc.ef0, ef)``): a request gets ``ef_eff = max(k,
    ef_policy)``. ``quantum`` is expansion iterations per tick;
    ``slo_ms`` (optional) stamps a default deadline on every request;
    ``adaptive_budget=False`` pins every query to the static step
    bound (the fixed-budget A/B arm)."""

    def __init__(self, svc, *, n_slots: Optional[int] = None,
                 quantum: int = 32, max_queue: int = 512,
                 slo_ms: Optional[float] = None,
                 ef: Optional[int] = None,
                 ef_policy: Optional[int] = None,
                 adaptive_budget: bool = True):
        if svc.mesh is not None:
            raise SchedulerUnsupported(
                "the mesh collective path has no slotted program; "
                "serve via the host path or run_stream_sync")
        snap = svc.sdb if svc.sdb is not None else svc.db
        self.sharded = svc.sdb is not None
        # DEFERRED re-ranking (single-shard host path): slots traverse
        # in filter space at the WIDE pool width and the promote
        # (cascade) + Dist.H passes run batched over each tick's
        # retiring slots — the exact final blocks of the synchronous
        # deferred program, so run_stream stays bit-equal to
        # run_stream_sync. The sharded deferred merge-then-rerank is
        # not slotted yet.
        self.deferred = bool(snap.cfg.deferred_rerank
                             and snap.filter_kind != "none")
        if self.deferred and self.sharded:
            raise SchedulerUnsupported(
                "sharded deferred re-ranking merges per-shard lists "
                "before the global re-rank; serve via run_stream_sync")
        self.cascade = self.deferred and snap.filter_kind == "cascade"
        self.rm = int(snap.cfg.rerank_mult) if self.deferred else 1
        # wide = the slot list's pool multiplier: the cascade's promote
        # pool, else the re-rank pool (1 when not deferred)
        self.wide = max(int(snap.cfg.promote_mult), self.rm) \
            if self.cascade else self.rm
        self.svc = svc
        self.cfg = snap.cfg
        self.EF = int(ef or svc.ef0)
        self.EFW = self.EF * self.wide   # compiled slot list width
        self.ef_policy = int(min(ef_policy or svc.ef0, self.EF))
        self.S = int(n_slots or svc.batch)
        self.quantum = int(quantum)
        self.W = self.cfg.expand_width
        self.max_queue = int(max_queue)
        self.slo_ms = slo_ms
        self.adaptive = bool(adaptive_budget)
        self.tracer = svc.tracer
        r = svc.stats.registry
        self._g_depth = r.gauge("phnsw_sched_queue_depth",
                                "admission queue depth")
        self._g_occ = r.gauge("phnsw_sched_slot_occupancy",
                              "fraction of slots in flight")
        self._c_shed = r.counter("phnsw_sched_shed_total",
                                 "requests shed by admission control",
                                 labels=("reason",))
        self._c_esc = r.counter("phnsw_sched_escalations_total",
                                "per-query step-budget escalations")
        self._c_adm = r.counter("phnsw_sched_admitted_total",
                                "queries admitted into slots")
        self._c_ret = r.counter("phnsw_sched_retired_total",
                                "queries retired from slots")
        self.steps_hist = r.histogram(
            "phnsw_sched_slot_steps",
            "expansion steps per retired query (drives the p50 "
            "initial budget)")
        # host mirrors of the per-slot bookkeeping (the device state
        # carries only what the compiled program reads)
        self._rid_of = np.full(self.S, -1, np.int64)
        self._budget = np.zeros(self.S, np.int32)
        self._cap = np.zeros(self.S, np.int32)
        # per-slot promote-keep width (cascade: ef_eff * rerank_mult)
        self._keep = np.zeros(self.S, np.int32)
        self._meta: Dict[int, _Pending] = {}
        self._queue: Deque[_Pending] = deque()
        self._next_rid = 0
        self._escalated = False
        self._live_mask: Optional[np.ndarray] = None   # test override
        D = int(snap.high.shape[-1])
        self._D = D
        qp_ex = svc.filt.prepare(np.zeros((1, D), np.float32))
        dbv = self._db()
        self.state = sj.make_slot_state(
            dbv, self.S, np.asarray(qp_ex), ef=self.EFW,
            n_shards=snap.n_shards if self.sharded else None,
            deferred=self.deferred)
        if self.sharded:
            self._offsets = np.asarray(svc.sdb.offsets, np.int64)
        # WIDTH LADDER: slots are allocated low-first and each tick
        # runs the smallest compiled prefix covering the highest live
        # slot — a fixed set of widths, so partial occupancy neither
        # pays full-bank prices nor recompiles
        rungs = {self.S} | {w for w in range(16, self.S, 16)}
        self.rungs = sorted(rungs)
        # warm every compiled program with a no-op admission (every
        # pad row's slot id is out of range -> dropped) and an empty
        # step (all budgets 0 -> the loop cond is false immediately);
        # nothing is recorded, so service stats stay clean
        for wd in self.rungs:
            self.state = self._admit_step_call(
                dbv, np.zeros((wd, D), np.float32),
                np.full(wd, self.S, np.int32),
                np.full(wd, self.EFW, np.int32),
                np.zeros(wd, np.int32), wd)
            self.state = self._step_call(dbv, wd)
        if self.deferred:
            # warm the retirement passes too (all-pad rows): steady
            # state then never compiles, even on the first real retire
            pad_fi = jnp.full((self.S, self.EFW), -1, jnp.int32)
            if self.cascade:
                sj._retire_promote_jit(
                    self.svc.db, self.state.qprep, pad_fi,
                    jnp.zeros((self.S,), jnp.int32))
            sj._retire_rerank_jit(self.svc.db, self.state.q_high,
                                  pad_fi)

    # -- plumbing ----------------------------------------------------------

    def _db(self):
        return stacked_db_view(self.svc.sdb) if self.sharded \
            else self.svc.db

    def _live(self) -> np.ndarray:
        """[P] live-shard mask: the service's fault-plane health when
        it has one, a test override otherwise, else all-live."""
        if not self.sharded:
            return np.ones(1, bool)
        if self.svc.health is not None:
            return ~np.asarray(self.svc.health.dead, bool)
        if self._live_mask is not None:
            return self._live_mask
        return np.ones(self.svc.sdb.n_shards, bool)

    def set_live(self, mask) -> None:
        """Degraded-mode override for tests/benches without a fault
        policy: serve from the ``mask``-live shards only."""
        self._live_mask = np.asarray(mask, bool)

    def _admit_step_call(self, dbv, q_new, slot_ids, ef_eff, budget,
                         width):
        qp = self.svc.filt.prepare(q_new)
        args = (jnp.asarray(q_new), jnp.asarray(qp),
                jnp.asarray(slot_ids), jnp.asarray(ef_eff),
                jnp.asarray(budget))
        fn = sj._slot_admit_step_sharded_jit if self.sharded \
            else sj._slot_admit_step_jit
        return fn(dbv, self.state, *args, width, self.quantum, self.W,
                  self.deferred)

    def _step_call(self, dbv, width):
        if width >= self.S:
            fn = sj._slot_step_sharded_jit if self.sharded \
                else sj._slot_step_jit
            return fn(dbv, self.state, self.quantum, self.W,
                      self.deferred)
        fn = sj._slot_step_prefix_sharded_jit if self.sharded \
            else sj._slot_step_prefix_jit
        return fn(dbv, self.state, width, self.quantum, self.W,
                  self.deferred)

    def _push_budget(self) -> None:
        b = jnp.asarray(self._budget)
        if self.sharded:
            b = jnp.broadcast_to(b, self.state.budget.shape)
        self.state = _dc.replace(self.state, budget=b)

    def _static_cap(self, ef_eff: int) -> int:
        """The per-request step bound — the exact bound the synchronous
        program compiles for this effective ef."""
        if self.cfg.step_budget is not None:
            cap = self.cfg.max_steps_for_layer(0)
        else:
            cap = 4 * ef_eff + 16
        return -(-cap // self.W) * self.W

    def _initial_budget(self, ef_eff: int) -> int:
        """Start at the observed p50 step budget once telemetry exists
        (>= 64 retired queries), else the static bound."""
        cap = self._static_cap(ef_eff)
        if not self.adaptive or self.steps_hist.count < 64:
            return cap
        b = int(np.ceil(self.steps_hist.percentile(50))) + 1
        b = -(-b // self.W) * self.W
        return int(min(max(b, self.W), cap))

    # -- admission ---------------------------------------------------------

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def in_flight(self) -> int:
        return int((self._rid_of >= 0).sum())

    def has_capacity(self) -> bool:
        return len(self._queue) < self.max_queue

    def submit(self, q, *, k: int = 10, rid: Optional[int] = None,
               deadline_ms: Optional[float] = None,
               t_sched: Optional[float] = None) -> Optional[int]:
        """Enqueue one query. ``k`` results come back (k <= compiled
        EF). ``deadline_ms`` (or the scheduler's ``slo_ms``) arms
        deadline shedding; ``t_sched`` is the open-loop scheduled
        arrival the latency clock starts from (defaults to now).
        Returns the request id, or None when admission control SHEDS
        the request (queue full / deadline infeasible)."""
        if k > self.EF:
            raise ValueError(f"k={k} exceeds the compiled result "
                             f"width EF={self.EF}; construct the "
                             f"scheduler with ef>={k}")
        now = time.monotonic()
        t_sched = now if t_sched is None else t_sched
        dl_ms = deadline_ms if deadline_ms is not None else self.slo_ms
        deadline = None if dl_ms is None else t_sched + dl_ms / 1e3
        if deadline is not None and now > deadline:
            self._c_shed.labels(reason="deadline").inc()
            return None
        if len(self._queue) >= self.max_queue:
            self._c_shed.labels(reason="queue_full").inc()
            return None
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid + 1)
        ef_eff = int(min(max(k, self.ef_policy), self.EF))
        self._queue.append(_Pending(
            rid=rid, k=int(k), ef_eff=ef_eff, t_submit=now,
            t_sched=t_sched, deadline=deadline,
            q=np.asarray(q, np.float32).reshape(-1)))
        self._g_depth.set(len(self._queue))
        return rid

    # -- the execution loop ------------------------------------------------

    def _admit_step(self, dbv, span) -> int:
        """Admit whatever the queue holds into the lowest free slots
        and advance the bank — ONE fused compiled call when there are
        arrivals, a prefix step otherwise, both at the smallest ladder
        width covering the highest live slot."""
        free = np.nonzero(self._rid_of < 0)[0]
        take: List[_Pending] = []
        if len(free) and self._queue:
            now = time.monotonic()
            while self._queue and len(take) < len(free):
                p = self._queue.popleft()
                if p.deadline is not None and now > p.deadline:
                    self._c_shed.labels(reason="deadline").inc()
                    span.event("shed", rid=p.rid)
                    continue
                take.append(p)
        for row, p in enumerate(take):
            s = int(free[row])
            self._rid_of[s] = p.rid
            self._budget[s] = self._initial_budget(p.ef_eff)
            self._cap[s] = self._static_cap(p.ef_eff)
            self._keep[s] = p.ef_eff * self.rm
            self._meta[p.rid] = p
        occ = np.nonzero(self._rid_of >= 0)[0]
        if not len(occ):
            self._g_depth.set(len(self._queue))
            return 0
        wd = next(w for w in self.rungs if w >= int(occ[-1]) + 1)
        if take:
            q_new = np.zeros((wd, self._D), np.float32)
            slot_ids = np.full(wd, self.S, np.int32)
            ef_eff = np.full(wd, self.EFW, np.int32)
            budget = np.zeros(wd, np.int32)
            for row, p in enumerate(take):
                s = int(free[row])
                q_new[row] = p.q
                slot_ids[row] = s
                # deferred slots hold the WIDE filter-space pool, so
                # the effective ef register scales with it
                ef_eff[row] = p.ef_eff * self.wide
                budget[row] = self._budget[s]
                p.q = None
            self.state = self._admit_step_call(dbv, q_new, slot_ids,
                                               ef_eff, budget, wd)
            self._c_adm.inc(len(take))
            span.set(admitted=len(take))
        else:
            self.state = self._step_call(dbv, wd)
        self._g_depth.set(len(self._queue))
        return len(take)

    def _retire(self, span) -> List[Completion]:
        self._escalated = False
        occupied = self._rid_of >= 0
        if not occupied.any():
            return []
        done = np.asarray(self.state.done)
        ns = np.asarray(self.state.nsteps)
        live = self._live()
        if self.sharded:
            if live.any():
                done_eff = done[live].all(axis=0)
                ns_eff = ns[live].max(axis=0)
            else:
                done_eff = np.ones(self.S, bool)
                ns_eff = ns.max(axis=0)
        else:
            done_eff, ns_eff = done, ns
        finished = occupied & done_eff
        # budget escalation: an unconverged slot that spent its budget
        # doubles it (up to the static bound); at the bound it is
        # force-retired with exactly what the static program would
        # have returned
        stalled = occupied & ~done_eff & (ns_eff >= self._budget)
        forced = np.zeros(self.S, bool)
        if stalled.any():
            dirty = False
            for s in np.nonzero(stalled)[0]:
                if self._budget[s] < self._cap[s]:
                    self._budget[s] = min(2 * int(self._budget[s]),
                                          int(self._cap[s]))
                    self._c_esc.inc()
                    dirty = True
                else:
                    forced[s] = True
            if dirty:
                self._push_budget()
                self._escalated = True
        finished = finished | forced
        if not finished.any():
            return []
        fd = np.asarray(self.state.F_d)
        fi = np.asarray(self.state.F_i)
        if self.deferred:
            # the deferred promote (cascade) + Dist.H passes, batched
            # over THIS tick's retiring slots at the full bank width
            # (non-retiring rows ride as fi = -1 pads — pure data, one
            # compiled shape): the exact final blocks of the
            # synchronous deferred program, so results are bit-equal
            db = self.svc.db
            fi_b = jnp.asarray(np.where(finished[:, None], fi, -1))
            if self.cascade:
                keep = np.where(finished, self._keep, 0).astype(np.int32)
                _, fi_b = sj._retire_promote_jit(
                    db, self.state.qprep, fi_b, jnp.asarray(keep))
            rd, ri, _ = sj._retire_rerank_jit(db, self.state.q_high,
                                              fi_b)
            fd, fi = np.asarray(rd), np.asarray(ri)
        degraded = self.sharded and bool(~live.all())
        cov = self.svc._coverage(live) if degraded else 1.0
        now = time.monotonic()
        out: List[Completion] = []
        for s in np.nonzero(finished)[0]:
            p = self._meta.pop(int(self._rid_of[s]))
            kq = p.k
            if self.sharded:
                ds = np.concatenate([fd[pp, s] for pp in
                                     np.nonzero(live)[0]])
                gs = np.concatenate(
                    [np.where(fi[pp, s] >= 0,
                              fi[pp, s] + self._offsets[pp], -1)
                     for pp in np.nonzero(live)[0]])
                order = np.argsort(ds, kind="stable")[:kq]
                ids, dists = gs[order], ds[order]
            else:
                ids, dists = fi[s, :kq].copy(), fd[s, :kq].copy()
            lat = (now - p.t_sched) * 1e3
            out.append(Completion(
                rid=p.rid, ids=ids, dists=dists, latency_ms=lat,
                steps=int(ns_eff[s]), forced=bool(forced[s]),
                degraded=degraded, coverage=cov))
            self.steps_hist.observe(float(ns_eff[s]))
            self.svc.stats.record_request(1, lat)
            if degraded:
                self.svc.stats.record_degraded(cov)
            self._rid_of[s] = -1
            self._budget[s] = 0
        self._c_ret.inc(len(out))
        if out:
            span.set(retired=len(out))
        return out

    def tick(self) -> List[Completion]:
        """One scheduler round: admit -> step -> escalate/retire.
        Returns the queries that completed this round (out-of-order by
        design — exactly-once per rid)."""
        span = self.tracer.span("sched.tick")
        with span:
            dbv = self._db()
            self._admit_step(dbv, span)
            out = self._retire(span)
            # escalation pass: a budget-frozen slot whose budget just
            # doubled resumes NOW instead of waiting out a full
            # admission round — the extra prefix step is the same
            # compiled program (done slots stay masked), so the rare
            # deep query pays a partial re-step, not a whole tick
            passes = 0
            while self._escalated and passes < 2:
                occ = np.nonzero(self._rid_of >= 0)[0]
                if not len(occ):
                    break
                wd = next(w for w in self.rungs
                          if w >= int(occ[-1]) + 1)
                self.state = self._step_call(dbv, wd)
                out.extend(self._retire(span))
                passes += 1
            self._g_occ.set(self.in_flight / self.S)
        return out

    def drain(self) -> List[Completion]:
        """Tick until the queue and every slot are empty; returns all
        completions in retirement order."""
        out: List[Completion] = []
        while self._queue or (self._rid_of >= 0).any():
            out.extend(self.tick())
        return out

    @staticmethod
    def cache_sizes():
        """The slotted compiled-program cache sizes (step, admit,
        step_sharded, admit_sharded, step_prefix,
        step_prefix_sharded) — zero-recompile assertions."""
        return sj.slot_cache_sizes()
