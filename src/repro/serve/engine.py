"""Generation engine: batched prefill -> decode with a right-padded KV
cache, greedy or temperature sampling.

The cache returned by ``prefill`` covers exactly the prompt; the engine
pads the sequence axis to ``prompt + max_new`` before stepping (and for
retrieval-attention archs, fills the inline low-dim keys for the prompt
region — the layout-(3) index is built at prefill time, like the paper
builds its database before the S phase).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import get_model


@dataclass
class GenerationResult:
    tokens: np.ndarray        # [B, max_new]
    steps: int
    prefill_s: float
    decode_s: float

    @property
    def tokens_per_s(self) -> float:
        return self.tokens.size / max(self.decode_s, 1e-9)


def _pad_cache_seq(cfg: ModelConfig, params, cache, target_t: int):
    """Right-pad the cache sequence axis (axis 2 of [L,B,T,...]) and
    derive low-dim keys for retrieval archs."""
    def pad(x):
        t = x.shape[2]
        if t >= target_t:
            return x
        widths = [(0, 0)] * x.ndim
        widths[2] = (0, target_t - t)
        return jnp.pad(x, widths)

    if cfg.family in ("dense", "moe", "vlm"):
        cache = {"k": pad(cache["k"]), "v": pad(cache["v"])}
        if cfg.retrieval.enabled:
            proj = params["layers"]["attn"]["rp_proj"]       # [L, Hd, dl]
            klow = jnp.einsum("lbtkh,lhc->lbtkc",
                              cache["k"].astype(jnp.float32),
                              proj).astype(cache["k"].dtype)
            cache["k_low"] = klow
        return cache
    if cfg.family == "encdec":
        return {"self": {"k": pad(cache["self"]["k"]),
                         "v": pad(cache["self"]["v"])},
                "cross": cache["cross"]}
    return cache   # hybrid / ssm states are fixed-size


class GenerationEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_new: int = 32,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.api = get_model(cfg)
        self.params = params
        self.max_new = max_new
        self.temperature = temperature
        self.key = jax.random.key(seed)
        self._prefill = jax.jit(self.api.prefill)
        self._step = jax.jit(self.api.decode_step)

    def _sample(self, logits):
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(
            sub, logits / self.temperature, axis=-1)[:, None].astype(jnp.int32)

    def generate(self, batch: Dict[str, Any]) -> GenerationResult:
        import time
        B, S = batch["tokens"].shape
        t0 = time.monotonic()
        logits, cache = self._prefill(self.params, batch)
        logits = jax.block_until_ready(logits)
        t1 = time.monotonic()
        if self.cfg.family in ("dense", "moe", "vlm", "encdec"):
            total = S + (self.cfg.vis_tokens or 0) + self.max_new
            if self.cfg.window:
                total = min(total, self.cfg.window)
            cache = _pad_cache_seq(self.cfg, self.params, cache, total)
        out = []
        tok = self._sample(logits)
        pos = S + (self.cfg.vis_tokens or 0)
        for i in range(self.max_new):
            out.append(np.asarray(tok))
            logits, cache = self._step(self.params, cache, tok,
                                       jnp.int32(pos + i))
            tok = self._sample(logits)
        t2 = time.monotonic()
        return GenerationResult(tokens=np.concatenate(out, axis=1),
                                steps=self.max_new,
                                prefill_s=t1 - t0, decode_s=t2 - t1)
