from repro.serve.engine import GenerationEngine
from repro.serve.replica import ReplicaSet
from repro.serve.scheduler import (Completion, SchedulerUnsupported,
                                   StreamScheduler)
from repro.serve.vector_service import VectorSearchService

__all__ = ["Completion", "GenerationEngine", "ReplicaSet",
           "SchedulerUnsupported", "StreamScheduler",
           "VectorSearchService"]
