from repro.serve.engine import GenerationEngine
from repro.serve.replica import ReplicaSet
from repro.serve.vector_service import VectorSearchService

__all__ = ["GenerationEngine", "ReplicaSet", "VectorSearchService"]
