"""Attention: GQA/MHA/MQA, sliding-window / local, cross-attention, and
single-token decode against a (possibly sequence-sharded) KV cache.

Training/prefill use a q-chunked formulation (``lax.scan`` over query
blocks) so the [S, T] score matrix is never materialized — this is the
pure-jnp analogue of the Pallas ``flash_attention`` kernel in
``repro/kernels`` and serves as its distribution-friendly XLA path.
Local/sliding-window attention uses a *banded* variant: each query block
only reads a ``window + chunk`` KV slice (O(S·w) instead of O(S²)).

Decode attention is a plain einsum over the full cache: with the cache
sequence axis sharded (flash-decoding style), GSPMD turns the softmax
max/sum and the PV contraction into all-reduces over the sharded axis —
the partial-softmax merge falls out of the partitioner.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_keys
from repro.models.rope import apply_rope

NEG_INF = -1e30


def init_attn(cfg, key, dtype):
    d, n, kvh, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.resolved_head_dim
    ks = split_keys(key, ["wq", "wk", "wv", "wo", "rp"])
    p = {
        "wq": dense_init(ks["wq"], (d, n * hd), dtype=dtype),
        "wk": dense_init(ks["wk"], (d, kvh * hd), dtype=dtype),
        "wv": dense_init(ks["wv"], (d, kvh * hd), dtype=dtype),
        "wo": dense_init(ks["wo"], (n * hd, d), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((n * hd,), dtype)
        p["bk"] = jnp.zeros((kvh * hd,), dtype)
        p["bv"] = jnp.zeros((kvh * hd,), dtype)
    if cfg.retrieval.enabled:
        from repro.models.retrieval_attention import init_retrieval
        p.update(init_retrieval(cfg, ks["rp"], dtype))
    return p


def _project_q(cfg, p, x):
    B, S, _ = x.shape
    n, hd = cfg.n_heads, cfg.resolved_head_dim
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    return q.reshape(B, S, n, hd)


def _project_kv(cfg, p, x):
    B, S, _ = x.shape
    kvh, hd = cfg.kv_heads, cfg.resolved_head_dim
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        k = k + p["bk"]
        v = v + p["bv"]
    return k.reshape(B, S, kvh, hd), v.reshape(B, S, kvh, hd)


def _merge_heads(cfg, p, o):
    B, S = o.shape[:2]
    return o.reshape(B, S, -1) @ p["wo"]


# ------------------------ q-chunked core -----------------------------------

def blocked_attention(q, k, v, q_pos, kv_pos, *, causal: bool,
                      window: int = 0, q_chunk: int = 256):
    """q: [B, S, N, Hd]; k, v: [B, T, KV, Hd]; positions int32 [S]/[T].
    Returns [B, S, N, Hd]. N must be a multiple of KV (GQA)."""
    B, S, N, Hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = N // KV
    scale = Hd ** -0.5
    qg = q.reshape(B, S, KV, G, Hd)
    c = min(q_chunk, S)
    while S % c:
        c -= 1  # S is a power-of-two in all assigned shapes; fallback for odd S
    n_chunks = S // c

    banded = window > 0 and T > window + c
    if banded:
        # pad KV on the left so every band slice is in-bounds
        pad = window
        kp = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        kv_pos_p = jnp.pad(kv_pos, (pad, 0), constant_values=-1)
        band = window + c

    def one_chunk(i):
        qs = i * c
        qc = jax.lax.dynamic_slice_in_dim(qg, qs, c, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(q_pos, qs, c, axis=0)
        if banded:
            # q block covers absolute kv range [qs, qs + c); band starts at
            # qs + pad - window = qs (in padded coords) of length window + c
            kc = jax.lax.dynamic_slice_in_dim(kp, qs, band, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(vp, qs, band, axis=1)
            kpos = jax.lax.dynamic_slice_in_dim(kv_pos_p, qs, band, axis=0)
        else:
            kc, vc, kpos = k, v, kv_pos
        lg = jnp.einsum("bskgh,btkh->bskgt", qc, kc,
                        preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((c, kpos.shape[0]), bool)
        if causal:
            mask &= qp[:, None] >= kpos[None, :]
        if window > 0:
            mask &= (qp[:, None] - kpos[None, :]) < window
        mask &= kpos[None, :] >= 0
        lg = jnp.where(mask[None, :, None, None, :], lg, NEG_INF)
        w = jax.nn.softmax(lg, axis=-1)
        oc = jnp.einsum("bskgt,btkh->bskgh", w.astype(v.dtype), vc)
        return oc.reshape(B, c, N, Hd)

    if n_chunks == 1:
        return one_chunk(0)
    outs = jax.lax.map(one_chunk, jnp.arange(n_chunks))   # [n, B, c, N, Hd]
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, N, Hd)


# ------------------------ block-level APIs ----------------------------------

def attn_forward(cfg, p, x, positions, *, causal=True, window=None,
                 kv_src=None, kv_positions=None):
    """Self- or cross-attention over a full sequence (train / prefill).
    kv_src: encoder states for cross-attention (no rope, no causal)."""
    q = _project_q(cfg, p, x)
    src = x if kv_src is None else kv_src
    k, v = _project_kv(cfg, p, src)
    w = cfg.window if window is None else window
    if kv_src is None and cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        kv_pos = positions
    else:
        kv_pos = kv_positions if kv_positions is not None else \
            jnp.arange(src.shape[1], dtype=jnp.int32)
    o = blocked_attention(q, k, v, positions, kv_pos,
                          causal=causal and kv_src is None, window=w or 0)
    return _merge_heads(cfg, p, o)


def attn_prefill(cfg, p, x, positions, cache_len: int, *, window=None):
    """Prefill: forward + return the KV slices to install in the cache."""
    q = _project_q(cfg, p, x)
    k, v = _project_kv(cfg, p, x)
    if cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    w = cfg.window if window is None else window
    o = blocked_attention(q, k, v, positions, positions, causal=True,
                          window=w or 0)
    return _merge_heads(cfg, p, o), (k, v)


def attn_decode(cfg, p, x, cache, pos, *, window=None):
    """One-token decode. x: [B, 1, D]; cache: {"k","v"}: [B, T, KV, Hd]
    (T = full seq for dense archs, T = window for SWA/local archs — the
    cache is then a ring buffer indexed pos % T). pos: scalar int32.
    Returns (y [B,1,D], new_cache)."""
    B = x.shape[0]
    T = cache["k"].shape[1]
    q = _project_q(cfg, p, x)
    k_new, v_new = _project_kv(cfg, p, x)
    if cfg.rope_theta > 0:
        pvec = jnp.full((1,), pos, jnp.int32)
        q = apply_rope(q, pvec, cfg.rope_theta)
        k_new = apply_rope(k_new, pvec, cfg.rope_theta)
    w = cfg.window if window is None else window
    slot = (pos % T) if w else jnp.minimum(pos, T - 1)
    # mask-based cache write: a dynamic-update-slice on a sequence-sharded
    # cache would force GSPMD to gather; jnp.where partitions trivially.
    idx = jnp.arange(T, dtype=jnp.int32)
    hit = (idx == slot)[None, :, None, None]
    quant = cfg.kv_quant and "k_sc" in cache
    if quant:
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        ck_q = jnp.where(hit, kq, cache["k"])
        cv_q = jnp.where(hit, vq, cache["v"])
        ks_c = jnp.where(hit, ks, cache["k_sc"])
        vs_c = jnp.where(hit, vs, cache["v_sc"])
        # dequant on read (fused into the decode kernel on TPU: the HBM
        # read is the int8 tensor + scales — half the bf16 bytes)
        ck = _dequantize_kv(ck_q, ks_c)
        cv = _dequantize_kv(cv_q, vs_c)
        new_cache_extra = {"k": ck_q, "v": cv_q, "k_sc": ks_c, "v_sc": vs_c}
    else:
        ck = jnp.where(hit, k_new, cache["k"])
        cv = jnp.where(hit, v_new, cache["v"])
        new_cache_extra = None
    if cfg.retrieval.enabled and "k_low" in cache:
        from repro.models import retrieval_attention as ra
        klow_new = ra.project_low(p, k_new)
        cklow = jnp.where(hit, klow_new, cache["k_low"])
        qh = q  # rope already applied above
        o = ra.retrieval_decode_attention(cfg, p, qh, ck, cv, cklow, pos)
        y = _merge_heads(cfg, p, o)
        out_cache = new_cache_extra if quant else {"k": ck, "v": cv}
        return y, {**out_cache, "k_low": cklow}
    if w:
        # ring buffer: slot s holds the largest position p' <= pos with
        # p' % T == s (negative -> slot not yet written)
        kv_pos = pos - ((pos - idx) % T)
    else:
        kv_pos = idx
    valid = (kv_pos <= pos) & (kv_pos >= 0)
    if w:
        valid &= (pos - kv_pos) < w
    N, KV, Hd = cfg.n_heads, cfg.kv_heads, cfg.resolved_head_dim
    G = N // KV
    qg = q.reshape(B, 1, KV, G, Hd)
    lg = jnp.einsum("bskgh,btkh->bskgt", qg, ck,
                    preferred_element_type=jnp.float32) * (Hd ** -0.5)
    lg = jnp.where(valid[None, None, None, None, :], lg, NEG_INF)
    wts = jax.nn.softmax(lg, axis=-1)
    o = jnp.einsum("bskgt,btkh->bskgh", wts.astype(cv.dtype), cv)
    y = _merge_heads(cfg, p, o.reshape(B, 1, N, Hd))
    return y, (new_cache_extra if quant else {"k": ck, "v": cv})


def init_cache(cfg, batch: int, seq_len: int, dtype) -> dict:
    """Per-layer KV cache. SWA/local archs get a bounded ring buffer.
    Retrieval archs additionally store low-dim keys inline (layout (3)).
    kv_quant stores int8 values + per-(token, head) absmax scales."""
    T = seq_len
    if cfg.window:
        T = min(seq_len, cfg.window)
    kvh, hd = cfg.kv_heads, cfg.resolved_head_dim
    if cfg.kv_quant:
        zq = jnp.zeros((batch, T, kvh, hd), jnp.int8)
        zs = jnp.zeros((batch, T, kvh, 1), dtype)
        c = {"k": zq, "v": zq, "k_sc": zs, "v_sc": zs}
    else:
        z = jnp.zeros((batch, T, kvh, hd), dtype)
        c = {"k": z, "v": z}
    if cfg.retrieval.enabled:
        c["k_low"] = jnp.zeros((batch, T, kvh, cfg.retrieval.d_low), dtype)
    return c


def _quantize_kv(x):
    """x: [B, S, KV, Hd] -> (int8, scale [B, S, KV, 1])."""
    sc = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                 keepdims=True) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / sc), -127, 127
                 ).astype(jnp.int8)
    return q, sc.astype(x.dtype)


def _dequantize_kv(q, sc):
    return q.astype(sc.dtype) * sc
