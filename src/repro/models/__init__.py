from repro.models.api import ModelApi, get_model

__all__ = ["ModelApi", "get_model"]
