"""RWKV6 model driver (attention-free; O(1) recurrent state).
State per layer: time-mix {x_prev, S [B,H,hd,hd]} + channel-mix {x_prev}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import rwkv6
from repro.models.common import (dtype_of, maybe_remat, scan_layers,
                                 split_keys, stack_layers)
from repro.models.layers import (apply_norm, chunked_xent, embed_tokens,
                                 init_embed, init_norm, logits_fn)
from repro.distributed.sharding import constrain


def _init_layer(cfg, key, dtype):
    ks = split_keys(key, ["tmix", "cmix", "n1", "n2"])
    return {
        "ln_t": init_norm(cfg, ks["n1"]),
        "tmix": rwkv6.init_rwkv_tmix(cfg, ks["tmix"], dtype),
        "ln_c": init_norm(cfg, ks["n2"]),
        "cmix": rwkv6.init_rwkv_cmix(cfg, ks["cmix"], dtype),
    }


def init(cfg, key):
    dtype = dtype_of(cfg)
    ks = split_keys(key, ["emb", "layers", "ln0", "lnf"])
    return {
        **init_embed(cfg, ks["emb"], dtype),
        "ln_0": init_norm(cfg, ks["ln0"]),        # rwkv convention
        "layers": stack_layers(lambda k: _init_layer(cfg, k, dtype),
                               ks["layers"], cfg.n_layers),
        "ln_f": init_norm(cfg, ks["lnf"]),
    }


def _layer(cfg, lp, h, state):
    t, st_t = rwkv6.tmix_forward(cfg, lp["tmix"],
                                 apply_norm(cfg, lp["ln_t"], h),
                                 None if state is None else state["t"])
    h = constrain(h + t, "act_btd")
    c, st_c = rwkv6.cmix_forward(cfg, lp["cmix"],
                                 apply_norm(cfg, lp["ln_c"], h),
                                 None if state is None else state["c"])
    h = constrain(h + c, "act_btd")
    return h, {"t": st_t, "c": st_c}


def loss(cfg, params, batch):
    tokens, labels = batch["tokens"], batch["labels"]
    h = apply_norm(cfg, params["ln_0"], embed_tokens(cfg, params, tokens))

    def body(carry, lp):
        hh, _ = _layer(cfg, lp, carry, None)
        return hh, None

    h, _ = scan_layers(cfg, body, h, params["layers"])
    h = apply_norm(cfg, params["ln_f"], h)
    nll = chunked_xent(cfg, params, h, labels)
    return nll, {"loss": nll}


def init_cache(cfg, batch: int, seq_len: int):
    dtype = dtype_of(cfg)
    H, hd, D = cfg.n_heads, cfg.resolved_head_dim, cfg.d_model
    L = cfg.n_layers
    return {
        "t": {"x_prev": jnp.zeros((L, batch, 1, D), dtype),
              "S": jnp.zeros((L, batch, H, hd, hd), jnp.float32)},
        "c": {"x_prev": jnp.zeros((L, batch, 1, D), dtype)},
    }


def prefill(cfg, params, batch):
    tokens = batch["tokens"]
    h = apply_norm(cfg, params["ln_0"], embed_tokens(cfg, params, tokens))

    def body(carry, lp):
        hh = carry
        tn = apply_norm(cfg, lp["ln_t"], hh)
        t, st_t = rwkv6.tmix_forward(cfg, lp["tmix"], tn, None)
        hh = constrain(hh + t, "act_btd")
        cn = apply_norm(cfg, lp["ln_c"], hh)
        c, st_c = rwkv6.cmix_forward(cfg, lp["cmix"], cn, None)
        hh = constrain(hh + c, "act_btd")
        return hh, {"t": st_t, "c": st_c}

    h, cache = scan_layers(cfg, body, h, params["layers"])
    h = apply_norm(cfg, params["ln_f"], h)
    logits = logits_fn(cfg, params, h[:, -1]).astype(jnp.float32)
    return logits, cache


def decode_step(cfg, params, cache, token, pos):
    del pos  # recurrent: position-free
    h = apply_norm(cfg, params["ln_0"], embed_tokens(cfg, params, token))

    def body(carry, xs):
        lp, st = xs
        hh, st2 = _layer(cfg, lp, carry, st)
        return hh, st2

    h, new_cache = jax.lax.scan(body, h, (params["layers"], cache))
    h = apply_norm(cfg, params["ln_f"], h)
    logits = logits_fn(cfg, params, h[:, -1]).astype(jnp.float32)
    return logits, new_cache
