"""Shared model plumbing: parameter init, dtype policy, pytree helpers.

Parameters are plain nested dicts of jnp arrays. Leaf NAMES are the
contract with ``distributed/sharding.py`` — the sharding rule table
dispatches on the leaf key (e.g. ``wq``, ``e_up``, ``emb``), with stacked
layer axes detected from rank.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32, scale=1.0):
    """Normal(0, scale/sqrt(fan_in)) init."""
    fan_in = shape[in_axis]
    std = scale / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def split_keys(key, names):
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


def stack_layers(init_one: Callable[[jax.Array], Params], key, n: int) -> Params:
    """Init ``n`` layers and stack each leaf along a new leading axis.
    Used with ``lax.scan`` over layers to keep HLO size O(1) in depth."""
    keys = jax.random.split(key, n)
    layers = [init_one(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *layers)


def count_params(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def param_bytes(params: Params) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(params))


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)


def maybe_remat(fn, cfg):
    if getattr(cfg, "remat", "none") == "full":
        return jax.checkpoint(fn)
    if getattr(cfg, "remat", "none") == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


def _sqrt_block(L: int) -> int:
    """Largest divisor of L that is <= ceil(sqrt(L))."""
    best = 1
    d = 1
    while d * d <= L:
        if L % d == 0:
            best = d
        d += 1
    return best


def scan_layers(cfg, body, carry, xs):
    """Scan ``body`` over the stacked-layer axis with the config's remat
    policy. For deep stacks under full remat, uses a two-level
    (sqrt-remat) scan: outer checkpoint over layer blocks, inner
    checkpoint per layer — residency drops from O(L) layer inputs to
    O(sqrt(L)) at ~1 extra forward of recompute. This is what makes
    llama3-405b train_4k fit (see EXPERIMENTS.md §Perf)."""
    mode = getattr(cfg, "remat", "none")
    if mode == "none":
        return jax.lax.scan(body, carry, xs)
    cbody = maybe_remat(body, cfg)
    L = jax.tree.leaves(xs)[0].shape[0]
    bs = _sqrt_block(L)
    if mode != "full" or L < 16 or bs == 1:
        return jax.lax.scan(cbody, carry, xs)
    nb = L // bs
    xs2 = jax.tree.map(lambda x: x.reshape((nb, bs) + x.shape[1:]), xs)

    @jax.checkpoint
    def outer(c, xb):
        return jax.lax.scan(cbody, c, xb)

    carry, ys = jax.lax.scan(outer, carry, xs2)
    if ys is not None:
        ys = jax.tree.map(
            lambda y: y.reshape((nb * bs,) + y.shape[2:]) if y is not None
            else None, ys)
    return carry, ys
