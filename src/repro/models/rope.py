"""Rotary position embeddings (and whisper's sinusoidal positions)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, Hd] (or [..., 1, H, Hd] at decode); positions: [..., S]."""
    if theta <= 0:
        return x
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    ang = ang[..., None, :]                             # broadcast over heads
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int):
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d))
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang[:, : (d + 1) // 2]))
    return pe
