"""RecurrentGemma-style hybrid: repeating (rec, rec, attn) pattern of
RG-LRU recurrent blocks and LOCAL (windowed, MQA) attention blocks, each
followed by a gated MLP. 38 layers = 12 full groups + 2 trailing rec.

Scan structure: ``lax.scan`` over the 12 groups (group params stacked),
then a second scan over the trailing rec layers — HLO stays O(1) in
depth. Sub-quadratic by construction: bounded attention window + O(1)
recurrent state, so long_500k decode runs natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import rglru
from repro.models.common import (dtype_of, maybe_remat, scan_layers,
                                 split_keys, stack_layers)
from repro.models.layers import (apply_mlp, apply_norm, chunked_xent,
                                 embed_tokens, init_embed, init_mlp, init_norm,
                                 logits_fn)
from repro.distributed.sharding import constrain


def _n_groups(cfg):
    g = len(cfg.pattern)
    return cfg.n_layers // g, cfg.n_layers % g   # (full groups, trailing rec)


def _init_rec_block(cfg, key, dtype):
    ks = split_keys(key, ["mix", "mlp", "n1", "n2"])
    return {
        "ln_mix": init_norm(cfg, ks["n1"]),
        "rec": rglru.init_rglru(cfg, ks["mix"], dtype),
        "ln_mlp": init_norm(cfg, ks["n2"]),
        "mlp": init_mlp(cfg, ks["mlp"], dtype),
    }


def _init_attn_block(cfg, key, dtype):
    ks = split_keys(key, ["mix", "mlp", "n1", "n2"])
    return {
        "ln_mix": init_norm(cfg, ks["n1"]),
        "attn": attn.init_attn(cfg, ks["mix"], dtype),
        "ln_mlp": init_norm(cfg, ks["n2"]),
        "mlp": init_mlp(cfg, ks["mlp"], dtype),
    }


def _init_group(cfg, key, dtype):
    ks = jax.random.split(key, len(cfg.pattern))
    g = {}
    for i, (kind, k) in enumerate(zip(cfg.pattern, ks)):
        if kind == "rec":
            g[f"rec{i}"] = _init_rec_block(cfg, k, dtype)
        else:
            g[f"attn{i}"] = _init_attn_block(cfg, k, dtype)
    return g


def init(cfg, key):
    dtype = dtype_of(cfg)
    nG, nT = _n_groups(cfg)
    ks = split_keys(key, ["emb", "groups", "trail", "lnf"])
    p = {
        **init_embed(cfg, ks["emb"], dtype),
        "groups": stack_layers(lambda k: _init_group(cfg, k, dtype),
                               ks["groups"], nG),
        "ln_f": init_norm(cfg, ks["lnf"]),
    }
    if nT:
        p["trail"] = stack_layers(lambda k: _init_rec_block(cfg, k, dtype),
                                  ks["trail"], nT)
    return p


def _rec_block(cfg, bp, h):
    m = rglru.apply_rglru(cfg, bp["rec"], apply_norm(cfg, bp["ln_mix"], h))
    h = constrain(h + m, "act_btd")
    m = apply_mlp(cfg, bp["mlp"], apply_norm(cfg, bp["ln_mlp"], h))
    return constrain(h + m, "act_btd")


def _attn_block(cfg, bp, h, positions):
    a = attn.attn_forward(cfg, bp["attn"], apply_norm(cfg, bp["ln_mix"], h),
                          positions, window=cfg.local_window)
    h = constrain(h + a, "act_btd")
    m = apply_mlp(cfg, bp["mlp"], apply_norm(cfg, bp["ln_mlp"], h))
    return constrain(h + m, "act_btd")


def loss(cfg, params, batch):
    tokens, labels = batch["tokens"], batch["labels"]
    h = embed_tokens(cfg, params, tokens)
    pos = jnp.arange(tokens.shape[1], dtype=jnp.int32)

    def group_body(carry, gp):
        hh = carry
        for i, kind in enumerate(cfg.pattern):
            if kind == "rec":
                hh = _rec_block(cfg, gp[f"rec{i}"], hh)
            else:
                hh = _attn_block(cfg, gp[f"attn{i}"], hh, pos)
        return hh, None

    h, _ = scan_layers(cfg, group_body, h, params["groups"])
    if "trail" in params:
        def trail_body(carry, bp):
            return _rec_block(cfg, bp, carry), None
        h, _ = scan_layers(cfg, trail_body, h, params["trail"])
    h = apply_norm(cfg, params["ln_f"], h)
    nll = chunked_xent(cfg, params, h, labels)
    return nll, {"loss": nll}


# ------------------------------ serving ------------------------------------

def init_cache(cfg, batch: int, seq_len: int):
    dtype = dtype_of(cfg)
    nG, nT = _n_groups(cfg)
    n_rec_per_group = cfg.pattern.count("rec")
    W = min(seq_len, cfg.local_window)
    kvh, hd = cfg.kv_heads, cfg.resolved_head_dim
    st = rglru.init_rglru_state(cfg, batch, dtype)
    stack = lambda tree, n: jax.tree.map(
        lambda x: jnp.zeros((n,) + x.shape, x.dtype), tree)
    cache = {
        "attn": {"k": jnp.zeros((nG, batch, W, kvh, hd), dtype),
                 "v": jnp.zeros((nG, batch, W, kvh, hd), dtype)},
        "rec": stack(st, nG * n_rec_per_group),
    }
    if nT:
        cache["trail"] = stack(st, nT)
    return cache


def prefill(cfg, params, batch):
    """Prefill via full-sequence forward; recurrent states rebuilt by a
    short suffix re-scan (states only need the final value): we run the
    sequence forms and extract final states."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = embed_tokens(cfg, params, tokens)
    pos = jnp.arange(S, dtype=jnp.int32)
    W = min(S, cfg.local_window)
    n_rec = cfg.pattern.count("rec")

    def group_body(carry, gp):
        hh = carry
        rec_states, attn_kv = [], None
        for i, kind in enumerate(cfg.pattern):
            if kind == "rec":
                bp = gp[f"rec{i}"]
                u = apply_norm(cfg, bp["ln_mix"], hh)
                m = rglru.apply_rglru(cfg, bp["rec"], u)
                # recompute final state cheaply via one decode step on the
                # last token (exact: h_T from the scan equals decode at T)
                st = _final_state(cfg, bp["rec"], u)
                rec_states.append(st)
                hh = constrain(hh + m, "act_btd")
                m = apply_mlp(cfg, bp["mlp"], apply_norm(cfg, bp["ln_mlp"], hh))
                hh = constrain(hh + m, "act_btd")
            else:
                bp = gp[f"attn{i}"]
                hn = apply_norm(cfg, bp["ln_mix"], hh)
                a, (k, v) = attn.attn_prefill(cfg, bp["attn"], hn, pos,
                                              cache_len=S,
                                              window=cfg.local_window)
                attn_kv = {"k": k[:, -W:], "v": v[:, -W:]}
                hh = constrain(hh + a, "act_btd")
                m = apply_mlp(cfg, bp["mlp"], apply_norm(cfg, bp["ln_mlp"], hh))
                hh = constrain(hh + m, "act_btd")
        rec_states = jax.tree.map(lambda *xs: jnp.stack(xs), *rec_states)
        return hh, {"rec": rec_states, "attn": attn_kv}

    h, caches = jax.lax.scan(group_body, h, params["groups"])
    nG, nT = _n_groups(cfg)
    cache = {
        "attn": caches["attn"],
        "rec": jax.tree.map(
            lambda x: x.reshape((nG * n_rec,) + x.shape[2:]), caches["rec"]),
    }
    if nT:
        def trail_body(carry, bp):
            hh = carry
            u = apply_norm(cfg, bp["ln_mix"], hh)
            m = rglru.apply_rglru(cfg, bp["rec"], u)
            st = _final_state(cfg, bp["rec"], u)
            hh = hh + m
            m = apply_mlp(cfg, bp["mlp"], apply_norm(cfg, bp["ln_mlp"], hh))
            return hh + m, st
        h, tstates = jax.lax.scan(trail_body, h, params["trail"])
        cache["trail"] = tstates
    h = apply_norm(cfg, params["ln_f"], h)
    logits = logits_fn(cfg, params, h[:, -1]).astype(jnp.float32)
    return logits, cache


def _final_state(cfg, rp, u_seq):
    """Final RG-LRU state after consuming u_seq (norm'd block input)."""
    w = cfg.lru_width or cfg.d_model
    h_heads = cfg.n_heads
    wh = w // h_heads
    x = u_seq @ rp["rg_in_x"]
    xc, conv_tail = rglru._causal_conv(rp, x)
    uf = xc.astype(jnp.float32)
    r, i = rglru._gates(rp, uf, h_heads, wh)
    log_a = rglru._log_a(rp, r)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
    return {"h": hs[:, -1], "conv": conv_tail}


def decode_step(cfg, params, cache, token, pos):
    B = token.shape[0]
    h = embed_tokens(cfg, params, token)
    n_rec = cfg.pattern.count("rec")

    def group_body(carry, xs):
        gp, ck = xs
        hh = carry
        rec_i = 0
        new_rec, new_attn = [], None
        for i, kind in enumerate(cfg.pattern):
            if kind == "rec":
                bp = gp[f"rec{i}"]
                st = jax.tree.map(lambda x: x[rec_i], ck["rec"])
                m, st2 = rglru.decode_rglru(
                    cfg, bp["rec"], apply_norm(cfg, bp["ln_mix"], hh), st)
                new_rec.append(st2)
                rec_i += 1
                hh = hh + m
            else:
                bp = gp[f"attn{i}"]
                a, new_attn = attn.attn_decode(
                    cfg, bp["attn"], apply_norm(cfg, bp["ln_mix"], hh),
                    ck["attn"], pos, window=cfg.local_window)
                hh = hh + a
            m = apply_mlp(cfg, bp["mlp"], apply_norm(cfg, bp["ln_mlp"], hh))
            hh = hh + m
        new_rec = jax.tree.map(lambda *xs: jnp.stack(xs), *new_rec)
        return hh, {"rec": new_rec, "attn": new_attn}

    nG, nT = _n_groups(cfg)
    grec = jax.tree.map(
        lambda x: x.reshape((nG, n_rec) + x.shape[1:]), cache["rec"])
    h, new_cache = jax.lax.scan(
        group_body, h, (params["groups"], {"rec": grec, "attn": cache["attn"]}))
    out_cache = {
        "attn": new_cache["attn"],
        "rec": jax.tree.map(
            lambda x: x.reshape((nG * n_rec,) + x.shape[2:]), new_cache["rec"]),
    }
    if nT:
        def trail_body(carry, xs):
            bp, st = xs
            hh = carry
            m, st2 = rglru.decode_rglru(
                cfg, bp["rec"], apply_norm(cfg, bp["ln_mix"], hh), st)
            hh = hh + m
            m = apply_mlp(cfg, bp["mlp"], apply_norm(cfg, bp["ln_mlp"], hh))
            return hh + m, st2
        h, tstates = jax.lax.scan(trail_body, h, (params["trail"],
                                                  cache["trail"]))
        out_cache["trail"] = tstates
    h = apply_norm(cfg, params["ln_f"], h)
    logits = logits_fn(cfg, params, h[:, -1]).astype(jnp.float32)
    return logits, out_cache
