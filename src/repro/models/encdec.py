"""Whisper-style encoder-decoder backbone. The conv audio frontend is a
STUB per the assignment: ``input_specs`` provides precomputed frame
embeddings [B, enc_frames, d_model]; the encoder is the transformer stack
over those frames (bidirectional), the decoder adds causal self-attention
+ cross-attention. Positions are sinusoidal (rope_theta=0)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import (dtype_of, maybe_remat, scan_layers,
                                 split_keys, stack_layers)
from repro.models.layers import (apply_mlp, apply_norm, chunked_xent,
                                 embed_tokens, init_embed, init_mlp, init_norm,
                                 logits_fn)
from repro.models.rope import sinusoidal_positions
from repro.distributed.sharding import constrain

MAX_DEC_POS = 65536   # sinusoidal table length for the decoder


def _init_enc_layer(cfg, key, dtype):
    ks = split_keys(key, ["attn", "mlp", "n1", "n2"])
    return {
        "ln_attn": init_norm(cfg, ks["n1"]),
        "attn": attn.init_attn(cfg, ks["attn"], dtype),
        "ln_mlp": init_norm(cfg, ks["n2"]),
        "mlp": init_mlp(cfg, ks["mlp"], dtype),
    }


def _init_dec_layer(cfg, key, dtype):
    ks = split_keys(key, ["attn", "xattn", "mlp", "n1", "n2", "n3"])
    return {
        "ln_attn": init_norm(cfg, ks["n1"]),
        "attn": attn.init_attn(cfg, ks["attn"], dtype),
        "ln_xattn": init_norm(cfg, ks["n2"]),
        "xattn": attn.init_attn(cfg, ks["xattn"], dtype),
        "ln_mlp": init_norm(cfg, ks["n3"]),
        "mlp": init_mlp(cfg, ks["mlp"], dtype),
    }


def init(cfg, key):
    dtype = dtype_of(cfg)
    ks = split_keys(key, ["emb", "enc", "dec", "lne", "lnd"])
    return {
        **init_embed(cfg, ks["emb"], dtype),
        "enc_layers_p": stack_layers(lambda k: _init_enc_layer(cfg, k, dtype),
                                     ks["enc"], cfg.enc_layers),
        "layers": stack_layers(lambda k: _init_dec_layer(cfg, k, dtype),
                               ks["dec"], cfg.n_layers),
        "ln_enc": init_norm(cfg, ks["lne"]),
        "ln_f": init_norm(cfg, ks["lnd"]),
    }


def encode(cfg, params, frames):
    """frames: [B, F, D] stubbed frontend output -> encoder states."""
    F = frames.shape[1]
    h = frames + sinusoidal_positions(F, cfg.d_model).astype(frames.dtype)
    pos = jnp.arange(F, dtype=jnp.int32)

    def body(carry, lp):
        hh = carry
        a = attn.attn_forward(cfg, lp["attn"],
                              apply_norm(cfg, lp["ln_attn"], hh), pos,
                              causal=False)
        hh = constrain(hh + a, "act_btd")
        m = apply_mlp(cfg, lp["mlp"], apply_norm(cfg, lp["ln_mlp"], hh))
        hh = constrain(hh + m, "act_btd")
        return hh, None

    h, _ = scan_layers(cfg, body, h, params["enc_layers_p"])
    return apply_norm(cfg, params["ln_enc"], h)


def _dec_layer(cfg, lp, h, positions, enc_out, enc_pos):
    a = attn.attn_forward(cfg, lp["attn"],
                          apply_norm(cfg, lp["ln_attn"], h), positions)
    h = constrain(h + a, "act_btd")
    x = attn.attn_forward(cfg, lp["xattn"],
                          apply_norm(cfg, lp["ln_xattn"], h), positions,
                          kv_src=enc_out, kv_positions=enc_pos, causal=False)
    h = constrain(h + x, "act_btd")
    m = apply_mlp(cfg, lp["mlp"], apply_norm(cfg, lp["ln_mlp"], h))
    h = constrain(h + m, "act_btd")
    return h


def loss(cfg, params, batch):
    enc_out = encode(cfg, params, batch["frames"])
    tokens, labels = batch["tokens"], batch["labels"]
    S = tokens.shape[1]
    h = embed_tokens(cfg, params, tokens)
    h = h + sinusoidal_positions(S, cfg.d_model).astype(h.dtype)
    pos = jnp.arange(S, dtype=jnp.int32)
    enc_pos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)

    def body(carry, lp):
        return _dec_layer(cfg, lp, carry, pos, enc_out, enc_pos), None

    h, _ = scan_layers(cfg, body, h, params["layers"])
    h = apply_norm(cfg, params["ln_f"], h)
    nll = chunked_xent(cfg, params, h, labels)
    return nll, {"loss": nll}


def init_cache(cfg, batch: int, seq_len: int):
    dtype = dtype_of(cfg)
    kvh, hd = cfg.kv_heads, cfg.resolved_head_dim
    self_c = jax.tree.map(
        lambda x: jnp.zeros((cfg.n_layers,) + x.shape, x.dtype),
        attn.init_cache(cfg, batch, seq_len, dtype))
    cross = {
        "k": jnp.zeros((cfg.n_layers, batch, cfg.enc_frames, kvh, hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, cfg.enc_frames, kvh, hd), dtype),
    }
    return {"self": self_c, "cross": cross}


def prefill(cfg, params, batch):
    """Encode audio + run the decoder prompt; returns (logits, cache)."""
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = embed_tokens(cfg, params, tokens)
    h = h + sinusoidal_positions(S, cfg.d_model).astype(h.dtype)
    pos = jnp.arange(S, dtype=jnp.int32)
    enc_pos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)

    def body(carry, lp):
        hh = carry
        hn = apply_norm(cfg, lp["ln_attn"], hh)
        a, (k, v) = attn.attn_prefill(cfg, lp["attn"], hn, pos, cache_len=S)
        hh = constrain(hh + a, "act_btd")
        x = attn.attn_forward(cfg, lp["xattn"],
                              apply_norm(cfg, lp["ln_xattn"], hh), pos,
                              kv_src=enc_out, kv_positions=enc_pos,
                              causal=False)
        hh = constrain(hh + x, "act_btd")
        m = apply_mlp(cfg, lp["mlp"], apply_norm(cfg, lp["ln_mlp"], hh))
        hh = constrain(hh + m, "act_btd")
        # cross K/V are position-independent: cache them for decode
        xk = (enc_out @ lp["xattn"]["wk"]).reshape(B, -1, cfg.kv_heads,
                                                   cfg.resolved_head_dim)
        xv = (enc_out @ lp["xattn"]["wv"]).reshape(B, -1, cfg.kv_heads,
                                                   cfg.resolved_head_dim)
        if cfg.qkv_bias:
            xk = xk + lp["xattn"]["bk"].reshape(cfg.kv_heads, -1)
            xv = xv + lp["xattn"]["bv"].reshape(cfg.kv_heads, -1)
        return hh, {"self": {"k": k, "v": v}, "cross": {"k": xk, "v": xv}}

    h, cache = scan_layers(cfg, body, h, params["layers"])
    h = apply_norm(cfg, params["ln_f"], h)
    logits = logits_fn(cfg, params, h[:, -1]).astype(jnp.float32)
    return logits, cache


def decode_step(cfg, params, cache, token, pos):
    B = token.shape[0]
    h = embed_tokens(cfg, params, token)
    table = sinusoidal_positions(MAX_DEC_POS, cfg.d_model)
    h = h + jax.lax.dynamic_slice_in_dim(table, pos, 1, axis=0).astype(h.dtype)

    def body(carry, xs):
        lp, cache_l = xs
        hh = carry
        hn = apply_norm(cfg, lp["ln_attn"], hh)
        a, new_self = attn.attn_decode(cfg, lp["attn"], hn, cache_l["self"],
                                       pos)
        hh = hh + a
        # cross-attention against the cached encoder K/V
        hn = apply_norm(cfg, lp["ln_xattn"], hh)
        q = (hn @ lp["xattn"]["wq"])
        if cfg.qkv_bias:
            q = q + lp["xattn"]["bq"]
        n, hd = cfg.n_heads, cfg.resolved_head_dim
        qh = q.reshape(B, 1, cfg.kv_heads, n // cfg.kv_heads, hd)
        lg = jnp.einsum("bskgh,btkh->bskgt", qh, cache_l["cross"]["k"],
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
        w = jax.nn.softmax(lg, axis=-1)
        o = jnp.einsum("bskgt,btkh->bskgh", w.astype(hh.dtype),
                       cache_l["cross"]["v"])
        x = o.reshape(B, 1, n * hd) @ lp["xattn"]["wo"]
        hh = hh + x
        m = apply_mlp(cfg, lp["mlp"], apply_norm(cfg, lp["ln_mlp"], hh))
        hh = hh + m
        return hh, {"self": new_self, "cross": cache_l["cross"]}

    h, new_cache = jax.lax.scan(body, h, (params["layers"], cache))
    h = apply_norm(cfg, params["ln_f"], h)
    logits = logits_fn(cfg, params, h[:, -1]).astype(jnp.float32)
    return logits, new_cache
