"""RG-LRU recurrent block (RecurrentGemma, arXiv:2402.19427).

    r_t = sigmoid(W_a x_t)           recurrence gate  (block-diag per head)
    i_t = sigmoid(W_x x_t)           input gate       (block-diag per head)
    a_t = exp(-c * softplus(L) * r_t),  c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The sequence recurrence is evaluated with ``jax.lax.associative_scan``
(parallel prefix over (a, b) pairs) — the TPU-native form; decode is the
single-step recurrence. The block follows the Griffin layout: two input
branches (GELU gate x conv1d->RG-LRU), merged then projected out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_keys

_C = 8.0
_CONV_W = 4


def init_rglru(cfg, key, dtype):
    d = cfg.d_model
    w = cfg.lru_width or d
    h = cfg.n_heads
    wh = w // h
    ks = split_keys(key, ["in_gate", "in_x", "conv", "wa", "wx", "lam", "out"])
    return {
        "rg_in_gate": dense_init(ks["in_gate"], (d, w), dtype=dtype),
        "rg_in_x": dense_init(ks["in_x"], (d, w), dtype=dtype),
        "rg_conv": dense_init(ks["conv"], (_CONV_W, w), dtype=dtype, scale=0.5),
        "rg_wa": dense_init(ks["wa"], (h, wh, wh), in_axis=1, dtype=jnp.float32),
        "rg_wx": dense_init(ks["wx"], (h, wh, wh), in_axis=1, dtype=jnp.float32),
        # init lambda so a ~ 0.9..0.999 at r=0.5
        "rg_lam": jnp.linspace(0.5, 4.0, w).astype(jnp.float32),
        "rg_out": dense_init(ks["out"], (w, d), dtype=dtype),
    }


def _gates(p, u, h, wh):
    """u: [B, S, W] (fp32) -> (a_gate, x_gate) via block-diagonal projections."""
    B, S, W = u.shape
    uh = u.reshape(B, S, h, wh)
    ra = jnp.einsum("bshw,hwv->bshv", uh, p["rg_wa"]).reshape(B, S, W)
    rx = jnp.einsum("bshw,hwv->bshv", uh, p["rg_wx"]).reshape(B, S, W)
    return jax.nn.sigmoid(ra), jax.nn.sigmoid(rx)


def _log_a(p, r):
    return -_C * jax.nn.softplus(p["rg_lam"]) * r      # [B, S, W], <= 0


def _causal_conv(p, u, state=None):
    """Depthwise causal conv, width 4. state: [B, 3, W] tail of prev inputs."""
    B, S, W = u.shape
    if state is None:
        pad = jnp.zeros((B, _CONV_W - 1, W), u.dtype)
    else:
        pad = state.astype(u.dtype)
    up = jnp.concatenate([pad, u], axis=1)
    out = sum(up[:, i:i + S] * p["rg_conv"][i] for i in range(_CONV_W))
    return out, up[:, -(_CONV_W - 1):]


def apply_rglru(cfg, p, x):
    """Full-sequence (train/prefill). x: [B, S, D] -> [B, S, D]."""
    h_heads = cfg.n_heads
    w = cfg.lru_width or cfg.d_model
    wh = w // h_heads
    gate = jax.nn.gelu(x @ p["rg_in_gate"], approximate=True)
    u = x @ p["rg_in_x"]
    u, _ = _causal_conv(p, u)
    uf = u.astype(jnp.float32)
    r, i = _gates(p, uf, h_heads, wh)
    log_a = _log_a(p, r)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, hseq = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (hseq.astype(x.dtype) * gate) @ p["rg_out"]
    return y


def init_rglru_state(cfg, batch, dtype):
    w = cfg.lru_width or cfg.d_model
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, _CONV_W - 1, w), dtype)}


def decode_rglru(cfg, p, x, state):
    """x: [B, 1, D]; state from init_rglru_state. Returns (y, new_state)."""
    h_heads = cfg.n_heads
    w = cfg.lru_width or cfg.d_model
    wh = w // h_heads
    gate = jax.nn.gelu(x @ p["rg_in_gate"], approximate=True)
    u = x @ p["rg_in_x"]
    u, conv_state = _causal_conv(p, u, state["conv"])
    uf = u.astype(jnp.float32)
    r, i = _gates(p, uf, h_heads, wh)
    log_a = _log_a(p, r)[:, 0]
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i[:, 0] * uf[:, 0])
    h_new = a * state["h"] + b
    y = (h_new[:, None].astype(x.dtype) * gate) @ p["rg_out"]
    return y, {"h": h_new, "conv": conv_state}
