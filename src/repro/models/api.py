"""Unified model API: family dispatch + input_specs (ShapeDtypeStruct
stand-ins for the dry-run; no device allocation)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, hybrid, ssm, transformer


_FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "encdec": encdec,
    "hybrid": hybrid,
    "ssm": ssm,
}


class ModelApi:
    """Thin namespace binding a config to its family implementation."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.mod = _FAMILY_MODULES[cfg.family]

    # --- parameters ---
    def init(self, key):
        return self.mod.init(self.cfg, key)

    def abstract_params(self):
        return jax.eval_shape(lambda: self.mod.init(
            self.cfg, jax.random.key(0)))

    # --- steps ---
    def loss(self, params, batch):
        return self.mod.loss(self.cfg, params, batch)

    def prefill(self, params, batch):
        return self.mod.prefill(self.cfg, params, batch)

    def decode_step(self, params, cache, token, pos):
        return self.mod.decode_step(self.cfg, params, cache, token, pos)

    def init_cache(self, batch: int, seq_len: int):
        return self.mod.init_cache(self.cfg, batch, seq_len)

    def abstract_cache(self, batch: int, seq_len: int):
        return jax.eval_shape(lambda: self.init_cache(batch, seq_len))

    # --- dry-run input specs ---
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        sds = jax.ShapeDtypeStruct
        dt = jnp.dtype(cfg.dtype)
        if shape.kind == "train":
            specs = {"tokens": sds((B, S), jnp.int32),
                     "labels": sds((B, S), jnp.int32)}
        elif shape.kind == "prefill":
            specs = {"tokens": sds((B, S), jnp.int32)}
        else:  # decode: one new token against a seq_len cache
            specs = {"token": sds((B, 1), jnp.int32),
                     "pos": sds((), jnp.int32)}
        if cfg.family == "encdec" and shape.kind != "decode":
            specs["frames"] = sds((B, cfg.enc_frames, cfg.d_model), dt)
        if cfg.vis_tokens and shape.kind != "decode":
            specs["patches"] = sds((B, cfg.vis_tokens, cfg.d_model), dt)
        return specs


def get_model(cfg: ModelConfig) -> ModelApi:
    return ModelApi(cfg)
