"""pHNSW retrieval attention — the paper's 3-step filter applied to
long-context decode (DESIGN.md section 4).

Attending to a 524288-entry KV cache is a nearest-neighbor problem: the
query vector wants the keys with the highest dot products. We map the
paper's pipeline onto it per attention head:

  Step 1 (PCA):   keys are projected to ``d_low`` with a fixed
                  orthonormal projection stored with the model (the
                  streaming analogue of the paper's offline PCA; for
                  dot-product search an orthonormal JL projection
                  preserves score ordering the way PCA preserves L2).
                  The low-dim keys are stored INLINE in the cache —
                  layout (3): regular access to the filter data.
  Step 2 (filter): low-dim scores over the whole cache (d_low/head_dim
                  of the full cost), block-max pooled (``block`` KV
                  positions per index entry), local top-k per cache
                  PARTITION — the kSort.L filter, kept partition-local
                  so a sequence-sharded cache never gathers globally.
  Step 3 (rerank): exact attention over the gathered candidate blocks
                  only — k irregular-but-block-contiguous fetches, the
                  same "irregular accesses bounded by k" guarantee as
                  the processor's AGU/DMA path.

Partition-local retrieval + full-softmax merge across partitions is the
distributed-pHNSW design (core/distributed.py) applied inside attention:
per-shard search, collective-light merge (GSPMD turns the softmax over
the partition axis into the flash-decoding all-reduce).

HBM math for llama3-405b long_500k (per layer, per step): full attention
reads 2 x T x KV x Hd x 2B = 2.1 GB; retrieval reads T x KV x d_low x 2B
(low keys) + topk x KV x 2 x Hd x 2B = 134 MB + ~2 MB — a ~16x cut in the
term that dominates decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.rope import apply_rope

NEG_INF = -1e30


def init_retrieval(cfg, key, dtype):
    """Orthonormal per-head-dim projection [Hd, d_low] (the 'PCA' matrix)."""
    hd, dl = cfg.resolved_head_dim, cfg.retrieval.d_low
    a = jax.random.normal(key, (hd, hd), jnp.float32)
    qm, _ = jnp.linalg.qr(a)
    return {"rp_proj": qm[:, :dl].astype(jnp.float32)}


def project_low(p, k):
    """k: [..., Hd] -> [..., d_low] low-dim keys (Step 1)."""
    return (k.astype(jnp.float32) @ p["rp_proj"]).astype(k.dtype)


def retrieval_decode_attention(cfg, p, q, cache_k, cache_v, cache_klow,
                               pos):
    """One-token retrieval attention, PARTITION-MAJOR formulation.

    q: [B, 1, N, Hd] (rope applied); cache_k/v: [B, T, KV, Hd];
    cache_klow: [B, T, KV, dl]; pos: scalar int32. Returns [B, 1, N, Hd].

    The cache sequence axis is reshaped to (nP, T/nP) with nP aligned to
    the mesh's cache shards. Every op then carries the nP axis:
      * low-dim scores + block-max pooling per partition (Step 2);
      * top-nb blocks per partition via ``take_along_axis`` along the
        UNSHARDED within-partition axis — the gather stays shard-local;
      * exact scores over selected blocks (Step 3), with the softmax
        max/sum and the PV contraction reducing over nP — GSPMD turns
        those into tiny [B,KV,G(,Hd)] all-reduces (the flash-decoding
        merge), never a cache-sized collective.
    v1 of this function flattened partitions before gathering; GSPMD
    all-gathered the whole low-dim cache (llama3-405b long_500k:
    1.44 s of collectives/step). See EXPERIMENTS.md §Perf iteration 1.
    """
    B, _, N, Hd = q.shape
    T, KV = cache_k.shape[1], cache_k.shape[2]
    rcfg = cfg.retrieval
    G = N // KV
    blk = rcfg.block
    n_blocks = T // blk
    nP = max(1, min(rcfg.partitions, n_blocks))
    pp = n_blocks // nP                  # blocks per partition
    tpp = pp * blk                       # tokens per partition
    nb = min(max(1, rcfg.topk // blk // nP), pp)   # blocks kept/partition
    scale = Hd ** -0.5

    # ---- Step 2: low-dim scores, partition-major ----
    # operands stay bf16 (f32 accumulate): casting k_low to f32 would
    # double the dominant HBM read (§Perf iteration 2)
    q_low = project_low(p, q).reshape(B, KV, G, -1)
    klow_p = cache_klow.reshape(B, nP, tpp, KV, -1)
    lg_low = jnp.einsum("bkgc,bptkc->bkgpt", q_low, klow_p,
                        preferred_element_type=jnp.float32)  # [B,KV,G,nP,tpp]
    tpos = (jnp.arange(nP)[:, None] * tpp
            + jnp.arange(tpp)[None, :]).astype(jnp.int32)    # [nP, tpp]
    lg_low = jnp.where((tpos <= pos)[None, None, None], lg_low, NEG_INF)
    # block score pooled over (blk positions) AND the G heads of the GQA
    # group: the group SHARES one candidate set, so the Step-3 gather is
    # per KV head, not per q-head (a per-q-head gather multiplies the
    # fetched volume by G=16 and re-reads the whole cache at T=32k —
    # §Perf iteration 3's refuted first attempt)
    bs = lg_low.reshape(B, KV, G, nP, pp, blk).max((-1, 2))  # [B,KV,nP,pp]
    _, top_idx = jax.lax.top_k(bs, nb)                       # [B,KV,nP,nb]

    # ---- Step 3: shard-local block gather + exact attention ----
    kb = cache_k.reshape(B, nP, pp, blk, KV, Hd)
    vb = cache_v.reshape(B, nP, pp, blk, KV, Hd)
    # operand [B,KV,nP,pp,blk,Hd]; indices [B,KV,nP,nb,1,1] -> gather
    # along the (unsharded) pp axis
    kb = jnp.moveaxis(kb, 4, 1)                              # [B,KV,nP,pp,blk,Hd]
    vb = jnp.moveaxis(vb, 4, 1)
    idx = top_idx[..., None, None]                           # [B,KV,nP,nb,1,1]
    k_sel = jnp.take_along_axis(kb, idx, axis=3)             # [B,KV,nP,nb,blk,Hd]
    v_sel = jnp.take_along_axis(vb, idx, axis=3)
    qh = q.reshape(B, KV, G, Hd)
    lg = jnp.einsum("bkgh,bkpnth->bkgpnt", qh, k_sel,
                    preferred_element_type=jnp.float32) * scale
    sel_pos = (jnp.arange(nP, dtype=jnp.int32)[:, None, None] * tpp
               + top_idx[..., None] * blk
               + jnp.arange(blk, dtype=jnp.int32))           # [B,KV,nP,nb,blk]
    lg = jnp.where(sel_pos[:, :, None] <= pos, lg, NEG_INF)
    # flash-decoding merge over (nP, nb, blk): reductions over nP are the
    # only cross-shard ops, each [B,KV,G(,Hd)]-sized
    m = jnp.max(lg, axis=(3, 4, 5), keepdims=True)
    m = jnp.maximum(m, NEG_INF / 2)
    e = jnp.exp(lg - m)
    denom = jnp.sum(e, axis=(3, 4, 5))                       # [B,KV,G]
    o = jnp.einsum("bkgpnt,bkpnth->bkgh", e.astype(v_sel.dtype), v_sel)
    o = o / jnp.maximum(denom, 1e-30)[..., None].astype(o.dtype)
    return o.reshape(B, 1, N, Hd)
