"""Norms, MLPs, embeddings, and the chunked cross-entropy loss."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_keys


# ----------------------------- norms --------------------------------------

def init_norm(cfg, key, width=None):
    d = width or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(cfg, p, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"]
    return y.astype(x.dtype)


# ----------------------------- MLPs ---------------------------------------

def init_mlp(cfg, key, dtype):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp in ("swiglu", "geglu"):
        ks = split_keys(key, ["w_gate", "w_up", "w_down"])
        return {
            "w_gate": dense_init(ks["w_gate"], (d, f), dtype=dtype),
            "w_up": dense_init(ks["w_up"], (d, f), dtype=dtype),
            "w_down": dense_init(ks["w_down"], (f, d), dtype=dtype),
        }
    # plain gelu MLP (whisper, starcoder2)
    ks = split_keys(key, ["w_up", "w_down"])
    return {
        "w_up": dense_init(ks["w_up"], (d, f), dtype=dtype),
        "b_up": jnp.zeros((f,), dtype),
        "w_down": dense_init(ks["w_down"], (f, d), dtype=dtype),
        "b_down": jnp.zeros((d,), dtype),
    }


def apply_mlp(cfg, p, x):
    if cfg.mlp == "swiglu":
        g = jax.nn.silu(x @ p["w_gate"])
        return (g * (x @ p["w_up"])) @ p["w_down"]
    if cfg.mlp == "geglu":
        g = jax.nn.gelu(x @ p["w_gate"], approximate=True)
        return (g * (x @ p["w_up"])) @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_up"] + p["b_up"], approximate=True)
    return h @ p["w_down"] + p["b_down"]


# ------------------------- embeddings / head -------------------------------

def init_embed(cfg, key, dtype):
    ks = split_keys(key, ["emb", "lm_head"])
    p = {"emb": (jax.random.normal(ks["emb"], (cfg.vocab, cfg.d_model),
                                   jnp.float32) * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks["lm_head"], (cfg.d_model, cfg.vocab),
                                  dtype=dtype)
    return p


def embed_tokens(cfg, p, tokens):
    return jnp.take(p["emb"], tokens, axis=0)


def head_matrix(cfg, p):
    return p["emb"].T if cfg.tie_embeddings else p["lm_head"]


def logits_fn(cfg, p, h):
    return h @ head_matrix(cfg, p)


# ------------------------- chunked XENT loss --------------------------------
# Never materialize [B, S, V] logits: scan over sequence chunks. For
# llama3-405b train_4k this is the difference between 269 GB of logits and
# ~2 GB of live chunk. (Recorded as a baseline memory optimization in
# EXPERIMENTS.md §Perf.)

def chunked_xent(cfg, p, h, labels, mask=None, chunk=512):
    """h: [B, S, D]; labels: [B, S] int32; returns mean NLL over mask."""
    B, S, D = h.shape
    W = head_matrix(cfg, p)
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    chunk = min(chunk, S)
    n_chunks = S // chunk
    rem = S - n_chunks * chunk

    @jax.checkpoint
    def one(hc, lc, mc):
        # checkpointed: the [B, c, V] logits are recomputed in backward
        # instead of being stored per scan step (13 GB/device saved on
        # starcoder2 train_4k; see EXPERIMENTS.md §Perf)
        lg = (hc @ W).astype(jnp.float32)                  # [B, c, V]
        lse = jax.nn.logsumexp(lg, axis=-1)
        tgt = jnp.take_along_axis(lg, lc[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - tgt) * mc), jnp.sum(mc)

    def body(carry, xs):
        tot, cnt = carry
        hc, lc, mc = xs
        l, c = one(hc, lc, mc)
        return (tot + l, cnt + c), None

    hs = h[:, :n_chunks * chunk].reshape(B, n_chunks, chunk, D).swapaxes(0, 1)
    ls = labels[:, :n_chunks * chunk].reshape(B, n_chunks, chunk).swapaxes(0, 1)
    ms = mask[:, :n_chunks * chunk].reshape(B, n_chunks, chunk).swapaxes(0, 1)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                 (hs, ls, ms))
    if rem:
        l, c = one(h[:, -rem:], labels[:, -rem:], mask[:, -rem:])
        tot, cnt = tot + l, cnt + c
    return tot / jnp.maximum(cnt, 1.0)
