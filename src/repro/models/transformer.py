"""Decoder-only transformer covering the dense / moe / vlm families.

Layers are stacked along a leading axis and executed with ``lax.scan`` so
HLO size is O(1) in depth — essential for the 40-cell x 2-mesh dry-run
compile budget. Remat policy wraps the scan body.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.common import (dtype_of, maybe_remat, scan_layers,
                                 split_keys, stack_layers)
from repro.models.layers import (apply_mlp, apply_norm, chunked_xent,
                                 embed_tokens, init_embed, init_mlp, init_norm,
                                 logits_fn)
from repro.distributed.sharding import constrain


# ----------------------------- init ----------------------------------------

def _init_layer(cfg, key, dtype):
    ks = split_keys(key, ["attn", "mlp", "n1", "n2"])
    p = {
        "ln_attn": init_norm(cfg, ks["n1"]),
        "attn": attn.init_attn(cfg, ks["attn"], dtype),
        "ln_mlp": init_norm(cfg, ks["n2"]),
    }
    if cfg.moe is not None:
        p["moe"] = moe_mod.init_moe(cfg, ks["mlp"], dtype)
    else:
        p["mlp"] = init_mlp(cfg, ks["mlp"], dtype)
    return p


def init(cfg, key):
    dtype = dtype_of(cfg)
    ks = split_keys(key, ["emb", "layers", "lnf", "vis"])
    params = {
        **init_embed(cfg, ks["emb"], dtype),
        "layers": stack_layers(lambda k: _init_layer(cfg, k, dtype),
                               ks["layers"], cfg.n_layers),
        "ln_f": init_norm(cfg, ks["lnf"]),
    }
    if cfg.vis_tokens:
        from repro.models.common import dense_init
        params["vis_proj"] = dense_init(ks["vis"], (cfg.d_model, cfg.d_model),
                                        dtype=dtype)
    return params


# --------------------------- forward (full-seq) -----------------------------

def _layer_fwd(cfg, lp, h, positions):
    a = attn.attn_forward(cfg, lp["attn"], apply_norm(cfg, lp["ln_attn"], h),
                          positions)
    h = constrain(h + a, "act_btd")
    hn = apply_norm(cfg, lp["ln_mlp"], h)
    if cfg.moe is not None:
        m, aux = moe_mod.apply_moe(cfg, lp["moe"], hn)
    else:
        m, aux = apply_mlp(cfg, lp["mlp"], hn), {}
    h = constrain(h + m, "act_btd")
    return h, aux


def forward_hidden(cfg, params, h, positions):
    """h: [B, S, D] embedded inputs -> final hidden [B, S, D] (+ moe aux)."""
    def body(carry, lp):
        return _layer_fwd(cfg, lp, carry, positions)
    h, aux = scan_layers(cfg, body, h, params["layers"])
    h = apply_norm(cfg, params["ln_f"], h)
    aux = {k: jnp.mean(v) for k, v in aux.items()} if aux else {}
    return h, aux


def _embed_inputs(cfg, params, batch) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (h [B, S_total, D], positions [S_total], loss_mask [B, S_total])."""
    tokens = batch["tokens"]
    h = embed_tokens(cfg, params, tokens)
    B, S = tokens.shape
    mask = jnp.ones((B, S), jnp.float32)
    if cfg.vis_tokens:
        vis = batch["patches"].astype(h.dtype) @ params["vis_proj"]
        h = jnp.concatenate([vis, h], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros((B, cfg.vis_tokens), jnp.float32), mask], axis=1)
    positions = jnp.arange(h.shape[1], dtype=jnp.int32)
    return h, positions, mask


def loss(cfg, params, batch) -> Tuple[jax.Array, Dict[str, Any]]:
    h, positions, mask = _embed_inputs(cfg, params, batch)
    h = constrain(h, "act_btd")
    h, aux = forward_hidden(cfg, params, h, positions)
    labels = batch["labels"]
    if cfg.vis_tokens:   # logits only over text positions
        h = h[:, cfg.vis_tokens:]
        mask = mask[:, cfg.vis_tokens:]
    nll = chunked_xent(cfg, params, h, labels, mask)
    metrics = {"loss": nll, **aux}
    total = nll
    if cfg.moe is not None and "aux_loss" in aux:
        total = total + cfg.moe.aux_loss_weight * aux["aux_loss"]
    return total, metrics


# ----------------------------- prefill / decode -----------------------------

def init_cache(cfg, batch: int, seq_len: int):
    dtype = dtype_of(cfg)
    one = attn.init_cache(cfg, batch, seq_len, dtype)
    zeros_like_stacked = jax.tree.map(
        lambda x: jnp.zeros((cfg.n_layers,) + x.shape, x.dtype), one)
    return zeros_like_stacked


def prefill(cfg, params, batch):
    """Run the prompt, return (last-token logits [B, V], cache).
    Cache seq dim == prompt length (the dry-run 'prefill' cell); decode
    continues in a caller-provided longer cache in the serving engine."""
    h, positions, _ = _embed_inputs(cfg, params, batch)
    h = constrain(h, "act_btd")

    def body(carry, lp):
        hh = carry
        hn = apply_norm(cfg, lp["ln_attn"], hh)
        a, (k, v) = attn.attn_prefill(cfg, lp["attn"], hn, positions,
                                      cache_len=h.shape[1])
        hh = constrain(hh + a, "act_btd")
        hn = apply_norm(cfg, lp["ln_mlp"], hh)
        if cfg.moe is not None:
            m, _ = moe_mod.apply_moe(cfg, lp["moe"], hn)
        else:
            m = apply_mlp(cfg, lp["mlp"], hn)
        hh = constrain(hh + m, "act_btd")
        return hh, {"k": k, "v": v}

    h, cache = scan_layers(cfg, body, h, params["layers"])
    h = apply_norm(cfg, params["ln_f"], h)
    logits = logits_fn(cfg, params, h[:, -1]).astype(jnp.float32)
    if cfg.window:   # bound the cache to the attention window
        cache = jax.tree.map(lambda x: x[:, :, -min(cfg.window, x.shape[2]):],
                             cache)
    return logits, cache


def decode_step(cfg, params, cache, token, pos):
    """token: [B, 1] int32; pos: scalar int32 (current position).
    Returns (logits [B, V], new_cache)."""
    h = embed_tokens(cfg, params, token)

    def body(carry, xs):
        lp, cache_l = xs
        hh = carry
        hn = apply_norm(cfg, lp["ln_attn"], hh)
        a, new_cache = attn.attn_decode(cfg, lp["attn"], hn, cache_l, pos)
        hh = hh + a
        hn = apply_norm(cfg, lp["ln_mlp"], hh)
        if cfg.moe is not None:
            m, _ = moe_mod.apply_moe(cfg, lp["moe"], hn, capacity_factor=2.0)
        else:
            m = apply_mlp(cfg, lp["mlp"], hn)
        hh = hh + m
        return hh, new_cache

    h, new_cache = jax.lax.scan(body, h, (params["layers"], cache))
    h = apply_norm(cfg, params["ln_f"], h)
    logits = logits_fn(cfg, params, h[:, -1]).astype(jnp.float32)
    return logits, new_cache
