"""RWKV6 "Finch" (arXiv:2404.05892): attention-free sequence mixer with
data-dependent per-channel decay.

Per head (state S in R^{hd x hd}):
    out_t = r_t @ (S_{t-1} + diag(u) k_t v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T
    w_t   = exp(-exp(w0 + lora_w(x_t)))          (data-dependent decay)

Training/prefill use the CHUNKED PARALLEL form (the TPU-native adaptation:
intra-chunk work is MXU matmuls over [c, hd] blocks; inter-chunk state is
a short ``lax.scan``), decode is the O(1) recurrence. The decay exponent
is clamped so fp32 within-chunk cumulative products cannot underflow.

Simplification vs the full Finch recipe (documented in DESIGN.md): the
token-shift interpolation uses static mu for r/k/v/g and keeps the
low-rank *data-dependent* path only for the decay w — the defining Finch
feature. Channel-mix is the standard relu^2 form.

The paper's PCA-filtering technique has no analogue here (no candidate
neighbor set to filter) — see DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_keys

_CHUNK = 16
_LORA_R = 64
_CLAMP = 5.0   # |log decay| per step; 16 * 5 = 80 < fp32 exp range (~87)


def init_rwkv_tmix(cfg, key, dtype):
    d = cfg.d_model
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    ks = split_keys(key, ["r", "k", "v", "g", "o", "w0", "la", "lb", "u", "ln"])
    return {
        "w_r": dense_init(ks["r"], (d, d), dtype=dtype),
        "w_k": dense_init(ks["k"], (d, d), dtype=dtype),
        "w_v": dense_init(ks["v"], (d, d), dtype=dtype),
        "w_g": dense_init(ks["g"], (d, d), dtype=dtype),
        "w_o": dense_init(ks["o"], (d, d), dtype=dtype),
        "w0": jnp.zeros((d,), jnp.float32) - 0.6,        # base log-log decay
        "lw_a": dense_init(ks["la"], (d, _LORA_R), dtype=jnp.float32),
        "lw_b": dense_init(ks["lb"], (_LORA_R, d), dtype=jnp.float32, scale=0.1),
        "u": (jax.random.normal(ks["u"], (h, hd), jnp.float32) * 0.1),
        "mu": jnp.full((5, d), 0.5, jnp.float32),        # shift mix r,k,v,g,w
        "gn_scale": jnp.ones((d,), jnp.float32),
    }


def _shift(x, prev=None):
    """Token shift: x_{t-1} (zeros / carried state at t=0)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _mix_inputs(p, x, xprev):
    mu = p["mu"]
    mix = lambda i: x + (xprev - x) * mu[i].astype(x.dtype)
    return mix(0), mix(1), mix(2), mix(3), mix(4)


def _log_decay(p, xw):
    """per-channel log decay in [-_CLAMP, -1e-4]."""
    lw = p["w0"] + jnp.tanh(xw.astype(jnp.float32) @ p["lw_a"]) @ p["lw_b"]
    return -jnp.clip(jnp.exp(lw), 1e-4, _CLAMP)


def _group_norm(p, o, h):
    """LayerNorm per head (RWKV 'group_norm' on [B, S, H, hd])."""
    mu = o.mean(-1, keepdims=True)
    var = o.var(-1, keepdims=True)
    o = (o - mu) * jax.lax.rsqrt(var + 64e-5)
    B, S = o.shape[:2]
    return o.reshape(B, S, -1) * p["gn_scale"]


def tmix_forward(cfg, p, x, state=None):
    """x: [B, S, D]. state: optional {"x_prev": [B,1,D], "S": [B,H,hd,hd]}.
    Returns (y, new_state). S must be a multiple of _CHUNK (all assigned
    shapes are powers of two) or a single step."""
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    xprev = _shift(x, None if state is None else state["x_prev"])
    xr, xk, xv, xg, xw = _mix_inputs(p, x, xprev)
    r = (xr @ p["w_r"]).reshape(B, S, H, hd).astype(jnp.float32)
    k = (xk @ p["w_k"]).reshape(B, S, H, hd).astype(jnp.float32)
    v = (xv @ p["w_v"]).reshape(B, S, H, hd).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["w_g"])
    logw = _log_decay(p, xw).reshape(B, S, H, hd)            # [B,S,H,hd]
    u = p["u"]

    S0 = jnp.zeros((B, H, hd, hd), jnp.float32) if state is None else state["S"]

    if S == 1:   # decode fast-path
        out = jnp.einsum("bhk,bhkv->bhv", r[:, 0],
                         S0 + u[None, :, :, None] * k[:, 0][..., None]
                         * v[:, 0][:, :, None, :])
        S1 = jnp.exp(logw[:, 0])[..., None] * S0 \
            + k[:, 0][..., None] * v[:, 0][:, :, None, :]
        o = out[:, None]                                      # [B,1,H,hd]
    else:
        c = min(_CHUNK, S)
        while S % c:       # assigned shapes are powers of two; tests aren't
            c -= 1
        n = S // c
        rc = r.reshape(B, n, c, H, hd).transpose(1, 0, 3, 2, 4)   # [n,B,H,c,hd]
        kc = k.reshape(B, n, c, H, hd).transpose(1, 0, 3, 2, 4)
        vc = v.reshape(B, n, c, H, hd).transpose(1, 0, 3, 2, 4)
        wc = logw.reshape(B, n, c, H, hd).transpose(1, 0, 3, 2, 4)

        causal = jnp.tril(jnp.ones((c, c), jnp.float32), k=-1)    # strict

        def chunk_step(Sin, xs):
            rb, kb, vb, wb = xs                                   # [B,H,c,hd]
            cum = jnp.cumsum(wb, axis=2)                          # inclusive logP
            pex = cum - wb                                        # exclusive
            r_t = rb * jnp.exp(pex)
            k_t = kb * jnp.exp(-cum)
            # intra attention: A[t,s] = sum_k r[t]k[s]exp(pex[t]-cum[s]), s<t
            intra = jnp.einsum("bhtk,bhsk->bhts", r_t, k_t) * causal
            diag = jnp.einsum("bhtk,bhtk->bht", rb * u[None, :, None, :], kb)
            out = jnp.einsum("bhts,bhsv->bhtv", intra, vb) \
                + diag[..., None] * vb \
                + jnp.einsum("bhtk,bhkv->bhtv", r_t, Sin)
            Pc = cum[:, :, -1]                                    # [B,H,hd]
            Snew = jnp.exp(Pc)[..., None] * Sin \
                + jnp.einsum("bhsk,bhsv->bhkv", k_t * jnp.exp(Pc)[:, :, None, :], vb)
            return Snew, out

        S1, outs = jax.lax.scan(chunk_step, S0, (rc, kc, vc, wc))
        # outs: [n, B, H, c, hd] -> [B, n, c, H, hd] -> [B, S, H, hd]
        o = outs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, hd)

    o = _group_norm(p, o.reshape(B, S, H, hd), H).astype(x.dtype)
    y = (o * g) @ p["w_o"]
    new_state = {"x_prev": x[:, -1:], "S": S1}
    return y, new_state


def init_rwkv_cmix(cfg, key, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = split_keys(key, ["k", "v", "r"])
    return {
        "c_wk": dense_init(ks["k"], (d, f), dtype=dtype),
        "c_wv": dense_init(ks["v"], (f, d), dtype=dtype),
        "c_wr": dense_init(ks["r"], (d, d), dtype=dtype),
        "c_mu": jnp.full((2, d), 0.5, jnp.float32),
    }


def cmix_forward(cfg, p, x, state=None):
    xprev = _shift(x, None if state is None else state["x_prev"])
    mu = p["c_mu"].astype(x.dtype)
    xk = x + (xprev - x) * mu[0]
    xr = x + (xprev - x) * mu[1]
    rgate = jax.nn.sigmoid(xr @ p["c_wr"])
    h = jnp.square(jax.nn.relu(xk @ p["c_wk"]))
    y = rgate * (h @ p["c_wv"])
    return y, {"x_prev": x[:, -1:]}
