"""Mixture-of-Experts FFN with top-k routing and capacity-based token
dispatch (sorted, dropped-token formulation).

Dispatch strategy: assignments are sorted by expert id, ranked within
their expert group, and scattered into a dense [E, C, D] buffer — a
static-shape formulation that shards cleanly: experts over the ``model``
mesh axis (expert parallelism) when E divides the axis, otherwise
per-expert tensor parallelism over d_ff. Tokens past capacity are dropped
(standard GShard/Switch behavior) and counted in the router metrics.

The router's top-k selection is the same "filter a candidate set down to
k" primitive the paper builds kSort.L for — ``repro/kernels/ksort_l``
implements it as a comparison-matrix Pallas kernel; here we use
``lax.top_k`` for the XLA path.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_keys


def init_moe(cfg, key, dtype):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    ks = split_keys(key, ["router", "e_gate", "e_up", "e_down"])
    return {
        "router": dense_init(ks["router"], (d, E), dtype=jnp.float32),
        "e_gate": dense_init(ks["e_gate"], (E, d, f), in_axis=1, dtype=dtype),
        "e_up": dense_init(ks["e_up"], (E, d, f), in_axis=1, dtype=dtype),
        "e_down": dense_init(ks["e_down"], (E, f, d), in_axis=1, dtype=dtype),
    }


def apply_moe(cfg, p, x, *, capacity_factor: float = 1.25):
    """x: [B, S, D] -> ([B, S, D], aux_metrics).

    Dispatch strategy is chosen by context:
      * Under a known mesh with E divisible by the ``model`` axis, the
        EXPLICIT shard_map path (`_apply_moe_sharded`): activations are
        replicated over ``model`` (batch shards over ``data``), so every
        chip already holds its tokens — each expert-shard masks the
        assignments it owns, runs a purely LOCAL capacity dispatch, and
        the combine is one psum over ``model`` ([T_local, D], the same
        volume dense TP pays for its down-projection all-reduce).
        The naive global-scatter formulation forced GSPMD to move the
        [E, C, D] buffer across shards every layer — 268 s of collectives
        per step on qwen3-235b train_4k (§Perf iteration on this cell).
      * Otherwise: the single-device scatter path below.
    """
    from repro.distributed.sharding import current_mesh
    mesh = current_mesh()
    if (mesh is not None and "model" in mesh.axis_names
            and cfg.moe.n_experts % mesh.shape["model"] == 0
            and mesh.shape["model"] > 1):
        return _apply_moe_sharded(cfg, p, x, mesh,
                                  capacity_factor=capacity_factor)
    return _apply_moe_local(cfg, p, x, capacity_factor=capacity_factor)


def _apply_moe_local(cfg, p, x, *, capacity_factor: float = 1.25):
    B, S, D = x.shape
    E, K = cfg.moe.n_experts, cfg.moe.experts_per_tok
    T = B * S
    xf = x.reshape(T, D)

    logits = (xf.astype(jnp.float32) @ p["router"])          # [T, E]
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(gates, K)                   # [T, K]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- load-balancing aux loss (Switch-style) ----
    me = jnp.mean(gates, axis=0)                             # [E]
    ce = jnp.mean(
        (jax.nn.one_hot(top_e, E, dtype=jnp.float32).sum(1)), axis=0)
    aux = E * jnp.sum(me * ce)

    # ---- sort-based capacity dispatch ----
    C = int(max(K, round(T * K / E * capacity_factor)))
    C = min(C, T)
    flat_e = top_e.reshape(-1)                               # [T*K]
    flat_w = top_w.reshape(-1)
    tok_of = jnp.arange(T * K, dtype=jnp.int32) // K
    order = jnp.argsort(flat_e, stable=True)                 # group by expert
    se, sw, stok = flat_e[order], flat_w[order], tok_of[order]
    ar = jnp.arange(T * K, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool), se[1:] != se[:-1]])
    group_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, ar, 0))
    rank = ar - group_start                                  # pos within expert
    keep = rank < C
    dropped = jnp.sum(1.0 - keep.astype(jnp.float32)) / (T * K)

    buf = jnp.zeros((E, C, D), x.dtype)
    buf = buf.at[se, jnp.minimum(rank, C - 1)].add(
        xf[stok] * keep[:, None].astype(x.dtype), mode="drop")

    h = jnp.einsum("ecd,edf->ecf", buf, p["e_gate"])
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, p["e_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["e_down"])     # [E, C, D]

    contrib = out_buf[se, jnp.minimum(rank, C - 1)]          # [T*K, D]
    contrib = contrib * (sw * keep.astype(jnp.float32)).astype(x.dtype)[:, None]
    y = jnp.zeros((T, D), x.dtype).at[stok].add(contrib, mode="drop")

    metrics = {"aux_loss": aux, "dropped_frac": dropped}
    return y.reshape(B, S, D), metrics


# ---------------------------------------------------------------------------
# explicit expert-parallel dispatch (shard_map over the model axis)
# ---------------------------------------------------------------------------

def _apply_moe_sharded(cfg, p, x, mesh, *, capacity_factor: float = 1.25):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import batch_axes

    B, S, D = x.shape
    E, K = cfg.moe.n_experts, cfg.moe.experts_per_tok
    m_size = mesh.shape["model"]
    E_loc = E // m_size
    b_ax = batch_axes(mesh)
    b_size = 1
    for a in b_ax:
        b_size *= mesh.shape[a]
    bspec = b_ax if (B % b_size == 0 and B >= b_size) else \
        (b_ax[:1] if B % mesh.shape[b_ax[0]] == 0 else None)

    def local(xl, router, eg, eu, ed):
        # xl: [B_l, S, D] (this data-shard's tokens, replicated over model)
        # eg/eu/ed: [E_loc, ...] this model-shard's experts
        Bl = xl.shape[0]
        T = Bl * S
        xf = xl.reshape(T, D)
        logits = xf.astype(jnp.float32) @ router              # [T, E]
        gates = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(gates, K)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
        me = jnp.mean(gates, axis=0)
        ce = jnp.mean(jax.nn.one_hot(top_e, E, dtype=jnp.float32).sum(1), 0)
        aux = E * jnp.sum(me * ce)
        # ---- mask to the experts THIS shard owns ----
        shard = jax.lax.axis_index("model")
        elo = shard * E_loc
        local_e = top_e - elo                                  # [T, K]
        mine = (local_e >= 0) & (local_e < E_loc)
        flat_e = jnp.where(mine, local_e, E_loc).reshape(-1)   # E_loc = trash
        flat_w = jnp.where(mine, top_w, 0.0).reshape(-1)
        tok_of = jnp.arange(T * K, dtype=jnp.int32) // K
        # capacity per local expert (per data-shard token pool)
        C = int(max(K, round(T * K / E * capacity_factor)))
        C = min(C, T)
        order = jnp.argsort(flat_e, stable=True)
        se, sw, stok = flat_e[order], flat_w[order], tok_of[order]
        ar = jnp.arange(T * K, dtype=jnp.int32)
        is_start = jnp.concatenate([jnp.ones((1,), bool), se[1:] != se[:-1]])
        group_start = jax.lax.associative_scan(
            jnp.maximum, jnp.where(is_start, ar, 0))
        rank = ar - group_start
        keep = (rank < C) & (se < E_loc)
        n_dropped = jnp.sum((rank >= C) & (se < E_loc))
        buf = jnp.zeros((E_loc + 1, C, D), xl.dtype)
        buf = buf.at[se, jnp.minimum(rank, C - 1)].add(
            xf[stok] * keep[:, None].astype(xl.dtype), mode="drop")
        buf = buf[:E_loc]
        h = jnp.einsum("ecd,edf->ecf", buf, eg)
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, eu)
        out_buf = jnp.einsum("ecf,efd->ecd", h, ed)            # [E_loc, C, D]
        out_buf = jnp.concatenate(
            [out_buf, jnp.zeros((1, C, D), out_buf.dtype)], axis=0)
        contrib = out_buf[se, jnp.minimum(rank, C - 1)]
        contrib = contrib * (sw * keep.astype(jnp.float32)
                             ).astype(xl.dtype)[:, None]
        y = jnp.zeros((T, D), xl.dtype).at[stok].add(contrib, mode="drop")
        # ---- combine across expert shards: the ONLY collective ----
        y = jax.lax.psum(y, "model")
        drop_frac = jax.lax.psum(n_dropped.astype(jnp.float32),
                                 "model") / (T * K)
        return y.reshape(Bl, S, D), aux, drop_frac

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(bspec, None, None), P(), P()),
        check_rep=False)
    y, aux, dropped = fn(x, p["router"], p["e_gate"], p["e_up"], p["e_down"])
    return y, {"aux_loss": aux, "dropped_frac": dropped}
