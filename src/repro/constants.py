"""Shared numeric sentinels for the traversal path.

One definition, imported by the kernels (``kernels/*.py``), the oracles
(``kernels/ref.py``) and the batched engine (``core/search_jax.py``) —
these three MUST agree bit-for-bit or masked slots stop round-tripping
between kernel calls.

``INF`` is deliberately a large FINITE f32 (not ``jnp.inf``) so
arithmetic on padded/filtered slots never produces NaNs; callers test
``d < VALID_MAX`` to detect real entries. ``NEG_INF`` plays the same
role for attention logits.
"""
from __future__ import annotations

# "filtered out / empty slot" distance sentinel on the traversal path
INF = 3.4e38
# validity threshold: any distance >= VALID_MAX is a masked slot
VALID_MAX = 1e37
# attention-logit mask value
NEG_INF = -1e30
