"""pHNSW retrieval attention on a long-context decode: the paper's
3-step filter (PCA project -> low-dim top-k -> exact rerank) applied to
a transformer KV cache.

Runs a small dense model twice over the same 2048-token cache — exact
attention vs retrieval attention — and reports agreement of the decoded
tokens plus the HBM-traffic arithmetic at the production long_500k shape.

    PYTHONPATH=src python examples/long_context_decode.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.configs.base import RetrievalConfig
from repro.models import get_model

T = 2048
STEPS = 48

base = get_smoke_config("llama3-405b").replace(n_layers=4, d_model=128,
                                               n_heads=8, kv_heads=2,
                                               head_dim=32)
retr = base.replace(retrieval=RetrievalConfig(enabled=True, d_low=16,
                                              topk=512, block=16))
api_d, api_r = get_model(base), get_model(retr)
params = api_r.init(jax.random.key(0))
params_d = dict(params)        # dense model ignores rp_proj
params_d["layers"] = jax.tree.map(lambda x: x, params["layers"])
del params_d["layers"]["attn"]["rp_proj"]

toks = jax.random.randint(jax.random.key(1), (1, T), 0, base.vocab)
cd, cr = api_d.init_cache(1, T), api_r.init_cache(1, T)
sd, sr = jax.jit(api_d.decode_step), jax.jit(api_r.decode_step)

agree = 0
for t in range(STEPS):
    lg_d, cd = sd(params_d, cd, toks[:, t:t + 1], jnp.int32(t))
    lg_r, cr = sr(params, cr, toks[:, t:t + 1], jnp.int32(t))
    agree += int(jnp.argmax(lg_d) == jnp.argmax(lg_r))
print(f"greedy-token agreement over {STEPS} steps "
      f"(topk={retr.retrieval.topk}/{T} cache): {agree}/{STEPS}")

# the production arithmetic (llama3-405b long_500k):
from repro.configs import get_config
cfg = get_config("llama3-405b")
Tl, KV, Hd, dl = 524_288, cfg.kv_heads, cfg.resolved_head_dim, 16
full = 2 * Tl * KV * Hd * 2
filt = Tl * KV * dl * 2 + 4096 * KV * 2 * Hd * 2
print(f"llama3-405b long_500k, per layer per decode step:")
print(f"  exact attention reads {full / 1e9:.2f} GB of KV cache")
print(f"  retrieval attention reads {filt / 1e9:.3f} GB "
      f"(low-dim keys + reranked blocks) -> {full / filt:.1f}x less HBM")
