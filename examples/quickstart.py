"""Quickstart: build a pHNSW index, search it, reproduce the paper's
headline comparison on your machine.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.configs.base import PHNSWConfig
from repro.core import (build_hnsw, build_packed, fit_pca, run_queries,
                        search_batched, recall_at, table3, hw_variant_stats)
from repro.data.vectors import brute_force_topk, make_queries, make_sift_like

N = 8_000
print(f"1. dataset: {N} SIFT-like 128-dim vectors")
x = make_sift_like(N)
q = make_queries(x, 64)
gt = brute_force_topk(x, q, 10)

print("2. build: six-layer HNSW graph (paper C phase) + PCA 128->15")
cfg = PHNSWConfig(name="quickstart", n_points=N, ef_construction=60)
t0 = time.time()
g = build_hnsw(x, cfg)
pca = fit_pca(x, cfg.d_low)
x_low = pca.transform(x).astype(np.float32)
print(f"   built in {time.time() - t0:.1f}s; "
      f"PCA-15 keeps {pca.explained.sum():.0%} of variance")

print("3. search: standard HNSW vs pHNSW (Algorithm 1)")
r_h, st_h = run_queries(g, q, gt, algo="hnsw", hw_mode=True)
r_p, st_p = run_queries(g, q, gt, algo="phnsw", x_low=x_low, pca=pca)
print(f"   recall@10: HNSW {r_h:.3f} | pHNSW {r_p:.3f} "
      f"(paper: filtering costs ~no recall)")
print(f"   high-dim distance computations per query: "
      f"{st_h.dist_high // len(q)} -> {st_p.dist_high // len(q)} "
      f"({st_h.dist_high / st_p.dist_high:.1f}x fewer)")

print("4. hardware cost model (Table III, DDR4/HBM):")
_, st_s = run_queries(g, q, gt, algo="phnsw", x_low=x_low, pca=pca,
                      layout="separate")
t3 = table3(hw_variant_stats(st_h, st_p, st_s), n_queries=len(q),
            dim=128, d_low=cfg.d_low)
for v in ("HNSW-Std", "pHNSW-Sep", "pHNSW"):
    row = "   " + v.ljust(10)
    for d in ("DDR4", "HBM"):
        c = t3[v][d]
        row += f" | {d} {c.qps:>9.0f} QPS {c.energy_uj:6.2f} uJ"
    print(row)

print("5. batched TPU-native search (fixed-shape, jit'd):")
db = build_packed(g, x_low)
_, fi = search_batched(db, jnp.asarray(q), pca=pca)
fi.block_until_ready()
t0 = time.time()
_, fi = search_batched(db, jnp.asarray(q), pca=pca)
fi.block_until_ready()
dt = time.time() - t0
fi = np.asarray(fi)
rec = float(np.mean([recall_at(fi[i], gt[i], 10) for i in range(len(q))]))
print(f"   {len(q) / dt:.0f} QPS on this host, recall@10 {rec:.3f}")
print("done.")
