"""End-to-end training driver with fault-tolerance demo: train a reduced
model, kill-and-resume from the checkpoint, verify the loss trajectory
continues identically.

    PYTHONPATH=src python examples/train_lm.py [--arch starcoder2-3b]
"""
import argparse
import shutil
import tempfile
from pathlib import Path

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh
from repro.optim import AdamWConfig
from repro.train.loop import TrainLoop, TrainLoopConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    shape = ShapeConfig("smoke", seq_len=128, global_batch=8, kind="train")
    mesh = make_host_mesh()
    opt = AdamWConfig(lr=1e-3, total_steps=args.steps, warmup_steps=4)
    d = Path(tempfile.mkdtemp(prefix="repro_train_"))
    try:
        half = args.steps // 2
        print(f"=== phase 1: {half} steps, then simulated failure ===")
        TrainLoop(cfg, shape, mesh,
                  TrainLoopConfig(steps=half, ckpt_every=10,
                                  ckpt_dir=str(d), seed=1), opt).run()
        print("=== phase 2: restart from checkpoint, continue ===")
        out = TrainLoop(cfg, shape, mesh,
                        TrainLoopConfig(steps=args.steps, ckpt_every=10,
                                        ckpt_dir=str(d), seed=1), opt).run()
        print(f"final loss {out['last_metrics']['loss']:.4f} at step "
              f"{out['final_step']} (restart was transparent: the data "
              f"pipeline is (seed, step)-deterministic)")
    finally:
        shutil.rmtree(d, ignore_errors=True)


if __name__ == "__main__":
    main()
