"""End-to-end serving driver (the paper's kind: an ANN *search* system
serving batched requests), now over the LIVE index: build/cache the 50k
index, adopt it as a MutableIndex, stream queries through the batched
pHNSW service while upserting and deleting under traffic, report QPS +
latency percentiles + recall — and that the whole run reused one
compiled search program (epochs swap, shapes don't).

    PYTHONPATH=src python examples/serve_vector_search.py [--n 50000]
"""
import argparse

import numpy as np

from benchmarks.common import load_bench_db
from repro.core.search_ref import recall_at
from repro.data.vectors import make_queries, make_sift_like
from repro.index import MutableIndex
from repro.serve.vector_service import VectorSearchService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--churn-batches", type=int, default=4,
                    help="upsert/delete batches interleaved mid-stream")
    args = ap.parse_args()

    cfg, x, g, pca, x_low, q, gt = load_bench_db(args.n,
                                                 min(args.queries, 200))
    if args.queries > len(q):
        q = make_queries(x, args.queries, seed=11)

    idx = MutableIndex.from_graph(g, pca)
    db = idx.db
    print(f"index: {idx.n_live} live points (capacity {idx.cap}), "
          f"layout(3) store {db.bytes_layout3 / 1e6:.0f} MB "
          f"({db.bytes_layout3 / (x.size * 4):.1f}x the raw data)")
    svc = VectorSearchService(idx, batch_size=args.batch)

    # mixed workload: serve the stream, folding in upserts + deletes
    fresh = make_sift_like((args.churn_batches + 1) * cfg.insert_batch,
                           seed=123)
    # warm the insert probe + the first post-swap query (the eager
    # scatter refresh compiles once) before the timed stream — same
    # practice as the query warmup in the service constructor
    svc.upsert(fresh[:cfg.insert_batch])
    fresh = fresh[cfg.insert_batch:]
    svc.query(q[:args.batch])
    svc.stats = type(svc.stats)()
    rng = np.random.default_rng(5)
    outs = []
    epoch0 = svc.epoch
    churn_every = max(len(q) // args.batch // max(args.churn_batches, 1),
                      1)
    step = 0
    for i in range(0, len(q), args.batch):
        _, fi = svc.query(q[i:i + args.batch])
        outs.append(fi)
        if step % churn_every == churn_every - 1 and len(fresh):
            svc.upsert(fresh[:cfg.insert_batch])
            fresh = fresh[cfg.insert_batch:]
            live = idx.live_ids()
            svc.delete(rng.choice(live, cfg.insert_batch // 2,
                                  replace=False))
        step += 1
    idx_out = np.concatenate(outs, axis=0)

    # recall against the FINAL live set (tombstones excluded by search)
    gt_live = idx.live_ground_truth(q, cfg.recall_at)
    rec = float(np.mean([recall_at(idx_out[i], gt_live[i], cfg.recall_at)
                         for i in range(len(q))]))
    drift = idx.pca_drift()
    print(f"served {len(q)} queries in batches of {args.batch} "
          f"with {svc.stats.upserts} upserts + {svc.stats.deletes} "
          f"deletes interleaved (epoch {epoch0} -> {svc.epoch}): "
          f"{svc.stats.qps:.0f} QPS over the mixed stream, "
          f"p50 {svc.stats.percentile(50):.1f} ms, "
          f"p99 {svc.stats.percentile(99):.1f} ms per query batch, "
          f"recall@10 {rec:.3f} vs the live set")
    print(f"tombstones {idx.tombstone_frac:.1%} "
          f"(compaction at {cfg.compact_tombstone_frac:.0%}); "
          f"PCA drift {drift['drift']:+.4f} "
          f"(refit_recommended={drift['refit_recommended']})")


if __name__ == "__main__":
    main()
