"""End-to-end serving driver (the paper's kind: an ANN *search* system
serving batched requests): build/cache the 50k index, stand up the
batched pHNSW service, stream 512 queries through it, report QPS +
latency percentiles + recall.

    PYTHONPATH=src python examples/serve_vector_search.py [--n 50000]
"""
import argparse

import numpy as np

from benchmarks.common import load_bench_db
from repro.core.search_jax import build_packed
from repro.core.search_ref import recall_at
from repro.serve.vector_service import VectorSearchService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--batch", type=int, default=64)
    args = ap.parse_args()

    cfg, x, g, pca, x_low, q, gt = load_bench_db(args.n,
                                                 min(args.queries, 200))
    # extend the query stream to the requested size
    from repro.data.vectors import make_queries, brute_force_topk
    if args.queries > len(q):
        q = make_queries(x, args.queries, seed=11)
        gt = brute_force_topk(x, q, cfg.recall_at)

    db = build_packed(g, x_low)
    print(f"index: {len(x)} points, layout(3) store "
          f"{db.bytes_layout3 / 1e6:.0f} MB "
          f"({db.bytes_layout3 / (x.size * 4):.1f}x the raw data)")
    svc = VectorSearchService(db, pca, batch_size=args.batch)
    idx, stats = svc.run_stream(q)
    rec = float(np.mean([recall_at(idx[i], gt[i], cfg.recall_at)
                         for i in range(len(q))]))
    print(f"served {len(q)} queries in batches of {args.batch}: "
          f"{stats['qps']:.0f} QPS, p50 {stats['p50_ms']:.1f} ms, "
          f"p99 {stats['p99_ms']:.1f} ms, recall@10 {rec:.3f}")


if __name__ == "__main__":
    main()
