"""Open-loop latency-under-load harness (DESIGN.md § Observability).

Closed-loop benchmarks (``bench_table3_qps``) measure *capacity*: the
next request starts when the previous one finishes, so the system is
never behind and latency percentiles say nothing about queueing. A
real service is OPEN-loop: requests arrive on their own clock whether
or not the server is ready, and latency under load — including the
queue wait — is the number an operator actually sees (this is the
classic coordinated-omission trap: closing the loop hides exactly the
slow requests that matter).

Protocol:

1. **Calibrate capacity** with a short closed loop (requests
   back-to-back) — this also A/Bs tracing ON vs OFF interleaved, the
   measured overhead the obs-smoke CI job gates at <= 10%.
2. **Offered-load points**: for each fraction of capacity, draw Poisson
   arrivals (seeded exponential inter-arrival times at the offered
   request rate), serve each request at its scheduled arrival time (or
   as soon as the server frees up, if it fell behind), and record
   ``now - scheduled_arrival`` — queue wait included — into a
   log-bucketed obs histogram labeled by the offered QPS.
3. **Report from the histograms themselves**: p50/p99/p999 are bucket
   quantiles of the recorded distribution and achieved QPS is its
   count over the run's wall span — the serving numbers and the
   scrape-exporter numbers are the same numbers by construction.
4. **Cost-model bridge**: one ``return_stats`` batch is folded through
   ``repro.obs.bridge`` (steps / Dist.H histograms + predicted-vs-
   measured query cost — the autotuner's calibration feed).

The canonical 8k run appends the tracked ``load`` section of
``BENCH_table3.json`` (own append-only history, like ``build`` /
``faults``); other sizes are CSV-only so CI gates on a small seeded
run without touching the tracked trajectory. ``prom_path`` dumps the
full Prometheus exposition text for the CI parse gate.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Optional, Sequence

import numpy as np

from benchmarks.common import emit, load_bench_db


def _closed_loop(svc, batches, reps: int) -> float:
    """Back-to-back serving; returns achieved queries/sec."""
    n_q = 0
    t0 = time.perf_counter()
    for r in range(reps):
        for b in batches:
            svc.query(b)
            n_q += len(b)
    return n_q / (time.perf_counter() - t0)


def _overhead_ab(svc, batches, tracer, reps: int = 4) -> dict:
    """Interleaved traced/untraced closed-loop A/B on the SAME service
    and compiled program (alternating per rep so drift hits both arms
    equally). Returns qps for each arm + the traced/untraced ratio."""
    from repro.obs.trace import NULL_TRACER
    t_on, t_off, q_on, q_off = 0.0, 0.0, 0, 0
    for r in range(2 * reps):
        traced = r % 2 == 0
        svc.tracer = tracer if traced else NULL_TRACER
        t0 = time.perf_counter()
        for b in batches:
            svc.query(b)
        dt = time.perf_counter() - t0
        nq = sum(len(b) for b in batches)
        if traced:
            t_on += dt
            q_on += nq
        else:
            t_off += dt
            q_off += nq
    svc.tracer = NULL_TRACER
    qps_on, qps_off = q_on / t_on, q_off / t_off
    return {"qps_traced": qps_on, "qps_untraced": qps_off,
            "overhead_ratio": qps_on / qps_off}


def _open_loop_point(svc, rng, q, req_size: int, rate_rps: float,
                     n_requests: int, hist) -> dict:
    """One offered-load point: Poisson arrivals at ``rate_rps``
    requests/sec; latency is measured FROM THE SCHEDULED ARRIVAL (queue
    wait included — no coordinated omission). Percentiles come from the
    obs histogram the latencies land in."""
    gaps = rng.exponential(1.0 / rate_rps, n_requests)
    picks = rng.integers(0, len(q) - req_size + 1, n_requests)
    t_start = time.perf_counter()
    arrivals = t_start + np.cumsum(gaps)
    before = hist.count
    for t_a, p in zip(arrivals, picks):
        now = time.perf_counter()
        if t_a > now:
            time.sleep(t_a - now)
        svc.query(q[p:p + req_size])
        hist.observe((time.perf_counter() - t_a) * 1e3)
    span_s = time.perf_counter() - t_start
    served = hist.count - before
    return {
        "offered_qps": rate_rps * req_size,
        "achieved_qps": served * req_size / span_s,
        "n_requests": int(served),
        "p50_ms": hist.percentile(50),
        "p99_ms": hist.percentile(99),
        "p999_ms": hist.percentile(99.9),
        "mean_ms": hist.mean,
    }


def main(n_points: int = 8_000, n_queries: int = 64,
         json_path: Optional[str] = None,
         prom_path: Optional[str] = None, seed: int = 0,
         req_size: int = 16,
         offered_fracs: Sequence[float] = (0.3, 0.7),
         n_requests: int = 120, calib_reps: int = 6):
    from repro.core.search_jax import build_packed, search_batched
    from repro.obs import (Registry, Tracer, parse_prometheus,
                           prometheus_families, record_search_stats,
                           to_prometheus)
    from repro.serve.vector_service import VectorSearchService

    cfg, x, g, pca, x_low, q, gt = load_bench_db(n_points, n_queries)
    rng = np.random.default_rng(seed)
    reg = Registry()
    tracer = Tracer()
    db = build_packed(g, x_low)
    svc = VectorSearchService(db, pca, batch_size=req_size,
                              registry=reg)
    rows = []

    # ---- closed-loop capacity + tracing-overhead A/B ----
    batches = [q[i:i + req_size] for i in
               range(0, len(q) - req_size + 1, req_size)]
    _closed_loop(svc, batches, 1)                     # steady-state warm
    cap_qps = _closed_loop(svc, batches, calib_reps)
    rows.append(("load/capacity", 1e6 / cap_qps,
                 f"qps={cap_qps:.0f};req_size={req_size};"
                 f"closed_loop=1"))
    ab = _overhead_ab(svc, batches, tracer)
    rows.append(("obs/overhead", 0.0,
                 f"qps_traced={ab['qps_traced']:.0f};"
                 f"qps_untraced={ab['qps_untraced']:.0f};"
                 f"ratio={ab['overhead_ratio']:.3f}"))

    # ---- open-loop offered-load points ----
    fam = reg.histogram("phnsw_load_latency_ms",
                        "open-loop request latency from scheduled "
                        "arrival (ms), queue wait included",
                        labels=("offered_qps",))
    points = []
    for frac in offered_fracs:
        rate_rps = frac * cap_qps / req_size
        hist = fam.labels(offered_qps=f"{frac * cap_qps:.0f}")
        pt = _open_loop_point(svc, rng, q, req_size, rate_rps,
                              n_requests, hist)
        pt["offered_frac"] = frac
        points.append(pt)
        rows.append((f"load/offered{pt['offered_qps']:.0f}",
                     pt["p50_ms"] * 1e3,
                     f"offered_qps={pt['offered_qps']:.0f};"
                     f"achieved_qps={pt['achieved_qps']:.0f};"
                     f"p50_ms={pt['p50_ms']:.3f};"
                     f"p99_ms={pt['p99_ms']:.3f};"
                     f"p999_ms={pt['p999_ms']:.3f}"))

    # ---- device-telemetry bridge: predicted vs measured cost ----
    import jax.numpy as jnp
    qd = jnp.asarray(q[:req_size])
    qp = jnp.asarray(svc.filt.prepare(np.asarray(q[:req_size])))
    search_batched(db, qd, qp, return_stats=True)[1].block_until_ready()
    t0 = time.perf_counter()
    _, fi, st = search_batched(db, qd, qp, return_stats=True)
    fi.block_until_ready()
    wall = time.perf_counter() - t0
    summary = record_search_stats(st, wall_s=wall, registry=reg,
                                  cfg=cfg, filt=svc.filt)
    rows.append(("obs/cost_model", summary["measured_us"],
                 f"predicted_us={summary['predicted_us']:.1f};"
                 f"ratio={summary['cost_ratio']:.2f};"
                 f"steps_mean={summary['steps_mean']:.1f};"
                 f"dist_h_mean={summary['dist_h_mean']:.1f}"))

    # ---- exporter: render, self-check the parse, optionally dump ----
    text = to_prometheus(reg)
    parsed = parse_prometheus(text)
    fams = prometheus_families(text)
    assert "phnsw_load_latency_ms" in fams and \
        "phnsw_request_latency_ms" in fams, fams
    assert "phnsw_load_latency_ms_count" in parsed
    if prom_path:
        Path(prom_path).write_text(text)
        rows.append(("obs/prometheus", 0.0,
                     f"families={len(fams)};path={prom_path}"))

    if json_path:
        entry = {
            "bench": "load",
            "n_points": n_points,
            "req_size": req_size,
            "capacity_qps": cap_qps,
            "points": points,
            "overhead": ab,
            "cost_model": summary,
        }
        p = Path(json_path)
        doc = {}
        if p.exists():
            try:
                doc = json.loads(p.read_text())
            except ValueError as e:
                # never silently replace a corrupted tracked trajectory
                raise RuntimeError(
                    f"{p} exists but is not valid JSON; refusing to "
                    f"overwrite the tracked trajectory") from e
        prev = doc.get("load")
        history = []
        if isinstance(prev, dict):
            history = prev.pop("history", [])
            history.append(prev)
        doc["load"] = {**entry, "history": history}
        p.write_text(json.dumps(doc, indent=2) + "\n")
    return emit(rows)


if __name__ == "__main__":
    main()
