"""Open-loop latency-under-load harness (DESIGN.md § Observability).

Closed-loop benchmarks (``bench_table3_qps``) measure *capacity*: the
next request starts when the previous one finishes, so the system is
never behind and latency percentiles say nothing about queueing. A
real service is OPEN-loop: requests arrive on their own clock whether
or not the server is ready, and latency under load — including the
queue wait — is the number an operator actually sees (this is the
classic coordinated-omission trap: closing the loop hides exactly the
slow requests that matter).

Protocol:

1. **Calibrate capacity** with a short closed loop (requests
   back-to-back) — this also A/Bs tracing ON vs OFF interleaved, the
   measured overhead the obs-smoke CI job gates at <= 10%.
2. **Offered-load points**: for each fraction of capacity, draw Poisson
   arrivals (seeded exponential inter-arrival times at the offered
   request rate), serve each request at its scheduled arrival time (or
   as soon as the server frees up, if it fell behind), and record
   ``now - scheduled_arrival`` — queue wait included — into a
   log-bucketed obs histogram labeled by the offered QPS. Both arms
   serve the same fixed compiled width (``svc_batch``, the canonical
   table3 B=64 config): the synchronous arm pads each underfull
   request's dead lanes, the scheduler arm packs queries from
   different requests into the same program — that padded-vs-packed
   A/B is what ``load/speedup_p99`` gates. ``load/sync_tight`` keeps a
   reference arm whose compiled width is tailored to the request size
   (the no-padding lower bound a fixed-shape deployment cannot offer
   under ragged traffic).
3. **Report exact sample percentiles**: p50/p99/p999 come from the raw
   latency samples (``numpy.percentile``), not the histogram buckets —
   the ~1.19x log-bucket width would otherwise quantize the sync/sched
   p99 ratio the serve gate compares. The same samples still land in
   the obs histograms, so the scrape exporter tells the same story at
   bucket resolution.
4. **Cost-model bridge**: one ``return_stats`` batch is folded through
   ``repro.obs.bridge`` (steps / Dist.H histograms + predicted-vs-
   measured query cost — the autotuner's calibration feed).

The canonical 8k run appends the tracked ``load`` section of
``BENCH_table3.json`` (own append-only history, like ``build`` /
``faults``); other sizes are CSV-only so CI gates on a small seeded
run without touching the tracked trajectory. ``prom_path`` dumps the
full Prometheus exposition text for the CI parse gate.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Optional, Sequence

import numpy as np

from benchmarks.common import emit, load_bench_db


def _closed_loop(svc, batches, reps: int) -> float:
    """Back-to-back serving; returns achieved queries/sec."""
    n_q = 0
    t0 = time.perf_counter()
    for r in range(reps):
        for b in batches:
            svc.query(b)
            n_q += len(b)
    return n_q / (time.perf_counter() - t0)


def _overhead_ab(svc, batches, tracer, reps: int = 4) -> dict:
    """Interleaved traced/untraced closed-loop A/B on the SAME service
    and compiled program (alternating per rep so drift hits both arms
    equally). Returns qps for each arm + the traced/untraced ratio."""
    from repro.obs.trace import NULL_TRACER
    t_on, t_off, q_on, q_off = 0.0, 0.0, 0, 0
    for r in range(2 * reps):
        traced = r % 2 == 0
        svc.tracer = tracer if traced else NULL_TRACER
        t0 = time.perf_counter()
        for b in batches:
            svc.query(b)
        dt = time.perf_counter() - t0
        nq = sum(len(b) for b in batches)
        if traced:
            t_on += dt
            q_on += nq
        else:
            t_off += dt
            q_off += nq
    svc.tracer = NULL_TRACER
    qps_on, qps_off = q_on / t_on, q_off / t_off
    return {"qps_traced": qps_on, "qps_untraced": qps_off,
            "overhead_ratio": qps_on / qps_off}


def _open_loop_point(svc, rng, q, req_size: int, rate_rps: float,
                     n_requests: int, hist) -> dict:
    """One offered-load point: Poisson arrivals at ``rate_rps``
    requests/sec; latency is measured FROM THE SCHEDULED ARRIVAL (queue
    wait included — no coordinated omission). Percentiles are exact
    sample quantiles; the samples also land in ``hist`` for the
    exporter."""
    gaps = rng.exponential(1.0 / rate_rps, n_requests)
    picks = rng.integers(0, len(q) - req_size + 1, n_requests)
    lats: list = []
    t_start = time.perf_counter()
    arrivals = t_start + np.cumsum(gaps)
    for t_a, p in zip(arrivals, picks):
        now = time.perf_counter()
        if t_a > now:
            time.sleep(t_a - now)
        svc.query(q[p:p + req_size])
        ms = (time.perf_counter() - t_a) * 1e3
        lats.append(ms)
        hist.observe(ms)
    span_s = time.perf_counter() - t_start
    return {
        "offered_qps": rate_rps * req_size,
        "achieved_qps": len(lats) * req_size / span_s,
        "n_requests": len(lats),
        "p50_ms": float(np.percentile(lats, 50)),
        "p99_ms": float(np.percentile(lats, 99)),
        "p999_ms": float(np.percentile(lats, 99.9)),
        "mean_ms": float(np.mean(lats)),
    }


def _recall_at(ids_row, gt_row, k: int) -> float:
    m = min(k, len(gt_row))
    return len(set(np.asarray(ids_row[:m]).tolist())
               & set(np.asarray(gt_row[:m]).tolist())) / m


def _open_loop_point_sched(sched, rng, q, req_size: int,
                           rate_rps: float, n_requests: int, hist, *,
                           gt=None, k_mix=None,
                           ragged: bool = False) -> dict:
    """The continuous-batching arm of the open-loop A/B: the same
    Poisson request arrivals as ``_open_loop_point``, but each
    request's queries are SUBMITTED to the scheduler at the scheduled
    arrival and the scheduler ticks while the clock waits — request
    latency is when its LAST query retires, measured from the
    scheduled arrival (no coordinated omission). ``k_mix`` ((ks, p)
    arrays) draws a seeded per-query k mixture and ``ragged`` draws
    per-request sizes in [1, req_size] — the mixed-k ragged traffic
    mode. Returns the same point dict as the synchronous arm (exact
    sample percentiles) plus recall/shed accounting."""
    gaps = rng.exponential(1.0 / rate_rps, n_requests)
    sizes = (rng.integers(1, req_size + 1, n_requests) if ragged
             else np.full(n_requests, req_size))
    picks = rng.integers(0, len(q) - req_size, n_requests)
    n_q_total = int(sizes.sum())
    ks = (rng.choice(k_mix[0], size=n_q_total, p=k_mix[1])
          if k_mix is not None else np.full(n_q_total, 10))
    remaining: dict = {}
    worst_ms: dict = {}
    rid2req: dict = {}
    qmeta: dict = {}
    recalls = []
    lats: list = []

    def absorb(comps):
        for c in comps:
            r = rid2req.pop(c.rid)
            remaining[r] -= 1
            worst_ms[r] = max(worst_ms[r], c.latency_ms)
            if gt is not None:
                row, kq = qmeta.pop(c.rid)
                recalls.append(_recall_at(c.ids, gt[row], kq))
            if remaining[r] == 0:
                lats.append(worst_ms[r])
                hist.observe(worst_ms[r])

    shed0 = sched.svc.stats.registry.get("phnsw_sched_shed_total")
    shed_before = sum(c.value for c in shed0.children()) if shed0 else 0
    t0 = time.monotonic()
    arrivals = t0 + np.cumsum(gaps)
    rid = qi = 0
    for i in range(n_requests):
        t_a = arrivals[i]
        while True:
            now = time.monotonic()
            if now >= t_a:
                break
            if sched.in_flight or sched.queue_depth:
                absorb(sched.tick())
            else:
                time.sleep(min(t_a - now, 5e-4))
        remaining[i] = 0
        worst_ms[i] = 0.0
        for j in range(int(sizes[i])):
            kq = int(ks[qi])
            r = sched.submit(q[picks[i] + j], k=kq, rid=rid,
                             t_sched=t_a)
            if r is not None:
                rid2req[rid] = i
                qmeta[rid] = (picks[i] + j, kq)
                remaining[i] += 1
            rid += 1
            qi += 1
        if remaining[i] == 0:
            del remaining[i], worst_ms[i]
        # when arrivals outrun service, keep serving while admitting
        # (otherwise the queue only drains after the last arrival)
        if sched.queue_depth >= sched.S:
            absorb(sched.tick())
    absorb(sched.drain())
    span_s = time.monotonic() - t0
    shed_after = sum(c.value for c in shed0.children()) if shed0 else 0
    pt = {
        "offered_qps": rate_rps * float(sizes.mean()),
        "achieved_qps": (n_q_total - (shed_after - shed_before))
        / span_s,
        "n_requests": len(lats),
        "p50_ms": float(np.percentile(lats, 50)) if lats else 0.0,
        "p99_ms": float(np.percentile(lats, 99)) if lats else 0.0,
        "p999_ms": float(np.percentile(lats, 99.9)) if lats else 0.0,
        "mean_ms": float(np.mean(lats)) if lats else 0.0,
        "shed": int(shed_after - shed_before),
    }
    if gt is not None:
        pt["recall"] = float(np.mean(recalls)) if recalls else 0.0
    return pt


def main(n_points: int = 8_000, n_queries: int = 64,
         json_path: Optional[str] = None,
         prom_path: Optional[str] = None, seed: int = 0,
         req_size: int = 16, svc_batch: int = 64,
         offered_fracs: Sequence[float] = (0.3, 0.7, 0.8),
         n_requests: int = 120, calib_reps: int = 6,
         sched_slots: int = 64):
    from repro.core.search_jax import build_packed, search_batched
    from repro.obs import (Registry, Tracer, parse_prometheus,
                           prometheus_families, record_search_stats,
                           to_prometheus)
    from repro.serve.vector_service import VectorSearchService

    cfg, x, g, pca, x_low, q, gt = load_bench_db(n_points, n_queries)
    rng = np.random.default_rng(seed)
    reg = Registry()
    tracer = Tracer()
    db = build_packed(g, x_low)
    # the CANONICAL service: one fixed compiled width (``svc_batch`` =
    # the tracked table3 B=64 config). The synchronous arm serves each
    # arriving request through it, padding dead lanes up to the static
    # batch dim — the scheduler arm packs queries from different
    # requests into the same width instead. That is the A/B the
    # speedup row gates: same traffic, same compiled width, padded vs
    # packed.
    svc = VectorSearchService(db, pca, batch_size=svc_batch,
                              registry=reg)
    rows = []

    # ---- closed-loop capacity + tracing-overhead A/B ----
    batches = [q[i:i + req_size] for i in
               range(0, len(q) - req_size + 1, req_size)]
    _closed_loop(svc, batches, 1)                     # steady-state warm
    cap_qps = _closed_loop(svc, batches, calib_reps)
    rows.append(("load/capacity", 1e6 / cap_qps,
                 f"qps={cap_qps:.0f};req_size={req_size};"
                 f"svc_batch={svc_batch};closed_loop=1"))
    # reference arm: a service whose compiled width is TAILORED to the
    # request size (no padding waste). A fixed-shape deployment cannot
    # actually serve ragged traffic this way without a program per
    # request shape, but the row keeps the comparison transparent:
    # whatever the padded arm loses to dead lanes is visible here.
    svc_tight = VectorSearchService(db, pca, batch_size=req_size,
                                    registry=Registry())
    _closed_loop(svc_tight, batches, 1)
    cap_tight = _closed_loop(svc_tight, batches, calib_reps)
    rows.append(("load/capacity_tight", 1e6 / cap_tight,
                 f"qps={cap_tight:.0f};req_size={req_size};"
                 f"svc_batch={req_size};closed_loop=1"))
    ab = _overhead_ab(svc, batches, tracer)
    rows.append(("obs/overhead", 0.0,
                 f"qps_traced={ab['qps_traced']:.0f};"
                 f"qps_untraced={ab['qps_untraced']:.0f};"
                 f"ratio={ab['overhead_ratio']:.3f}"))

    # ---- open-loop offered-load points ----
    fam = reg.histogram("phnsw_load_latency_ms",
                        "open-loop request latency from scheduled "
                        "arrival (ms), queue wait included",
                        labels=("offered_qps",))
    points = []
    for frac in offered_fracs:
        rate_rps = frac * cap_qps / req_size
        hist = fam.labels(offered_qps=f"{frac * cap_qps:.0f}")
        pt = _open_loop_point(svc, rng, q, req_size, rate_rps,
                              n_requests, hist)
        pt["offered_frac"] = frac
        points.append(pt)
        rows.append((f"load/offered{pt['offered_qps']:.0f}",
                     pt["p50_ms"] * 1e3,
                     f"offered_qps={pt['offered_qps']:.0f};"
                     f"achieved_qps={pt['achieved_qps']:.0f};"
                     f"p50_ms={pt['p50_ms']:.3f};"
                     f"p99_ms={pt['p99_ms']:.3f};"
                     f"p999_ms={pt['p999_ms']:.3f}"))
    # tailored-width reference at 0.8x of ITS OWN capacity
    pt_tight = _open_loop_point(
        svc_tight, rng, q, req_size, 0.8 * cap_tight / req_size,
        n_requests, fam.labels(offered_qps="tight0.8"))
    rows.append(("load/sync_tight", pt_tight["p50_ms"] * 1e3,
                 f"offered_qps={pt_tight['offered_qps']:.0f};"
                 f"achieved_qps={pt_tight['achieved_qps']:.0f};"
                 f"p50_ms={pt_tight['p50_ms']:.3f};"
                 f"p99_ms={pt_tight['p99_ms']:.3f}"))

    # ---- continuous-batching scheduler arm (same arrivals/clock) ----
    from repro.core.search_jax import slot_cache_sizes
    fam_s = reg.histogram("phnsw_sched_load_latency_ms",
                          "open-loop request latency through the "
                          "continuous-batching scheduler (ms), queue "
                          "wait included",
                          labels=("offered_qps",))
    sched = svc.scheduler(n_slots=sched_slots)
    sched_mk = svc.scheduler(ef=100, ef_policy=10,
                             n_slots=sched_slots)
    warm = slot_cache_sizes()
    sched_points = []
    for frac in offered_fracs:
        rate_rps = frac * cap_qps / req_size
        hist = fam_s.labels(offered_qps=f"{frac * cap_qps:.0f}")
        pt = _open_loop_point_sched(sched, rng, q, req_size, rate_rps,
                                    n_requests, hist, gt=gt)
        pt["offered_frac"] = frac
        sched_points.append(pt)
        rows.append((f"load/sched{pt['offered_qps']:.0f}",
                     pt["p50_ms"] * 1e3,
                     f"offered_qps={pt['offered_qps']:.0f};"
                     f"achieved_qps={pt['achieved_qps']:.0f};"
                     f"p50_ms={pt['p50_ms']:.3f};"
                     f"p99_ms={pt['p99_ms']:.3f};"
                     f"shed={pt['shed']};recall={pt['recall']:.4f}"))

    def _pt(pts, frac):
        return next((p for p in pts if p["offered_frac"] == frac), None)

    speedup = None
    s_sync, s_sched = _pt(points, 0.8), _pt(sched_points, 0.8)
    if s_sync and s_sched and s_sched["p99_ms"] > 0:
        speedup = s_sync["p99_ms"] / s_sched["p99_ms"]
        rows.append(("load/speedup_p99", 0.0,
                     f"frac=0.8;sync_p99_ms={s_sync['p99_ms']:.3f};"
                     f"sched_p99_ms={s_sched['p99_ms']:.3f};"
                     f"speedup={speedup:.2f}"))

    # ---- mixed-k ragged-arrival traffic (seeded k in {1,10,100}) ----
    # The synchronous path would have to serve EVERY query at ef>=100;
    # the scheduler compiles one ef=100 program and runs each query at
    # ef_eff = max(k, ef_policy) — the per-slot-k win this mode pins.
    k_mix = (np.array([1, 10, 100]),
             np.array([0.45, 0.45, 0.10]))
    rate_mk = 0.5 * cap_qps / req_size
    pt_mk = _open_loop_point_sched(
        sched_mk, rng, q, req_size, rate_mk, n_requests,
        fam_s.labels(offered_qps="mixed_k"), gt=gt,
        k_mix=k_mix, ragged=True)
    pt_mk["k_mix"] = {"ks": k_mix[0].tolist(),
                      "p": k_mix[1].tolist()}
    rows.append(("load/mixed_k", pt_mk["p50_ms"] * 1e3,
                 f"achieved_qps={pt_mk['achieved_qps']:.0f};"
                 f"p50_ms={pt_mk['p50_ms']:.3f};"
                 f"p99_ms={pt_mk['p99_ms']:.3f};"
                 f"shed={pt_mk['shed']};recall={pt_mk['recall']:.4f}"))
    recompiles = [a - b for a, b in zip(slot_cache_sizes(), warm)]
    rows.append(("load/recompiles", 0.0,
                 f"steady_state={sum(max(r, 0) for r in recompiles)}"))

    # ---- device-telemetry bridge: predicted vs measured cost ----
    import jax.numpy as jnp
    qd = jnp.asarray(q[:req_size])
    qp = jnp.asarray(svc.filt.prepare(np.asarray(q[:req_size])))
    search_batched(db, qd, qp, return_stats=True)[1].block_until_ready()
    t0 = time.perf_counter()
    _, fi, st = search_batched(db, qd, qp, return_stats=True)
    fi.block_until_ready()
    wall = time.perf_counter() - t0
    summary = record_search_stats(st, wall_s=wall, registry=reg,
                                  cfg=cfg, filt=svc.filt)
    rows.append(("obs/cost_model", summary["measured_us"],
                 f"predicted_us={summary['predicted_us']:.1f};"
                 f"ratio={summary['cost_ratio']:.2f};"
                 f"steps_mean={summary['steps_mean']:.1f};"
                 f"dist_h_mean={summary['dist_h_mean']:.1f}"))

    # ---- exporter: render, self-check the parse, optionally dump ----
    text = to_prometheus(reg)
    parsed = parse_prometheus(text)
    fams = prometheus_families(text)
    assert "phnsw_load_latency_ms" in fams and \
        "phnsw_sched_load_latency_ms" in fams and \
        "phnsw_request_latency_ms" in fams, fams
    assert "phnsw_load_latency_ms_count" in parsed
    if prom_path:
        Path(prom_path).write_text(text)
        rows.append(("obs/prometheus", 0.0,
                     f"families={len(fams)};path={prom_path}"))

    if json_path:
        entry = {
            "bench": "load",
            "n_points": n_points,
            "req_size": req_size,
            "svc_batch": svc_batch,
            "capacity_qps": cap_qps,
            "capacity_tight_qps": cap_tight,
            "sync_tight_point": pt_tight,
            "points": points,
            "sched_points": sched_points,
            "speedup_p99_at_0.8": speedup,
            "sched_slots": sched_slots,
            "recompiles": recompiles,
            "mixed_k": pt_mk,
            "overhead": ab,
            "cost_model": summary,
        }
        p = Path(json_path)
        doc = {}
        if p.exists():
            try:
                doc = json.loads(p.read_text())
            except ValueError as e:
                # never silently replace a corrupted tracked trajectory
                raise RuntimeError(
                    f"{p} exists but is not valid JSON; refusing to "
                    f"overwrite the tracked trajectory") from e
        prev = doc.get("load")
        history = []
        if isinstance(prev, dict):
            history = prev.pop("history", [])
            history.append(prev)
        doc["load"] = {**entry, "history": history}
        p.write_text(json.dumps(doc, indent=2) + "\n")
    return emit(rows)


if __name__ == "__main__":
    main()
