"""Table III: single-query search throughput (QPS).

Rows:
  HNSW-CPU / pHNSW-CPU     — measured wall time of the host reference
                             implementations (the paper's CPU rows; our
                             CPU differs from their i9, ratios are what
                             transfer).
  HNSW-Std / pHNSW-Sep / pHNSW x {DDR4, HBM}
                           — the processor cost model driven by
                             instrumented traversal traces (paper's
                             synthesized-RTL rows).
  pHNSW-JAX-batched        — measured QPS of the fixed-shape batched
                             search (beyond-paper row: the TPU-native
                             engine, here timed on CPU).

derived column = QPS normalized to HNSW-CPU (paper's normalization), and
for pHNSW rows also the layout-(3) memory blow-up vs the raw dataset.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Optional

import numpy as np

from benchmarks.common import batched_filter_ab, emit, load_bench_db
from repro.core.cost_model import table3, hw_variant_stats
from repro.core.search_jax import build_packed
from repro.core.search_ref import run_queries


def main(n_points: int = 50_000, n_queries: int = 200,
         json_path: Optional[str] = None, filter_kind: str = "pca",
         deferred: bool = False, rerank_mult: Optional[int] = None,
         n_shards: int = 1):
    """``filter_kind``/``deferred``/``rerank_mult`` select the filter
    stage and re-rank mode of the measured batched row (the CPU
    reference and cost-model rows stay on the paper's PCA
    configuration). The tracked BENCH_table3.json entry is only
    written for the canonical pca/per-step single-shard configuration
    and embeds a pca/pq/none/deferred A/B (``filters``).
    ``n_shards > 1`` adds a measured DISTRIBUTED row (the same filter x
    rerank mode over a P-way sharded build — the mesh collective path
    when the host exposes >= P devices, the bit-equal single-device
    shard loop otherwise)."""
    cfg, x, g, pca, x_low, q, gt = load_bench_db(n_points, n_queries)
    rows = []

    # --- CPU measured (reference implementations) ---
    t0 = time.perf_counter()
    r_cpu_h, st_sw = run_queries(g, q, gt, algo="hnsw")
    t_h = (time.perf_counter() - t0) / len(q)
    t0 = time.perf_counter()
    r_cpu_p, _ = run_queries(g, q, gt, algo="phnsw", x_low=x_low, pca=pca)
    t_p = (time.perf_counter() - t0) / len(q)
    qps_cpu_h = 1.0 / t_h
    rows.append(("table3/HNSW-CPU", t_h * 1e6,
                 f"norm=1.00;recall@10={r_cpu_h:.3f}"))
    rows.append(("table3/pHNSW-CPU", t_p * 1e6,
                 f"norm={(1 / t_p) / qps_cpu_h:.2f};recall@10={r_cpu_p:.3f}"))

    # --- processor cost model (hw_mode traces) ---
    _, st_h = run_queries(g, q, gt, algo="hnsw", hw_mode=True)
    _, st_p = run_queries(g, q, gt, algo="phnsw", x_low=x_low, pca=pca)
    _, st_s = run_queries(g, q, gt, algo="phnsw", x_low=x_low, pca=pca,
                          layout="separate")
    t3 = table3(hw_variant_stats(st_h, st_p, st_s), n_queries=len(q),
                dim=x.shape[1], d_low=x_low.shape[1])
    base = {d: t3["HNSW-Std"][d].qps for d in ("DDR4", "HBM")}
    for variant in ("HNSW-Std", "pHNSW-Sep", "pHNSW"):
        for dram in ("DDR4", "HBM"):
            c = t3[variant][dram]
            rows.append((f"table3/{variant}/{dram}", c.total_ns / 1e3,
                         f"qps={c.qps:.0f};vs_std={c.qps / base[dram]:.2f}x"))

    # --- layout (3) memory cost (Section IV-A claim: ~2.9x) ---
    db = build_packed(g, x_low)
    raw = x.size * 4
    rows.append(("table3/layout3_memory", 0.0,
                 f"bytes={db.bytes_layout3};vs_raw="
                 f"{db.bytes_layout3 / raw:.2f}x"))

    # --- batched JAX engine (beyond paper), measured; the filter stage
    # and rerank mode are pluggable (core/filters.py), and the single
    # measurement protocol lives in common.batched_filter_ab ---
    B = min(64, len(q))
    m = batched_filter_ab(cfg, x, g, pca, q, gt, batch=B, reps=5,
                          rerank_mult=rerank_mult,
                          modes=[(filter_kind, deferred)])[0]
    rows.append((f"table3/pHNSW-JAX-batched/{m['name']}",
                 m["us_per_query"],
                 f"qps={m['qps']:.0f};recall@10={m['recall']:.3f};"
                 f"steps_mean={m['steps_mean']:.1f};"
                 f"steps_p99={m['steps_p99']:.1f};"
                 f"dist_h_mean={m['dist_h_mean']:.1f}"))
    # --- sharded engine row (core/distributed.py), same measurement
    # protocol: the per-shard traversal + cross-shard merge, end to end
    if n_shards > 1:
        import time as _time
        import jax
        import jax.numpy as jnp
        from benchmarks.common import make_bench_filter
        from repro.core.distributed import (build_sharded,
                                            distributed_search,
                                            shard_search_host)
        from repro.core.search_ref import recall_at
        filt = make_bench_filter(filter_kind, cfg, x, pca,
                                 levels=g.levels)
        sdb = build_sharded(x, cfg, filt, n_shards)
        qd = jnp.asarray(q[:B])
        qprep = filt.prepare_jnp(qd)
        on_mesh = len(jax.devices()) >= n_shards
        kw = dict(deferred=deferred,
                  rerank_mult=int(rerank_mult or cfg.rerank_mult))
        if on_mesh:
            mesh = jax.make_mesh((1, n_shards), ("data", "model"))
            run = lambda: distributed_search(mesh, sdb, qd, qprep, **kw)
        else:
            run = lambda: shard_search_host(sdb, qd, qprep, **kw)
        run()[1].block_until_ready()                   # compile
        t0 = _time.perf_counter()
        reps = 5
        for _ in range(reps):
            _, fi = run()
        fi.block_until_ready()
        dt = (_time.perf_counter() - t0) / reps
        fi = np.asarray(fi)
        rec = float(np.mean([recall_at(fi[i], gt[i], cfg.recall_at)
                             for i in range(B)]))
        mode = filter_kind + ("-deferred" if deferred else "")
        rows.append((f"table3/pHNSW-JAX-sharded/p{n_shards}-{mode}",
                     dt / B * 1e6,
                     f"qps={B / dt:.0f};recall@10={rec:.3f};"
                     f"path={'mesh' if on_mesh else 'host'};"
                     f"vs_1shard={m['qps'] / (B / dt):.2f}x_slowdown"))

    # the tracked perf trajectory pins the canonical single-shard
    # configuration
    if json_path and (filter_kind != "pca" or deferred or n_shards > 1):
        json_path = None
    if json_path:
        # filter-stage A/B on the same graph/queries, embedded in the
        # tracked entry (pca / pq / none / pca-deferred)
        ab = batched_filter_ab(cfg, x, g, pca, q, gt, batch=B)
        rows.extend((f"table3/filter_ab/{a['name']}",
                     a["us_per_query"],
                     f"qps={a['qps']:.0f};recall@10={a['recall']:.3f};"
                     f"dist_h_mean={a['dist_h_mean']:.1f};"
                     f"bytes_per_vec={a['bytes_per_vec']};"
                     f"sidecar_bytes_per_vec="
                     f"{a['sidecar_bytes_per_vec']}")
                    for a in ab)
        entry = {
            "bench": "table3_qps",
            "n_points": n_points,
            "batch": B,
            "qps": m["qps"],
            "us_per_query": m["us_per_query"],
            "recall_at_10": m["recall"],
            "steps_mean": m["steps_mean"],
            "steps_p99": m["steps_p99"],
            "steps_max": m["steps_max"],
            "dist_h_mean": m["dist_h_mean"],
            "filters": {a["name"]: {k: a[k] for k in
                                    ("qps", "recall", "dist_h_mean",
                                     "bytes_per_vec",
                                     "sidecar_bytes_per_vec",
                                     "rerank_mult", "promote_mult")}
                        for a in ab},
        }
        # append-only perf trajectory: latest entry at top level (the
        # tracked number), prior --perf-smoke runs under "history"; the
        # "build" / "faults" / "load" sections (bench_build's /
        # bench_faults' / bench_load's own append-only trajectories)
        # are carried forward untouched, not buried into the QPS
        # history
        p = Path(json_path)
        history, carried = [], {}
        if p.exists():
            try:
                prev = json.loads(p.read_text())
                history = prev.pop("history", [])
                for k in ("build", "faults", "load"):
                    if k in prev:
                        carried[k] = prev.pop(k)
                history.append(prev)
            except (ValueError, KeyError):
                pass
        doc = {**entry, "history": history, **carried}
        p.write_text(json.dumps(doc, indent=2) + "\n")
    return emit(rows)


if __name__ == "__main__":
    main()
