"""Per-kernel cost table (the JAX analogue of the paper's Fig 4 area
breakdown — RTL area is not reproducible; the comparable artifact is
each kernel's VMEM block footprint, FLOPs, and measured wall time in
interpret/ref mode on this host)."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def main():
    rng = np.random.default_rng(0)
    rows = []
    B, M, dl, K, D = 64, 32, 15, 16, 128
    x = jnp.asarray(rng.standard_normal((B, M, dl)), jnp.float32)
    qv = jnp.asarray(rng.standard_normal((B, dl)), jnp.float32)
    us = _time(ops.dist_l, x, qv)
    vmem = (8 * M * dl + 8 * dl + 8 * M) * 4
    rows.append(("kernels/dist_l", us,
                 f"vmem_block_bytes={vmem};flops={2 * B * M * dl * 3}"))
    d = ops.dist_l(x, qv)
    us = _time(lambda dd: ops.ksort_l(dd, K), d)
    rows.append(("kernels/ksort_l", us,
                 f"vmem_block_bytes={8 * M * M * 4};cmp_matrix={M}x{M}"))
    xh = jnp.asarray(rng.standard_normal((B, K, D)), jnp.float32)
    qh = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)
    us = _time(ops.dist_h, xh, qh)
    rows.append(("kernels/dist_h", us,
                 f"vmem_block_bytes={(8 * K * D + 8 * D) * 4};"
                 f"flops={2 * B * K * D * 3}"))
    us = _time(lambda a, b: ops.fused_filter(a, b, K), x, qv)
    rows.append(("kernels/fused_filter", us,
                 f"hbm_saved_per_call_bytes={2 * B * M * 4}"))
    Bq, H, S, hd = 1, 4, 512, 64
    qa = jnp.asarray(rng.standard_normal((Bq, H, S, hd)), jnp.bfloat16)
    us = _time(lambda a: ops.flash_attention(a, a, a, causal=True), qa)
    rows.append(("kernels/flash_attention", us,
                 f"flops={4 * Bq * H * S * S * hd // 2};bq=128;bk=128"))
    qd = jnp.asarray(rng.standard_normal((Bq, H, hd)), jnp.bfloat16)
    kd = jnp.asarray(rng.standard_normal((Bq, H, 4096, hd)), jnp.bfloat16)
    ln = jnp.full((Bq,), 4096, jnp.int32)
    us = _time(lambda a, b, c: ops.decode_attention(a, b, b, c), qd, kd, ln)
    rows.append(("kernels/decode_attention", us,
                 f"cache_bytes_read={2 * Bq * H * 4096 * hd * 2}"))
    return emit(rows)


if __name__ == "__main__":
    main()
