"""Fig 2: recall@10 and QPS vs per-layer filter size k.

(a) sweep k(layer1) at fixed k(layer0)=16;
(b) sweep k(layer0) at fixed k(layer1)=8.
Also runs the automated knee-finding (core/kselect.select_schedule) and
reports the schedule it picks — the paper picked (16, 8, 3...).
"""
from __future__ import annotations

from benchmarks.common import emit, load_bench_db
from repro.core.kselect import select_schedule, sweep_k0, sweep_k1


def main(n_points: int = 50_000, n_queries: int = 100):
    cfg, x, g, pca, x_low, q, gt = load_bench_db(n_points, n_queries)
    rows = []
    for p in sweep_k1(g, x_low, pca, q, gt, k0=16):
        rows.append((f"fig2a/k1={p.k1}", 1e6 / p.qps_hbm,
                     f"recall={p.recall:.3f};qps_ddr4={p.qps_ddr4:.0f};"
                     f"qps_hbm={p.qps_hbm:.0f}"))
    for p in sweep_k0(g, x_low, pca, q, gt, k1=8):
        rows.append((f"fig2b/k0={p.k0}", 1e6 / p.qps_hbm,
                     f"recall={p.recall:.3f};qps_ddr4={p.qps_ddr4:.0f};"
                     f"qps_hbm={p.qps_hbm:.0f}"))
    sched, _ = select_schedule(g, x_low, pca, q, gt)
    rows.append(("fig2/selected_schedule", 0.0,
                 f"k={'-'.join(map(str, sched))};paper=16-8-3-3-3-3"))
    return emit(rows)


if __name__ == "__main__":
    main()
