"""Fig 5: normalized single-query energy (vs HNSW-Std), with the
DRAM-vs-core breakdown. Paper claims: DRAM 82-87% (DDR4) / 63-72% (HBM)
of total; pHNSW saves up to 57.4% vs HNSW-Std; pHNSW vs pHNSW-Sep ~ -11%
(same bytes, lower latency -> less idle energy)."""
from __future__ import annotations

from benchmarks.common import emit, load_bench_db
from repro.core.cost_model import table3, hw_variant_stats
from repro.core.search_ref import run_queries


def main(n_points: int = 50_000, n_queries: int = 200):
    cfg, x, g, pca, x_low, q, gt = load_bench_db(n_points, n_queries)
    _, st_h = run_queries(g, q, gt, algo="hnsw", hw_mode=True)
    _, st_p = run_queries(g, q, gt, algo="phnsw", x_low=x_low, pca=pca)
    _, st_s = run_queries(g, q, gt, algo="phnsw", x_low=x_low, pca=pca,
                          layout="separate")
    t3 = table3(hw_variant_stats(st_h, st_p, st_s), n_queries=len(q),
                dim=x.shape[1], d_low=x_low.shape[1])
    rows = []
    for dram in ("DDR4", "HBM"):
        base = t3["HNSW-Std"][dram].energy_uj
        for variant in ("HNSW-Std", "pHNSW-Sep", "pHNSW"):
            c = t3[variant][dram]
            rows.append((f"fig5/{variant}/{dram}", c.total_ns / 1e3,
                         f"energy_uj={c.energy_uj:.3f};"
                         f"norm={c.energy_uj / base:.3f};"
                         f"dram_share={c.dram_energy_share:.2f}"))
    saved = 1 - t3["pHNSW"]["DDR4"].energy_uj / t3["HNSW-Std"]["DDR4"].energy_uj
    rows.append(("fig5/savings_ddr4", 0.0,
                 f"saved={saved:.1%};paper=57.4%max"))
    return emit(rows)


if __name__ == "__main__":
    main()
