"""Shared benchmark fixtures: the cached SIFT-like graph + queries."""
from __future__ import annotations

import time
from pathlib import Path

import numpy as np

ROOT = Path(__file__).resolve().parents[1]
DATA_DIR = ROOT / "experiments" / "data"


def load_bench_db(n_points: int = 50_000, n_queries: int = 200):
    """(cfg, x, graph, pca, x_low, queries, ground_truth) — cached."""
    from repro.configs.sift1m_phnsw import SMALL
    from repro.core.graph import cached_graph
    from repro.core.pca import fit_pca
    from repro.data.vectors import (brute_force_topk, make_queries,
                                    make_sift_like)

    cfg = SMALL if n_points == SMALL.n_points else \
        SMALL.__class__(**{**SMALL.__dict__, "n_points": n_points,
                           "name": f"sift{n_points // 1000}k"})
    x = make_sift_like(cfg.n_points)
    g = cached_graph(x, cfg, DATA_DIR)
    pca = fit_pca(x, cfg.d_low)
    x_low = pca.transform(x).astype(np.float32)
    qf = DATA_DIR / f"queries_{cfg.name}.npz"
    if qf.exists():
        z = np.load(qf)
        q, gt = z["q"][:n_queries], z["gt"][:n_queries]
    else:
        q = make_queries(x, n_queries)
        gt = brute_force_topk(x, q, cfg.recall_at)
        DATA_DIR.mkdir(parents=True, exist_ok=True)
        np.savez(qf, q=q, gt=gt)
    return cfg, x, g, pca, x_low, q, gt


def make_bench_filter(kind: str, cfg, x, pca, levels=None):
    """The filter used by the batched benchmarks: adopt the cached PCA
    for "pca"/"cascade", fit PQ/identity from cfg (smoke-speed
    training: 4 Lloyd iterations is recall-equivalent on the 8-50k
    benches). "pq<N>" (e.g. "pq64") overrides cfg.pq_n_sub — the
    matched-byte-budget arms of the ablation. ``levels`` (the graph's
    per-point layer assignment) trains cascade/PQ codebooks
    density-aware."""
    import dataclasses
    from repro.core.filters import PCAFilter, make_filter
    if kind == "pca":
        return PCAFilter(pca, low_dtype=cfg.low_dtype)
    n_sub = cfg.pq_n_sub
    if kind.startswith("pq") and kind != "pq":
        kind, n_sub = "pq", int(kind[2:])
    # the cascade rides its codes through the whole traversal before
    # the promote stage can help, so it gets the config's full Lloyd
    # schedule; the plain-PQ arms are recall-equivalent at 4
    iters = cfg.pq_train_iters if kind == "cascade" else 4
    return make_filter(dataclasses.replace(cfg, filter_kind=kind,
                                           pq_n_sub=n_sub,
                                           pq_train_iters=iters), x,
                       pca=pca, levels=levels)


def batched_filter_ab(cfg, x, g, pca, q, gt, *, batch: int = 64,
                      reps: int = 3, rerank_mult=None, modes=None):
    """Apples-to-apples batched-engine A/B across filter stages: same
    graph, same queries, same compiled traversal — only the filter
    payload/kernel (and optionally the rerank mode) swaps. Returns one
    dict per mode: qps, recall@cfg.recall_at, mean Dist.H evals/query,
    step telemetry, payload bytes/vec."""
    import time as _time
    import numpy as _np
    import jax.numpy as jnp
    from repro.core.search_jax import build_packed, search_batched
    from repro.core.search_ref import recall_at

    modes = modes or [("pca", False), ("pq", False), ("none", False),
                      ("pca", True), ("cascade", True)]
    B = min(batch, len(q))
    qd = jnp.asarray(q[:B])
    filt_cache, db_cache = {}, {}       # payload depends only on kind
    out = []
    for kind, deferred in modes:
        if kind not in filt_cache:
            filt_cache[kind] = make_bench_filter(kind, cfg, x, pca,
                                                 levels=g.levels)
            db_cache[kind] = build_packed(g, filt_cache[kind].encode(x),
                                          filt=filt_cache[kind])
        filt, db = filt_cache[kind], db_cache[kind]
        # the cascade's promote stage hands the re-rank a PCA-ordered
        # pool, so its Dist.H budget is capped at rerank_mult=2 —
        # strictly below the pca-deferred row's high-dim traffic
        rm = int(rerank_mult or
                 (2 if kind == "cascade" else cfg.rerank_mult))
        kw = dict(filt=filt, deferred=deferred, rerank_mult=rm)
        search_batched(db, qd, **kw)[1].block_until_ready()   # compile
        t0 = _time.perf_counter()
        for _ in range(reps):
            _, fi = search_batched(db, qd, **kw)
        fi.block_until_ready()
        dt = (_time.perf_counter() - t0) / reps
        fi = _np.asarray(fi)
        rec = float(_np.mean([recall_at(fi[i], gt[i], cfg.recall_at)
                              for i in range(B)]))
        _, _, stc = search_batched(db, qd, return_stats=True, **kw)
        dhe = float(_np.asarray(stc["dist_h_evals"]).mean())
        steps = _np.asarray(stc["steps_total"])
        out.append({
            "name": kind + ("-deferred" if deferred else ""),
            "qps": B / dt, "us_per_query": dt / B * 1e6,
            "recall": rec, "dist_h_mean": dhe,
            "steps_mean": float(steps.mean()),
            "steps_p99": float(_np.percentile(steps, 99)),
            "steps_max": int(steps.max()),
            "bytes_per_vec": filt.bytes_per_vec,
            "sidecar_bytes_per_vec": getattr(filt, "mid_bytes_per_vec",
                                             0),
            "rerank_mult": rm if deferred else 1,
            "promote_mult": cfg.promote_mult
            if (deferred and filt.kind == "cascade") else 1,
        })
    return out


def emit(rows):
    """Print the required ``name,us_per_call,derived`` CSV rows."""
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
    return rows
