"""Shared benchmark fixtures: the cached SIFT-like graph + queries."""
from __future__ import annotations

import time
from pathlib import Path

import numpy as np

ROOT = Path(__file__).resolve().parents[1]
DATA_DIR = ROOT / "experiments" / "data"


def load_bench_db(n_points: int = 50_000, n_queries: int = 200):
    """(cfg, x, graph, pca, x_low, queries, ground_truth) — cached."""
    from repro.configs.sift1m_phnsw import SMALL
    from repro.core.graph import cached_graph
    from repro.core.pca import fit_pca
    from repro.data.vectors import (brute_force_topk, make_queries,
                                    make_sift_like)

    cfg = SMALL if n_points == SMALL.n_points else \
        SMALL.__class__(**{**SMALL.__dict__, "n_points": n_points,
                           "name": f"sift{n_points // 1000}k"})
    x = make_sift_like(cfg.n_points)
    g = cached_graph(x, cfg, DATA_DIR)
    pca = fit_pca(x, cfg.d_low)
    x_low = pca.transform(x).astype(np.float32)
    qf = DATA_DIR / f"queries_{cfg.name}.npz"
    if qf.exists():
        z = np.load(qf)
        q, gt = z["q"][:n_queries], z["gt"][:n_queries]
    else:
        q = make_queries(x, n_queries)
        gt = brute_force_topk(x, q, cfg.recall_at)
        DATA_DIR.mkdir(parents=True, exist_ok=True)
        np.savez(qf, q=q, gt=gt)
    return cfg, x, g, pca, x_low, q, gt


def emit(rows):
    """Print the required ``name,us_per_call,derived`` CSV rows."""
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
    return rows
