"""Build throughput: the wave pipeline vs the sequential oracle.

Rows (``name,us_per_call,derived`` — us_per_call is per VECTOR):

  build/ref   — ``build_hnsw_ref`` wall-clock; vps + structural check.
  build/wave  — the wave pipeline (``core/build.py``); vps, speedup vs
                ref, recall-after-build A/B on the same queries, and
                the structural cross-check against the oracle (shared
                level assignment + entry, graph invariants).

The canonical 8k configuration appends the tracked entry under the
``"build"`` section of ``BENCH_table3.json`` (append-only: the previous
build entry is pushed onto ``build.history`` — same protocol as the
QPS rows at the top level).
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Optional

import numpy as np

from benchmarks.common import emit


def _recall_after_build(g, x, pca, q, gt, recall_at_k: int) -> float:
    import jax.numpy as jnp
    from repro.core.search_jax import build_packed, search_batched
    from repro.core.search_ref import recall_at
    db = build_packed(g, pca.transform(x).astype(np.float32))
    _, fi = search_batched(db, jnp.asarray(q), pca=pca)
    fi = np.asarray(fi)
    return float(np.mean([recall_at(fi[i], gt[i], recall_at_k)
                          for i in range(len(q))]))


def main(n_points: int = 8_000, n_queries: int = 64,
         json_path: Optional[str] = None,
         wave_size: Optional[int] = None, seed: int = 0):
    from repro.configs.sift1m_phnsw import SMALL
    from repro.core.build import build_hnsw_wave, graph_invariants
    from repro.core.graph import build_hnsw_ref
    from repro.core.pca import fit_pca
    from repro.data.vectors import (brute_force_topk, make_queries,
                                    make_sift_like)

    cfg = SMALL.__class__(**{**SMALL.__dict__, "n_points": n_points,
                             "name": f"sift{n_points // 1000}k"})
    x = make_sift_like(cfg.n_points)
    q = make_queries(x, n_queries)
    gt = brute_force_topk(x, q, cfg.recall_at)
    pca = fit_pca(x, cfg.d_low)

    t0 = time.perf_counter()
    g_ref = build_hnsw_ref(x, cfg, seed=seed)
    t_ref = time.perf_counter() - t0
    # warm the probe program first so the timed run (and the CI
    # speedup gate) measures steady-state build throughput, not XLA
    # compile latency on a cold/noisy runner
    build_hnsw_wave(x, cfg, seed=seed, wave_size=wave_size)
    t0 = time.perf_counter()
    g_wave = build_hnsw_wave(x, cfg, seed=seed, wave_size=wave_size)
    t_wave = time.perf_counter() - t0

    inv_r = graph_invariants(g_ref)
    inv_w = graph_invariants(g_wave)
    rec_r = _recall_after_build(g_ref, x, pca, q, gt, cfg.recall_at)
    rec_w = _recall_after_build(g_wave, x, pca, q, gt, cfg.recall_at)
    # structural cross-check: both builders share sample_levels, so a
    # given seed must produce identical levels and entry point
    lv_match = int((g_ref.levels == g_wave.levels).all())
    en_match = int(g_ref.entry == g_wave.entry)

    rows = [
        ("build/ref", t_ref / n_points * 1e6,
         f"vps={n_points / t_ref:.0f};recall@10={rec_r:.3f};"
         f"invariants={'ok' if inv_r['ok'] else 'FAIL'};"
         f"mean_deg0={inv_r['mean_degree'][0]:.1f}"),
        ("build/wave", t_wave / n_points * 1e6,
         f"vps={n_points / t_wave:.0f};recall@10={rec_w:.3f};"
         f"speedup_vs_ref={t_ref / t_wave:.2f};"
         f"recall_delta={rec_w - rec_r:+.4f};"
         f"invariants={'ok' if inv_w['ok'] else 'FAIL'};"
         f"mean_deg0={inv_w['mean_degree'][0]:.1f};"
         f"levels_match={lv_match};entry_match={en_match}"),
    ]

    if json_path:
        entry = {
            "bench": "build",
            "n_points": n_points,
            "wave_size": wave_size or cfg.wave_size,
            "wave_vps": n_points / t_wave,
            "ref_vps": n_points / t_ref,
            "speedup_vs_ref": t_ref / t_wave,
            "recall_at_10_wave": rec_w,
            "recall_at_10_ref": rec_r,
            "invariants_ok": bool(inv_w["ok"] and inv_r["ok"]),
            "levels_match": bool(lv_match),
        }
        p = Path(json_path)
        doc = {}
        if p.exists():
            try:
                doc = json.loads(p.read_text())
            except ValueError as e:
                # never silently replace a corrupted tracked trajectory
                # with a build-only document — fail loudly instead
                raise RuntimeError(
                    f"{p} exists but is not valid JSON; refusing to "
                    f"overwrite the tracked trajectory") from e
        prev = doc.get("build")
        history = []
        if isinstance(prev, dict):
            history = prev.pop("history", [])
            history.append(prev)
        doc["build"] = {**entry, "history": history}
        p.write_text(json.dumps(doc, indent=2) + "\n")
    return emit(rows)


if __name__ == "__main__":
    main()
