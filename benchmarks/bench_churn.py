"""Churn benchmark: recall@10 and QPS under a mixed insert/delete/query
workload against the mutable index (beyond-paper: the paper's system is
build-once; a production index absorbs updates while serving).

Workload: start from the cached n_points index, then run ``rounds``
rounds of {upsert one batch, delete half a batch of random live ids,
serve one query batch}, timing each op class separately. Ends with a
recall@10 measurement against exact brute force over the FINAL live set
(so tombstones and the graph's post-churn quality are both in the
number), plus the tombstone density and PCA-drift report.

Rows (name,us_per_call,derived):
  churn/upsert   — mean us per upserted vector; derived: vectors/s
  churn/delete   — mean us per deleted id;     derived: ids/s
  churn/query    — mean us per query;          derived: qps + p99 ms
  churn/final    — 0; derived: recall@10, live size, tombstone frac,
                   pca drift
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Optional

import numpy as np

from benchmarks.common import emit, load_bench_db
from repro.core.search_ref import recall_at
from repro.data.vectors import make_sift_like
from repro.index import MutableIndex, ShardedMutableIndex
from repro.serve.vector_service import VectorSearchService


def main(n_points: int = 8_000, n_queries: int = 64, rounds: int = 8,
         batch: int = 64, json_path: Optional[str] = None,
         n_shards: int = 1):
    """``n_shards > 1`` runs the identical workload against a
    ``ShardedMutableIndex`` (round-robin upsert routing, owner-offset
    delete routing, stacked-snapshot republish per mutation) through
    the same serving front."""
    cfg, x, g, pca, x_low, q, gt = load_bench_db(n_points, n_queries)
    # fresh vectors from the same generator family, disjoint seed
    fresh = make_sift_like(rounds * cfg.insert_batch, seed=1234)
    if n_shards > 1:
        from repro.core.filters import PCAFilter
        idx = ShardedMutableIndex.build(
            x, cfg, n_shards, seed=1,
            filt=PCAFilter(pca, low_dtype=cfg.low_dtype))
        idx.reserve(-(-(n_points + len(fresh)) // n_shards))
    else:
        idx = MutableIndex.from_graph(g, pca, seed=1)
        idx.reserve(n_points + len(fresh))       # no growth mid-run
    svc = VectorSearchService(idx, batch_size=batch, ef0=cfg.ef0)
    # warm the insert probe before timing (mirrors serving practice)
    svc.upsert(fresh[:cfg.insert_batch])

    rng = np.random.default_rng(7)
    t_up = t_del = t_q = 0.0
    n_up = n_del = n_q = 0
    for r in range(1, rounds):
        xb = fresh[r * cfg.insert_batch:(r + 1) * cfg.insert_batch]
        t0 = time.perf_counter()
        svc.upsert(xb)
        t_up += time.perf_counter() - t0
        n_up += len(xb)

        live = idx.live_ids()
        doomed = rng.choice(live, size=cfg.insert_batch // 2,
                            replace=False)
        t0 = time.perf_counter()
        svc.delete(doomed)
        t_del += time.perf_counter() - t0
        n_del += len(doomed)

        qb = q[(r * batch) % max(len(q) - batch, 1):][:batch]
        if len(qb) < batch:
            qb = q[:batch]
        t0 = time.perf_counter()
        svc.query(qb)
        t_q += time.perf_counter() - t0
        n_q += len(qb)

    # final recall vs brute force over the live set
    live = idx.live_ids()
    gt_live = idx.live_ground_truth(q, cfg.recall_at)
    _, fi = idx.search(q)
    fi = np.asarray(fi)
    rec = float(np.mean([recall_at(fi[i], gt_live[i], cfg.recall_at)
                         for i in range(len(q))]))
    drift = idx.pca_drift()
    rows = [
        ("churn/upsert", t_up / max(n_up, 1) * 1e6,
         f"vecs_per_s={n_up / max(t_up, 1e-9):.0f}"),
        ("churn/delete", t_del / max(n_del, 1) * 1e6,
         f"ids_per_s={n_del / max(t_del, 1e-9):.0f}"),
        ("churn/query", t_q / max(n_q, 1) * 1e6,
         f"qps={n_q / max(t_q, 1e-9):.0f};"
         f"p99_ms={svc.stats.percentile(99):.1f}"),
        ("churn/final", 0.0,
         f"recall@10={rec:.3f};live={len(live)};"
         f"tombstone_frac={idx.tombstone_frac:.3f};"
         f"pca_drift={drift['drift']:.4f}"),
    ]
    if json_path:
        Path(json_path).write_text(json.dumps({
            "bench": "churn", "n_points": n_points, "rounds": rounds,
            "qps": n_q / max(t_q, 1e-9),
            "upserts_per_s": n_up / max(t_up, 1e-9),
            "recall_at_10": rec,
            "tombstone_frac": idx.tombstone_frac,
        }, indent=2) + "\n")
    return emit(rows)


if __name__ == "__main__":
    main()
