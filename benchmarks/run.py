"""Benchmark orchestrator — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (plus a roofline summary appendix
when dry-run artifacts exist).

  PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller database (8k points) for quick runs")
    ap.add_argument("--n-points", type=int, default=None)
    ap.add_argument("--perf-smoke", action="store_true",
                    help="only the batched-QPS benchmark on a small "
                         "database; writes BENCH_table3.json (QPS, "
                         "recall, mean/p99 steps) for the tracked perf "
                         "trajectory")
    ap.add_argument("--churn", action="store_true",
                    help="only the mutable-index churn benchmark "
                         "(mixed insert/delete/query workload)")
    ap.add_argument("--build", action="store_true",
                    help="only the build benchmark: wave-pipeline vs "
                         "sequential-oracle throughput (vectors/sec) "
                         "and recall-after-build A/B; the canonical "
                         "8k/default-wave run appends the tracked "
                         "'build' section of BENCH_table3.json")
    ap.add_argument("--wave-size", type=int, default=None,
                    help="override cfg.wave_size for --build")
    ap.add_argument("--faults", action="store_true",
                    help="only the fault-tolerance benchmark: "
                         "recall-vs-dead-shards curve (P=4) plus the "
                         "kill/degraded/failover/reseed/recover cycle "
                         "with zero-recompile accounting; the canonical "
                         "8k run appends the tracked 'faults' section "
                         "of BENCH_table3.json")
    ap.add_argument("--load", action="store_true",
                    help="only the open-loop latency-under-load "
                         "harness: Poisson arrivals at fractions of "
                         "the calibrated capacity, p50/p99/p999 from "
                         "the obs histograms, plus the traced-vs-"
                         "untraced overhead A/B; the canonical 8k run "
                         "appends the tracked 'load' section of "
                         "BENCH_table3.json")
    ap.add_argument("--prom-out", type=str, default=None,
                    help="with --load: dump the Prometheus text "
                         "exposition of the run's metrics registry to "
                         "this path (the CI obs-smoke parse gate)")
    ap.add_argument("--filter", choices=("pca", "pq", "cascade", "none"),
                    default="pca", dest="filter_kind",
                    help="filter stage for the measured batched row "
                         "(core/filters.py); the tracked "
                         "BENCH_table3.json entry is only written for "
                         "the canonical pca/per-step configuration")
    ap.add_argument("--deferred", action="store_true",
                    help="deferred re-ranking: traverse on filter "
                         "distances, one batched Dist.H per query")
    ap.add_argument("--rerank-mult", type=int, default=None,
                    help="deferred-rerank candidate multiplier "
                         "(default: cfg.rerank_mult)")
    ap.add_argument("--shards", type=int, default=1,
                    help="shard the database P ways and measure the "
                         "distributed path (perf-smoke and churn "
                         "benches); forces P simulated host devices so "
                         "the mesh collective path runs, and never "
                         "touches the tracked BENCH_table3.json entry")
    args = ap.parse_args()
    if args.shards > 1:
        # must precede the first jax import anywhere below
        import os
        flags = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.shards}").strip()
    n_points = args.n_points or \
        (8_000 if args.fast or args.perf_smoke else 50_000)
    n_queries = 64 if args.fast or args.perf_smoke else 200
    json_path = str(Path(__file__).resolve().parents[1]
                    / "BENCH_table3.json")

    from benchmarks import (bench_build, bench_churn, bench_faults,
                            bench_fig2_kselect, bench_fig5_energy,
                            bench_kernel_footprint, bench_load,
                            bench_pq_ablation, bench_table3_qps)

    if args.load:
        print("name,us_per_call,derived")
        t0 = time.time()
        n = args.n_points or 8_000
        # the tracked "load" section pins the canonical 8k
        # configuration; other sizes are CSV-only (CI gates on 2k)
        jp = json_path if n == 8_000 else None
        bench_load.main(n_points=n, n_queries=64, json_path=jp,
                        prom_path=args.prom_out)
        if jp:
            print(f"# wrote {jp} (load section)", file=sys.stderr)
        print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
        return

    if args.build:
        print("name,us_per_call,derived")
        t0 = time.time()
        n = args.n_points or 8_000
        # the tracked "build" section pins the canonical 8k /
        # default-wave configuration; other sizes are CSV-only
        jp = json_path if (n == 8_000 and args.wave_size is None) \
            else None
        bench_build.main(n_points=n, n_queries=n_queries,
                         json_path=jp, wave_size=args.wave_size)
        if jp:
            print(f"# wrote {jp} (build section)", file=sys.stderr)
        print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
        return

    if args.faults:
        print("name,us_per_call,derived")
        t0 = time.time()
        n = args.n_points or 8_000
        # the tracked "faults" section pins the canonical 8k/P=4
        # configuration; other sizes are CSV-only (CI gates on 2k)
        jp = json_path if n == 8_000 else None
        bench_faults.main(n_points=n, n_queries=64, n_shards=4,
                          json_path=jp)
        if jp:
            print(f"# wrote {jp} (faults section)", file=sys.stderr)
        print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
        return

    if args.churn:
        print("name,us_per_call,derived")
        t0 = time.time()
        # an explicit --n-points is honored; only the default shrinks
        bench_churn.main(n_points=args.n_points or 8_000,
                         n_queries=n_queries, n_shards=args.shards)
        print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
        return

    if args.perf_smoke:
        print("name,us_per_call,derived")
        t0 = time.time()
        bench_table3_qps.main(n_points=n_points, n_queries=n_queries,
                              json_path=json_path,
                              filter_kind=args.filter_kind,
                              deferred=args.deferred,
                              rerank_mult=args.rerank_mult,
                              n_shards=args.shards)
        if args.filter_kind == "pca" and not args.deferred \
                and args.shards == 1:
            print(f"# wrote {json_path}", file=sys.stderr)
        print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
        return

    print("name,us_per_call,derived")
    t0 = time.time()
    # BENCH_table3.json tracks the fixed --perf-smoke configuration
    # only; full runs at other sizes must not overwrite it
    for mod, kwargs in (
        (bench_table3_qps, dict(n_points=n_points, n_queries=n_queries,
                                filter_kind=args.filter_kind,
                                deferred=args.deferred,
                                rerank_mult=args.rerank_mult,
                                n_shards=args.shards)),
        (bench_fig2_kselect, dict(n_points=n_points,
                                  n_queries=min(n_queries, 100))),
        (bench_fig5_energy, dict(n_points=n_points, n_queries=n_queries)),
        (bench_kernel_footprint, {}),
        (bench_pq_ablation, dict(n_points=n_points,
                                 n_queries=min(n_queries, 64))),
        (bench_churn, dict(n_points=args.n_points or 8_000,
                           n_queries=min(n_queries, 64),
                           n_shards=args.shards)),
    ):
        try:
            mod.main(**kwargs)
        except Exception:
            print(f"# {mod.__name__} FAILED", file=sys.stderr)
            traceback.print_exc()
            raise
    # roofline appendix (if the dry-run has been run)
    try:
        from repro.launch.roofline import load_all
        rows = load_all("pod16x16")
        if rows:
            for r in rows:
                step_s = max(r["compute_s"], r["memory_s"],
                             r["collective_s"])
                print(f"roofline/{r['arch']}/{r['shape']},"
                      f"{step_s * 1e6:.1f},"
                      f"bottleneck={r['bottleneck']};"
                      f"roofline_frac={r['roofline_fraction']};"
                      f"useful_flops={r['useful_flops_ratio']}")
    except Exception:
        pass
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
