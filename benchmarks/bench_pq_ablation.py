"""Filter-stage A/B on the REAL batched engine (was: a host-only
ranking toy). Same graph, same queries, same compiled traversal — only
the pluggable filter stage (core/filters.py) swaps:

  pca            — the paper's dense low-dim projection (60 B/vec),
  pq             — Flash [15]-style product quantization scored by the
                   fused on-device ADC kernel (16 B/vec, 3.75x smaller),
  pq64           — PQ at the MATCHED byte budget (64 B/vec ~ PCA-15's
                   60): the "quantized filtering at equal memory"
                   question this ablation exists to answer,
  none           — filter bypass (HNSW-Std: every neighbor re-ranked),
  pca-deferred   — PCA filter + deferred re-ranking (traversal in
                   filter space, ONE batched Dist.H per query),
  cascade-deferred — the multi-stage cascade: PQ-code traversal (16
                   B/vec inline), a PCA promote pass over the wide
                   layer-0 exit list (60 B/vec side-car, touched once
                   per query instead of every step), ONE batched
                   Dist.H — PQ-class hot-stream bytes at
                   PCA-deferred-class recall.

Reported per mode: measured QPS, recall@10, mean Dist.H evaluations
per query (the high-dim traffic the filter exists to shrink), and the
payload bytes/vec (the memory cost it pays — inline hot-stream bytes,
plus the cascade's off-stream side-car reported separately). This
replaces the old synthetic frontier protocol with end-to-end numbers
where traversal effects (threshold feedback, frontier ordering) are
included.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import batched_filter_ab, emit, load_bench_db


def main(n_points: int = 50_000, n_queries: int = 64):
    cfg, x, g, pca, x_low, q, gt = load_bench_db(n_points, n_queries)
    ab = batched_filter_ab(cfg, x, g, pca, q, gt,
                           batch=min(64, len(q)),
                           modes=[("pca", False), ("pq", False),
                                  ("pq64", False), ("none", False),
                                  ("pca", True), ("cascade", True)])
    rows = []
    for m in ab:
        rows.append((f"pq_ablation/{m['name']}", m["us_per_query"],
                     f"qps={m['qps']:.0f};recall@10={m['recall']:.3f};"
                     f"dist_h_mean={m['dist_h_mean']:.1f};"
                     f"bytes_per_vec={m['bytes_per_vec']};"
                     f"sidecar_bytes_per_vec="
                     f"{m['sidecar_bytes_per_vec']};"
                     f"rerank_mult={m['rerank_mult']};"
                     f"promote_mult={m['promote_mult']}"))
    return emit(rows)


if __name__ == "__main__":
    main()
