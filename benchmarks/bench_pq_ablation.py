"""Filter ablation: the paper's PCA filter vs Flash [15]-style PQ, at
matched and unmatched byte budgets.

Protocol: for each query take its true top-200 high-dim candidates plus
1800 random distractors (a stand-in for an expansion frontier), rank
them with each low-cost filter, keep the top-16 (the paper's layer-0 k)
and measure how many of the true top-10 survive — filter recall, the
quantity that bounds pHNSW's end recall.

Budgets: PCA-15 = 60 B/vec (the paper's choice); PQ-16 = 16 B/vec
(3.75x smaller); PQ-64 = 64 B/vec (matched).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, load_bench_db
from repro.core.pq import adc_distances, adc_table, encode_pq, train_pq


def _filter_recall(rank_scores, cand_ids, true10, k=16):
    order = np.argsort(rank_scores)[:k]
    kept = set(cand_ids[order].tolist())
    return len(kept & set(true10.tolist())) / len(true10)


def main(n_points: int = 50_000, n_queries: int = 64):
    cfg, x, g, pca, x_low, q, gt = load_bench_db(n_points, n_queries)
    rng = np.random.default_rng(0)
    n2 = (x * x).sum(1)

    pq16 = train_pq(x[:20000], 16)          # 16 B/vec
    pq64 = train_pq(x[:20000], 64)          # 64 B/vec (~matched to PCA-15)
    codes16 = encode_pq(pq16, x)
    codes64 = encode_pq(pq64, x)

    rec = {"pca15": [], "pq16": [], "pq64": [], "exact": []}
    for i in range(n_queries):
        d_true = n2 - 2.0 * (x @ q[i])
        top200 = np.argsort(d_true)[:200]
        distract = rng.integers(0, len(x), 1800)
        cand = np.unique(np.concatenate([top200, distract]))
        true10 = gt[i][:10]
        # PCA filter
        ql = pca.transform(q[i][None])[0]
        d_pca = ((x_low[cand] - ql) ** 2).sum(1)
        rec["pca15"].append(_filter_recall(d_pca, cand, true10))
        # PQ filters
        t16 = adc_table(pq16, q[i])
        rec["pq16"].append(_filter_recall(
            adc_distances(t16, codes16[cand]), cand, true10))
        t64 = adc_table(pq64, q[i])
        rec["pq64"].append(_filter_recall(
            adc_distances(t64, codes64[cand]), cand, true10))
        rec["exact"].append(_filter_recall(d_true[cand], cand, true10))

    rows = []
    for name, bytes_per in (("pca15", 60), ("pq16", 16), ("pq64", 64),
                            ("exact", 512)):
        rows.append((f"pq_ablation/{name}", 0.0,
                     f"filter_recall@10={np.mean(rec[name]):.3f};"
                     f"bytes_per_vec={bytes_per}"))
    return emit(rows)


if __name__ == "__main__":
    main()
