"""Fault-tolerance benchmark: the recall-vs-dead-shards curve plus the
kill -> degraded-serve -> replica-failover -> snapshot-reseed -> recover
cycle, with zero-recompile accounting (DESIGN.md § Fault tolerance).

Two recall yardsticks per dead-shard count:

* ``recall_full``  — against the FULL live ground truth: the price of
  losing shards (necessarily ~ coverage-bounded: a query whose true
  neighbors lived on a dead shard cannot recall them);
* ``recall_survivor`` — against ground truth over the SURVIVING live
  vectors only: what degraded mode is responsible for. This is the
  gated floor (>= 0.90 at P=4 with one dead shard): the survivors must
  answer as well as a healthy index built on just them.

The canonical 8k/P=4 run appends the tracked ``faults`` section of
``BENCH_table3.json`` (own append-only history, like ``build``); other
sizes are CSV-only, so CI can gate on a small seeded run without
touching the tracked trajectory.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Optional

import numpy as np

from benchmarks.common import emit


def _survivor_gt(idx, q: np.ndarray, mask: np.ndarray, at: int = 10
                 ) -> np.ndarray:
    """Exact top-``at`` over the live vectors of the SURVIVING shards,
    as global ids."""
    from repro.data.vectors import brute_force_topk
    xs, gids = [], []
    for s_i, s in enumerate(idx.shards):
        if not mask[s_i]:
            continue
        li = s.live_ids()
        xs.append(s.x[li])
        gids.append(li + s_i * idx.stride)
    g = np.concatenate(gids)
    return g[brute_force_topk(np.concatenate(xs), q, at)]


def _recall(fi: np.ndarray, gt: np.ndarray, at: int = 10) -> float:
    from repro.core.search_ref import recall_at
    return float(np.mean([recall_at(fi[i], gt[i], at)
                          for i in range(len(gt))]))


def main(n_points: int = 8_000, n_queries: int = 64, n_shards: int = 4,
         json_path: Optional[str] = None, seed: int = 0,
         reps: int = 5):
    from repro.configs.sift1m_phnsw import SMALL
    from repro.core import distributed as dist
    from repro.data.vectors import make_queries, make_sift_like
    from repro.distributed import faults
    from repro.distributed.faults import FaultPlan, FaultPolicy
    from repro.index import ShardedMutableIndex
    from repro.serve import ReplicaSet, VectorSearchService

    cfg = SMALL.__class__(**{**SMALL.__dict__, "n_points": n_points,
                             "name": f"faults{n_points // 1000}k",
                             "ef_construction": 32})
    x = make_sift_like(n_points, seed=11)
    q = make_queries(x, n_queries, seed=12)
    B = min(64, n_queries)
    qb = q[:B]

    idx = ShardedMutableIndex.build(x, cfg, n_shards, seed=1)
    # ground truth in the sharded GLOBAL id space (gid = shard * stride
    # + local), which is what searches return
    gt_full = idx.live_ground_truth(qb, 10)
    pol = FaultPolicy(deadline_ms=250.0, max_retries=2, backoff_ms=5.0,
                      dead_after_failures=2)
    svc = VectorSearchService(idx, batch_size=B, fault_policy=pol)

    rows, curve = [], []

    # ---- recall / coverage / latency vs dead shards (the tracked
    # degradation curve; masks are data — one compiled program) ----
    idx.search(qb, live=np.ones(n_shards, bool))[1].block_until_ready()
    for k_dead in range(n_shards):
        mask = np.ones(n_shards, bool)
        mask[:k_dead] = False
        fd = fi = None
        t0 = time.perf_counter()
        for _ in range(reps):
            fd, fi, st = idx.search(qb, live=mask, return_stats=True)
            fi.block_until_ready()
        us = (time.perf_counter() - t0) / reps / B * 1e6
        fi = np.asarray(fi)
        rec_full = _recall(fi, gt_full)
        rec_surv = _recall(fi, _survivor_gt(idx, qb, mask))
        cov = st["coverage"]
        curve.append({"dead_shards": k_dead, "coverage": cov,
                      "recall_full": rec_full,
                      "recall_survivor": rec_surv,
                      "us_per_query": us})
        rows.append((f"faults/dead{k_dead}", us,
                     f"coverage={cov:.4f};recall_full={rec_full:.3f};"
                     f"recall_survivor={rec_surv:.3f};"
                     f"live_shards={int(mask.sum())}/{n_shards}"))

    # ---- the full cycle: kill -> degraded -> failover -> reseed ->
    # recover, recompile counters frozen across all of it ----
    rs = ReplicaSet.replicate(svc, 2)
    rs.query(qb)                              # both replicas warm
    counters = (dist.search_cache_sizes(), dist.resilient_cache_sizes())

    t0 = time.perf_counter()
    for _ in range(reps):
        rs.query(qb)
    healthy_ms = (time.perf_counter() - t0) / reps * 1e3

    plan = faults.install(FaultPlan(seed=seed))
    plan.add("kill_shard", 0)
    rs.query(qb)                              # pays detection+retries
    t0 = time.perf_counter()
    for _ in range(reps):
        _, _, st = rs.query(qb, return_stats=True)
    degraded_ms = (time.perf_counter() - t0) / reps * 1e3
    degraded_cov = st["coverage"]

    plan.add("kill_replica", 0)               # primary replica dies
    t0 = time.perf_counter()
    rs.query(qb)                              # fails over mid-request
    failover_ms = (time.perf_counter() - t0) * 1e3

    plan.heal()                               # faults repaired
    faults.clear()
    t0 = time.perf_counter()
    rs.recover(0)                             # snapshot ship + replay
    reseed_ms = (time.perf_counter() - t0) * 1e3
    for r in rs.replicas:                     # shard dead-marks clear
        if r.svc.health is not None:
            for s in range(n_shards):
                r.svc.recover_shard(s)
    _, _, st = rs.query(qb, return_stats=True)
    recovered_cov = st["coverage"]

    zero_recompiles = (dist.search_cache_sizes(),
                       dist.resilient_cache_sizes()) == counters
    rows.append(("faults/cycle", degraded_ms * 1e3 / B,
                 f"healthy_ms={healthy_ms:.2f};"
                 f"degraded_ms={degraded_ms:.2f};"
                 f"degraded_coverage={degraded_cov:.4f};"
                 f"failover_ms={failover_ms:.2f};"
                 f"reseed_ms={reseed_ms:.1f};"
                 f"recovered_coverage={recovered_cov:.4f};"
                 f"zero_recompiles={int(zero_recompiles)}"))

    if json_path:
        entry = {
            "bench": "faults",
            "n_points": n_points,
            "n_shards": n_shards,
            "batch": B,
            "curve": curve,
            "healthy_query_ms": healthy_ms,
            "degraded_query_ms": degraded_ms,
            "failover_ms": failover_ms,
            "reseed_ms": reseed_ms,
            "zero_recompiles": bool(zero_recompiles),
        }
        p = Path(json_path)
        doc = {}
        if p.exists():
            try:
                doc = json.loads(p.read_text())
            except ValueError as e:
                # never silently replace a corrupted tracked trajectory
                raise RuntimeError(
                    f"{p} exists but is not valid JSON; refusing to "
                    f"overwrite the tracked trajectory") from e
        prev = doc.get("faults")
        history = []
        if isinstance(prev, dict):
            history = prev.pop("history", [])
            history.append(prev)
        doc["faults"] = {**entry, "history": history}
        p.write_text(json.dumps(doc, indent=2) + "\n")
    return emit(rows)


if __name__ == "__main__":
    main()
